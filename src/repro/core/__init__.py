"""MF-Net core: the paper's contribution as composable JAX modules."""

from repro.core.cim import (CimConfig, CimKernelState, CimPartials,
                            CimWeightState, ProjectionSilicon,
                            cim_input_partials, cim_mf_matmul,
                            cim_mf_matmul_ste, cim_mf_partials,
                            cim_mf_recombine, cim_program_kernel_state,
                            cim_program_weight_state)
from repro.core.energy import (DEFAULT_MACRO, MacroParams,
                               mixed_system_tops_per_watt, tops_per_watt,
                               unit_op_cycles, unit_op_energy_j)
from repro.core.mapping import (FleetMappingPolicy, LayerStat, MappingPolicy,
                                MappingReport, plan_mapping)
from repro.core.mf import (ExecMode, apply_projection, dense_init, hw_sign,
                           mf_conv2d, mf_correlate_ref,
                           mf_correlate_step_form, mf_dense_init, mf_matmul)
from repro.core.programmed import (CimLosslessState, CimPackedPlanes,
                                   ProgrammedLayer, ProgrammedMacro,
                                   cim_mf_matmul_programmed, iter_projections,
                                   map_projections, pack_weight_state,
                                   program_macro, program_weights,
                                   programmed_bytes,
                                   programmed_bytes_unpacked,
                                   strip_programmed, unpack_weight_state)
from repro.core.quant import fake_quant, quantize, dequantize, calibrate_scale
from repro.core.variability import (VariabilityConfig,
                                    mav_crossover_probability,
                                    sample_cap_weights,
                                    sample_comparator_offset, screen_columns)

__all__ = [
    "CimConfig", "CimKernelState", "CimPartials", "CimWeightState",
    "ProjectionSilicon",
    "cim_input_partials", "cim_mf_matmul", "cim_mf_matmul_ste",
    "cim_mf_partials", "cim_mf_recombine", "cim_program_kernel_state",
    "cim_program_weight_state", "CimLosslessState", "CimPackedPlanes",
    "ProgrammedLayer", "ProgrammedMacro",
    "cim_mf_matmul_programmed", "iter_projections", "map_projections",
    "pack_weight_state", "program_macro", "program_weights",
    "programmed_bytes", "programmed_bytes_unpacked", "strip_programmed",
    "unpack_weight_state", "DEFAULT_MACRO",
    "MacroParams", "mixed_system_tops_per_watt", "tops_per_watt",
    "unit_op_cycles", "unit_op_energy_j", "FleetMappingPolicy", "LayerStat",
    "MappingPolicy", "MappingReport", "plan_mapping", "ExecMode",
    "apply_projection",
    "dense_init", "hw_sign", "mf_conv2d", "mf_correlate_ref",
    "mf_correlate_step_form", "mf_dense_init", "mf_matmul", "fake_quant",
    "quantize", "dequantize", "calibrate_scale", "VariabilityConfig",
    "mav_crossover_probability", "sample_cap_weights",
    "sample_comparator_offset", "screen_columns",
]
