"""Fixed-point quantisation (paper Sec. III / Fig. 2c).

The paper operates the MF network at 8-bit fixed-precision inputs/weights
with accuracy equivalent to float. We provide symmetric signed quantisers
(per-tensor and per-channel max-abs calibration), fake-quant with a
straight-through estimator for QAT, and integer encode/decode used by the
CIM bitplane path.

A b-bit symmetric signed code uses the integer grid [-(2^(b-1)-1),
2^(b-1)-1] (no -2^(b-1): the hardware stores sign + (b-1) magnitude
bitplanes, so codes are sign-magnitude symmetric).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def qmax(bits: int) -> int:
    """Largest magnitude code for a b-bit symmetric signed format."""
    return 2 ** (bits - 1) - 1


def calibrate_scale(v: jax.Array, bits: int, axis: Optional[int] = None,
                    eps: float = 1e-8) -> jax.Array:
    """Max-abs scale such that v/scale fits the b-bit grid.

    axis=None -> per-tensor scalar scale; axis=k -> per-channel along k
    (scale shape broadcastable against v with that axis reduced).
    """
    if axis is None:
        amax = jnp.max(jnp.abs(v))
    else:
        amax = jnp.max(jnp.abs(v), axis=axis, keepdims=True)
    return jnp.maximum(amax, eps) / qmax(bits)


def quantize(v: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Encode to the integer grid (returned as int32)."""
    q = jnp.round(v / scale)
    return jnp.clip(q, -qmax(bits), qmax(bits)).astype(jnp.int32)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(scale.dtype if hasattr(scale, "dtype") else jnp.float32) * scale


def fake_quant(v: jax.Array, bits: int, axis: Optional[int] = None) -> jax.Array:
    """Quantise-dequantise with a straight-through gradient (QAT)."""
    scale = calibrate_scale(v, bits, axis)
    q = dequantize(quantize(v, scale, bits), scale)
    # STE: forward q, backward identity.
    return v + jax.lax.stop_gradient(q - v)


def bitplanes(mag: jax.Array, bits: int) -> jax.Array:
    """Decompose non-negative integer magnitudes into bitplanes.

    mag: (...,) int32 in [0, 2^(bits-1)-1] -> (bits-1, ...) float32 planes,
    plane p holding bit p (LSB first). The hardware stores |w| as
    (bits-1) magnitude rows in a µArray (the sign occupies its own row).
    """
    nplanes = bits - 1
    shifts = jnp.arange(nplanes, dtype=jnp.int32)
    planes = (mag[None, ...] >> shifts.reshape((nplanes,) + (1,) * mag.ndim)) & 1
    return planes.astype(jnp.float32)


def from_bitplanes(planes: jax.Array) -> jax.Array:
    """Inverse of ``bitplanes`` (plane axis leading)."""
    nplanes = planes.shape[0]
    weights = (2.0 ** jnp.arange(nplanes)).reshape(
        (nplanes,) + (1,) * (planes.ndim - 1))
    return jnp.sum(planes * weights, axis=0)
