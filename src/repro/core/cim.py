"""Behavioural compute-in-SRAM simulator (paper Sec. IV).

Models the µArray execution of the MF operator bit-for-bit:

  * operands quantised to W_P-bit weights / X_P-bit inputs (sign-magnitude),
  * |w| bitplanes stored as rows, one output channel per µArray,
  * the K contraction dim split into column chunks of M (µArray half width);
    padded columns store 0 so they never discharge (denominator stays M),
  * per (chunk, plane): multiply-average MAV = (1/M) sum_j bit_pj * step_j,
  * SA-ADC digitisation of each MAV to A_P bits (uniform mid-tread code on
    [0, 1]; code = round(MAV * (2^A_P - 1)) — exactly lossless when
    2^A_P >= M + 1, reproducing the paper's 8x62 -> 5-bit / 8x30 -> 4-bit
    pairings),
  * Eq. 2 recombination with the two residues: sum|x| via an ADC'd dummy
    all-ones row, sum|w| as an exact digital weight statistic.

Optional process variability (the silicon lab, ``repro.silicon``) perturbs
the charge averaging with per-column capacitor mismatch and adds comparator
offset before digitisation — either one shared draw (legacy
``cap_weights``/``comparator_offset``) or one sampled ADC instance per
µArray tile slot (:class:`ProjectionSilicon`).

The datapath is split along the hardware's program-time / step-time
boundary: ``cim_program_weight_state`` / ``cim_program_kernel_state`` do
everything that depends only on the weights (quantise, sign/magnitude
decompose, chunk or kernel-pack, digital residue) and the step-time passes
(``cim_input_partials`` / ``cim_kernel_forward``) consume that frozen
state, computing only the input-side work per call. ``cim_mf_partials`` /
``cim_mf_matmul`` compose both phases on the fly; ``core/programmed.py``
builds persistent programmed state for weight-stationary serving.

This path is forward-only hardware emulation; ``cim_mf_matmul_ste`` wraps it
with a straight-through estimator whose backward is the float MF surrogate
gradient, enabling hardware-in-the-loop QAT.
"""
# repro-lint: module=exactness-critical,step-time

from __future__ import annotations

import contextlib
import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.mf import mf_matmul


# ---------------------------------------------------------------------------
# Conversion clock (per-stream thermal dither)
# ---------------------------------------------------------------------------
#
# Thermal noise is a PER-CONVERSION phenomenon: every SA-ADC evaluation sees
# a fresh input-referred sample, unlike the static per-slot mismatch/offset
# lottery. The dither draw is keyed by (projection noise key, stream step,
# role salt), so the serving engine threads its input-stream counter into
# the jitted decode through this clock — a tap-style trace-time holder, the
# same idiom as ``repro.calib.tap``. Outside any clock the step is 0
# (single-shot forwards stay deterministic and reproducible).

_CONV_STEP: list = [None]


@contextlib.contextmanager
def conversion_clock(step):
    """Install ``step`` (int or traced scalar) as the current stream index
    for per-conversion thermal dither. Wrap the TRACE of a jitted forward
    (the engine wraps ``lm_decode_step`` / ``lm_prefill_cache``); the
    traced value is baked into the noise-key fold of every silicon ADC
    evaluation inside."""
    prev = _CONV_STEP[0]
    _CONV_STEP[0] = step
    try:
        yield
    finally:
        _CONV_STEP[0] = prev


def conversion_step():
    """Current conversion-clock value (0 when no clock is installed)."""
    s = _CONV_STEP[0]
    return 0 if s is None else s


@dataclasses.dataclass(frozen=True)
class CimConfig:
    """Geometry + precision of a compute-in-SRAM macro.

    The paper's two design points:
      8x62 µArray: m_columns=31 (per half), adc_bits=5  (~105 TOPS/W)
      8x30 µArray: m_columns=15 (per half), adc_bits=4  (~84 TOPS/W)
    """

    w_bits: int = 8          # weight precision W_P (sign + W_P-1 planes)
    x_bits: int = 8          # input precision
    adc_bits: int = 5        # SA-ADC precision A_P
    m_columns: int = 31      # columns per µArray half (vector-parallelism l)
    use_kernel: bool = False  # route the MAV loop through the Pallas kernel

    @property
    def w_planes(self) -> int:
        return self.w_bits - 1

    @property
    def x_planes(self) -> int:
        return self.x_bits - 1


def adc_codes(mav: jax.Array, adc_bits: int,
              comparator_offset: Optional[jax.Array] = None) -> jax.Array:
    """SA-ADC transfer returning the raw integer code (as float32).

    code = clip(round(mav * (2^A_P - 1))): the capacitive-DAC binary search
    settles on the nearest of 2^A_P evenly spaced reference levels. A
    comparator offset (fraction of full scale) shifts every comparison.

    Codes are small integers (<= 2^A_P - 1), exactly representable in
    float32 — every downstream accumulation of codes is therefore exact,
    which is what lets the tiled compiler path (repro.compiler.execute)
    reproduce the monolithic result bit for bit.
    """
    levels = 2 ** adc_bits - 1
    v = mav if comparator_offset is None else mav + comparator_offset
    codes = jnp.clip(jnp.round(v * levels), 0, levels)
    from repro.analysis import sanitize
    if sanitize.tripwires_armed():
        # REPRO_SANITIZE=1 only: stage a NaN/saturation tripwire callback
        # per conversion (armed at trace time; each engine owns a fresh
        # jit cache, so production traces carry no callback).
        sanitize.stage_conversion_tripwire(codes, float(levels))
    return codes


def adc_quantize(mav: jax.Array, adc_bits: int,
                 comparator_offset: Optional[jax.Array] = None) -> jax.Array:
    """SA-ADC transfer: uniform A_P-bit code on [0,1], returned dequantised."""
    return adc_codes(mav, adc_bits, comparator_offset) / (2 ** adc_bits - 1)


# Fractional bits of the cap-DAC fixed-point grid. The tail-current trim
# DACs that set each unit cap's effective weight have finite resolution;
# modelling them on a 2^-14 grid (~6e-5 of a unit cap, far below the
# sigma~0.02 mismatch being modelled) buys an exactness property the
# float-valued model cannot have: every pre-ADC numerator is a sum of
# {0,1}-gated cap products, i.e. an integer multiple of 2^-14 bounded far
# below 2^24 quanta — EXACT in float32 under any summation order. The
# fused Pallas kernel's per-chunk dot and XLA's einsum contraction then
# produce bit-identical numerators, hence identical integer ADC codes
# (the sigma>0 kernel-vs-reference parity gate of BENCH_silicon.json).
CAP_FIXED_BITS = 14


def cap_fixed(cap: jax.Array) -> jax.Array:
    """Quantise cap-DAC weights to the 2^-CAP_FIXED_BITS fixed-point grid.

    Applied identically by the reference einsum routes and the program-
    time kernel fold (:func:`cim_program_silicon`). At sigma=0 every cap
    is exactly 1.0 — a grid point — so the quantisation is the identity
    and all nominal-collapse invariants are untouched.
    """
    s = jnp.float32(2.0 ** CAP_FIXED_BITS)
    return jnp.round(cap.astype(jnp.float32) * s) / s


def _weight_operands(w: jax.Array, cfg: CimConfig, sw: jax.Array):
    """Quantise the weight operand and decompose into sign gates + planes.

    Sign bits are stored SEPARATELY from the magnitude planes in the
    µArray (sign row + W_P-1 magnitude rows), so they come from the
    ORIGINAL operand sign — a weight whose magnitude truncates to zero
    keeps its true sign bit (quantising first would flip small negative
    weights to +, a large systematic error at low W_P).

    Returns (step_w, abs_w, w_planes): {0,1} sign gates (K, N), integer
    magnitudes (K, N), and the (Pw, K, N) bitplane stack (LSB first).
    """
    wq = quant.quantize(w, sw, cfg.w_bits)          # (K, N) int
    step_w = (w >= 0).astype(jnp.float32)           # (K, N)
    abs_w = jnp.abs(wq)
    w_planes = quant.bitplanes(abs_w, cfg.w_bits)   # (Pw, K, N)
    return step_w, abs_w, w_planes


def _input_operands(x2: jax.Array, cfg: CimConfig, sx: jax.Array):
    """Input-side mirror of :func:`_weight_operands` (same conventions)."""
    xq = quant.quantize(x2, sx, cfg.x_bits)         # (B, K) int
    step_x = (x2 >= 0).astype(jnp.float32)          # (B, K)
    abs_x = jnp.abs(xq)
    x_planes = quant.bitplanes(abs_x, cfg.x_bits)   # (Px, B, K)
    return step_x, abs_x, x_planes


def _chunk(v: jax.Array, m: int, axis_len: int) -> jax.Array:
    """Pad the contraction axis (last) to a multiple of m and fold it."""
    pad = (-axis_len) % m
    if pad:
        v = jnp.pad(v, [(0, 0)] * (v.ndim - 1) + [(0, pad)])
    return v.reshape(v.shape[:-1] + ((axis_len + pad) // m, m))


class CimPartials(NamedTuple):
    """Pre-recombination macro statistics of one (x, w) tile.

    All four fields are *integer-valued* float32 arrays (plane-weighted sums
    of SA-ADC codes / digital |w| counts), so summing the partials of K-tiles
    is exact in float32 — the foundation of the compiler's bit-exact tiled
    execution. Recombine with :func:`cim_mf_recombine`.
    """

    s1c: jax.Array   # (B, N) plane-weighted code sum, Eq. 2b numerator side
    s2c: jax.Array   # (B, N) plane-weighted code sum, Eq. 2a numerator side
    rxc: jax.Array   # (B, 1) plane-weighted code sum of the |x| dummy row
    r_w: jax.Array   # (1, N) exact digital sum_k |w_q|_kn

    def __add__(self, other: "CimPartials") -> "CimPartials":
        return CimPartials(self.s1c + other.s1c, self.s2c + other.s2c,
                           self.rxc + other.rxc, self.r_w + other.r_w)


class ProjectionSilicon(NamedTuple):
    """Per-tile sampled ADC instances of one macro-mapped (K, N) projection.

    The SA-ADC of the paper is *memory-immersed*: its capacitive DAC is the
    bit-line parasitic capacitance of the µArray half it digitises, so cap
    mismatch and comparator offset are properties of the physical SLOT a
    tile occupies, not of the weights programmed into it. This struct is
    the projection-shaped gather of a fleet's per-slot silicon state
    (:mod:`repro.silicon.instance` builds it): tile (c, n) — K-chunk ``c``
    of output channel ``n`` — reads the cap-DAC weights and comparator
    offset of the slot it is placed in. The |x| dummy-row conversion of
    chunk ``c`` (shared across every output channel) uses a designated
    per-chunk instance (``rx_*``, the slot of channel 0's tile).

    With all caps exactly 1.0 and all offsets exactly 0.0 the silicon
    route below is *bitwise identical* to the nominal fast path: every
    pre-ADC numerator is an integer-valued count, the denominator sums to
    exactly ``m``, and plane/code recombinations sum the same integers in
    a different order — exact in float32 (the σ=0 collapse gate of
    ``benchmarks/silicon_report.py``).

    ``thermal_fs``/``noise_key`` (both optional, absent by default) add the
    comparator's input-referred noise floor as PER-CONVERSION dither: every
    ADC evaluation draws a fresh N(0, thermal_fs²) sample keyed by
    (``noise_key``, the :func:`conversion_clock` stream step, a role salt),
    instead of the old static per-slot draw. Same key + same step ⇒ the
    same dither (replayable); consecutive stream steps decorrelate. Dither
    is drawn per *executed* conversion batch: layouts that batch
    conversions differently (round-interleaved swapped segments vs one
    pinned pass) draw independent — statistically equivalent — samples, so
    the bit-exactness invariants are guaranteed in the thermal_fs=None
    regime only (where every exactness gate runs).
    """

    cap: jax.Array        # (N, C, m) per-tile cap-DAC weights, 1.0 nominal
    offset: jax.Array     # (N, C) per-tile comparator offset (FS fraction)
    rx_cap: jax.Array     # (C, m) dummy-row conversion instance
    rx_offset: jax.Array  # (C,) dummy-row comparator offset
    thermal_fs: Optional[jax.Array] = None   # () noise RMS (FS fraction)
    noise_key: Optional[jax.Array] = None    # PRNG key of the dither stream

    def slice(self, n0: int, n1: int, k0: int, k1: int,
              m_columns: int) -> "ProjectionSilicon":
        """The silicon view of operand segment [k0:k1, n0:n1].

        ``k0`` must be M-chunk aligned (the tiled/swapped bit-exactness
        condition), so segment chunk boundaries coincide with the
        projection's global chunking. The dither stream is re-keyed by the
        segment origin so distinct segments draw independent samples.
        """
        if k0 % m_columns:
            raise ValueError(
                f"segment k0={k0} is not aligned to m_columns={m_columns}: "
                f"the sliced silicon chunks would not match the tiles")
        c0, c1 = k0 // m_columns, -(-k1 // m_columns)
        nkey = self.noise_key
        if nkey is not None and (n0 or c0):
            nkey = jax.random.fold_in(jax.random.fold_in(nkey, n0), c0)
        return ProjectionSilicon(self.cap[n0:n1, c0:c1],
                                 self.offset[n0:n1, c0:c1],
                                 self.rx_cap[c0:c1], self.rx_offset[c0:c1],
                                 self.thermal_fs, nkey)

    def dither(self, shape, salt: int) -> Optional[jax.Array]:
        """Per-conversion thermal dither for one ADC tensor (``None`` when
        the noise floor is off). ``salt`` separates the S1/S2/Rx roles of
        one stream step."""
        if self.thermal_fs is None:
            return None
        step = conversion_step()
        key = jax.random.fold_in(jax.random.fold_in(self.noise_key, step),
                                 salt)
        return self.thermal_fs * jax.random.normal(key, shape)


class CimWeightState(NamedTuple):
    """Program-time weight-side state of one macro-mapped projection.

    This is exactly what the hardware holds after the µArray is written:
    chunked sign gates and magnitude bitplanes plus the digital |w| residue.
    Built once by :func:`cim_program_weight_state`; every subsequent input
    streams through :func:`cim_input_partials` without touching ``w``.

    The arrays are stored contraction-ready for the step-time batched dot
    (chunk batch leading, the m columns as the contraction axis) so the
    hot loop never transposes the big weight-side operand — and the {0,1}
    cells are held as int8 (exactly the µArray's storage density class),
    quartering the bytes the bandwidth-bound decode step streams per
    token; the widening cast to f32 is exact on {0,1} so bit-exactness is
    untouched.
    """

    wt: jax.Array    # (C, m, N, Pw) int8 chunked |w| magnitude bitplanes
    gwt: jax.Array   # (C, m, N) int8 chunked step(w) sign gates
    r_w: jax.Array   # (1, N) exact digital sum_k |w_q|_kn


def cim_program_weight_state(w: jax.Array, cfg: CimConfig,
                             sw: jax.Array) -> CimWeightState:
    """Program-time pass: quantise/decompose/chunk the weights once."""
    K, N = w.shape
    step_w, abs_w, w_planes = _weight_operands(w, cfg, sw)
    m = cfg.m_columns
    wp = _chunk(jnp.moveaxis(w_planes, -1, 0), m, K)             # (N, Pw, C, m)
    wt = jnp.transpose(wp, (2, 3, 0, 1)).astype(jnp.int8)        # (C, m, N, Pw)
    gw = _chunk(step_w.T, m, K)                                  # (N, C, m)
    gwt = jnp.transpose(gw, (1, 2, 0)).astype(jnp.int8)          # (C, m, N)
    # exact-ok: integer |w_q| magnitudes, column sums below 2^24 — exact in f32
    r_w = jnp.sum(abs_w, axis=0).astype(jnp.float32)[None, :]    # (1, N)
    return CimWeightState(wt, gwt, r_w)


def cim_input_partials(x2: jax.Array, ws: CimWeightState, cfg: CimConfig,
                       sx: jax.Array,
                       cap_weights: Optional[jax.Array] = None,
                       comparator_offset: Optional[jax.Array] = None,
                       silicon: Optional[ProjectionSilicon] = None,
                       dac_gains: Optional[jax.Array] = None
                       ) -> CimPartials:
    """Step-time pass: stream x2:(B, Kt) through a programmed µArray.

    Only input-side work happens here (x quantisation against the static
    activation scale ``sx``, gates, MAVs, ADC) — the weight-side state was
    frozen at program time, mirroring the weight-stationary hardware.

    Bit-exactness across layouts: every pre-ADC MAV numerator is an
    integer-valued count (products of {0,1} gates and bits), exact in
    float32 for any summation order — so the nominal fast path below may
    contract in the program-time layout and still produce codes identical
    to the cap-weighted reference einsums.

    Variability injection, two flavours (mutually exclusive):
      * ``cap_weights`` (K,) + scalar ``comparator_offset`` — one shared
        mismatch draw across the projection (the legacy Fig. 8 model);
      * ``silicon`` — a :class:`ProjectionSilicon` giving every µArray
        TILE its own cap-DAC weights and comparator offset (the fleet-
        faithful per-slot model of ``repro.silicon``). With
        ``cfg.use_kernel`` the silicon state is folded into kernel
        operands and the fused Pallas route runs instead of the
        reference einsums (bit-identical codes by the fixed-point cap
        argument of :func:`cap_fixed`).

    ``dac_gains`` (K,) carries per-feature input-DAC gain trims <= 1 (the
    per-channel ``sx`` calibration of ``core.programmed``): the |x| bit
    stream is attenuated per column BEFORE the charge average, touching
    only the S2/R_x conversions (the sign-gate S1 stream is unscaled).
    """
    if silicon is not None and (cap_weights is not None
                                or comparator_offset is not None):
        raise ValueError(
            "pass either per-tile `silicon` or the legacy shared "
            "cap_weights/comparator_offset injection, not both")
    if dac_gains is not None and (silicon is not None
                                  or cap_weights is not None
                                  or comparator_offset is not None):
        raise ValueError(
            "per-channel DAC gain trims (per-channel sx calibration) do "
            "not compose with variability injection: the gain-cap "
            "products leave the fixed-point grid that guarantees "
            "cross-layout exactness. Program per-tensor scales for "
            "silicon-injected serving.")
    K = x2.shape[-1]
    m = cfg.m_columns

    if silicon is not None and cfg.use_kernel:
        # Fused Pallas fast path: fold the per-slot silicon state into
        # kernel operands and evaluate the SA-ADC instances in-kernel.
        ks = cim_kernel_state_from_weight_state(ws, cfg)
        silk = cim_program_silicon(ks, silicon, cfg, n_chunks=-(-K // m))
        return cim_kernel_silicon_partials(x2, ks, silk, cfg, sx, silicon)

    step_x, _, x_planes = _input_operands(x2, cfg, sx)

    def adc(mav: jax.Array) -> jax.Array:
        return adc_codes(mav, cfg.adc_bits, comparator_offset)

    pw = 2.0 ** jnp.arange(cfg.w_planes)
    px = 2.0 ** jnp.arange(cfg.x_planes)
    gx = _chunk(step_x, m, K)                                    # (B, C, m)
    xp = _chunk(x_planes, m, K)                                  # (Px, B, C, m)
    if dac_gains is not None:
        # Per-column attenuation of the streamed |x| bits (exact: gains
        # live on the cap_fixed grid, bits are {0,1}).
        xp = xp * _chunk(dac_gains.astype(jnp.float32)[None, :], m, K)[0]

    if silicon is not None:
        return _silicon_partials(gx, xp, ws, cfg, silicon, pw, px)

    if cap_weights is None and comparator_offset is None:
        # Nominal macro: the charge-average denominator is exactly m and
        # the counts are integers, so the contraction runs as a layout-
        # friendly batched dot straight against the program-time operand
        # layout — no per-step transpose of the weight state. (An offset
        # routes to the reference branch below: its broadcast contract is
        # defined against the (B, N, Pw, C) ADC tensor layout.)
        inv = jnp.float32(m)
        # S1 = sum_k step(x_k) * |w|_kn  (Eq. 2b numerator)
        # exact-ok: {0,1} bits x 2^-14-grid caps; integer quanta < 2^24 — exact in f32
        counts1 = jnp.einsum("bcm,cmnp->cbnp", gx,
                             ws.wt.astype(jnp.float32))
        codes1 = adc(counts1 / inv)                              # (C, B, N, Pw)
        # exact-ok: integer ADC codes x power-of-two plane weights — exact in f32
        s1c = jnp.einsum("cbnp,p->bn", codes1, pw)
        # S2 = sum_k step(w_kn) * |x|_k  (Eq. 2a numerator)
        # exact-ok: {0,1} bits x 2^-14-grid caps; integer quanta < 2^24 — exact in f32
        counts2 = jnp.einsum("qbcm,cmn->cqbn", xp,
                             ws.gwt.astype(jnp.float32))
        codes2 = adc(counts2 / inv)                              # (C, Px, B, N)
        # exact-ok: integer ADC codes x power-of-two plane weights — exact in f32
        s2c = jnp.einsum("cqbn,q->bn", codes2, px)
        # R_x via the dummy all-ones row (shared across weight vectors).
        rxc = _nominal_rx(xp, cfg)                               # (B, 1)
        return CimPartials(s1c, s2c, rxc, ws.r_w)

    # Variability injection: capacitor mismatch and/or comparator offset
    # change the charge averaging / digitisation, so run the general
    # cap-weighted einsums against the (N, Pw, C, m) reference layout.
    nchunks = -(-K // m)
    if cap_weights is None:
        cap = jnp.ones((nchunks, m), jnp.float32)
    else:
        cap = cap_fixed(_chunk(cap_weights.astype(jnp.float32)[None, :],
                               m, K)[0])
    # exact-ok: 2^-14-grid caps; small fixed-point chunk sums — exact in f32
    cap_sum = jnp.sum(cap, axis=-1)                              # (C,)
    wp = jnp.transpose(ws.wt.astype(jnp.float32),
                       (2, 3, 0, 1))                             # (N, Pw, C, m)
    gw = jnp.transpose(ws.gwt.astype(jnp.float32), (2, 0, 1))    # (N, C, m)
    # exact-ok: {0,1} bits x 2^-14-grid caps; integer quanta < 2^24 — exact in f32
    num1 = jnp.einsum("bcm,npcm,cm->bnpc", gx, wp, cap)
    codes1 = adc(num1 / cap_sum[None, None, None, :])            # (B, N, Pw, C)
    # exact-ok: integer ADC codes x power-of-two plane weights — exact in f32
    s1c = jnp.einsum("bnpc,p->bn", codes1, pw)
    # exact-ok: {0,1} bits x 2^-14-grid caps; integer quanta < 2^24 — exact in f32
    num2 = jnp.einsum("pbcm,ncm,cm->pbnc", xp, gw, cap)
    codes2 = adc(num2 / cap_sum[None, None, None, :])            # (Px, B, N, C)
    # exact-ok: integer ADC codes x power-of-two plane weights — exact in f32
    s2c = jnp.einsum("pbnc,p->bn", codes2, px)
    # exact-ok: {0,1} bits x 2^-14-grid caps; integer quanta < 2^24 — exact in f32
    num_rx = jnp.einsum("pbcm,cm->pbc", xp, cap)
    codes_rx = adc(num_rx / cap_sum[None, None, :])              # (Px, B, C)
    # exact-ok: integer ADC codes x power-of-two plane weights — exact in f32
    rxc = jnp.einsum("pbc,p->b", codes_rx, px)[:, None]          # (B, 1)
    return CimPartials(s1c, s2c, rxc, ws.r_w)


def _silicon_partials(gx: jax.Array, xp: jax.Array, ws: CimWeightState,
                      cfg: CimConfig, sil: ProjectionSilicon,
                      pw: jax.Array, px: jax.Array) -> CimPartials:
    """Per-tile silicon route: every (chunk, channel) tile digitises with
    its own sampled cap-DAC weights and comparator offset.

    The zero-padded tail columns of the final chunk keep their sampled
    capacitance in the denominator (a padded cell stores 0 and never
    discharges, but its bit-line parasitic still loads the DAC) — at σ=0
    the denominator is therefore exactly ``m`` and this route collapses
    bitwise to the nominal fast path.
    """
    nchunks, n_out = gx.shape[-2], ws.wt.shape[2]
    if sil.cap.shape != (n_out, nchunks, cfg.m_columns):
        raise ValueError(
            f"silicon cap shape {sil.cap.shape} does not match this "
            f"projection's ({n_out}, {nchunks}, {cfg.m_columns}) tiles")
    cap = cap_fixed(sil.cap)                                     # (N, C, m)
    # exact-ok: 2^-14-grid caps; small fixed-point chunk sums — exact in f32
    cap_sum = jnp.sum(cap, axis=-1)                              # (N, C)
    off = sil.offset.astype(jnp.float32)                         # (N, C)
    wp = jnp.transpose(ws.wt.astype(jnp.float32),
                       (2, 3, 0, 1))                             # (N, Pw, C, m)
    gw = jnp.transpose(ws.gwt.astype(jnp.float32), (2, 0, 1))    # (N, C, m)
    # exact-ok: {0,1} bits x 2^-14-grid caps; integer quanta < 2^24 — exact in f32
    num1 = jnp.einsum("bcm,npcm,ncm->bnpc", gx, wp, cap)
    off1 = off[:, None, :]
    d1 = sil.dither(num1.shape, 1)
    if d1 is not None:
        off1 = off1 + d1
    codes1 = adc_codes(num1 / cap_sum[:, None, :], cfg.adc_bits,
                       off1)                                     # (B, N, Pw, C)
    # exact-ok: integer ADC codes x power-of-two plane weights — exact in f32
    s1c = jnp.einsum("bnpc,p->bn", codes1, pw)
    # exact-ok: {0,1} bits x 2^-14-grid caps; integer quanta < 2^24 — exact in f32
    num2 = jnp.einsum("qbcm,ncm,ncm->qbnc", xp, gw, cap)
    off2 = off
    d2 = sil.dither(num2.shape, 2)
    if d2 is not None:
        off2 = off2 + d2
    codes2 = adc_codes(num2 / cap_sum, cfg.adc_bits, off2)       # (Px, B, N, C)
    # exact-ok: integer ADC codes x power-of-two plane weights — exact in f32
    s2c = jnp.einsum("qbnc,q->bn", codes2, px)
    rxc = _silicon_rx(xp, cfg, sil)                              # (B, 1)
    return CimPartials(s1c, s2c, rxc, ws.r_w)


def _silicon_rx(xp: jax.Array, cfg: CimConfig, sil: ProjectionSilicon
                ) -> jax.Array:
    """|x| dummy-row code sum digitised by the per-chunk rx instances."""
    px = 2.0 ** jnp.arange(cfg.x_planes)
    rx_cap = cap_fixed(sil.rx_cap)                               # (C, m)
    # exact-ok: 2^-14-grid caps; small fixed-point chunk sums — exact in f32
    rx_sum = jnp.sum(rx_cap, axis=-1)                            # (C,)
    # exact-ok: {0,1} bits x 2^-14-grid caps; integer quanta < 2^24 — exact in f32
    num_rx = jnp.einsum("qbcm,cm->qbc", xp, rx_cap)
    off_rx = sil.rx_offset.astype(jnp.float32)
    d_rx = sil.dither(num_rx.shape, 3)
    if d_rx is not None:
        off_rx = off_rx + d_rx
    codes_rx = adc_codes(num_rx / rx_sum, cfg.adc_bits,
                         off_rx)                                 # (Px, B, C)
    # exact-ok: integer ADC codes x power-of-two plane weights — exact in f32
    return jnp.einsum("qbc,q->b", codes_rx, px)[:, None]         # (B, 1)


def _nominal_rx(xp: jax.Array, cfg: CimConfig) -> jax.Array:
    """Nominal |x| dummy-row code sum from chunked x-planes (Px, B, C, m).

    The single implementation behind both :func:`cim_input_partials`'s
    ``rxc`` field and :func:`cim_rx_partials` — sharing it makes their
    bit-identity structural rather than hand-synchronised.
    """
    px = 2.0 ** jnp.arange(cfg.x_planes)
    # exact-ok: {0,1} x-plane bits -> integer counts — exact in f32
    counts_rx = jnp.sum(xp, axis=-1)                             # (Px, B, C)
    codes_rx = adc_codes(counts_rx / jnp.float32(cfg.m_columns),
                         cfg.adc_bits)
    # exact-ok: integer ADC codes x power-of-two plane weights — exact in f32
    return jnp.einsum("pbc,p->b", codes_rx, px)[:, None]         # (B, 1)


def cim_rx_partials(x2: jax.Array, cfg: CimConfig, sx: jax.Array,
                    silicon: Optional[ProjectionSilicon] = None
                    ) -> jax.Array:
    """|x| dummy-row code sum R_x over the FULL contraction dim.

    x2: (B, K) -> (B, 1). Bit-identical to the ``rxc`` field
    :func:`cim_input_partials` produces for the same (full-K) input slice:
    the dummy all-ones row is shared across every weight vector and has no
    N dependence, so round-interleaved execution (``core.programmed
    .cim_mf_matmul_swapped``) computes it once per input stream instead of
    accumulating it tile by tile. With ``silicon``, the per-chunk rx
    instances digitise the dummy row instead of the nominal ADC.
    """
    K = x2.shape[-1]
    _, _, x_planes = _input_operands(x2, cfg, sx)
    xp = _chunk(x_planes, cfg.m_columns, K)
    if silicon is not None:
        return _silicon_rx(xp, cfg, silicon)
    return _nominal_rx(xp, cfg)


def cim_mf_partials(x2: jax.Array, w: jax.Array, cfg: CimConfig,
                    sw: jax.Array, sx: jax.Array,
                    cap_weights: Optional[jax.Array] = None,
                    comparator_offset: Optional[jax.Array] = None,
                    silicon: Optional[ProjectionSilicon] = None
                    ) -> CimPartials:
    """µArray pass over one tile: x2:(B, Kt) against w:(Kt, N_t).

    On-the-fly composition of the two phases (program + stream in one
    call). ``sw``/``sx`` are the *global* calibration scales of the full
    operands — a tile never re-calibrates, so slicing commutes with
    quantisation and a tiled execution reproduces the monolithic bitstream
    exactly. Kt must be a multiple of ``cfg.m_columns`` except for the
    final K-tile (the zero padding then matches the monolithic chunking).
    """
    ws = cim_program_weight_state(w, cfg, sw)
    return cim_input_partials(x2, ws, cfg, sx, cap_weights,
                              comparator_offset, silicon)


def cim_mf_recombine(parts: CimPartials, sw: jax.Array, sx: jax.Array,
                     cfg: CimConfig) -> jax.Array:
    """Eq. 2 recombination of (possibly tile-accumulated) partials -> (B, N).

    The code sums are rescaled by m / (2^A_P - 1) once, here — never per
    tile — so the floating-point rounding sequence is identical no matter
    how the contraction dimension was split.
    """
    levels = 2 ** cfg.adc_bits - 1
    s1 = cfg.m_columns * (parts.s1c / levels)
    s2 = cfg.m_columns * (parts.s2c / levels)
    r_x = cfg.m_columns * (parts.rxc / levels)
    sum_sign_x_abs_w = 2.0 * s1 - parts.r_w    # sum sign(x)|w|
    sum_sign_w_abs_x = 2.0 * s2 - r_x          # sum sign(w)|x|
    return sw * sum_sign_x_abs_w + sx * sum_sign_w_abs_x


class CimKernelState(NamedTuple):
    """Program-time weight-side state in the Pallas kernel's chunk layout.

    The packed arrays come straight from :func:`repro.kernels.ops
    .pack_chunks` at program time, so the fused kernel never re-packs the
    stationary weight operand per step. ``rx_gates`` is the chunk-packed
    all-ones dummy-row gate operand — static for a given (K, m_columns),
    so it is hoisted here too and step time packs only the input planes.
    """

    gw_packed: jax.Array   # (N, Kp) chunk-packed step(w) gates (step_w.T)
    wp_packed: jax.Array   # (Pw, Kp, N) chunk-packed |w| magnitude planes
    r_w: jax.Array         # (1, N) exact digital sum_k |w_q|_kn
    rx_gates: Optional[jax.Array] = None   # (1, Kp) packed dummy-row gates


def cim_program_kernel_state(w: jax.Array, cfg: CimConfig,
                             sw: jax.Array) -> CimKernelState:
    """Program-time pass for the fused Pallas path (pre-packed layout)."""
    from repro.kernels import ops as kops
    K = w.shape[0]
    step_w, abs_w, w_planes = _weight_operands(w, cfg, sw)
    gw_packed = kops.pack_chunks(step_w.T, cfg.m_columns)
    wp_packed = kops.pack_planes(w_planes, cfg.m_columns)
    # exact-ok: integer |w_q| magnitudes, column sums below 2^24 — exact in f32
    r_w = jnp.sum(abs_w, axis=0).astype(jnp.float32)[None, :]
    rx_gates = kops.pack_chunks(jnp.ones((1, K), jnp.float32), cfg.m_columns)
    return CimKernelState(gw_packed, wp_packed, r_w, rx_gates)


def cim_kernel_state_from_weight_state(ws: CimWeightState,
                                       cfg: CimConfig) -> CimKernelState:
    """Re-layout programmed plane state into the kernel's packed layout.

    Lets paths that hold :class:`CimWeightState` (tiled compiler segments,
    swapped streams, on-the-fly matmuls) enter the fused silicon route
    without reprogramming from ``w``. Pure {0,1} relayout — bit-identical
    to packing the raw operands with :func:`cim_program_kernel_state`.
    """
    from repro.kernels import ops as kops
    m = cfg.m_columns
    wp = jnp.transpose(ws.wt.astype(jnp.float32), (3, 2, 0, 1))  # (Pw,N,C,m)
    wp_packed = jnp.moveaxis(kops.pack_chunked(wp, m), 1, -1)    # (Pw,Kp,N)
    gw = jnp.transpose(ws.gwt.astype(jnp.float32), (2, 0, 1))    # (N, C, m)
    gw_packed = kops.pack_chunked(gw, m)                         # (N, Kp)
    return CimKernelState(gw_packed, wp_packed, ws.r_w)


def cim_kernel_forward(x2: jax.Array, ks: CimKernelState, cfg: CimConfig,
                       sw: jax.Array, sx: jax.Array,
                       dac_gains: Optional[jax.Array] = None) -> jax.Array:
    """Step-time fused Pallas pass against programmed kernel state.

    Per-chunk MAV + ADC + plane recombination without materialising the
    MAV tensor; only the streaming input side is packed per call (the
    weight gates/planes AND the all-ones dummy-row gates were packed at
    program time). Recombines through :func:`cim_mf_recombine`, so the
    output is bitwise identical to the einsum fast path. Silicon-injected
    projections do not come through here — they take the fused
    :func:`cim_kernel_silicon_partials` route via ``cim_input_partials``.
    """
    from repro.kernels import ops as kops
    K = x2.shape[-1]
    m = cfg.m_columns
    sx_q = sx if dac_gains is None else sx * dac_gains
    step_x, _, x_planes = _input_operands(x2, cfg, sx_q)
    if dac_gains is not None:
        x_planes = x_planes * dac_gains.astype(jnp.float32)
    gx = kops.pack_chunks(step_x, m)                             # (B, Kp)
    xp = kops.pack_planes(jnp.moveaxis(x_planes, 1, -1), m)      # (Px, Kp, B)
    rx_gates = ks.rx_gates
    if rx_gates is None:
        rx_gates = kops.pack_chunks(jnp.ones((1, K), jnp.float32), m)
    s1c = kops.cim_mav_packed(gx, ks.wp_packed, m_columns=m,
                              adc_bits=cfg.adc_bits)             # (B, N)
    s2c = kops.cim_mav_packed(ks.gw_packed, xp, m_columns=m,
                              adc_bits=cfg.adc_bits).T           # (B, N)
    rxc = kops.cim_mav_packed(rx_gates, xp, m_columns=m,
                              adc_bits=cfg.adc_bits).T           # (B, 1)
    return cim_mf_recombine(CimPartials(s1c, s2c, rxc, ks.r_w), sw, sx, cfg)


class CimKernelSilicon(NamedTuple):
    """Program-time fold of per-slot silicon state into kernel operands.

    Built once by :func:`cim_program_silicon`: the stationary {0,1} packs
    are weighted by their tile's fixed-point cap-DAC caps (see
    :func:`cap_fixed`), and the per-(chunk, channel) SA-ADC instances —
    cap-sum denominator, comparator offset — are laid out as
    (Kp/CHUNK_PAD, N) tiles the kernel indexes by grid position. Padded
    chunks carry den=1/off=0 so their all-zero planes digitise to code 0.
    Leading stacked axes (fleet instance stacking) are preserved.
    """

    wpc: jax.Array      # (..., Pw, Kp, N) cap-folded |w| magnitude planes
    gwc: jax.Array      # (..., Kp, N) cap-folded step(w) gates
    den: jax.Array      # (..., Ct, N) per-tile cap-sum denominator
    off: jax.Array      # (..., Ct, N) per-tile comparator offset
    rxp: jax.Array      # (..., Kp) packed dummy-row rx caps
    rx_den: jax.Array   # (..., Ct) dummy-row cap-sum denominator
    rx_off: jax.Array   # (..., Ct) dummy-row comparator offset


def _pad_axis(v: jax.Array, axis: int, pad: int, fill: float) -> jax.Array:
    if pad == 0:
        return v
    widths = [(0, 0)] * v.ndim
    widths[axis] = (0, pad)
    return jnp.pad(v, widths, constant_values=fill)


def cim_program_silicon(ks: CimKernelState, sil: ProjectionSilicon,
                        cfg: CimConfig,
                        n_chunks: Optional[int] = None) -> CimKernelSilicon:
    """Fold a :class:`ProjectionSilicon` into fused-kernel operands.

    The cap weighting moves entirely to the weight-stationary side
    (plane_bit * cap and gate * cap products are exact: caps live on the
    2^-CAP_FIXED_BITS grid, bits are {0,1}), so the streamed operand stays
    a plain {0,1} pack and the in-kernel dot reproduces the reference
    einsum numerators bit for bit.
    """
    from repro.kernels import ops as kops
    from repro.kernels.cim_mav import CHUNK_PAD
    m = cfg.m_columns
    n_out, c = sil.cap.shape[-3], sil.cap.shape[-2]
    if sil.cap.shape[-1] != m or n_out != ks.wp_packed.shape[-1]:
        raise ValueError(
            f"silicon cap shape {sil.cap.shape} does not match the "
            f"programmed kernel state (N={ks.wp_packed.shape[-1]}, "
            f"m={m}) tiles")
    if n_chunks is not None and c != n_chunks:
        raise ValueError(
            f"silicon cap shape {sil.cap.shape} holds {c} chunks, "
            f"projection needs {n_chunks}")
    kp = ks.wp_packed.shape[-2]
    c_tiles = kp // CHUNK_PAD
    if _round_up_chunks(c) != c_tiles:
        raise ValueError(
            f"silicon chunk count {c} does not pack to the kernel state's "
            f"K_pad={kp} ({c_tiles} chunk tiles)")
    cpad = c_tiles - c
    capq = cap_fixed(sil.cap)                                    # (...,N,C,m)
    capk = jnp.swapaxes(kops.pack_chunked(capq, m), -1, -2)      # (...,Kp,N)
    wpc = ks.wp_packed.astype(jnp.float32) * capk[..., None, :, :]
    gwc = jnp.swapaxes(ks.gw_packed.astype(jnp.float32), -1, -2) * capk
    # exact-ok: 2^-14-grid caps; small fixed-point chunk sums — exact in f32
    den = _pad_axis(jnp.swapaxes(jnp.sum(capq, -1), -1, -2), -2, cpad, 1.0)
    off = _pad_axis(jnp.swapaxes(sil.offset.astype(jnp.float32), -1, -2),
                    -2, cpad, 0.0)
    rxq = cap_fixed(sil.rx_cap)                                  # (..., C, m)
    rxp = kops.pack_chunked(rxq, m)                              # (..., Kp)
    # exact-ok: 2^-14-grid caps; small fixed-point chunk sums — exact in f32
    rx_den = _pad_axis(jnp.sum(rxq, -1), -1, cpad, 1.0)
    rx_off = _pad_axis(sil.rx_offset.astype(jnp.float32), -1, cpad, 0.0)
    return CimKernelSilicon(wpc, gwc, den, off, rxp, rx_den, rx_off)


def _round_up_chunks(c: int) -> int:
    from repro.kernels.cim_mav import CHUNKS_PER_TILE
    return -(-c // CHUNKS_PER_TILE) * CHUNKS_PER_TILE


def cim_kernel_silicon_partials(x2: jax.Array, ks: CimKernelState,
                                silk: CimKernelSilicon, cfg: CimConfig,
                                sx: jax.Array, sil: ProjectionSilicon
                                ) -> CimPartials:
    """Fused silicon step-time pass: the SA-ADC instances run IN-KERNEL.

    Thermal dither is drawn OUTSIDE the kernel with the exact tensor
    shapes/salts of the reference route (``_silicon_partials`` /
    ``_silicon_rx``) and rides in as a kernel operand, so the fused codes
    match the einsum codes bit for bit at thermal_fs>0 too — same
    ``noise_key``/:func:`conversion_step`/salt fold, same samples, same
    ``mav + (off + dither)`` associativity.
    """
    from repro.kernels import ops as kops
    K = x2.shape[-1]
    m = cfg.m_columns
    step_x, _, x_planes = _input_operands(x2, cfg, sx)
    B = x2.shape[0]
    N = ks.r_w.shape[-1]
    C = -(-K // m)
    gx = kops.pack_chunks(step_x, m)[None]                       # (1, B, Kp)
    xp = kops.pack_chunks(x_planes, m)                           # (Px, B, Kp)
    d1k = d2k = drk = None
    if sil.thermal_fs is not None:
        c_tiles = silk.den.shape[-2]
        cpad = c_tiles - C
        d1 = sil.dither((B, N, cfg.w_planes, C), 1)
        d2 = sil.dither((cfg.x_planes, B, N, C), 2)
        dr = sil.dither((cfg.x_planes, B, C), 3)
        d1k = _pad_axis(jnp.transpose(d1, (2, 3, 0, 1)), 1, cpad, 0.0)
        d2k = _pad_axis(jnp.transpose(d2, (0, 3, 1, 2)), 1, cpad, 0.0)
        drk = _pad_axis(jnp.transpose(dr, (0, 2, 1)), 1, cpad, 0.0)[..., None]
    s1c = kops.cim_mav_silicon(gx, silk.wpc, silk.den, silk.off, d1k,
                               adc_bits=cfg.adc_bits)            # (B, N)
    s2c = kops.cim_mav_silicon(xp, silk.gwc[None], silk.den, silk.off, d2k,
                               adc_bits=cfg.adc_bits)            # (B, N)
    rxc = kops.cim_mav_silicon(xp, silk.rxp[None, :, None],
                               silk.rx_den[:, None], silk.rx_off[:, None],
                               drk, adc_bits=cfg.adc_bits)       # (B, 1)
    return CimPartials(s1c, s2c, rxc, ks.r_w)


def cim_mf_matmul(x: jax.Array, w: jax.Array, cfg: CimConfig,
                  cap_weights: Optional[jax.Array] = None,
                  comparator_offset: Optional[jax.Array] = None,
                  silicon: Optional[ProjectionSilicon] = None) -> jax.Array:
    """Hardware-faithful MF correlation x:(...,K) (+) w:(K,N) -> (...,N).

    cap_weights: optional (K,) positive per-column capacitor weights
    (1.0 = nominal) applied to the charge averaging (variability
    injection); the zero-padded tail columns of the final chunk then drop
    out of the charge average (cap weight 0).
    comparator_offset: optional scalar/broadcastable offset in full-scale
    fractions added inside the ADC.
    silicon: optional :class:`ProjectionSilicon` giving every µArray tile
    its own sampled ADC instance (exclusive with the two legacy knobs).
    """
    K, N = w.shape
    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, K)

    sw = quant.calibrate_scale(w, cfg.w_bits)
    sx = quant.calibrate_scale(x2, cfg.x_bits)

    if cfg.use_kernel and cap_weights is None and comparator_offset is None \
            and silicon is None:
        # Fused Pallas path (no variability injection).
        ks = cim_program_kernel_state(w, cfg, sw)
        y = cim_kernel_forward(x2, ks, cfg, sw, sx)
        return y.reshape(batch_shape + (N,)).astype(x.dtype)

    parts = cim_mf_partials(x2, w, cfg, sw, sx, cap_weights,
                            comparator_offset, silicon)
    y = cim_mf_recombine(parts, sw, sx, cfg)
    return y.reshape(batch_shape + (N,)).astype(x.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def cim_mf_matmul_ste(x: jax.Array, w: jax.Array, cfg: CimConfig) -> jax.Array:
    """CIM forward with straight-through MF surrogate backward (QAT)."""
    return cim_mf_matmul(x, w, cfg)


def _cim_ste_fwd(x, w, cfg):
    return cim_mf_matmul(x, w, cfg), (x, w)


def _cim_ste_bwd(cfg, res, g):
    x, w = res
    _, vjp = jax.vjp(lambda a, b: mf_matmul(a, b, 0.5, 1.0), x, w)
    return vjp(g)


cim_mf_matmul_ste.defvjp(_cim_ste_fwd, _cim_ste_bwd)
