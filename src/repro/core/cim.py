"""Behavioural compute-in-SRAM simulator (paper Sec. IV).

Models the µArray execution of the MF operator bit-for-bit:

  * operands quantised to W_P-bit weights / X_P-bit inputs (sign-magnitude),
  * |w| bitplanes stored as rows, one output channel per µArray,
  * the K contraction dim split into column chunks of M (µArray half width);
    padded columns store 0 so they never discharge (denominator stays M),
  * per (chunk, plane): multiply-average MAV = (1/M) sum_j bit_pj * step_j,
  * SA-ADC digitisation of each MAV to A_P bits (uniform mid-tread code on
    [0, 1]; code = round(MAV * (2^A_P - 1)) — exactly lossless when
    2^A_P >= M + 1, reproducing the paper's 8x62 -> 5-bit / 8x30 -> 4-bit
    pairings),
  * Eq. 2 recombination with the two residues: sum|x| via an ADC'd dummy
    all-ones row, sum|w| as an exact digital weight statistic.

Optional process variability (core/variability.py) perturbs the charge
averaging with per-column capacitor mismatch and adds comparator offset
before digitisation.

This path is forward-only hardware emulation; ``cim_mf_matmul_ste`` wraps it
with a straight-through estimator whose backward is the float MF surrogate
gradient, enabling hardware-in-the-loop QAT.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.mf import mf_matmul


@dataclasses.dataclass(frozen=True)
class CimConfig:
    """Geometry + precision of a compute-in-SRAM macro.

    The paper's two design points:
      8x62 µArray: m_columns=31 (per half), adc_bits=5  (~105 TOPS/W)
      8x30 µArray: m_columns=15 (per half), adc_bits=4  (~84 TOPS/W)
    """

    w_bits: int = 8          # weight precision W_P (sign + W_P-1 planes)
    x_bits: int = 8          # input precision
    adc_bits: int = 5        # SA-ADC precision A_P
    m_columns: int = 31      # columns per µArray half (vector-parallelism l)
    use_kernel: bool = False  # route the MAV loop through the Pallas kernel

    @property
    def w_planes(self) -> int:
        return self.w_bits - 1

    @property
    def x_planes(self) -> int:
        return self.x_bits - 1


def adc_codes(mav: jax.Array, adc_bits: int,
              comparator_offset: Optional[jax.Array] = None) -> jax.Array:
    """SA-ADC transfer returning the raw integer code (as float32).

    code = clip(round(mav * (2^A_P - 1))): the capacitive-DAC binary search
    settles on the nearest of 2^A_P evenly spaced reference levels. A
    comparator offset (fraction of full scale) shifts every comparison.

    Codes are small integers (<= 2^A_P - 1), exactly representable in
    float32 — every downstream accumulation of codes is therefore exact,
    which is what lets the tiled compiler path (repro.compiler.execute)
    reproduce the monolithic result bit for bit.
    """
    levels = 2 ** adc_bits - 1
    v = mav if comparator_offset is None else mav + comparator_offset
    return jnp.clip(jnp.round(v * levels), 0, levels)


def adc_quantize(mav: jax.Array, adc_bits: int,
                 comparator_offset: Optional[jax.Array] = None) -> jax.Array:
    """SA-ADC transfer: uniform A_P-bit code on [0,1], returned dequantised."""
    return adc_codes(mav, adc_bits, comparator_offset) / (2 ** adc_bits - 1)


def _bitplane_operands(x2: jax.Array, w: jax.Array, cfg: CimConfig,
                       sw: jax.Array, sx: jax.Array):
    """Quantise both operands and decompose into sign gates + bitplanes.

    Sign bits are stored SEPARATELY from the magnitude planes in the
    µArray (sign row + W_P-1 magnitude rows), so they come from the
    ORIGINAL operand sign — a weight whose magnitude truncates to zero
    keeps its true sign bit (quantising first would flip small negative
    weights to +, a large systematic error at low W_P).

    Returns (step_x, step_w, abs_x, abs_w, w_planes, x_planes) with
    step_*: {0,1} sign gates, abs_*: integer magnitudes, *_planes:
    (P, ...) bitplane stacks (LSB first).
    """
    wq = quant.quantize(w, sw, cfg.w_bits)          # (K, N) int
    xq = quant.quantize(x2, sx, cfg.x_bits)         # (B, K) int
    step_w = (w >= 0).astype(jnp.float32)           # (K, N)
    step_x = (x2 >= 0).astype(jnp.float32)          # (B, K)
    abs_w = jnp.abs(wq)
    abs_x = jnp.abs(xq)
    w_planes = quant.bitplanes(abs_w, cfg.w_bits)   # (Pw, K, N)
    x_planes = quant.bitplanes(abs_x, cfg.x_bits)   # (Px, B, K)
    return step_x, step_w, abs_x, abs_w, w_planes, x_planes


def _chunk(v: jax.Array, m: int, axis_len: int) -> jax.Array:
    """Pad the contraction axis (last) to a multiple of m and fold it."""
    pad = (-axis_len) % m
    if pad:
        v = jnp.pad(v, [(0, 0)] * (v.ndim - 1) + [(0, pad)])
    return v.reshape(v.shape[:-1] + ((axis_len + pad) // m, m))


class CimPartials(NamedTuple):
    """Pre-recombination macro statistics of one (x, w) tile.

    All four fields are *integer-valued* float32 arrays (plane-weighted sums
    of SA-ADC codes / digital |w| counts), so summing the partials of K-tiles
    is exact in float32 — the foundation of the compiler's bit-exact tiled
    execution. Recombine with :func:`cim_mf_recombine`.
    """

    s1c: jax.Array   # (B, N) plane-weighted code sum, Eq. 2b numerator side
    s2c: jax.Array   # (B, N) plane-weighted code sum, Eq. 2a numerator side
    rxc: jax.Array   # (B, 1) plane-weighted code sum of the |x| dummy row
    r_w: jax.Array   # (1, N) exact digital sum_k |w_q|_kn

    def __add__(self, other: "CimPartials") -> "CimPartials":
        return CimPartials(self.s1c + other.s1c, self.s2c + other.s2c,
                           self.rxc + other.rxc, self.r_w + other.r_w)


def cim_mf_partials(x2: jax.Array, w: jax.Array, cfg: CimConfig,
                    sw: jax.Array, sx: jax.Array,
                    cap_weights: Optional[jax.Array] = None,
                    comparator_offset: Optional[jax.Array] = None
                    ) -> CimPartials:
    """µArray pass over one tile: x2:(B, Kt) against w:(Kt, N_t).

    ``sw``/``sx`` are the *global* calibration scales of the full operands —
    a tile never re-calibrates, so slicing commutes with quantisation and a
    tiled execution reproduces the monolithic bitstream exactly. Kt must be
    a multiple of ``cfg.m_columns`` except for the final K-tile (the zero
    padding then matches the monolithic chunking).
    """
    K, N = w.shape
    step_x, step_w, abs_x, abs_w, w_planes, x_planes = _bitplane_operands(
        x2, w, cfg, sw, sx)

    m = cfg.m_columns
    nchunks = -(-K // m)

    if cap_weights is None:
        cap = jnp.ones((nchunks, m), jnp.float32)
    else:
        cap = _chunk(cap_weights.astype(jnp.float32)[None, :], m, K)[0]
    cap_sum = jnp.sum(cap, axis=-1)                              # (C,)

    def adc(mav: jax.Array) -> jax.Array:
        return adc_codes(mav, cfg.adc_bits, comparator_offset)

    # --- term S1 = sum_k step(x_k) * |w|_kn  (Eq. 2b numerator) ----------
    # planes of |w| against the step(x) column gates, charge-averaged per
    # (chunk, plane) with the (possibly mismatched) column capacitors.
    wp = _chunk(jnp.moveaxis(w_planes, -1, 0), m, K)             # (N, Pw, C, m)
    gx = _chunk(step_x, m, K)                                    # (B, C, m)
    num1 = jnp.einsum("bcm,npcm,cm->bnpc", gx, wp, cap)
    codes1 = adc(num1 / cap_sum[None, None, None, :])            # (B, N, Pw, C)
    pw = 2.0 ** jnp.arange(cfg.w_planes)
    s1c = jnp.einsum("bnpc,p->bn", codes1, pw)

    # --- term S2 = sum_k step(w_kn) * |x|_k  (Eq. 2a numerator) ----------
    xp = _chunk(x_planes, m, K)                                  # (Px, B, C, m)
    gw = _chunk(step_w.T, m, K)                                  # (N, C, m)
    num2 = jnp.einsum("pbcm,ncm,cm->pbnc", xp, gw, cap)
    codes2 = adc(num2 / cap_sum[None, None, None, :])            # (Px, B, N, C)
    px = 2.0 ** jnp.arange(cfg.x_planes)
    s2c = jnp.einsum("pbnc,p->bn", codes2, px)

    # --- residues ---------------------------------------------------------
    # R_x = sum_k |x|_k via the dummy all-ones row (also ADC'd in hardware;
    # shared across every weight vector, so computed once per input).
    num_rx = jnp.einsum("pbcm,cm->pbc", xp, cap)
    codes_rx = adc(num_rx / cap_sum[None, None, :])              # (Px, B, C)
    rxc = jnp.einsum("pbc,p->b", codes_rx, px)[:, None]          # (B, 1)
    # R_w = sum_k |w|_kn, precomputed digitally (exact).
    r_w = jnp.sum(abs_w, axis=0).astype(jnp.float32)[None, :]    # (1, N)
    return CimPartials(s1c, s2c, rxc, r_w)


def cim_mf_recombine(parts: CimPartials, sw: jax.Array, sx: jax.Array,
                     cfg: CimConfig) -> jax.Array:
    """Eq. 2 recombination of (possibly tile-accumulated) partials -> (B, N).

    The code sums are rescaled by m / (2^A_P - 1) once, here — never per
    tile — so the floating-point rounding sequence is identical no matter
    how the contraction dimension was split.
    """
    levels = 2 ** cfg.adc_bits - 1
    s1 = cfg.m_columns * (parts.s1c / levels)
    s2 = cfg.m_columns * (parts.s2c / levels)
    r_x = cfg.m_columns * (parts.rxc / levels)
    sum_sign_x_abs_w = 2.0 * s1 - parts.r_w    # sum sign(x)|w|
    sum_sign_w_abs_x = 2.0 * s2 - r_x          # sum sign(w)|x|
    return sw * sum_sign_x_abs_w + sx * sum_sign_w_abs_x


def cim_mf_matmul(x: jax.Array, w: jax.Array, cfg: CimConfig,
                  cap_weights: Optional[jax.Array] = None,
                  comparator_offset: Optional[jax.Array] = None) -> jax.Array:
    """Hardware-faithful MF correlation x:(...,K) (+) w:(K,N) -> (...,N).

    cap_weights: optional (K,) positive per-column capacitor weights
    (1.0 = nominal) applied to the charge averaging (variability
    injection); the zero-padded tail columns of the final chunk then drop
    out of the charge average (cap weight 0).
    comparator_offset: optional scalar/broadcastable offset in full-scale
    fractions added inside the ADC.
    """
    K, N = w.shape
    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, K)

    sw = quant.calibrate_scale(w, cfg.w_bits)
    sx = quant.calibrate_scale(x2, cfg.x_bits)

    if cfg.use_kernel and cap_weights is None and comparator_offset is None:
        # Fused Pallas path (no variability injection): per-chunk MAV + ADC
        # + plane recombination without materialising the MAV tensor.
        from repro.kernels import ops as kops
        step_x, step_w, _, abs_w, w_planes, x_planes = _bitplane_operands(
            x2, w, cfg, sw, sx)
        m = cfg.m_columns
        s1 = kops.cim_mav(step_x, w_planes, m_columns=m,
                          adc_bits=cfg.adc_bits)                     # (B, N)
        s2 = kops.cim_mav(step_w.T, jnp.moveaxis(x_planes, 1, -1),
                          m_columns=m, adc_bits=cfg.adc_bits).T      # (B, N)
        r_x = kops.cim_mav(jnp.ones((1, K), jnp.float32),
                           jnp.moveaxis(x_planes, 1, -1),
                           m_columns=m, adc_bits=cfg.adc_bits).T     # (B, 1)
        r_w = jnp.sum(abs_w, axis=0).astype(jnp.float32)[None, :]
        y = (sw * (2.0 * s1 - r_w) + sx * (2.0 * s2 - r_x))
        return y.reshape(batch_shape + (N,)).astype(x.dtype)

    parts = cim_mf_partials(x2, w, cfg, sw, sx, cap_weights,
                            comparator_offset)
    y = cim_mf_recombine(parts, sw, sx, cfg)
    return y.reshape(batch_shape + (N,)).astype(x.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def cim_mf_matmul_ste(x: jax.Array, w: jax.Array, cfg: CimConfig) -> jax.Array:
    """CIM forward with straight-through MF surrogate backward (QAT)."""
    return cim_mf_matmul(x, w, cfg)


def _cim_ste_fwd(x, w, cfg):
    return cim_mf_matmul(x, w, cfg), (x, w)


def _cim_ste_bwd(cfg, res, g):
    x, w = res
    _, vjp = jax.vjp(lambda a, b: mf_matmul(a, b, 0.5, 1.0), x, w)
    return vjp(g)


cim_mf_matmul_ste.defvjp(_cim_ste_fwd, _cim_ste_bwd)
