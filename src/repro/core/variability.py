"""Re-export shim: the variability models moved to ``repro.silicon``.

The process-variability distributions (cap mismatch, comparator offset,
screening, Fig. 8 crossover Monte-Carlos) are now part of the silicon lab
subsystem — :mod:`repro.silicon.variability` — next to the per-slot fleet
instance sampling (:mod:`repro.silicon.instance`) that consumes them.
This module keeps every historical import path working.
"""

from repro.silicon.variability import (VariabilityConfig, calibrated_offset,
                                       estimate_cap_strength,
                                       mav_crossover_probability,
                                       sample_cap_weights,
                                       sample_comparator_offset,
                                       screen_columns)

__all__ = [
    "VariabilityConfig", "calibrated_offset", "estimate_cap_strength",
    "mav_crossover_probability", "sample_cap_weights",
    "sample_comparator_offset", "screen_columns",
]
