"""Synergistic digital/CIM mixed-mapping policy (paper Sec. VI, Fig. 9).

The paper's observation: early layers have few parameters but high weight
reuse (ops/param in the hundreds-to-thousands) — ideal for weight-stationary
compute-in-memory; late/classifier layers are parameter-heavy with ops/param
~1 — better left in dense digital storage. The mixed mapping keeps >85% of
ops on the MF CIM fabric while storing only ~1/3 of weights there.

We port the policy directly: every projection in every model reports
(params, ops) per layer; the policy assigns ExecMode.MF (or CIM_SIM) to
layers above an ops/param threshold and ExecMode.REGULAR to the rest, with
embeddings/classifier heads always digital (the paper keeps the last layer
typical in all three configurations). Config-level overrides reproduce the
paper's exact per-table choices.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.mf import ExecMode


@dataclasses.dataclass(frozen=True)
class LayerStat:
    name: str
    params: int
    ops: int                      # 2 * MACs for one forward pass

    @property
    def ops_per_param(self) -> float:
        return self.ops / max(self.params, 1)


@dataclasses.dataclass(frozen=True)
class MappingPolicy:
    """ops/param-threshold policy with always-digital name patterns."""

    threshold: float = 2.0
    always_digital: Sequence[str] = ("embed", "lm_head", "logits",
                                     "classifier", "router")
    overrides: Optional[dict[str, str]] = None  # name -> "mf"|"regular"|...
    mf_mode: ExecMode = ExecMode.MF

    def assign(self, stat: LayerStat) -> ExecMode:
        if self.overrides and stat.name in self.overrides:
            return ExecMode(self.overrides[stat.name])
        low = stat.name.lower()
        if any(p in low for p in self.always_digital):
            return ExecMode.REGULAR
        if stat.ops_per_param >= self.threshold:
            return self.mf_mode
        return ExecMode.REGULAR


@dataclasses.dataclass(frozen=True)
class MappingReport:
    assignments: dict[str, ExecMode]
    stats: list[LayerStat]

    @property
    def mf_ops_fraction(self) -> float:
        mf = sum(s.ops for s in self.stats
                 if self.assignments[s.name] != ExecMode.REGULAR)
        tot = sum(s.ops for s in self.stats)
        return mf / max(tot, 1)

    @property
    def mf_param_fraction(self) -> float:
        mf = sum(s.params for s in self.stats
                 if self.assignments[s.name] != ExecMode.REGULAR)
        tot = sum(s.params for s in self.stats)
        return mf / max(tot, 1)

    def ops_split(self) -> tuple[float, float]:
        """(mf_ops, digital_ops) for the Fig. 9 TOPS/W projection."""
        mf = sum(s.ops for s in self.stats
                 if self.assignments[s.name] != ExecMode.REGULAR)
        tot = sum(s.ops for s in self.stats)
        return float(mf), float(tot - mf)


def plan_mapping(stats: Sequence[LayerStat],
                 policy: MappingPolicy = MappingPolicy()) -> MappingReport:
    return MappingReport(
        assignments={s.name: policy.assign(s) for s in stats},
        stats=list(stats))
