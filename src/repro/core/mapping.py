"""Synergistic digital/CIM mixed-mapping policy (paper Sec. VI, Fig. 9).

The paper's observation: early layers have few parameters but high weight
reuse (ops/param in the hundreds-to-thousands) — ideal for weight-stationary
compute-in-memory; late/classifier layers are parameter-heavy with ops/param
~1 — better left in dense digital storage. The mixed mapping keeps >85% of
ops on the MF CIM fabric while storing only ~1/3 of weights there.

We port the policy directly: every projection in every model reports
(params, ops) per layer; the policy assigns ExecMode.MF (or CIM_SIM) to
layers above an ops/param threshold and ExecMode.REGULAR to the rest, with
embeddings/classifier heads always digital (the paper keeps the last layer
typical in all three configurations). Config-level overrides reproduce the
paper's exact per-table choices.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.mf import ExecMode


@dataclasses.dataclass(frozen=True)
class LayerStat:
    name: str
    params: int
    ops: int                      # 2 * MACs for one forward pass
    # matmul view of the projection (0 = unknown): K contraction columns,
    # N output channels. ops = 2*k*n*calls, so weight reuse is implied.
    k: int = 0
    n: int = 0

    @property
    def ops_per_param(self) -> float:
        return self.ops / max(self.params, 1)

    @property
    def calls(self) -> int:
        """Input vectors per forward (weight reuse); 1 if shape unknown."""
        if not (self.k and self.n):
            return 1
        return max(1, round(self.ops / (2 * self.k * self.n)))


@dataclasses.dataclass(frozen=True)
class MappingPolicy:
    """ops/param-threshold policy with always-digital name patterns."""

    threshold: float = 2.0
    always_digital: Sequence[str] = ("embed", "lm_head", "logits",
                                     "classifier", "router")
    overrides: Optional[dict[str, str]] = None  # name -> "mf"|"regular"|...
    mf_mode: ExecMode = ExecMode.MF

    def assign(self, stat: LayerStat) -> ExecMode:
        if self.overrides and stat.name in self.overrides:
            return ExecMode(self.overrides[stat.name])
        low = stat.name.lower()
        if any(p in low for p in self.always_digital):
            return ExecMode.REGULAR
        if stat.ops_per_param >= self.threshold:
            return self.mf_mode
        return ExecMode.REGULAR


@dataclasses.dataclass(frozen=True)
class FleetMappingPolicy(MappingPolicy):
    """Fleet-aware mixed mapping: ops/param threshold AND capacity check.

    A layer only maps to CIM if its µArray tile count fits the fleet's
    resident weight capacity (``capacity_tiles`` slots of ``m_columns``
    columns each). ``allow_swap`` lifts the capacity check for fleets that
    stream weights in rounds. Layers without a recorded (k, n) shape fall
    back to a best-effort ``params / m_columns`` estimate — exact when K is
    a chunk multiple, an UNDERestimate when K < m_columns (many short-K
    output channels each waste a padded tile); record shapes on stats that
    must gate reliably.

    Build one from a fleet with ``repro.compiler.Fleet.mapping_policy()``.
    """

    m_columns: int = 31
    capacity_tiles: int = 128
    allow_swap: bool = False

    def layer_tiles(self, stat: LayerStat) -> int:
        if stat.k and stat.n:
            return -(-stat.k // self.m_columns) * stat.n
        return -(-stat.params // self.m_columns)

    def assign(self, stat: LayerStat) -> ExecMode:
        base = super().assign(stat)
        if base == ExecMode.REGULAR or self.allow_swap:
            return base
        if self.layer_tiles(stat) > self.capacity_tiles:
            return ExecMode.REGULAR
        return base


@dataclasses.dataclass(frozen=True)
class MappingReport:
    assignments: dict[str, ExecMode]
    stats: list[LayerStat]

    @property
    def mf_ops_fraction(self) -> float:
        mf = sum(s.ops for s in self.stats
                 if self.assignments[s.name] != ExecMode.REGULAR)
        tot = sum(s.ops for s in self.stats)
        return mf / max(tot, 1)

    @property
    def mf_param_fraction(self) -> float:
        mf = sum(s.params for s in self.stats
                 if self.assignments[s.name] != ExecMode.REGULAR)
        tot = sum(s.params for s in self.stats)
        return mf / max(tot, 1)

    def ops_split(self) -> tuple[float, float]:
        """(mf_ops, digital_ops) for the Fig. 9 TOPS/W projection."""
        mf = sum(s.ops for s in self.stats
                 if self.assignments[s.name] != ExecMode.REGULAR)
        tot = sum(s.ops for s in self.stats)
        return float(mf), float(tot - mf)


def plan_mapping(stats: Sequence[LayerStat],
                 policy: MappingPolicy = MappingPolicy()) -> MappingReport:
    return MappingReport(
        assignments={s.name: policy.assign(s) for s in stats},
        stats=list(stats))
