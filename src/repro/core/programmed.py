"""Weight-stationary programmed-macro runtime (program-time/step-time split).

In the paper's macro the weights are programmed ONCE into the µArray (sign
row + magnitude bitplane rows) and only inputs stream per cycle — the
SA-ADC digitises charge-averaged MAVs against *stationary* weight
bitplanes. This module mirrors that discipline for the behavioural
simulator:

  * :class:`ProgrammedMacro` — the frozen per-projection weight state: the
    calibrated weight scale ``sw``, a *static* activation scale ``sx``
    fixed at program time, the exact digital ``r_w`` residue, and either
    the bit-packed plane-level state (:class:`CimPackedPlanes` — 8 µArray
    cells per byte), the Pallas kernel's pre-packed chunk layout
    (:class:`~repro.core.cim.CimKernelState`), or the collapsed
    exactly-lossless state (:class:`CimLosslessState`).
  * :func:`program_macro` — program one (K, N) projection.
  * :func:`program_weights` — walk a model parameter tree and attach a
    ``"prog"`` entry to every MF projection dict (those carrying the MF
    neuron's ``alpha``), stacked-layer, conv, and MoE-expert layouts
    included, so the programmed state flows through ``jax.lax.scan``
    exactly like the parameters it shadows. ``core.mf.apply_projection``
    picks it up in CIM_SIM mode; ``convnets.conv_apply`` and
    ``moe._expert_ffn`` consume the conv / expert variants.
  * :func:`map_projections` / :func:`iter_projections` — the shared tree
    walk (also used by the calibration lab in ``repro.calib`` to attach
    observers with the SAME names scale programming looks up).
  * :class:`ProgrammedLayer` — per-tile programmed slices of one
    compiler-tiled projection (see ``repro.compiler.execute``).

Bit-exactness contract: for the same ``CimConfig`` and the same ``sx``,
the programmed path is bit-identical to the on-the-fly path (monolithic
and tiled) — both phases run the very same ops on the very same arrays,
just split across time; bit-packing is a pure storage transform (unpack
reproduces the exact {0,1} cells). The *static* ``sx`` is the one
modelling choice (hardware cannot re-calibrate the input DAC per batch);
``repro.calib`` records corpus statistics and programs measured
per-projection scales through the ``scales=`` hook below — see
EXPERIMENTS.md "Corpus-driven activation calibration".
"""
# repro-lint: module=exactness-critical

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.cim import (CimConfig, CimKernelSilicon, CimKernelState,
                            CimPartials, CimWeightState, ProjectionSilicon,
                            _input_operands, _weight_operands, cap_fixed,
                            cim_input_partials, cim_kernel_forward,
                            cim_kernel_silicon_partials, cim_mf_recombine,
                            cim_program_kernel_state, cim_program_silicon,
                            cim_program_weight_state, cim_rx_partials)

# Full-scale assumption for the default static activation calibration:
# post-norm activations are ~unit-RMS, so |x| <= ~4 covers >4 sigma. Used
# only when no measured scale is supplied (see EXPERIMENTS.md).
DEFAULT_ACT_AMAX = 4.0

# The sign gate occupies the top bit of every packed byte; magnitude
# planes fill bits [0, W_P-1). W_P <= 8 always holds (magnitudes are
# stored as int8 |w_q| <= 127 everywhere in the simulator).
_SIGN_BIT = 7


def adc_exactly_lossless(cfg: CimConfig) -> bool:
    """True at the paper's exactly-lossless pairings (2^A_P - 1 == M).

    There the SA-ADC code of every chunk MAV *is* the integer discharge
    count (code = round(count/M * (2^A_P - 1)) = count), so plane/chunk
    decomposition, digitisation, and plane recombination collapse
    algebraically: sum_p 2^p sum_c code[c,p] == sum_k gate_k * |v_k|.
    Both hardware design points (8x62 -> 5-bit, 8x30 -> 4-bit) qualify.
    """
    return 2 ** cfg.adc_bits - 1 == cfg.m_columns


def _check_packable(cfg: CimConfig) -> None:
    if cfg.w_planes > _SIGN_BIT:
        raise ValueError(
            f"w_bits={cfg.w_bits}: {cfg.w_planes} magnitude planes + sign "
            f"do not fit one packed byte (the simulator stores |w_q| as "
            f"int8, so w_bits <= 8)")


class CimPackedPlanes(NamedTuple):
    """Bit-packed plane-level programmed weight state (8 cells/byte).

    One uint8 per µArray cell in the program-time (C, m, N) layout: bits
    [0, W_P-1) hold the |w_q| magnitude bitplanes (LSB first — exactly the
    rows the hardware stores), bit 7 holds the step(w) sign gate. This
    cuts programmed-state memory ~(W_P)x versus one int8 per plane-cell
    (8x at W_P=8); :func:`unpack_weight_state` reproduces the exact {0,1}
    cells, so the step-time datapath is bit-identical to unpacked state.
    """

    packed: jax.Array   # (C, m, N) uint8 plane bits + sign gate
    r_w: jax.Array      # (1, N) float32 exact digital sum_k |w_q|_kn


def pack_weight_state(ws: CimWeightState, cfg: CimConfig) -> CimPackedPlanes:
    """Pack chunked {0,1} plane/gate cells into one byte per cell."""
    _check_packable(cfg)
    bits = jnp.arange(cfg.w_planes, dtype=jnp.int32)
    # exact-ok: int32 shift-sum of {0,1} plane bits — integer arithmetic
    mag = jnp.sum(ws.wt.astype(jnp.int32) << bits, axis=-1)      # (C, m, N)
    packed = mag | (ws.gwt.astype(jnp.int32) << _SIGN_BIT)
    return CimPackedPlanes(packed.astype(jnp.uint8), ws.r_w)


def unpack_weight_state(ps: CimPackedPlanes, cfg: CimConfig) -> CimWeightState:
    """Exact inverse of :func:`pack_weight_state` (step-time expand)."""
    p32 = ps.packed.astype(jnp.int32)
    bits = jnp.arange(cfg.w_planes, dtype=jnp.int32)
    wt = ((p32[..., None] >> bits) & 1).astype(jnp.int8)     # (C, m, N, Pw)
    gwt = ((p32 >> _SIGN_BIT) & 1).astype(jnp.int8)          # (C, m, N)
    return CimWeightState(wt, gwt, ps.r_w)


class CimLosslessState(NamedTuple):
    """Collapsed weight state for exactly-lossless ADC design points.

    One uint8 per (K, N) cell: bits [0, 7) hold the integer |w_q|
    magnitude (<= 127), bit 7 the step(w) sign gate — the sign bit rides
    in the byte the hardware would spend on the sign row. The step
    becomes two (B, K) @ (K, N) matmuls — bit-identical to the plane-level
    pipeline (every partial sum is integer-valued, exact in float32) while
    streaming W_P-1 times fewer weight bytes per decode step.
    """

    packed: jax.Array   # (K, N) uint8: |w_q| magnitude | sign gate << 7

    def magnitudes(self) -> jax.Array:
        """(K, N) float32 integer |w_q| magnitudes."""
        return (self.packed.astype(jnp.int32)
                & (2 ** _SIGN_BIT - 1)).astype(jnp.float32)

    def gates(self) -> jax.Array:
        """(K, N) float32 {0,1} step(w) sign gates."""
        return ((self.packed.astype(jnp.int32) >> _SIGN_BIT)
                & 1).astype(jnp.float32)


class ProgrammedMacro(NamedTuple):
    """Frozen weight state of one macro-mapped (K, N) projection.

    ``dac_gains`` (present iff the macro was programmed with a per-feature
    activation scale VECTOR) holds the attenuation-only input-DAC column
    gains g_k = clip(sx_k / max(sx), 2^-8, 1) on the :func:`cap_fixed`
    grid; ``sx`` is then the shared scalar max(sx). Inputs quantise
    against sx * g_k and the |x|-side bit streams are attenuated by g_k
    before the charge average — the hardware realisation of per-channel
    calibration on a DAC that has one reference per macro.
    """

    sw: jax.Array                          # calibrated weight scale
    sx: jax.Array                          # STATIC activation scale
    r_w: jax.Array                         # (1, N) digital |w| residue
    state: Optional[CimPackedPlanes]       # einsum-path bit-packed state
    kernel: Optional[CimKernelState]       # Pallas-path pre-packed state
    lossless: Optional[CimLosslessState]   # collapsed exact-ADC state
    dac_gains: Optional[jax.Array] = None  # (K,) per-feature DAC gains

    @property
    def n_out(self) -> int:
        return self.r_w.shape[-1]


# Attenuation floor of the per-feature input-DAC gain trim: a feature
# whose calibrated scale is >256x below the macro max saturates at
# 2^-8 of full scale rather than driving the shared reference down.
DAC_GAIN_FLOOR = 2.0 ** -8


def _split_channel_sx(sx: jax.Array):
    """Split a per-feature (K,) static scale into (scalar max, DAC gains).

    The macro's input DAC has ONE full-scale reference; per-feature scales
    are realised as attenuation-only column gain trims on the
    :func:`cap_fixed` fixed-point grid (so gain-weighted bit streams keep
    the float32-exact summation property that makes tiled/swapped
    execution bitwise reproducible). Scalar scales pass through unchanged.
    """
    if sx.ndim == 0:
        return sx, None
    sbar = jnp.max(sx)
    gains = cap_fixed(jnp.clip(sx / sbar, DAC_GAIN_FLOOR, 1.0))
    return sbar, gains


def program_macro(w: jax.Array, cfg: CimConfig, *, sx, sw=None,
                  prefer_lossless: bool = True) -> ProgrammedMacro:
    """Program one (K, N) projection's weights into macro state.

    ``sx`` is the static activation scale the macro will quantise inputs
    against for its whole service life — a scalar, or a per-feature (K,)
    vector (per-channel calibration), which splits into a scalar
    full-scale reference plus fixed-point DAC gain trims (see
    :func:`_split_channel_sx`). ``sw`` defaults to the max-abs calibration
    the on-the-fly path uses. The expensive weight-side work (quantise,
    sign/magnitude split, bitplanes, chunk/kernel packing) happens exactly
    once, here. Plane-level and lossless states store one byte per cell
    (magnitude bits + sign gate, :class:`CimPackedPlanes` /
    :class:`CimLosslessState`); the kernel layout stays int8 — Mosaic
    wants the cells pre-expanded.

    At exactly-lossless ADC design points the collapsed
    :class:`CimLosslessState` is programmed instead of the plane-level
    state (``prefer_lossless=False`` forces planes — needed for per-step
    variability injection and the compiler's tiled partial accumulation).
    DAC gain trims also force plane/kernel state: a gain-weighted MAV
    count is no longer integer, so the lossless collapse (code == count)
    does not hold.
    """
    if sw is None:
        sw = quant.calibrate_scale(w, cfg.w_bits)
    sw = jnp.asarray(sw, jnp.float32)
    sx = jnp.asarray(sx, jnp.float32)
    sx, dac_gains = _split_channel_sx(sx)
    if dac_gains is not None and dac_gains.shape[-1] != w.shape[0]:
        raise ValueError(
            f"per-feature sx vector has {dac_gains.shape[-1]} entries, "
            f"projection contracts over K={w.shape[0]}")
    if cfg.use_kernel:
        ks = cim_program_kernel_state(w, cfg, sw)
        return ProgrammedMacro(sw, sx, ks.r_w, None, ks, None, dac_gains)
    _check_packable(cfg)
    if prefer_lossless and adc_exactly_lossless(cfg) and dac_gains is None:
        step_w, abs_w, _ = _weight_operands(w, cfg, sw)
        # exact-ok: integer |w_q| magnitudes, column sums below 2^24 — exact in f32
        r_w = jnp.sum(abs_w, axis=0).astype(jnp.float32)[None, :]
        packed = (abs_w.astype(jnp.int32)
                  | (step_w.astype(jnp.int32) << _SIGN_BIT))
        ls = CimLosslessState(packed.astype(jnp.uint8))
        return ProgrammedMacro(sw, sx, r_w, None, None, ls)
    ws = cim_program_weight_state(w, cfg, sw)
    return ProgrammedMacro(sw, sx, ws.r_w, pack_weight_state(ws, cfg),
                           None, None, dac_gains)


def _lossless_partials(x2: jax.Array, ls: CimLosslessState, cfg: CimConfig,
                       sx: jax.Array, r_w: jax.Array) -> CimPartials:
    """Collapsed step at an exactly-lossless design point.

    With code == count, the plane-weighted code sums reduce to the dense
    correlations sum_k step(x)*|w| and sum_k |x|*step(w); all entries are
    integers below 2^24, so the float32 matmuls are exact and the result
    is bit-identical to the plane-level path fed through the same
    ``cim_mf_recombine``.
    """
    step_x, abs_x, _ = _input_operands(x2, cfg, sx)
    # exact-ok: integer-valued f32 operands below 2^24 — exact matmul
    s1c = step_x @ ls.magnitudes()                             # (B, N)
    # exact-ok: integer-valued f32 operands below 2^24 — exact matmul
    s2c = abs_x.astype(jnp.float32) @ ls.gates()
    # exact-ok: integer |x_q| magnitudes, row sums below 2^24 — exact in f32
    rxc = jnp.sum(abs_x, axis=-1, keepdims=True).astype(jnp.float32)
    return CimPartials(s1c, s2c, rxc, r_w)


def cim_mf_matmul_programmed(x: jax.Array, prog: ProgrammedMacro,
                             cfg: CimConfig,
                             cap_weights: Optional[jax.Array] = None,
                             comparator_offset: Optional[jax.Array] = None,
                             silicon: Optional[ProjectionSilicon] = None,
                             silicon_kernel: Optional[CimKernelSilicon]
                             = None) -> jax.Array:
    """Step-time MF correlation x:(...,K) against a programmed macro.

    Bit-identical to ``cim_mf_matmul(x, w, cfg)`` whenever ``prog`` was
    programmed with the same ``cfg`` and the dynamic activation scale of
    ``x`` (the parity tested by tests/test_programmed.py).

    Variability injection: the legacy shared draw (``cap_weights`` /
    ``comparator_offset``) runs on the bit-packed plane-level state
    (:class:`CimPackedPlanes`) — the packed bytes expand to the exact
    {0,1} cells, so injection composes with bit packing. Per-tile
    ``silicon`` instances run on plane-level state OR on the Pallas
    kernel layout — there the SA-ADC instances evaluate inside the fused
    kernel (:func:`~repro.core.cim.cim_kernel_silicon_partials`), with
    ``silicon_kernel`` optionally supplying the program-time cap fold
    (:func:`~repro.core.cim.cim_program_silicon`) so the hot loop skips
    the per-step fold. The collapsed lossless state has no per-chunk ADC
    evaluations to perturb and raises for every injection flavour.
    """
    K = x.shape[-1]
    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, K)
    inject = (cap_weights is not None or comparator_offset is not None
              or silicon is not None)
    sx_q = prog.sx if prog.dac_gains is None else prog.sx * prog.dac_gains
    if prog.state is not None:
        ws = unpack_weight_state(prog.state, cfg)
        parts = cim_input_partials(x2, ws, cfg, sx_q,
                                   cap_weights, comparator_offset, silicon,
                                   dac_gains=prog.dac_gains)
        y = cim_mf_recombine(parts, prog.sw, prog.sx, cfg)
    elif prog.kernel is not None:
        if cap_weights is not None or comparator_offset is not None:
            raise ValueError(
                "the legacy shared cap_weights/comparator_offset injection "
                "is not available on the Pallas kernel layout — only "
                "per-tile `silicon` instances fold into the fused kernel. "
                "Re-program with use_kernel=False and "
                "prefer_lossless=False for the legacy knobs.")
        if silicon is not None:
            if prog.dac_gains is not None:
                raise ValueError(
                    "per-feature DAC gain trims (per-channel sx "
                    "calibration) do not compose with silicon injection; "
                    "program per-tensor scales for silicon fleets")
            silk = silicon_kernel
            if silk is None:
                silk = cim_program_silicon(prog.kernel, silicon, cfg,
                                           n_chunks=-(-K // cfg.m_columns))
            parts = cim_kernel_silicon_partials(x2, prog.kernel, silk, cfg,
                                                prog.sx, silicon)
            y = cim_mf_recombine(parts, prog.sw, prog.sx, cfg)
        else:
            y = cim_kernel_forward(x2, prog.kernel, cfg, prog.sw, prog.sx,
                                   prog.dac_gains)
    elif inject:
        raise ValueError(
            "variability injection needs per-chunk ADC evaluations, but "
            "this macro holds the collapsed exactly-lossless state — its "
            "step collapses the conversions that mismatch and comparator "
            "offset perturb. Re-program the projection with "
            "prefer_lossless=False (program_weights(..., "
            "prefer_lossless=False)).")
    else:
        parts = _lossless_partials(x2, prog.lossless, cfg, prog.sx,
                                   prog.r_w)
        y = cim_mf_recombine(parts, prog.sw, prog.sx, cfg)
    return y.reshape(batch_shape + (prog.n_out,)).astype(x.dtype)


class ProgrammedLayer(NamedTuple):
    """Per-tile programmed slices of one compiler-tiled (K, N) projection.

    ``tiles[j][i]`` is the :class:`ProgrammedMacro` of n-slice j / k-slice
    i of the owning :class:`~repro.compiler.tiling.TilingPlan`; every tile
    shares the layer-global ``sw``/``sx`` so tiled step-time execution
    stays bit-exact against the monolithic programmed path.
    """

    sw: jax.Array
    sx: jax.Array
    tiles: tuple[tuple[ProgrammedMacro, ...], ...]

    @property
    def n_tiles(self) -> int:
        # exact-ok: host-side integer byte/count arithmetic
        return sum(len(row) for row in self.tiles)


# ---------------------------------------------------------------------------
# Round-interleaved (weight-swapped) serving of oversized projections.
# ---------------------------------------------------------------------------

class CimSwapSchedule(NamedTuple):
    """STATIC round partition of one (K, N) projection over a fleet.

    When a model's µArray tiles exceed the fleet's resident ``tile_slots``,
    the layer executes in *rounds* (paper Sec. V dataflow): program up to
    ``tile_slots`` tiles, stream every input through them, swap in the
    next batch. Tiles are enumerated column-major (output channel outer,
    K-chunk inner), so each round covers at most three contiguous operand
    segments: a partial leading channel, a block of whole channels, and a
    partial trailing channel. ``rounds[r]`` lists that round's segments as
    ``(n0, n1, k0, k1)`` half-open index ranges over the original operand;
    every k-range is M-chunk aligned (except the ragged final chunk), which
    is exactly the tiled-bit-exactness condition of
    :mod:`repro.compiler.execute`.

    All fields are plain ints / int tuples — the schedule is hashable and
    rides pytrees as static aux data (see :class:`SwappedMacro`).
    """

    k: int
    n: int
    m_columns: int
    tile_slots: int
    rounds: tuple[tuple[tuple[int, int, int, int], ...], ...]

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    @property
    def n_chunks(self) -> int:
        return -(-self.k // self.m_columns)

    @property
    def n_tiles(self) -> int:
        return self.n_chunks * self.n


def build_swap_schedule(k: int, n: int, m_columns: int,
                        tile_slots: int) -> CimSwapSchedule:
    """Partition a (k, n) projection's µArray tiles into weight-swap
    rounds of at most ``tile_slots`` tiles. The round count equals the
    compiler's ``ceil(tiles / tile_slots)``
    (:func:`repro.compiler.schedule.schedule_layer`) by construction."""
    if k <= 0 or n <= 0:
        raise ValueError(f"degenerate projection ({k}, {n})")
    if tile_slots < 1:
        raise ValueError(f"tile_slots must be >= 1, got {tile_slots}")
    m = m_columns
    chunks = -(-k // m)
    total = chunks * n

    def k_range(c0: int, c1: int) -> tuple[int, int]:
        return c0 * m, min(c1 * m, k)

    rounds = []
    for t0 in range(0, total, tile_slots):
        t1 = min(t0 + tile_slots, total)
        n_lo, c_lo = divmod(t0, chunks)
        n_hi, c_hi = divmod(t1 - 1, chunks)
        c_hi += 1                       # exclusive chunk end in channel n_hi
        segs: list[tuple[int, int, int, int]] = []
        if n_lo == n_hi:
            segs.append((n_lo, n_lo + 1) + k_range(c_lo, c_hi))
        else:
            mid_lo = n_lo
            if c_lo > 0:
                segs.append((n_lo, n_lo + 1) + k_range(c_lo, chunks))
                mid_lo = n_lo + 1
            mid_hi = n_hi + 1 if c_hi == chunks else n_hi
            if mid_hi > mid_lo:
                segs.append((mid_lo, mid_hi) + k_range(0, chunks))
            if c_hi < chunks:
                segs.append((n_hi, n_hi + 1) + k_range(0, c_hi))
        rounds.append(tuple(segs))
    return CimSwapSchedule(k=k, n=n, m_columns=m, tile_slots=tile_slots,
                           rounds=tuple(rounds))


@jax.tree_util.register_pytree_node_class
class SwappedMacro:
    """Swap-scheduled (NOT fleet-resident) state of one (K, N) projection.

    The fleet is too small to pin this model, so the projection owns no
    frozen weight-plane state: every input stream re-programs its tiles
    round by round (the schedule's reprogram events) and only the scales
    persist — ``sw``/``sx`` are fixed at construction exactly like a
    resident :class:`ProgrammedMacro`'s, which is what keeps swapped
    execution bit-identical to the pinned path. Children are the scale
    arrays (stacked leading axes ride ``jax.lax.scan`` like parameters);
    the :class:`CimSwapSchedule` is static aux data.
    """

    def __init__(self, sw: jax.Array, sx: jax.Array,
                 sched: CimSwapSchedule):
        self.sw = sw
        self.sx = sx
        self.sched = sched

    def tree_flatten(self):
        return (self.sw, self.sx), self.sched

    @classmethod
    def tree_unflatten(cls, sched, children):
        return cls(children[0], children[1], sched)


def swap_macro(w: jax.Array, cfg: CimConfig, tile_slots: int, *,
               sx, sw=None) -> SwappedMacro:
    """Build swap-scheduled state for a (..., K, N) weight (stacked leading
    axes get per-instance ``sw``/``sx``, sharing one static schedule)."""
    K, N = w.shape[-2:]
    sched = build_swap_schedule(K, N, cfg.m_columns, tile_slots)
    if sw is None:
        w2 = w.reshape((-1, K, N))
        sw = jax.vmap(lambda wi: quant.calibrate_scale(wi, cfg.w_bits))(w2)
        sw = sw.reshape(w.shape[:-2])
    sw = jnp.asarray(sw, jnp.float32)
    sx = jnp.asarray(sx, jnp.float32)
    if sx.ndim > w.ndim - 2:
        raise NotImplementedError(
            "per-feature (per-channel) static activation scales are not "
            "supported on swap-scheduled projections: the DAC gain trims "
            "belong to resident macro state, and a swapped projection "
            "re-programs its tiles every stream. Use a scalar sx here.")
    sx = jnp.broadcast_to(sx, w.shape[:-2])
    return SwappedMacro(sw, sx, sched)


def cim_mf_matmul_swapped(x: jax.Array, w: jax.Array, swap: SwappedMacro,
                          cfg: CimConfig,
                          silicon: Optional[ProjectionSilicon] = None
                          ) -> jax.Array:
    """Round-interleaved MF correlation x:(...,K) against a swap-scheduled
    projection: program round r's tiles (weight-side work, per STREAM — the
    reprogram events billed by the compiler's Eq. 4 roll-up), stream the
    step-time inputs through them, swap in round r+1.

    Bit-identical to ``cim_mf_matmul_programmed`` against a resident macro
    programmed with the same ``sw``/``sx``: partial code sums are
    integer-valued floats, so per-segment ``.at[].add`` accumulation is
    exact regardless of the round partition, and the single final
    recombine applies the same rounding sequence.

    ``silicon`` carries the per-TILE ADC instances of the projection: the
    swap rounds fill fleet slots 0..S-1 in tile order, and the silicon
    gather (``repro.silicon.instance.projection_silicon``) uses exactly
    that assignment, so tile (c, n) digitises through the same physical
    slot's instance whether the projection is pinned or swapped.
    """
    sched = swap.sched
    K, N = sched.k, sched.n
    if w.shape != (K, N):
        raise ValueError(f"swap schedule is for ({K}, {N}), weight is "
                         f"{w.shape}")
    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, K)
    b = x2.shape[0]
    s1 = jnp.zeros((b, N), jnp.float32)
    s2 = jnp.zeros((b, N), jnp.float32)
    r_w = jnp.zeros((1, N), jnp.float32)
    for segments in sched.rounds:
        for (n0, n1, k0, k1) in segments:
            ws = cim_program_weight_state(w[k0:k1, n0:n1], cfg, swap.sw)
            sil = None if silicon is None else \
                silicon.slice(n0, n1, k0, k1, sched.m_columns)
            p = cim_input_partials(x2[:, k0:k1], ws, cfg, swap.sx,
                                   silicon=sil)
            s1 = s1.at[:, n0:n1].add(p.s1c)
            s2 = s2.at[:, n0:n1].add(p.s2c)
            r_w = r_w.at[:, n0:n1].add(p.r_w)
    rxc = cim_rx_partials(x2, cfg, swap.sx, silicon)
    y = cim_mf_recombine(CimPartials(s1, s2, rxc, r_w), swap.sw, swap.sx,
                         cfg)
    return y.reshape(batch_shape + (N,)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Projection-tree walk shared by programming and the calibration lab.
# ---------------------------------------------------------------------------

def default_static_sx(cfg: CimConfig,
                      act_amax: float = DEFAULT_ACT_AMAX) -> float:
    """Static activation scale from a full-scale amax assumption."""
    return float(act_amax) / quant.qmax(cfg.x_bits)


def _is_projection(node: Any) -> bool:
    """MF projection dicts are exactly those carrying the neuron's alpha."""
    return (isinstance(node, dict) and "w" in node and "alpha" in node
            and hasattr(node["w"], "ndim") and node["w"].ndim >= 2
            and hasattr(node["alpha"], "ndim"))


def _is_conv_projection(node: Any) -> bool:
    """Conv projections carry a (kh, kw, Cin, Cout) weight against a
    per-channel alpha: two extra leading weight axes relative to a
    (possibly stack-vmapped) linear projection, whose w/alpha ranks always
    differ by exactly one."""
    return node["w"].ndim - node["alpha"].ndim == 3


_EXPERT_KEYS = ("up", "gate", "down")


def _is_expert_bank(node: Any) -> bool:
    """The MoE expert layout: stacked (E, K, N) arrays per projection role
    plus the stacked MF alphas (``moe.moe_init``)."""
    return (isinstance(node, dict) and "alpha_up" in node
            and all(k in node and hasattr(node[k], "ndim")
                    and node[k].ndim >= 3 for k in _EXPERT_KEYS))


def map_projections(params: Any, fn: Callable[[str, dict, str], dict]) -> Any:
    """Rebuild a parameter tree, transforming every MF projection.

    ``fn(name, node, kind)`` is called with a stable dotted path name
    (dict keys / sequence indices joined by '.'), the projection dict, and
    ``kind`` in {'linear', 'conv', 'experts'}; its return value replaces
    the node. Non-projection structure is preserved. The same walk (and
    therefore the same names) drives both scale programming here and the
    calibration observers in ``repro.calib`` — names line up by
    construction.
    """
    def walk(node, path):
        if _is_expert_bank(node):
            return fn(".".join(path), node, "experts")
        if _is_projection(node):
            kind = "conv" if _is_conv_projection(node) else "linear"
            return fn(".".join(path), node, kind)
        if isinstance(node, dict):
            return {k: walk(v, path + (str(k),)) for k, v in node.items()}
        if isinstance(node, tuple):
            if hasattr(node, "_fields"):
                # NamedTuple pytree nodes are leaves here: they hold
                # arrays, never projection dicts, and a plain-tuple
                # rebuild would corrupt the treedef.
                return node
            return tuple(walk(v, path + (str(i),))
                         for i, v in enumerate(node))
        if isinstance(node, list):
            return [walk(v, path + (str(i),)) for i, v in enumerate(node)]
        return node

    return walk(params, ())


def iter_projections(params: Any) -> list[tuple[str, dict, str]]:
    """List (name, node, kind) for every MF projection in ``params``."""
    found: list[tuple[str, dict, str]] = []

    def collect(name, node, kind):
        found.append((name, node, kind))
        return node

    map_projections(params, collect)
    return found


def conv_weight_matrix(w: jax.Array) -> jax.Array:
    """(kh, kw, Cin, Cout) conv weight -> the (Cin*kh*kw, Cout) im2col
    matmul operand, matching ``convnets.conv_apply``'s patch layout."""
    kh, kw, cin, cout = w.shape
    return jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * kh * kw, cout)


# ---------------------------------------------------------------------------
# Whole-model programming (the serve-time entry point).
# ---------------------------------------------------------------------------

def _program_nd(w: jax.Array, cfg: CimConfig, sx: jax.Array,
                prefer_lossless: bool = True) -> ProgrammedMacro:
    """Program a (..., K, N) weight, vmapping over stacked leading axes
    (scan periods, experts) so programmed leaves slice exactly like the
    parameter leaves they shadow; ``sx`` carries one scale per stacked
    instance (shape = the leading axes)."""
    if w.ndim == 2:
        return program_macro(w, cfg, sx=sx, prefer_lossless=prefer_lossless)
    return jax.vmap(lambda wi, si: _program_nd(wi, cfg, si,
                                               prefer_lossless))(w, sx)


def program_weights(params: Any, cfg: CimConfig, *,
                    act_amax: float = DEFAULT_ACT_AMAX,
                    scales: Optional[dict] = None,
                    swap: Optional[dict[str, int]] = None,
                    prefer_lossless: bool = True) -> Any:
    """Program every MF projection in a model parameter tree.

    Returns a copy of ``params`` where each projection dict gains a
    ``"prog"`` entry (a :class:`ProgrammedMacro`, possibly with stacked
    leading axes); MoE expert banks gain ``"prog_up"/"prog_gate"/
    "prog_down"`` and conv projections a ``"prog"`` over the im2col
    operand. ``apply_projection`` / ``conv_apply`` / ``_expert_ffn`` then
    serve CIM_SIM projections from the programmed state with no per-step
    weight-side work.

    ``scales`` maps projection names (the :func:`map_projections` dotted
    paths; expert banks use ``<name>.up/gate/down``) to static activation
    scales — a scalar, an array over the stacked leading axes (scan
    periods, experts) for per-instance calibration, or a per-feature
    (..., K) vector (conv projections: per-Cin, expanded over the im2col
    patch) for per-CHANNEL calibration, realised as input-DAC gain trims
    (:func:`_split_channel_sx`). Unnamed projections fall back to the
    full-scale ``act_amax`` assumption. Calibration artifacts from
    ``repro.calib`` produce exactly this mapping.

    ``swap`` maps projection names to a fleet's resident ``tile_slots``:
    those projections are NOT pinned — they get a :class:`SwappedMacro`
    whose round-interleaved execution re-programs tiles every input
    stream (the fleet cannot hold the model; see ``repro.serve.engine``).
    Only linear projections can swap; scales compose with ``swap``.

    ``prefer_lossless=False`` forces plane-level (bit-packed) state even
    at exactly-lossless ADC design points — required when per-tile
    silicon variation will be injected at step time (the lossless
    collapse has no per-chunk ADC evaluations to perturb).
    """
    default_sx = jnp.float32(default_static_sx(cfg, act_amax))
    scales = scales or {}
    swap = swap or {}
    if cfg.use_kernel and cfg.m_columns > 0:
        # Fail early with the pack_chunks precondition rather than deep in
        # a traced program.
        from repro.kernels.cim_mav import CHUNK_PAD
        if cfg.m_columns > CHUNK_PAD:
            raise ValueError(
                f"m_columns={cfg.m_columns} > CHUNK_PAD={CHUNK_PAD}: the "
                f"kernel layout cannot hold this µArray geometry")

    def sx_for(name: str, w: jax.Array) -> jax.Array:
        sx = jnp.asarray(scales.get(name, default_sx), jnp.float32)
        lead = w.shape[:-2]
        if sx.shape == lead:
            return sx
        if sx.ndim >= 1 and sx.shape[-1] == w.shape[-2]:
            # Per-feature (K,) scale vector -> per-channel calibration.
            return jnp.broadcast_to(sx, lead + (w.shape[-2],))
        return jnp.broadcast_to(sx, lead)

    def prog(name, node, kind):
        out = dict(node)
        if name in swap:
            if kind != "linear":
                raise NotImplementedError(
                    f"{name}: round-interleaved weight swapping covers "
                    f"linear projections only ({kind} projections must "
                    f"stay fleet-resident)")
            out["prog"] = swap_macro(node["w"], cfg, swap[name],
                                     sx=sx_for(name, node["w"]))
            return out
        if kind == "experts":
            for key in _EXPERT_KEYS:
                w = node[key]
                out[f"prog_{key}"] = _program_nd(
                    w, cfg, sx_for(f"{name}.{key}", w), prefer_lossless)
        elif kind == "conv":
            kh, kw, cin, _ = node["w"].shape
            w2 = conv_weight_matrix(node["w"])
            sxc = jnp.asarray(scales.get(name, default_sx), jnp.float32)
            if sxc.ndim >= 1 and sxc.shape[-1] == cin:
                # Per-Cin calibration: the im2col operand is Cin-major
                # (conv_weight_matrix), so each channel's gain covers its
                # kh*kw patch columns.
                sxc = jnp.repeat(sxc, kh * kw, axis=-1)
            out["prog"] = program_macro(w2, cfg, sx=sxc,
                                        prefer_lossless=prefer_lossless)
        else:
            out["prog"] = _program_nd(node["w"], cfg,
                                      sx_for(name, node["w"]),
                                      prefer_lossless)
        return out

    return map_projections(params, prog)


def _is_prog_key(k: Any) -> bool:
    return isinstance(k, str) and (k == "prog" or k.startswith("prog_"))


def strip_keys(params: Any, drop: Callable[[Any], bool]) -> Any:
    """Rebuild a parameter tree without the dict entries whose KEY
    matches ``drop`` — the shared walk behind :func:`strip_programmed`
    and the silicon lab's ``strip_silicon``. NamedTuple pytree nodes
    (ProgrammedMacro, ProjectionSilicon, ...) are leaves: rebuilding
    them as plain tuples would corrupt the tree, and they cannot contain
    dict entries to strip."""
    def walk(node):
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items() if not drop(k)}
        if isinstance(node, tuple):
            if hasattr(node, "_fields"):
                return node
            return tuple(walk(v) for v in node)
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node
    return walk(params)


def strip_programmed(params: Any) -> Any:
    """Inverse of :func:`program_weights` (drop every programmed entry)."""
    return strip_keys(params, _is_prog_key)


def _walk_programmed(params: Any, fn: Callable[[Any], None]) -> None:
    """Call ``fn`` on every programmed entry in a parameter tree."""
    def walk(node):
        if isinstance(node, dict):
            for k, v in node.items():
                if _is_prog_key(k):
                    fn(v)
                else:
                    walk(v)
        elif isinstance(node, (tuple, list)):
            for v in node:
                walk(v)
    walk(params)


def programmed_bytes(params: Any) -> int:
    """Total bytes held by programmed state in a parameter tree."""
    total = 0

    def count(v):
        nonlocal total
        # exact-ok: host-side integer byte/count arithmetic
        total += sum(leaf.size * leaf.dtype.itemsize
                     for leaf in jax.tree.leaves(v))

    _walk_programmed(params, count)
    return total


def programmed_bytes_unpacked(params: Any, cfg: CimConfig) -> int:
    """Bytes the same programmed state would occupy WITHOUT bit-packing.

    The pre-packing layouts held one int8 per µArray plane-cell plus one
    int8 sign-gate cell (plane-level state: ``w_planes + 1`` bytes per
    cell) and separate int8 magnitude/gate arrays for the lossless
    collapse (2 bytes per cell). Kernel-layout state is not packed, so it
    counts as-is. The ratio against :func:`programmed_bytes` is the
    packing win tracked in ``BENCH_serve.json``.
    """
    total = 0

    def count(v):
        nonlocal total

        def one(pm):
            nonlocal total
            for leaf in jax.tree.leaves((pm.sw, pm.sx, pm.r_w,
                                         pm.dac_gains)):
                total += leaf.size * leaf.dtype.itemsize
            if pm.state is not None:
                total += pm.state.packed.size * (cfg.w_planes + 1)
                total += pm.state.r_w.size * pm.state.r_w.dtype.itemsize
            if pm.lossless is not None:
                total += pm.lossless.packed.size * 2
            if pm.kernel is not None:
                # exact-ok: host-side integer byte/count arithmetic
                total += sum(leaf.size * leaf.dtype.itemsize
                             for leaf in jax.tree.leaves(pm.kernel))

        if isinstance(v, ProgrammedMacro):
            one(v)
        elif isinstance(v, ProgrammedLayer):
            for row in v.tiles:
                for pm in row:
                    one(pm)

    _walk_programmed(params, count)
    return total
