"""Weight-stationary programmed-macro runtime (program-time/step-time split).

In the paper's macro the weights are programmed ONCE into the µArray (sign
row + magnitude bitplane rows) and only inputs stream per cycle — the
SA-ADC digitises charge-averaged MAVs against *stationary* weight
bitplanes. This module mirrors that discipline for the behavioural
simulator:

  * :class:`ProgrammedMacro` — the frozen per-projection weight state: the
    calibrated weight scale ``sw``, a *static* activation scale ``sx``
    fixed at program time, the exact digital ``r_w`` residue, and either
    the chunked einsum-path weight state (:class:`~repro.core.cim
    .CimWeightState`) or the Pallas kernel's pre-packed chunk layout
    (:class:`~repro.core.cim.CimKernelState`) built from
    ``kernels/ops.pack_chunks``.
  * :func:`program_macro` — program one (K, N) projection.
  * :func:`program_weights` — walk a model parameter tree and attach a
    ``"prog"`` entry to every MF projection dict (those carrying the MF
    neuron's ``alpha``), stacked-layer and vmapped layouts included, so the
    programmed state flows through ``jax.lax.scan`` exactly like the
    parameters it shadows. ``core.mf.apply_projection`` picks it up in
    CIM_SIM mode.
  * :class:`ProgrammedLayer` — per-tile programmed slices of one
    compiler-tiled projection (see ``repro.compiler.execute``).

Bit-exactness contract: for the same ``CimConfig`` and the same ``sx``,
the programmed path is bit-identical to the on-the-fly path (monolithic
and tiled) — both phases run the very same ops on the very same arrays,
just split across time. The *static* ``sx`` is the one modelling choice
(hardware cannot re-calibrate the input DAC per batch); see
EXPERIMENTS.md "Static activation-scale calibration".
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.cim import (CimConfig, CimKernelState, CimPartials,
                            CimWeightState, _input_operands, _weight_operands,
                            cim_input_partials, cim_kernel_forward,
                            cim_mf_recombine, cim_program_kernel_state,
                            cim_program_weight_state)

# Full-scale assumption for the default static activation calibration:
# post-norm activations are ~unit-RMS, so |x| <= ~4 covers >4 sigma. Used
# only when no measured amax is supplied (see EXPERIMENTS.md).
DEFAULT_ACT_AMAX = 4.0


def adc_exactly_lossless(cfg: CimConfig) -> bool:
    """True at the paper's exactly-lossless pairings (2^A_P - 1 == M).

    There the SA-ADC code of every chunk MAV *is* the integer discharge
    count (code = round(count/M * (2^A_P - 1)) = count), so plane/chunk
    decomposition, digitisation, and plane recombination collapse
    algebraically: sum_p 2^p sum_c code[c,p] == sum_k gate_k * |v_k|.
    Both hardware design points (8x62 -> 5-bit, 8x30 -> 4-bit) qualify.
    """
    return 2 ** cfg.adc_bits - 1 == cfg.m_columns


class CimLosslessState(NamedTuple):
    """Collapsed weight state for exactly-lossless ADC design points.

    Holds only the dense integer magnitudes and sign gates: the step
    becomes two (B, K) @ (K, N) matmuls — bit-identical to the plane-level
    pipeline (every partial sum is integer-valued, exact in float32) while
    streaming W_P-1 times fewer weight bytes per decode step.
    """

    aw: jax.Array   # (K, N) int8 |w_q| integer magnitudes
    gw: jax.Array   # (K, N) int8 step(w) sign gates


class ProgrammedMacro(NamedTuple):
    """Frozen weight state of one macro-mapped (K, N) projection."""

    sw: jax.Array                          # calibrated weight scale
    sx: jax.Array                          # STATIC activation scale
    r_w: jax.Array                         # (1, N) digital |w| residue
    state: Optional[CimWeightState]        # einsum-path chunked state
    kernel: Optional[CimKernelState]       # Pallas-path pre-packed state
    lossless: Optional[CimLosslessState]   # collapsed exact-ADC state

    @property
    def n_out(self) -> int:
        return self.r_w.shape[-1]


def program_macro(w: jax.Array, cfg: CimConfig, *, sx, sw=None,
                  prefer_lossless: bool = True) -> ProgrammedMacro:
    """Program one (K, N) projection's weights into macro state.

    ``sx`` is the static activation scale the macro will quantise inputs
    against for its whole service life; ``sw`` defaults to the max-abs
    calibration the on-the-fly path uses. The expensive weight-side work
    (quantise, sign/magnitude split, bitplanes, chunk/kernel packing)
    happens exactly once, here.

    At exactly-lossless ADC design points the collapsed
    :class:`CimLosslessState` is programmed instead of the plane-level
    state (``prefer_lossless=False`` forces planes — needed for per-step
    variability injection and the compiler's tiled partial accumulation).
    """
    if sw is None:
        sw = quant.calibrate_scale(w, cfg.w_bits)
    sw = jnp.asarray(sw, jnp.float32)
    sx = jnp.asarray(sx, jnp.float32)
    if cfg.use_kernel:
        ks = cim_program_kernel_state(w, cfg, sw)
        return ProgrammedMacro(sw, sx, ks.r_w, None, ks, None)
    if prefer_lossless and adc_exactly_lossless(cfg):
        step_w, abs_w, _ = _weight_operands(w, cfg, sw)
        r_w = jnp.sum(abs_w, axis=0).astype(jnp.float32)[None, :]
        ls = CimLosslessState(abs_w.astype(jnp.int8),
                              step_w.astype(jnp.int8))
        return ProgrammedMacro(sw, sx, r_w, None, None, ls)
    ws = cim_program_weight_state(w, cfg, sw)
    return ProgrammedMacro(sw, sx, ws.r_w, ws, None, None)


def _lossless_partials(x2: jax.Array, ls: CimLosslessState, cfg: CimConfig,
                       sx: jax.Array, r_w: jax.Array) -> CimPartials:
    """Collapsed step at an exactly-lossless design point.

    With code == count, the plane-weighted code sums reduce to the dense
    correlations sum_k step(x)*|w| and sum_k |x|*step(w); all entries are
    integers below 2^24, so the float32 matmuls are exact and the result
    is bit-identical to the plane-level path fed through the same
    ``cim_mf_recombine``.
    """
    step_x, abs_x, _ = _input_operands(x2, cfg, sx)
    s1c = step_x @ ls.aw.astype(jnp.float32)                   # (B, N)
    s2c = abs_x.astype(jnp.float32) @ ls.gw.astype(jnp.float32)
    rxc = jnp.sum(abs_x, axis=-1, keepdims=True).astype(jnp.float32)
    return CimPartials(s1c, s2c, rxc, r_w)


def cim_mf_matmul_programmed(x: jax.Array, prog: ProgrammedMacro,
                             cfg: CimConfig,
                             cap_weights: Optional[jax.Array] = None,
                             comparator_offset: Optional[jax.Array] = None
                             ) -> jax.Array:
    """Step-time MF correlation x:(...,K) against a programmed macro.

    Bit-identical to ``cim_mf_matmul(x, w, cfg)`` whenever ``prog`` was
    programmed with the same ``cfg`` and the dynamic activation scale of
    ``x`` (the parity tested by tests/test_programmed.py). Per-step
    variability injection (cap mismatch / comparator offset) is supported
    on the plane-level einsum path only.
    """
    K = x.shape[-1]
    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, K)
    inject = cap_weights is not None or comparator_offset is not None
    if prog.state is not None:
        parts = cim_input_partials(x2, prog.state, cfg, prog.sx,
                                   cap_weights, comparator_offset)
        y = cim_mf_recombine(parts, prog.sw, prog.sx, cfg)
    elif inject:
        raise ValueError(
            "variability injection needs a plane-level ProgrammedMacro "
            "(program with use_kernel=False, prefer_lossless=False)")
    elif prog.lossless is not None:
        parts = _lossless_partials(x2, prog.lossless, cfg, prog.sx,
                                   prog.r_w)
        y = cim_mf_recombine(parts, prog.sw, prog.sx, cfg)
    else:
        y = cim_kernel_forward(x2, prog.kernel, cfg, prog.sw, prog.sx)
    return y.reshape(batch_shape + (prog.n_out,)).astype(x.dtype)


class ProgrammedLayer(NamedTuple):
    """Per-tile programmed slices of one compiler-tiled (K, N) projection.

    ``tiles[j][i]`` is the :class:`ProgrammedMacro` of n-slice j / k-slice
    i of the owning :class:`~repro.compiler.tiling.TilingPlan`; every tile
    shares the layer-global ``sw``/``sx`` so tiled step-time execution
    stays bit-exact against the monolithic programmed path.
    """

    sw: jax.Array
    sx: jax.Array
    tiles: tuple[tuple[ProgrammedMacro, ...], ...]

    @property
    def n_tiles(self) -> int:
        return sum(len(row) for row in self.tiles)


# ---------------------------------------------------------------------------
# Whole-model programming (the serve-time entry point).
# ---------------------------------------------------------------------------

def default_static_sx(cfg: CimConfig,
                      act_amax: float = DEFAULT_ACT_AMAX) -> float:
    """Static activation scale from a full-scale amax assumption."""
    return float(act_amax) / quant.qmax(cfg.x_bits)


def _is_projection(node: Any) -> bool:
    """MF projection dicts are exactly those carrying the neuron's alpha."""
    return (isinstance(node, dict) and "w" in node and "alpha" in node
            and hasattr(node["w"], "ndim") and node["w"].ndim >= 2)


def _program_nd(w: jax.Array, cfg: CimConfig, sx) -> ProgrammedMacro:
    """Program a (..., K, N) weight, vmapping over stacked leading axes
    (scan periods, experts) so programmed leaves slice exactly like the
    parameter leaves they shadow."""
    if w.ndim == 2:
        return program_macro(w, cfg, sx=sx)
    return jax.vmap(lambda wi: _program_nd(wi, cfg, sx))(w)


def program_weights(params: Any, cfg: CimConfig, *,
                    act_amax: float = DEFAULT_ACT_AMAX) -> Any:
    """Program every MF projection in a model parameter tree.

    Returns a copy of ``params`` where each projection dict gains a
    ``"prog"`` entry (a :class:`ProgrammedMacro`, possibly with stacked
    leading axes). ``apply_projection`` then serves CIM_SIM projections
    from the programmed state with no per-step weight-side work. Non-dict
    projection layouts (e.g. the MoE expert arrays) keep the on-the-fly
    path — see ROADMAP open items.
    """
    sx = jnp.float32(default_static_sx(cfg, act_amax))
    if cfg.use_kernel and cfg.m_columns > 0:
        # Fail early with the pack_chunks precondition rather than deep in
        # a traced program.
        from repro.kernels.cim_mav import CHUNK_PAD
        if cfg.m_columns > CHUNK_PAD:
            raise ValueError(
                f"m_columns={cfg.m_columns} > CHUNK_PAD={CHUNK_PAD}: the "
                f"kernel layout cannot hold this µArray geometry")

    def walk(node):
        if _is_projection(node):
            out = dict(node)
            out["prog"] = _program_nd(node["w"], cfg, sx)
            return out
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, tuple):
            return tuple(walk(v) for v in node)
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    return walk(params)


def strip_programmed(params: Any) -> Any:
    """Inverse of :func:`program_weights` (drop every ``"prog"`` entry)."""
    def walk(node):
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items() if k != "prog"}
        if isinstance(node, tuple):
            return tuple(walk(v) for v in node)
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node
    return walk(params)


def programmed_bytes(params: Any) -> int:
    """Total bytes held by programmed state in a parameter tree."""
    total = 0
    def walk(node):
        nonlocal total
        if isinstance(node, dict):
            for k, v in node.items():
                if k == "prog":
                    total += sum(leaf.size * leaf.dtype.itemsize
                                 for leaf in jax.tree.leaves(v))
                else:
                    walk(v)
        elif isinstance(node, (tuple, list)):
            for v in node:
                walk(v)
    walk(params)
    return total
