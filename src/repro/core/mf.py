"""Multiplication-free (MF) operator — the paper's core contribution (Eq. 1-3).

The MF correlation of an input vector ``x`` and weight vector ``w`` is

    x (+) w = sum_i sign(x_i) * |w_i| + sign(w_i) * |x_i|

which is an l1-flavoured correlation (``x (+) x = 2 * ||x||_1``). A neuron is
``phi(alpha * (x (+) w) + b)``; the operator is itself nonlinear, so ``phi``
may be identity.

On TPU we realise the operator as TWO MXU matmuls over transformed operands:

    X (+) W = sign(X) @ |W| + |X| @ sign(W)

(`kernels/mf_matmul.py` fuses both into one Pallas kernel that reads X and W
from HBM once). Training uses the paper's surrogate gradients (Eq. 3):
``d sign(x)/dx = 2*delta(x)`` approximated by a steep zero-centred Gaussian,
``d|x|/dx = sign(x)`` exact a.e.

Sign convention: ``jnp.sign`` (sign(0) = 0) for the float/training path; the
hardware path (`core/cim.py`) uses the storage convention sign(0) = +1 which
is what an SRAM sign bit encodes — see ``hw_sign``.
"""

from __future__ import annotations

import enum
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

# Calibration-lab observation taps (no-ops unless a calibration collector
# is installed; `tap` deliberately imports nothing from repro so this
# module-load import cannot cycle).
from repro.calib import tap as _calib_tap


class ExecMode(str, enum.Enum):
    """Execution backend for a weight-activation projection."""

    REGULAR = "regular"        # typical operator: x @ w
    MF = "mf"                  # MF operator, jnp dual-matmul, surrogate grads
    MF_KERNEL = "mf_kernel"    # MF operator, fused Pallas kernel forward
    CIM_SIM = "cim_sim"        # bitplane + SA-ADC hardware-faithful forward
    BNN = "bnn"                # binarized-weight baseline (Table I / BNN)


def hw_sign(v: jax.Array) -> jax.Array:
    """Hardware sign convention: +1 for v >= 0, -1 otherwise.

    An SRAM sign bit has no third state; 0 is stored as +. Satisfies
    ``hw_sign(v) == 2 * step(v) - 1`` with ``step(v) = (v >= 0)``.
    """
    return jnp.where(v >= 0, jnp.ones_like(v), -jnp.ones_like(v))


def mf_correlate_ref(x: jax.Array, w: jax.Array, *, hw: bool = False) -> jax.Array:
    """Reference (x (+) w) along the last axis of ``x`` / first of ``w``.

    x: (..., K), w: (K, N) -> (..., N). ``hw=True`` uses the sign(0)=+1
    storage convention (matches the CIM path bit-for-bit).
    """
    sgn = hw_sign if hw else jnp.sign
    return sgn(x) @ jnp.abs(w) + jnp.abs(x) @ sgn(w)


def _gauss_delta(v: jax.Array, sigma: float) -> jax.Array:
    """Steep zero-centred Gaussian approximating the Dirac delta (Eq. 3)."""
    inv = 1.0 / (sigma * math.sqrt(2.0 * math.pi))
    return inv * jnp.exp(-0.5 * (v / sigma) ** 2)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def mf_matmul(x: jax.Array, w: jax.Array, delta_sigma: float = 0.5,
              delta_coeff: float = 1.0) -> jax.Array:
    """MF correlation with the paper's surrogate gradients (Eq. 3).

    Forward: ``sign(x) @ |w| + |x| @ sign(w)`` with x: (..., K), w: (K, N).

    Backward (per Eq. 3, vectorised):
      dX = sign(X) * (g @ sign(W)^T) + 2*delta(X) * (g @ |W|^T)
      dW = sign(W) * (sign(X)^T @ g) + 2*delta(W) * (|X|^T @ g)
    with delta(.) a steep Gaussian of width ``delta_sigma`` scaled by
    ``delta_coeff`` (0 disables the delta term -> pure sign-product grads).
    """
    return mf_correlate_ref(x, w)


def _mf_fwd(x, w, delta_sigma, delta_coeff):
    return mf_correlate_ref(x, w), (x, w)


def _mf_bwd(delta_sigma, delta_coeff, res, g):
    x, w = res
    sx, ax = jnp.sign(x), jnp.abs(x)
    sw, aw = jnp.sign(w), jnp.abs(w)
    # Collapse leading batch dims of x/g for the weight cotangent.
    g2 = g.reshape(-1, g.shape[-1])
    x2 = x.reshape(-1, x.shape[-1])
    dx = sx * (g @ sw.T)
    dw = sw * (jnp.sign(x2).T @ g2)
    if delta_coeff != 0.0:
        dx = dx + 2.0 * delta_coeff * _gauss_delta(x, delta_sigma) * (g @ aw.T)
        dw = dw + 2.0 * delta_coeff * _gauss_delta(w, delta_sigma) * (
            jnp.abs(x2).T @ g2)
    dx = dx.astype(x.dtype)
    dw = dw.astype(w.dtype)
    return dx, dw


mf_matmul.defvjp(_mf_fwd, _mf_bwd)


def mf_conv2d(x: jax.Array, w: jax.Array, *, stride: tuple[int, int] = (1, 1),
              padding: str = "SAME", delta_sigma: float = 0.5,
              delta_coeff: float = 1.0) -> jax.Array:
    """MF 2-D convolution via patch extraction + MF matmul.

    Unlike a linear matmul, the MF operator does not commute with the
    convolution lowering tricks XLA uses, so we materialise patches
    (im2col) and run the MF correlation per patch — exactly how the
    hardware maps a conv channel onto a µArray (flattened filter across
    columns).

    x: (B, H, W, Cin) NHWC; w: (kh, kw, Cin, Cout).
    """
    kh, kw, cin, cout = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, filter_shape=(kh, kw), window_strides=stride, padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    # conv_general_dilated_patches returns feature dim ordered as
    # (Cin, kh, kw); reorder w to match.
    w2 = jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * kh * kw, cout)
    b, oh, ow, _ = patches.shape
    flat = patches.reshape(b * oh * ow, cin * kh * kw)
    out = mf_matmul(flat, w2, delta_sigma, delta_coeff)
    return out.reshape(b, oh, ow, cout)


# ---------------------------------------------------------------------------
# Eq. 2 hardware reformulation (used by the CIM path; exposed for tests).
# ---------------------------------------------------------------------------

def step(v: jax.Array) -> jax.Array:
    """step() in Eq. 2: 1 for v >= 0 else 0 (matches hw_sign convention)."""
    return (v >= 0).astype(v.dtype)


def mf_correlate_step_form(x: jax.Array, w: jax.Array) -> jax.Array:
    """Eq. 2 step()-reformulated MF correlation (identical to hw ref).

    sum sign(w)|x| = 2*sum step(w)|x| - sum|x|   (residue: dummy-ones row)
    sum sign(x)|w| = 2*sum step(x)|w| - sum|w|   (residue: weight statistic)
    """
    ax, aw = jnp.abs(x), jnp.abs(w)
    s1 = 2.0 * (step(x) @ aw) - jnp.sum(aw, axis=0)          # sign(x)|w|
    s2 = 2.0 * (ax @ step(w)) - jnp.sum(ax, axis=-1, keepdims=True)
    return s1 + s2


@jax.custom_vjp
def bnn_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Binarized-weight correlation x @ sign(w) with straight-through
    gradients (the BNN baseline the paper compares against in Table I)."""
    return x @ hw_sign(w)


def _bnn_fwd(x, w):
    return x @ hw_sign(w), (x, w)


def _bnn_bwd(res, g):
    x, w = res
    g2 = g.reshape(-1, g.shape[-1])
    x2 = x.reshape(-1, x.shape[-1])
    dx = (g @ hw_sign(w).T).astype(x.dtype)
    dw = (x2.T @ g2).astype(w.dtype)          # STE through sign()
    return dx, dw


bnn_matmul.defvjp(_bnn_fwd, _bnn_bwd)


# ---------------------------------------------------------------------------
# Layer-level primitives used by the model zoo.
# ---------------------------------------------------------------------------

def mf_dense_init(key: jax.Array, in_dim: int, out_dim: int,
                  dtype: Any = jnp.float32) -> dict:
    """Params for an MF neuron: phi(alpha * (x (+) w) + b), alpha per-channel.

    alpha is initialised to 1/sqrt(2K) so the MF output (std ~ sqrt(K*(s_w^2
    + s_x^2)), dominated by the |x| term) starts at unit scale.
    """
    kw, = jax.random.split(key, 1)
    w = jax.random.normal(kw, (in_dim, out_dim), dtype) / math.sqrt(in_dim)
    alpha = jnp.full((out_dim,), 1.0 / math.sqrt(2.0 * in_dim), dtype)
    b = jnp.zeros((out_dim,), dtype)
    return {"w": w, "alpha": alpha, "b": b}


def dense_init(key: jax.Array, in_dim: int, out_dim: int,
               dtype: Any = jnp.float32, use_bias: bool = True) -> dict:
    w = jax.random.normal(key, (in_dim, out_dim), dtype) / math.sqrt(in_dim)
    p = {"w": w}
    if use_bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def apply_projection(params: dict, x: jax.Array, mode: ExecMode | str,
                     *, cim_cfg: Optional[Any] = None,
                     programmed: Optional[Any] = None,
                     delta_sigma: float = 0.5, delta_coeff: float = 1.0,
                     precision=None) -> jax.Array:
    """Uniform weight-activation projection used throughout the model zoo.

    mode=REGULAR: x @ w (+ b). mode=MF/MF_KERNEL/CIM_SIM: the paper's neuron
    ``alpha * (x (+) w) + b`` with the chosen backend. Every projection in
    every architecture funnels through here, so the mixed-mapping policy
    (core/mapping.py) can flip a layer between digital and CIM execution by
    changing ``mode`` alone.

    In CIM_SIM mode a weight-stationary :class:`~repro.core.programmed
    .ProgrammedMacro` is consumed when available — either passed explicitly
    via ``programmed`` or embedded as ``params["prog"]`` by
    ``core.programmed.program_weights`` — serving the projection from the
    frozen macro state (inference-only: no STE backward on that path).

    Calibration taps (``repro.calib``): while a collector is installed,
    the projection input is recorded against the embedded ``obs_id``
    (observe mode), and in CIM_SIM mode the output is additionally scored
    against the float MF reference on the same input (SQNR mode).
    """
    mode = ExecMode(mode)
    w = params["w"]
    if _calib_tap.stats_active() and mode != ExecMode.REGULAR:
        _calib_tap.record_activation(params.get("obs_id"), x)
    if mode == ExecMode.REGULAR:
        y = x @ w
    elif mode == ExecMode.MF:
        y = mf_matmul(x, w, delta_sigma, delta_coeff)
    elif mode == ExecMode.MF_KERNEL:
        from repro.kernels import ops as kops  # local import: kernels optional
        y = kops.mf_matmul(x, w)
    elif mode == ExecMode.CIM_SIM:
        from repro.core import cim
        assert cim_cfg is not None, "CIM_SIM mode requires a CimConfig"
        prog = programmed if programmed is not None else params.get("prog")
        if prog is not None:
            from repro.core.programmed import (SwappedMacro,
                                               cim_mf_matmul_programmed,
                                               cim_mf_matmul_swapped)
            # Per-slot silicon instances (repro.silicon.instance
            # .attach_silicon embeds them as "sil", riding scans exactly
            # like the programmed state they perturb).
            sil = params.get("sil")
            if isinstance(prog, SwappedMacro):
                # Fleet too small to pin this projection: round-interleaved
                # execution re-programs tiles per input stream.
                y = cim_mf_matmul_swapped(x, w, prog, cim_cfg, silicon=sil)
            else:
                y = cim_mf_matmul_programmed(x, prog, cim_cfg, silicon=sil,
                                             silicon_kernel=params.get(
                                                 "silk"))
        else:
            y = cim.cim_mf_matmul_ste(x, w, cim_cfg)
        if _calib_tap.error_active():
            _calib_tap.record_projection_error(
                params.get("obs_id"), y, mf_correlate_ref(x, w, hw=True))
    elif mode == ExecMode.BNN:
        y = bnn_matmul(x, w)
    else:  # pragma: no cover
        raise ValueError(mode)
    if mode != ExecMode.REGULAR and "alpha" in params:
        y = y * params["alpha"]
    if "b" in params:
        y = y + params["b"]
    return y
