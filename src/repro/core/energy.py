"""Eq. 4 latency/energy model of the compute-in-SRAM macro (paper Sec. V).

    T = W_P * (1 + 2*A_P)                                   [clock cycles]
    E = W_P * (M * C_PL * V_PCH^2)
        + sum_{i=0}^{A_P-1} (E_C + E_SAR + 2^i * C_PL * V_PCH^2)

The absolute constants (C_PL, E_C, E_SAR) live in the paper's Fig. 7d, which
is not legible in the source text. We therefore CALIBRATE them against the
paper's two headline design points, which are stated numerically:

    8x62 µArray (M=31, W_P=8, A_P=5)  ->  ~105 TOPS/W
    8x30 µArray (M=15, W_P=8, A_P=4)  ->   ~84 TOPS/W

with the standard CIM op convention of 2 ops (1 MAC) per column per unit
operation. Solving the two linear equations gives C_PL*V^2 = 1.3065 fJ and
E_C + E_SAR = 45.19 fJ; at V_PCH = 0.4 V that is C_PL ~ 8.2 fF (including
the paper's 20% interconnect overhead). The resulting MAV/digitisation
energy split is ~55/45 versus the paper's stated 44/55 — the paper's
secondary numbers (7.6 uW MAV power, the split, and Table II TOPS/W) are
not mutually consistent at this resolution; we pin the calibration to the
TOPS/W design points because those are the comparison currency of Table II.
This is recorded in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses

from repro.core.cim import CimConfig


@dataclasses.dataclass(frozen=True)
class MacroParams:
    """Physical constants of the 45 nm macro (calibrated, see module doc)."""

    c_pl_v2_j: float = 1.3065e-15    # C_PL * V_PCH^2 (J) incl. interconnect
    e_comp_sar_j: float = 45.19e-15  # E_C + E_SAR per SA iteration (J)
    v_pch: float = 0.4               # precharge / hold voltage (V)
    clock_hz: float = 1e9            # macro clock (Sec. V-B)
    leakage_w: float = 0.97e-9       # per-µArray leakage at 0.4 V hold
    worst_discharge_s: float = 50e-12  # PL discharge, SS corner @ 120C

    @property
    def c_pl_f(self) -> float:
        return self.c_pl_v2_j / (self.v_pch ** 2)


DEFAULT_MACRO = MacroParams()

# Digital baseline efficiency used by the paper's Fig. 9 system projection.
DIGITAL_TOPS_PER_W = 2.8


def unit_op_cycles(cfg: CimConfig) -> int:
    """Eq. 4a: T = W_P * (1 + 2 * A_P) clock cycles."""
    return cfg.w_bits * (1 + 2 * cfg.adc_bits)


def unit_op_latency_s(cfg: CimConfig, macro: MacroParams = DEFAULT_MACRO) -> float:
    return unit_op_cycles(cfg) / macro.clock_hz


def unit_op_energy_j(cfg: CimConfig, macro: MacroParams = DEFAULT_MACRO) -> float:
    """Eq. 4b, exactly as printed (ADC sum not scaled by W_P)."""
    c = macro.c_pl_v2_j
    mav = cfg.w_bits * cfg.m_columns * c
    adc = sum(macro.e_comp_sar_j + (2 ** i) * c for i in range(cfg.adc_bits))
    return mav + adc


def energy_split(cfg: CimConfig, macro: MacroParams = DEFAULT_MACRO
                 ) -> dict[str, float]:
    """Fractional energy split: MAV vs digitisation vs leakage (Fig. 6b)."""
    c = macro.c_pl_v2_j
    mav = cfg.w_bits * cfg.m_columns * c
    adc = sum(macro.e_comp_sar_j + (2 ** i) * c for i in range(cfg.adc_bits))
    leak = macro.leakage_w * unit_op_latency_s(cfg, macro)
    tot = mav + adc + leak
    return {"mav": mav / tot, "digitization": adc / tot, "leakage": leak / tot}


def ops_per_unit_op(cfg: CimConfig) -> int:
    """2 ops (1 MAC) per active column per unit operation."""
    return 2 * cfg.m_columns


def tops_per_watt(cfg: CimConfig, macro: MacroParams = DEFAULT_MACRO) -> float:
    return ops_per_unit_op(cfg) / unit_op_energy_j(cfg, macro) / 1e12


def macro_throughput_ops(cfg: CimConfig, macro: MacroParams = DEFAULT_MACRO
                         ) -> float:
    """Ops/s of one µArray half pipelined at the Eq. 4a unit-op latency."""
    return ops_per_unit_op(cfg) / unit_op_latency_s(cfg, macro)


# ---------------------------------------------------------------------------
# Fig. 6a: hold-voltage trade-off (leakage vs discharge time). Simple
# exponential models anchored at the paper's chosen 0.4 V operating point.
# ---------------------------------------------------------------------------

def leakage_vs_hold_voltage(v_hold: float, macro: MacroParams = DEFAULT_MACRO
                            ) -> float:
    """Subthreshold-like leakage growth with hold voltage (anchored 0.4 V)."""
    import math
    return macro.leakage_w * math.exp((v_hold - macro.v_pch) / 0.1)


def discharge_time_vs_hold_voltage(v_hold: float,
                                   macro: MacroParams = DEFAULT_MACRO) -> float:
    """PL discharge slows as hold voltage (gate drive) drops."""
    import math
    return macro.worst_discharge_s * math.exp(-(v_hold - macro.v_pch) / 0.15)


# ---------------------------------------------------------------------------
# Fig. 9 system-level projection: mixed digital + CIM mapping.
# ---------------------------------------------------------------------------

def mixed_system_tops_per_watt(ops_mf: float, ops_digital: float,
                               cfg: CimConfig,
                               macro: MacroParams = DEFAULT_MACRO,
                               digital_tops_w: float = DIGITAL_TOPS_PER_W
                               ) -> float:
    """Fig. 9 'Avg. TOPs/W': OPS-WEIGHTED arithmetic mean of the two
    fabrics' efficiencies. (The paper's 103.97/100.91/98 values only
    reproduce under this convention; the energy-correct harmonic mean —
    `mixed_system_tops_per_watt_energy` — is much lower whenever any
    digital share exists, because the 2.8 TOPS/W fabric dominates energy.
    Both are reported in the Fig. 9 benchmark.)
    """
    mf_eff = tops_per_watt(cfg, macro)
    total = ops_mf + ops_digital
    if total <= 0:
        return 0.0
    return (ops_mf * mf_eff + ops_digital * digital_tops_w) / total


def mixed_system_tops_per_watt_energy(ops_mf: float, ops_digital: float,
                                      cfg: CimConfig,
                                      macro: MacroParams = DEFAULT_MACRO,
                                      digital_tops_w: float =
                                      DIGITAL_TOPS_PER_W) -> float:
    """Energy-correct system efficiency: total_ops / total_energy."""
    mf_eff = tops_per_watt(cfg, macro)
    energy = ops_mf / (mf_eff * 1e12) + ops_digital / (digital_tops_w * 1e12)
    total = ops_mf + ops_digital
    return total / energy / 1e12 if energy > 0 else 0.0
