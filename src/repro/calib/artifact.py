"""Calibration artifact: the saved result of a calibration run.

A :class:`CalibrationArtifact` is the per-projection static activation
scale map (``program_weights(..., scales=artifact.scales)``) plus enough
metadata to audit it — selection method, input precision, corpus size.
Serialised as plain JSON so artifacts diff cleanly in review and survive
any environment: scale values are float32, stored as exact decimal
reprs of their float64 widening, so a save/load round trip is
bit-identical.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

import numpy as np


@dataclasses.dataclass
class CalibrationArtifact:
    """Per-projection static activation scales for one (model, CimConfig).

    ``scales`` maps projection names (the ``core.programmed
    .map_projections`` dotted paths; expert banks use
    ``<name>.up/gate/down``) to float32 arrays over the projection's
    stacked leading axes — scalar-shaped for unstacked projections.
    """

    method: str
    x_bits: int
    scales: dict[str, np.ndarray]
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    def save(self, path: str) -> None:
        payload = {
            "kind": "mfnet-calibration",
            "method": self.method,
            "x_bits": self.x_bits,
            "meta": self.meta,
            "scales": {
                name: {"shape": list(np.shape(v)),
                       "data": np.asarray(v, np.float32).reshape(-1)
                       .astype(np.float64).tolist()}
                for name, v in self.scales.items()
            },
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "CalibrationArtifact":
        with open(path) as f:
            payload = json.load(f)
        if payload.get("kind") != "mfnet-calibration":
            raise ValueError(f"{path} is not a calibration artifact")
        scales = {
            name: np.asarray(rec["data"], np.float32)
            .reshape(tuple(rec["shape"]))
            for name, rec in payload["scales"].items()
        }
        return cls(method=payload["method"], x_bits=int(payload["x_bits"]),
                   scales=scales, meta=payload.get("meta", {}))
