"""Observation taps: how the calibration lab hooks the projection path.

``core.mf.apply_projection`` (and the conv/expert entry points) call the
module-level hooks below on every projection; when no collector is
installed they are no-ops costing one global read at trace time. A
calibration run installs a collector with the :func:`observing` /
:func:`measuring_error` context managers and replays a corpus through the
ordinary model forward — scan-stacked layers, vmapped experts and convs
all included, because the obs-id arrays attached by
``repro.calib.corpus.attach_observer_ids`` flow through ``jax.lax.scan``
exactly like the parameters they shadow and arrive here as concrete
per-instance ids at run time.

This module intentionally imports nothing from ``repro`` (it is imported
by ``repro.core.mf`` at module load): collectors are duck-typed objects
with ``emit_activation(obs_id, x)`` / ``emit_error(obs_id, y, y_ref)``
methods, defined in ``repro.calib.corpus``.
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Any, Optional

_STATS: Optional[Any] = None
_ERROR: Optional[Any] = None


def stats_active() -> bool:
    """True while an activation-statistics collector is installed."""
    return _STATS is not None


def error_active() -> bool:
    """True while a projection-error (SQNR) collector is installed."""
    return _ERROR is not None


def record_activation(obs_id, x) -> None:
    """Record the input of one projection call (observe mode)."""
    if _STATS is not None and obs_id is not None:
        _STATS.emit_activation(obs_id, x)


def record_projection_error(obs_id, y, y_ref) -> None:
    """Record one projection's CIM output against its float reference."""
    if _ERROR is not None and obs_id is not None:
        _ERROR.emit_error(obs_id, y, y_ref)


@contextmanager
def observing(collector):
    """Install an activation-statistics collector for the enclosed pass."""
    global _STATS
    prev, _STATS = _STATS, collector
    try:
        yield collector
    finally:
        _STATS = prev


@contextmanager
def measuring_error(collector):
    """Install a projection-error collector for the enclosed pass."""
    global _ERROR
    prev, _ERROR = _ERROR, collector
    try:
        yield collector
    finally:
        _ERROR = prev
