"""Calibration + accuracy harness: does data-driven `sx` actually help?

Two halves:

  * :func:`calibrate` — generic corpus pass: tag a parameter tree, replay
    batches through any forward in observe mode, lower the recorded
    statistics into a :class:`~repro.calib.artifact.CalibrationArtifact`.
    :func:`calibrate_lm` binds it to the unified LM forward (observation
    runs the float MF reference — the distribution the DAC must cover).
  * :func:`accuracy_report` — evaluation pass: run the fp32 MF reference
    and the programmed CIM simulator over the same batches, accumulating
    (a) per-projection SQNR through the error tap (each projection's CIM
    output against its float MF correlation on the SAME inputs) and
    (b) end-to-end logits error + top-1 agreement. :func:`evaluate_lm`
    binds it to the LM forward; ``benchmarks/calib_report.py`` sweeps
    calibration methods x ADC design points and emits BENCH_calib.json.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Optional, Sequence

import jax
import numpy as np

from repro.calib import tap
from repro.calib.artifact import CalibrationArtifact
from repro.calib.corpus import (ErrorCollector, ObserverRegistry,
                                attach_observer_ids, collect_stats,
                                scales_from_stats)
from repro.calib.observers import ObserverConfig
from repro.core.programmed import DEFAULT_ACT_AMAX, program_weights


@dataclasses.dataclass(frozen=True)
class AccuracyReport:
    """One (model, CimConfig, scale-policy) accuracy measurement."""

    rel_l2: float           # ||logits_cim - logits_ref||2 / ||logits_ref||2
    top1_agree: float       # fraction of positions with matching argmax
    mean_sqnr_db: float     # mean per-projection SQNR (CIM vs float MF)
    min_sqnr_db: float
    n_projections: int      # projection instances that saw signal

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def calibrate(forward_fn: Callable[[Any, Any], Any], params: Any,
              batches: Sequence[Any], x_bits: int, *, method: str = "mse",
              obs_cfg: ObserverConfig = ObserverConfig(), pct: float = 99.9,
              fallback_amax: float = DEFAULT_ACT_AMAX,
              per_channel: bool = False,
              meta: Optional[dict] = None) -> CalibrationArtifact:
    """One corpus pass -> a calibration artifact, for ANY model forward.

    ``forward_fn(tagged_params, batch)`` must route its projections
    through ``apply_projection`` / ``conv_apply`` (everything in the model
    zoo does); scan-stacked layers and MoE experts record one observer per
    layer instance / expert.

    ``per_channel=True`` records per-feature amax profiles alongside the
    scalar statistics and emits ``(lead..., K)`` scale vectors that
    ``program_weights`` realises as input-DAC gain trims (per-channel
    calibration; see ``corpus.scales_from_stats``).
    """
    tagged, registry = attach_observer_ids(params)
    collector = collect_stats(forward_fn, tagged, batches, registry,
                              obs_cfg)
    scales = scales_from_stats(collector, registry, x_bits, method,
                               pct=pct, fallback_amax=fallback_amax,
                               per_channel=per_channel)
    info = {"n_batches": len(batches), "n_projections": registry.n_ids,
            "obs_bins": obs_cfg.n_bins, "obs_range_max": obs_cfg.range_max,
            "per_channel": per_channel}
    info.update(meta or {})
    return CalibrationArtifact(method=method, x_bits=x_bits, scales=scales,
                               meta=info)


def accuracy_report(ref_forward: Callable[[Any], Any],
                    cim_forward: Callable[[Any], Any],
                    batches: Iterable[Any],
                    registry: ObserverRegistry) -> AccuracyReport:
    """Measure a programmed CIM forward against its float MF reference."""
    err_col = ErrorCollector(registry.n_ids)
    num = den = 0.0
    agree = total = 0
    for batch in batches:
        ref = np.asarray(ref_forward(batch), np.float32)
        with tap.measuring_error(err_col):
            cim = np.asarray(cim_forward(batch), np.float32)
        num += float(np.sum((cim - ref) ** 2))
        den += float(np.sum(ref ** 2))
        agree += int(np.sum(np.argmax(cim, -1) == np.argmax(ref, -1)))
        total += int(np.prod(ref.shape[:-1]))
    sqnr = err_col.sqnr_db()
    return AccuracyReport(
        rel_l2=float(np.sqrt(num / max(den, 1e-30))),
        top1_agree=agree / max(total, 1),
        mean_sqnr_db=float(np.mean(sqnr)) if sqnr.size else float("nan"),
        min_sqnr_db=float(np.min(sqnr)) if sqnr.size else float("nan"),
        n_projections=int(sqnr.size))


# ---------------------------------------------------------------------------
# LM bindings (the unified decoder-only forward).
# ---------------------------------------------------------------------------

def lm_ref_config(cfg):
    """The float MF reference of a cim_sim model config."""
    return dataclasses.replace(cfg, mf=dataclasses.replace(cfg.mf,
                                                           mode="mf"))


def _lm_forward(cfg):
    from repro.models import transformer as T

    def fwd(params, batch):
        logits, _ = T.lm_forward(params, batch, cfg)
        return logits

    return fwd


def restore_lm_params(checkpoint: str, template: Any, cfg,
                      step: Optional[int] = None,
                      train_cfg: Optional[Any] = None
                      ) -> tuple[Any, int]:
    """Restore trained LM parameters from a ``train.checkpoint`` root.

    ``template`` (a fresh ``lm_init`` tree for ``cfg``) provides structure
    and dtypes. Two checkpoint layouts are accepted, distinguished by the
    manifest's leaf count: a bare parameter tree, or the full
    ``TrainState`` the launch loop saves (``launch/train.py``) — there
    the optimizer-state template is rebuilt from ``cfg`` (+ ``train_cfg``
    when the run used a non-default optimizer) and the trained ``params``
    sub-tree is returned. Returns ``(params, restored_step)`` — the step
    actually read, resolved once (a concurrent training run may commit a
    newer checkpoint at any moment).
    """
    from repro.train import checkpoint as ckpt
    want = step if step is not None else ckpt.latest_step(checkpoint)
    if want is None:
        raise FileNotFoundError(f"no committed checkpoint under "
                                f"{checkpoint}")
    n_saved = ckpt.read_manifest(checkpoint, want)["n_leaves"]
    n_params = len(jax.tree_util.tree_leaves(template))
    if n_saved == n_params:
        return ckpt.restore(checkpoint, template, step=want), want
    from repro.configs.base import TrainConfig
    from repro.train import train_loop as TL
    state = TL.init_state(jax.random.PRNGKey(0), cfg,
                          train_cfg or TrainConfig())
    state = dataclasses.replace(state, params=template)
    n_state = len(jax.tree_util.tree_leaves(state))
    if n_saved != n_state:
        raise ValueError(
            f"checkpoint at {checkpoint} (step {want}) has {n_saved} "
            f"leaves; the model's parameter tree has {n_params} and a "
            f"default TrainState {n_state} — was it written for a "
            f"different config/optimizer? Pass the matching train_cfg.")
    return ckpt.restore(checkpoint, state, step=want).params, want


def calibrate_lm(params: Any, cfg, batches: Sequence[dict], *,
                 method: str = "mse",
                 obs_cfg: ObserverConfig = ObserverConfig(),
                 pct: float = 99.9,
                 fallback_amax: float = DEFAULT_ACT_AMAX,
                 per_channel: bool = False,
                 checkpoint: Optional[str] = None,
                 checkpoint_step: Optional[int] = None,
                 train_cfg: Optional[Any] = None
                 ) -> CalibrationArtifact:
    """Calibrate every projection of an LM config over a token corpus.

    ``checkpoint`` (a ``train.checkpoint`` root directory) restores
    TRAINED parameters into the structure of ``params`` before observing,
    so the recorded statistics — and the SQNR/logits gates downstream —
    track a trained activation distribution instead of random init
    (ROADMAP "trained-model calibration"). The artifact notes the
    restored step in its metadata.
    """
    meta: dict = {"model": cfg.name}
    if checkpoint is not None:
        params, restored = restore_lm_params(checkpoint, params, cfg,
                                             step=checkpoint_step,
                                             train_cfg=train_cfg)
        meta["checkpoint"] = checkpoint
        meta["checkpoint_step"] = restored
    fwd = _lm_forward(lm_ref_config(cfg))
    return calibrate(fwd, params, batches, cfg.mf.cim.x_bits,
                     method=method, obs_cfg=obs_cfg, pct=pct,
                     fallback_amax=fallback_amax, per_channel=per_channel,
                     meta=meta)


def evaluate_lm(params: Any, cfg, batches: Sequence[dict], *,
                artifact: Optional[CalibrationArtifact] = None,
                act_amax: float = DEFAULT_ACT_AMAX) -> AccuracyReport:
    """Accuracy of the programmed cim_sim forward vs the float reference.

    ``artifact=None`` evaluates the static full-scale baseline
    (``act_amax`` for every projection); with an artifact, its measured
    per-projection scales are programmed instead.
    """
    tagged, registry = attach_observer_ids(params)
    scales = artifact.scales if artifact is not None else None
    progd = program_weights(tagged, cfg.mf.cim, scales=scales,
                            act_amax=act_amax)
    ref_fwd = _lm_forward(lm_ref_config(cfg))
    cim_fwd = _lm_forward(cfg)
    return accuracy_report(lambda b: ref_fwd(params, b),
                           lambda b: cim_fwd(progd, b),
                           batches, registry)
