"""Corpus runner: record per-projection activation statistics via the tap.

The flow mirrors scale programming exactly (both ride
``core.programmed.map_projections``, so names line up by construction):

  1. :func:`attach_observer_ids` walks the parameter tree and embeds an
     int32 ``obs_id`` array in every MF projection dict (stacked layers
     and MoE experts get stacked id arrays — one id per layer *instance*,
     sliced by ``jax.lax.scan``/``vmap`` exactly like the weights).
  2. A :class:`StatsCollector` is installed with ``tap.observing`` and
     the ordinary model forward replays a corpus; ``apply_projection`` /
     ``conv_apply`` emit per-call :class:`~repro.calib.observers
     .ObserverState` summaries that reach the host through
     ``jax.experimental.io_callback`` (unordered — merging is
     order-invariant) and merge into per-id accumulators.
  3. :func:`scales_from_stats` lowers the accumulated states into the
     per-projection ``scales`` mapping ``program_weights`` consumes.

The same id plumbing powers the accuracy report: an :class:`ErrorCollector`
under ``tap.measuring_error`` accumulates per-projection signal/error
energy (SQNR) while a programmed CIM forward runs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback

from repro.calib import observers as obs
from repro.calib import tap
from repro.core.programmed import (_EXPERT_KEYS, DAC_GAIN_FLOOR,
                                   map_projections)


@dataclasses.dataclass(frozen=True)
class ObserverRegistry:
    """Name -> (id offset, stacked leading shape) for one tagged tree."""

    entries: dict[str, tuple[int, tuple[int, ...]]]
    n_ids: int


def attach_observer_ids(params: Any) -> tuple[Any, ObserverRegistry]:
    """Embed per-instance observer ids in every MF projection dict.

    Returns the tagged tree (safe to run through any forward — the extra
    int32 leaves ride scans like parameters and are ignored outside
    observe mode) and the registry mapping projection names to id blocks.
    Expert banks register ``<name>.up/gate/down`` — the same key scheme
    ``program_weights(scales=...)`` resolves.
    """
    entries: dict[str, tuple[int, tuple[int, ...]]] = {}
    next_id = 0

    def make_ids(name: str, shape: tuple[int, ...]) -> jax.Array:
        nonlocal next_id
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        ids = np.arange(n, dtype=np.int32).reshape(shape) + next_id
        entries[name] = (next_id, shape)
        next_id += n
        return jnp.asarray(ids)

    def attach(name, node, kind):
        out = dict(node)
        if kind == "experts":
            for key in _EXPERT_KEYS:
                out[f"obs_id_{key}"] = make_ids(f"{name}.{key}",
                                                node[key].shape[:-2])
        elif kind == "conv":
            out["obs_id"] = make_ids(name, ())
        else:
            out["obs_id"] = make_ids(name, node["w"].shape[:-2])
        return out

    tagged = map_projections(params, attach)
    return tagged, ObserverRegistry(entries, next_id)


def strip_observer_ids(params: Any) -> Any:
    """Inverse of :func:`attach_observer_ids`."""
    def walk(node):
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()
                    if not (isinstance(k, str) and k.startswith("obs_id"))}
        if isinstance(node, tuple):
            if hasattr(node, "_fields"):
                # NamedTuple pytree nodes (ProgrammedMacro, ...) are
                # leaves: a plain-tuple rebuild would change the treedef.
                return node
            return tuple(walk(v) for v in node)
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node
    return walk(params)


class StatsCollector:
    """Per-id activation-statistic accumulators (host side).

    ``emit_activation`` runs in traced code: it reduces the tensor to an
    :class:`ObserverState` summary on device and ships only that summary
    (a handful of floats + one histogram row) through ``io_callback``.
    """

    def __init__(self, n_ids: int,
                 obs_cfg: obs.ObserverConfig = obs.ObserverConfig()):
        self.obs_cfg = obs_cfg
        self.count = np.zeros((n_ids,), np.float64)
        self.amax = np.zeros((n_ids,), np.float64)
        self.hist = np.zeros((n_ids, obs_cfg.n_bins), np.float64)
        # Per-channel |x| maxima, keyed by id: projections contract over
        # different K, so these stay a ragged dict rather than one array.
        self.camax: dict[int, np.ndarray] = {}

    # -- traced side --------------------------------------------------------
    def emit_activation(self, obs_id, x) -> None:
        st = obs.summarize(x, self.obs_cfg)
        io_callback(self._accumulate, None,
                    jnp.asarray(obs_id, jnp.int32), st.count, st.amax,
                    st.hist, obs.channel_amax(x), ordered=False)

    # -- host side ----------------------------------------------------------
    def _accumulate(self, obs_id, count, amax, hist, camax) -> None:
        i = int(obs_id)
        self.count[i] += float(count)
        self.amax[i] = max(self.amax[i], float(amax))
        self.hist[i] += np.asarray(hist, np.float64)
        cm = np.asarray(camax, np.float64)
        prev = self.camax.get(i)
        self.camax[i] = cm.copy() if prev is None else np.maximum(prev, cm)

    def state(self, i: int) -> obs.ObserverState:
        """The merged state of instance ``i`` (numpy-backed)."""
        return obs.ObserverState(np.float32(self.count[i]),
                                 np.float32(self.amax[i]),
                                 self.hist[i].astype(np.float32))

    def channel_state(self, i: int) -> Optional[np.ndarray]:
        """Per-channel amax of instance ``i``, or None if it never fired
        (an expert no input routed to, a scan period the corpus skipped)."""
        return self.camax.get(i)


class ErrorCollector:
    """Per-id signal/error energy accumulators for the SQNR report."""

    def __init__(self, n_ids: int):
        self.ref_sq = np.zeros((n_ids,), np.float64)
        self.err_sq = np.zeros((n_ids,), np.float64)
        self.count = np.zeros((n_ids,), np.float64)

    def emit_error(self, obs_id, y, y_ref) -> None:
        yf = y.astype(jnp.float32)
        rf = y_ref.astype(jnp.float32)
        io_callback(self._accumulate, None,
                    jnp.asarray(obs_id, jnp.int32),
                    jnp.sum(rf * rf), jnp.sum((yf - rf) ** 2),
                    jnp.float32(rf.size), ordered=False)

    def _accumulate(self, obs_id, ref_sq, err_sq, count) -> None:
        i = int(obs_id)
        self.ref_sq[i] += float(ref_sq)
        self.err_sq[i] += float(err_sq)
        self.count[i] += float(count)

    def sqnr_db(self, cap_db: float = 120.0) -> np.ndarray:
        """Per-id SQNR in dB over the ids that saw any signal; bit-exact
        projections cap at ``cap_db`` (so means stay finite)."""
        seen = (self.count > 0) & (self.ref_sq > 0)
        ref, err = self.ref_sq[seen], self.err_sq[seen]
        floor = ref * 10.0 ** (-cap_db / 10.0)
        return 10.0 * np.log10(ref / np.maximum(err, floor))


def _split_batch(batch: Any, n: int) -> list[tuple[int, Any]]:
    """Split a batch pytree into up to ``n`` contiguous blocks along the
    leading axis (``np.array_split`` sizing). Returns ``(device_index,
    shard)`` pairs; zero-length blocks are skipped, so a batch smaller
    than the device list just uses fewer devices."""
    leaves = jax.tree.leaves(batch)
    if not leaves:
        return [(0, batch)]
    dim = int(leaves[0].shape[0])
    shards: list[tuple[int, Any]] = []
    start = 0
    for i in range(n):
        size = dim // n + (1 if i < dim % n else 0)
        if size == 0:
            continue
        sl = slice(start, start + size)
        shards.append((i, jax.tree.map(lambda x: x[sl], batch)))
        start += size
    return shards


def collect_stats(forward_fn: Callable[[Any, Any], Any], tagged_params: Any,
                  batches: Iterable[Any],
                  registry: ObserverRegistry,
                  obs_cfg: obs.ObserverConfig = obs.ObserverConfig(),
                  devices: Any = None
                  ) -> StatsCollector:
    """Replay ``batches`` through ``forward_fn(tagged_params, batch)`` in
    observe mode, returning the filled collector.

    The observe forward is jitted ONCE here, inside the observing
    context, so large-corpus calibration traces a single program per
    batch shape instead of paying eager per-batch (re)tracing of every
    inner scan — the observation io_callbacks are staged into the traced
    program and fire per execution. The jit is created fresh per
    ``collect_stats`` call because the tap gate and the collector are
    captured at TRACE time: ``forward_fn`` itself must not be a jit
    cached OUTSIDE this call (a trace cached before — or across —
    calibration runs would record into the wrong collector, or into
    none). An all-empty collection raises instead of silently producing
    fallback scales.

    ``devices``: an optional list of jax devices to shard the observe
    forward over. Each batch is split along its leading axis into one
    contiguous block per device; the tagged params are replicated once
    and the per-shard forwards are dispatched asynchronously (devices
    run concurrently, blocked per batch). The observation callbacks are
    UNORDERED and the accumulators are order-invariant (sum / max /
    histogram add), so the sharded collection merges to exactly the
    single-device result. Shards shorter than the device list skip the
    surplus devices.
    """
    collector = StatsCollector(registry.n_ids, obs_cfg)
    with tap.observing(collector):
        # Fresh jit per collector: traces (and stages the callbacks) on
        # the first batch of each shape, replays compiled thereafter.
        # repro-lint: disable=R003 reason=one trace per collector tap, reused per batch
        jitted = jax.jit(lambda p, b: forward_fn(p, b))
        if devices is None:
            for batch in batches:
                out = jitted(tagged_params, batch)
                jax.block_until_ready(out)
        else:
            devices = list(devices)
            if not devices:
                raise ValueError("devices must be a non-empty list "
                                 "(or None for the default device)")
            rep_params = [jax.device_put(tagged_params, d)
                          for d in devices]
            for batch in batches:
                outs = [jitted(rep_params[di],
                               jax.device_put(shard, devices[di]))
                        for di, shard in _split_batch(batch, len(devices))]
                for out in outs:
                    jax.block_until_ready(out)
    jax.effects_barrier()
    if registry.n_ids and not np.any(collector.count > 0):
        raise RuntimeError(
            "observe pass recorded no statistics for any of the "
            f"{registry.n_ids} registered projections — the forward was "
            "likely traced (jitted) outside tap.observing, so the "
            "observation callbacks were never staged; pass an un-cached "
            "forward (see collect_stats docstring)")
    return collector


def scales_from_stats(collector: StatsCollector, registry: ObserverRegistry,
                      x_bits: int, method: str, *, pct: float = 99.9,
                      fallback_amax: float = 4.0, per_channel: bool = False,
                      channel_floor: float = DAC_GAIN_FLOOR
                      ) -> dict[str, np.ndarray]:
    """Lower accumulated stats into the ``program_weights`` scales map:
    one float32 array per projection name, shaped like its stacked
    leading axes (scan periods, experts).

    ``per_channel=True`` appends a trailing per-feature axis: each
    instance's method-selected scalar scale is shaped over its recorded
    per-channel amax profile (:func:`~repro.calib.observers
    .shape_scale_channels`, attenuation-only, floored at
    ``channel_floor``), producing ``(lead..., K)`` vectors that
    ``program_weights`` realises as input-DAC gain trims. Instances that
    never fired (unrouted experts, skipped scan periods) fall back to a
    uniform vector at the scalar fallback scale; a projection with NO
    fired instance stays scalar-shaped (nothing to profile)."""
    scales: dict[str, np.ndarray] = {}
    for name, (off, shape) in registry.entries.items():
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        flat = [obs.select_scale(collector.state(off + j), x_bits, method,
                                 cfg=collector.obs_cfg, pct=pct,
                                 fallback_amax=fallback_amax)
                for j in range(n)]
        if not per_channel:
            scales[name] = np.asarray(flat, np.float32).reshape(shape)
            continue
        profiles = [collector.channel_state(off + j) for j in range(n)]
        k = next((p.shape[0] for p in profiles if p is not None), None)
        if k is None:
            scales[name] = np.asarray(flat, np.float32).reshape(shape)
            continue
        vecs = [obs.shape_scale_channels(
                    s, p if p is not None else np.zeros((k,)),
                    floor=channel_floor)
                for s, p in zip(flat, profiles)]
        scales[name] = np.stack(vecs).reshape(shape + (k,))
    return scales
