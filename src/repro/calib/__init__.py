"""Calibration lab: corpus-driven activation statistics, per-projection
scale programming, and the CIM accuracy/error report.

The paper's co-design only pays off when quantisation ranges match the
data: this package records per-projection |x| statistics over a corpus
(``corpus``/``observers``), lowers them into the static activation scales
the programmed runtime quantises against (``artifact`` +
``core.programmed.program_weights(scales=...)``), and measures what that
buys — per-projection SQNR and end-to-end logits error of the CIM
simulator against the float MF reference (``report``).

Only the light, cycle-free modules load eagerly (``tap`` is imported by
``core.mf`` at module load); ``corpus``/``report`` pull in the model zoo
and resolve lazily.
"""

from repro.calib import tap
from repro.calib.artifact import CalibrationArtifact
from repro.calib.observers import (SCALE_METHODS, ObserverConfig,
                                   ObserverState, observer_init,
                                   observer_merge, observer_update,
                                   select_scale)

__all__ = [
    "tap", "CalibrationArtifact", "SCALE_METHODS", "ObserverConfig",
    "ObserverState", "observer_init", "observer_merge", "observer_update",
    "select_scale",
    # lazy (see __getattr__):
    "attach_observer_ids", "collect_stats", "scales_from_stats",
    "StatsCollector", "ErrorCollector", "ObserverRegistry",
    "calibrate", "calibrate_lm", "evaluate_lm", "accuracy_report",
    "AccuracyReport", "lm_ref_config",
]

_LAZY = {
    "attach_observer_ids": "corpus", "collect_stats": "corpus",
    "scales_from_stats": "corpus", "StatsCollector": "corpus",
    "ErrorCollector": "corpus", "ObserverRegistry": "corpus",
    "calibrate": "report", "calibrate_lm": "report",
    "evaluate_lm": "report", "accuracy_report": "report",
    "AccuracyReport": "report", "lm_ref_config": "report",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f"repro.calib.{_LAZY[name]}")
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
