"""Per-projection activation-statistic observers (jit/vmap-compatible).

An :class:`ObserverState` is a small pytree of running statistics of the
absolute activations one projection has seen — element count, running
amax, and a fixed-grid histogram of |x|. States accumulate with
:func:`observer_update` inside jitted/vmapped code and combine with
:func:`observer_merge` across batches, shards, and hosts; every field is
an exact commutative monoid (float32 sums of integer counts, max), so
merging is order-invariant bit-for-bit and the empty state is a true
identity.

Scale *selection* happens after collection, on the host: three policies
lower a state into the static activation scale ``sx`` the programmed
runtime quantises against (see ``core/programmed.py``):

  * ``amax``       — classic max-abs: sx = amax / qmax (no clipping).
  * ``percentile`` — clip the |x| tail at the q-th percentile of the
                     histogram CDF (robust to outlier spikes).
  * ``mse``        — sweep candidate clip points and keep the one whose
                     quantise-clip reconstruction MSE over the histogram
                     is minimal (the OCS/TensorRT-style search; matches
                     the signal-range fitting of Kang et al. 1610.07501).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant


@dataclasses.dataclass(frozen=True)
class ObserverConfig:
    """Histogram geometry shared by every observer of one calibration run.

    ``range_max`` is the upper edge of the |x| grid; values beyond it land
    in the last (overflow) bin — amax is tracked exactly regardless, and
    the scale selectors treat the overflow bin pessimistically (its mass
    sits at the recorded amax).
    """

    n_bins: int = 256
    range_max: float = 16.0


class ObserverState(NamedTuple):
    """Mergeable running statistics of |x| for one projection instance."""

    count: jax.Array    # () float32 — number of elements observed
    amax: jax.Array     # () float32 — running max |x|
    hist: jax.Array     # (n_bins,) float32 — |x| counts on the fixed grid


def observer_init(cfg: ObserverConfig = ObserverConfig()) -> ObserverState:
    """The empty (identity) observer state."""
    return ObserverState(jnp.zeros((), jnp.float32),
                         jnp.zeros((), jnp.float32),
                         jnp.zeros((cfg.n_bins,), jnp.float32))


def observer_update(state: ObserverState, x: jax.Array,
                    cfg: ObserverConfig = ObserverConfig()) -> ObserverState:
    """Fold one activation tensor into a state. jit/vmap-safe; a
    zero-element ``x`` (empty batch) is a no-op."""
    v = jnp.abs(x.astype(jnp.float32)).reshape(-1)
    if v.shape[0] == 0:     # static shape — resolved at trace time
        return state
    amax = jnp.maximum(state.amax, jnp.max(v))
    idx = jnp.clip((v * (cfg.n_bins / cfg.range_max)).astype(jnp.int32),
                   0, cfg.n_bins - 1)
    hist = state.hist.at[idx].add(1.0)
    return ObserverState(state.count + v.shape[0], amax, hist)


def observer_merge(a: ObserverState, b: ObserverState) -> ObserverState:
    """Combine two states; commutative/associative, exact in float32 for
    any realistic count (integer-valued sums below 2^24 per bin)."""
    return ObserverState(a.count + b.count, jnp.maximum(a.amax, b.amax),
                         a.hist + b.hist)


def summarize(x: jax.Array,
              cfg: ObserverConfig = ObserverConfig()) -> ObserverState:
    """One-shot state of a single tensor (what the tap emits per call)."""
    return observer_update(observer_init(cfg), x, cfg)


def channel_amax(x: jax.Array) -> jax.Array:
    """Per-feature max |x| over every axis but the last — the (K,) vector
    the per-channel calibration path shapes DAC gain trims from. The last
    axis of the recorded tensor is the projection's contraction axis
    (linear: K = d_model; conv: the Cin-major im2col patch axis), so this
    merges across batch/sequence/spatial positions with the same exact
    max monoid as the scalar amax. A zero-row tensor (empty batch) yields
    zeros — the identity under max-merge."""
    v = jnp.abs(x.astype(jnp.float32)).reshape(-1, x.shape[-1])
    if v.shape[0] == 0:     # static shape — resolved at trace time
        return jnp.zeros((v.shape[1],), jnp.float32)
    return jnp.max(v, axis=0)


# ---------------------------------------------------------------------------
# Host-side scale selection (numpy; runs once per calibration, not jitted).
# ---------------------------------------------------------------------------

SCALE_METHODS = ("amax", "percentile", "mse")


def _bin_centers(cfg: ObserverConfig) -> np.ndarray:
    w = cfg.range_max / cfg.n_bins
    return (np.arange(cfg.n_bins) + 0.5) * w


def _effective_centers(state: ObserverState,
                       cfg: ObserverConfig) -> np.ndarray:
    """Bin centers with the overflow bin pinned at the true amax (mass
    beyond range_max clipped into the last bin must not be assumed small)."""
    c = _bin_centers(cfg)
    amax = float(state.amax)
    if amax > cfg.range_max:
        c = c.copy()
        c[-1] = amax
    return c


def scale_amax(state: ObserverState, x_bits: int, *,
               fallback_amax: float = 4.0) -> float:
    """Max-abs scale: the finest grid that never clips what was seen."""
    amax = float(state.amax)
    if float(state.count) == 0.0 or amax == 0.0:
        amax = fallback_amax
    return amax / quant.qmax(x_bits)


def scale_percentile(state: ObserverState, x_bits: int, *,
                     pct: float = 99.9,
                     cfg: ObserverConfig = ObserverConfig(),
                     fallback_amax: float = 4.0) -> float:
    """Clip at the pct-th percentile of |x| (histogram CDF upper edge)."""
    count = float(state.count)
    if count == 0.0 or float(state.amax) == 0.0:
        return fallback_amax / quant.qmax(x_bits)
    hist = np.asarray(state.hist, np.float64)
    cdf = np.cumsum(hist)
    target = pct / 100.0 * count
    b = int(np.searchsorted(cdf, target, side="left"))
    if b >= cfg.n_bins - 1:
        # The percentile falls in the overflow bin: only amax bounds it.
        amax_p = float(state.amax)
    else:
        amax_p = min((b + 1) * cfg.range_max / cfg.n_bins,
                     float(state.amax))
    return max(amax_p, 1e-8) / quant.qmax(x_bits)


def scale_mse(state: ObserverState, x_bits: int, *,
              cfg: ObserverConfig = ObserverConfig(),
              n_candidates: int = 64,
              fallback_amax: float = 4.0) -> float:
    """Minimum quantise-clip-MSE clip point over the histogram.

    For each candidate clip amax ``a`` the b-bit symmetric grid has scale
    ``s = a / qmax``; a bin at center c contributes
    ``hist * (c - clip(round(c/s), 0, qmax) * s)^2`` — in-range bins pay
    rounding error ~s^2/12, clipped bins pay (c - a)^2. The sweep trades
    tail clipping against grid resolution exactly like the hardware's
    fixed input DAC does.
    """
    count = float(state.count)
    amax = float(state.amax)
    if count == 0.0 or amax == 0.0:
        return fallback_amax / quant.qmax(x_bits)
    qm = quant.qmax(x_bits)
    hist = np.asarray(state.hist, np.float64)
    centers = _effective_centers(state, cfg)
    top = min(amax, cfg.range_max)
    cands = np.linspace(top / n_candidates, max(top, 1e-8), n_candidates)
    scales = cands / qm                                     # (C,)
    q = np.clip(np.round(centers[None, :] / scales[:, None]), 0, qm)
    err = (centers[None, :] - q * scales[:, None]) ** 2     # (C, B)
    mse = err @ hist
    return float(scales[int(np.argmin(mse))])


def shape_scale_channels(scale: float, camax: np.ndarray, *,
                         floor: float = 2.0 ** -8) -> np.ndarray:
    """Shape a method-selected scalar scale into a per-channel (K,) vector.

    The macro's input DAC keeps ONE full-scale reference, so per-channel
    calibration is attenuation-only: every channel's scale is the scalar
    policy scale times ``clip(camax_k / max(camax), floor, 1)`` — a quiet
    channel gets a proportionally finer grid, a loud channel keeps the
    full-range grid the scalar policy chose, and no channel's gain drops
    below ``floor`` (the hardware trim range,
    ``core.programmed.DAC_GAIN_FLOOR``). The histogram-driven clip policy
    (percentile / MSE) stays scalar — it sets the shared reference; the
    per-channel shaping only redistributes resolution below it. A
    silent-everywhere vector (all-zero camax) degenerates to the uniform
    scalar scale.
    """
    camax = np.asarray(camax, np.float64)
    top = float(camax.max()) if camax.size else 0.0
    if top <= 0.0:
        return np.full(camax.shape, scale, np.float32)
    g = np.clip(camax / top, floor, 1.0)
    return (scale * g).astype(np.float32)


def select_scale(state: ObserverState, x_bits: int, method: str, *,
                 cfg: ObserverConfig = ObserverConfig(),
                 pct: float = 99.9, fallback_amax: float = 4.0) -> float:
    """Dispatch on ``method`` in :data:`SCALE_METHODS`."""
    if method == "amax":
        return scale_amax(state, x_bits, fallback_amax=fallback_amax)
    if method == "percentile":
        return scale_percentile(state, x_bits, pct=pct, cfg=cfg,
                                fallback_amax=fallback_amax)
    if method == "mse":
        return scale_mse(state, x_bits, cfg=cfg,
                         fallback_amax=fallback_amax)
    raise ValueError(f"unknown scale method {method!r}; "
                     f"expected one of {SCALE_METHODS}")
