"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from `compiled.cost_analysis()` (whole-program,
already per-partition under SPMD). collective_bytes is parsed from the
compiled/optimised HLO text: we sum the RESULT sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (result size
== wire payload for all-reduce/permute; for all-gather it upper-bounds the
per-device payload by the gathered size — documented approximation).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-reduce.5 = f32[8,128]{1,0} all-reduce(...)
#       ROOT %tuple = (bf16[2,4]{1,0}, f32[]) all-to-all(...)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes per collective kind over the HLO module."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", line)
        if not m:
            continue
        rhs = m.group(1)
        for kind in _COLLECTIVES:
            # match the opcode (sync or async -start; -done carries no
            # payload of its own and would double count)
            if re.search(rf"\)?\s{kind}(?:-start)?\(", " " + rhs) or \
                    rhs.startswith(f"{kind}("):
                # result type is the prefix before the opcode
                type_part = rhs.split(kind)[0]
                out[kind] += _shape_bytes(type_part)
                break
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                  # per-device HLO flops
    hbm_bytes: float              # per-device bytes accessed
    coll_bytes: float             # per-device collective payload bytes
    chips: int
    coll_breakdown: Optional[dict] = None

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "coll_breakdown": self.coll_breakdown,
        }


def terms_from_compiled(compiled, chips: int) -> RooflineTerms:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:           # pragma: no cover - backend specific
        hlo = ""
    coll = collective_bytes(hlo)
    return RooflineTerms(flops=flops, hbm_bytes=hbm,
                         coll_bytes=float(sum(coll.values())), chips=chips,
                         coll_breakdown=coll)


# ---------------------------------------------------------------------------
# MODEL_FLOPS (useful-work reference): 6*N*D train, 2*N*D inference;
# MoE uses N_active.
# ---------------------------------------------------------------------------

def count_params(params_tree, active_expert_fraction: float = 1.0) -> dict:
    """Returns {'total': N, 'active': N_active} from an (abstract) tree.

    Expert leaves (path containing 'experts') count toward 'active' only
    at `active_expert_fraction` = (top_k + n_shared*E_share...) / E.
    """
    import jax

    total = 0
    active = 0.0
    for path, leaf in jax.tree_util.tree_leaves_with_path(params_tree):
        n = 1
        for d in leaf.shape:
            n *= d
        parts = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        total += n
        if "experts" in parts and "router" not in parts:
            active += n * active_expert_fraction
        else:
            active += n
    return {"total": int(total), "active": int(active)}


def model_flops(n_params_active: int, tokens: int, kind: str) -> float:
    """6*N*D for training (fwd+bwd), 2*N*D for inference forward."""
    factor = 6.0 if kind == "train" else 2.0
    return factor * n_params_active * tokens
