"""Keyed-deterministic arrival processes for the traffic lab.

Every draw runs through one counter-based ``numpy`` Philox generator
keyed by ``WorkloadConfig.seed``: the same config produces the same
trace bit for bit on any host — the arrival-determinism contract the
tests pin (same key ⇒ same trace), and what makes an offered-load sweep
comparable across schedulers (every point replays identical traffic).

Processes:

  * ``poisson`` — memoryless arrivals at ``rate_rps`` (exponential
    inter-arrival times), the open-loop baseline of serving papers;
  * ``mmpp`` — a 2-state Markov-modulated Poisson process: a slow state
    and a burst state at ``burst_rate_mult`` × the slow rate, sojourn
    times exponential with mean cycle ``1/switch_rate_hz``, normalised
    so the long-run mean rate is still ``rate_rps``. Burstiness is what
    separates continuous batching from naive admission — the queue-depth
    tail under MMPP is the figure to watch;
  * trace replay (:func:`replay_trace`) — explicit arrival timestamps
    (e.g. production logs) wrapped in the same request schema.

Each request carries its SLO budget as ABSOLUTE deadlines: first token
by ``t_arrival_s + ttft_slo_s``, full completion by that plus
``tpot_slo_s`` per requested token — the quantities the batcher's
admission control and deadline eviction act on.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass
class TrafficRequest:
    """One timestamped request flowing through the traffic lab.

    The generator fills identity/SLO fields; the scheduler
    (:class:`~repro.traffic.batching.ContinuousBatcher`) fills the
    ``t_*`` observation fields and drives ``state`` through
    ``pending -> queued -> running -> completed`` (or ``rejected`` /
    ``evicted``). ``serve`` is the engine-level
    :class:`~repro.serve.engine.Request` once admitted.
    """

    rid: int
    t_arrival_s: float
    prompt: list[int]
    max_new_tokens: int
    ttft_deadline_s: float      # absolute: first token due by this time
    deadline_s: float           # absolute: completion due by this time
    priority: int = 0           # lower = more urgent
    # -- scheduler-filled observations --------------------------------
    t_admit_s: Optional[float] = None
    t_first_token_s: Optional[float] = None
    t_done_s: Optional[float] = None
    state: str = "pending"
    serve: Optional[object] = None

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done_s is None:
            return None
        return self.t_done_s - self.t_arrival_s

    @property
    def ttft_s(self) -> Optional[float]:
        if self.t_first_token_s is None:
            return None
        return self.t_first_token_s - self.t_arrival_s

    @property
    def slo_met(self) -> bool:
        """Completed with both the TTFT and the completion deadline met
        — rejected/evicted/late requests all count as SLO misses."""
        return (self.state == "completed"
                and self.t_first_token_s is not None
                and self.t_first_token_s <= self.ttft_deadline_s
                and self.t_done_s is not None
                and self.t_done_s <= self.deadline_s)


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """One offered-load point's traffic recipe (fully keyed)."""

    rate_rps: float = 4.0        # long-run mean arrival rate
    n_requests: int = 64
    process: str = "poisson"     # "poisson" | "mmpp"
    # -- mmpp (2-state bursty) ----------------------------------------
    burst_rate_mult: float = 4.0   # burst-state rate / slow-state rate
    burst_fraction: float = 0.25   # long-run fraction of time in burst
    switch_rate_hz: float = 0.5    # 1 / mean(slow + burst sojourn)
    # -- per-request shape (uniform ints, inclusive bounds) -----------
    prompt_len_min: int = 4
    prompt_len_max: int = 16
    decode_len_min: int = 4
    decode_len_max: int = 16
    vocab_size: int = 128
    # -- SLO budgets --------------------------------------------------
    ttft_slo_s: float = 0.5      # first token within this of arrival
    tpot_slo_s: float = 0.1      # per-token budget after first token
    priority_levels: int = 1
    seed: int = 0

    def __post_init__(self):
        if self.rate_rps <= 0 or self.n_requests < 1:
            raise ValueError(
                f"degenerate workload (rate_rps={self.rate_rps}, "
                f"n_requests={self.n_requests})")
        if self.process not in ("poisson", "mmpp"):
            raise ValueError(
                f"unknown arrival process {self.process!r} — use "
                f"'poisson', 'mmpp', or replay_trace() for logs")
        if not 0.0 < self.burst_fraction < 1.0:
            raise ValueError(
                f"burst_fraction must be in (0, 1), got "
                f"{self.burst_fraction}")
        if self.prompt_len_min < 1 or self.decode_len_min < 1:
            raise ValueError("prompts and decode budgets need >= 1 token")


def _rng(seed: int) -> np.random.Generator:
    """Counter-based generator: keyed, platform-stable."""
    return np.random.Generator(np.random.Philox(key=seed))


def _poisson_arrivals(rng: np.random.Generator, n: int,
                      rate: float) -> np.ndarray:
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def _mmpp_arrivals(rng: np.random.Generator, n: int,
                   cfg: WorkloadConfig) -> np.ndarray:
    """2-state MMPP, arrival by arrival with exponential state sojourns.

    The slow rate is chosen so the stationary mean equals ``rate_rps``:
    mean = (1 - f) * r_slow + f * mult * r_slow.
    """
    f = cfg.burst_fraction
    r_slow = cfg.rate_rps / ((1.0 - f) + f * cfg.burst_rate_mult)
    rates = (r_slow, r_slow * cfg.burst_rate_mult)
    # Sojourn means per state sum to one mean cycle (1 / switch_rate).
    sojourn = ((1.0 - f) / cfg.switch_rate_hz, f / cfg.switch_rate_hz)
    out = np.empty(n)
    t = 0.0
    state = 0
    t_switch = rng.exponential(sojourn[state])
    i = 0
    while i < n:
        dt = rng.exponential(1.0 / rates[state])
        if t + dt >= t_switch:
            # The candidate arrival straddles a state change: advance to
            # the switch and redraw at the new rate (memorylessness makes
            # the discard exact, the classic thinning-free simulation).
            t = t_switch
            state = 1 - state
            t_switch = t + rng.exponential(sojourn[state])
            continue
        t += dt
        out[i] = t
        i += 1
    return out


def generate(cfg: WorkloadConfig) -> list[TrafficRequest]:
    """Materialise one keyed workload trace (same cfg ⇒ same trace)."""
    rng = _rng(cfg.seed)
    if cfg.process == "poisson":
        arrivals = _poisson_arrivals(rng, cfg.n_requests, cfg.rate_rps)
    else:
        arrivals = _mmpp_arrivals(rng, cfg.n_requests, cfg)
    prompt_lens = rng.integers(cfg.prompt_len_min, cfg.prompt_len_max + 1,
                               size=cfg.n_requests)
    decode_lens = rng.integers(cfg.decode_len_min, cfg.decode_len_max + 1,
                               size=cfg.n_requests)
    priorities = (rng.integers(0, cfg.priority_levels,
                               size=cfg.n_requests)
                  if cfg.priority_levels > 1
                  else np.zeros(cfg.n_requests, np.int64))
    reqs = []
    for i in range(cfg.n_requests):
        # Token 0 is reserved for padding in the batched prefill slabs.
        prompt = rng.integers(1, cfg.vocab_size,
                              size=int(prompt_lens[i])).tolist()
        t_arr = float(arrivals[i])
        n_new = int(decode_lens[i])
        reqs.append(TrafficRequest(
            rid=i, t_arrival_s=t_arr, prompt=prompt, max_new_tokens=n_new,
            ttft_deadline_s=t_arr + cfg.ttft_slo_s,
            deadline_s=t_arr + cfg.ttft_slo_s + cfg.tpot_slo_s * n_new,
            priority=int(priorities[i])))
    return reqs


def replay_trace(arrivals_s: Sequence[float],
                 prompts: Sequence[Sequence[int]],
                 max_new_tokens: Sequence[int],
                 *, ttft_slo_s: float = 0.5, tpot_slo_s: float = 0.1,
                 priorities: Optional[Sequence[int]] = None
                 ) -> list[TrafficRequest]:
    """Wrap an explicit arrival log in the traffic-lab request schema
    (the trace-replay process: timestamps come from outside, SLO budgets
    are applied uniformly)."""
    if not (len(arrivals_s) == len(prompts) == len(max_new_tokens)):
        raise ValueError(
            f"trace columns disagree: {len(arrivals_s)} arrivals, "
            f"{len(prompts)} prompts, {len(max_new_tokens)} budgets")
    if sorted(arrivals_s) != list(arrivals_s):
        raise ValueError("trace arrivals must be sorted ascending")
    reqs = []
    for i, (t, p, n) in enumerate(zip(arrivals_s, prompts,
                                      max_new_tokens)):
        reqs.append(TrafficRequest(
            rid=i, t_arrival_s=float(t), prompt=list(p),
            max_new_tokens=int(n),
            ttft_deadline_s=float(t) + ttft_slo_s,
            deadline_s=float(t) + ttft_slo_s + tpot_slo_s * int(n),
            priority=int(priorities[i]) if priorities is not None else 0))
    return reqs
