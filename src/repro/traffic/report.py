"""TrafficReport: per-offered-load-point serving quality accounting.

Layered on the engine's :class:`~repro.serve.engine.ServeReport` (stream
counters, Eq. 4 reload/recalibration charges) with the quantities only a
clocked scheduler can observe: latency percentiles, time-to-first-token,
SLO attainment, queue depth, slot occupancy — plus the per-wave Eq. 4
roll-up (:func:`repro.compiler.cost.serve_wave_cost`) pricing the
window's energy per generated token when the engine carries a fleet
schedule.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.traffic.batching import TrafficRunLog


def percentile(xs: Sequence[float], q: float) -> float:
    """Deterministic linear-interpolation percentile (q in [0, 100]) —
    no numpy dtype surprises in JSON-bound report fields.

    Method (pinned by ``tests/test_traffic.py``): Hyndman–Fan type 7,
    the numpy/Excel default. Sort the n samples, place q at fractional
    rank ``pos = (n - 1) * q / 100`` and linearly interpolate between
    the two neighbouring order statistics; ``pos`` past the last index
    clamps to the maximum. Consequences worth knowing when reading
    small-sample tails: p99/p999 of fewer than ~100/~1000 samples sit
    between the two largest samples (n >= 2) or AT the maximum — they
    never extrapolate beyond observed data, and adding one large sample
    moves them deterministically. Empty input returns NaN rather than
    raising: a load point where nothing completed still reports."""
    if not xs:
        return float("nan")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q={q} outside [0, 100]")
    s = sorted(float(x) for x in xs)
    if len(s) == 1:
        return s[0]
    pos = (len(s) - 1) * q / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(s) - 1)
    frac = pos - lo
    return s[lo] * (1.0 - frac) + s[hi] * frac


@dataclasses.dataclass(frozen=True)
class TrafficReport:
    """One offered-load point's serving quality + cost roll-up."""

    # -- offered load --------------------------------------------------
    offered_rps: float            # requests/s offered (measured on trace)
    n_requests: int
    # -- outcomes ------------------------------------------------------
    completed: int
    rejected: int                 # shed at admission or past-TTFT in queue
    evicted: int                  # reclaimed in flight past deadline
    evicted_tokens: int           # generated tokens those evictions threw
    # away (single-sourced from the engine's eviction counter, so a
    # window report and the run report can never disagree)
    slo_attainment: float         # fraction of OFFERED requests slo_met
    # -- throughput ----------------------------------------------------
    tok_s: float                  # generated tokens / clock elapsed
    decode_tokens: int
    elapsed_s: float              # clock time of the run window
    wall_s: float                 # host wall time (≠ elapsed under sim)
    # -- latency (clock seconds; NaN when no request completed) --------
    ttft_p50_s: float
    ttft_p99_s: float
    latency_p50_s: float
    latency_p99_s: float
    latency_p999_s: float
    # -- pressure ------------------------------------------------------
    queue_depth_mean: float
    queue_depth_max: int
    slot_utilization: float       # mean occupied / engine slots
    out_of_ticks: bool
    # -- engine + Eq. 4 roll-ups ---------------------------------------
    serve: object                 # ServeReport of the window
    wave: Optional[object] = None  # WaveCost when a fleet schedule exists

    @property
    def energy_per_token_j(self) -> float:
        return self.wave.energy_per_token_j if self.wave is not None \
            else 0.0

    def to_json(self) -> dict:
        """Flat JSON-safe payload (benchmarks/CI artifacts)."""
        out = {f.name: getattr(self, f.name)
               for f in dataclasses.fields(self)
               if f.name not in ("serve", "wave")}
        sr = self.serve
        out["serve"] = {
            "decode_steps": sr.decode_steps,
            "prefill_calls": sr.prefill_calls,
            "prefill_tokens": sr.prefill_tokens,
            "reprogram_events": sr.reprogram_events,
            "reload_energy_j": sr.reload_energy_j,
            "utilization": sr.utilization,
            "recalibrations": sr.recalibrations,
        }
        if self.wave is not None:
            out["wave"] = {
                "streams": self.wave.streams,
                "compute_energy_j": self.wave.compute_energy_j,
                "reload_energy_j": self.wave.reload.reload_energy_j,
                "energy_per_token_j": self.wave.energy_per_token_j,
                "latency_s": self.wave.latency_s,
            }
        return out


def from_run(log: TrafficRunLog, engine) -> TrafficReport:
    """Roll one batcher run up into a :class:`TrafficReport`."""
    reqs = log.requests
    n = len(reqs)
    completed = [r for r in reqs if r.state == "completed"]
    rejected = sum(r.state == "rejected" for r in reqs)
    evicted = sum(r.state == "evicted" for r in reqs)
    ttfts = [r.ttft_s for r in completed if r.ttft_s is not None]
    lats = [r.latency_s for r in completed]
    span = (max(r.t_arrival_s for r in reqs)
            - min(r.t_arrival_s for r in reqs)) if n > 1 else 0.0
    sr = log.serve_report
    wave = None
    if engine.schedule is not None:
        from repro.compiler.cost import serve_wave_cost
        wave = serve_wave_cost(engine.schedule, sr.decode_steps,
                               sr.prefill_calls, sr.decode_tokens)
    return TrafficReport(
        offered_rps=(n - 1) / span if span > 0 else float("inf"),
        n_requests=n,
        completed=len(completed), rejected=int(rejected),
        evicted=int(evicted), evicted_tokens=int(sr.evicted_tokens),
        slo_attainment=sum(r.slo_met for r in reqs) / n if n else 0.0,
        tok_s=sr.decode_tokens / log.elapsed_s if log.elapsed_s > 0
        else 0.0,
        decode_tokens=sr.decode_tokens,
        elapsed_s=log.elapsed_s, wall_s=log.wall_s,
        ttft_p50_s=percentile(ttfts, 50), ttft_p99_s=percentile(ttfts, 99),
        latency_p50_s=percentile(lats, 50),
        latency_p99_s=percentile(lats, 99),
        latency_p999_s=percentile(lats, 99.9),
        queue_depth_mean=(sum(log.queue_depth) / len(log.queue_depth)
                          if log.queue_depth else 0.0),
        queue_depth_max=max(log.queue_depth, default=0),
        slot_utilization=(sum(log.occupied)
                          / (len(log.occupied) * engine.slots)
                          if log.occupied else 0.0),
        out_of_ticks=log.out_of_ticks,
        serve=sr, wave=wave)
