"""Continuous batching with SLO-aware admission control.

:class:`ContinuousBatcher` replaces the engine's submit-everything
``run()`` loop with a scheduler that sees TIME: requests arrive on a
clock (virtual or wall), wait in a priority-FIFO queue, are wave-filled
into free cache slots as soon as slots open, and are shed or evicted
when their SLO can no longer be met.

Invariants (pinned by ``tests/test_traffic.py``):

  * no cache-slot overflow — in-flight requests never exceed the
    engine's ``slots``; oversized requests are *rejected*, never raised;
  * FIFO within priority — among equal-priority queued requests,
    admission follows arrival order;
  * deadline eviction frees slots — an in-flight request past its
    completion deadline is evicted via ``engine.evict`` and its slot is
    reusable in the same tick's admission wave.

Clocks: the :class:`VirtualClock` advances by a fixed measured per-tick
cost (one decode step = ``tick_s``, one batched-prefill wave =
``prefill_s``), making a whole offered-load sweep deterministic and
machine-independent; the :class:`WallClock` reads ``perf_counter`` for
live measurement. Both expose the same 4-method protocol.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.obs import trace as obs_trace
from repro.obs.metrics import LATENCY_EDGES_S
from repro.serve.engine import Request
from repro.traffic.workload import TrafficRequest


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Admission-control policy of one batcher."""

    max_queue: int = 256          # arrivals beyond this depth are shed
    drop_late: bool = True        # shed queued requests past TTFT SLO
    evict_past_deadline: bool = True  # reclaim slots from late streams


class VirtualClock:
    """Deterministic simulation clock: decode ticks and prefill waves
    cost a fixed, measured amount of virtual time."""

    def __init__(self, tick_s: float, prefill_s: Optional[float] = None):
        if tick_s <= 0:
            raise ValueError(f"tick_s must be > 0, got {tick_s}")
        self.tick_s = tick_s
        self.prefill_s = tick_s if prefill_s is None else prefill_s
        self.now = 0.0

    def on_decode(self) -> None:
        self.now += self.tick_s

    def on_prefill(self) -> None:
        self.now += self.prefill_s

    def fast_forward(self, t: float) -> None:
        """Jump an idle engine to the next arrival (never backwards)."""
        if t > self.now:
            self.now = t


class WallClock:
    """Live wall-clock: decode/prefill advance time by themselves."""

    def __init__(self):
        self._t0 = time.perf_counter()

    @property
    def now(self) -> float:
        return time.perf_counter() - self._t0

    def on_decode(self) -> None:
        pass

    def on_prefill(self) -> None:
        pass

    def fast_forward(self, t: float) -> None:
        delta = t - self.now
        if delta > 0:
            time.sleep(delta)


@dataclasses.dataclass
class TrafficRunLog:
    """Everything one batcher run observed (feeds ``report.from_run``)."""

    requests: list[TrafficRequest]
    ticks: int
    queue_depth: list[int]        # sampled once per decode tick
    occupied: list[int]           # occupied slots, sampled per tick
    elapsed_s: float              # clock time (virtual or wall)
    wall_s: float                 # host wall time regardless of clock
    serve_report: object          # ServeReport of the run window
    out_of_ticks: bool = False


class ContinuousBatcher:
    """SLO-aware continuous batching in front of one ``ServeEngine``."""

    def __init__(self, engine, clock=None,
                 admission: AdmissionConfig = AdmissionConfig()):
        self.engine = engine
        self.clock = clock if clock is not None else WallClock()
        self.admission = admission
        self._slot_map: dict[int, TrafficRequest] = {}
        self._by_serve: dict[int, TrafficRequest] = {}
        engine.admission_hooks.append(self._on_wave)
        # Scheduler-side telemetry rides the ENGINE's metrics registry —
        # one registry per serving process, one Prometheus exposition.
        # Latencies are in clock seconds (virtual or wall, whichever
        # clock drives this batcher).
        m = engine.metrics
        self._g_queue = m.gauge(
            "traffic_queue_depth", "queued requests NOW (level)")
        self._c_shed = m.counter(
            "traffic_shed_total", "requests rejected or shed")
        self._c_completed = m.counter(
            "traffic_completed_total", "requests served to completion")
        self._h_ttft = m.histogram(
            "traffic_ttft_s", LATENCY_EDGES_S,
            "time to first token, completed requests (clock seconds)")
        self._h_latency = m.histogram(
            "traffic_latency_s", LATENCY_EDGES_S,
            "arrival-to-done latency, completed requests (clock seconds)")

    # -- engine admission hook ------------------------------------------

    def _on_wave(self, wave: list[tuple[int, Request]]) -> None:
        for slot, sreq in wave:
            tr = self._by_serve.get(id(sreq))
            if tr is not None:
                self._slot_map[slot] = tr

    # -- queue policy ---------------------------------------------------

    def _reject(self, tr: TrafficRequest, now: float,
                reason: str = "inadmissible") -> None:
        tr.state = "rejected"
        tr.t_done_s = now
        self._c_shed.inc()
        obs_trace.emit("shed", rid=tr.rid, engine=self.engine.trace_tag,
                       reason=reason)

    def _admissible(self, tr: TrafficRequest) -> bool:
        """Cache-fit check — rejection, not an exception: under open-loop
        traffic a malformed request must not take the scheduler down."""
        return (len(tr.prompt) >= 1
                and len(tr.prompt) + tr.max_new_tokens
                <= self.engine.max_len)

    # -- main loop ------------------------------------------------------

    def run(self, requests: list[TrafficRequest],
            max_ticks: int = 100_000) -> TrafficRunLog:
        """Serve one workload trace to completion (or ``max_ticks``)."""
        eng, clock, adm = self.engine, self.clock, self.admission
        arrivals = sorted(requests,
                          key=lambda r: (r.t_arrival_s, r.rid))
        queue: list[TrafficRequest] = []
        queue_depth: list[int] = []
        occupied: list[int] = []
        i = 0
        ticks = 0
        wall0 = time.perf_counter()
        t_start = clock.now
        counters0 = eng.counters()

        while i < len(arrivals) or queue or self._slot_map:
            if ticks >= max_ticks:
                break
            now = clock.now
            # 1) pull arrivals whose timestamp has passed
            while i < len(arrivals) and \
                    arrivals[i].t_arrival_s <= now:
                tr = arrivals[i]
                i += 1
                if len(queue) >= adm.max_queue or not self._admissible(tr):
                    self._reject(tr, now,
                                 "queue_full"
                                 if len(queue) >= adm.max_queue
                                 else "inadmissible")
                    continue
                tr.state = "queued"
                queue.append(tr)
            # 2) idle engine, empty queue: jump to the next arrival
            #    instead of burning empty decode ticks
            if not queue and not self._slot_map and i < len(arrivals):
                clock.fast_forward(arrivals[i].t_arrival_s)
                continue
            # 3) shed queued requests that already missed their TTFT SLO
            if adm.drop_late:
                late = [t for t in queue if now > t.ttft_deadline_s]
                for tr in late:
                    queue.remove(tr)
                    self._reject(tr, now, "ttft_slo")
            # 4) evict in-flight requests past their completion deadline
            if adm.evict_past_deadline:
                for slot, tr in list(self._slot_map.items()):
                    if now > tr.deadline_s and not tr.serve.done:
                        # engine.evict emits the slot-side "evict" event
                        # (freed tokens); this one joins it to the rid.
                        eng.evict(slot)
                        obs_trace.emit("evict_sched", rid=tr.rid,
                                       slot=slot,
                                       engine=eng.trace_tag,
                                       reason="deadline")
                        del self._slot_map[slot]
                        tr.state = "evicted"
                        tr.t_done_s = now
            # 5) wave-fill free slots: priority first, FIFO within
            if queue and eng.free_slots:
                queue.sort(key=lambda t: (t.priority, t.t_arrival_s,
                                          t.rid))
                n = min(len(queue), len(eng.free_slots))
                wave, queue = queue[:n], queue[n:]
                sreqs = []
                for tr in wave:
                    tr.serve = Request(prompt=tr.prompt,
                                       max_new_tokens=tr.max_new_tokens)
                    self._by_serve[id(tr.serve)] = tr
                    tr.state = "running"
                    tr.t_admit_s = now
                    sreqs.append(tr.serve)
                admitted = eng.submit_many(sreqs)
                assert admitted == len(sreqs), \
                    "wave sized to free_slots must admit fully"
                if eng.batched_prefill and \
                        any(len(r.prompt) > 1 for r in sreqs):
                    clock.on_prefill()
            # 6) one decode tick for every occupied slot
            occupied.append(len(self._slot_map))
            queue_depth.append(len(queue))
            self._g_queue.set(len(queue))
            eng.step()
            clock.on_decode()
            ticks += 1
            now = clock.now
            # 7) observe first tokens and completions
            for slot, tr in list(self._slot_map.items()):
                if tr.t_first_token_s is None and tr.serve.out:
                    tr.t_first_token_s = now
                if tr.serve.done:
                    tr.state = "completed"
                    tr.t_done_s = now
                    self._c_completed.inc()
                    if tr.ttft_s is not None:
                        self._h_ttft.observe(tr.ttft_s)
                    self._h_latency.observe(tr.latency_s)
                    del self._slot_map[slot]

        # drain bookkeeping for anything still alive at the tick budget
        out_of_ticks = bool(queue or self._slot_map
                            or i < len(arrivals))
        now = clock.now
        for slot, tr in list(self._slot_map.items()):
            eng.evict(slot)
            obs_trace.emit("evict_sched", rid=tr.rid, slot=slot,
                           engine=eng.trace_tag, reason="out_of_ticks")
            tr.state = "evicted"
            tr.t_done_s = now
        self._slot_map.clear()
        for tr in queue:
            self._reject(tr, now, "out_of_ticks")
        for tr in arrivals[i:]:
            self._reject(tr, now, "out_of_ticks")
        self._by_serve.clear()
        elapsed = clock.now - t_start
        report = eng.report_since(counters0, elapsed)
        return TrafficRunLog(
            requests=list(requests), ticks=ticks,
            queue_depth=queue_depth, occupied=occupied,
            elapsed_s=elapsed, wall_s=time.perf_counter() - wall0,
            serve_report=report, out_of_ticks=out_of_ticks)
