"""Mesh sharding of a serving engine's state.

``shard_engine`` places a constructed :class:`~repro.serve.engine
.ServeEngine`'s working state on a jax device mesh built by
:func:`repro.launch.mesh.make_serve_mesh`:

  * the decode cache's slot (batch) dim shards over the ``data`` axis —
    device d of the data axis serves a contiguous block of cache slots,
    classic data parallelism over concurrent streams;
  * the programmed fleet state (bit-packed µArray planes, lossless
    bytes, digital residues) shards its output-channel dim over the
    ``fleet`` axis — macro placement across dies
    (:func:`repro.parallel.sharding.exec_param_pspecs`);
  * everything else (scales, silicon views, float params) replicates.

No re-jit is needed: the engine's existing ``step_fn``/``_prefill_fn``
retrace against the committed shardings and GSPMD partitions the step —
which is exactly why a SINGLE-device mesh is bitwise identical to the
unsharded path (same program, same device, shardings are no-ops). The
engine's exec-refresh hook keeps re-built trees (drift refresh,
recalibration) on the mesh.
"""

from __future__ import annotations

import jax

from repro.parallel.sharding import (exec_param_pspecs, serve_cache_pspecs,
                                     tree_shardings)


def _count_sharded(spec_tree) -> int:
    from jax.sharding import PartitionSpec as P
    n = [0]

    def visit(s):
        if isinstance(s, P) and any(ax is not None for ax in s):
            n[0] += 1

    jax.tree.map(visit, spec_tree, is_leaf=lambda x: isinstance(x, P))
    return n[0]


def shard_engine(engine, mesh) -> dict:
    """Place ``engine``'s cache and exec tree on ``mesh`` (in place).

    Returns a placement summary ``{"data": ..., "fleet": ...,
    "cache_sharded_leaves": ..., "param_sharded_leaves": ...}`` the
    traffic benchmark records. Raises when the engine's slot count does
    not divide the data axis (a ragged slot split would silently
    replicate the cache instead).
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data = axis_sizes.get("data", 1)
    if engine.slots % data:
        raise ValueError(
            f"engine slots ({engine.slots}) must divide the data axis "
            f"({data}) — ragged slot blocks cannot be placed")
    cache_specs = serve_cache_pspecs(engine.cfg, engine.cache, axis_sizes)
    param_specs = exec_param_pspecs(engine._exec_params, axis_sizes)
    cache_sh = tree_shardings(cache_specs, mesh)
    param_sh = tree_shardings(param_specs, mesh)
    engine.cache = jax.device_put(engine.cache, cache_sh)
    engine._exec_params = jax.device_put(engine._exec_params, param_sh)
    engine.mesh = mesh

    def _reput(eng):
        """Exec-refresh hook: a re-attached/re-programmed tree is born on
        the default device — put it back on the mesh. The tree STRUCTURE
        is invariant across refreshes (same programmed layout), so the
        shardings are reusable as-is."""
        eng._exec_params = jax.device_put(eng._exec_params, param_sh)

    engine.exec_refresh_hooks.append(_reput)
    return {
        "data": data, "fleet": axis_sizes.get("fleet", 1),
        "cache_sharded_leaves": _count_sharded(cache_specs),
        "param_sharded_leaves": _count_sharded(param_specs),
    }
