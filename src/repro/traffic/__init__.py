"""Traffic lab: arrival-process load generation, continuous batching with
SLO-aware admission control, and mesh-sharded fleet serving.

The serving stack below (``repro.serve.ServeEngine``) answers "how fast
does one engine decode a batch it was handed"; this package answers the
question the in-SRAM inference literature actually reports — throughput
per decision under *sustained, stochastic* load:

  * :mod:`~repro.traffic.workload` — keyed-deterministic arrival
    processes (Poisson, Markov-modulated bursty, trace replay) emitting
    timestamped requests with prompt/decode-length distributions and
    per-request SLO deadlines;
  * :mod:`~repro.traffic.batching` — a continuous-batching scheduler in
    front of the engine: admission control, wave-filling into free cache
    slots, prefill/decode interleaving, deadline-aware eviction;
  * :mod:`~repro.traffic.shard` — places the engine's decode batch and
    programmed fleet state on a jax device mesh (data-parallel slot
    axis, fleet axis for macro placement); a single-device mesh is
    bitwise identical to the unsharded path;
  * :mod:`~repro.traffic.report` — :class:`TrafficReport` layered on the
    engine's ``ServeReport``: p50/p99/p999 latency, TTFT, tok/s, SLO
    attainment, queue depth, utilization per offered-load point.
"""

from repro.traffic.batching import (AdmissionConfig, ContinuousBatcher,
                                    TrafficRunLog, VirtualClock, WallClock)
from repro.traffic.report import TrafficReport, percentile
from repro.traffic.shard import shard_engine
from repro.traffic.workload import (TrafficRequest, WorkloadConfig,
                                    generate, replay_trace)

__all__ = [
    "AdmissionConfig", "ContinuousBatcher", "TrafficReport",
    "TrafficRequest", "TrafficRunLog", "VirtualClock", "WallClock",
    "WorkloadConfig", "generate", "percentile", "replay_trace",
    "shard_engine",
]
