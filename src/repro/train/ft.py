"""Fault-tolerance runtime: preemption handling, step watchdog, straggler
detection, and the restart-safe training driver used by launch/train.py.

On a real cluster each host runs this driver; the watchdog timings come
from per-host step clocks (a straggling host shows up as a slow collective
for everyone, so the coordinator's clock suffices), and preemption arrives
as SIGTERM from the scheduler. All of it is exercised single-host here.
"""

from __future__ import annotations

import collections
import signal
import threading
import time
from typing import Callable, Optional


class PreemptionHandler:
    """Flips a flag on SIGTERM/SIGINT so the loop checkpoint-exits."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._installed = False
        self._signals = signals

    def install(self) -> "PreemptionHandler":
        for s in self._signals:
            try:
                signal.signal(s, lambda *_: self._flag.set())
                self._installed = True
            except ValueError:      # non-main thread (tests)
                pass
        return self

    def preempted(self) -> bool:
        return self._flag.is_set()

    def trigger(self) -> None:      # for tests / manual drills
        self._flag.set()


class StepWatchdog:
    """Tracks step durations; flags stalls and stragglers.

    A step slower than `straggler_factor` x rolling-median is logged as a
    straggler event (on TPU pods this is how slow hosts/links surface).
    `stalled()` (no step for `stall_timeout_s`) is the restart trigger for
    an external supervisor.
    """

    def __init__(self, window: int = 64, straggler_factor: float = 2.0,
                 stall_timeout_s: float = 600.0,
                 log: Optional[Callable[[str], None]] = None):
        self.durations = collections.deque(maxlen=window)
        self.straggler_factor = straggler_factor
        self.stall_timeout_s = stall_timeout_s
        self.straggler_events: list[tuple[int, float, float]] = []
        self._last_tick = time.monotonic()
        self._log = log or (lambda msg: None)

    def tick(self, step: int) -> None:
        now = time.monotonic()
        dur = now - self._last_tick
        self._last_tick = now
        if self.durations:
            med = sorted(self.durations)[len(self.durations) // 2]
            if dur > self.straggler_factor * med and len(self.durations) > 8:
                self.straggler_events.append((step, dur, med))
                self._log(f"[watchdog] straggler at step {step}: "
                          f"{dur:.3f}s vs median {med:.3f}s")
        self.durations.append(dur)

    def stalled(self) -> bool:
        return (time.monotonic() - self._last_tick) > self.stall_timeout_s

    @property
    def median_step_s(self) -> float:
        if not self.durations:
            return float("nan")
        return sorted(self.durations)[len(self.durations) // 2]


def run_with_restarts(make_loop: Callable[[int], int], max_restarts: int = 3,
                      log: Optional[Callable[[str], None]] = None) -> int:
    """Supervisor harness: call `make_loop(start_step)`, restart on crash.

    `make_loop` must be restart-safe: it restores from the latest
    checkpoint and returns the last completed step. Models the per-host
    supervisor of a 1000-node deployment (where the real restart comes
    from the cluster scheduler re-scheduling the job).
    """
    log = log or (lambda m: None)
    start = 0
    for attempt in range(max_restarts + 1):
        try:
            return make_loop(start)
        except Exception as e:                  # noqa: BLE001
            log(f"[ft] loop crashed (attempt {attempt}): {e!r}")
            if attempt == max_restarts:
                raise
            time.sleep(0.1)
    return start
