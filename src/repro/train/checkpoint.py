"""Fault-tolerant checkpointing: sharded, atomic, async, elastic.

Layout of a checkpoint directory::

    <root>/step_000001230/
        manifest.msgpack     # treedef, shapes, dtypes, step, metadata
        shard_00000.npz      # flat leaves (this host's addressable data)
    <root>/step_000001230.COMMITTED   # atomicity marker (rename-last)

Properties:
  * atomic — data written to `<dir>.tmp`, fsync'd, renamed; the COMMITTED
    marker file is written last, so readers never see torn checkpoints.
  * async — `CheckpointManager.save_async` snapshots params to host RAM
    (device_get) synchronously and writes on a background thread, so the
    train loop blocks only for the device->host copy.
  * elastic restore — `restore(..., shardings=...)` re-lays-out any saved
    checkpoint onto a new mesh/sharding (different chip count), enabling
    restart after losing nodes.
  * retention — keeps the newest `keep` checkpoints, deleting older ones
    only after the new COMMITTED marker exists.
"""

from __future__ import annotations

import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_names(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(root: str, step: int, tree: Any, metadata: Optional[dict] = None
         ) -> str:
    """Synchronous atomic save. Returns the committed directory path."""
    name = f"step_{step:012d}"
    final = os.path.join(root, name)
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = _flatten_with_names(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(host_leaves),
        "shapes": [list(x.shape) for x in host_leaves],
        "dtypes": [str(x.dtype) for x in host_leaves],
        "metadata": metadata or {},
        "time": time.time(),
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    np.savez(os.path.join(tmp, "shard_00000.npz"),
             **{f"leaf_{i}": x for i, x in enumerate(host_leaves)})
    # fsync directory contents then atomic rename + commit marker
    for fn in os.listdir(tmp):
        with open(os.path.join(tmp, fn), "rb") as f:
            os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(final + ".COMMITTED", "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    return final


def read_manifest(root: str, step: int) -> dict:
    """The manifest of a committed checkpoint (shapes/dtypes/leaf count).

    The single accessor for the on-disk layout — callers probing a
    checkpoint's structure (e.g. params-tree vs TrainState, see
    ``repro.calib.report.restore_lm_params``) go through here instead of
    hardcoding directory naming or the manifest schema.
    """
    final = os.path.join(root, f"step_{step:012d}")
    if not os.path.exists(final + ".COMMITTED"):
        raise FileNotFoundError(f"checkpoint {final} not committed")
    with open(os.path.join(final, "manifest.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read())


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for fn in os.listdir(root):
        if fn.endswith(".COMMITTED"):
            steps.append(int(fn[len("step_"):-len(".COMMITTED")]))
    return max(steps) if steps else None


def restore(root: str, target_tree: Any, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``target_tree``.

    ``shardings`` (a matching tree of NamedSharding / None) re-lays-out
    each leaf for the CURRENT mesh — elastic restart onto a different
    topology is just a different shardings tree.
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {root}")
    final = os.path.join(root, f"step_{step:012d}")
    if not os.path.exists(final + ".COMMITTED"):
        raise FileNotFoundError(f"checkpoint {final} not committed")
    with open(os.path.join(final, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(final, "shard_00000.npz"))
    leaves = [data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]

    t_leaves, treedef = jax.tree_util.tree_flatten(target_tree)
    assert len(t_leaves) == len(leaves), (
        f"checkpoint has {len(leaves)} leaves, target {len(t_leaves)}")
    s_leaves = (treedef.flatten_up_to(shardings) if shardings is not None
                else [None] * len(leaves))
    out = []
    for ref, val, shd in zip(t_leaves, leaves, s_leaves):
        arr = jnp.asarray(val, dtype=ref.dtype)
        if shd is not None:
            arr = jax.device_put(arr, shd)
        out.append(arr)
    return treedef.unflatten(out)


def gc_old(root: str, keep: int = 3) -> None:
    if not os.path.isdir(root):
        return
    steps = sorted(s for s in (
        int(fn[len("step_"):-len(".COMMITTED")])
        for fn in os.listdir(root) if fn.endswith(".COMMITTED")))
    for s in steps[:-keep]:
        name = os.path.join(root, f"step_{s:012d}")
        shutil.rmtree(name, ignore_errors=True)
        try:
            os.remove(name + ".COMMITTED")
        except OSError:
            pass


class CheckpointManager:
    """Async writer with retention. One in-flight save at a time."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None
        os.makedirs(root, exist_ok=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    def save_async(self, step: int, tree: Any,
                   metadata: Optional[dict] = None) -> None:
        self.wait()
        # Snapshot to host synchronously (cheap relative to the write).
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save(self.root, step, host_tree, metadata)
                gc_old(self.root, self.keep)
            except BaseException as e:          # noqa: BLE001
                self._last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_blocking(self, step: int, tree: Any,
                      metadata: Optional[dict] = None) -> str:
        self.wait()
        path = save(self.root, step, tree, metadata)
        gc_old(self.root, self.keep)
        return path
