"""Pure-JAX optimizers: AdamW, SGD-M, Adafactor; quantised (int8) moment
states for memory-bound giants (deepseek-v3 on 256 chips needs them); and
int8 error-feedback gradient compression.

API mirrors the (init, update) pair convention:

    opt = make_optimizer(tcfg)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = apply_updates(params, updates)
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class Optimizer(NamedTuple):
    init: Callable
    update: Callable          # (grads, state, params, step) -> (upd, state)


# ---------------------------------------------------------------------------
# int8 moment quantisation (per-tensor absmax blocks along the last axis)
# ---------------------------------------------------------------------------

_BLOCK = 256


def _q8(v: jax.Array) -> tuple[jax.Array, jax.Array]:
    flat = v.reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8_static(q, scale, shape) -> jax.Array:
    n = 1
    for s in shape:
        n *= s
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(shape)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def make_adamw(tcfg: TrainConfig) -> Optimizer:
    int8 = tcfg.opt_state_dtype == "int8"

    def init(params):
        def zero_like(p):
            if int8:
                q, s = _q8(jnp.zeros_like(p, jnp.float32))
                return {"q": q, "s": s}
            return jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(zero_like, params),
                "v": jax.tree.map(zero_like, params)}

    def update(grads, state, params, step):
        b1, b2, eps = tcfg.beta1, tcfg.beta2, tcfg.eps
        t = step.astype(jnp.float32) + 1.0
        lr = schedule(tcfg, step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            mf = _dq8_static(m["q"], m["s"], g.shape) if int8 else m
            vf = _dq8_static(v["q"], v["s"], g.shape) if int8 else v
            mf = b1 * mf + (1 - b1) * g
            vf = b2 * vf + (1 - b2) * g * g
            mh = mf / (1 - b1 ** t)
            vh = vf / (1 - b2 ** t)
            u = -lr * (mh / (jnp.sqrt(vh) + eps)
                       + tcfg.weight_decay * p.astype(jnp.float32))
            if int8:
                qm, sm = _q8(mf)
                qv, sv = _q8(vf)
                return u.astype(p.dtype), {"q": qm, "s": sm}, {"q": qv,
                                                               "s": sv}
            return u.astype(p.dtype), mf, vf

        flat_u, flat_m, flat_v = [], [], []
        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_m = treedef.flatten_up_to(state["m"])
        leaves_v = treedef.flatten_up_to(state["v"])
        leaves_p = treedef.flatten_up_to(params)
        for g, m, v, p in zip(leaves_g, leaves_m, leaves_v, leaves_p):
            u, nm, nv = upd(g, m, v, p)
            flat_u.append(u)
            flat_m.append(nm)
            flat_v.append(nv)
        return (treedef.unflatten(flat_u),
                {"m": treedef.unflatten(flat_m),
                 "v": treedef.unflatten(flat_v)})

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# SGD with momentum
# ---------------------------------------------------------------------------

def make_sgdm(tcfg: TrainConfig, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                  params)}

    def update(grads, state, params, step):
        lr = schedule(tcfg, step)

        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_m = treedef.flatten_up_to(state["m"])
        leaves_p = treedef.flatten_up_to(params)
        us, ms = [], []
        for g, m, p in zip(leaves_g, leaves_m, leaves_p):
            mf = momentum * m + g.astype(jnp.float32)
            us.append((-lr * (mf + tcfg.weight_decay
                              * p.astype(jnp.float32))).astype(p.dtype))
            ms.append(mf)
        return treedef.unflatten(us), {"m": treedef.unflatten(ms)}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments — O(n+m) state for (n,m) params)
# ---------------------------------------------------------------------------

def make_adafactor(tcfg: TrainConfig) -> Optimizer:
    eps = 1e-30

    def init(params):
        def st(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"f": jax.tree.map(st, params)}

    def update(grads, state, params, step):
        lr = schedule(tcfg, step)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** -0.8

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                     eps)
                u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                         / jnp.sqrt(jnp.maximum(
                             jnp.mean(vc, axis=-1)[..., None, None], eps))
                         + 1e-8)
                # clip update RMS to 1 (Adafactor stability)
                rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
                u = u / jnp.maximum(1.0, rms)
                return (-lr * (u + tcfg.weight_decay * p.astype(jnp.float32))
                        ).astype(p.dtype), {"vr": vr, "vc": vc}
            v = beta * s["v"] + (1 - beta) * g2
            u = g / (jnp.sqrt(v) + 1e-8)
            return (-lr * u).astype(p.dtype), {"v": v}

        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_s = treedef.flatten_up_to(state["f"])
        leaves_p = treedef.flatten_up_to(params)
        us, ss = [], []
        for g, s, p in zip(leaves_g, leaves_s, leaves_p):
            u, ns = upd(g, s, p)
            us.append(u)
            ss.append(ns)
        return treedef.unflatten(us), {"f": treedef.unflatten(ss)}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression (wire format for the DP
# all-reduce; the residual error re-enters next step's gradient)
# ---------------------------------------------------------------------------

def ef_compress_init(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def ef_compress(grads, err):
    """Returns (decompressed grads as transmitted, new error state)."""
    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_e = treedef.flatten_up_to(err)
    outs, errs = [], []
    for g, e in zip(leaves_g, leaves_e):
        gc = g.astype(jnp.float32) + e
        q, s = _q8(gc)
        deq = _dq8_static(q, s, gc.shape)
        outs.append(deq.astype(g.dtype))
        errs.append(gc - deq)
    return treedef.unflatten(outs), treedef.unflatten(errs)


# ---------------------------------------------------------------------------

def schedule(tcfg: TrainConfig, step) -> jax.Array:
    """Linear warmup + cosine decay."""
    s = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum((s + 1.0) / jnp.maximum(tcfg.warmup_steps, 1), 1.0)
    total = max(tcfg.total_steps, 1)
    frac = jnp.clip((s - tcfg.warmup_steps)
                    / max(total - tcfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return tcfg.lr * warm * (0.1 + 0.9 * cos)


def make_optimizer(tcfg: TrainConfig) -> Optimizer:
    if tcfg.optimizer == "adamw":
        return make_adamw(tcfg)
    if tcfg.optimizer == "sgdm":
        return make_sgdm(tcfg)
    if tcfg.optimizer == "adafactor":
        return make_adafactor(tcfg)
    raise ValueError(tcfg.optimizer)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                      + u.astype(jnp.float32)).astype(p.dtype),
                        params, updates)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(v.astype(jnp.float32)))
                        for v in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    factor = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda v: (v.astype(jnp.float32) * factor
                                   ).astype(v.dtype), tree), norm
