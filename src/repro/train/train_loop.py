"""Train-step builder: microbatched grad accumulation, global-norm clip,
optimizer update, optional error-feedback gradient compression.

`make_train_step(cfg, pcfg, tcfg)` returns a pure (state, batch) ->
(state, metrics) function suitable for jit/pjit; the dry-run lowers
exactly this function for the train_4k cells.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.models import encdec as encdec_mod
from repro.models import transformer as transformer_mod
from repro.train import optimizer as opt_mod


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array
    ef_error: Optional[Any] = None     # error-feedback buffer (compression)


def loss_fn_for(cfg: ModelConfig) -> Callable:
    if cfg.family == "encdec":
        return encdec_mod.encdec_loss
    return transformer_mod.lm_loss


def init_state(key: jax.Array, cfg: ModelConfig, tcfg: TrainConfig
               ) -> TrainState:
    if cfg.family == "encdec":
        params = encdec_mod.encdec_init(key, cfg)
    else:
        params = transformer_mod.lm_init(key, cfg)
    opt = opt_mod.make_optimizer(tcfg)
    ef = (opt_mod.ef_compress_init(params)
          if tcfg.grad_compression == "int8_ef" else None)
    return TrainState(params=params, opt_state=opt.init(params),
                      step=jnp.zeros((), jnp.int32), ef_error=ef)


def _split_microbatches(batch: dict, n: int) -> dict:
    return jax.tree.map(
        lambda v: v.reshape((n, v.shape[0] // n) + v.shape[1:]), batch)


def make_train_step(cfg: ModelConfig, pcfg: ParallelConfig,
                    tcfg: TrainConfig,
                    pctx: Optional[transformer_mod.ParallelContext] = None
                    ) -> Callable:
    opt = opt_mod.make_optimizer(tcfg)
    loss_fn = loss_fn_for(cfg)
    pctx = pctx or transformer_mod.ParallelContext(cfg=pcfg)

    def loss(params, mb):
        total, metrics = loss_fn(params, mb, cfg, pctx)
        return total, metrics

    def train_step(state: TrainState, batch: dict
                   ) -> tuple[TrainState, dict]:
        nmb = pcfg.microbatches
        if nmb > 1:
            # Grad accumulation over microbatches: the scan pipelines
            # backward compute of microbatch i with (XLA-scheduled)
            # gradient reduction of i-1 — compute/comm overlap.
            mbs = _split_microbatches(batch, nmb)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (l, metrics), g = jax.value_and_grad(loss, has_aux=True)(
                    state.params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + metrics["loss"]), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            (grads, lsum), _ = jax.lax.scan(accum,
                                            (zeros, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / nmb, grads)
            mean_loss = lsum / nmb
            metrics = {"loss": mean_loss}
        else:
            (total, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(state.params, batch)
            mean_loss = metrics["loss"]

        ef_error = state.ef_error
        if ef_error is not None:
            grads, ef_error = opt_mod.ef_compress(grads, ef_error)

        grads, gnorm = opt_mod.clip_by_global_norm(grads, tcfg.grad_clip)
        updates, new_opt = opt.update(grads, state.opt_state, state.params,
                                      state.step)
        new_params = opt_mod.apply_updates(state.params, updates)
        new_state = TrainState(params=new_params, opt_state=new_opt,
                               step=state.step + 1, ef_error=ef_error)
        out_metrics = {"loss": mean_loss, "grad_norm": gnorm,
                       "lr": opt_mod.schedule(tcfg, state.step)}
        if "aux" in metrics:
            out_metrics["aux"] = metrics["aux"]
        return new_state, out_metrics

    return train_step


def make_eval_step(cfg: ModelConfig, pcfg: ParallelConfig,
                   pctx: Optional[transformer_mod.ParallelContext] = None
                   ) -> Callable:
    loss_fn = loss_fn_for(cfg)
    pctx = pctx or transformer_mod.ParallelContext(cfg=pcfg)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch, cfg, pctx)
        return metrics

    return eval_step
