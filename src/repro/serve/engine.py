"""Batched serving engine: slot-based continuous batching over the decode
step, greedy/temperature sampling, and prompt ingestion.

The engine owns a fixed-capacity KV cache (`slots` x `max_len`); requests
occupy slots, prompts are ingested through batched programmed prefill
(one (B, T) forward per admission wave) when the architecture supports it
— token-by-token through the jitted decode step otherwise — and finished
slots are recycled. `serve_step` — the function the decode dry-run cells
lower — is a single fused (decode + sample) step over the whole batch.

Weight-stationary CIM serving: when the model config maps projections to
``cim_sim``, the engine programs the whole model ONCE at construction
(`core.programmed.program_weights`) and every jitted decode step serves
from the frozen macro state — the per-step weight recalibrate/requantise/
bitplane/pack work of the on-the-fly path disappears from the hot loop,
mirroring how the hardware writes the µArray once and streams inputs.

Fleet-faithful serving: constructed with a ``Fleet``, the engine compiles
the model's projections onto it (`repro.compiler.schedule.compile_model`).
A model whose µArray tiles all fit the fleet's ``tile_slots`` is *pinned*
— weights stay resident, reloads amortise to zero. A model that does NOT
fit decodes through round-interleaved execution: every projection becomes
a :class:`~repro.core.programmed.SwappedMacro` whose step re-programs
tile rounds per input stream (program round r, stream the step-time
inputs through the resident tiles, swap in round r+1) — bit-exact against
the pinned path, with every reprogram event charged against the Eq. 4
roll-up (`repro.compiler.cost.serve_reload_cost`) in the
:class:`ServeReport` each ``run()`` produces.

Silicon-aware serving (``repro.silicon`` + ``repro.macros``):
constructed with a ``SiliconConfig``, a macro model, or a registered
macro name (``silicon="collaborative"``), the engine samples the
flavour's silicon instances over the fleet's tile slots (cap-DAC
mismatch — per slot or shared per group, comparator offset +
tail-current correction, conversion noise, drift directions) and every
stream decodes through the per-tile silicon datapath. A ``DriftPolicy``
adds the aging loop: the fleet ages one unit per input stream, drifted
views are refreshed on cadence, and a probe corpus is replayed against
the float MF reference on cadence — past the alarm thresholds the
engine re-runs the macro's tiered comparator re-trim (fine DAC, coarse
tier once drift saturates the ±3σ range, retirement screening beyond
that), re-measures per-projection activation scales on the healed
datapath, re-programs every macro, and charges the rewrite in the
``ServeReport`` next to the per-stream reload costs.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.obs import trace as obs_trace
from repro.obs.metrics import MetricsRegistry

# Monotonic engine tags for trace events (``TraceEvent.engine``): small,
# stable, and human-readable where ``id()`` is neither.
_ENGINE_TAGS = itertools.count(1)


def make_serve_step(cfg: ModelConfig, pctx=None, temperature: float = 0.0,
                    trace_tag: Optional[int] = None) -> Callable:
    """(params, cache, tokens, rng, step) -> (next_tokens, logits, cache).

    ``step`` is the engine's input-stream counter (decode steps + prefill
    calls), threaded through :func:`repro.core.cim.conversion_clock` so
    per-conversion thermal dither decorrelates across stream steps. It is
    unused (and free) when the exec tree carries no thermal silicon.

    ``trace_tag`` (an engine's trace id) stages a ``decode_tick`` trace
    emission into the compiled program — an unordered ``io_callback``
    that routes through :mod:`repro.obs.trace`'s module-global bus at
    FIRE time, so buses come and go without retracing, and a program
    built with ``trace_tag=None`` is exactly today's program (the
    bitwise-parity gate of ``benchmarks/obs_report.py``). Traced
    programs take one extra operand, ``active`` (occupied slots this
    tick), which rides the event payload.
    """
    pctx = pctx or T.ParallelContext()
    from repro.core import cim

    def serve_step(params, cache, tokens, rng, step=0, active=0):
        with cim.conversion_clock(step):
            logits, new_cache = T.lm_decode_step(params, cache, tokens,
                                                 cfg, pctx)
        if temperature > 0.0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = nxt.astype(jnp.int32)
        if trace_tag is not None:
            obs_trace.emit_decode_tick(step, nxt, active, engine=trace_tag)
        return nxt, logits, new_cache

    return serve_step


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # Set by ServeEngine.run when the tick budget ran out before the
    # request finished (or before it was ever scheduled): the request is
    # returned with whatever it produced instead of being dropped.
    timed_out: bool = False
    # Set by ServeEngine.evict (deadline-aware schedulers reclaiming the
    # slot): the request keeps its partial output but never finished.
    evicted: bool = False


@dataclasses.dataclass(frozen=True)
class ServeReport:
    """Per-``run()`` serving accounting (also at ``engine.last_report``).

    ``streams`` counts the input streams the fleet served: decode steps
    plus batched-prefill calls — each one replays the full weight reload
    of a non-pinned schedule, which is what the Eq. 4 reload fields
    charge (``repro.compiler.cost.serve_reload_cost``). Pinned models
    (and engines built without a fleet) report zero reload cost.

    The ``drift_*`` / ``recal_*`` fields account the silicon lab's
    auto-recalibration (``repro.silicon.drift``): every recalibration
    rewrites the whole model's µArray weights (the scales changed), so
    its reload bits are charged next to the per-stream reload cost at the
    same fleet write energy / load-port bandwidth.
    """

    decode_tokens: int          # tokens generated this run
    decode_steps: int           # engine ticks this run
    prefill_tokens: int         # prompt tokens ingested via batched prefill
    prefill_calls: int          # batched-prefill invocations (waves)
    elapsed_s: float
    tok_s: float                # generated tokens / elapsed
    pinned: Optional[bool]      # None = no fleet attached
    rounds_max: int             # deepest weight-swap round of any layer
    reprogram_events: int       # schedule events x streams
    reload_bits: int
    reload_energy_j: float
    reload_s: float
    utilization: float          # fleet compute-slot occupancy (schedule)
    drift_checks: int = 0       # drift probes run this run
    drift_alarms: int = 0       # probes that raised the drift alarm
    recalibrations: int = 0     # auto-recalibration events this run
    recal_reload_bits: int = 0  # µArray weight bits rewritten by recals
    recal_energy_j: float = 0.0
    recal_s: float = 0.0
    # Slots whose drifted offset exceeded even the coarse re-trim DAC
    # range at the LAST recalibration (a fleet-health level, not a
    # per-window delta): screened for retirement — their residue can no
    # longer be trimmed and only grows with further drift.
    retired_slots: int = 0
    # Generated tokens discarded by slot evictions this window (deadline
    # shedding): work the fleet paid for that no caller received.
    evicted_tokens: int = 0

    @property
    def streams(self) -> int:
        return self.decode_steps + self.prefill_calls

    @property
    def reload_energy_nj(self) -> float:
        return self.reload_energy_j * 1e9

    @property
    def recal_energy_nj(self) -> float:
        return self.recal_energy_j * 1e9


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, slots: int, max_len: int,
                 temperature: float = 0.0, eos_id: Optional[int] = None,
                 seed: int = 0, program: bool = True, calibration=None,
                 fleet=None, batched_prefill: Optional[bool] = None,
                 silicon=None, silicon_key=None, drift=None,
                 tracing: bool = False, trace_tick_interval: int = 128):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        # Weight-stationary programming: freeze every CIM projection's
        # macro state now so the jitted step does input-side work only.
        # ``program=False`` keeps the legacy on-the-fly path (benchmarks).
        # ``calibration`` (a repro.calib CalibrationArtifact, or a path to
        # a saved one) programs its measured per-projection activation
        # scales instead of the static full-scale default.
        # ``fleet`` (a repro.compiler.tiling.Fleet) makes serving
        # fleet-faithful: models that exceed its resident tile slots are
        # served round-interleaved (see module docstring).
        # ``silicon`` (a repro.silicon SiliconConfig, a repro.macros
        # MacroModel, or a registered macro name like "collaborative")
        # samples the flavour's silicon instances over the fleet's tile
        # slots (keyed by ``silicon_key``, default PRNGKey(seed)) and
        # serves every decode/prefill stream through the per-tile
        # silicon datapath.
        # ``drift`` (a repro.silicon.drift DriftPolicy) probes the live
        # datapath against the calibration baseline every
        # ``check_interval`` streams and auto-recalibrates on alarm.
        # ``tracing=True`` compiles a SECOND decode program with the
        # in-jit ``decode_tick`` emission staged (see ``make_serve_step``
        # — the ONLY observability decision baked into a compiled
        # program; host-side events and metrics are always live and cost
        # one global read when no bus/reader is attached). Any host
        # callback in a jitted program costs the C++ fast-dispatch path
        # (milliseconds per call on CPU), so the traced program runs on a
        # SAMPLING CADENCE: every ``trace_tick_interval``-th decode tick
        # dispatches it (and emits), every other tick runs the pure
        # program. ``decode_tick`` events are therefore a sampled
        # timeline; the metrics counters stay tick-exact. Interval 1
        # traces every tick (tests; short diagnostic runs).
        self._exec_params = params
        self.tracing = bool(tracing)
        if trace_tick_interval < 1:
            raise ValueError(
                f"trace_tick_interval must be >= 1, "
                f"got {trace_tick_interval}")
        self.trace_tick_interval = int(trace_tick_interval)
        self.trace_tag = next(_ENGINE_TAGS)
        # The metrics registry every stream/health counter lives in:
        # ``ServeReport`` (and the traffic lab's ``TrafficReport``) are
        # views over this registry — counters are monotonic so windowed
        # reports difference snapshots and disjoint windows sum exactly;
        # the retrim-tier numbers are gauges (fleet-health LEVELS).
        self.metrics = MetricsRegistry()
        m = self.metrics
        self._c_decode_steps = m.counter(
            "serve_decode_steps_total", "engine decode ticks")
        self._c_decode_tokens = m.counter(
            "serve_decode_tokens_total", "tokens generated")
        self._c_prefill_calls = m.counter(
            "serve_prefill_calls_total", "batched-prefill waves")
        self._c_prefill_tokens = m.counter(
            "serve_prefill_tokens_total", "prompt tokens ingested")
        self._c_drift_checks = m.counter(
            "serve_drift_checks_total", "drift probes run")
        self._c_drift_alarms = m.counter(
            "serve_drift_alarms_total", "drift probes that alarmed")
        self._c_recals = m.counter(
            "serve_recalibrations_total", "auto-recalibration events")
        self._c_recal_bits = m.counter(
            "serve_recal_reload_bits_total",
            "uArray weight bits rewritten by recalibrations")
        self._c_evictions = m.counter(
            "serve_evictions_total", "slots reclaimed before completion")
        self._c_evicted_tokens = m.counter(
            "serve_evicted_tokens_total",
            "generated tokens discarded by evictions")
        self._g_coarse = m.gauge(
            "fleet_coarse_slots",
            "slots on the coarse trim tier NOW (level)")
        self._g_retired = m.gauge(
            "fleet_retired_slots",
            "slots screened for retirement NOW (level)")
        # Per-stream Eq. 4 reload payload for "reload" trace events
        # (None = pinned or fleet-less: nothing is reloaded per stream).
        self._stream_reload_payload = None
        self.programmed = False
        self.calibration = None
        self.fleet = fleet
        self.schedule = None
        self.silicon = None                 # sampled FleetSilicon
        self.silicon_cfg = None             # the macro model serving it
        self.macro = None                   # alias of silicon_cfg
        self.drift = drift
        self.drift_log = []                 # DriftStatus per probe
        self.last_drift_status = None
        self._monitor = None
        self._registry = None
        self._swap_map = None
        self._drifting = False
        programmable = (program and cfg.mf.enabled
                        and cfg.mf.mode == "cim_sim")
        if calibration is not None and not programmable:
            raise ValueError(
                "a calibration artifact was supplied but the engine is not "
                "programming CIM macros (program=False or the config does "
                "not map projections to cim_sim) — the scales would be "
                "silently dropped")
        if fleet is not None and not programmable:
            raise ValueError(
                "a fleet was supplied but the engine is not programming "
                "CIM macros (program=False or the config does not map "
                "projections to cim_sim) — the schedule would not "
                "describe the executed datapath")
        if silicon is not None:
            if not programmable or fleet is None:
                raise ValueError(
                    "silicon variation is per fleet tile slot: it needs a "
                    "programmed CIM engine built with a fleet (the slots "
                    "the sampled ADC instances live in)")
        if drift is not None and calibration is None:
            raise ValueError(
                "drift monitoring compares live probes against the "
                "programmed calibration artifact — construct the engine "
                "with calibration=")
        if programmable:
            scales = None
            if calibration is not None:
                from repro.calib.artifact import CalibrationArtifact
                if not isinstance(calibration, CalibrationArtifact):
                    calibration = CalibrationArtifact.load(calibration)
                if calibration.x_bits != cfg.mf.cim.x_bits:
                    raise ValueError(
                        f"calibration artifact is for x_bits="
                        f"{calibration.x_bits}, model runs x_bits="
                        f"{cfg.mf.cim.x_bits}")
                _check_calibration_names(params, calibration)
                scales = calibration.scales
                self.calibration = calibration
            self._swap_map = self._compile_fleet_schedule() \
                if fleet is not None else None
            self._base_params = params
            if drift is not None:
                # Observer ids ride the programmed tree so the live amax
                # tap (and recalibration observe passes) can address every
                # projection instance.
                from repro.calib.corpus import attach_observer_ids
                self._base_params, self._registry = \
                    attach_observer_ids(params)
            if silicon is not None:
                from repro.macros.registry import as_macro
                from repro.silicon.instance import fleet_silicon
                # Any macro-shaped spec: SiliconConfig (→ the SA-ADC
                # flavour, the pre-registry physics), a MacroModel, or
                # a registered name. Unknown names/types fail with the
                # registry's precise error.
                model = as_macro(silicon)
                self.silicon_cfg = model
                self.macro = model
                self.silicon = fleet_silicon(fleet, model, silicon_key)
                self._drifting = model.is_drifting
            self._program(scales)
            self.programmed = True
        self.cache = T.lm_init_cache(cfg, slots, max_len)
        # The pure program (exactly today's); compiled lazily on first
        # untraced tick, so an interval-1 tracing engine never pays for
        # it. The traced twin exists only when tracing is on.
        self.step_fn = jax.jit(make_serve_step(cfg,
                                               temperature=temperature))
        self._traced_step_fn = jax.jit(make_serve_step(
            cfg, temperature=temperature,
            trace_tag=self.trace_tag)) if self.tracing else None
        self.requests: list[Optional[Request]] = [None] * slots
        self._feed = np.zeros((slots,), np.int32)       # next token to feed
        self._prompt_left = np.zeros((slots,), np.int64)
        self._rng = jax.random.PRNGKey(seed)
        # Batched programmed prefill: one (slots, T) forward per admission
        # wave instead of one decode step per prompt token. Auto-enabled
        # when the architecture supports it (GQA attention caches);
        # ``batched_prefill=False`` forces prefill-as-decode.
        supported = T.prefill_supported(cfg)
        if batched_prefill and not supported:
            raise ValueError(
                f"{cfg.name}: batched prefill needs an all-GQA-attention "
                f"pattern with a full-length KV cache")
        self.batched_prefill = supported if batched_prefill is None \
            else bool(batched_prefill)
        from repro.core import cim as _cim

        def _prefill(p, c, tok, val, step=0):
            with _cim.conversion_clock(step):
                return T.lm_prefill_cache(p, c, tok, val, cfg)

        self._prefill_fn = jax.jit(_prefill)
        # Wave-admission observers: each hook receives the admitted
        # [(slot, request), ...] wave — schedulers (repro.traffic) track
        # slot occupancy through this instead of polling.
        self.admission_hooks: list[Callable] = []
        # Exec-tree refresh observers: called after _refresh_silicon
        # rebuilds self._exec_params (drift refresh, recalibration) —
        # mesh sharding (repro.traffic.shard) re-places the new tree.
        self.exec_refresh_hooks: list[Callable] = []
        self.last_report: Optional[ServeReport] = None
        # Runtime sanitizer (REPRO_SANITIZE=1): shadow-execute every
        # decode tick through the reference einsum datapath and assert
        # bitwise agreement (see repro.analysis.sanitize).
        self._sanitizer = None
        from repro.analysis.sanitize import sanitize_enabled
        if sanitize_enabled() and self.programmed:
            from repro.analysis.sanitize import ServeSanitizer
            self._sanitizer = ServeSanitizer(self, temperature=temperature)
        if drift is not None:
            from repro.silicon.drift import DriftMonitor
            self._monitor = DriftMonitor(cfg, params, drift, self._registry,
                                         scales or {}, cfg.mf.cim.x_bits)
            # Pin the pre-drift probe error: the recovery gate every
            # post-recalibration measurement is judged against.
            self._monitor.record_baseline(self._exec_params)

    def _emit(self, kind: str, **kw) -> None:
        """Host-side trace emission tagged with this engine (a no-op
        global read when no bus is installed)."""
        obs_trace.emit(kind, engine=self.trace_tag, **kw)

    def _program(self, scales) -> None:
        """(Re-)program every macro from the base tree, then overlay the
        current silicon state. Plane-level (bit-packed) state is forced
        whenever silicon is attached — the lossless collapse has no ADC
        evaluations to perturb. With ``use_kernel`` configs the macros
        keep their Pallas kernel layout instead: silicon folds into the
        fused kernel operands (``attach_silicon``'s ``silk`` entries), so
        sigma>0 fleets decode on the fused fast path."""
        from repro.core.programmed import program_weights
        self._last_scales = scales   # the shadow sanitizer re-programs with these
        self._programmed_params = program_weights(
            self._base_params, self.cfg.mf.cim, scales=scales,
            swap=self._swap_map, prefer_lossless=self.silicon is None)
        if obs_trace.enabled():
            data = {"calibrated": scales is not None,
                    "reprogram": self.programmed}
            if self.schedule is not None:
                from repro.compiler.cost import serve_reload_cost
                data.update(
                    pinned=self.schedule.pinned,
                    tiles=self.schedule.total_tiles,
                    weight_bits=(self.schedule.total_tiles
                                 * self.fleet.tile_weight_bits),
                    per_stream=serve_reload_cost(self.schedule,
                                                 1).to_payload())
            self._emit("program", stream=self.stream_index, **data)
        self._refresh_silicon()

    def _refresh_silicon(self) -> None:
        """Re-gather the per-projection silicon views from the fleet's
        CURRENT state (age/corrections) into the exec tree."""
        if self.silicon is None:
            self._exec_params = self._programmed_params
        else:
            from repro.silicon.instance import attach_silicon
            pinned = self.schedule.pinned if self.schedule is not None \
                else True
            self._exec_params = attach_silicon(
                self._programmed_params, self.silicon, self.silicon_cfg,
                self.cfg.mf.cim, pinned=pinned)
        # getattr: _refresh_silicon first runs from __init__ before the
        # hook list (and the sanitizer) exist.
        for hook in getattr(self, "exec_refresh_hooks", ()):
            hook(self)
        san = getattr(self, "_sanitizer", None)
        if san is not None:
            san.refresh(self)

    def _compile_fleet_schedule(self):
        """Compile the model's projections onto the fleet; returns the
        ``program_weights`` swap map (None when the model pins)."""
        from repro.compiler.frontend import projection_layer_stats
        from repro.compiler.schedule import compile_model
        from repro.core.mapping import MappingPolicy
        fleet, cim = self.fleet, self.cfg.mf.cim
        if (fleet.cfg.m_columns, fleet.cfg.w_bits) != (cim.m_columns,
                                                       cim.w_bits):
            raise ValueError(
                f"fleet µArray geometry (M={fleet.cfg.m_columns}, "
                f"W_P={fleet.cfg.w_bits}) does not match the model's "
                f"CimConfig (M={cim.m_columns}, W_P={cim.w_bits})")
        stats, groups = projection_layer_stats(self.params,
                                               calls=self.slots)
        # Every walked projection executes in cim_sim here, so the policy
        # gate is wide open — the fleet decides residency, not ops/param.
        self.schedule = compile_model(
            stats, fleet, policy=MappingPolicy(threshold=0.0,
                                               always_digital=()))
        # The schedule is frozen for the engine's lifetime: roll up its
        # Eq. 4 utilization once instead of per run().
        from repro.compiler.cost import model_cost
        self._fleet_utilization = model_cost(self.schedule)[1].utilization
        if self.schedule.pinned:
            return None
        # Round-interleaved serving replays this reload charge on every
        # input stream — cache the Eq. 4 payload once for the per-stream
        # "reload" trace events.
        from repro.compiler.cost import serve_reload_cost
        self._stream_reload_payload = \
            serve_reload_cost(self.schedule, 1).to_payload()
        not_linear = [g.name for g in groups if g.kind != "linear"]
        if not_linear:
            raise NotImplementedError(
                f"model does not fit the fleet ({self.schedule.total_tiles}"
                f" tiles > {fleet.tile_slots} slots) and round-interleaved "
                f"serving covers linear projections only; non-linear "
                f"projections: {not_linear[:4]}")
        return {g.name: fleet.tile_slots for g in groups}

    @property
    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.requests) if r is None]

    @property
    def occupied_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.requests) if r is not None]

    @property
    def stream_index(self) -> int:
        """The engine's input-stream counter (decode steps + prefill
        calls) — the conversion clock threaded into the jitted forwards
        and the age clock of the silicon lab."""
        return int(self._c_decode_steps.value + self._c_prefill_calls.value)

    def evict(self, slot: int) -> Request:
        """Reclaim an occupied slot before its request finishes (deadline-
        aware schedulers shedding a stream that can no longer meet its
        SLO). The request is marked ``evicted`` and returned with its
        partial output; the slot is free for the next admission wave —
        whose `_reset_slots` scatter zeroes the cache positions, so no
        state leaks to the next occupant.

        The freed slot's in-flight work is made visible: the generated
        tokens the eviction discards feed ``serve_evicted_tokens_total``
        (surfacing as ``ServeReport.evicted_tokens`` and the traffic
        lab's ``TrafficReport.evicted_tokens``) and ride the ``evict``
        trace event next to the un-ingested prompt remainder."""
        req = self.requests[slot]
        if req is None:
            raise ValueError(f"slot {slot} is not occupied")
        req.evicted = True
        self.requests[slot] = None
        freed = len(req.out)
        self._c_evictions.inc()
        self._c_evicted_tokens.inc(freed)
        self._emit("evict", stream=self.stream_index, slot=slot,
                   tokens=freed, prompt_left=int(self._prompt_left[slot]))
        return req

    def submit_many(self, reqs: list[Request]) -> int:
        """Admit up to ``len(free_slots)`` requests in ONE jitted scatter.

        Multi-slot admission waves (engine start, post-completion refills)
        previously paid one ``_reset_slot`` dispatch per request; all
        admitted slots now reset through a single ``_reset_slots`` call
        whose slot vector is padded to a fixed length (repeating the first
        slot — idempotent zeroing), so every wave reuses one compiled
        program. With batched prefill enabled, the admitted requests'
        prompts (all but the final token, which feeds the first sampling
        decode step) are then ingested in one ``lm_prefill_cache`` call
        instead of one decode tick per token. Returns the number of
        requests admitted.
        """
        self._validate(reqs)
        free = self.free_slots
        take = reqs[:len(free)]
        if not take:
            return 0
        sel = free[:len(take)]
        for s, req in zip(sel, take):
            self.requests[s] = req
            self._feed[s] = req.prompt[0]
            self._prompt_left[s] = len(req.prompt) - 1
        pad = np.full((self.slots,), sel[0], np.int32)
        pad[:len(sel)] = sel
        self.cache = _reset_slots(self.cache, jnp.asarray(pad))
        if obs_trace.enabled():
            self._emit("admit", stream=self.stream_index, slots=len(sel),
                       prompt_tokens=sum(len(r.prompt) for r in take))
        for hook in self.admission_hooks:
            hook(list(zip(sel, take)))
        if self.batched_prefill:
            self._prefill_wave([(s, r) for s, r in zip(sel, take)
                                if len(r.prompt) > 1])
        return len(take)

    def _prefill_wave(self, wave: list[tuple[int, Request]]) -> None:
        """Ingest the admitted prompts' first ``len - 1`` tokens in one
        batched forward; the final prompt token stays in ``_feed`` so the
        next ordinary decode tick samples the first output token exactly
        like the prefill-as-decode flow. Slab length buckets to the next
        power of two to bound recompiles; non-participating slots carry
        ``valid = 0`` and are untouched."""
        if not wave:
            return
        t_max = max(len(r.prompt) - 1 for _, r in wave)
        t_b = min(1 << (t_max - 1).bit_length(), self.max_len)
        tokens = np.zeros((self.slots, t_b), np.int32)
        valid = np.zeros((self.slots,), np.int32)
        for s, req in wave:
            n = len(req.prompt) - 1
            tokens[s, :n] = req.prompt[:n]
            valid[s] = n
            self._feed[s] = req.prompt[n]
            self._prompt_left[s] = 0
        stream = self.stream_index
        self.cache = self._prefill_fn(self._exec_params, self.cache,
                                      jnp.asarray(tokens),
                                      jnp.asarray(valid),
                                      jnp.int32(stream))
        self._c_prefill_calls.inc()
        self._c_prefill_tokens.inc(int(valid.sum()))
        self._emit("prefill_wave", stream=stream, slots=len(wave),
                   tokens=int(valid.sum()), bucket=t_b)
        self._after_stream()

    def _validate(self, reqs: list[Request]) -> None:
        """Reject malformed requests BEFORE any engine state mutates."""
        for req in reqs:
            if not req.prompt:
                raise ValueError(
                    "request has an empty prompt — the decode step needs "
                    "at least one token to feed (submit a BOS token "
                    "explicitly if that is what you mean)")
            if len(req.prompt) > self.max_len:
                raise ValueError(
                    f"prompt of {len(req.prompt)} tokens exceeds the "
                    f"engine's KV cache (max_len={self.max_len}) — "
                    f"ingesting it would silently wrap and corrupt the "
                    f"cache")

    def submit(self, req: Request) -> bool:
        return self.submit_many([req]) == 1

    def step(self) -> None:
        """One engine tick: decode every occupied slot by one token."""
        self._rng, sub = jax.random.split(self._rng)
        tokens = jnp.asarray(self._feed)
        step_idx = jnp.int32(self.stream_index)
        cache_before = self.cache if self._sanitizer is not None else None
        if self.tracing and \
                int(self._c_decode_steps.value) \
                % self.trace_tick_interval == 0:
            # Sampled tick: dispatch the traced twin program. One extra
            # int32 operand (occupied slots) rides the staged decode_tick
            # emission. Same jaxpr every sampled tick — the operand is an
            # array, not a Python constant — so the twin is traced once
            # and the cadence never recompiles anything.
            active = jnp.int32(
                sum(r is not None for r in self.requests))
            nxt, logits, self.cache = self._traced_step_fn(
                self._exec_params, self.cache, tokens, sub, step_idx,
                active)
        else:
            nxt, logits, self.cache = self.step_fn(self._exec_params,
                                                   self.cache, tokens,
                                                   sub, step_idx)
        if self._sanitizer is not None:
            self._sanitizer.check_step(self, cache_before, tokens, sub,
                                       step_idx, nxt, logits)
        self._c_decode_steps.inc()
        nxt = np.asarray(nxt)
        for s, req in enumerate(self.requests):
            if req is None:
                continue
            if self._prompt_left[s] > 0:
                # still ingesting the prompt: feed the next prompt token
                k = len(req.prompt) - int(self._prompt_left[s])
                self._feed[s] = req.prompt[k]
                self._prompt_left[s] -= 1
                continue
            tok = int(nxt[s])
            req.out.append(tok)
            self._c_decode_tokens.inc()
            self._feed[s] = tok
            if (self.eos_id is not None and tok == self.eos_id) or \
                    len(req.out) >= req.max_new_tokens:
                req.done = True
                self.requests[s] = None
        self._after_stream()

    # -- silicon aging + drift monitoring -----------------------------------

    # Re-gather cadence for a drifting fleet served WITHOUT a DriftPolicy
    # (the silicon still ages; nobody is watching the probe).
    _SILICON_UPDATE_DEFAULT = 8

    def _after_stream(self) -> None:
        """Per-input-stream hook: charge the stream's reload trace event
        (non-pinned schedules), age the silicon, refresh the drifted
        views on cadence, run the drift probe on cadence."""
        if (self._stream_reload_payload is not None
                and obs_trace.enabled()):
            self._emit("reload", stream=self.stream_index,
                       **self._stream_reload_payload)
        if self.silicon is None and self._monitor is None:
            return
        streams = self.stream_index
        if self.silicon is not None and self._drifting:
            # A fleet with zero drift sigmas never changes with age, so
            # static-silicon engines skip the per-token aging entirely.
            from repro.silicon.instance import age
            self.silicon = age(self.silicon, 1)
            interval = (self.drift.silicon_update_interval
                        if self.drift is not None
                        else self._SILICON_UPDATE_DEFAULT)
            if streams % max(interval, 1) == 0:
                self._refresh_silicon()
        if (self._monitor is not None
                and streams % max(self.drift.check_interval, 1) == 0):
            self._drift_check(streams)

    def _drift_check(self, streams: int) -> None:
        self._c_drift_checks.inc()
        status = self._monitor.check(self._exec_params, streams)
        # Emit the probe BEFORE any recalibration it triggers, so the
        # trace's seq order reads causally: drift_probe(alarm) →
        # retrim → retire → program → recal.
        if obs_trace.enabled():
            data = dict(rel_l2=float(status.rel_l2),
                        baseline_rel_l2=float(status.baseline_rel_l2),
                        max_clip_ratio=float(status.max_clip_ratio),
                        alarm=bool(status.alarm),
                        reasons=list(status.reasons))
            if obs_trace.detail_enabled() and self.silicon is not None:
                off = np.asarray(
                    self.macro.effective_offsets(self.silicon))
                data["residue_fs"] = [round(float(x), 6) for x in off]
            self._emit("drift_probe", stream=streams, **data)
        if status.alarm:
            self._c_drift_alarms.inc()
            if self.drift.auto_recalibrate:
                post = self._recalibrate(streams)
                status = dataclasses.replace(
                    status, recalibrated=True, post_rel_l2=post,
                    retrim_coarse_slots=int(self._g_coarse.value),
                    retired_slots=int(self._g_retired.value))
        self.drift_log.append(status)
        self.last_drift_status = status

    def _recalibrate(self, streams: int) -> float:
        """Auto-recalibration: re-run the macro's tiered comparator
        re-trim against the DRIFTED silicon (fine DAC where it still
        captures, the coarse tier where drift saturated the ±3σ range,
        retirement screening beyond even that), re-measure
        per-projection activation scales on the healed datapath,
        re-program every macro, and charge the full weight rewrite.
        Returns the post-recovery probe rel-L2.
        """
        from repro.calib.artifact import CalibrationArtifact
        from repro.calib.corpus import scales_from_stats
        if self.silicon is not None:
            prev_retired = int(self._g_retired.value)
            self.silicon, tiers = self.macro.retrim(self.silicon)
            tiers = np.asarray(tiers)
            coarse = int((tiers == 1).sum())
            retired = int((tiers == 2).sum())
            self._g_coarse.set(coarse)
            self._g_retired.set(retired)
            self._refresh_silicon()
            if obs_trace.enabled():
                data = dict(coarse=coarse, retired=retired)
                if obs_trace.detail_enabled():
                    data["tiers"] = [int(t) for t in tiers]
                self._emit("retrim", stream=streams, **data)
                if retired > prev_retired:
                    self._emit("retire", stream=streams, retired=retired,
                               newly=retired - prev_retired)
        # One probe replay on the healed datapath measures the live
        # activation statistics (the monitor's observe forward is
        # compiled once; re-attachment changes leaf values only).
        _, collector = self._monitor.observe(self._exec_params)
        scales = scales_from_stats(collector, self._registry,
                                   self.cfg.mf.cim.x_bits,
                                   self.calibration.method)
        self._program(scales)
        self._monitor.set_scales(scales)
        self.calibration = CalibrationArtifact(
            method=self.calibration.method, x_bits=self.calibration.x_bits,
            scales=scales,
            meta=dict(self.calibration.meta,
                      recalibrated_at_stream=streams))
        self._c_recals.inc()
        bits = 0
        if self.schedule is not None:
            bits = self.schedule.total_tiles * self.fleet.tile_weight_bits
            self._c_recal_bits.inc(bits)
        post = self._monitor.rel_l2(self._exec_params)
        # Future drift is judged against the healed datapath, not day
        # zero — the re-programmed scales shifted the noise floor.
        self._monitor.rebaseline(post)
        if obs_trace.enabled():
            nj = (bits * self.fleet.reload_j_per_bit * 1e9
                  if self.schedule is not None else 0.0)
            self._emit("recal", stream=streams, reload_bits=bits,
                       energy_nj=nj, post_rel_l2=float(post))
        return post

    def counters(self) -> dict:
        """Snapshot of the engine's cumulative stream counters (a view
        over ``self.metrics``). Take one before a serving window and hand
        it to :meth:`report_since` after — how external schedulers
        (``repro.traffic``) get per-window :class:`ServeReport`s without
        going through :meth:`run`. Every entry except the two fleet-
        health LEVELS (``retired_slots`` / ``retrim_coarse_slots``) is a
        monotonic counter, so deltas over disjoint windows sum exactly to
        the full-run totals — no event (a recalibration straddling a
        window boundary included) is ever counted twice."""
        return dict(decode_steps=int(self._c_decode_steps.value),
                    decode_tokens=int(self._c_decode_tokens.value),
                    prefill_calls=int(self._c_prefill_calls.value),
                    prefill_tokens=int(self._c_prefill_tokens.value),
                    drift_checks=int(self._c_drift_checks.value),
                    drift_alarms=int(self._c_drift_alarms.value),
                    recals=int(self._c_recals.value),
                    recal_bits=int(self._c_recal_bits.value),
                    evictions=int(self._c_evictions.value),
                    evicted_tokens=int(self._c_evicted_tokens.value),
                    retired_slots=int(self._g_retired.value),
                    retrim_coarse_slots=int(self._g_coarse.value))

    def report_since(self, before: dict, elapsed_s: float) -> ServeReport:
        """Eq. 4-charged :class:`ServeReport` of the window between a
        :meth:`counters` snapshot and now (also stored at
        ``last_report``)."""
        now = self.counters()
        self.last_report = self._build_report(
            decode_steps=now["decode_steps"] - before["decode_steps"],
            decode_tokens=now["decode_tokens"] - before["decode_tokens"],
            prefill_calls=now["prefill_calls"] - before["prefill_calls"],
            prefill_tokens=now["prefill_tokens"] - before["prefill_tokens"],
            elapsed_s=elapsed_s,
            drift_checks=now["drift_checks"] - before["drift_checks"],
            drift_alarms=now["drift_alarms"] - before["drift_alarms"],
            recalibrations=now["recals"] - before["recals"],
            recal_reload_bits=now["recal_bits"] - before["recal_bits"],
            # A fleet-health level as of the last recalibration, not a
            # windowed delta — retirement is a standing condition.
            retired_slots=now["retired_slots"],
            # .get: snapshots predating the telemetry counters lack the
            # key (saved-to-JSON benchmark baselines).
            evicted_tokens=(now["evicted_tokens"]
                            - before.get("evicted_tokens", 0)))
        return self.last_report

    def run(self, reqs: list[Request], max_ticks: int = 10_000
            ) -> list[Request]:
        """Serve ``reqs`` to completion (or until ``max_ticks``).

        Every submitted request comes back, in SUBMISSION order — callers
        zipping results to inputs stay aligned no matter which wave or
        slot a request landed on (requests already in flight from direct
        ``submit`` calls are appended after, in completion order).
        Requests still in flight — or never scheduled — when the tick
        budget runs out are marked ``timed_out`` and returned with their
        partial output, and their slots are released.

        Each run also produces a :class:`ServeReport` (``last_report``)
        charging the fleet schedule's reprogram events against the run's
        input streams.
        """
        self._validate(reqs)
        t0 = time.perf_counter()
        counters0 = self.counters()
        pending = list(reqs)
        done: list[Request] = []
        ticks = 0
        while (pending or any(r is not None for r in self.requests)) \
                and ticks < max_ticks:
            if pending and self.free_slots:
                admitted = self.submit_many(pending)
                del pending[:admitted]
            before = [r for r in self.requests]
            self.step()
            for r in before:
                if r is not None and r.done:
                    done.append(r)
            ticks += 1
        for s, r in enumerate(self.requests):
            if r is not None:
                r.timed_out = True
                done.append(r)
                self.requests[s] = None
        for r in pending:
            r.timed_out = True
            done.append(r)
        self.report_since(counters0, time.perf_counter() - t0)
        # Submission order first; extras (in-flight from direct submit
        # calls before this run) keep completion order after.
        submitted = {id(r) for r in reqs}
        extras = [r for r in done if id(r) not in submitted]
        return list(reqs) + extras

    def _build_report(self, *, decode_steps: int, decode_tokens: int,
                      prefill_calls: int, prefill_tokens: int,
                      elapsed_s: float, drift_checks: int = 0,
                      drift_alarms: int = 0, recalibrations: int = 0,
                      recal_reload_bits: int = 0, retired_slots: int = 0,
                      evicted_tokens: int = 0) -> ServeReport:
        pinned = None
        rounds_max = 0
        utilization = 0.0
        reprogram = reload_bits = 0
        reload_j = reload_s = 0.0
        recal_j = recal_s = 0.0
        if self.schedule is not None:
            from repro.compiler.cost import serve_reload_cost
            pinned = self.schedule.pinned
            rounds_max = self.schedule.rounds_max
            utilization = self._fleet_utilization
            reload = serve_reload_cost(self.schedule,
                                       decode_steps + prefill_calls)
            reprogram = reload.reprogram_events
            reload_bits = reload.reload_bits
            reload_j = reload.reload_energy_j
            reload_s = reload.reload_s
            # Recalibration rewrites are priced at the same fleet weight-
            # load port the per-stream reloads go through.
            recal_j = recal_reload_bits * self.fleet.reload_j_per_bit
            recal_s = recal_reload_bits / self.fleet.reload_bits_per_s
        return ServeReport(
            decode_tokens=decode_tokens, decode_steps=decode_steps,
            prefill_tokens=prefill_tokens, prefill_calls=prefill_calls,
            elapsed_s=elapsed_s,
            tok_s=decode_tokens / elapsed_s if elapsed_s > 0 else 0.0,
            pinned=pinned, rounds_max=rounds_max,
            reprogram_events=reprogram, reload_bits=reload_bits,
            reload_energy_j=reload_j, reload_s=reload_s,
            utilization=utilization, drift_checks=drift_checks,
            drift_alarms=drift_alarms, recalibrations=recalibrations,
            recal_reload_bits=recal_reload_bits, recal_energy_j=recal_j,
            recal_s=recal_s, retired_slots=retired_slots,
            evicted_tokens=evicted_tokens)


def _check_calibration_names(params, calibration) -> None:
    """Fail loudly when an artifact's projection names don't belong to
    this model — otherwise every scale lookup would miss and the engine
    would serve the static full-scale default while claiming to be
    calibrated."""
    from repro.core.programmed import iter_projections
    expected: set[str] = set()
    for name, _, kind in iter_projections(params):
        if kind == "experts":
            expected.update(f"{name}.{k}" for k in ("up", "gate", "down"))
        else:
            expected.add(name)
    unknown = set(calibration.scales) - expected
    if unknown or not (set(calibration.scales) & expected):
        raise ValueError(
            f"calibration artifact does not match this model's "
            f"projections (unknown names: {sorted(unknown)[:5]}; model "
            f"has {len(expected)} projections) — was it calibrated for a "
            f"different config?")


@partial(jax.jit, donate_argnums=0)
def _reset_slots(cache, slots):
    """Zero a VECTOR of slots' positions in one on-device scatter.

    ``slots`` is an int32 vector (duplicates allowed — zeroing is
    idempotent, which is what lets ``submit_many`` pad admission waves to
    a fixed length and reuse one compiled program). The cache argument is
    donated — callers always rebind (``cache = _reset_slots(cache, s)``),
    so the untouched KV leaves alias in place instead of being copied per
    admission."""
    def fix(path, v):
        last = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if last in ("len", "pos"):
            if v.ndim == 1:
                return v.at[slots].set(0)
            return v.at[:, slots].set(0)
        return v
    return jax.tree_util.tree_map_with_path(fix, cache)


@partial(jax.jit, donate_argnums=0)
def _reset_slot(cache, slot):
    """Single-slot variant of :func:`_reset_slots` (kept for callers that
    admit one request outside a wave)."""
    def fix(path, v):
        last = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if last in ("len", "pos"):
            if v.ndim == 1:
                return v.at[slot].set(0)
            return v.at[:, slot].set(0)
        return v
    return jax.tree_util.tree_map_with_path(fix, cache)
