"""Batched serving engine: slot-based continuous batching over the decode
step, greedy/temperature sampling, and prompt ingestion.

The engine owns a fixed-capacity KV cache (`slots` x `max_len`); requests
occupy slots, prompts are ingested token-by-token through the same jitted
decode step (prefill-as-decode keeps one compiled program), and finished
slots are recycled. `serve_step` — the function the decode dry-run cells
lower — is a single fused (decode + sample) step over the whole batch.

Weight-stationary CIM serving: when the model config maps projections to
``cim_sim``, the engine programs the whole model ONCE at construction
(`core.programmed.program_weights`) and every jitted decode step serves
from the frozen macro state — the per-step weight recalibrate/requantise/
bitplane/pack work of the on-the-fly path disappears from the hot loop,
mirroring how the hardware writes the µArray once and streams inputs.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T


def make_serve_step(cfg: ModelConfig, pctx=None,
                    temperature: float = 0.0) -> Callable:
    """(params, cache, tokens, rng) -> (next_tokens, logits, new_cache)."""
    pctx = pctx or T.ParallelContext()

    def serve_step(params, cache, tokens, rng):
        logits, new_cache = T.lm_decode_step(params, cache, tokens, cfg,
                                             pctx)
        if temperature > 0.0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), logits, new_cache

    return serve_step


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # Set by ServeEngine.run when the tick budget ran out before the
    # request finished (or before it was ever scheduled): the request is
    # returned with whatever it produced instead of being dropped.
    timed_out: bool = False


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, slots: int, max_len: int,
                 temperature: float = 0.0, eos_id: Optional[int] = None,
                 seed: int = 0, program: bool = True):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        # Weight-stationary programming: freeze every CIM projection's
        # macro state now so the jitted step does input-side work only.
        # ``program=False`` keeps the legacy on-the-fly path (benchmarks).
        self._exec_params = params
        self.programmed = False
        if program and cfg.mf.enabled and cfg.mf.mode == "cim_sim":
            from repro.core.programmed import program_weights
            self._exec_params = program_weights(params, cfg.mf.cim)
            self.programmed = True
        self.cache = T.lm_init_cache(cfg, slots, max_len)
        self.step_fn = jax.jit(make_serve_step(cfg, temperature=temperature))
        self.requests: list[Optional[Request]] = [None] * slots
        self._feed = np.zeros((slots,), np.int32)       # next token to feed
        self._prompt_left = np.zeros((slots,), np.int64)
        self._rng = jax.random.PRNGKey(seed)

    @property
    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.requests) if r is None]

    def submit(self, req: Request) -> bool:
        free = self.free_slots
        if not free:
            return False
        s = free[0]
        self.requests[s] = req
        self._feed[s] = req.prompt[0]
        self._prompt_left[s] = len(req.prompt) - 1
        # reset the slot's cache position
        self.cache = _reset_slot(self.cache, s)
        return True

    def step(self) -> None:
        """One engine tick: decode every occupied slot by one token."""
        self._rng, sub = jax.random.split(self._rng)
        tokens = jnp.asarray(self._feed)
        nxt, _, self.cache = self.step_fn(self._exec_params, self.cache,
                                          tokens, sub)
        nxt = np.asarray(nxt)
        for s, req in enumerate(self.requests):
            if req is None:
                continue
            if self._prompt_left[s] > 0:
                # still ingesting the prompt: feed the next prompt token
                k = len(req.prompt) - int(self._prompt_left[s])
                self._feed[s] = req.prompt[k]
                self._prompt_left[s] -= 1
                continue
            tok = int(nxt[s])
            req.out.append(tok)
            self._feed[s] = tok
            if (self.eos_id is not None and tok == self.eos_id) or \
                    len(req.out) >= req.max_new_tokens:
                req.done = True
                self.requests[s] = None

    def run(self, reqs: list[Request], max_ticks: int = 10_000
            ) -> list[Request]:
        """Serve ``reqs`` to completion (or until ``max_ticks``).

        Every submitted request comes back: requests still in flight — or
        never scheduled — when the tick budget runs out are marked
        ``timed_out`` and returned with their partial output, and their
        slots are released.
        """
        pending = list(reqs)
        done: list[Request] = []
        ticks = 0
        while (pending or any(r is not None for r in self.requests)) \
                and ticks < max_ticks:
            while pending and self.free_slots:
                self.submit(pending.pop(0))
            before = [r for r in self.requests]
            self.step()
            for r in before:
                if r is not None and r.done:
                    done.append(r)
            ticks += 1
        for s, r in enumerate(self.requests):
            if r is not None:
                r.timed_out = True
                done.append(r)
                self.requests[s] = None
        for r in pending:
            r.timed_out = True
            done.append(r)
        return done


@partial(jax.jit, donate_argnums=0)
def _reset_slot(cache, slot):
    """Zero one slot's positions, on device (no host round trip: a jitted
    ``.at[..., slot].set(0)`` tree-map instead of numpy cache surgery).

    The cache argument is donated — callers always rebind
    (``cache = _reset_slot(cache, s)``), so the untouched KV leaves alias
    in place instead of being copied per admission."""
    def fix(path, v):
        last = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if last in ("len", "pos"):
            if v.ndim == 1:
                return v.at[slot].set(0)
            return v.at[:, slot].set(0)
        return v
    return jax.tree_util.tree_map_with_path(fix, cache)
