"""Batched serving engine: slot-based continuous batching over the decode
step, greedy/temperature sampling, and prompt ingestion.

The engine owns a fixed-capacity KV cache (`slots` x `max_len`); requests
occupy slots, prompts are ingested token-by-token through the same jitted
decode step (prefill-as-decode keeps one compiled program), and finished
slots are recycled. `serve_step` — the function the decode dry-run cells
lower — is a single fused (decode + sample) step over the whole batch.

Weight-stationary CIM serving: when the model config maps projections to
``cim_sim``, the engine programs the whole model ONCE at construction
(`core.programmed.program_weights`) and every jitted decode step serves
from the frozen macro state — the per-step weight recalibrate/requantise/
bitplane/pack work of the on-the-fly path disappears from the hot loop,
mirroring how the hardware writes the µArray once and streams inputs.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T


def make_serve_step(cfg: ModelConfig, pctx=None,
                    temperature: float = 0.0) -> Callable:
    """(params, cache, tokens, rng) -> (next_tokens, logits, new_cache)."""
    pctx = pctx or T.ParallelContext()

    def serve_step(params, cache, tokens, rng):
        logits, new_cache = T.lm_decode_step(params, cache, tokens, cfg,
                                             pctx)
        if temperature > 0.0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), logits, new_cache

    return serve_step


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # Set by ServeEngine.run when the tick budget ran out before the
    # request finished (or before it was ever scheduled): the request is
    # returned with whatever it produced instead of being dropped.
    timed_out: bool = False


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, slots: int, max_len: int,
                 temperature: float = 0.0, eos_id: Optional[int] = None,
                 seed: int = 0, program: bool = True, calibration=None):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        # Weight-stationary programming: freeze every CIM projection's
        # macro state now so the jitted step does input-side work only.
        # ``program=False`` keeps the legacy on-the-fly path (benchmarks).
        # ``calibration`` (a repro.calib CalibrationArtifact, or a path to
        # a saved one) programs its measured per-projection activation
        # scales instead of the static full-scale default.
        self._exec_params = params
        self.programmed = False
        self.calibration = None
        programmable = (program and cfg.mf.enabled
                        and cfg.mf.mode == "cim_sim")
        if calibration is not None and not programmable:
            raise ValueError(
                "a calibration artifact was supplied but the engine is not "
                "programming CIM macros (program=False or the config does "
                "not map projections to cim_sim) — the scales would be "
                "silently dropped")
        if programmable:
            from repro.core.programmed import program_weights
            scales = None
            if calibration is not None:
                from repro.calib.artifact import CalibrationArtifact
                if not isinstance(calibration, CalibrationArtifact):
                    calibration = CalibrationArtifact.load(calibration)
                if calibration.x_bits != cfg.mf.cim.x_bits:
                    raise ValueError(
                        f"calibration artifact is for x_bits="
                        f"{calibration.x_bits}, model runs x_bits="
                        f"{cfg.mf.cim.x_bits}")
                _check_calibration_names(params, calibration)
                scales = calibration.scales
                self.calibration = calibration
            self._exec_params = program_weights(params, cfg.mf.cim,
                                                scales=scales)
            self.programmed = True
        self.cache = T.lm_init_cache(cfg, slots, max_len)
        self.step_fn = jax.jit(make_serve_step(cfg, temperature=temperature))
        self.requests: list[Optional[Request]] = [None] * slots
        self._feed = np.zeros((slots,), np.int32)       # next token to feed
        self._prompt_left = np.zeros((slots,), np.int64)
        self._rng = jax.random.PRNGKey(seed)

    @property
    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.requests) if r is None]

    def submit_many(self, reqs: list[Request]) -> int:
        """Admit up to ``len(free_slots)`` requests in ONE jitted scatter.

        Multi-slot admission waves (engine start, post-completion refills)
        previously paid one ``_reset_slot`` dispatch per request; all
        admitted slots now reset through a single ``_reset_slots`` call
        whose slot vector is padded to a fixed length (repeating the first
        slot — idempotent zeroing), so every wave reuses one compiled
        program. Returns the number of requests admitted.
        """
        free = self.free_slots
        take = reqs[:len(free)]
        if not take:
            return 0
        sel = free[:len(take)]
        for s, req in zip(sel, take):
            self.requests[s] = req
            self._feed[s] = req.prompt[0]
            self._prompt_left[s] = len(req.prompt) - 1
        pad = np.full((self.slots,), sel[0], np.int32)
        pad[:len(sel)] = sel
        self.cache = _reset_slots(self.cache, jnp.asarray(pad))
        return len(take)

    def submit(self, req: Request) -> bool:
        return self.submit_many([req]) == 1

    def step(self) -> None:
        """One engine tick: decode every occupied slot by one token."""
        self._rng, sub = jax.random.split(self._rng)
        tokens = jnp.asarray(self._feed)
        nxt, _, self.cache = self.step_fn(self._exec_params, self.cache,
                                          tokens, sub)
        nxt = np.asarray(nxt)
        for s, req in enumerate(self.requests):
            if req is None:
                continue
            if self._prompt_left[s] > 0:
                # still ingesting the prompt: feed the next prompt token
                k = len(req.prompt) - int(self._prompt_left[s])
                self._feed[s] = req.prompt[k]
                self._prompt_left[s] -= 1
                continue
            tok = int(nxt[s])
            req.out.append(tok)
            self._feed[s] = tok
            if (self.eos_id is not None and tok == self.eos_id) or \
                    len(req.out) >= req.max_new_tokens:
                req.done = True
                self.requests[s] = None

    def run(self, reqs: list[Request], max_ticks: int = 10_000
            ) -> list[Request]:
        """Serve ``reqs`` to completion (or until ``max_ticks``).

        Every submitted request comes back: requests still in flight — or
        never scheduled — when the tick budget runs out are marked
        ``timed_out`` and returned with their partial output, and their
        slots are released.
        """
        pending = list(reqs)
        done: list[Request] = []
        ticks = 0
        while (pending or any(r is not None for r in self.requests)) \
                and ticks < max_ticks:
            if pending and self.free_slots:
                admitted = self.submit_many(pending)
                del pending[:admitted]
            before = [r for r in self.requests]
            self.step()
            for r in before:
                if r is not None and r.done:
                    done.append(r)
            ticks += 1
        for s, r in enumerate(self.requests):
            if r is not None:
                r.timed_out = True
                done.append(r)
                self.requests[s] = None
        for r in pending:
            r.timed_out = True
            done.append(r)
        return done


def _check_calibration_names(params, calibration) -> None:
    """Fail loudly when an artifact's projection names don't belong to
    this model — otherwise every scale lookup would miss and the engine
    would serve the static full-scale default while claiming to be
    calibrated."""
    from repro.core.programmed import iter_projections
    expected: set[str] = set()
    for name, _, kind in iter_projections(params):
        if kind == "experts":
            expected.update(f"{name}.{k}" for k in ("up", "gate", "down"))
        else:
            expected.add(name)
    unknown = set(calibration.scales) - expected
    if unknown or not (set(calibration.scales) & expected):
        raise ValueError(
            f"calibration artifact does not match this model's "
            f"projections (unknown names: {sorted(unknown)[:5]}; model "
            f"has {len(expected)} projections) — was it calibrated for a "
            f"different config?")


@partial(jax.jit, donate_argnums=0)
def _reset_slots(cache, slots):
    """Zero a VECTOR of slots' positions in one on-device scatter.

    ``slots`` is an int32 vector (duplicates allowed — zeroing is
    idempotent, which is what lets ``submit_many`` pad admission waves to
    a fixed length and reuse one compiled program). The cache argument is
    donated — callers always rebind (``cache = _reset_slots(cache, s)``),
    so the untouched KV leaves alias in place instead of being copied per
    admission."""
    def fix(path, v):
        last = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if last in ("len", "pos"):
            if v.ndim == 1:
                return v.at[slots].set(0)
            return v.at[:, slots].set(0)
        return v
    return jax.tree_util.tree_map_with_path(fix, cache)


@partial(jax.jit, donate_argnums=0)
def _reset_slot(cache, slot):
    """Single-slot variant of :func:`_reset_slots` (kept for callers that
    admit one request outside a wave)."""
    def fix(path, v):
        last = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if last in ("len", "pos"):
            if v.ndim == 1:
                return v.at[slot].set(0)
            return v.at[:, slot].set(0)
        return v
    return jax.tree_util.tree_map_with_path(fix, cache)
