"""Macro compiler: lower model layers onto a tiled fleet of CIM macros.

Pipeline (each stage usable standalone):

  tiling    — split (K, N) projections into µArray tiles on a Fleet
  schedule  — place tiles, derive rounds/passes per macro
  cost      — Eq. 4 latency/energy/TOPS-W/utilization roll-up
  execute   — bit-exact tiled execution through the behavioural simulator
  report    — per-layer schedule tables and roll-up summaries
  frontend  — (K, N, calls) extraction from registry model configs
"""

from repro.compiler.cost import (FleetCost, LayerCost, layer_cost,
                                 model_cost, rollup)
from repro.compiler.execute import compiled_matmul, verify_bit_exact
from repro.compiler.frontend import lm_layer_stats
from repro.compiler.report import benchmark_rows, layer_table, rollup_summary
from repro.compiler.schedule import (LayerSchedule, ModelSchedule,
                                     compile_model, schedule_layer)
from repro.compiler.tiling import Fleet, TilingPlan, plan_tiling

__all__ = [
    "Fleet", "TilingPlan", "plan_tiling",
    "LayerSchedule", "ModelSchedule", "compile_model", "schedule_layer",
    "LayerCost", "FleetCost", "layer_cost", "model_cost", "rollup",
    "compiled_matmul", "verify_bit_exact",
    "layer_table", "rollup_summary", "benchmark_rows",
    "lm_layer_stats",
]
