"""Stage 5 of the macro compiler: human-readable schedule/cost reports."""

from __future__ import annotations

from typing import Sequence

from repro.compiler.cost import FleetCost, LayerCost
from repro.compiler.schedule import ModelSchedule


def _si(v: float, unit: str) -> str:
    for scale, prefix in ((1.0, ""), (1e-3, "m"), (1e-6, "u"), (1e-9, "n"),
                          (1e-12, "p"), (1e-15, "f")):
        if abs(v) >= scale:
            return f"{v / scale:7.2f} {prefix}{unit}"
    return f"{v:9.2e} {unit}"


_COLS = ("layer", "tiles", "rounds", "unit_ops", "latency", "energy",
         "TOPS/W", "util", "waste")


def layer_table(msched: ModelSchedule, costs: Sequence[LayerCost]) -> str:
    """Fixed-width per-layer schedule table (one row per CIM layer)."""
    rows = [("{:<16} {:>8} {:>6} {:>10} {:>12} {:>12} {:>7} {:>6} {:>6}"
             .format(*_COLS))]
    for s, c in zip(msched.layers, costs):
        rows.append(
            f"{c.name:<16} {s.plan.n_tiles:>8} {c.rounds:>6} "
            f"{c.unit_ops:>10} {_si(c.latency_s, 's'):>12} "
            f"{_si(c.energy_j, 'J'):>12} {c.tops_per_w:>7.1f} "
            f"{c.utilization:>6.2f} {c.waste_fraction:>6.2f}")
    for d in msched.digital:
        rows.append(f"{d.name:<16} {'-':>8} {'-':>6} {'-':>10} "
                    f"{'digital':>12} {'-':>12} {'-':>7} {'-':>6} {'-':>6}")
    return "\n".join(rows)


def rollup_summary(msched: ModelSchedule, total: FleetCost) -> str:
    f = msched.fleet
    lines = [
        f"fleet: {f.n_macros} macros x 8x{2 * f.cfg.m_columns} µArray "
        f"(A_P={f.cfg.adc_bits}), {f.tile_slots} tile slots, "
        f"{'weight-stationary' if f.weight_stationary else 'weight-swapped'}"
        f"{', pinned' if msched.pinned else ''}",
        f"tiles={msched.total_tiles}  unit_ops={total.unit_ops}  "
        f"rounds_max={max((c.rounds for c in msched.layers), default=0)}  "
        f"reprogram_events={msched.total_reprogram_events}",
        f"latency={_si(total.latency_s, 's').strip()}  "
        f"energy={_si(total.energy_j, 'J').strip()} "
        f"(reload {_si(total.reload_energy_j, 'J').strip()})",
        f"cim_tops_per_w={total.tops_per_w:.1f}  "
        f"system_tops_per_w={total.system_tops_per_w():.2f}  "
        f"utilization={total.utilization:.2f}",
    ]
    return "\n".join(lines)


def benchmark_rows(prefix: str, msched: ModelSchedule,
                   costs: Sequence[LayerCost], total: FleetCost
                   ) -> list[tuple[str, float, str]]:
    """(name, us, derived) rows in the benchmarks/run.py CSV convention."""
    rows = []
    for s, c in zip(msched.layers, costs):
        rows.append((f"{prefix}_layer_{c.name}", 0.0,
                     f"tiles={s.plan.n_tiles} "
                     f"rounds={c.rounds} unit_ops={c.unit_ops} "
                     f"lat={c.latency_s:.3e}s e={c.energy_j:.3e}J "
                     f"topsw={c.tops_per_w:.1f} util={c.utilization:.2f}"))
    rows.append((f"{prefix}_rollup", 0.0,
                 f"unit_ops={total.unit_ops} lat={total.latency_s:.3e}s "
                 f"e={total.energy_j:.3e}J topsw={total.tops_per_w:.1f} "
                 f"sys_topsw={total.system_tops_per_w():.2f} "
                 f"util={total.utilization:.2f} pinned={msched.pinned} "
                 f"reprog={msched.total_reprogram_events}"))
    return rows
