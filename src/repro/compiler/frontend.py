"""Model frontends: per-layer (K, N, calls) shapes for the macro compiler.

The mapping layer (:mod:`repro.core.mapping`) already records (params, ops)
per layer; the compiler additionally needs each projection's matmul view —
contraction width K, output channels N — with the weight-reuse count
recovered from ``ops = 2·K·N·calls``. The paper's own convnets carry those
shapes directly (``repro.models.convnets``); this module derives them for
the LM registry configs (attention + MLP projections of standard decoder
blocks, embeddings/heads flagged digital-by-name as in the paper).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.configs.base import ModelConfig
from repro.core.mapping import LayerStat


def _proj(name: str, k: int, n: int, tokens: int) -> LayerStat:
    return LayerStat(name, params=k * n, ops=2 * k * n * tokens, k=k, n=n)


def lm_layer_stats(cfg: ModelConfig, tokens: int = 1024,
                   unique_blocks: bool = False) -> list[LayerStat]:
    """Projection-level stats for a decoder LM forward over ``tokens``.

    unique_blocks: emit one representative block instead of all n_layers
    (all blocks share shapes; useful for compact reports — totals then
    cover 1/n_layers of the model).
    """
    # Only families whose blocks really are dense attention + MLP decoders:
    # MoE experts, MLA factorisations, and hybrid SSM mixers have different
    # projection shapes and would be silently mispriced.
    if cfg.family not in ("lm", "vlm") or cfg.moe or cfg.attn_type != "gqa":
        raise ValueError(
            f"LM frontend only models dense GQA decoder stacks; "
            f"{cfg.name} (family={cfg.family}, attn={cfg.attn_type}, "
            f"moe={cfg.moe is not None}) needs its own frontend")
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qkv_n = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
    ff_in = 2 * cfg.d_ff if cfg.mlp_type in ("silu_glu", "geglu") else cfg.d_ff
    stats = [LayerStat("embed", params=cfg.vocab_size * d, ops=0)]
    n_blocks = 1 if unique_blocks else cfg.n_layers
    for i in range(n_blocks):
        stats += [
            _proj(f"L{i}_attn_qkv", d, qkv_n, tokens),
            _proj(f"L{i}_attn_out", cfg.n_heads * hd, d, tokens),
            _proj(f"L{i}_mlp_up", d, ff_in, tokens),
            _proj(f"L{i}_mlp_down", cfg.d_ff, d, tokens),
        ]
    stats.append(LayerStat("lm_head", params=d * cfg.vocab_size,
                           ops=2 * d * cfg.vocab_size * tokens,
                           k=d, n=cfg.vocab_size))
    return stats


def total_ops(stats: Sequence[LayerStat]) -> int:
    return sum(s.ops for s in stats)


@dataclasses.dataclass(frozen=True)
class ProjectionGroup:
    """One named MF projection of a parameter tree (possibly stacked)."""

    name: str               # the map_projections walk name (+ expert role)
    kind: str               # 'linear' | 'conv' | 'experts'
    k: int
    n: int
    n_instances: int        # stacked leading instances (scan periods, E)


def projection_layer_stats(params, *, calls: int = 1
                           ) -> tuple[list[LayerStat],
                                      list[ProjectionGroup]]:
    """Per-INSTANCE layer stats straight from a model parameter tree.

    Unlike :func:`lm_layer_stats` (which prices shapes from a config),
    this walks the actual parameters via ``core.programmed
    .iter_projections`` — the very walk scale programming and the serve
    engine use — so the schedule the engine compiles covers exactly the
    projections it executes, with names that line up by construction.
    Stacked layers (scan periods) and MoE experts emit one
    :class:`LayerStat` per weight instance (each is a separate tile
    placement on the fleet); ``calls`` is the input vectors streamed per
    instance per forward (= engine slots for one decode step).
    """
    import numpy as np

    from repro.core.programmed import (_EXPERT_KEYS, conv_weight_matrix,
                                       iter_projections)

    stats: list[LayerStat] = []
    groups: list[ProjectionGroup] = []

    def add(name: str, kind: str, k: int, n: int, n_inst: int) -> None:
        groups.append(ProjectionGroup(name, kind, k, n, n_inst))
        for j in range(n_inst):
            inst = f"{name}[{j}]" if n_inst > 1 else name
            stats.append(LayerStat(inst, params=k * n, ops=2 * k * n * calls,
                                   k=k, n=n))

    for name, node, kind in iter_projections(params):
        if kind == "experts":
            for key in _EXPERT_KEYS:
                w = node[key]
                k, n = w.shape[-2:]
                n_inst = int(np.prod(w.shape[:-2], dtype=np.int64))
                add(f"{name}.{key}", kind, k, n, n_inst)
        elif kind == "conv":
            k, n = conv_weight_matrix(node["w"]).shape
            add(name, kind, k, n, 1)
        else:
            w = node["w"]
            k, n = w.shape[-2:]
            n_inst = int(np.prod(w.shape[:-2], dtype=np.int64))
            add(name, kind, k, n, n_inst)
    return stats, groups
