"""Model frontends: per-layer (K, N, calls) shapes for the macro compiler.

The mapping layer (:mod:`repro.core.mapping`) already records (params, ops)
per layer; the compiler additionally needs each projection's matmul view —
contraction width K, output channels N — with the weight-reuse count
recovered from ``ops = 2·K·N·calls``. The paper's own convnets carry those
shapes directly (``repro.models.convnets``); this module derives them for
the LM registry configs (attention + MLP projections of standard decoder
blocks, embeddings/heads flagged digital-by-name as in the paper).
"""

from __future__ import annotations

from typing import Sequence

from repro.configs.base import ModelConfig
from repro.core.mapping import LayerStat


def _proj(name: str, k: int, n: int, tokens: int) -> LayerStat:
    return LayerStat(name, params=k * n, ops=2 * k * n * tokens, k=k, n=n)


def lm_layer_stats(cfg: ModelConfig, tokens: int = 1024,
                   unique_blocks: bool = False) -> list[LayerStat]:
    """Projection-level stats for a decoder LM forward over ``tokens``.

    unique_blocks: emit one representative block instead of all n_layers
    (all blocks share shapes; useful for compact reports — totals then
    cover 1/n_layers of the model).
    """
    # Only families whose blocks really are dense attention + MLP decoders:
    # MoE experts, MLA factorisations, and hybrid SSM mixers have different
    # projection shapes and would be silently mispriced.
    if cfg.family not in ("lm", "vlm") or cfg.moe or cfg.attn_type != "gqa":
        raise ValueError(
            f"LM frontend only models dense GQA decoder stacks; "
            f"{cfg.name} (family={cfg.family}, attn={cfg.attn_type}, "
            f"moe={cfg.moe is not None}) needs its own frontend")
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qkv_n = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
    ff_in = 2 * cfg.d_ff if cfg.mlp_type in ("silu_glu", "geglu") else cfg.d_ff
    stats = [LayerStat("embed", params=cfg.vocab_size * d, ops=0)]
    n_blocks = 1 if unique_blocks else cfg.n_layers
    for i in range(n_blocks):
        stats += [
            _proj(f"L{i}_attn_qkv", d, qkv_n, tokens),
            _proj(f"L{i}_attn_out", cfg.n_heads * hd, d, tokens),
            _proj(f"L{i}_mlp_up", d, ff_in, tokens),
            _proj(f"L{i}_mlp_down", cfg.d_ff, d, tokens),
        ]
    stats.append(LayerStat("lm_head", params=d * cfg.vocab_size,
                           ops=2 * d * cfg.vocab_size * tokens,
                           k=d, n=cfg.vocab_size))
    return stats


def total_ops(stats: Sequence[LayerStat]) -> int:
    return sum(s.ops for s in stats)
