"""Stage 3 of the macro compiler: Eq. 4 latency/energy roll-up.

Prices a :class:`~repro.compiler.schedule.LayerSchedule` with the
calibrated macro constants of :mod:`repro.core.energy`:

  * compute energy  = unit_ops × ``unit_op_energy_j`` (Eq. 4b) — by
    construction, so the roll-up equals the schedule's unit-op count times
    the unit energy *analytically*, not just numerically;
  * compute latency = busiest-macro unit ops × ``unit_op_cycles`` (Eq. 4a)
    at the macro clock;
  * weight reloads  = bits written × SRAM write energy, streamed at the
    fleet's load-port bandwidth (overlapped with nothing — conservative);
  * utilization     = fleet compute-slot occupancy on the critical path;
  * TOPS/W uses *useful* (unpadded) MAC ops, so µArray padding waste shows
    up as an efficiency loss rather than being silently credited.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.compiler.schedule import LayerSchedule, ModelSchedule
from repro.core.energy import (DEFAULT_MACRO, DIGITAL_TOPS_PER_W, MacroParams,
                               unit_op_cycles, unit_op_energy_j)
from repro.compiler.tiling import Fleet


@dataclasses.dataclass(frozen=True)
class LayerCost:
    name: str
    unit_ops: int
    mac_ops: int
    cycles: int                 # busiest-macro compute cycles
    latency_s: float            # compute + (serialised) weight reload
    compute_energy_j: float
    reload_energy_j: float
    utilization: float          # unit_ops / (n_macros * macro_unit_ops)
    waste_fraction: float       # padded µArray cells
    rounds: int
    reprogram_events: int = 0   # weight-program events (0 when preloaded)

    @property
    def energy_j(self) -> float:
        return self.compute_energy_j + self.reload_energy_j

    @property
    def tops_per_w(self) -> float:
        return self.mac_ops / self.energy_j / 1e12 if self.energy_j else 0.0


@dataclasses.dataclass(frozen=True)
class FleetCost:
    """End-to-end roll-up over a model's CIM layers (executed in order)."""

    unit_ops: int
    mac_ops: int
    cycles: int
    latency_s: float
    compute_energy_j: float
    reload_energy_j: float
    utilization: float
    digital_ops: int = 0        # ops left on the digital fabric
    reprogram_events: int = 0   # weight-program events per input stream

    @property
    def energy_j(self) -> float:
        return self.compute_energy_j + self.reload_energy_j

    @property
    def tops_per_w(self) -> float:
        return self.mac_ops / self.energy_j / 1e12 if self.energy_j else 0.0

    def system_tops_per_w(self,
                          digital_tops_w: float = DIGITAL_TOPS_PER_W) -> float:
        """Energy-correct system efficiency incl. the digital-fabric share."""
        e = self.energy_j + self.digital_ops / (digital_tops_w * 1e12)
        ops = self.mac_ops + self.digital_ops
        return ops / e / 1e12 if e else 0.0


def _fleet_cycles(fleet: Fleet) -> int:
    """Eq. 4a unit-op cycles for this fleet — through the macro model's
    hook when one is attached (``Fleet.macro``), else the source
    paper's SA-ADC formula."""
    if fleet.macro is not None:
        return fleet.macro.unit_op_cycles(fleet.cfg)
    return unit_op_cycles(fleet.cfg)


def _fleet_energy_j(fleet: Fleet, macro: MacroParams) -> float:
    """Eq. 4b unit-op energy for this fleet (macro-model aware)."""
    if fleet.macro is not None:
        return fleet.macro.unit_op_energy_j(fleet.cfg, macro)
    return unit_op_energy_j(fleet.cfg, macro)


def layer_cost(sched: LayerSchedule, fleet: Fleet,
               macro: MacroParams = DEFAULT_MACRO) -> LayerCost:
    cycles = sched.macro_unit_ops * _fleet_cycles(fleet)
    reload_s = sched.reload_bits / fleet.reload_bits_per_s
    busy = fleet.n_macros * sched.macro_unit_ops
    return LayerCost(
        name=sched.name,
        unit_ops=sched.unit_ops,
        mac_ops=sched.mac_ops,
        cycles=cycles,
        latency_s=cycles / macro.clock_hz + reload_s,
        compute_energy_j=sched.unit_ops * _fleet_energy_j(fleet, macro),
        reload_energy_j=sched.reload_bits * fleet.reload_j_per_bit,
        utilization=sched.unit_ops / busy if busy else 0.0,
        waste_fraction=sched.plan.waste_fraction,
        rounds=sched.rounds,
        reprogram_events=sched.reprogram_events)


def rollup(costs: Sequence[LayerCost], fleet: Fleet,
           macro: MacroParams = DEFAULT_MACRO,
           digital_ops: int = 0) -> FleetCost:
    unit_ops = sum(c.unit_ops for c in costs)
    macro_unit_ops = sum(c.cycles for c in costs) // _fleet_cycles(fleet) \
        if costs else 0
    busy = fleet.n_macros * macro_unit_ops
    return FleetCost(
        unit_ops=unit_ops,
        mac_ops=sum(c.mac_ops for c in costs),
        cycles=sum(c.cycles for c in costs),
        latency_s=sum(c.latency_s for c in costs),
        # product of the TOTAL, not a sum of per-layer products: keeps the
        # "unit_ops x unit energy == roll-up" identity exact in floats.
        compute_energy_j=unit_ops * _fleet_energy_j(fleet, macro),
        reload_energy_j=sum(c.reload_energy_j for c in costs),
        utilization=unit_ops / busy if busy else 0.0,
        digital_ops=digital_ops,
        reprogram_events=sum(c.reprogram_events for c in costs))


def model_cost(msched: ModelSchedule, macro: MacroParams = DEFAULT_MACRO
               ) -> tuple[list[LayerCost], FleetCost]:
    costs = [layer_cost(s, msched.fleet, macro) for s in msched.layers]
    return costs, rollup(costs, msched.fleet, macro,
                         digital_ops=msched.digital_ops)


@dataclasses.dataclass(frozen=True)
class ServeReloadCost:
    """Eq. 4 reprogramming charge of serving ``streams`` input streams.

    Every stream through a non-pinned model replays the schedule's weight
    reloads (the fleet holds one working set at a time); pinned models
    amortise programming to zero in steady state, so all fields are 0.
    """

    streams: int
    reprogram_events: int       # schedule events x streams
    reload_bits: int
    reload_energy_j: float      # bits x SRAM write energy (Eq. 4b term)
    reload_s: float             # bits / load-port bandwidth, serialised

    def to_payload(self) -> dict:
        """JSON-safe Eq. 4 figures for telemetry (``repro.obs``) trace
        events — nJ / µs, the natural scale of a per-stream charge."""
        return {"streams": self.streams,
                "reprogram_events": self.reprogram_events,
                "reload_bits": self.reload_bits,
                "reload_energy_nj": self.reload_energy_j * 1e9,
                "reload_us": self.reload_s * 1e6}


def serve_reload_cost(msched: ModelSchedule, streams: int) -> ServeReloadCost:
    """Charge the schedule's reprogram events against ``streams`` decode
    steps / batched-prefill calls (one stream each)."""
    if streams < 0:
        raise ValueError(f"streams must be >= 0, got {streams}")
    bits = msched.total_reload_bits * streams
    fleet = msched.fleet
    return ServeReloadCost(
        streams=streams,
        reprogram_events=msched.total_reprogram_events * streams,
        reload_bits=bits,
        reload_energy_j=bits * fleet.reload_j_per_bit,
        reload_s=bits / fleet.reload_bits_per_s)


@dataclasses.dataclass(frozen=True)
class WaveCost:
    """Eq. 4 roll-up of one serving WINDOW (an admission wave's lifetime,
    or any scheduler-chosen span of input streams).

    The compute side prices every stream at the schedule's per-stream
    unit-op roll-up (Eq. 4b: total unit ops × unit energy); the reload
    side replays the per-stream reprogram charge of a non-pinned schedule
    (:func:`serve_reload_cost`). ``energy_per_token_j`` is the figure the
    traffic lab reports per offered-load point: total wave energy over
    generated tokens — admission waves that fill more slots per stream
    amortise the same stream energy over more tokens, which is exactly
    the continuous-batching win the Eq. 4 model should surface.
    """

    decode_steps: int
    prefill_calls: int
    decode_tokens: int
    compute_energy_j: float
    reload: ServeReloadCost
    latency_s: float            # modelled fleet time for the window

    @property
    def streams(self) -> int:
        return self.decode_steps + self.prefill_calls

    @property
    def energy_j(self) -> float:
        return self.compute_energy_j + self.reload.reload_energy_j

    @property
    def energy_per_token_j(self) -> float:
        return self.energy_j / self.decode_tokens if self.decode_tokens \
            else 0.0


def serve_wave_cost(msched: ModelSchedule, decode_steps: int,
                    prefill_calls: int = 0, decode_tokens: int = 0,
                    macro: MacroParams = DEFAULT_MACRO) -> WaveCost:
    """Price one serving window of ``decode_steps`` + ``prefill_calls``
    input streams on ``msched``'s fleet (Eq. 4 per-wave roll-up)."""
    if decode_steps < 0 or prefill_calls < 0:
        raise ValueError(
            f"negative window: decode_steps={decode_steps}, "
            f"prefill_calls={prefill_calls}")
    streams = decode_steps + prefill_calls
    _, fc = model_cost(msched, macro)
    reload = serve_reload_cost(msched, streams)
    return WaveCost(
        decode_steps=decode_steps, prefill_calls=prefill_calls,
        decode_tokens=decode_tokens,
        # The identity of :func:`rollup` extends stream-wise: N streams'
        # unit ops × unit energy == N × the per-stream product.
        compute_energy_j=fc.compute_energy_j * streams,
        reload=reload,
        # Compute cycles only — the reload term is charged once here, not
        # per layer (FleetCost.latency_s already folds schedule reloads).
        latency_s=fc.cycles / macro.clock_hz * streams + reload.reload_s)
