"""Stage 2 of the macro compiler: schedule tiled layers onto the fleet.

Scheduling model (weight-stationary dataflow, paper Sec. V):

  * a layer's µArray tiles are placed round-robin across macro halves;
  * if the layer needs more tiles than the fleet has slots, it executes in
    *rounds* — load up to ``tile_slots`` tiles, stream every input call
    through them, swap in the next batch of tiles;
  * within a round, macros run in lockstep on independent tiles, so the
    round's critical path is the busiest macro: ``ceil(tiles_r / n_macros)``
    serial tile-passes × ``calls`` input vectors, each pass one Eq. 4 unit
    op of ``W_P·(1+2·A_P)`` cycles;
  * weight loads are counted per tile write; a model whose CIM layers fit
    the fleet simultaneously under a weight-stationary fleet is *pinned*
    (reloads amortise to zero in steady-state serving).

The unit-op convention matches :mod:`repro.core.energy`: one unit op per
(chunk, output-channel, input-call) covering all W_P bitplane evaluations
and the SA-ADC search — 2·M MAC-ops of useful work at 100% column
occupancy. The input-plane (S2) passes share the same pipelined window;
their cost is absorbed in the Eq. 4 calibration (see EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.compiler.tiling import Fleet, TilingPlan, _ceil_div
from repro.core.mapping import (LayerStat, MappingPolicy, MappingReport,
                                plan_mapping)
from repro.core.mf import ExecMode


@dataclasses.dataclass(frozen=True)
class LayerSchedule:
    """Placement + pass structure of one CIM-mapped layer on the fleet."""

    name: str
    plan: TilingPlan
    calls: int             # input vectors streamed through the layer
    rounds: int            # weight-swap rounds (1 = layer fits resident)
    unit_ops: int          # fleet-total Eq. 4 unit operations
    macro_unit_ops: int    # serial unit ops on the busiest macro (crit path)
    reload_bits: int       # µArray weight bits written for this layer
    # Explicit weight-(re)program events in the schedule: one per weight-
    # swap round (the program-time phase of the weight-stationary runtime,
    # executed by repro.compiler.execute.program_layer_tiles). 0 when the
    # layer's tiles are pinned fleet-resident.
    reprogram_events: int = 0

    @property
    def fits_resident(self) -> bool:
        return self.rounds == 1

    @property
    def mac_ops(self) -> int:
        """Useful (unpadded) ops: 2 ops per MAC."""
        return 2 * self.plan.k * self.plan.n * self.calls


def schedule_layer(plan: TilingPlan, fleet: Fleet, *, calls: int = 1,
                   preloaded: bool = False) -> LayerSchedule:
    """Schedule one tiled projection; ``preloaded`` skips the weight write
    (model-level pinning decided by :func:`compile_model`)."""
    if calls < 1:
        raise ValueError(f"calls must be >= 1, got {calls}")
    tiles, slots = plan.n_tiles, fleet.tile_slots
    rounds = _ceil_div(tiles, slots)
    macro_unit_ops = 0
    for r in range(rounds):
        tiles_r = min(slots, tiles - r * slots)
        macro_unit_ops += _ceil_div(tiles_r, fleet.n_macros) * calls
    return LayerSchedule(
        name=plan.name, plan=plan, calls=calls, rounds=rounds,
        unit_ops=tiles * calls, macro_unit_ops=macro_unit_ops,
        reload_bits=0 if preloaded else tiles * fleet.tile_weight_bits,
        reprogram_events=0 if preloaded else rounds)


@dataclasses.dataclass(frozen=True)
class ModelSchedule:
    """A model lowered onto one fleet: CIM layer schedules + digital rest."""

    fleet: Fleet
    layers: tuple[LayerSchedule, ...]
    digital: tuple[LayerStat, ...]
    pinned: bool                     # weights resident across the whole model
    mapping: MappingReport

    @property
    def total_unit_ops(self) -> int:
        return sum(s.unit_ops for s in self.layers)

    @property
    def total_tiles(self) -> int:
        return sum(s.plan.n_tiles for s in self.layers)

    @property
    def digital_ops(self) -> int:
        return sum(s.ops for s in self.digital)

    @property
    def total_reprogram_events(self) -> int:
        """Weight-program events across the model (0 when pinned)."""
        return sum(s.reprogram_events for s in self.layers)

    @property
    def total_reload_bits(self) -> int:
        """µArray weight bits written per input stream (0 when pinned).

        A non-pinned model pays this for EVERY stream it serves: the fleet
        cannot hold the weights across streams, so each decode step (or
        batched-prefill call) replays the full reload — the regime where
        Eq. 4 reload energy dominates (see ``cost.serve_reload_cost``).
        """
        return sum(s.reload_bits for s in self.layers)

    @property
    def rounds_max(self) -> int:
        """Deepest weight-swap round count of any layer."""
        return max((s.rounds for s in self.layers), default=0)


def compile_model(stats: Sequence[LayerStat], fleet: Fleet,
                  policy: Optional[MappingPolicy] = None) -> ModelSchedule:
    """Lower a model's per-layer shapes onto the fleet.

    Layers the (fleet-aware) policy keeps digital — and CIM-eligible layers
    with no recorded (k, n) shape — stay on the digital fabric; the rest
    are tiled and scheduled in declaration order.
    """
    stats = list(stats)
    rep = plan_mapping(stats, policy if policy is not None
                       else fleet.mapping_policy())
    cim, digital = [], []
    for s in stats:
        if rep.assignments[s.name] != ExecMode.REGULAR and s.k and s.n:
            cim.append(s)
        else:
            digital.append(s)

    plans = [fleet.plan(s.k, s.n, name=s.name) for s in cim]
    pinned = (fleet.weight_stationary
              and sum(p.n_tiles for p in plans) <= fleet.tile_slots)
    layers = tuple(
        schedule_layer(p, fleet, calls=s.calls, preloaded=pinned)
        for p, s in zip(plans, cim))
    return ModelSchedule(fleet=fleet, layers=layers, digital=tuple(digital),
                         pinned=pinned, mapping=rep)
