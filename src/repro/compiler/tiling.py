"""Stage 1 of the macro compiler: tile (K, N) projections onto µArrays.

The paper's macro is an 8-row SRAM µArray pair: W_P rows (sign + W_P-1
magnitude bitplanes) by 2·M columns, operated as two independent M-column
halves. One *µArray tile* is therefore the atomic unit of both weight
storage and compute: M contraction columns × 1 output channel × W_P rows,
processed in one Eq. 4 unit operation of T = W_P·(1 + 2·A_P) cycles.

A (K, N) projection decomposes into ``ceil(K/M) × N`` µArray tiles; the
final K-chunk of each output channel zero-pads its unused columns (padded
cells never discharge, so the charge-averaging denominator stays M — same
convention as the behavioural simulator in :mod:`repro.core.cim`).

:class:`TilingPlan` records that decomposition plus the coarser execution
slicing (groups of chunks / output channels evaluated per simulator call);
:class:`Fleet` describes the macro population a model is lowered onto.
"""

from __future__ import annotations

import dataclasses

from repro.core.cim import CimConfig


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class TilingPlan:
    """µArray tiling of one (K, N) projection, plus execution slices.

    ``k_slices``/``n_slices`` are half-open index ranges over the ORIGINAL
    (unpadded) operand; every K-slice except the last spans a whole number
    of M-column chunks, which is what makes tiled execution bit-exact
    against the monolithic simulator (chunk boundaries coincide).
    """

    name: str
    k: int
    n: int
    m_columns: int
    w_bits: int
    k_slices: tuple[tuple[int, int], ...]
    n_slices: tuple[tuple[int, int], ...]

    @property
    def n_chunks(self) -> int:
        """M-column chunks along the contraction dimension."""
        return _ceil_div(self.k, self.m_columns)

    @property
    def k_padded(self) -> int:
        return self.n_chunks * self.m_columns

    @property
    def pad_k(self) -> int:
        """Zero-padded columns in the final chunk of every output channel."""
        return self.k_padded - self.k

    @property
    def waste_fraction(self) -> float:
        """Fraction of occupied µArray cells holding padding zeros."""
        return self.pad_k / self.k_padded

    @property
    def n_tiles(self) -> int:
        """Total µArray tiles (= weight placement slots = unit ops/input)."""
        return self.n_chunks * self.n

    @property
    def weight_bits(self) -> int:
        """SRAM bits to hold the tiled weights (sign + magnitude rows)."""
        return self.n_tiles * self.m_columns * self.w_bits


def _slices(total: int, step: int) -> tuple[tuple[int, int], ...]:
    return tuple((lo, min(lo + step, total)) for lo in range(0, total, step))


def plan_tiling(k: int, n: int, cfg: CimConfig, *, tile_k_chunks: int = 4,
                tile_n: int = 32, name: str = "") -> TilingPlan:
    """Tile a (k, n) projection for macros of geometry ``cfg``.

    tile_k_chunks / tile_n set the *execution* granularity (how many chunks
    and output channels one behavioural-simulator call covers); they do not
    change the µArray tile count or any cost — only loop overhead.
    """
    if k <= 0 or n <= 0:
        raise ValueError(f"degenerate projection ({k}, {n})")
    if tile_k_chunks < 1 or tile_n < 1:
        raise ValueError("execution tile sizes must be >= 1")
    return TilingPlan(
        name=name, k=k, n=n, m_columns=cfg.m_columns, w_bits=cfg.w_bits,
        k_slices=_slices(k, tile_k_chunks * cfg.m_columns),
        n_slices=_slices(n, tile_n))


@dataclasses.dataclass(frozen=True)
class Fleet:
    """A population of identical CIM SRAM macros plus its weight-load port.

    ``halves_per_macro``: the 8×62 macro holds two independent M=31 halves;
    each half stores (and serially processes) one µArray tile at a time.
    ``weight_stationary``: when True, a model whose CIM layers all fit the
    fleet simultaneously keeps weights pinned (no per-inference reloads);
    otherwise — or when capacity is exceeded — tiles are streamed in
    rounds and every tile write is priced and scheduled.
    ``macro``: an optional macro model (``repro.macros``) whose Eq. 4
    cycle/energy hooks price this fleet's unit operations — None keeps
    the source paper's SA-ADC constants. ``fleet_for_macro`` builds the
    matching re-budgeted geometry (flavour ADC area traded for columns
    at fixed macro area) and sets this field in one step.
    """

    n_macros: int = 64
    cfg: CimConfig = dataclasses.field(default_factory=CimConfig)
    halves_per_macro: int = 2
    weight_stationary: bool = True
    reload_j_per_bit: float = 10e-15     # SRAM write energy (~10 fJ/bit @45nm)
    reload_bits_per_s: float = 64e9      # fleet weight-load bandwidth
    macro: object = None                 # Optional[repro.macros.MacroModel]

    @property
    def tile_slots(self) -> int:
        """µArray tiles resident fleet-wide at any instant."""
        return self.n_macros * self.halves_per_macro

    @property
    def tile_weight_bits(self) -> int:
        return self.cfg.m_columns * self.cfg.w_bits

    @property
    def weight_capacity_bits(self) -> int:
        return self.tile_slots * self.tile_weight_bits

    def plan(self, k: int, n: int, *, name: str = "",
             tile_k_chunks: int = 4, tile_n: int = 32) -> TilingPlan:
        return plan_tiling(k, n, self.cfg, tile_k_chunks=tile_k_chunks,
                           tile_n=tile_n, name=name)

    def mapping_policy(self, threshold: float = 2.0, **kw):
        """Fleet-aware mixed-mapping policy (see repro.core.mapping)."""
        from repro.core.mapping import FleetMappingPolicy
        return FleetMappingPolicy(
            threshold=threshold, m_columns=self.cfg.m_columns,
            capacity_tiles=self.tile_slots,
            allow_swap=not self.weight_stationary, **kw)
