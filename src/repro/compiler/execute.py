"""Stage 4 of the macro compiler: bit-exact tiled execution.

Runs a :class:`~repro.compiler.tiling.TilingPlan` tile-group by tile-group
through the behavioural µArray simulator and reproduces the monolithic
``cim_mf_matmul`` output *bit for bit*. Three properties make that hold:

  1. calibration scales are computed once over the FULL operands and shared
     by every tile (quantisation then commutes with slicing);
  2. every K-slice except the last spans whole M-column chunks, so tile
     chunk boundaries coincide with the monolithic chunking and the final
     slice's zero padding is identical;
  3. tiles accumulate :class:`~repro.core.cim.CimPartials` — plane-weighted
     SA-ADC *code* sums, which are integer-valued floats — so float32
     accumulation is exact regardless of tile order, and the single final
     :func:`~repro.core.cim.cim_mf_recombine` applies the same rounding
     sequence as the monolithic path.

(Exactness needs the code sums to stay below 2^24, i.e. K below ~10^5
chunks-worth per output — far beyond any projection in the registry.)
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.compiler.tiling import TilingPlan
from repro.core import quant
from repro.core.cim import (CimConfig, CimPartials, cim_mf_matmul,
                            cim_mf_partials, cim_mf_recombine)


def compiled_matmul(x: jax.Array, w: jax.Array, plan: TilingPlan,
                    cfg: CimConfig,
                    cap_weights: Optional[jax.Array] = None,
                    comparator_offset: Optional[jax.Array] = None
                    ) -> jax.Array:
    """Tiled CIM execution of x:(...,K) (+) w:(K,N) under ``plan``.

    ``comparator_offset`` must be a scalar (a per-element offset would not
    slice consistently across tiles). Output is bit-exact with
    ``cim_mf_matmul(x, w, cfg, cap_weights, comparator_offset)``.
    """
    K, N = w.shape
    if (plan.k, plan.n) != (K, N):
        raise ValueError(f"plan is for ({plan.k}, {plan.n}), operands are "
                         f"({K}, {N})")
    if plan.m_columns != cfg.m_columns or plan.w_bits != cfg.w_bits:
        raise ValueError("plan geometry does not match CimConfig")
    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, K)

    sw = quant.calibrate_scale(w, cfg.w_bits)
    sx = quant.calibrate_scale(x2, cfg.x_bits)

    s1_cols, s2_cols, rw_cols = [], [], []
    rxc = None
    for (n0, n1) in plan.n_slices:
        acc: Optional[CimPartials] = None
        for (k0, k1) in plan.k_slices:
            caps = None if cap_weights is None else cap_weights[k0:k1]
            p = cim_mf_partials(x2[:, k0:k1], w[k0:k1, n0:n1], cfg, sw, sx,
                                caps, comparator_offset)
            acc = p if acc is None else acc + p
        s1_cols.append(acc.s1c)
        s2_cols.append(acc.s2c)
        rw_cols.append(acc.r_w)
        if rxc is None:
            rxc = acc.rxc    # the |x| dummy-row residue has no N dependence

    parts = CimPartials(jnp.concatenate(s1_cols, axis=-1),
                        jnp.concatenate(s2_cols, axis=-1),
                        rxc, jnp.concatenate(rw_cols, axis=-1))
    y = cim_mf_recombine(parts, sw, sx, cfg)
    return y.reshape(batch_shape + (N,)).astype(x.dtype)


def verify_bit_exact(x: jax.Array, w: jax.Array, plan: TilingPlan,
                     cfg: CimConfig,
                     cap_weights: Optional[jax.Array] = None,
                     comparator_offset: Optional[jax.Array] = None) -> bool:
    """True iff tiled and monolithic executions agree on every bit."""
    import numpy as np
    tiled = compiled_matmul(x, w, plan, cfg, cap_weights, comparator_offset)
    mono = cim_mf_matmul(x, w, cfg, cap_weights, comparator_offset)
    return bool(np.array_equal(np.asarray(tiled), np.asarray(mono)))
