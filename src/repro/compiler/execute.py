"""Stage 4 of the macro compiler: bit-exact tiled execution.

Runs a :class:`~repro.compiler.tiling.TilingPlan` tile-group by tile-group
through the behavioural µArray simulator and reproduces the monolithic
``cim_mf_matmul`` output *bit for bit*. Three properties make that hold:

  1. calibration scales are computed once over the FULL operands and shared
     by every tile (quantisation then commutes with slicing);
  2. every K-slice except the last spans whole M-column chunks, so tile
     chunk boundaries coincide with the monolithic chunking and the final
     slice's zero padding is identical;
  3. tiles accumulate :class:`~repro.core.cim.CimPartials` — plane-weighted
     SA-ADC *code* sums, which are integer-valued floats — so float32
     accumulation is exact regardless of tile order, and the single final
     :func:`~repro.core.cim.cim_mf_recombine` applies the same rounding
     sequence as the monolithic path.

(Exactness needs the code sums to stay below 2^24, i.e. K below ~10^5
chunks-worth per output — far beyond any projection in the registry.)

The weight-stationary split lives here too: :func:`program_layer_tiles`
freezes every tile's weight state once (the schedule's reprogram events),
and :func:`compiled_matmul_programmed` streams inputs through those
programmed slices doing only step-time work — bit-exact against both
on-the-fly paths.
"""
# repro-lint: module=exactness-critical

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.compiler.tiling import TilingPlan
from repro.core import quant
from repro.core.cim import (CimConfig, CimPartials, ProjectionSilicon,
                            cim_input_partials, cim_mf_matmul,
                            cim_mf_partials, cim_mf_recombine)
from repro.core.programmed import (ProgrammedLayer, default_static_sx,
                                   program_macro, unpack_weight_state)


def compiled_matmul(x: jax.Array, w: jax.Array, plan: TilingPlan,
                    cfg: CimConfig,
                    cap_weights: Optional[jax.Array] = None,
                    comparator_offset: Optional[jax.Array] = None
                    ) -> jax.Array:
    """Tiled CIM execution of x:(...,K) (+) w:(K,N) under ``plan``.

    ``comparator_offset`` must be a scalar (a per-element offset would not
    slice consistently across tiles). Output is bit-exact with
    ``cim_mf_matmul(x, w, cfg, cap_weights, comparator_offset)``.
    """
    K, N = w.shape
    if (plan.k, plan.n) != (K, N):
        raise ValueError(f"plan is for ({plan.k}, {plan.n}), operands are "
                         f"({K}, {N})")
    if plan.m_columns != cfg.m_columns or plan.w_bits != cfg.w_bits:
        raise ValueError("plan geometry does not match CimConfig")
    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, K)

    sw = quant.calibrate_scale(w, cfg.w_bits)
    sx = quant.calibrate_scale(x2, cfg.x_bits)

    s1_cols, s2_cols, rw_cols = [], [], []
    rxc = None
    for (n0, n1) in plan.n_slices:
        acc: Optional[CimPartials] = None
        for (k0, k1) in plan.k_slices:
            caps = None if cap_weights is None else cap_weights[k0:k1]
            p = cim_mf_partials(x2[:, k0:k1], w[k0:k1, n0:n1], cfg, sw, sx,
                                caps, comparator_offset)
            acc = p if acc is None else acc + p
        s1_cols.append(acc.s1c)
        s2_cols.append(acc.s2c)
        rw_cols.append(acc.r_w)
        if rxc is None:
            rxc = acc.rxc    # the |x| dummy-row residue has no N dependence

    parts = CimPartials(jnp.concatenate(s1_cols, axis=-1),
                        jnp.concatenate(s2_cols, axis=-1),
                        rxc, jnp.concatenate(rw_cols, axis=-1))
    y = cim_mf_recombine(parts, sw, sx, cfg)
    return y.reshape(batch_shape + (N,)).astype(x.dtype)


def program_layer_tiles(w: jax.Array, plan: TilingPlan, cfg: CimConfig, *,
                        sx=None, sw=None) -> ProgrammedLayer:
    """Program one tiled projection: per-tile frozen weight-state slices.

    Each (n-slice, k-slice) tile gets its own :class:`ProgrammedMacro`
    programmed with the LAYER-GLOBAL scales, so tile boundaries commute
    with quantisation exactly as in the on-the-fly tiled path. In the
    scheduled fleet these tile writes are the reprogram events
    (:attr:`~repro.compiler.schedule.LayerSchedule.reprogram_events`) —
    a weight-swap round re-runs this for the incoming tile batch.
    """
    K, N = w.shape
    if (plan.k, plan.n) != (K, N):
        raise ValueError(f"plan is for ({plan.k}, {plan.n}), operands are "
                         f"({K}, {N})")
    if plan.m_columns != cfg.m_columns or plan.w_bits != cfg.w_bits:
        raise ValueError("plan geometry does not match CimConfig")
    if sw is None:
        sw = quant.calibrate_scale(w, cfg.w_bits)
    if sx is None:
        sx = default_static_sx(cfg)
    # Tiled step-time execution accumulates CimPartials, i.e. the plane-
    # level einsum path — program that state regardless of cfg.use_kernel
    # (and skip the lossless collapse: tiles must expose raw partials).
    tile_cfg = dataclasses.replace(cfg, use_kernel=False)
    tiles = tuple(
        tuple(program_macro(w[k0:k1, n0:n1], tile_cfg, sx=sx, sw=sw,
                            prefer_lossless=False)
              for (k0, k1) in plan.k_slices)
        for (n0, n1) in plan.n_slices)
    return ProgrammedLayer(sw=jnp.asarray(sw, jnp.float32),
                           sx=jnp.asarray(sx, jnp.float32), tiles=tiles)


def compiled_matmul_programmed(x: jax.Array, prog: ProgrammedLayer,
                               plan: TilingPlan, cfg: CimConfig,
                               cap_weights: Optional[jax.Array] = None,
                               comparator_offset: Optional[jax.Array] = None,
                               silicon: Optional[ProjectionSilicon] = None
                               ) -> jax.Array:
    """Step-time tiled execution against programmed tile slices.

    Bit-exact with :func:`compiled_matmul` (and hence with the monolithic
    paths) when ``prog`` was programmed with the same scales — only the
    input-side work runs per call. ``silicon`` threads the projection's
    per-tile ADC instances (``repro.silicon``): each execution slice
    digitises with the instances of exactly the tiles it covers, so the
    tiled result matches the monolithic silicon route bit for bit.
    """
    K, N = plan.k, plan.n
    if len(prog.tiles) != len(plan.n_slices) or any(
            len(row) != len(plan.k_slices) for row in prog.tiles):
        raise ValueError("programmed tiles do not match the plan's slicing")
    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, K)

    s1_cols, s2_cols, rw_cols = [], [], []
    rxc = None
    for row, (n0, n1) in zip(prog.tiles, plan.n_slices):
        acc: Optional[CimPartials] = None
        for tile, (k0, k1) in zip(row, plan.k_slices):
            caps = None if cap_weights is None else cap_weights[k0:k1]
            sil = None if silicon is None else \
                silicon.slice(n0, n1, k0, k1, cfg.m_columns)
            p = cim_input_partials(x2[:, k0:k1],
                                   unpack_weight_state(tile.state, cfg),
                                   cfg, prog.sx, caps, comparator_offset,
                                   sil)
            acc = p if acc is None else acc + p
        s1_cols.append(acc.s1c)
        s2_cols.append(acc.s2c)
        rw_cols.append(acc.r_w)
        if rxc is None:
            rxc = acc.rxc    # the |x| dummy-row residue has no N dependence

    parts = CimPartials(jnp.concatenate(s1_cols, axis=-1),
                        jnp.concatenate(s2_cols, axis=-1),
                        rxc, jnp.concatenate(rw_cols, axis=-1))
    y = cim_mf_recombine(parts, prog.sw, prog.sx, cfg)
    return y.reshape(batch_shape + (N,)).astype(x.dtype)


def verify_bit_exact(x: jax.Array, w: jax.Array, plan: TilingPlan,
                     cfg: CimConfig,
                     cap_weights: Optional[jax.Array] = None,
                     comparator_offset: Optional[jax.Array] = None) -> bool:
    """True iff tiled and monolithic executions agree on every bit."""
    import numpy as np
    tiled = compiled_matmul(x, w, plan, cfg, cap_weights, comparator_offset)
    mono = cim_mf_matmul(x, w, cfg, cap_weights, comparator_offset)
    return bool(np.array_equal(np.asarray(tiled), np.asarray(mono)))
