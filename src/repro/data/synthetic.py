"""Deterministic synthetic data pipeline.

Stateless by construction: batch(step) is a pure function of
(seed, step, shape), so a restarted job regenerates exactly the batches it
would have seen — checkpoints need no data-reader state. Per-host sharding
takes `host_index/host_count` slices of the global batch, matching how a
multi-host pod feeds its addressable devices.

Tasks:
  * 'uniform'  — i.i.d. tokens (throughput/dry-run fodder)
  * 'copy'     — second half of the sequence repeats the first half;
                 learnable, used by examples/tests to show loss decrease.
  * 'images'   — synthetic MNIST/CIFAR-like class-conditional blobs for
                 the paper's convnets (separable => accuracy can rise).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    task: str = "copy"
    host_index: int = 0
    host_count: int = 1


def _rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, 0xD5EED]))


def lm_batch(cfg: DataConfig, step: int) -> dict:
    """Global batch for `step`, sliced to this host."""
    rng = _rng(cfg, step)
    b, t, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    if cfg.task == "uniform":
        tokens = rng.integers(0, v, (b, t + 1), dtype=np.int32)
    elif cfg.task == "copy":
        half = (t + 1) // 2 + 1
        first = rng.integers(0, v, (b, half), dtype=np.int32)
        tokens = np.concatenate([first, first], axis=1)[:, :t + 1]
    else:
        raise ValueError(cfg.task)
    lo = cfg.host_index * b // cfg.host_count
    hi = (cfg.host_index + 1) * b // cfg.host_count
    return {"tokens": tokens[lo:hi, :-1],
            "targets": tokens[lo:hi, 1:].astype(np.int32)}


def lm_batches(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield lm_batch(cfg, step)
        step += 1


def image_batch(n: int, n_classes: int, hw: int, channels: int, step: int,
                seed: int = 0, noise: float = 0.35) -> tuple[np.ndarray,
                                                             np.ndarray]:
    """Class-conditional Gaussian-blob images: linearly separable-ish.

    Each class has a fixed random template; samples are template + noise.
    Accuracy well above chance is reachable by a small net in a few
    hundred steps — the harness for the paper-model training examples.
    """
    tmpl_rng = np.random.default_rng(np.random.SeedSequence([seed, 0xA11CE]))
    templates = tmpl_rng.normal(0, 1, (n_classes, hw, hw, channels)
                                ).astype(np.float32)
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 0x1417]))
    labels = rng.integers(0, n_classes, (n,))
    x = templates[labels] + noise * rng.normal(0, 1, (n, hw, hw, channels)
                                               ).astype(np.float32)
    return x.astype(np.float32), labels.astype(np.int32)


class Prefetcher:
    """One-step lookahead prefetch on a background thread."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        import queue
        import threading
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def work():
            for item in it:
                if self._stop.is_set():
                    return
                self._q.put(item)

        self._t = threading.Thread(target=work, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
