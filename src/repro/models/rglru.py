"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block structure (Griffin recurrent block):

    x -> [linear -> conv1d(w=4) -> RG-LRU] (.) [linear -> GeLU] -> linear

RG-LRU (real-gated linear recurrent unit), per channel:

    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(c * softplus(Lambda) * (-r_t))  in (0,1),  c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is diagonal-linear in h, so prefill/train uses
`jax.lax.associative_scan` (O(log T) depth — this is what makes the 500k
shape practical) and decode is a single O(1) state update.

The in/out/gate projections are MF-able weight-activation products; the
elementwise recurrence itself has no weight matmul and stays in the
typical operator (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.mf import ExecMode
from repro.models import blocks

_C = 8.0


def rglru_init(key: jax.Array, d_model: int, width: int, conv_width: int,
               *, mf: bool, dtype: Any = jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    p = {
        "in_x": blocks.proj_init(ks[0], d_model, width, bias=False, mf=mf,
                                 dtype=dtype),
        "in_gate": blocks.proj_init(ks[1], d_model, width, bias=False, mf=mf,
                                    dtype=dtype),
        "out": blocks.proj_init(ks[2], width, d_model, bias=False, mf=mf,
                                dtype=dtype),
        "conv_w": (jax.random.normal(ks[3], (conv_width, width))
                   * (1.0 / math.sqrt(conv_width))).astype(dtype),
        "conv_b": jnp.zeros((width,), dtype),
        # gates are small diagonal-ish projections; keep digital (f32)
        "w_a": (jax.random.normal(ks[4], (width, width)) * (1.0 / math.sqrt(
            width))).astype(dtype),
        "b_a": jnp.zeros((width,), jnp.float32),
        "w_x": (jax.random.normal(ks[5], (width, width)) * (1.0 / math.sqrt(
            width))).astype(dtype),
        "b_x": jnp.zeros((width,), jnp.float32),
        # Lambda init so a^c in [0.9, 0.999] at r=1 (Griffin appendix)
        "lam": jnp.log(jnp.expm1(-jnp.log(
            jnp.linspace(0.9, 0.999, width)) / _C)).astype(jnp.float32),
    }
    return p


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. x: (B,T,C), w: (W,C). Returns (y, new_state).

    state: (B, W-1, C) trailing inputs from the previous segment.
    """
    wlen = w.shape[0]
    bsz, t, c = x.shape
    if state is None:
        state = jnp.zeros((bsz, wlen - 1, c), x.dtype)
    xin = jnp.concatenate([state, x], axis=1)
    y = sum(xin[:, i:i + t, :] * w[i] for i in range(wlen)) + b
    return y.astype(x.dtype), xin[:, -(wlen - 1):, :]


def _rglru_gates(p: dict, xc: jax.Array) -> tuple[jax.Array, jax.Array]:
    """a_t (decay) and gated input for the linear recurrence."""
    r = jax.nn.sigmoid(xc.astype(jnp.float32) @ p["w_a"].astype(jnp.float32)
                       + p["b_a"])
    i = jax.nn.sigmoid(xc.astype(jnp.float32) @ p["w_x"].astype(jnp.float32)
                       + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * xc.astype(jnp.float32))
    return a, gated


def rglru_scan(p: dict, xc: jax.Array, h0: Optional[jax.Array] = None
               ) -> tuple[jax.Array, jax.Array]:
    """Parallel prefix over time. xc: (B,T,C) -> (h_seq, h_last)."""
    a, gated = _rglru_gates(p, xc)
    if h0 is not None:
        # fold carried state in as a virtual step 0
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        gated = jnp.concatenate([h0[:, None].astype(gated.dtype), gated],
                                axis=1)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h.astype(xc.dtype), h[:, -1]


def rglru_step(p: dict, xc: jax.Array, h_prev: jax.Array
               ) -> tuple[jax.Array, jax.Array]:
    """Single decode step. xc: (B,1,C); h_prev: (B,C)."""
    a, gated = _rglru_gates(p, xc)
    h = a[:, 0] * h_prev.astype(jnp.float32) + gated[:, 0]
    return h[:, None].astype(xc.dtype), h


def rglru_block_apply(p: dict, x: jax.Array, *,
                      mode: ExecMode | str = ExecMode.REGULAR,
                      state: Optional[dict] = None, **kw
                      ) -> tuple[jax.Array, Optional[dict]]:
    """Full Griffin recurrent block. state holds {'conv', 'h'} for decode."""
    xb = blocks.proj_apply(p["in_x"], x, mode, **kw)
    gate = jax.nn.gelu(blocks.proj_apply(p["in_gate"], x, mode, **kw))
    if state is None:
        xc, _ = _causal_conv(xb, p["conv_w"], p["conv_b"])
        h, _ = rglru_scan(p, xc)
        new_state = None
    else:
        xc, conv_state = _causal_conv(xb, p["conv_w"], p["conv_b"],
                                      state["conv"])
        h, h_last = rglru_step(p, xc, state["h"])
        new_state = {"conv": conv_state, "h": h_last}
    y = blocks.proj_apply(p["out"], h * gate, mode, **kw)
    return y, new_state


def rglru_init_state(batch: int, width: int, conv_width: int,
                     dtype: Any = jnp.bfloat16) -> dict:
    return {"conv": jnp.zeros((batch, conv_width - 1, width), dtype),
            "h": jnp.zeros((batch, width), jnp.float32)}
