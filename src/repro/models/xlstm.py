"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Per arXiv:2405.04517, simplified to the recurrences' essentials:

mLSTM (parallelisable; here chunk-free recurrent-scan form):
    C_t = f_t * C_{t-1} + i_t * (v_t k_t^T)      per head, C: (dh, dh)
    n_t = f_t * n_{t-1} + i_t * k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)
with exponential input gate stabilised in log space via m_t:
    m_t = max(log f_t + m_{t-1}, log i_t)
    i'_t = exp(log i_t - m_t),  f'_t = exp(log f_t + m_{t-1} - m_t)

sLSTM (sequential): scalar memory per channel with recurrent kernel R:
    z = tanh(Wz x + Rz h),  i = exp(Wi x + Ri h),  f = exp(Wf x + Rf h)
    o = sigmoid(Wo x + Ro h); c = f*c + i*z; n = f*n + i; h = o * c/n
(stabilised identically via a running max m).

Block wrappers follow the paper: pre-LN, up-projection (factor 2),
causal conv(4) on the recurrent path, gated output, down-projection.
q/k/v/gate/up/down projections are MF-able; the state updates are
elementwise/outer-product ops with no weight matmul and stay typical
(DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.mf import ExecMode
from repro.models import blocks
from repro.models.rglru import _causal_conv


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key: jax.Array, d_model: int, n_heads: int, *, mf: bool,
               conv_width: int = 4, dtype: Any = jnp.float32) -> dict:
    d_inner = 2 * d_model
    ks = jax.random.split(key, 8)
    mk = lambda k, i, o: blocks.proj_init(k, i, o, bias=False, mf=mf,
                                          dtype=dtype)
    return {
        "norm": blocks.layernorm_init(d_model, dtype),
        "up": mk(ks[0], d_model, d_inner),
        "gate": mk(ks[1], d_model, d_inner),
        "q": mk(ks[2], d_inner, d_inner),
        "k": mk(ks[3], d_inner, d_inner),
        "v": mk(ks[4], d_inner, d_inner),
        "igate": blocks.proj_init(ks[5], d_inner, n_heads, bias=True,
                                  mf=False, dtype=jnp.float32),
        "fgate": blocks.proj_init(ks[6], d_inner, n_heads, bias=True,
                                  mf=False, dtype=jnp.float32),
        "conv_w": (jax.random.normal(ks[7], (conv_width, d_inner))
                   * (1.0 / math.sqrt(conv_width))).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "out_norm": blocks.rmsnorm_init(d_inner, dtype),
        "down": mk(jax.random.fold_in(key, 99), d_inner, d_model),
    }


def _mlstm_cell_scan(q, k, v, log_i, log_f, state=None):
    """q/k/v: (B,T,H,dh); log gates: (B,T,H). Recurrent lax.scan over T."""
    b, t, h, dh = q.shape
    scale = 1.0 / math.sqrt(dh)
    if state is None:
        c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h), -jnp.inf, jnp.float32)
    else:
        c0, n0, m0 = state["c"], state["n"], state["m"]

    def step(carry, inp):
        c, n, m = carry
        qt, kt, vt, li, lf = inp            # (B,H,dh), ..., (B,H)
        m_new = jnp.maximum(lf + m, li)
        i_ = jnp.exp(li - m_new)
        f_ = jnp.exp(lf + m - m_new)
        # first step: m == -inf -> f_ = exp(-inf)=0, i_ = exp(0)=1 at max
        f_ = jnp.where(jnp.isfinite(m), f_, 0.0)[..., None]
        i_ = i_[..., None]
        kt = kt * scale
        c = f_[..., None] * c + (i_[..., None] * vt[..., :, None]
                                 * kt[..., None, :])
        n = f_ * n + i_ * kt
        num = jnp.einsum("bhij,bhj->bhi", c, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, qt)), 1.0)
        h_t = num / den[..., None]
        return (c, n, m_new), h_t

    xs = (jnp.moveaxis(q, 1, 0).astype(jnp.float32),
          jnp.moveaxis(k, 1, 0).astype(jnp.float32),
          jnp.moveaxis(v, 1, 0).astype(jnp.float32),
          jnp.moveaxis(log_i, 1, 0), jnp.moveaxis(log_f, 1, 0))
    (c, n, m), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    return jnp.moveaxis(hs, 0, 1), {"c": c, "n": n, "m": m}


def mlstm_apply(p: dict, x: jax.Array, n_heads: int, *,
                mode: ExecMode | str = ExecMode.REGULAR,
                state: Optional[dict] = None, **kw
                ) -> tuple[jax.Array, Optional[dict]]:
    b, t, d = x.shape
    xn = blocks.layernorm(p["norm"], x)
    up = blocks.proj_apply(p["up"], xn, mode, **kw)
    gate = blocks.proj_apply(p["gate"], xn, mode, **kw)
    conv_state = None if state is None else state["conv"]
    xc, new_conv = _causal_conv(up, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    d_inner = up.shape[-1]
    dh = d_inner // n_heads
    split = lambda v: v.reshape(b, t, n_heads, dh)
    q = split(blocks.proj_apply(p["q"], xc, mode, **kw))
    k = split(blocks.proj_apply(p["k"], xc, mode, **kw))
    v = split(blocks.proj_apply(p["v"], up, mode, **kw))
    log_i = blocks.proj_apply(p["igate"], xc.astype(jnp.float32))
    log_f = jax.nn.log_sigmoid(
        blocks.proj_apply(p["fgate"], xc.astype(jnp.float32)))
    cell_state = None if state is None else state["cell"]
    h, new_cell = _mlstm_cell_scan(q, k, v, log_i, log_f, cell_state)
    h = h.reshape(b, t, d_inner).astype(x.dtype)
    h = blocks.rmsnorm(p["out_norm"], h) * jax.nn.silu(gate)
    y = blocks.proj_apply(p["down"], h, mode, **kw)
    new_state = None if state is None else {"conv": new_conv,
                                            "cell": new_cell}
    return y, new_state


def mlstm_init_state(batch: int, d_model: int, n_heads: int,
                     conv_width: int = 4, dtype: Any = jnp.bfloat16) -> dict:
    d_inner = 2 * d_model
    dh = d_inner // n_heads
    return {
        "conv": jnp.zeros((batch, conv_width - 1, d_inner), dtype),
        "cell": {"c": jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
                 "n": jnp.zeros((batch, n_heads, dh), jnp.float32),
                 "m": jnp.full((batch, n_heads), -jnp.inf, jnp.float32)},
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key: jax.Array, d_model: int, n_heads: int, *, mf: bool,
               dtype: Any = jnp.float32) -> dict:
    ks = jax.random.split(key, 10)
    mk = lambda k, i, o, b=False: blocks.proj_init(k, i, o, bias=b, mf=mf,
                                                   dtype=dtype)
    dh = d_model // n_heads
    # recurrent kernels are block-diagonal per head: (H, dh, dh)
    rk = lambda k: (jax.random.normal(k, (n_heads, dh, dh))
                    * (1.0 / math.sqrt(dh))).astype(jnp.float32)
    p = {
        "norm": blocks.layernorm_init(d_model, dtype),
        "wz": mk(ks[0], d_model, d_model), "rz": rk(ks[1]),
        "wi": mk(ks[2], d_model, d_model), "ri": rk(ks[3]),
        "wf": mk(ks[4], d_model, d_model), "rf": rk(ks[5]),
        "wo": mk(ks[6], d_model, d_model), "ro": rk(ks[7]),
        "out_norm": blocks.rmsnorm_init(d_model, dtype),
        "up": mk(ks[8], d_model, (4 * d_model) // 3),
        "down": mk(ks[9], (4 * d_model) // 3, d_model),
    }
    return p


def _slstm_scan(p: dict, zx, ix, fx, ox, n_heads: int, state=None):
    """Sequential scan. zx/ix/fx/ox: (B,T,D) pre-activations from x."""
    b, t, d = zx.shape
    dh = d // n_heads
    if state is None:
        h0 = jnp.zeros((b, d), jnp.float32)
        c0 = jnp.zeros((b, d), jnp.float32)
        n0 = jnp.zeros((b, d), jnp.float32)
        m0 = jnp.full((b, d), -jnp.inf, jnp.float32)
    else:
        h0, c0, n0, m0 = state["h"], state["c"], state["n"], state["m"]

    def rmm(h, r):  # block-diagonal recurrent matmul
        hh = h.reshape(b, n_heads, dh)
        return jnp.einsum("bhi,hij->bhj", hh, r).reshape(b, d)

    def step(carry, inp):
        h, c, n, m = carry
        zt, it, ft, ot = inp
        z = jnp.tanh(zt + rmm(h, p["rz"]))
        li = it + rmm(h, p["ri"])
        lf = jax.nn.log_sigmoid(ft + rmm(h, p["rf"]))
        o = jax.nn.sigmoid(ot + rmm(h, p["ro"]))
        m_new = jnp.maximum(lf + m, li)
        i_ = jnp.exp(li - m_new)
        f_ = jnp.where(jnp.isfinite(m), jnp.exp(lf + m - m_new), 0.0)
        c = f_ * c + i_ * z
        n = f_ * n + i_
        h_new = o * c / jnp.maximum(n, 1.0)
        return (h_new, c, n, m_new), h_new

    xs = tuple(jnp.moveaxis(v, 1, 0).astype(jnp.float32)
               for v in (zx, ix, fx, ox))
    (h, c, n, m), hs = jax.lax.scan(step, (h0, c0, n0, m0), xs)
    return jnp.moveaxis(hs, 0, 1), {"h": h, "c": c, "n": n, "m": m}


def slstm_apply(p: dict, x: jax.Array, n_heads: int, *,
                mode: ExecMode | str = ExecMode.REGULAR,
                state: Optional[dict] = None, **kw
                ) -> tuple[jax.Array, Optional[dict]]:
    b, t, d = x.shape
    xn = blocks.layernorm(p["norm"], x)
    zx = blocks.proj_apply(p["wz"], xn, mode, **kw)
    ix = blocks.proj_apply(p["wi"], xn, mode, **kw)
    fx = blocks.proj_apply(p["wf"], xn, mode, **kw)
    ox = blocks.proj_apply(p["wo"], xn, mode, **kw)
    cell_state = None if state is None else state["cell"]
    h, new_cell = _slstm_scan(p, zx, ix, fx, ox, n_heads, cell_state)
    h = blocks.rmsnorm(p["out_norm"], h.astype(x.dtype))
    # position-wise GLU-free FFN (proj factor 4/3)
    y = blocks.proj_apply(p["down"],
                          jax.nn.gelu(blocks.proj_apply(p["up"], h, mode,
                                                        **kw)), mode, **kw)
    new_state = None if state is None else {"cell": new_cell}
    return y, new_state


def slstm_init_state(batch: int, d_model: int) -> dict:
    z = jnp.zeros((batch, d_model), jnp.float32)
    return {"cell": {"h": z, "c": z, "n": z,
                     "m": jnp.full((batch, d_model), -jnp.inf, jnp.float32)}}
