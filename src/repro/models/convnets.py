"""The paper's own evaluation networks (Sec. III / Fig. 9).

  * LeNet-5 for MNIST: 2 conv + 2 FC, max-pooling. Mixed config: conv1,
    conv2, fc1 MF; fc2 (classifier) typical — 98.6% in the paper.
  * CIFAR10 CNN: 5 conv + 2 FC with batch-norm-free GN-ish normalisation
    (we use per-channel scale after conv; the paper's BN folds into
    inference weights). Mixed: convs MF, FCs typical — 90.2%.
  * MobileNetV2 (CIFAR100): inverted-residual bottlenecks; mixed config
    makes the bottleneck (BN1-BN7) blocks MF, stem/final conv + FC typical
    — 66.9%.

Every conv/fc accepts an ExecMode so the same network runs as
'regular' (digital), 'mf'/'mf_kernel' (the proposed operator), or
'cim_sim' (bitplane + SA-ADC hardware emulation) — that triple is exactly
the paper's Table I / Fig. 9 comparison axis. Per-layer (params, ops)
stats feed the Fig. 9 mapping tables.
"""

from __future__ import annotations

import math
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.calib import tap as _calib_tap
from repro.core.cim import CimConfig
from repro.core.mapping import LayerStat
from repro.core.mf import ExecMode, mf_conv2d, mf_correlate_ref
from repro.core import cim as cim_mod
from repro.models import blocks


def conv_init(key: jax.Array, kh: int, kw: int, cin: int, cout: int, *,
              mf: bool, dtype: Any = jnp.float32) -> dict:
    fan_in = kh * kw * cin
    p = {"w": (jax.random.normal(key, (kh, kw, cin, cout))
               * math.sqrt(2.0 / fan_in)).astype(dtype),
         "b": jnp.zeros((cout,), dtype)}
    if mf:
        p["alpha"] = jnp.full((cout,), 1.0 / math.sqrt(2.0 * fan_in), dtype)
    return p


def conv_apply(p: dict, x: jax.Array, mode: ExecMode | str, *,
               stride: tuple[int, int] = (1, 1), padding: str = "SAME",
               groups: int = 1, cim_cfg: Optional[CimConfig] = None
               ) -> jax.Array:
    mode = ExecMode(mode)
    w = p["w"]
    if (_calib_tap.stats_active() and mode != ExecMode.REGULAR
            and groups == 1 and "obs_id" in p):
        # The CIM operand is the im2col patch matrix; patches are copies
        # of x entries (plus SAME-padding zeros), so record the patches
        # the input DAC will actually quantise.
        kh, kw_, cin, _ = w.shape
        patches = jax.lax.conv_general_dilated_patches(
            x, (kh, kw_), stride, padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        _calib_tap.record_activation(p["obs_id"],
                                     patches.reshape(-1, cin * kh * kw_))
    if mode == ExecMode.BNN:
        # binarized weights, straight-through gradient (Table I baseline)
        from repro.core.mf import hw_sign
        wq = w + jax.lax.stop_gradient(hw_sign(w) - w)
        y = jax.lax.conv_general_dilated(
            x, wq, stride, padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups)
    elif mode == ExecMode.REGULAR:
        y = jax.lax.conv_general_dilated(
            x, w, stride, padding, dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups)
    elif groups > 1:
        # depthwise conv: per-channel correlation via patches
        y = _depthwise_mf(p, x, w, stride, padding, mode, cim_cfg)
    elif mode in (ExecMode.MF, ExecMode.MF_KERNEL):
        y = mf_conv2d(x, w, stride=stride, padding=padding)
    else:  # CIM_SIM
        from repro.core.programmed import conv_weight_matrix
        kh, kw_, cin, cout = w.shape
        patches = jax.lax.conv_general_dilated_patches(
            x, (kh, kw_), stride, padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        w2 = conv_weight_matrix(w)
        b, oh, ow, _ = patches.shape
        flat = patches.reshape(-1, cin * kh * kw_)
        prog = p.get("prog")
        if prog is not None:
            # Weight-stationary: program_weights programmed this same
            # conv_weight_matrix operand once — step-time input work only.
            from repro.core.programmed import cim_mf_matmul_programmed
            y = cim_mf_matmul_programmed(flat, prog,
                                         cim_cfg or CimConfig(),
                                         silicon=p.get("sil"),
                                         silicon_kernel=p.get("silk"))
        else:
            y = cim_mod.cim_mf_matmul_ste(flat, w2, cim_cfg or CimConfig())
        if _calib_tap.error_active():
            _calib_tap.record_projection_error(
                p.get("obs_id"), y, mf_correlate_ref(flat, w2, hw=True))
        y = y.reshape(b, oh, ow, cout)
    if mode != ExecMode.REGULAR and "alpha" in p:
        y = y * p["alpha"]
    return y + p["b"]


def _depthwise_mf(p, x, w, stride, padding, mode, cim_cfg):
    """Depthwise conv under the MF operator (per-channel patches)."""
    kh, kw_, cin_per_g, cmul = w.shape[0], w.shape[1], 1, w.shape[3]
    c = x.shape[-1]
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw_), stride, padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    b, oh, ow, _ = patches.shape
    # feature dim ordered (C, kh*kw)
    pt = patches.reshape(b * oh * ow, c, kh * kw_)
    wv = w.reshape(kh * kw_, c).T                     # (C, kh*kw)
    y = jnp.sum(jnp.sign(pt) * jnp.abs(wv)[None]
                + jnp.abs(pt) * jnp.sign(wv)[None], axis=-1)
    return y.reshape(b, oh, ow, c)


def fc_init(key: jax.Array, din: int, dout: int, *, mf: bool,
            dtype: Any = jnp.float32) -> dict:
    return blocks.proj_init(key, din, dout, bias=True, mf=mf, dtype=dtype)


def fc_apply(p: dict, x: jax.Array, mode: ExecMode | str,
             cim_cfg: Optional[CimConfig] = None) -> jax.Array:
    return blocks.proj_apply(p, x, mode, cim_cfg=cim_cfg)


def maxpool(x: jax.Array, k: int = 2) -> jax.Array:
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, k, k, 1), "VALID")


def norm_scale_init(c: int, dtype=jnp.float32) -> dict:
    return {"g": jnp.ones((c,), dtype), "b": jnp.zeros((c,), dtype)}


def norm_scale(p: dict, x: jax.Array) -> jax.Array:
    # inference-style folded BN: per-channel affine after normalising over
    # batch+space statistics (train-mode batch statistics).
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * p["g"] + p["b"]


# ---------------------------------------------------------------------------
# LeNet-5 (MNIST)
# ---------------------------------------------------------------------------

LENET_LAYERS = ("conv1", "conv2", "fc1", "fc2")


def lenet_init(key: jax.Array, mf_layers: Sequence[str] = LENET_LAYERS[:3],
               dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    mf = lambda n: n in mf_layers
    return {
        "conv1": conv_init(ks[0], 5, 5, 1, 6, mf=mf("conv1"), dtype=dtype),
        "conv2": conv_init(ks[1], 5, 5, 6, 16, mf=mf("conv2"), dtype=dtype),
        "fc1": fc_init(ks[2], 16 * 7 * 7, 120, mf=mf("fc1"), dtype=dtype),
        "fc2": fc_init(ks[3], 120, 10, mf=mf("fc2"), dtype=dtype),
    }


def lenet_apply(params: dict, x: jax.Array,
                modes: Optional[dict[str, str]] = None,
                cim_cfg: Optional[CimConfig] = None) -> jax.Array:
    """x: (B, 28, 28, 1). modes: layer name -> ExecMode (default: paper's
    mixed config — MF everywhere except the fc2 classifier)."""
    modes = modes or {"conv1": "mf", "conv2": "mf", "fc1": "mf",
                      "fc2": "regular"}
    h = conv_apply(params["conv1"], x, modes["conv1"], cim_cfg=cim_cfg)
    # MF operator is itself nonlinear (phi = identity); typical layers tanh
    if ExecMode(modes["conv1"]) == ExecMode.REGULAR:
        h = jnp.tanh(h)
    h = maxpool(h)
    h = conv_apply(params["conv2"], h, modes["conv2"], cim_cfg=cim_cfg)
    if ExecMode(modes["conv2"]) == ExecMode.REGULAR:
        h = jnp.tanh(h)
    h = maxpool(h)
    h = h.reshape(h.shape[0], -1)
    h = fc_apply(params["fc1"], h, modes["fc1"], cim_cfg)
    if ExecMode(modes["fc1"]) == ExecMode.REGULAR:
        h = jax.nn.relu(h)
    return fc_apply(params["fc2"], h, modes["fc2"], cim_cfg)


def lenet_layer_stats(img: int = 28) -> list[LayerStat]:
    """(params, ops, matmul shape) per layer for the Fig. 9a mapping table.

    k/n are the im2col matmul view each conv lowers to on the CIM fleet
    (k = kh*kw*cin patch width, n = cout; spatial reuse implied by ops).
    """
    return [
        LayerStat("conv1", 5 * 5 * 1 * 6 + 6, 2 * 5 * 5 * 1 * 6 * 28 * 28,
                  k=5 * 5 * 1, n=6),
        LayerStat("conv2", 5 * 5 * 6 * 16 + 16, 2 * 5 * 5 * 6 * 16 * 14 * 14,
                  k=5 * 5 * 6, n=16),
        LayerStat("fc1", 16 * 7 * 7 * 120 + 120, 2 * 16 * 7 * 7 * 120,
                  k=16 * 7 * 7, n=120),
        LayerStat("fc2_classifier", 120 * 10 + 10, 2 * 120 * 10,
                  k=120, n=10),
    ]


# ---------------------------------------------------------------------------
# CIFAR10 CNN: 5 conv + 2 FC (paper Sec. III)
# ---------------------------------------------------------------------------

CIFAR_CHANNELS = (64, 64, 128, 128, 256)


def cifar_cnn_init(key: jax.Array, mf_convs: bool = True,
                   dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 8)
    chans = (3,) + CIFAR_CHANNELS
    p = {}
    for i in range(5):
        p[f"conv{i+1}"] = conv_init(ks[i], 3, 3, chans[i], chans[i + 1],
                                    mf=mf_convs, dtype=dtype)
        p[f"norm{i+1}"] = norm_scale_init(chans[i + 1], dtype)
    p["fc1"] = fc_init(ks[5], 256 * 4 * 4, 256, mf=False, dtype=dtype)
    p["fc2"] = fc_init(ks[6], 256, 10, mf=False, dtype=dtype)
    return p


def cifar_cnn_apply(params: dict, x: jax.Array, conv_mode: str = "mf",
                    fc_mode: str = "regular",
                    cim_cfg: Optional[CimConfig] = None) -> jax.Array:
    """x: (B, 32, 32, 3). Paper mixed config: convs MF, FCs typical."""
    h = x
    pool_after = {2, 4, 5}
    for i in range(1, 6):
        h = conv_apply(params[f"conv{i}"], h, conv_mode, cim_cfg=cim_cfg)
        h = norm_scale(params[f"norm{i}"], h)
        if ExecMode(conv_mode) == ExecMode.REGULAR:
            h = jax.nn.relu(h)
        if i in pool_after:
            h = maxpool(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(fc_apply(params["fc1"], h, fc_mode, cim_cfg))
    return fc_apply(params["fc2"], h, fc_mode, cim_cfg)


def cifar_layer_stats() -> list[LayerStat]:
    chans = (3,) + CIFAR_CHANNELS
    sizes = (32, 32, 16, 16, 8)
    out = []
    for i in range(5):
        par = 9 * chans[i] * chans[i + 1]
        ops = 2 * par * sizes[i] * sizes[i]
        out.append(LayerStat(f"conv{i+1}", par, ops,
                             k=9 * chans[i], n=chans[i + 1]))
    out.append(LayerStat("fc1", 256 * 16 * 256, 2 * 256 * 16 * 256,
                         k=256 * 16, n=256))
    out.append(LayerStat("fc2_classifier", 2560, 2 * 2560, k=256, n=10))
    return out


# ---------------------------------------------------------------------------
# MobileNetV2 (CIFAR100) — inverted residual bottlenecks
# ---------------------------------------------------------------------------

# (expansion t, out channels c, repeats n, stride s) — CIFAR-adapted
MBV2_CFG = ((1, 16, 1, 1), (6, 24, 2, 1), (6, 32, 3, 2), (6, 64, 4, 2),
            (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1))


def _bottleneck_init(key, cin, cout, t, mf, dtype):
    ks = jax.random.split(key, 3)
    hid = cin * t
    return {
        "expand": conv_init(ks[0], 1, 1, cin, hid, mf=mf, dtype=dtype),
        "dw": conv_init(ks[1], 3, 3, 1, hid, mf=mf, dtype=dtype),
        "project": conv_init(ks[2], 1, 1, hid, cout, mf=mf, dtype=dtype),
        "n1": norm_scale_init(hid, dtype), "n2": norm_scale_init(hid, dtype),
        "n3": norm_scale_init(cout, dtype),
    }


def _bottleneck_apply(p, x, stride, mode, cim_cfg):
    h = conv_apply(p["expand"], x, mode, cim_cfg=cim_cfg)
    h = norm_scale(p["n1"], h)
    if ExecMode(mode) == ExecMode.REGULAR:
        h = jax.nn.relu6(h)
    h = conv_apply(p["dw"], h, mode, stride=(stride, stride),
                   groups=h.shape[-1], cim_cfg=cim_cfg)
    h = norm_scale(p["n2"], h)
    if ExecMode(mode) == ExecMode.REGULAR:
        h = jax.nn.relu6(h)
    h = conv_apply(p["project"], h, mode, cim_cfg=cim_cfg)
    h = norm_scale(p["n3"], h)
    if stride == 1 and x.shape[-1] == h.shape[-1]:
        h = h + x
    return h


def mobilenetv2_init(key: jax.Array, n_classes: int = 100,
                     mf_bottlenecks: bool = True, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, len(MBV2_CFG) + 3)
    p = {"stem": conv_init(ks[0], 3, 3, 3, 32, mf=False, dtype=dtype),
         "stem_n": norm_scale_init(32, dtype)}
    cin = 32
    for bi, (t, c, n, s) in enumerate(MBV2_CFG):
        blocks_p = []
        bkeys = jax.random.split(ks[bi + 1], n)
        for i in range(n):
            blocks_p.append(_bottleneck_init(
                bkeys[i], cin, c, t, mf_bottlenecks, dtype))
            cin = c
        p[f"bn{bi+1}"] = blocks_p
    p["head"] = conv_init(ks[-2], 1, 1, cin, 1280, mf=False, dtype=dtype)
    p["head_n"] = norm_scale_init(1280, dtype)
    p["classifier"] = fc_init(ks[-1], 1280, n_classes, mf=False, dtype=dtype)
    return p


def mobilenetv2_apply(params: dict, x: jax.Array, bn_mode: str = "mf",
                      cim_cfg: Optional[CimConfig] = None) -> jax.Array:
    """Paper's CIFAR100 mixed config: bottlenecks MF; stem/head/fc typical."""
    h = jax.nn.relu6(norm_scale(params["stem_n"],
                                conv_apply(params["stem"], x, "regular")))
    for bi, (t, c, n, s) in enumerate(MBV2_CFG):
        for i in range(n):
            h = _bottleneck_apply(params[f"bn{bi+1}"][i], h,
                                  s if i == 0 else 1, bn_mode, cim_cfg)
    h = jax.nn.relu6(norm_scale(params["head_n"],
                                conv_apply(params["head"], h, "regular")))
    h = jnp.mean(h, axis=(1, 2))
    return fc_apply(params["classifier"], h, "regular")
