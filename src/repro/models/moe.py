"""Mixture-of-Experts: top-k routing with shared experts.

Two execution paths sharing one parameter layout:

  * `moe_apply_dense` — reference path: every expert runs over every token,
    masked by combine weights. O(E) compute; used for correctness tests and
    tiny smoke configs.
  * `moe_apply_ep` — production expert-parallel path for use INSIDE
    shard_map: tokens are sequence/batch-sharded, experts sharded over the
    `model` mesh axis. Sort-based dispatch with fixed per-link capacity ->
    `lax.all_to_all` -> per-expert batched matmul -> reverse all_to_all ->
    weighted combine. This is the DeepSeek/GShard pattern with capacity
    drops (tokens over capacity fall back to the shared expert + residual).

MF-Net integration: each expert FFN is the weight-stationary sweet spot of
the paper's µArray mapping (one expert <-> one CIM bank), so expert
projections honour the layer's ExecMode; the router stays digital
(precision-critical, tiny).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.mf import ExecMode
from repro.models import blocks


def moe_init(key: jax.Array, d_model: int, d_ff: int, n_experts: int,
             n_shared: int, top_k: int, *, mf: bool,
             dtype: Any = jnp.float32) -> dict:
    kr, ke, ks = jax.random.split(key, 3)
    std = 1.0 / math.sqrt(d_model)

    def expert(k):
        k1, k2, k3 = jax.random.split(k, 3)
        p = {"up": (jax.random.normal(k1, (d_model, d_ff)) * std).astype(dtype),
             "gate": (jax.random.normal(k2, (d_model, d_ff)) * std
                      ).astype(dtype),
             "down": (jax.random.normal(k3, (d_ff, d_model))
                      * (1.0 / math.sqrt(d_ff))).astype(dtype)}
        return p

    p = {
        "router": {"w": (jax.random.normal(kr, (d_model, n_experts))
                         * std).astype(jnp.float32)},
        "experts": jax.vmap(expert)(jax.random.split(ke, n_experts)),
    }
    if mf:
        p["experts"]["alpha_up"] = jnp.full(
            (n_experts, d_ff), 1.0 / math.sqrt(2.0 * d_model), dtype)
        p["experts"]["alpha_down"] = jnp.full(
            (n_experts, d_model), 1.0 / math.sqrt(2.0 * d_ff), dtype)
    if n_shared:
        p["shared"] = blocks.mlp_init(ks, d_model, n_shared * d_ff,
                                      "silu_glu", mf=mf, dtype=dtype)
    return p


def _router(p: dict, x2: jax.Array, top_k: int
            ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x2: (S, d) -> (weights (S,k), ids (S,k), aux load-balance loss)."""
    logits = x2.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch-style load-balance loss: E * sum_e f_e * P_e.
    e = probs.shape[-1]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(ids[:, 0], e), axis=0)
    aux = e * jnp.sum(me * ce)
    return weights, ids, aux


def _sel(v, idx):
    """Index a leading-E-stacked value (array or pytree, e.g. a
    ProgrammedMacro); the full slice means 'already sliced, use as-is' —
    which also keeps 0-d leaves (observer ids inside a scan) legal."""
    if isinstance(idx, slice) and idx == slice(None):
        return v
    return jax.tree.map(lambda a: a[idx], v)


def _expert_ffn(experts: dict, idx_or_slice, h: jax.Array,
                mode: ExecMode | str, **kw) -> jax.Array:
    """Apply expert FFN(s). h: (..., d); expert params indexed by leading E.

    Programmed state (``core.programmed.program_weights`` attaches
    ``prog_up/gate/down`` to the expert bank) and calibration observer ids
    (``obs_id_up/...``) thread through to the per-role projection dicts,
    so MoE experts serve weight-stationary and calibrate exactly like
    every other projection.
    """
    up = {"w": _sel(experts["up"], idx_or_slice)}
    gate = {"w": _sel(experts["gate"], idx_or_slice)}
    down = {"w": _sel(experts["down"], idx_or_slice)}
    if "alpha_up" in experts:
        up["alpha"] = _sel(experts["alpha_up"], idx_or_slice)
        gate["alpha"] = _sel(experts["alpha_up"], idx_or_slice)
        down["alpha"] = _sel(experts["alpha_down"], idx_or_slice)
    for role, d in (("up", up), ("gate", gate), ("down", down)):
        if f"prog_{role}" in experts:
            d["prog"] = _sel(experts[f"prog_{role}"], idx_or_slice)
        if f"obs_id_{role}" in experts:
            d["obs_id"] = _sel(experts[f"obs_id_{role}"], idx_or_slice)
        if f"sil_{role}" in experts:
            # Per-slot silicon instances (repro.silicon) slice by expert
            # exactly like the programmed state they perturb.
            d["sil"] = _sel(experts[f"sil_{role}"], idx_or_slice)
        if f"silk_{role}" in experts:
            d["silk"] = _sel(experts[f"silk_{role}"], idx_or_slice)
    z = (jax.nn.silu(blocks.proj_apply(gate, h, mode, **kw))
         * blocks.proj_apply(up, h, mode, **kw))
    return blocks.proj_apply(down, z, mode, **kw)


def moe_apply_dense(p: dict, x: jax.Array, *, top_k: int,
                    mode: ExecMode | str = ExecMode.REGULAR, **kw
                    ) -> tuple[jax.Array, jax.Array]:
    """Reference path: run all experts on all tokens (tests/smokes only)."""
    orig_shape = x.shape
    x2 = x.reshape(-1, x.shape[-1])
    weights, ids, aux = _router(p, x2, top_k)
    n_experts = p["router"]["w"].shape[-1]
    combine = jnp.zeros((x2.shape[0], n_experts), jnp.float32)
    combine = jax.vmap(
        lambda c, i, w: c.at[i].add(w), in_axes=(0, 0, 0))(combine, ids,
                                                           weights)

    def body(carry, ep_and_w):
        ep, cw = ep_and_w
        y = _expert_ffn(ep, slice(None), x2, mode, **kw)
        return carry + cw[:, None] * y.astype(jnp.float32), None

    experts_stacked = jax.tree.map(lambda v: v, p["experts"])
    y0 = jnp.zeros_like(x2, jnp.float32)
    y, _ = jax.lax.scan(
        lambda c, ew: body(c, ew), y0,
        (experts_stacked, combine.T))
    if "shared" in p:
        y = y + blocks.mlp_apply(p["shared"], x2, "silu_glu", mode,
                                 **kw).astype(jnp.float32)
    return y.reshape(orig_shape).astype(x.dtype), aux


def _segment_positions(sorted_seg_ids: jax.Array, n_segments: int
                       ) -> jax.Array:
    """Position of each element within its (sorted) segment."""
    idx = jnp.arange(sorted_seg_ids.shape[0])
    seg_start = jnp.searchsorted(sorted_seg_ids, jnp.arange(n_segments),
                                 side="left")
    return idx - seg_start[sorted_seg_ids]


def moe_apply_ep(p: dict, x: jax.Array, *, top_k: int, ep_axis: str,
                 capacity_factor: float = 1.25,
                 expert_capacity_factor: float = 2.0,
                 mode: ExecMode | str = ExecMode.REGULAR,
                 fuse_single_expert: bool = True, **kw
                 ) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel path. MUST run inside shard_map with ``ep_axis``.

    x: (S_local, d) local token shard; expert params arrive pre-sharded so
    that p['experts'][...] leading dim is E_local = E / n_ep.
    """
    s, d = x.shape
    axes = (ep_axis,) if isinstance(ep_axis, str) else tuple(ep_axis)
    n_ep = 1
    from repro.launch.mesh import axis_size
    for a in axes:                        # static: reads the axis env
        n_ep *= axis_size(a)
    e_local = p["experts"]["up"].shape[0]
    n_experts = p["router"]["w"].shape[-1]
    assert n_experts == e_local * n_ep, (n_experts, e_local, n_ep)

    weights, ids, aux = _router(p, x, top_k)
    aux = jax.lax.pmean(aux, axes)

    sk = s * top_k
    flat_e = ids.reshape(sk)
    flat_w = weights.reshape(sk)
    flat_tok = jnp.repeat(jnp.arange(s), top_k)

    # ---- stage 1: route token copies to the owning EP shard -------------
    target = flat_e // e_local
    order = jnp.argsort(target, stable=True)
    t_sorted = target[order]
    pos = _segment_positions(t_sorted, n_ep)
    cap = int(8 * math.ceil(sk / n_ep * capacity_factor / 8))
    keep = pos < cap
    dest = jnp.where(keep, t_sorted * cap + pos, n_ep * cap)  # OOB -> drop

    send_tok = jnp.zeros((n_ep * cap, d), x.dtype).at[dest].set(
        x[flat_tok[order]], mode="drop").reshape(n_ep, cap, d)
    send_eid = jnp.full((n_ep * cap,), e_local, jnp.int32).at[dest].set(
        flat_e[order] % e_local, mode="drop").reshape(n_ep, cap)
    # Bookkeeping for the return trip (stays on the source device).
    src_tok = jnp.full((n_ep * cap,), s, jnp.int32).at[dest].set(
        flat_tok[order], mode="drop").reshape(n_ep, cap)
    src_w = jnp.zeros((n_ep * cap,), jnp.float32).at[dest].set(
        flat_w[order], mode="drop").reshape(n_ep, cap)

    recv_tok = jax.lax.all_to_all(send_tok, axes, 0, 0, tiled=False)
    recv_eid = jax.lax.all_to_all(send_eid, axes, 0, 0, tiled=False)

    r = n_ep * cap
    re = recv_eid.reshape(r)                    # e_local == invalid sentinel
    rt = recv_tok.reshape(r, d)

    if e_local == 1 and fuse_single_expert:
        # Wide-EP fast path (one expert per shard): every valid received
        # row belongs to the single local expert — skip the second
        # sort/scatter and the 2x-capacity staging buffer entirely, and
        # run the FFN on the receive buffer in place (§Perf iteration:
        # halves stage-2 FLOPs and removes two scatters + one gather).
        ffn_out = _expert_ffn(p["experts"], 0, rt, mode, **kw)
        out_rows = jnp.where((re < e_local)[:, None], ffn_out, 0.0
                             ).astype(x.dtype)
        back = jax.lax.all_to_all(out_rows.reshape(n_ep, cap, d), axes, 0,
                                  0, tiled=False).reshape(n_ep * cap, d)
        y = jnp.zeros((s + 1, d), jnp.float32).at[src_tok.reshape(-1)].add(
            back.astype(jnp.float32) * src_w.reshape(-1, 1))[:s]
        if "shared" in p:
            y = y + blocks.mlp_apply(p["shared"], x, "silu_glu", mode,
                                     **kw).astype(jnp.float32)
        return y.astype(x.dtype), aux

    # ---- stage 2: group received rows by local expert --------------------
    order2 = jnp.argsort(re, stable=True)
    e_sorted = re[order2]
    pos2 = _segment_positions(e_sorted, e_local + 1)
    cap2 = int(8 * math.ceil(r / e_local * expert_capacity_factor / 8))
    keep2 = (pos2 < cap2) & (e_sorted < e_local)
    dest2 = jnp.where(keep2, e_sorted * cap2 + pos2, e_local * cap2)

    buf = jnp.zeros((e_local * cap2, d), x.dtype).at[dest2].set(
        rt[order2], mode="drop").reshape(e_local, cap2, d)

    # ---- expert compute: batched over local experts ----------------------
    out_buf = jax.vmap(
        lambda ep, h: _expert_ffn(ep, slice(None), h, mode, **kw),
        in_axes=(0, 0))(
            jax.tree.map(lambda v: v, p["experts"]), buf)

    # ---- inverse of stage 2 ----------------------------------------------
    # row r (in sorted order) came from flat position order2[r].
    inv_vals = out_buf.reshape(e_local * cap2, d)
    gathered = jnp.where(keep2[:, None],
                         inv_vals[jnp.clip(dest2, 0, e_local * cap2 - 1)],
                         0.0)
    out_rows = jnp.zeros((r, d), x.dtype).at[order2].set(gathered)

    # ---- reverse all_to_all + weighted combine ---------------------------
    back = jax.lax.all_to_all(out_rows.reshape(n_ep, cap, d), axes, 0, 0,
                              tiled=False)
    back = back.reshape(n_ep * cap, d)
    y = jnp.zeros((s + 1, d), jnp.float32).at[src_tok.reshape(-1)].add(
        back.astype(jnp.float32) * src_w.reshape(-1, 1))[:s]

    if "shared" in p:
        y = y + blocks.mlp_apply(p["shared"], x, "silu_glu", mode,
                                 **kw).astype(jnp.float32)
    return y.astype(x.dtype), aux
