"""Attention: GQA/MQA with RoPE, optional qk-norm/bias/local windows.

Two execution regimes:

  * `blocked_attention` — blockwise online-softmax (flash-style) scan over
    KV chunks. O(T * block) memory instead of O(T^2); this is what makes
    the 32k prefill shapes lowerable, and under sequence sharding each
    device scans only its local KV blocks.
  * `decode_attention` — single-query attention against a (possibly
    sequence-sharded) KV cache, with partial-softmax (max/denominator)
    combine exposed for the shard_map flash-decode path in
    `parallel/collectives.py`.

QKV/O projections run through `core.mf.apply_projection`, so attention
projections participate in the MF mixed mapping like every other layer.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.mf import ExecMode
from repro.models import blocks

NEG_INF = -1e30


def attn_init(key: jax.Array, d_model: int, n_heads: int, n_kv_heads: int,
              head_dim: int, *, qkv_bias: bool, qk_norm: bool, mf: bool,
              dtype: Any = jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "q": blocks.proj_init(ks[0], d_model, n_heads * head_dim,
                              bias=qkv_bias, mf=mf, dtype=dtype),
        "k": blocks.proj_init(ks[1], d_model, n_kv_heads * head_dim,
                              bias=qkv_bias, mf=mf, dtype=dtype),
        "v": blocks.proj_init(ks[2], d_model, n_kv_heads * head_dim,
                              bias=qkv_bias, mf=mf, dtype=dtype),
        "o": blocks.proj_init(ks[3], n_heads * head_dim, d_model, bias=False,
                              mf=mf, dtype=dtype),
    }
    if qk_norm:
        p["q_norm"] = blocks.rmsnorm_init(head_dim, dtype)
        p["k_norm"] = blocks.rmsnorm_init(head_dim, dtype)
    return p


def _axis_size(pctx, axis: str) -> int:
    sizes = dict(zip(pctx.mesh.axis_names, pctx.mesh.devices.shape))
    return sizes.get(axis, 1)


def _split_heads(v: jax.Array, n: int) -> jax.Array:
    b, t, _ = v.shape
    return v.reshape(b, t, n, -1)


def _repeat_kv(v: jax.Array, groups: int) -> jax.Array:
    """(B, T, Hkv, D) -> (B, T, Hkv*groups, D) for GQA."""
    if groups == 1:
        return v
    b, t, h, d = v.shape
    return jnp.broadcast_to(v[:, :, :, None, :], (b, t, h, groups, d)
                            ).reshape(b, t, h * groups, d)


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      q_offset: int = 0, block: int = 1024,
                      block_skip: bool = False) -> jax.Array:
    """Online-softmax attention. q: (B,Tq,H,D), k/v: (B,Tk,Hkv,D).

    Scans KV in chunks of ``block`` keeping running (max, denom, out)
    accumulators — the flash-attention recurrence in pure lax. ``q_offset``
    is the absolute position of q[0] (for sequence-sharded queries).
    ``window`` enables sliding-window (local) attention.

    ``block_skip=True`` switches to the 2-D blocked schedule that
    statically skips (q-block, kv-block) pairs that are fully masked —
    ~2x fewer score blocks for causal attention at large T/block, and
    O(window/T) of the work for sliding-window attention (§Perf).
    """
    if block_skip:
        return _blocked_attention_skip(q, k, v, causal=causal,
                                       window=window, q_offset=q_offset,
                                       block=block)
    b, tq, h, d = q.shape
    _, tk, hkv, _ = k.shape
    dv = v.shape[-1]
    groups = h // hkv
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    scale = 1.0 / math.sqrt(d)
    qf = q.astype(jnp.float32) * scale

    nblocks = -(-tk // block)
    pad = nblocks * block - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nblocks, block, h, d)
    vb = v.reshape(b, nblocks, block, h, dv)

    q_pos = q_offset + jnp.arange(tq)

    def body(carry, inp):
        m, l, o = carry
        kc, vc, blk_idx = inp
        k_pos = blk_idx * block + jnp.arange(block)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kc.astype(jnp.float32))
        mask = jnp.ones((tq, block), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        mask &= (k_pos < tk)[None, :]            # padding keys
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        # Fully-masked rows have s == m_new == NEG_INF -> exp(0) == 1;
        # zero them explicitly so they contribute nothing.
        p = jnp.where(mask[None, None], p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        o_new = o * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vc.astype(jnp.float32))
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, h, tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, tq), jnp.float32)
    o0 = jnp.zeros((b, h, tq, dv), jnp.float32)
    (m, l, o), _ = jax.lax.scan(
        body, (m0, l0, o0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0),
         jnp.arange(nblocks)))
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)    # (B, Tq, H, D)


def _blocked_attention_skip(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            causal: bool, window: Optional[int],
                            q_offset: int, block: int) -> jax.Array:
    """2-D blocked online softmax over a statically pruned (i, j) pair
    list: pairs whose every (q,k) position is masked never execute."""
    b, tq, h, d = q.shape
    _, tk, hkv, _ = k.shape
    dv = v.shape[-1]
    groups = h // hkv
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    scale = 1.0 / math.sqrt(d)

    nq, nk = -(-tq // block), -(-tk // block)
    qp = jnp.pad(q, ((0, 0), (0, nq * block - tq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * block - tk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * block - tk), (0, 0), (0, 0)))
    qb = (qp.astype(jnp.float32) * scale).reshape(b, nq, block, h, d)
    kb = kp.reshape(b, nk, block, h, d)
    vb = vp.reshape(b, nk, block, h, dv)

    def kv_blocks_for(i: int) -> list[int]:
        q_lo, q_hi = i * block + q_offset, i * block + q_offset + block - 1
        out = []
        for j in range(nk):
            k_lo, k_hi = j * block, j * block + block - 1
            if causal and k_lo > q_hi:
                continue                      # fully above the diagonal
            if window is not None and k_hi < q_lo - window + 1:
                continue                      # fully outside the window
            out.append(j)
        return out

    def partial_block(i: int, j: int, m, l, o):
        kj = kb[:, j]
        vj = vb[:, j]
        s = jnp.einsum("bqhd,bkhd->bhqk", qb[:, i], kj.astype(jnp.float32))
        q_pos = q_offset + i * block + jnp.arange(block)
        k_pos = j * block + jnp.arange(block)
        mask = jnp.ones((block, block), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        mask &= (k_pos < tk)[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        o_new = o * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vj.astype(jnp.float32))
        return m_new, l_new, o_new

    # Static Python double loop: per-q-block accumulators stay LOCAL
    # (b,h,block,dv) values — no (nq, ...) gather/scatter buffers whose
    # full-size dynamic-update-slices would dominate bytes accessed.
    outs = []
    for i in range(nq):
        m = jnp.full((b, h, block), NEG_INF, jnp.float32)
        l = jnp.zeros((b, h, block), jnp.float32)
        o = jnp.zeros((b, h, block, dv), jnp.float32)
        for j in kv_blocks_for(i):
            m, l, o = partial_block(i, j, m, l, o)
        outs.append(o / jnp.maximum(l, 1e-30)[..., None])
    out = jnp.concatenate(outs, axis=2)            # (b, h, nq*block, dv)
    out = out[:, :, :tq]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)


def decode_attention_partial(q: jax.Array, k: jax.Array, v: jax.Array,
                             valid: jax.Array
                             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token attention partials for flash-decode combine.

    q: (B,H,D); k/v: (B,S,Hkv,D) local cache shard; valid: (B,S) bool.
    Returns (m, l, o): per-head running max (B,H), denom (B,H), and
    unnormalised output (B,H,D) — combinable across shards with the
    standard log-sum-exp merge.
    """
    b, s, hkv, d = k.shape
    h = q.shape[1]
    groups = h // hkv
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    scale = 1.0 / math.sqrt(d)
    sco = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32) * scale,
                     k.astype(jnp.float32))
    sco = jnp.where(valid[:, None, :], sco, NEG_INF)
    m = jnp.max(sco, axis=-1)
    p = jnp.exp(sco - m[..., None])
    p = jnp.where(valid[:, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32))
    return m, l, o


def combine_partials(parts: list[tuple[jax.Array, jax.Array, jax.Array]]
                     ) -> jax.Array:
    """Merge flash-decode partials from sequence shards."""
    m = parts[0][0]
    for mp, _, _ in parts[1:]:
        m = jnp.maximum(m, mp)
    l = sum(lp * jnp.exp(mp - m) for mp, lp, _ in parts)
    o = sum(op * jnp.exp(mp - m)[..., None] for mp, _, op in parts)
    return o / jnp.maximum(l, 1e-30)[..., None]


def flash_decode_sharded(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                         cache_k: jax.Array, cache_v: jax.Array,
                         idx: jax.Array, *, mesh, dp, tp: str
                         ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Distributed single-token attention over a sequence-sharded KV cache.

    GSPMD cannot auto-distribute a softmax whose reduction axis is sharded
    — it falls back to all-gathering the (f32-cast) cache every layer,
    which dominates decode collectives (HC3 finding: 2.15 GB/layer/chip
    for a 72B-class model). This shard_map computes flash-decode partials
    (m, l, o) on each shard's local cache slice and merges them with an
    O(B*H) log-sum-exp psum instead.

    q: (B, H, D); k_new/v_new: (B, 1, Hkv, D); caches: (B, S, Hkv, D)
    sequence-sharded over ``tp``; idx: (B,) current lengths.
    Returns (out (B, H, D), new_k_cache, new_v_cache).
    """
    from jax.sharding import PartitionSpec as P

    def local_fn(qL, knL, vnL, kcL, vcL, idxL):
        s_loc = kcL.shape[1]
        off = jax.lax.axis_index(tp) * s_loc
        widx = idxL - off                                   # (B,)
        in_range = (widx >= 0) & (widx < s_loc)
        safe = jnp.clip(widx, 0, s_loc - 1)
        upd_k = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0, 0)))(kcL, knL.astype(kcL.dtype), safe)
        upd_v = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0, 0)))(vcL, vnL.astype(vcL.dtype), safe)
        kc2 = jnp.where(in_range[:, None, None, None], upd_k, kcL)
        vc2 = jnp.where(in_range[:, None, None, None], upd_v, vcL)
        valid = (off + jnp.arange(s_loc))[None, :] < (idxL + 1)[:, None]
        m, l, o = decode_attention_partial(qL, kc2, vc2, valid)
        mg = jax.lax.pmax(m, tp)
        scale = jnp.exp(m - mg)
        lg = jax.lax.psum(l * scale, tp)
        og = jax.lax.psum(o * scale[..., None], tp)
        out = og / jnp.maximum(lg, 1e-30)[..., None]
        return out.astype(qL.dtype), kc2, vc2

    from repro.launch.mesh import shard_map
    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp, None, None), P(dp, None, None, None),
                  P(dp, None, None, None), P(dp, tp, None, None),
                  P(dp, tp, None, None), P(dp)),
        out_specs=(P(dp, None, None), P(dp, tp, None, None),
                   P(dp, tp, None, None)),
        check_vma=False,
    )(q, k_new, v_new, cache_k, cache_v, idx)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array) -> jax.Array:
    """q: (B,1,H,D) vs cache (B,S,Hkv,D); cache_len: (B,) valid prefix."""
    b, s, _, _ = k_cache.shape
    valid = jnp.arange(s)[None, :] < cache_len[:, None]
    m, l, o = decode_attention_partial(q[:, 0], k_cache, v_cache, valid)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out[:, None].astype(q.dtype)


def gqa_apply(p: dict, x: jax.Array, *, n_heads: int, n_kv_heads: int,
              head_dim: int, rope_theta: float, positions: jax.Array,
              mode: ExecMode | str = ExecMode.REGULAR,
              qk_norm: bool = False, causal: bool = True,
              window: Optional[int] = None,
              cache: Optional[dict] = None,
              attn_block: int = 1024, attn_block_skip: bool = False,
              pctx=None, prefill_valid: Optional[jax.Array] = None, **kw
              ) -> tuple[jax.Array, Optional[dict]]:
    """Full GQA block. With ``cache`` (decode): append one token and attend
    against the cache; without: blockwise self-attention over x.

    ``prefill_valid`` (with ``cache``) switches to batched prefill: x is a
    (B, T) slab of prompt tokens, per-batch lengths ``prefill_valid`` of
    which are real; causal self-attention runs over the slab and only the
    valid positions' K/V are written into the cache (slots with
    ``prefill_valid == 0`` — e.g. mid-decode neighbours in a serving batch
    — keep their cache rows and length untouched). Requires fresh slots
    (``cache['len'] == 0`` wherever valid > 0) and a non-ring cache.
    """
    b, t, _ = x.shape
    q = _split_heads(blocks.proj_apply(p["q"], x, mode, **kw), n_heads)
    k = _split_heads(blocks.proj_apply(p["k"], x, mode, **kw), n_kv_heads)
    v = _split_heads(blocks.proj_apply(p["v"], x, mode, **kw), n_kv_heads)
    if qk_norm:
        q = blocks.rmsnorm(p["q_norm"], q)
        k = blocks.rmsnorm(p["k_norm"], k)
    q = blocks.apply_rope(q, positions, rope_theta)
    k = blocks.apply_rope(k, positions, rope_theta)

    if cache is None:
        out = blocked_attention(q, k, v, causal=causal, window=window,
                                block=attn_block,
                                block_skip=attn_block_skip)
        new_cache = None
    elif prefill_valid is not None:
        # Batched prefill: fold the whole (B, T) prompt slab through one
        # forward. Causal masking already confines every consumed query
        # position to real prefix keys (padding positions beyond a slot's
        # valid length only feed query rows nobody reads and cache rows
        # the write mask drops), so plain causal attention over the slab
        # is enough — no per-slot key masking needed.
        s = cache["k"].shape[1]
        if window is not None and s <= window:
            raise ValueError("batched prefill does not support ring-buffer "
                             "(local-attention) caches")
        if t > s:
            raise ValueError(f"prefill slab length {t} exceeds cache "
                             f"length {s}")
        out = blocked_attention(q, k, v, causal=causal, window=window,
                                block=attn_block,
                                block_skip=attn_block_skip)
        mask = (jnp.arange(t)[None, :]
                < prefill_valid[:, None])[..., None, None]     # (B,T,1,1)
        k_cache = cache["k"].at[:, :t].set(
            jnp.where(mask, k.astype(cache["k"].dtype), cache["k"][:, :t]))
        v_cache = cache["v"].at[:, :t].set(
            jnp.where(mask, v.astype(cache["v"].dtype), cache["v"][:, :t]))
        new_cache = {"k": k_cache, "v": v_cache,
                     "len": cache["len"] + prefill_valid}
    else:
        # Decode: write k/v at cache_len, attend over the whole cache.
        # When the cache is smaller than the sequence (local attention) it
        # is a ring buffer: writes wrap and every resident entry is within
        # the window by construction (RoPE is absolute at write time, so
        # relative scores are unaffected by the ring position).
        idx = cache["len"]                                   # (B,)
        s = cache["k"].shape[1]
        is_ring = window is not None and s <= window
        use_flash_sp = (pctx is not None and getattr(pctx, "active", False)
                        and pctx.cfg.seq_shard_cache and not is_ring
                        and window is None
                        and s % _axis_size(pctx, pctx.cfg.tp_axis) == 0)
        if use_flash_sp:
            dp = (pctx.cfg.dp_axes if len(pctx.cfg.dp_axes) > 1
                  else pctx.cfg.dp_axes[0])
            out, k_cache, v_cache = flash_decode_sharded(
                q[:, 0], k, v, cache["k"], cache["v"], idx,
                mesh=pctx.mesh, dp=dp, tp=pctx.cfg.tp_axis)
            out = out[:, None]
            y = blocks.proj_apply(
                p["o"], out.reshape(b, t, n_heads * head_dim), mode, **kw)
            return y, {"k": k_cache, "v": v_cache, "len": idx + 1}
        widx = idx % s if is_ring else idx
        k_cache = jax.vmap(
            lambda c, kv, i: jax.lax.dynamic_update_slice(
                c, kv, (i, 0, 0)))(cache["k"], k.astype(cache["k"].dtype),
                                   widx)
        v_cache = jax.vmap(
            lambda c, kv, i: jax.lax.dynamic_update_slice(
                c, kv, (i, 0, 0)))(cache["v"], v.astype(cache["v"].dtype),
                                   widx)
        if is_ring:
            pos_ok = jnp.arange(s)[None, :] < jnp.minimum(idx + 1, s)[:, None]
        else:
            pos_ok = jnp.arange(s)[None, :] < (idx + 1)[:, None]
            if window is not None:
                pos_ok &= jnp.arange(s)[None, :] > (idx[:, None] - window)
        m, l, o = decode_attention_partial(q[:, 0], k_cache, v_cache, pos_ok)
        out = (o / jnp.maximum(l, 1e-30)[..., None])[:, None].astype(q.dtype)
        new_cache = {"k": k_cache, "v": v_cache, "len": idx + 1}

    y = blocks.proj_apply(
        p["o"], out.reshape(b, t, n_heads * head_dim), mode, **kw)
    return y, new_cache


def init_kv_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
                  dtype: Any = jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }
