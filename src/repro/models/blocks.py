"""Shared model blocks: norms, RoPE, MLPs, embeddings.

Every weight-activation projection goes through `core.mf.apply_projection`
so the MF-Net technique (regular | mf | mf_kernel | cim_sim execution) is a
per-layer switch driven by the mixed-mapping policy — the paper's Sec. VI
integration, applied uniformly across all ten architectures.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.mf import ExecMode, apply_projection


# ---------------------------------------------------------------------------
# Projection params. `mf=True` adds the per-channel alpha of the MF neuron.
# ---------------------------------------------------------------------------

def proj_init(key: jax.Array, in_dim: int, out_dim: int, *, bias: bool,
              mf: bool, dtype: Any = jnp.float32,
              scale: Optional[float] = None) -> dict:
    std = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    p = {"w": (jax.random.normal(key, (in_dim, out_dim)) * std).astype(dtype)}
    if mf:
        p["alpha"] = jnp.full((out_dim,), 1.0 / math.sqrt(2.0 * in_dim),
                              dtype)
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def proj_apply(p: dict, x: jax.Array, mode: ExecMode | str = ExecMode.REGULAR,
               *, programmed: Optional[Any] = None, **kw) -> jax.Array:
    """Apply one projection; ``programmed`` (or an embedded ``p["prog"]``
    from ``core.programmed.program_weights``) serves CIM_SIM projections
    from weight-stationary programmed macro state."""
    return apply_projection(p, x, mode, programmed=programmed, **kw)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype: Any = jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


def layernorm_init(dim: int, dtype: Any = jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def norm_init(kind: str, dim: int, dtype: Any = jnp.float32) -> dict:
    return layernorm_init(dim, dtype) if kind == "layernorm" else rmsnorm_init(
        dim, dtype)


def norm_apply(kind: str, p: dict, x: jax.Array) -> jax.Array:
    return layernorm(p, x) if kind == "layernorm" else rmsnorm(p, x)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(v: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """v: (..., T, H, D) rotated pairwise; positions: (..., T)."""
    d = v.shape[-1]
    freqs = rope_freqs(d, theta)                           # (D/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,T,1,D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    v1, v2 = v[..., 0::2], v[..., 1::2]
    r1 = v1 * cos - v2 * sin
    r2 = v2 * cos + v1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(v.shape)
    return out.astype(v.dtype)


# ---------------------------------------------------------------------------
# MLPs — the per-family feed-forward variants.
# ---------------------------------------------------------------------------

MLP_GATED = {"silu_glu", "geglu"}


def mlp_init(key: jax.Array, d_model: int, d_ff: int, kind: str, *,
             mf: bool, bias: bool = False, dtype: Any = jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    p = {"up": proj_init(ks[0], d_model, d_ff, bias=bias, mf=mf, dtype=dtype),
         "down": proj_init(ks[1], d_ff, d_model, bias=bias, mf=mf,
                           dtype=dtype)}
    if kind in MLP_GATED:
        p["gate"] = proj_init(ks[2], d_model, d_ff, bias=bias, mf=mf,
                              dtype=dtype)
    return p


def mlp_apply(p: dict, x: jax.Array, kind: str,
              mode: ExecMode | str = ExecMode.REGULAR, **kw) -> jax.Array:
    up = proj_apply(p["up"], x, mode, **kw)
    if kind == "silu_glu":
        h = jax.nn.silu(proj_apply(p["gate"], x, mode, **kw)) * up
    elif kind == "geglu":
        h = jax.nn.gelu(proj_apply(p["gate"], x, mode, **kw)) * up
    elif kind == "gelu":
        h = jax.nn.gelu(up)
    elif kind == "sq_relu":                      # nemotron-4 squared ReLU
        h = jnp.square(jax.nn.relu(up))
    else:  # pragma: no cover
        raise ValueError(kind)
    return proj_apply(p["down"], h, mode, **kw)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_init(key: jax.Array, vocab: int, d_model: int,
               dtype: Any = jnp.float32) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d_model)) * 0.02
                      ).astype(dtype)}


def embed_apply(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def lm_head_apply(p: dict, x: jax.Array, *, tied_table: Optional[jax.Array]
                  = None) -> jax.Array:
    if tied_table is not None:
        return x @ tied_table.T
    return proj_apply(p, x)
