"""Encoder-decoder backbone (whisper-base shape).

The conv/mel frontend is a STUB per the assignment: `input_specs()` feeds
precomputed frame embeddings (B, T_enc, d_model) directly. The backbone is
faithful: sinusoidal-positioned bidirectional encoder, causal decoder with
self-attention + cross-attention, pre-LN, GELU MLPs. Projections are
MF-able like every other arch.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, blocks
from repro.models.transformer import ParallelContext, resolve_modes, _mf_kw


def _sinusoid(t: int, d: int) -> jax.Array:
    pos = jnp.arange(t)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _xattn_init(key, cfg: ModelConfig, mf: bool):
    return attention.attn_init(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.resolved_head_dim, qkv_bias=False,
                               qk_norm=False, mf=mf, dtype=cfg.dtype)


def _enc_layer_init(key, cfg: ModelConfig, mf: bool):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": blocks.layernorm_init(cfg.d_model, cfg.dtype),
        "attn": _xattn_init(k1, cfg, mf),
        "ln2": blocks.layernorm_init(cfg.d_model, cfg.dtype),
        "mlp": blocks.mlp_init(k2, cfg.d_model, cfg.d_ff, "gelu", mf=mf,
                               dtype=cfg.dtype),
    }


def _dec_layer_init(key, cfg: ModelConfig, mf: bool):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": blocks.layernorm_init(cfg.d_model, cfg.dtype),
        "self_attn": _xattn_init(k1, cfg, mf),
        "ln_x": blocks.layernorm_init(cfg.d_model, cfg.dtype),
        "cross_attn": _xattn_init(k2, cfg, mf),
        "ln2": blocks.layernorm_init(cfg.d_model, cfg.dtype),
        "mlp": blocks.mlp_init(k3, cfg.d_model, cfg.d_ff, "gelu", mf=mf,
                               dtype=cfg.dtype),
    }


def encdec_init(key: jax.Array, cfg: ModelConfig) -> dict:
    mf = cfg.mf.enabled
    ks = jax.random.split(key, 4)
    return {
        "embed": blocks.embed_init(ks[0], cfg.vocab_size, cfg.d_model,
                                   cfg.dtype),
        "enc": jax.vmap(lambda k: _enc_layer_init(k, cfg, mf))(
            jax.random.split(ks[1], cfg.encoder_layers)),
        "enc_norm": blocks.layernorm_init(cfg.d_model, cfg.dtype),
        "dec": jax.vmap(lambda k: _dec_layer_init(k, cfg, mf))(
            jax.random.split(ks[2], cfg.n_layers)),
        "dec_norm": blocks.layernorm_init(cfg.d_model, cfg.dtype),
    }


def _mha(p, xq, xkv, *, cfg, mode, causal, positions_q, positions_kv, cache=None,
         **kw):
    """Self- or cross-attention via the blocked kernel (no RoPE: whisper
    uses learned/sinusoidal absolute embeddings added to the stream)."""
    b, tq, _ = xq.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = blocks.proj_apply(p["q"], xq, mode, **kw).reshape(b, tq, h, hd)
    if cache is not None and "k" in cache and cache.get("static", False):
        k, v = cache["k"], cache["v"]
        new_cache = cache
    else:
        tk = xkv.shape[1]
        k = blocks.proj_apply(p["k"], xkv, mode, **kw).reshape(b, tk, hkv, hd)
        v = blocks.proj_apply(p["v"], xkv, mode, **kw).reshape(b, tk, hkv, hd)
        new_cache = None
    if cache is not None and not cache.get("static", False):
        idx = cache["len"]
        k = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0, 0)))(cache["k"], k.astype(cache["k"].dtype), idx)
        v = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0, 0)))(cache["v"], v.astype(cache["v"].dtype), idx)
        s = k.shape[1]
        valid = jnp.arange(s)[None, :] < (idx + 1)[:, None]
        m, l, o = attention.decode_attention_partial(q[:, 0], k, v, valid)
        out = (o / jnp.maximum(l, 1e-30)[..., None])[:, None].astype(q.dtype)
        new_cache = {"k": k, "v": v, "len": idx + 1}
        return blocks.proj_apply(p["o"], out.reshape(b, tq, h * hd), mode,
                                 **kw), new_cache
    out = attention.blocked_attention(q, k, v, causal=causal,
                                      block=cfg.attn_block,
                                      block_skip=cfg.attn_block_skip)
    y = blocks.proj_apply(p["o"], out.reshape(b, tq, h * hd), mode, **kw)
    return y, new_cache


def encode(params: dict, frames: jax.Array, cfg: ModelConfig,
           pctx: ParallelContext = ParallelContext()) -> jax.Array:
    """frames: (B, T_enc, d_model) stub embeddings -> encoder states."""
    modes = resolve_modes(cfg)
    kw = _mf_kw(cfg)
    b, t, d = frames.shape
    x = frames + _sinusoid(t, d).astype(frames.dtype)
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))

    def body_full(h, lp):
        hn = blocks.layernorm(lp["ln1"], h)
        a, _ = _mha(lp["attn"], hn, hn, cfg=cfg, mode=modes["attn"],
                    causal=False, positions_q=pos, positions_kv=pos, **kw)
        h = h + a
        h = h + blocks.mlp_apply(lp["mlp"], blocks.layernorm(lp["ln2"], h),
                                 "gelu", modes["mlp"], **kw)
        return h, None

    x, _ = jax.lax.scan(body_full, x, params["enc"],
                        unroll=pctx.cfg.scan_unroll)
    return blocks.layernorm(params["enc_norm"], x)


def decode_train(params: dict, enc_out: jax.Array, tokens: jax.Array,
                 cfg: ModelConfig,
                 pctx: ParallelContext = ParallelContext()) -> jax.Array:
    """Teacher-forced decoder. tokens: (B, T_dec) -> logits."""
    modes = resolve_modes(cfg)
    kw = _mf_kw(cfg)
    b, t = tokens.shape
    x = blocks.embed_apply(params["embed"], tokens)
    x = x + _sinusoid(t, cfg.d_model).astype(x.dtype)
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    pos_kv = jnp.broadcast_to(jnp.arange(enc_out.shape[1])[None],
                              (b, enc_out.shape[1]))

    def body(h, lp):
        hn = blocks.layernorm(lp["ln1"], h)
        a, _ = _mha(lp["self_attn"], hn, hn, cfg=cfg, mode=modes["attn"],
                    causal=True, positions_q=pos, positions_kv=pos, **kw)
        h = h + a
        c, _ = _mha(lp["cross_attn"], blocks.layernorm(lp["ln_x"], h),
                    enc_out, cfg=cfg, mode=modes["attn"], causal=False,
                    positions_q=pos, positions_kv=pos_kv, **kw)
        h = h + c
        h = h + blocks.mlp_apply(lp["mlp"], blocks.layernorm(lp["ln2"], h),
                                 "gelu", modes["mlp"], **kw)
        return h, None

    x, _ = jax.lax.scan(body, x, params["dec"],
                        unroll=pctx.cfg.scan_unroll)
    x = blocks.layernorm(params["dec_norm"], x)
    return x @ params["embed"]["table"].T       # tied head (whisper)


def encdec_loss(params: dict, batch: dict, cfg: ModelConfig,
                pctx: ParallelContext = ParallelContext()
                ) -> tuple[jax.Array, dict]:
    from repro.models.transformer import _sharded_ce
    enc_out = encode(params, batch["frames"], cfg, pctx)
    logits = decode_train(params, enc_out, batch["tokens"], cfg, pctx)
    targets = batch["targets"]
    loss = _sharded_ce(logits, jnp.maximum(targets, 0), targets >= 0)
    return loss, {"loss": loss}


def encdec_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                      enc_len: int) -> dict:
    """Self-attn KV ring + per-layer static cross-attn K/V."""
    hd = cfg.resolved_head_dim
    one_self = attention.init_kv_cache(batch, max_len, cfg.n_kv_heads, hd,
                                       dtype=cfg.dtype)
    n = cfg.n_layers
    stack = lambda v: jnp.broadcast_to(v, (n,) + v.shape).copy()
    return {
        "self": jax.tree.map(stack, one_self),
        "cross_k": jnp.zeros((n, batch, enc_len, cfg.n_kv_heads, hd),
                             cfg.dtype),
        "cross_v": jnp.zeros((n, batch, enc_len, cfg.n_kv_heads, hd),
                             cfg.dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def encdec_cache_pspecs(cfg: ModelConfig, cache_tree, pcfg,
                        axis_sizes: dict):
    """Spec tree matching `encdec_init_cache`: batch over DP, cache
    sequence dims over `model` (flash-decode SP)."""
    from jax.sharding import PartitionSpec as P
    dp = pcfg.dp_axes if len(pcfg.dp_axes) > 1 else pcfg.dp_axes[0]
    tp = pcfg.tp_axis
    return {
        "self": {"k": P(None, dp, tp, None, None),
                 "v": P(None, dp, tp, None, None),
                 "len": P(None, dp)},
        "cross_k": P(None, dp, tp, None, None),
        "cross_v": P(None, dp, tp, None, None),
        "pos": P(dp),
    }


def encdec_prefill_cross(params: dict, cache: dict, enc_out: jax.Array,
                         cfg: ModelConfig) -> dict:
    """Project encoder states into the per-layer static cross K/V cache."""
    modes = resolve_modes(cfg)
    kw = _mf_kw(cfg)
    b, tk, _ = enc_out.shape
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim

    def per_layer(lp):
        k = blocks.proj_apply(lp["cross_attn"]["k"], enc_out, modes["attn"],
                              **kw).reshape(b, tk, hkv, hd)
        v = blocks.proj_apply(lp["cross_attn"]["v"], enc_out, modes["attn"],
                              **kw).reshape(b, tk, hkv, hd)
        return k.astype(cache["cross_k"].dtype), v.astype(
            cache["cross_v"].dtype)

    ks, vs = jax.lax.map(per_layer, params["dec"])
    return dict(cache, cross_k=ks, cross_v=vs)


def encdec_decode_step(params: dict, cache: dict, tokens: jax.Array,
                       cfg: ModelConfig,
                       pctx: ParallelContext = ParallelContext()
                       ) -> tuple[jax.Array, dict]:
    """One decoder step against precomputed cross K/V."""
    modes = resolve_modes(cfg)
    kw = _mf_kw(cfg)
    x = blocks.embed_apply(params["embed"], tokens[:, None])
    max_len = cache["self"]["k"].shape[2]
    table = _sinusoid(max_len, cfg.d_model)
    x = x + table[cache["pos"]][:, None].astype(x.dtype)

    def body(h, inp):
        lp, self_c, ck, cv = inp
        hn = blocks.layernorm(lp["ln1"], h)
        a, new_self = _mha(lp["self_attn"], hn, hn, cfg=cfg,
                           mode=modes["attn"], causal=True, positions_q=None,
                           positions_kv=None, cache=self_c, **kw)
        h = h + a
        c, _ = _mha(lp["cross_attn"], blocks.layernorm(lp["ln_x"], h), None,
                    cfg=cfg, mode=modes["attn"], causal=False,
                    positions_q=None, positions_kv=None,
                    cache={"k": ck, "v": cv, "static": True}, **kw)
        h = h + c
        h = h + blocks.mlp_apply(lp["mlp"], blocks.layernorm(lp["ln2"], h),
                                 "gelu", modes["mlp"], **kw)
        return h, new_self

    x, new_self = jax.lax.scan(
        body, x, (params["dec"], cache["self"], cache["cross_k"],
                  cache["cross_v"]), unroll=pctx.cfg.scan_unroll)
    x = blocks.layernorm(params["dec_norm"], x)
    logits = (x @ params["embed"]["table"].T)[:, 0]
    new_cache = dict(cache, self=new_self, pos=cache["pos"] + 1)
    return logits, new_cache
