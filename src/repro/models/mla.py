"""Multi-head Latent Attention (DeepSeek-V2/V3, arXiv:2412.19437).

Queries and keys/values are low-rank-compressed:

    c_q  = norm(x W_dq)                    (q_lora_rank)
    q    = c_q W_uq  -> per-head [q_nope | q_rope]
    c_kv = norm(x W_dkv)                   (kv_lora_rank)
    k_nope = c_kv W_uk, v = c_kv W_uv      (per head)
    k_rope = x W_kr                        (shared across heads)

Prefill/train: decompress and run blocked attention with QK dim
(nope+rope) and V dim v_head_dim.

Decode: the latent cache stores only (c_kv, k_rope) — 576 floats/token for
V3 — and uses weight absorption:
    score_h = (q_nope_h W_uk_h^T) . c_kv + q_rope_h . k_rope
    out_h   = (softmax . c_kv) W_uv_h
Absorption relies on linearity, so W_uq/W_uk/W_uv stay in the typical
operator; the MF technique applies to the down-projections W_dq/W_dkv
(the dominant prefill FLOPs) and the output projection
(DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.core.mf import ExecMode
from repro.models import blocks
from repro.models.attention import blocked_attention, NEG_INF


def mla_init(key: jax.Array, d_model: int, n_heads: int, mla: MLAConfig, *,
             mf: bool, dtype: Any = jnp.float32) -> dict:
    ks = jax.random.split(key, 7)
    dn, dr, dv = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    mk = lambda k, i, o, use_mf: blocks.proj_init(k, i, o, bias=False,
                                                  mf=use_mf, dtype=dtype)
    return {
        "dq": mk(ks[0], d_model, mla.q_lora_rank, mf),
        "q_norm": blocks.rmsnorm_init(mla.q_lora_rank, dtype),
        "uq": mk(ks[1], mla.q_lora_rank, n_heads * (dn + dr), False),
        "dkv": mk(ks[2], d_model, mla.kv_lora_rank, mf),
        "kv_norm": blocks.rmsnorm_init(mla.kv_lora_rank, dtype),
        "kr": mk(ks[3], d_model, dr, False),
        "uk": mk(ks[4], mla.kv_lora_rank, n_heads * dn, False),
        "uv": mk(ks[5], mla.kv_lora_rank, n_heads * dv, False),
        "o": mk(ks[6], n_heads * dv, d_model, mf),
    }


def mla_apply(p: dict, x: jax.Array, *, n_heads: int, mla: MLAConfig,
              rope_theta: float, positions: jax.Array,
              mode: ExecMode | str = ExecMode.REGULAR,
              cache: Optional[dict] = None, attn_block: int = 1024,
              attn_block_skip: bool = False, **kw
              ) -> tuple[jax.Array, Optional[dict]]:
    b, t, _ = x.shape
    dn, dr, dv = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim

    cq = blocks.rmsnorm(p["q_norm"], blocks.proj_apply(p["dq"], x, mode, **kw))
    q = blocks.proj_apply(p["uq"], cq).reshape(b, t, n_heads, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = blocks.apply_rope(q_rope, positions, rope_theta)

    ckv = blocks.rmsnorm(p["kv_norm"],
                         blocks.proj_apply(p["dkv"], x, mode, **kw))
    k_rope = blocks.apply_rope(
        blocks.proj_apply(p["kr"], x)[:, :, None, :], positions, rope_theta)

    if cache is None:
        # ---- prefill/train: decompress, blocked attention ---------------
        k_nope = blocks.proj_apply(p["uk"], ckv).reshape(b, t, n_heads, dn)
        v = blocks.proj_apply(p["uv"], ckv).reshape(b, t, n_heads, dv)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, t, n_heads, dr))], axis=-1)
        out = blocked_attention(q_full, k_full, v, causal=True,
                                block=attn_block,
                                block_skip=attn_block_skip)
        new_cache = None
    else:
        # ---- decode: latent cache + weight absorption --------------------
        idx = cache["len"]
        ckv_cache = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0)))(cache["ckv"], ckv.astype(cache["ckv"].dtype), idx)
        kr_cache = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0)))(cache["kr"],
                           k_rope[:, :, 0, :].astype(cache["kr"].dtype), idx)
        s = ckv_cache.shape[1]
        w_uk = p["uk"]["w"].reshape(-1, n_heads, dn)        # (rank, H, dn)
        q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        scores = (jnp.einsum("bhr,bsr->bhs", q_abs,
                             ckv_cache.astype(jnp.float32))
                  + jnp.einsum("bhd,bsd->bhs",
                               q_rope[:, 0].astype(jnp.float32),
                               kr_cache.astype(jnp.float32)))
        scores = scores / math.sqrt(dn + dr)
        valid = jnp.arange(s)[None, :] < (idx + 1)[:, None]
        scores = jnp.where(valid[:, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhs,bsr->bhr", probs,
                         ckv_cache.astype(jnp.float32))     # (B,H,rank)
        w_uv = p["uv"]["w"].reshape(-1, n_heads, dv)
        out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv.astype(jnp.float32))
        out = out[:, None].astype(x.dtype)                  # (B,1,H,dv)
        new_cache = {"ckv": ckv_cache, "kr": kr_cache, "len": idx + 1}

    y = blocks.proj_apply(p["o"], out.reshape(b, t, n_heads * dv), mode, **kw)
    return y, new_cache


def mla_init_cache(batch: int, max_len: int, mla: MLAConfig,
                   dtype: Any = jnp.bfloat16) -> dict:
    return {
        "ckv": jnp.zeros((batch, max_len, mla.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, max_len, mla.qk_rope_head_dim), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }
