"""Unified decoder-only LM covering all assigned architectures.

A model is a stack of pattern periods (cfg.block_pattern); homogeneous
models are the 1-element pattern ('attn',). Parameters for each pattern
position are stacked over periods and executed with `jax.lax.scan` (one
traced copy of each distinct block kind — compile time and HLO size stay
flat in depth). Remainder layers (depth not divisible by the pattern) run
unrolled as the tail.

Block kinds: 'attn' (GQA or MLA per cfg.attn_type, + MLP or MoE),
'local_attn' (sliding window), 'rglru' (Griffin recurrent block + MLP),
'mlstm'/'slstm' (xLSTM, self-contained).

MF-Net integration: `resolve_modes` maps the config's MFTechniqueConfig
to an ExecMode per projection group — the paper's mixed mapping. Embeds,
routers, gates and the LM head are always the typical operator.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.mf import ExecMode
from repro.models import attention, blocks, mla as mla_mod, moe as moe_mod
from repro.models import rglru as rglru_mod, xlstm as xlstm_mod


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    """Runtime distribution context; None mesh -> single-process paths."""

    mesh: Any = None
    cfg: ParallelConfig = dataclasses.field(default_factory=ParallelConfig)

    @property
    def active(self) -> bool:
        return self.mesh is not None


def resolve_modes(cfg: ModelConfig) -> dict[str, ExecMode]:
    """Projection-group -> ExecMode under the mixed-mapping policy."""
    if not cfg.mf.enabled:
        off = ExecMode.REGULAR
        return {"attn": off, "mlp": off, "expert": off}
    m = ExecMode(cfg.mf.mode)
    return {
        "attn": m if cfg.mf.attn_qkv else ExecMode.REGULAR,
        "mlp": m if cfg.mf.mlp else ExecMode.REGULAR,
        "expert": m if cfg.mf.experts else ExecMode.REGULAR,
    }


def _mf_kw(cfg: ModelConfig) -> dict:
    kw = {"delta_sigma": cfg.mf.delta_sigma, "delta_coeff": cfg.mf.delta_coeff}
    if cfg.mf.mode == "cim_sim":
        kw["cim_cfg"] = cfg.mf.cim
    return kw


# ---------------------------------------------------------------------------
# Block init/apply dispatch
# ---------------------------------------------------------------------------

def _block_init(key: jax.Array, cfg: ModelConfig, kind: str) -> dict:
    dtype = cfg.dtype
    k1, k2, k3 = jax.random.split(key, 3)
    use_mf = cfg.mf.enabled
    if kind in ("attn", "local_attn"):
        p = {"ln1": blocks.norm_init(cfg.norm_type, cfg.d_model, dtype)}
        if cfg.attn_type == "mla" and kind == "attn":
            p["attn"] = mla_mod.mla_init(k1, cfg.d_model, cfg.n_heads,
                                         cfg.mla, mf=use_mf and cfg.mf.attn_qkv,
                                         dtype=dtype)
        else:
            p["attn"] = attention.attn_init(
                k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.resolved_head_dim, qkv_bias=cfg.qkv_bias,
                qk_norm=cfg.qk_norm, mf=use_mf and cfg.mf.attn_qkv,
                dtype=dtype)
        p["ln2"] = blocks.norm_init(cfg.norm_type, cfg.d_model, dtype)
        if cfg.moe is not None:
            p["moe"] = moe_mod.moe_init(
                k2, cfg.d_model, cfg.moe.d_ff_expert or cfg.d_ff,
                cfg.moe.n_experts, cfg.moe.n_shared, cfg.moe.top_k,
                mf=use_mf and cfg.mf.experts, dtype=dtype)
        else:
            p["mlp"] = blocks.mlp_init(k2, cfg.d_model, cfg.d_ff,
                                       cfg.mlp_type,
                                       mf=use_mf and cfg.mf.mlp, dtype=dtype)
        return p
    if kind == "rglru":
        return {
            "ln1": blocks.norm_init(cfg.norm_type, cfg.d_model, dtype),
            "rec": rglru_mod.rglru_init(
                k1, cfg.d_model, cfg.lru_width or cfg.d_model,
                cfg.conv_width, mf=use_mf and cfg.mf.attn_qkv, dtype=dtype),
            "ln2": blocks.norm_init(cfg.norm_type, cfg.d_model, dtype),
            "mlp": blocks.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_type,
                                   mf=use_mf and cfg.mf.mlp, dtype=dtype),
        }
    if kind == "mlstm":
        return {"block": xlstm_mod.mlstm_init(
            k1, cfg.d_model, cfg.n_heads, mf=use_mf and cfg.mf.mlp,
            conv_width=cfg.conv_width, dtype=dtype)}
    if kind == "slstm":
        return {"block": xlstm_mod.slstm_init(
            k1, cfg.d_model, cfg.n_heads, mf=use_mf and cfg.mf.mlp,
            dtype=dtype)}
    raise ValueError(kind)  # pragma: no cover


def _moe_apply(p: dict, x: jax.Array, cfg: ModelConfig, pctx: ParallelContext,
               mode: ExecMode, **kw) -> tuple[jax.Array, jax.Array]:
    mcfg = cfg.moe
    if not (pctx.active and pctx.cfg.use_ep):
        return moe_mod.moe_apply_dense(p, x, top_k=mcfg.top_k, mode=mode,
                                       **kw)
    from jax.sharding import PartitionSpec as P
    pcfg = pctx.cfg
    dp = pcfg.dp_axes if len(pcfg.dp_axes) > 1 else pcfg.dp_axes[0]
    tp = pcfg.tp_axis
    ep = pcfg.ep_axes if len(pcfg.ep_axes) > 1 else pcfg.ep_axes[0]
    b, t, d = x.shape
    mesh_sizes = dict(zip(pctx.mesh.axis_names, pctx.mesh.devices.shape))
    tp_size = mesh_sizes.get(tp, 1)
    seq_shardable = t % tp_size == 0 and t >= tp_size
    all_axes = tuple(pcfg.dp_axes) + (tp,)

    if seq_shardable:
        # Training/prefill: tokens distinct per (dp, tp) shard — sequence-
        # parallel region around the MoE (DeepSeek pattern).
        def ep_fn(pp, xx):
            s = xx.shape[0] * xx.shape[1]
            y, aux = moe_mod.moe_apply_ep(
                pp, xx.reshape(s, d), top_k=mcfg.top_k, ep_axis=ep,
                capacity_factor=mcfg.capacity_factor,
                expert_capacity_factor=mcfg.expert_capacity_factor,
                mode=mode,
                fuse_single_expert=pcfg.moe_fuse_single_expert, **kw)
            return y.reshape(xx.shape), jax.lax.pmean(aux, all_axes)

        x_spec = P(dp, tp, None)
        out_spec = P(dp, tp, None)
    else:
        # Decode (t == 1): tokens replicated over tp inside the region;
        # each tp shard takes its batch slice, runs EP, and the slices are
        # reassembled with an all_gather — no duplicate expert sends.
        def ep_fn(pp, xx):
            bl = xx.shape[0]
            chunk = -(-bl // tp_size)
            pad = chunk * tp_size - bl
            xp = jnp.pad(xx.reshape(bl, d), ((0, pad), (0, 0)))
            mine = jax.lax.dynamic_slice_in_dim(
                xp, jax.lax.axis_index(tp) * chunk, chunk, axis=0)
            y, aux = moe_mod.moe_apply_ep(
                pp, mine, top_k=mcfg.top_k, ep_axis=ep,
                capacity_factor=mcfg.capacity_factor,
                expert_capacity_factor=mcfg.expert_capacity_factor,
                mode=mode,
                fuse_single_expert=pcfg.moe_fuse_single_expert, **kw)
            y_full = jax.lax.all_gather(y, tp, axis=0, tiled=True)[:bl]
            return (y_full.reshape(xx.shape),
                    jax.lax.pmean(aux, all_axes))

        x_spec = P(dp, None, None)
        out_spec = P(dp, None, None)

    expert_specs = jax.tree.map(lambda _: P(ep), p["experts"])
    pspecs = {"router": jax.tree.map(lambda _: P(), p["router"]),
              "experts": expert_specs}
    if "shared" in p:
        pspecs["shared"] = jax.tree.map(lambda _: P(), p["shared"])
    from repro.launch.mesh import shard_map
    return shard_map(
        ep_fn, mesh=pctx.mesh,
        in_specs=(pspecs, x_spec),
        out_specs=(out_spec, P()),
        check_vma=False,
    )(p, x)


def _block_apply(p: dict, x: jax.Array, kind: str, cfg: ModelConfig,
                 modes: dict, positions: jax.Array, pctx: ParallelContext,
                 cache: Optional[dict] = None,
                 prefill_valid: Optional[jax.Array] = None
                 ) -> tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (x_out, new_cache, aux_loss)."""
    kw = _mf_kw(cfg)
    aux = jnp.zeros((), jnp.float32)
    new_cache: Optional[dict] = None
    if prefill_valid is not None and not (
            kind in ("attn", "local_attn") and cfg.attn_type != "mla"):
        raise ValueError(
            f"batched prefill is implemented for GQA attention caches "
            f"only; block kind {kind!r} (attn_type={cfg.attn_type}) must "
            f"ingest prompts through the decode step")
    if kind in ("attn", "local_attn"):
        window = cfg.window if kind == "local_attn" else (
            cfg.window if cfg.block_pattern is None else None)
        h = blocks.norm_apply(cfg.norm_type, p["ln1"], x)
        attn_cache = None if cache is None else cache.get("attn")
        if cfg.attn_type == "mla" and kind == "attn":
            a, attn_cache = mla_mod.mla_apply(
                p["attn"], h, n_heads=cfg.n_heads, mla=cfg.mla,
                rope_theta=cfg.rope_theta, positions=positions,
                mode=modes["attn"], cache=attn_cache,
                attn_block=cfg.attn_block,
                attn_block_skip=cfg.attn_block_skip, **kw)
        else:
            a, attn_cache = attention.gqa_apply(
                p["attn"], h, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
                rope_theta=cfg.rope_theta, positions=positions,
                mode=modes["attn"], qk_norm=cfg.qk_norm, causal=True,
                window=window, cache=attn_cache,
                attn_block=cfg.attn_block,
                attn_block_skip=cfg.attn_block_skip, pctx=pctx,
                prefill_valid=prefill_valid, **kw)
        x = x + a
        h = blocks.norm_apply(cfg.norm_type, p["ln2"], x)
        if cfg.moe is not None:
            f, aux = _moe_apply(p["moe"], h, cfg, pctx, modes["expert"], **kw)
            # named for the 'save_moe' remat policy: saving the MoE output
            # keeps backward from recomputing the expert all_to_alls.
            from jax.ad_checkpoint import checkpoint_name
            f = checkpoint_name(f, "moe_out")
        else:
            f = blocks.mlp_apply(p["mlp"], h, cfg.mlp_type, modes["mlp"],
                                 **kw)
        x = x + f
        if cache is not None:
            new_cache = {"attn": attn_cache}
        return x, new_cache, aux
    if kind == "rglru":
        h = blocks.norm_apply(cfg.norm_type, p["ln1"], x)
        rec_state = None if cache is None else cache.get("rec")
        r, rec_state = rglru_mod.rglru_block_apply(
            p["rec"], h, mode=modes["attn"], state=rec_state, **kw)
        x = x + r
        h = blocks.norm_apply(cfg.norm_type, p["ln2"], x)
        x = x + blocks.mlp_apply(p["mlp"], h, cfg.mlp_type, modes["mlp"],
                                 **kw)
        if cache is not None:
            new_cache = {"rec": rec_state}
        return x, new_cache, aux
    if kind == "mlstm":
        state = None if cache is None else cache.get("cell")
        y, state = xlstm_mod.mlstm_apply(p["block"], x, cfg.n_heads,
                                         mode=modes["mlp"], state=state, **kw)
        if cache is not None:
            new_cache = {"cell": state}
        return x + y, new_cache, aux
    if kind == "slstm":
        state = None if cache is None else cache.get("cell")
        y, state = xlstm_mod.slstm_apply(p["block"], x, cfg.n_heads,
                                         mode=modes["mlp"], state=state, **kw)
        if cache is not None:
            new_cache = {"cell": state}
        return x + y, new_cache, aux
    raise ValueError(kind)  # pragma: no cover


def _block_init_cache(cfg: ModelConfig, kind: str, batch: int,
                      max_len: int) -> dict:
    if kind in ("attn", "local_attn"):
        window = cfg.window if kind == "local_attn" else None
        # Local attention keeps a ring buffer of the window only — this is
        # what makes long_500k decode O(window) instead of O(T) memory.
        size = min(max_len, window) if window else max_len
        if cfg.attn_type == "mla" and kind == "attn":
            return {"attn": mla_mod.mla_init_cache(batch, max_len, cfg.mla,
                                                   dtype=cfg.dtype)}
        return {"attn": attention.init_kv_cache(
            batch, size, cfg.n_kv_heads, cfg.resolved_head_dim,
            dtype=cfg.dtype)}
    if kind == "rglru":
        return {"rec": rglru_mod.rglru_init_state(
            batch, cfg.lru_width or cfg.d_model, cfg.conv_width,
            dtype=cfg.dtype)}
    if kind == "mlstm":
        return {"cell": xlstm_mod.mlstm_init_state(batch, cfg.d_model,
                                                   cfg.n_heads,
                                                   cfg.conv_width)}
    if kind == "slstm":
        return {"cell": xlstm_mod.slstm_init_state(batch, cfg.d_model)}
    raise ValueError(kind)  # pragma: no cover


# ---------------------------------------------------------------------------
# Model init / apply
# ---------------------------------------------------------------------------

def _periods(cfg: ModelConfig) -> tuple[int, int]:
    plen = len(cfg.pattern)
    return cfg.n_layers // plen, cfg.n_layers % plen


def lm_init(key: jax.Array, cfg: ModelConfig) -> dict:
    n_periods, tail = _periods(cfg)
    keys = jax.random.split(key, 4)
    params: dict = {"embed": blocks.embed_init(keys[0], cfg.vocab_size,
                                               cfg.d_model, cfg.dtype)}
    if cfg.vision_tokens:
        params["vision_proj"] = blocks.proj_init(
            jax.random.fold_in(keys[0], 1), cfg.vision_embed_dim,
            cfg.d_model, bias=True, mf=False, dtype=cfg.dtype)
    stacked = []
    for pos, kind in enumerate(cfg.pattern):
        pk = jax.random.split(jax.random.fold_in(keys[1], pos), n_periods)
        stacked.append(jax.vmap(lambda k: _block_init(k, cfg, kind))(pk))
    params["layers"] = tuple(stacked)
    params["tail"] = tuple(
        _block_init(jax.random.fold_in(keys[2], i), cfg, cfg.pattern[i])
        for i in range(tail))
    params["final_norm"] = blocks.norm_init(cfg.norm_type, cfg.d_model,
                                            cfg.dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = blocks.proj_init(keys[3], cfg.d_model,
                                             cfg.vocab_size, bias=False,
                                             mf=False, dtype=cfg.dtype)
    return params


def _embed_inputs(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    x = blocks.embed_apply(params["embed"], batch["tokens"])
    if cfg.vision_tokens and "vision_embeds" in batch:
        v = blocks.proj_apply(params["vision_proj"], batch["vision_embeds"])
        x = jnp.concatenate([v.astype(x.dtype), x], axis=1)
    return x


def lm_forward(params: dict, batch: dict, cfg: ModelConfig,
               pctx: ParallelContext = ParallelContext()
               ) -> tuple[jax.Array, jax.Array]:
    """Training/prefill forward. batch['tokens']: (B,T). -> (logits, aux)."""
    modes = resolve_modes(cfg)
    x = _embed_inputs(params, cfg, batch)
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    n_periods, tail = _periods(cfg)

    def period_body(carry, period_params):
        h, aux = carry
        for pos, kind in enumerate(cfg.pattern):
            h, _, a = _block_apply(period_params[pos], h, kind, cfg, modes,
                                   positions, pctx)
            aux = aux + a
        return (h, aux), None

    body = period_body
    if pctx.cfg.remat == "block":
        body = jax.checkpoint(period_body, prevent_cse=False)
    elif pctx.cfg.remat == "save_moe":
        body = jax.checkpoint(
            period_body, prevent_cse=False,
            policy=jax.checkpoint_policies.save_only_these_names("moe_out"))
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"],
                               unroll=pctx.cfg.scan_unroll)
    for i, p in enumerate(params["tail"]):
        x, _, a = _block_apply(p, x, cfg.pattern[i], cfg, modes, positions,
                               pctx)
        aux = aux + a
    x = blocks.norm_apply(cfg.norm_type, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = blocks.lm_head_apply(None, x,
                                      tied_table=params["embed"]["table"])
    else:
        logits = blocks.lm_head_apply(params["lm_head"], x)
    return logits, aux / max(cfg.n_layers, 1)


def serve_prefill(params: dict, batch: dict, cfg: ModelConfig,
                  pctx: ParallelContext = ParallelContext()) -> jax.Array:
    """Prefill forward returning only the last-position logits (the
    full (B, T, vocab) logits tensor is never materialised — XLA DCEs
    the other positions' head matmul)."""
    modes = resolve_modes(cfg)
    x = _embed_inputs(params, cfg, batch)
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    n_periods, tail = _periods(cfg)

    def period_body(carry, period_params):
        h = carry
        for pos, kind in enumerate(cfg.pattern):
            h, _, _ = _block_apply(period_params[pos], h, kind, cfg, modes,
                                   positions, pctx)
        return h, None

    body = period_body
    if pctx.cfg.remat == "block":
        body = jax.checkpoint(period_body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"],
                        unroll=pctx.cfg.scan_unroll)
    for i, p in enumerate(params["tail"]):
        x, _, _ = _block_apply(p, x, cfg.pattern[i], cfg, modes, positions,
                               pctx)
    x = blocks.norm_apply(cfg.norm_type, params["final_norm"], x[:, -1:])
    if cfg.tie_embeddings:
        logits = blocks.lm_head_apply(None, x,
                                      tied_table=params["embed"]["table"])
    else:
        logits = blocks.lm_head_apply(params["lm_head"], x)
    return logits[:, 0]


def lm_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    n_periods, tail = _periods(cfg)
    stacked = []
    for kind in cfg.pattern:
        one = _block_init_cache(cfg, kind, batch, max_len)
        stacked.append(jax.tree.map(
            lambda v: jnp.broadcast_to(v, (n_periods,) + v.shape).copy() if
            n_periods else v[None][:0], one))
    return {
        "layers": tuple(stacked),
        "tail": tuple(_block_init_cache(cfg, cfg.pattern[i], batch, max_len)
                      for i in range(tail)),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def _block_cache_pspec(cfg: ModelConfig, kind: str, pcfg, axis_sizes: dict
                       ) -> dict:
    """PartitionSpec tree mirroring `_block_init_cache` for one block.

    Attention caches: batch over DP, sequence over the `model` axis
    (flash-decode SP — works for any kv-head count). Recurrent states:
    batch over DP, channel width over `model` when divisible.
    """
    from jax.sharding import PartitionSpec as P
    dp = pcfg.dp_axes if len(pcfg.dp_axes) > 1 else pcfg.dp_axes[0]
    tp = pcfg.tp_axis
    tps = axis_sizes.get(tp, 1)

    def g(dim):  # guard divisibility
        return tp if tps > 1 and dim % tps == 0 else None

    d = cfg.d_model
    if kind in ("attn", "local_attn"):
        if cfg.attn_type == "mla" and kind == "attn":
            return {"attn": {"ckv": P(dp, tp, None), "kr": P(dp, tp, None),
                             "len": P(dp)}}
        return {"attn": {"k": P(dp, tp, None, None),
                         "v": P(dp, tp, None, None), "len": P(dp)}}
    if kind == "rglru":
        w = cfg.lru_width or d
        return {"rec": {"conv": P(dp, None, g(w)), "h": P(dp, g(w))}}
    if kind == "mlstm":
        return {"cell": {"conv": P(dp, None, g(2 * d)),
                         "cell": {"c": P(dp, None, None, None),
                                  "n": P(dp, None, None),
                                  "m": P(dp, None)}}}
    if kind == "slstm":
        return {"cell": {"cell": {"h": P(dp, g(d)), "c": P(dp, g(d)),
                                  "n": P(dp, g(d)), "m": P(dp, g(d))}}}
    raise ValueError(kind)  # pragma: no cover


def lm_cache_pspecs(cfg: ModelConfig, cache_tree, pcfg, axis_sizes: dict):
    """Spec tree matching `lm_init_cache` (stacked periods get a leading
    None dim)."""
    from jax.sharding import PartitionSpec as P
    n_periods, tail = _periods(cfg)
    dp = pcfg.dp_axes if len(pcfg.dp_axes) > 1 else pcfg.dp_axes[0]
    stacked = []
    for kind in cfg.pattern:
        one = _block_cache_pspec(cfg, kind, pcfg, axis_sizes)
        stacked.append(jax.tree.map(
            lambda s: P(*((None,) + tuple(s))), one,
            is_leaf=lambda x: isinstance(x, P)))
    return {
        "layers": tuple(stacked),
        "tail": tuple(_block_cache_pspec(cfg, cfg.pattern[i], pcfg,
                                         axis_sizes) for i in range(tail)),
        "pos": P(dp),
    }


def lm_decode_step(params: dict, cache: dict, tokens: jax.Array,
                   cfg: ModelConfig,
                   pctx: ParallelContext = ParallelContext()
                   ) -> tuple[jax.Array, dict]:
    """One decode step. tokens: (B,) -> (logits (B,V), new cache)."""
    modes = resolve_modes(cfg)
    x = blocks.embed_apply(params["embed"], tokens[:, None])
    positions = cache["pos"][:, None]

    def period_body(h, inp):
        period_params, period_cache = inp
        new_caches = []
        for pos, kind in enumerate(cfg.pattern):
            h, nc, _ = _block_apply(period_params[pos], h, kind, cfg, modes,
                                    positions, pctx, cache=period_cache[pos])
            new_caches.append(nc)
        return h, tuple(new_caches)

    x, new_layer_caches = jax.lax.scan(
        period_body, x, (params["layers"], cache["layers"]),
        unroll=pctx.cfg.scan_unroll)
    new_tail = []
    for i, p in enumerate(params["tail"]):
        x, nc, _ = _block_apply(p, x, cfg.pattern[i], cfg, modes, positions,
                                pctx, cache=cache["tail"][i])
        new_tail.append(nc)
    x = blocks.norm_apply(cfg.norm_type, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = blocks.lm_head_apply(None, x,
                                      tied_table=params["embed"]["table"])
    else:
        logits = blocks.lm_head_apply(params["lm_head"], x)
    new_cache = {"layers": new_layer_caches, "tail": tuple(new_tail),
                 "pos": cache["pos"] + 1}
    return logits[:, 0], new_cache


def prefill_supported(cfg: ModelConfig) -> bool:
    """True when ``lm_prefill_cache`` can ingest prompts for this config:
    every block is a GQA attention block with a non-ring (full-length) KV
    cache. Recurrent mixers (rgLRU/xLSTM) and MLA caches fall back to
    prefill-as-decode in the serve engine."""
    kinds_ok = all(k in ("attn", "local_attn") for k in cfg.pattern)
    return kinds_ok and cfg.attn_type != "mla" and cfg.window is None


def lm_prefill_cache(params: dict, cache: dict, tokens: jax.Array,
                     valid: jax.Array, cfg: ModelConfig,
                     pctx: ParallelContext = ParallelContext()) -> dict:
    """Batched prompt ingestion: fold a (B, T) prompt slab into the cache.

    The T > 1 prompt axis rides the same collapsed step-time matmuls as
    decode (every CIM projection reshapes (..., K) -> (B*T, K), so
    programmed/swapped macro execution is identical per position) while
    attention runs causally over the slab — prompt ingestion stops paying
    one decode step per token. ``valid`` gives each slot's real prompt
    length within the slab (0 = slot not participating: its cache rows,
    length and position are left untouched, so mid-decode neighbours in a
    serving batch are safe). Participating slots must be fresh
    (``cache['pos'] == 0``). Returns the new cache only — sampling the
    first output token happens in the ordinary decode step that feeds the
    last prompt token.
    """
    if not prefill_supported(cfg):
        raise ValueError(
            f"{cfg.name}: batched prefill needs an all-GQA-attention "
            f"pattern with a full-length KV cache")
    modes = resolve_modes(cfg)
    x = blocks.embed_apply(params["embed"], tokens)
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))

    def period_body(h, inp):
        period_params, period_cache = inp
        new_caches = []
        for pos, kind in enumerate(cfg.pattern):
            h, nc, _ = _block_apply(period_params[pos], h, kind, cfg, modes,
                                    positions, pctx,
                                    cache=period_cache[pos],
                                    prefill_valid=valid)
            new_caches.append(nc)
        return h, tuple(new_caches)

    x, new_layer_caches = jax.lax.scan(
        period_body, x, (params["layers"], cache["layers"]),
        unroll=pctx.cfg.scan_unroll)
    new_tail = []
    for i, p in enumerate(params["tail"]):
        x, nc, _ = _block_apply(p, x, cfg.pattern[i], cfg, modes, positions,
                                pctx, cache=cache["tail"][i],
                                prefill_valid=valid)
        new_tail.append(nc)
    # No final norm / LM head: prefill produces cache state, not logits.
    return {"layers": new_layer_caches, "tail": tuple(new_tail),
            "pos": cache["pos"] + valid.astype(cache["pos"].dtype)}


def lm_loss(params: dict, batch: dict, cfg: ModelConfig,
            pctx: ParallelContext = ParallelContext(),
            aux_weight: float = 0.01) -> tuple[jax.Array, dict]:
    logits, aux = lm_forward(params, batch, cfg, pctx)
    targets = batch["targets"]
    if cfg.vision_tokens and "vision_embeds" in batch:
        logits = logits[:, -targets.shape[1]:]
    valid = (targets >= 0)
    tgt = jnp.maximum(targets, 0)
    # One-hot CE: elementwise mask-and-reduce keeps the (B,T,V) logits
    # sharded on the vocab axis under GSPMD (take_along_axis would force an
    # all-gather of the full logits — fatal at 152k vocab x 1M tokens).
    loss = _sharded_ce(logits, tgt, valid)
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux}


def _sharded_ce(logits: jax.Array, tgt: jax.Array, valid: jax.Array
                ) -> jax.Array:
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    v = logits.shape[-1]
    onehot = (jnp.arange(v, dtype=jnp.int32)[None, None, :]
              == tgt[..., None])
    tl = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    nll = lse - tl
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)
