"""Typed metrics registry: counters, gauges, fixed-bucket histograms.

The serving stack's end-of-run reports (``ServeReport``,
``TrafficReport``) are *views over this registry*: the engine and the
batcher increment named metrics as events happen, report builders read
cumulative values (or window deltas via :meth:`MetricsRegistry.snapshot`
/ :meth:`MetricsRegistry.delta`), and nothing is counted in two places —
the invariant that makes windowed reports sum to run totals even when a
recalibration or an eviction straddles a window boundary.

Conventions (Prometheus-compatible, see ``repro.obs.export``):

* **Counter** — monotonically non-decreasing float. Windowed views take
  deltas between snapshots; deltas over disjoint windows sum exactly to
  the full-run delta.
* **Gauge** — a level (current queue depth, retired slots NOW). Levels
  are never summed across windows.
* **Histogram** — fixed, immutable bucket edges chosen at registration;
  observations land in ``counts`` (len(edges) + 1, the last bucket is
  +inf) plus ``sum``/``count`` scalars. ``merge`` is commutative and
  associative (element-wise adds), so shard-parallel collection is
  order-invariant — the same discipline as the calibration lab's
  observers.

Metric names: ``[a-zA-Z_:][a-zA-Z0-9_:]*`` (the Prometheus charset), so
every registered metric can be exposed verbatim.
"""
# repro-lint: module=observability

from __future__ import annotations

import re
from typing import Iterable, Optional, Union

import numpy as np

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")

# Default latency-style edges (seconds): 1 ms .. 100 s, log-ish.
LATENCY_EDGES_S = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0)


class Counter:
    """Monotonic cumulative count (float-valued; token/bit totals fit)."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0

    def inc(self, n: Union[int, float] = 1) -> None:
        if n < 0:
            raise ValueError(
                f"counter {self.name}: negative increment {n} — use a "
                f"gauge for values that go down")
        self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """A level: set to the current value, read at report time."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0

    def set(self, v: Union[int, float]) -> None:
        self._value = float(v)

    def inc(self, n: Union[int, float] = 1) -> None:
        self._value += n

    def dec(self, n: Union[int, float] = 1) -> None:
        self._value -= n

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed-bucket histogram with order-invariant merge.

    ``edges`` are the inclusive upper bounds of the finite buckets
    (strictly ascending); one overflow bucket catches everything above
    the last edge. ``counts`` is a float64 array so a histogram is a
    valid fixed-shape pytree leaf wherever one is needed.
    """

    kind = "histogram"

    def __init__(self, name: str, edges: Iterable[float], help: str = ""):
        self.name = name
        self.help = help
        e = tuple(float(x) for x in edges)
        if len(e) < 1 or any(b <= a for a, b in zip(e, e[1:])):
            raise ValueError(
                f"histogram {name}: edges must be non-empty and strictly "
                f"ascending, got {e}")
        self.edges = e
        self.counts = np.zeros((len(e) + 1,), np.float64)
        self.sum = 0.0

    @property
    def count(self) -> float:
        return float(self.counts.sum())

    def observe(self, x: Union[int, float]) -> None:
        x = float(x)
        self.counts[np.searchsorted(self.edges, x, side="left")] += 1.0
        self.sum += x

    def observe_many(self, xs) -> None:
        xs = np.asarray(xs, np.float64).ravel()
        if xs.size == 0:
            return
        idx = np.searchsorted(self.edges, xs, side="left")
        np.add.at(self.counts, idx, 1.0)
        self.sum += float(xs.sum())

    def merge(self, other: "Histogram") -> None:
        """Fold ``other`` in (element-wise adds: commutative/associative,
        so any merge order yields the identical state)."""
        if other.edges != self.edges:
            raise ValueError(
                f"histogram {self.name}: merging incompatible edges "
                f"{other.edges} into {self.edges}")
        self.counts += other.counts
        self.sum += other.sum

    def quantile(self, q: float) -> float:
        """Deterministic bucket-interpolated quantile estimate (q in
        [0, 1]): linear within the bucket the rank falls in, clamped to
        the last finite edge for overflow-bucket ranks. An *estimate* —
        exact report percentiles come from the raw samples
        (``repro.traffic.report.percentile``); this is the dashboard
        view over merged, sample-free histogram state."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile q={q} outside [0, 1]")
        total = self.counts.sum()
        if total == 0:
            return float("nan")
        rank = q * total
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, rank, side="left"))
        if i >= len(self.edges):          # overflow bucket: clamp
            return self.edges[-1]
        lo = 0.0 if i == 0 else self.edges[i - 1]
        hi = self.edges[i]
        prev = 0.0 if i == 0 else float(cum[i - 1])
        inb = float(self.counts[i])
        frac = (rank - prev) / inb if inb > 0 else 0.0
        return lo + (hi - lo) * min(max(frac, 0.0), 1.0)


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Name-keyed metric store with get-or-create registration.

    Re-registering an existing name returns the existing metric when the
    type (and histogram edges) agree and raises otherwise — two call
    sites can never silently count into differently-shaped state.
    """

    def __init__(self):
        self._metrics: dict[str, Metric] = {}

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str) -> Metric:
        return self._metrics[name]

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def metrics(self) -> list[Metric]:
        return [self._metrics[n] for n in self.names()]

    def _register(self, name: str, make, check) -> Metric:
        if not _NAME_RE.match(name):
            raise ValueError(
                f"metric name {name!r} is not Prometheus-exposable "
                f"([a-zA-Z_:][a-zA-Z0-9_:]*)")
        existing = self._metrics.get(name)
        if existing is not None:
            check(existing)
            return existing
        m = make()
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        def check(m):
            if not isinstance(m, Counter):
                raise ValueError(f"{name} is already a {m.kind}")
        return self._register(name, lambda: Counter(name, help), check)

    def gauge(self, name: str, help: str = "") -> Gauge:
        def check(m):
            if not isinstance(m, Gauge):
                raise ValueError(f"{name} is already a {m.kind}")
        return self._register(name, lambda: Gauge(name, help), check)

    def histogram(self, name: str, edges: Iterable[float],
                  help: str = "") -> Histogram:
        edges = tuple(float(x) for x in edges)

        def check(m):
            if not isinstance(m, Histogram):
                raise ValueError(f"{name} is already a {m.kind}")
            if m.edges != edges:
                raise ValueError(
                    f"{name} is already registered with edges {m.edges}, "
                    f"re-registration asked for {edges}")
        return self._register(name, lambda: Histogram(name, edges, help),
                              check)

    # -- windowed views ------------------------------------------------

    def snapshot(self) -> dict[str, float]:
        """Scalar state of every metric (histograms contribute their
        ``_sum`` / ``_count`` scalars) — feed to :meth:`delta` after a
        serving window for exact windowed counters."""
        out: dict[str, float] = {}
        for m in self.metrics():
            if isinstance(m, Histogram):
                out[f"{m.name}_sum"] = m.sum
                out[f"{m.name}_count"] = m.count
            else:
                out[m.name] = m.value
        return out

    def delta(self, before: Optional[dict[str, float]] = None
              ) -> dict[str, float]:
        """Counter/histogram-scalar deltas since ``before`` (gauges are
        levels: reported as-is, never differenced). Metrics registered
        after ``before`` was taken difference against zero."""
        now = self.snapshot()
        before = before or {}
        out: dict[str, float] = {}
        for name, v in now.items():
            base = self._metrics.get(name)
            if isinstance(base, Gauge):
                out[name] = v
            else:
                out[name] = v - before.get(name, 0.0)
        return out
