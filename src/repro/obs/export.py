"""Exports: Prometheus text exposition for metrics, JSONL for traces.

Both formats round-trip (``parse_prometheus`` /
:func:`read_trace_jsonl`), which is what the CI artifacts and the test
suite pin — an exported telemetry file is a faithful, loss-bounded
serialisation of the in-process state, not a pretty-print.

Prometheus exposition follows the text format version 0.0.4: ``# HELP``
/ ``# TYPE`` headers, histogram ``_bucket{le="..."}`` series with a
cumulative ``+Inf`` bucket, ``_sum`` and ``_count``. Floats are
serialised with ``repr`` so parsing recovers them exactly.
"""
# repro-lint: module=observability

from __future__ import annotations

import json
import math
from pathlib import Path
from typing import Iterable, Union

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import TraceBuffer, TraceEvent


def _fmt(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _esc(s: str) -> str:
    return s.replace("\\", "\\\\").replace("\n", "\\n")


def to_prometheus(registry: MetricsRegistry) -> str:
    """Text exposition of every registered metric (stable name order)."""
    lines: list[str] = []
    for m in registry.metrics():
        if m.help:
            lines.append(f"# HELP {m.name} {_esc(m.help)}")
        lines.append(f"# TYPE {m.name} {m.kind}")
        if isinstance(m, (Counter, Gauge)):
            lines.append(f"{m.name} {_fmt(m.value)}")
        elif isinstance(m, Histogram):
            cum = 0.0
            for edge, c in zip(m.edges, m.counts):
                cum += float(c)
                lines.append(
                    f'{m.name}_bucket{{le="{_fmt(edge)}"}} {_fmt(cum)}')
            cum += float(m.counts[-1])
            lines.append(f'{m.name}_bucket{{le="+Inf"}} {_fmt(cum)}')
            lines.append(f"{m.name}_sum {_fmt(m.sum)}")
            lines.append(f"{m.name}_count {_fmt(cum)}")
    return "\n".join(lines) + ("\n" if lines else "")


def parse_prometheus(text: str) -> dict[str, dict]:
    """Parse a :func:`to_prometheus` exposition back into
    ``{name: {"type": ..., "value": ...}}`` for counters/gauges and
    ``{"type": "histogram", "buckets": [(le, cumulative), ...],
    "sum": ..., "count": ...}`` for histograms. Supports exactly the
    subset this module emits (no labels beyond ``le``)."""
    out: dict[str, dict] = {}

    def entry(name: str) -> dict:
        return out.setdefault(name, {})

    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(None, 3)
            entry(name)["type"] = kind
            continue
        if line.startswith("#"):
            continue
        series, _, value = line.rpartition(" ")
        v = float(value)
        if '{le="' in series:
            base = series[:series.index("{")]
            le = series[series.index('le="') + 4:series.rindex('"')]
            name = base[:-len("_bucket")]
            entry(name).setdefault("buckets", []).append(
                (float("inf") if le == "+Inf" else float(le), v))
        elif series.endswith("_sum") and series[:-4] in out \
                and out[series[:-4]].get("type") == "histogram":
            entry(series[:-4])["sum"] = v
        elif series.endswith("_count") and series[:-6] in out \
                and out[series[:-6]].get("type") == "histogram":
            entry(series[:-6])["count"] = v
        else:
            entry(series)["value"] = v
    return out


# ---------------------------------------------------------------------------
# Trace JSONL.
# ---------------------------------------------------------------------------

def write_trace_jsonl(events: Union[TraceBuffer, Iterable[TraceEvent]],
                      path: Union[str, Path]) -> int:
    """One JSON object per line, emission order; returns lines written."""
    if isinstance(events, TraceBuffer):
        events = events.events()
    n = 0
    with open(path, "w", encoding="utf-8") as f:
        for ev in events:
            f.write(json.dumps(ev.to_json(), separators=(",", ":")))
            f.write("\n")
            n += 1
    return n


def read_trace_jsonl(path: Union[str, Path]) -> list[TraceEvent]:
    events: list[TraceEvent] = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(TraceEvent.from_json(json.loads(line)))
    return events
