"""Hardware-health timelines: per-slot drift/trim/retirement over time.

Consumes the trace bus's silicon events (``drift_probe``, ``retrim``,
``retire``, ``recal``, ``silicon_refresh``) and reconstructs what the
end-of-run ``DriftStatus`` log cannot show: *when* each tile slot's
offset residue grew, *which* probe tripped the alarm, which slots the
tiered re-trim pushed onto the coarse DAC and which it retired, and what
every recalibration cost in reload bits / nJ. The per-slot matrices are
available only when the bus was installed with ``detail=True`` (the
engine ships per-slot residue/tier vectors in those payloads); the
scalar trajectory (rel-L2, SQNR, clip ratio, alarm/recal marks) is
always reconstructable.

This is the substrate ROADMAP item 1 (multi-tenant fleets) builds
per-tenant accounting on, and what makes the collaborative macros'
6-12 dB SQNR yield floor debuggable: a slot-tier heatmap over service
age shows *where* in the fleet the floor comes from.
"""
# repro-lint: module=observability

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.obs.trace import TraceEvent

# Tier encoding shared with repro.silicon.instance.retrim_comparators.
TIER_FINE, TIER_COARSE, TIER_RETIRED, TIER_UNKNOWN = 0, 1, 2, -1
_TIER_GLYPHS = {TIER_UNKNOWN: " ", TIER_FINE: ".", TIER_COARSE: "o",
                TIER_RETIRED: "#"}


def rel_l2_to_sqnr_db(rel_l2: float) -> float:
    """Probe rel-L2 → SQNR in dB (the macro-zoo yield metric)."""
    if rel_l2 <= 0.0:
        return math.inf
    return -20.0 * math.log10(rel_l2)


@dataclasses.dataclass(frozen=True)
class ProbePoint:
    """One drift probe on the scalar health trajectory."""

    stream: int
    rel_l2: float
    sqnr_db: float
    max_clip_ratio: float
    alarm: bool
    recalibrated: bool


@dataclasses.dataclass
class FleetHealthTimeline:
    """Everything the silicon events say about one engine's fleet."""

    probes: list[ProbePoint]
    recal_streams: list[int]
    recal_reload_bits: list[int]
    recal_energy_nj: list[float]
    # (n_retrims, n_slots) int8 tier verdicts per retrim event, and the
    # probe residue matrix (n_probes, n_slots) in full-scale fractions —
    # empty (0, 0) when the bus carried no detail payloads.
    tier_streams: list[int]
    tiers: np.ndarray
    residue_fs: np.ndarray

    @property
    def alarms(self) -> list[int]:
        return [p.stream for p in self.probes if p.alarm]

    @property
    def retired_now(self) -> int:
        """Slots retired as of the LAST retrim (a level)."""
        if self.tiers.size == 0:
            return 0
        return int((self.tiers[-1] == TIER_RETIRED).sum())

    @property
    def coarse_now(self) -> int:
        if self.tiers.size == 0:
            return 0
        return int((self.tiers[-1] == TIER_COARSE).sum())


def _ordered(events: Iterable[TraceEvent]) -> list[TraceEvent]:
    return sorted(events, key=lambda e: e.seq)


def from_events(events: Iterable[TraceEvent],
                engine: Optional[int] = None) -> FleetHealthTimeline:
    """Reconstruct the health timeline from a trace (bus events or a
    re-read JSONL export); ``engine`` filters a multi-engine trace."""
    probes: list[ProbePoint] = []
    recal_streams: list[int] = []
    recal_bits: list[int] = []
    recal_nj: list[float] = []
    tier_streams: list[int] = []
    tier_rows: list[np.ndarray] = []
    residue_rows: list[np.ndarray] = []
    for ev in _ordered(events):
        if engine is not None and ev.engine is not None \
                and ev.engine != engine:
            continue
        if ev.kind == "drift_probe":
            d = ev.data
            rel = float(d.get("rel_l2", math.nan))
            probes.append(ProbePoint(
                stream=int(ev.stream or 0), rel_l2=rel,
                sqnr_db=rel_l2_to_sqnr_db(rel) if rel == rel else math.nan,
                max_clip_ratio=float(d.get("max_clip_ratio", math.nan)),
                alarm=bool(d.get("alarm", False)),
                recalibrated=bool(d.get("recalibrated", False))))
            if "residue_fs" in d:
                residue_rows.append(np.asarray(d["residue_fs"],
                                               np.float32))
        elif ev.kind == "recal":
            recal_streams.append(int(ev.stream or 0))
            recal_bits.append(int(ev.data.get("reload_bits", 0)))
            recal_nj.append(float(ev.data.get("energy_nj", 0.0)))
        elif ev.kind == "retrim":
            tier_streams.append(int(ev.stream or 0))
            if "tiers" in ev.data:
                tier_rows.append(np.asarray(ev.data["tiers"], np.int8))
    return FleetHealthTimeline(
        probes=probes, recal_streams=recal_streams,
        recal_reload_bits=recal_bits, recal_energy_nj=recal_nj,
        tier_streams=tier_streams,
        tiers=(np.stack(tier_rows) if tier_rows
               else np.zeros((0, 0), np.int8)),
        residue_fs=(np.stack(residue_rows) if residue_rows
                    else np.zeros((0, 0), np.float32)))


# ---------------------------------------------------------------------------
# Fleet heatmap.
# ---------------------------------------------------------------------------

def _downsample_slots(mat: np.ndarray, max_slots: int) -> np.ndarray:
    """Max-pool the slot axis (worst tier wins a bucket — a heatmap that
    hides a retired slot would be lying)."""
    n = mat.shape[1]
    if n <= max_slots:
        return mat
    bounds = np.linspace(0, n, max_slots + 1, dtype=int)
    return np.stack([mat[:, a:b].max(axis=1)
                     for a, b in zip(bounds, bounds[1:]) if b > a], axis=1)


def fleet_heatmap(timeline: FleetHealthTimeline, *,
                  max_slots: int = 64) -> dict:
    """Slot-tier heatmap over retrim events (rows = retrims in time
    order, cols = slot buckets, cell = worst tier in the bucket), plus
    an ASCII render (``.`` fine / ``o`` coarse / ``#`` retired). JSON-
    safe — this is the ``BENCH_obs.json`` fleet-health panel."""
    tiers = timeline.tiers
    if tiers.size == 0:
        return {"rows": 0, "slots": 0, "grid": [], "render": [],
                "legend": ". fine / o coarse / # retired"}
    grid = _downsample_slots(tiers, max_slots)
    render = ["".join(_TIER_GLYPHS.get(int(t), "?") for t in row)
              for row in grid]
    return {
        "rows": int(grid.shape[0]),
        "slots": int(tiers.shape[1]),
        "slot_buckets": int(grid.shape[1]),
        "streams": list(timeline.tier_streams),
        "grid": grid.astype(int).tolist(),
        "render": render,
        "legend": ". fine / o coarse / # retired",
        "retired_now": timeline.retired_now,
        "coarse_now": timeline.coarse_now,
    }


# ---------------------------------------------------------------------------
# The drift-alarm → recal → retire story (the bench's end-to-end gate).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DriftStory:
    """The reconstructed maintenance narrative of one trace."""

    steps: list[dict]             # ordered {stream, kind, summary}
    alarm_stream: Optional[int]
    recal_stream: Optional[int]
    retire_stream: Optional[int]

    @property
    def complete(self) -> bool:
        """Alarm observed, and the maintenance it triggered produced
        both a retirement/coarse-tier verdict and a completed
        recalibration at (or after) the alarm stream — the full
        hardware-maintenance causal chain. The retrim/retire verdicts
        land INSIDE the recalibration transaction (seq order:
        drift_probe → retrim → retire → program → recal), so they are
        ordered against the alarm, not the recal-complete event."""
        return (self.alarm_stream is not None
                and self.recal_stream is not None
                and self.retire_stream is not None
                and self.alarm_stream <= self.recal_stream
                and self.alarm_stream <= self.retire_stream)


def drift_story(events: Iterable[TraceEvent],
                engine: Optional[int] = None) -> DriftStory:
    """Walk a trace and reconstruct the first complete alarm → recal →
    retire/retrim sequence (bench gate: a maintenance incident must be
    fully explainable from the exported trace alone)."""
    steps: list[dict] = []
    alarm = recal = retire = None
    for ev in _ordered(events):
        if engine is not None and ev.engine is not None \
                and ev.engine != engine:
            continue
        s = int(ev.stream or 0)
        if ev.kind == "drift_probe" and ev.data.get("alarm"):
            if alarm is None:
                alarm = s
            steps.append({
                "stream": s, "kind": "drift_alarm",
                "summary": (f"rel_l2 {ev.data.get('rel_l2', 0.0):.4f} "
                            f"({', '.join(ev.data.get('reasons', []))})")})
        elif ev.kind == "recal":
            if recal is None and alarm is not None:
                recal = s
            steps.append({
                "stream": s, "kind": "recal",
                "summary": (f"reload {ev.data.get('reload_bits', 0)} bits"
                            f" / {ev.data.get('energy_nj', 0.0):.1f} nJ, "
                            f"post rel_l2 "
                            f"{ev.data.get('post_rel_l2', 0.0):.4f}")})
        elif ev.kind == "retrim":
            n_ret = int(ev.data.get("retired", 0))
            n_coarse = int(ev.data.get("coarse", 0))
            if retire is None and alarm is not None \
                    and (n_ret > 0 or n_coarse > 0):
                retire = s
            steps.append({
                "stream": s, "kind": "retrim",
                "summary": (f"{n_coarse} slot(s) to coarse tier, "
                            f"{n_ret} retired")})
        elif ev.kind == "retire":
            if retire is None and alarm is not None:
                retire = s
            steps.append({
                "stream": s, "kind": "retire",
                "summary": f"{ev.data.get('retired', 0)} slot(s) retired"})
    return DriftStory(steps=steps, alarm_stream=alarm,
                      recal_stream=recal, retire_stream=retire)


def slot_timelines(timeline: FleetHealthTimeline,
                   slots: Optional[Sequence[int]] = None
                   ) -> dict[int, list[dict]]:
    """Per-slot event lists (stream-ordered) from the detail matrices:
    residue at each probe, tier at each retrim. Empty when the trace
    carried no detail payloads."""
    out: dict[int, list[dict]] = {}
    n_slots = max(
        timeline.residue_fs.shape[1] if timeline.residue_fs.size else 0,
        timeline.tiers.shape[1] if timeline.tiers.size else 0)
    wanted = range(n_slots) if slots is None else slots
    for s in wanted:
        points: list[dict] = []
        if timeline.residue_fs.size:
            for p, row in zip(timeline.probes, timeline.residue_fs):
                points.append({"stream": p.stream, "kind": "probe",
                               "residue_fs": float(row[s])})
        if timeline.tiers.size:
            for st, row in zip(timeline.tier_streams, timeline.tiers):
                points.append({"stream": st, "kind": "retrim",
                               "tier": int(row[s])})
        points.sort(key=lambda d: d["stream"])
        out[int(s)] = points
    return out
