"""Fleet telemetry: tracing, metrics, and hardware-health timelines.

``repro.obs`` is the observability layer for the serving stack:

* :mod:`repro.obs.trace` — process-local ring-buffered event bus with
  in-jit emission (unordered ``io_callback``, trace-once).
* :mod:`repro.obs.metrics` — typed registry (counters / gauges /
  fixed-bucket histograms) backing the serve and traffic reports.
* :mod:`repro.obs.export` — Prometheus text exposition + JSONL traces,
  both round-trippable.
* :mod:`repro.obs.health` — per-slot hardware-health timelines and the
  fleet heatmap reconstructed from a trace.
"""
# repro-lint: module=observability

from repro.obs.export import (
    parse_prometheus,
    read_trace_jsonl,
    to_prometheus,
    write_trace_jsonl,
)
from repro.obs.health import (
    DriftStory,
    FleetHealthTimeline,
    drift_story,
    fleet_heatmap,
    from_events,
    rel_l2_to_sqnr_db,
    slot_timelines,
)
from repro.obs.metrics import (
    LATENCY_EDGES_S,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.trace import (
    DETAIL_KINDS,
    TraceBuffer,
    TraceEvent,
    bus,
    detail_enabled,
    emit,
    emit_decode_tick,
    enabled,
    install,
    span,
    tracing,
    uninstall,
)

__all__ = [
    "DETAIL_KINDS",
    "Counter",
    "DriftStory",
    "FleetHealthTimeline",
    "Gauge",
    "Histogram",
    "LATENCY_EDGES_S",
    "MetricsRegistry",
    "TraceBuffer",
    "TraceEvent",
    "bus",
    "detail_enabled",
    "drift_story",
    "emit",
    "emit_decode_tick",
    "enabled",
    "fleet_heatmap",
    "from_events",
    "install",
    "parse_prometheus",
    "read_trace_jsonl",
    "rel_l2_to_sqnr_db",
    "slot_timelines",
    "span",
    "to_prometheus",
    "tracing",
    "uninstall",
    "write_trace_jsonl",
]
