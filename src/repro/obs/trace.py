"""Process-local span/event bus: structured tracing for the serving stack.

End-of-run snapshots (``ServeReport`` / ``TrafficReport`` /
``DriftStatus``) say *how much* happened; the trace says *when*, on which
slot/tenant/request, and *why*. Every event is a :class:`TraceEvent` —
a kind from the serving taxonomy (``program``, ``reload``, ``recal``,
``retrim``, ``drift_probe``, ``admit`` / ``shed`` / ``evict``,
``prefill_wave``, ``decode_tick``, ``sanitize``, ...), a monotonic
timestamp, the engine's input-stream index, optional slot / request /
layer coordinates, and a JSON-safe payload (nJ / bits figures pulled
from the Eq. 4 roll-up, drift residues, queue depths).

Design constraints, in order:

1. **Zero cost when off, bounded cost when on.** Host-side emitters are
   one ``None`` check; the in-jit decode-tick emitter is staged into a
   SEPARATE compiled twin of the decode step that exists only when the
   engine was constructed with tracing enabled — a tracing-off engine
   compiles exactly the program it compiles today and its decoded
   tokens are BITWISE identical (gated in ``benchmarks/obs_report.py``).
   Because any host callback in a jitted program forfeits the C++
   fast-dispatch path (milliseconds per call on CPU), a tracing engine
   dispatches the traced twin on a sampling cadence
   (``trace_tick_interval``, default every 128th tick) and the pure
   program otherwise — ``decode_tick`` events are a sampled timeline
   (each names its stream index, so gaps are explicit), while the
   metrics counters remain tick-exact. The overhead gate (<= 5% decode
   tok/s, same bench) holds at the default cadence.
2. **No retracing.** The in-jit emitter follows the calibration tap's
   ``io_callback`` discipline (unordered, staged at trace time, routed
   through a module-global read at FIRE time): the jitted decode step is
   traced once per shape whether or not a bus is installed, and
   installing / swapping a bus between runs never invalidates the cache.
3. **Bounded memory.** The bus is a ring buffer: the newest ``capacity``
   events win, ``dropped`` counts what the ring evicted, so a week-long
   serve cannot OOM the host.

The bus is process-local and deliberately global (one serving process =
one timeline); concurrent engines tag events with their ``engine`` field
and readers filter. Not thread-safe beyond CPython list-append atomicity
— the serving loop is single-threaded, and unordered ``io_callback``s
fire on the main thread between dispatches.
"""
# repro-lint: module=observability

from __future__ import annotations

import dataclasses
import time
from contextlib import contextmanager
from typing import Any, Iterator, Optional

# Events whose payloads may carry large per-slot arrays (drift residue /
# tier vectors). They are emitted only when the installed bus asks for
# detail — the fleet heatmap needs them, steady-state tracing does not.
DETAIL_KINDS = frozenset({"drift_probe", "retrim"})


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One structured event on the bus (JSON-safe by construction)."""

    kind: str
    t_ns: int                      # monotonic nanoseconds (host clock)
    seq: int                       # bus-wide emission index (total order)
    stream: Optional[int] = None   # engine input-stream counter
    slot: Optional[int] = None     # fleet tile slot / cache slot
    rid: Optional[Any] = None      # request id
    layer: Optional[str] = None    # projection / layer name
    engine: Optional[int] = None   # emitting engine's id() tag
    data: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        out = {"kind": self.kind, "t_ns": self.t_ns, "seq": self.seq}
        for f in ("stream", "slot", "rid", "layer", "engine"):
            v = getattr(self, f)
            if v is not None:
                out[f] = v
        if self.data:
            out["data"] = self.data
        return out

    @classmethod
    def from_json(cls, obj: dict) -> "TraceEvent":
        return cls(kind=obj["kind"], t_ns=int(obj["t_ns"]),
                   seq=int(obj["seq"]), stream=obj.get("stream"),
                   slot=obj.get("slot"), rid=obj.get("rid"),
                   layer=obj.get("layer"), engine=obj.get("engine"),
                   data=obj.get("data", {}))


class TraceBuffer:
    """Fixed-capacity ring of :class:`TraceEvent`; newest events win."""

    def __init__(self, capacity: int = 65536, detail: bool = False):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.detail = detail          # ship per-slot arrays in payloads
        self._ring: list[Optional[TraceEvent]] = [None] * capacity
        self._next = 0                # next write position
        self.total = 0                # events ever appended
        self.dropped = 0              # events the ring evicted

    def append(self, ev: TraceEvent) -> None:
        if self._ring[self._next] is not None:
            self.dropped += 1
        self._ring[self._next] = ev
        self._next = (self._next + 1) % self.capacity
        self.total += 1

    def __len__(self) -> int:
        return min(self.total, self.capacity)

    def events(self) -> list[TraceEvent]:
        """Retained events in emission order (oldest surviving first)."""
        if self.total <= self.capacity:
            return [e for e in self._ring[:self._next] if e is not None]
        return ([e for e in self._ring[self._next:] if e is not None]
                + [e for e in self._ring[:self._next] if e is not None])

    def by_kind(self, *kinds: str) -> list[TraceEvent]:
        want = frozenset(kinds)
        return [e for e in self.events() if e.kind in want]

    def clear(self) -> None:
        self._ring = [None] * self.capacity
        self._next = 0
        self.total = 0
        self.dropped = 0


# ---------------------------------------------------------------------------
# The process-local bus.
# ---------------------------------------------------------------------------

_BUS: Optional[TraceBuffer] = None
_SEQ = 0


def bus() -> Optional[TraceBuffer]:
    """The currently installed bus (None = tracing off)."""
    return _BUS


def enabled() -> bool:
    return _BUS is not None


def detail_enabled() -> bool:
    return _BUS is not None and _BUS.detail


def install(capacity: int = 65536, detail: bool = False) -> TraceBuffer:
    """Install (and return) a fresh process-local bus."""
    global _BUS
    _BUS = TraceBuffer(capacity, detail=detail)
    return _BUS


def uninstall() -> None:
    global _BUS
    _BUS = None


@contextmanager
def tracing(capacity: int = 65536,
            detail: bool = False) -> Iterator[TraceBuffer]:
    """Scoped bus: install for the block, restore the previous one after.

    The buffer stays readable after the block — exports and health
    timelines are typically built from it once serving finished.
    """
    global _BUS
    prev, _BUS = _BUS, TraceBuffer(capacity, detail=detail)
    try:
        yield _BUS
    finally:
        _BUS = prev


def emit(kind: str, *, stream: Optional[int] = None,
         slot: Optional[int] = None, rid: Optional[Any] = None,
         layer: Optional[str] = None, engine: Optional[int] = None,
         **data: Any) -> None:
    """Host-side emit: one dict-build + list-append when a bus is
    installed, one global read when not."""
    if _BUS is None:
        return
    global _SEQ
    _SEQ += 1
    _BUS.append(TraceEvent(kind=kind, t_ns=time.monotonic_ns(), seq=_SEQ,
                           stream=stream, slot=slot, rid=rid, layer=layer,
                           engine=engine, data=data))


@contextmanager
def span(kind: str, **fields: Any) -> Iterator[None]:
    """Emit ``kind`` once on exit with the block's duration in ``dur_ns``
    (single-event spans: cheap, and ring-eviction cannot orphan a
    begin/end pair)."""
    if _BUS is None:
        yield
        return
    t0 = time.monotonic_ns()
    try:
        yield
    finally:
        emit(kind, dur_ns=time.monotonic_ns() - t0, **fields)


# ---------------------------------------------------------------------------
# In-jit emission (the calib-tap io_callback pattern).
# ---------------------------------------------------------------------------

def emit_decode_tick(step, tokens, active,
                     engine: Optional[int] = None) -> None:
    """Stage one unordered ``io_callback`` emitting a ``decode_tick``
    event per execution of the enclosing jitted program.

    Call ONLY under trace, and only when the engine decided at
    construction that this compiled program is a traced one — the
    callback routes through the module-global bus at fire time, so the
    staged program keeps working (or cheaply no-ops) as buses come and
    go, without retracing. ``step`` is the engine's input-stream counter,
    ``tokens`` the sampled next-token vector, ``active`` the number of
    occupied slots this tick; ``engine`` is a small static tag captured
    into the compiled program (NOT a traced operand).
    """
    from functools import partial

    import jax.numpy as jnp
    from jax.experimental import io_callback

    io_callback(partial(_decode_tick_host, engine), None,
                jnp.asarray(step, jnp.int32),
                jnp.asarray(tokens, jnp.int32),
                jnp.asarray(active, jnp.int32), ordered=False)


def _decode_tick_host(engine, step, tokens, active) -> None:
    if _BUS is None:
        return
    emit("decode_tick", stream=int(step), engine=engine,
         active=int(active), tokens=[int(t) for t in tokens])
