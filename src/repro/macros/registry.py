"""Macro-model registry: name → flavour, plus coercion helpers.

``@register`` on a :class:`~repro.macros.base.MacroModel` subclass makes
it constructible by name everywhere a macro model is accepted —
``ServeEngine(silicon="collaborative")``, yield sweeps, the compiler's
re-budgeting, benches. The built-in flavours (``saadc``,
``collaborative``, ``p8t``) self-register on first lookup, so importing
:mod:`repro.macros` is enough; external papers add theirs with one
module that defines a dataclass and calls :func:`register`.

:func:`as_macro` is the dispatch seam the silicon lab uses to stay
backward compatible: every function that historically took a
``SiliconConfig`` now coerces its argument through it — a plain
``SiliconConfig`` becomes the SA-ADC flavour wrapping it (the exact
pre-registry physics), a string resolves through the registry, and a
``MacroModel`` passes through untouched.
"""

from __future__ import annotations

from typing import Type, Union

from repro.macros.base import MacroModel
from repro.silicon.instance import SiliconConfig

_REGISTRY: dict[str, Type[MacroModel]] = {}


def register(cls: Type[MacroModel]) -> Type[MacroModel]:
    """Class decorator: add a macro flavour to the registry under its
    ``name`` ClassVar. Re-registering a name overwrites (last wins) so
    notebooks can iterate on a flavour without restarting."""
    if not isinstance(getattr(cls, "name", None), str) or not cls.name:
        raise ValueError(
            f"{cls.__name__} needs a non-empty `name` ClassVar to be "
            f"registered")
    _REGISTRY[cls.name] = cls
    return cls


def _ensure_builtin() -> None:
    """Import the built-in flavours (their decorators register them)."""
    from repro.macros import collaborative, p8t, saadc  # noqa: F401


def available() -> tuple[str, ...]:
    """Registered macro-model names, sorted."""
    _ensure_builtin()
    return tuple(sorted(_REGISTRY))


def get_macro(name: str, **kwargs) -> MacroModel:
    """Construct a registered flavour by name (kwargs → its dataclass
    fields). Unknown names fail with the full menu."""
    _ensure_builtin()
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown macro model '{name}' — registered models: "
            f"{', '.join(sorted(_REGISTRY))}")
    return _REGISTRY[name](**kwargs)


MacroLike = Union[MacroModel, SiliconConfig, str]


def as_macro(spec: MacroLike) -> MacroModel:
    """Coerce anything macro-shaped to a :class:`MacroModel`.

    * ``MacroModel`` → itself;
    * ``SiliconConfig`` → the SA-ADC flavour wrapping it (bitwise the
      pre-registry per-slot silicon path);
    * ``str`` → :func:`get_macro` with default fields.
    """
    if isinstance(spec, MacroModel):
        return spec
    if isinstance(spec, SiliconConfig):
        from repro.macros.saadc import SAADC
        return SAADC(silicon=spec)
    if isinstance(spec, str):
        return get_macro(spec)
    raise TypeError(
        f"expected a MacroModel, SiliconConfig or registered macro name, "
        f"got {type(spec).__name__}")
