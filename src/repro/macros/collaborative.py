"""Memory-immersed collaborative digitization (arXiv 2307.03863).

The follow-up to the source paper: neighbouring macros lend their
bit-line parasitics as a *shared* cap-DAC, so one SA-ADC instance spans
a group of tile slots instead of every slot carrying its own. Two
consequences, both modelled:

  * **correlated mismatch** — the group shares one physical cap-DAC and
    comparator, so all member slots see the SAME sampled cap weights,
    offset, correction and drift directions. :meth:`sample` draws one
    instance per group and broadcasts it across members (perfectly
    correlated within a group, independent across groups, same key ⇒
    same shared caps).
  * **cross-macro coupling** — bridging bit lines across macros couples
    switching noise from the (group_size − 1) lending neighbours into
    every conversion. Modelled as a per-conversion zero-mean dither of
    RMS ``coupling_sigma_v · sqrt(group_size − 1)`` riding the existing
    thermal-noise channel (:meth:`conversion_pair`): keyed off the
    serving engine's ``conversion_clock``, fresh per ADC evaluation,
    untouched by recalibration.

The pay-off is area: the per-slot ADC cost divides by the group size
(plus a small bridge-switch overhead), which the compiler re-spends on
µArray columns (``fleet_for_macro``) — bigger feasible tiles at fixed
macro area. The price is latency: the shared SAR serialises a short
arbitration tail over the lending neighbours each unit op, and every
conversion charges the bridge switching.
"""
# repro-lint: module=deterministic

from __future__ import annotations

import dataclasses
import math
from typing import ClassVar, Optional

import jax
import jax.numpy as jnp

from repro.core.cim import CimConfig
from repro.core.energy import (DEFAULT_MACRO, MacroParams, unit_op_cycles,
                               unit_op_energy_j)
from repro.macros.base import (CAL_DAC_AREA_UNITS, COMPARATOR_AREA_UNITS,
                               COUPLING_AREA_UNITS, SAR_AREA_UNITS_PER_BIT,
                               MacroModel)
from repro.macros.registry import register
from repro.silicon import instance as inst
from repro.silicon.instance import FleetSilicon, SiliconConfig


@register
@dataclasses.dataclass(frozen=True)
class CollaborativeDigitization(MacroModel):
    """Shared cap-DAC SA-ADC spanning ``group_size`` tile slots."""

    group_size: int = 4
    coupling_sigma_v: float = 0.002   # per-neighbour switching noise RMS (V)

    name: ClassVar[str] = "collaborative"

    def __post_init__(self):
        if self.group_size < 1:
            raise ValueError(
                f"group_size must be >= 1, got {self.group_size}")
        if self.coupling_sigma_v < 0.0:
            raise ValueError(
                f"coupling_sigma_v must be >= 0, got "
                f"{self.coupling_sigma_v}")

    # -- silicon hooks ------------------------------------------------------

    def sample(self, key: jax.Array, n_slots: int, m_columns: int
               ) -> FleetSilicon:
        """One sampled ADC instance per slot GROUP, broadcast across the
        group's members — the correlated-mismatch structure of a shared
        cap-DAC. Slot s belongs to group s // group_size."""
        g = self.group_size
        n_groups = -(-n_slots // g)
        shared = inst.sample_fleet(key, n_groups, m_columns, self.silicon)

        def spread(a: jax.Array) -> jax.Array:
            return jnp.repeat(a, g, axis=0)[:n_slots]

        return FleetSilicon(
            cap=spread(shared.cap),
            offset_v=spread(shared.offset_v),
            correction_v=spread(shared.correction_v),
            drift_dir_v=spread(shared.drift_dir_v),
            drift_dir_cap=spread(shared.drift_dir_cap),
            age_streams=shared.age_streams)

    def conversion_pair(self, noise_key: Optional[jax.Array] = None
                        ) -> tuple[Optional[jax.Array],
                                   Optional[jax.Array]]:
        """Thermal floor ⊕ cross-macro coupling, as one per-conversion
        dither RMS (independent noise sources add in quadrature)."""
        scfg = self.silicon
        coupled = (self.coupling_sigma_v ** 2) * (self.group_size - 1)
        sigma_v = math.sqrt(scfg.thermal_sigma_v ** 2 + coupled)
        if sigma_v == 0.0:
            return None, None
        fs = jnp.float32(sigma_v / scfg.v_full_scale)
        if noise_key is None:
            noise_key = jax.random.PRNGKey(scfg.seed)
        return fs, noise_key

    # -- area ---------------------------------------------------------------

    def adc_area_units(self, adc_bits: int) -> float:
        """The shared ADC amortises over the group; the bit-line bridge
        switches are per slot and do not."""
        shared = (COMPARATOR_AREA_UNITS
                  + SAR_AREA_UNITS_PER_BIT * adc_bits
                  + CAL_DAC_AREA_UNITS)
        return shared / self.group_size + COUPLING_AREA_UNITS

    # -- energy / latency ---------------------------------------------------

    def unit_op_cycles(self, cim: CimConfig) -> int:
        """Eq. 4a plus an arbitration tail: the shared SAR hands the
        group token across the (group_size − 1) lending neighbours, one
        short settle per resolved bit (stylised serialisation cost)."""
        return (unit_op_cycles(cim)
                + (self.group_size - 1) * cim.adc_bits)

    def unit_op_energy_j(self, cim: CimConfig,
                         macro: MacroParams = DEFAULT_MACRO) -> float:
        """Eq. 4b plus the bridge-switch charge: each of the A_P SA
        iterations drives the coupled neighbour bit lines once (one
        C_PL·V² quantum per lending neighbour per iteration)."""
        bridge = ((self.group_size - 1) * cim.adc_bits
                  * macro.c_pl_v2_j)
        return unit_op_energy_j(cim, macro) + bridge

    # -- config plumbing ----------------------------------------------------

    @property
    def is_nominal(self) -> bool:
        return self.silicon.is_nominal and (
            self.group_size == 1 or self.coupling_sigma_v == 0.0)

    def nominal(self) -> "CollaborativeDigitization":
        return dataclasses.replace(
            self,
            silicon=SiliconConfig(cap_sigma=0.0, comparator_sigma_v=0.0,
                                  seed=self.silicon.seed),
            coupling_sigma_v=0.0)

    def describe(self, cim: CimConfig) -> dict:
        return super().describe(cim) | {
            "group_size": self.group_size,
            "coupling_sigma_v": self.coupling_sigma_v,
        }
