"""Macro zoo: pluggable CIM macro models behind one registry.

One frozen-dataclass flavour per macro paper
(:class:`~repro.macros.base.MacroModel` protocol), registered by name:

  * ``saadc`` — the source paper's per-slot memory-immersed SA-ADC,
    delegating to the raw :mod:`repro.silicon.instance` physics (bitwise
    the pre-registry silicon path);
  * ``collaborative`` — memory-immersed collaborative digitization
    (arXiv 2307.03863): shared cap-DAC instances spanning slot groups,
    correlated mismatch, cross-macro coupling noise, amortised ADC area;
  * ``p8t`` — the charge-domain P-8T cell (arXiv 2211.16008): explicit
    metal-cap DAC (better matching, bigger cell), cheaper MAV energy.

Everywhere the silicon lab takes a ``SiliconConfig`` it now also takes
a flavour (or its registry name): ``ServeEngine(silicon=...)``,
``attach_silicon``, ``projection_silicon``, ``fleet_silicon``, the
Monte-Carlo sweeps. The compiler re-budgets each flavour's ADC area
into µArray columns at fixed macro area (:func:`fleet_for_macro`) and
prices unit ops through the flavour's Eq. 4 hooks.
"""

from repro.macros.base import (CELL_AREA_UNITS, COMPARATOR_AREA_UNITS,
                               COUPLING_AREA_UNITS, CAL_DAC_AREA_UNITS,
                               SAR_AREA_UNITS_PER_BIT, MacroModel,
                               feasible_columns, fleet_for_macro,
                               reference_budget_units)
from repro.macros.collaborative import CollaborativeDigitization
from repro.macros.p8t import P8T
from repro.macros.registry import (MacroLike, as_macro, available,
                                   get_macro, register)
from repro.macros.saadc import SAADC

__all__ = [
    "MacroModel", "MacroLike", "SAADC", "CollaborativeDigitization", "P8T",
    "register", "available", "get_macro", "as_macro",
    "feasible_columns", "fleet_for_macro", "reference_budget_units",
    "CELL_AREA_UNITS", "COMPARATOR_AREA_UNITS", "COUPLING_AREA_UNITS",
    "CAL_DAC_AREA_UNITS", "SAR_AREA_UNITS_PER_BIT",
]
