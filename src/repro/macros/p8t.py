"""Charge-domain P-8T macro flavour (arXiv 2211.16008).

A second external plug-in proving the registry interface: the P-8T
bitcell computes in the charge domain through an explicit per-cell metal
capacitor instead of the 6T cell's parasitic bit line. Three cost-point
differences from the SA-ADC macro, all expressed through existing
protocol hooks:

  * **cell area** — the 8T cell plus its metal cap is larger than the
    6T cell (``cell_area_units`` > 1), so at fixed macro area the
    feasible tile is NARROWER than the source paper's — the compiler's
    re-budgeting surfaces the trade honestly in both directions;
  * **DAC matching** — metal-oxide-metal caps match far better than
    bit-line parasitics: the sampled cap-DAC mismatch is the configured
    ``cap_sigma`` scaled by ``dac_matching`` (< 1), which is what buys
    the flavour its yield at high mismatch corners;
  * **MAV energy** — charge-domain accumulation avoids repeated
    precharge of the full bit line; the Eq. 4b MAV term scales by
    ``mav_energy_scale`` while the SAR digitisation term is unchanged
    (same comparator + SAR back end).
"""
# repro-lint: module=deterministic

from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax

from repro.core.cim import CimConfig
from repro.core.energy import (DEFAULT_MACRO, MacroParams,
                               unit_op_energy_j)
from repro.macros.base import (CAL_DAC_AREA_UNITS, COMPARATOR_AREA_UNITS,
                               SAR_AREA_UNITS_PER_BIT, MacroModel)
from repro.macros.registry import register
from repro.silicon import instance as inst
from repro.silicon.instance import FleetSilicon


@register
@dataclasses.dataclass(frozen=True)
class P8T(MacroModel):
    """Charge-domain 8T + metal-cap macro."""

    dac_matching: float = 0.5      # cap mismatch relative to parasitic DAC
    mav_energy_scale: float = 0.6  # charge-domain MAV vs bit-line precharge
    p8t_cell_area_units: float = 1.35  # 8T + metal cap vs the 6T cell

    name: ClassVar[str] = "p8t"

    def sample(self, key: jax.Array, n_slots: int, m_columns: int
               ) -> FleetSilicon:
        """Same per-slot sampling lottery, tighter cap distribution (the
        metal-cap DAC's matching advantage)."""
        scfg = dataclasses.replace(
            self.silicon,
            cap_sigma=self.silicon.cap_sigma * self.dac_matching)
        return inst.sample_fleet(key, n_slots, m_columns, scfg)

    def adc_area_units(self, adc_bits: int) -> float:
        """Same SAR back end as the SA-ADC; the explicit cap-DAC is
        per-cell metal (priced into ``cell_area_units``), not a
        standalone block."""
        return (COMPARATOR_AREA_UNITS
                + SAR_AREA_UNITS_PER_BIT * adc_bits
                + CAL_DAC_AREA_UNITS)

    @property
    def cell_area_units(self) -> float:
        return self.p8t_cell_area_units

    def unit_op_energy_j(self, cim: CimConfig,
                         macro: MacroParams = DEFAULT_MACRO) -> float:
        """Eq. 4b with the MAV term rescaled to the charge domain."""
        mav = cim.w_bits * cim.m_columns * macro.c_pl_v2_j
        return (unit_op_energy_j(cim, macro)
                - mav * (1.0 - self.mav_energy_scale))
