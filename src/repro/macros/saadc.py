"""The source paper's per-slot SA-ADC, as a registered macro flavour.

This is the pre-registry silicon path of :mod:`repro.silicon.instance`
refactored *behind* the :class:`~repro.macros.base.MacroModel` protocol:
every hook delegates to the exact raw functions the silicon lab has
always run (``sample_fleet`` / ``effective_caps`` / ``effective_offsets``
/ ``_thermal_pair`` / the tail-current re-trim), so an engine built with
``SAADC(silicon=cfg)`` is bitwise identical to one built with the bare
``SiliconConfig`` at σ=0 AND exact-code identical at σ>0 — the
acceptance gate of ``BENCH_macros.json``.

Area: the SA-ADC is *memory-immersed* — its cap-DAC is the bit-line
parasitic capacitance of the half it serves, so the per-slot
digitisation area is just comparator + SAR logic + calibration DAC (no
explicit capacitor array), and the cell is the plain 6T bit cell.
"""
# repro-lint: module=deterministic

from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax

from repro.macros.base import (CAL_DAC_AREA_UNITS, COMPARATOR_AREA_UNITS,
                               SAR_AREA_UNITS_PER_BIT, MacroModel)
from repro.macros.registry import register
from repro.silicon import instance as inst
from repro.silicon.instance import FleetSilicon


@register
@dataclasses.dataclass(frozen=True)
class SAADC(MacroModel):
    """Per-slot memory-immersed SA-ADC (the source paper's macro)."""

    name: ClassVar[str] = "saadc"

    def sample(self, key: jax.Array, n_slots: int, m_columns: int
               ) -> FleetSilicon:
        return inst.sample_fleet(key, n_slots, m_columns, self.silicon)

    def adc_area_units(self, adc_bits: int) -> float:
        return (COMPARATOR_AREA_UNITS
                + SAR_AREA_UNITS_PER_BIT * adc_bits
                + CAL_DAC_AREA_UNITS)
