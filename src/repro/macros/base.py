"""Macro-model protocol: one pluggable object per CIM macro paper.

The silicon lab (PR 5/7) hard-coded the source paper's per-slot SA-ADC:
``repro.silicon.instance`` both *sampled* the silicon lottery and *was*
the only macro physics the compiler, serving engine and Monte-Carlo
sweeps knew about. Follow-up papers from the same group change exactly
the pieces that were hard-coded — how ADC instances are shared across
slots (memory-immersed collaborative digitization, arXiv 2307.03863),
what the conversion costs in area/energy/cycles (charge-domain P-8T,
arXiv 2211.16008) — so this module turns the macro model into a
first-class extension point.

:class:`MacroModel` is a frozen dataclass protocol with three groups of
hooks:

  * **silicon hooks** — ``sample`` / ``effective_caps`` /
    ``effective_offsets`` / ``recalibrate`` / ``retrim`` / ``age`` /
    ``conversion_pair`` / ``quantise``: everything the serving datapath
    and yield sweeps need to realise and evolve one silicon instance of
    a fleet. The defaults delegate to the *raw* per-slot SA-ADC
    functions in :mod:`repro.silicon.instance` — the exact code the
    pre-registry silicon path ran — so the built-in
    :class:`~repro.macros.saadc.SAADC` plug-in is bitwise identical at
    σ=0 and exact-code identical at σ>0 by construction.
  * **area descriptors** — ``adc_area_units`` / ``cell_area_units`` in
    a stylised cell-equivalent unit system (below). The compiler
    re-budgets ADC area saved by a macro flavour into extra µArray
    columns at fixed macro area (:func:`feasible_columns` /
    :func:`fleet_for_macro`): fewer ADC units per slot ⇒ strictly wider
    feasible tiles ⇒ fewer tiles per projection in the Eq. 4 roll-up.
  * **energy/latency descriptors** — ``unit_op_cycles`` /
    ``unit_op_energy_j`` hooks defaulting to the calibrated Eq. 4a/4b
    model of :mod:`repro.core.energy`; flavours override to price their
    own conversion scheme.

Area unit system (stylised, relative — absolute µm² are not published
at matching granularity across the three papers): one 6T bit cell of
the µArray is 1.0 unit; the SA-ADC's comparator (the half's sense amp
plus latch), its SAR logic per resolved bit, and the 2-bit tail-current
calibration DAC are priced as small digital/analog blocks relative to
that cell. The *memory-immersed* trick is already reflected here: there
is no explicit cap-DAC term for the SA-ADC because the bit-line
parasitics ARE the DAC — collaborative digitization then divides the
remaining per-slot ADC cost across the slots of a sharing group, and
the P-8T flavour instead grows the cell (8T + explicit metal cap) while
keeping the same SAR back end.
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar, Optional

import jax

from repro.core.cim import CimConfig, adc_codes
from repro.core.energy import (DEFAULT_MACRO, MacroParams, unit_op_cycles,
                               unit_op_energy_j)
from repro.silicon import instance as inst
from repro.silicon.instance import FleetSilicon, SiliconConfig

# --- stylised area unit system (cell-equivalent units) ---------------------
CELL_AREA_UNITS = 1.0          # one 6T µArray bit cell
COMPARATOR_AREA_UNITS = 24.0   # sense-amp comparator + latch per half
SAR_AREA_UNITS_PER_BIT = 10.0  # SAR logic + timing per resolved bit
CAL_DAC_AREA_UNITS = 16.0      # 2-bit tail-current offset-cal DAC
COUPLING_AREA_UNITS = 8.0      # inter-macro bit-line bridge switches


@dataclasses.dataclass(frozen=True)
class MacroModel:
    """Base protocol + the source paper's SA-ADC physics as defaults.

    Concrete flavours are frozen dataclasses registered with
    :func:`repro.macros.registry.register`; their ``silicon`` field
    carries the distribution/drift knobs (a plain
    :class:`~repro.silicon.instance.SiliconConfig`), so every existing
    Monte-Carlo sweep parameterises over macro models by
    ``dataclasses.replace`` on that field (:meth:`with_mismatch`).
    """

    silicon: SiliconConfig = dataclasses.field(
        default_factory=SiliconConfig)
    # Fine re-trim range is ±3σ (the tail-current DAC of Fig. 8e); the
    # coarse tier re-trims saturated slots on a DAC re-biased to this
    # multiple of the fine range (same step count ⇒ coarser LSB).
    coarse_retrim_mult: float = 3.0

    name: ClassVar[str] = "base"

    # -- silicon hooks ------------------------------------------------------

    def sample(self, key: jax.Array, n_slots: int, m_columns: int
               ) -> FleetSilicon:
        """Sample one silicon realisation of ``n_slots`` tile slots."""
        raise NotImplementedError

    def effective_caps(self, state: FleetSilicon) -> jax.Array:
        """(S, m) cap-DAC weights at the fleet's current age."""
        return inst.effective_caps(state, self.silicon)

    def effective_offsets(self, state: FleetSilicon) -> jax.Array:
        """(S,) comparator offsets NOW, as full-scale fractions."""
        return inst.effective_offsets(state, self.silicon)

    def age(self, state: FleetSilicon, streams) -> FleetSilicon:
        return inst.age(state, streams)

    def recalibrate(self, state: FleetSilicon) -> FleetSilicon:
        """Fine-tier-only comparator re-trim (the pre-aging behaviour)."""
        return inst.recalibrate_comparators(state, self.silicon)

    def retrim(self, state: FleetSilicon
               ) -> tuple[FleetSilicon, jax.Array]:
        """Tiered comparator re-trim: ``(new_state, tier)`` with tier 0
        (fine DAC), 1 (coarse tier engaged) or 2 (beyond even the coarse
        range — the slot is flagged retired) per slot. Identical to
        :meth:`recalibrate` wherever the fine range suffices."""
        return inst.retrim_comparators(state, self.silicon,
                                       coarse_mult=self.coarse_retrim_mult)

    def retired_mask(self, state: FleetSilicon) -> jax.Array:
        """(S,) bool — slots whose drifted offset exceeds even the
        coarse re-trim DAC range (screening verdict: retire)."""
        return inst.retired_slots_mask(state, self.silicon,
                                       coarse_mult=self.coarse_retrim_mult)

    def conversion_pair(self, noise_key: Optional[jax.Array] = None
                        ) -> tuple[Optional[jax.Array],
                                   Optional[jax.Array]]:
        """(noise_rms_fs, noise_key) of the per-conversion dither stream
        (:meth:`~repro.core.cim.ProjectionSilicon.dither`, keyed off the
        serving engine's ``conversion_clock``) — (None, None) when this
        flavour adds no per-conversion noise."""
        return inst._thermal_pair(self.silicon, noise_key)

    def quantise(self, mav: jax.Array, adc_bits: int,
                 comparator_offset: Optional[jax.Array] = None
                 ) -> jax.Array:
        """ADC transfer function: MAV (full-scale fraction) → integer
        code. Built-in flavours keep the uniform mid-tread SA quantiser
        (:func:`repro.core.cim.adc_codes`) — the jitted datapath relies
        on that transfer function for its lossless-collapse and kernel
        identities, so this hook is a *contract* (verified by the macro
        test suite), not a per-call dispatch in the hot loop."""
        return adc_codes(mav, adc_bits, comparator_offset)

    # -- area descriptors ---------------------------------------------------

    @property
    def cell_area_units(self) -> float:
        """Area of one weight-bit cell, in cell-equivalent units."""
        return CELL_AREA_UNITS

    def adc_area_units(self, adc_bits: int) -> float:
        """Per-slot digitisation area (comparator + SAR + cal DAC) in
        cell-equivalent units, amortised over any sharing group."""
        raise NotImplementedError

    def half_area_units(self, cim: CimConfig) -> float:
        """Total per-slot (µArray half) area: cells + amortised ADC."""
        return (cim.w_bits * cim.m_columns * self.cell_area_units
                + self.adc_area_units(cim.adc_bits))

    # -- energy / latency descriptors ---------------------------------------

    def unit_op_cycles(self, cim: CimConfig) -> int:
        """Eq. 4a unit-operation latency in macro clock cycles."""
        return unit_op_cycles(cim)

    def unit_op_energy_j(self, cim: CimConfig,
                         macro: MacroParams = DEFAULT_MACRO) -> float:
        """Eq. 4b unit-operation energy (J)."""
        return unit_op_energy_j(cim, macro)

    # -- config plumbing ----------------------------------------------------

    @property
    def is_nominal(self) -> bool:
        """σ=0 everywhere ⇒ the bitwise-parity regime."""
        return self.silicon.is_nominal

    @property
    def is_drifting(self) -> bool:
        return (self.silicon.drift_sigma_v_per_kstream != 0.0
                or self.silicon.drift_cap_sigma_per_kstream != 0.0)

    @property
    def seed(self) -> int:
        return self.silicon.seed

    def with_silicon(self, cfg: SiliconConfig) -> "MacroModel":
        return dataclasses.replace(self, silicon=cfg)

    def with_mismatch(self, cap_sigma: float) -> "MacroModel":
        """The yield-sweep knob: same flavour, swept cap-DAC mismatch."""
        return self.with_silicon(dataclasses.replace(
            self.silicon, cap_sigma=float(cap_sigma)))

    def nominal(self) -> "MacroModel":
        """The σ=0 instance of this flavour (bitwise-parity regime)."""
        return self.with_silicon(SiliconConfig(
            cap_sigma=0.0, comparator_sigma_v=0.0,
            seed=self.silicon.seed))

    def describe(self, cim: CimConfig) -> dict:
        """Bench-facing summary of this flavour at one design point."""
        return {
            "name": self.name,
            "cell_area_units": self.cell_area_units,
            "adc_area_units": self.adc_area_units(cim.adc_bits),
            "half_area_units": self.half_area_units(cim),
            "unit_op_cycles": self.unit_op_cycles(cim),
            "unit_op_energy_j": self.unit_op_energy_j(cim),
        }


def reference_budget_units(cim: CimConfig) -> float:
    """The fixed per-slot area envelope everything is re-budgeted
    against: the source paper's SA-ADC half at geometry ``cim`` (cells
    at 1.0 unit + the full un-shared per-slot ADC). 8×62 (M=31, A_P=5)
    ⇒ 8·31·1.0 + (24 + 50 + 16) = 338 units."""
    return (cim.w_bits * cim.m_columns * CELL_AREA_UNITS
            + COMPARATOR_AREA_UNITS
            + SAR_AREA_UNITS_PER_BIT * cim.adc_bits
            + CAL_DAC_AREA_UNITS)


def feasible_columns(model: MacroModel, adc_bits: int, *,
                     budget_units: float, w_bits: int = 8) -> int:
    """Widest µArray half (columns M) a flavour fits in a fixed area
    envelope: whatever the (amortised) ADC does not consume is re-spent
    on weight cells. This is the area-for-tiles trade-off of the
    collaborative-digitization paper, in compiler currency."""
    cells = budget_units - model.adc_area_units(adc_bits)
    m = int(cells // (w_bits * model.cell_area_units))
    if m < 1:
        raise ValueError(
            f"macro '{model.name}' does not fit the {budget_units:.0f}-"
            f"unit envelope at A_P={adc_bits} (ADC alone is "
            f"{model.adc_area_units(adc_bits):.1f} units)")
    return m


def fleet_for_macro(model: MacroModel, base, adc_bits: Optional[int] = None):
    """Re-budget a reference fleet's macro area for ``model``: same
    per-slot area envelope (the SA-ADC half of ``base.cfg``), the
    flavour's ADC cost, every spare unit converted to columns. Returns a
    new :class:`~repro.compiler.tiling.Fleet` carrying the model (so the
    Eq. 4 roll-up prices unit ops through the flavour's hooks)."""
    import dataclasses as _dc
    a = base.cfg.adc_bits if adc_bits is None else int(adc_bits)
    budget = reference_budget_units(base.cfg)
    m = feasible_columns(model, a, budget_units=budget,
                         w_bits=base.cfg.w_bits)
    cfg = _dc.replace(base.cfg, m_columns=m, adc_bits=a)
    return _dc.replace(base, cfg=cfg, macro=model)
