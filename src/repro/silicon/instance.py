"""Per-slot silicon instances of a CIM SRAM fleet.

The paper's SA-ADC is *memory-immersed*: its capacitive DAC is the
bit-line parasitic capacitance of the µArray half it serves, and its
comparator is that half's sense amplifier. Mismatch, offset, noise and
drift are therefore properties of the physical TILE SLOT, shared by every
weight tile ever programmed into it — not of the weights. This module
samples one ADC instance per fleet slot and gathers them into the
projection-shaped :class:`~repro.core.cim.ProjectionSilicon` views the
step-time datapath consumes.

Sampling model (all draws keyed, deterministic, mergeable):

  * cap-DAC weights: per-column C_PL = 1 + eps, eps ~ N(0, cap_sigma^2) —
    the bit-line parasitic mismatch of :mod:`repro.silicon.variability`;
  * comparator offset: N(0, comparator_sigma_v^2) volts, bulk-corrected by
    the 2-bit tail-current DAC (``calibrated_offset``) at time zero;
  * thermal noise: the comparator's input-referred noise floor, drawn PER
    CONVERSION — every ADC evaluation sees a fresh keyed
    N(0, thermal_sigma_v^2) dither sample (``ProjectionSilicon.dither``),
    keyed by (projection instance, stream step, role) through the
    :func:`repro.core.cim.conversion_clock` the serving engine threads its
    input-stream counter into. Unlike offset it is never touched by
    recalibration, and unlike the old static per-slot draw it averages
    over conversions the way real thermal noise does;
  * drift: per-slot constant-rate aging — slot s drifts at
    ``drift_sigma * dir_s / 1000`` per stream with dir_s ~ N(0,1), so at
    age t the fleet's offsets have spread by N(0, (drift_sigma*t/1000)^2)
    on top of the corrected residue. ``recalibrate_comparators`` re-runs
    the tail-current calibration against the *drifted* offset, restoring
    the residue to within half a cal-DAC LSB (range permitting).

Slot assignment convention (shared with the swap rounds of
``core.programmed.build_swap_schedule``): a projection's µArray tiles are
enumerated column-major (output channel outer, K-chunk inner) and tile t
occupies slot ``(base + t) % tile_slots`` — ``base`` is the projection's
cumulative tile offset for pinned models and 0 for swapped execution,
whose rounds always fill slots from 0. ``attach_silicon`` applies this
walk-order convention across a whole parameter tree.
"""
# repro-lint: module=deterministic

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.cim import (CimConfig, ProjectionSilicon,
                            cim_program_silicon)
from repro.core.programmed import (_EXPERT_KEYS, ProgrammedMacro,
                                   conv_weight_matrix, map_projections,
                                   strip_keys)
from repro.silicon.variability import calibrated_offset, retrim_offset


def _as_macro(spec):
    """Coerce a ``SiliconConfig`` / macro name / MacroModel to a macro
    model (lazy import: ``repro.macros`` builds on this module). The
    SA-ADC wrapper delegates straight back to the raw functions below,
    so dispatching through it is the identical computation."""
    from repro.macros.registry import as_macro
    return as_macro(spec)


@dataclasses.dataclass(frozen=True)
class SiliconConfig:
    """Distribution + drift parameters of one fleet's silicon lottery.

    ``comparator_sigma_v``/``comparator_cal_bits`` follow the
    :class:`~repro.silicon.variability.VariabilityConfig` conventions
    (±3σ is the tail-current cal-DAC range), so ``calibrated_offset``
    consumes this config directly.
    """

    cap_sigma: float = 0.02              # per-column C_PL mismatch (fraction)
    comparator_sigma_v: float = 0.045 / 3.0   # raw offset sigma (V)
    v_full_scale: float = 0.4            # MAV full scale (= V_PCH)
    calibrate_comparator: bool = True    # run the 2-bit cal at time zero
    comparator_cal_bits: int = 2
    thermal_sigma_v: float = 0.0         # per-conversion noise floor RMS (V)
    drift_sigma_v_per_kstream: float = 0.0    # offset drift RMS per 1k streams
    drift_cap_sigma_per_kstream: float = 0.0  # fractional cap drift per 1k
    seed: int = 0

    @property
    def is_nominal(self) -> bool:
        """True when every sampled quantity collapses to its nominal value
        (the σ=0 bitwise-parity regime)."""
        return (self.cap_sigma == 0.0 and self.comparator_sigma_v == 0.0
                and self.thermal_sigma_v == 0.0
                and self.drift_sigma_v_per_kstream == 0.0
                and self.drift_cap_sigma_per_kstream == 0.0)


class FleetSilicon(NamedTuple):
    """One sampled silicon realisation of a fleet's tile slots.

    All fields are arrays (a valid jax pytree): the struct vmaps over
    sampling keys for Monte-Carlo yield sweeps and rides ``jax.jit``
    boundaries unchanged. ``age_streams`` is the fleet's elapsed service
    age in input streams (decode steps + prefill calls) — the clock the
    drift process runs on.
    """

    cap: jax.Array           # (S, m) sampled cap-DAC weights, 1.0 nominal
    offset_v: jax.Array      # (S,) raw comparator offsets (V), pre-correction
    correction_v: jax.Array  # (S,) current tail-current DAC correction (V)
    drift_dir_v: jax.Array   # (S,) per-slot offset drift direction ~ N(0,1)
    drift_dir_cap: jax.Array  # (S, m) per-column cap drift direction
    age_streams: jax.Array   # () float32 service age

    @property
    def n_slots(self) -> int:
        return self.cap.shape[0]

    @property
    def m_columns(self) -> int:
        return self.cap.shape[1]


def sample_fleet(key: jax.Array, n_slots: int, m_columns: int,
                 cfg: SiliconConfig) -> FleetSilicon:
    """Sample every slot's ADC instance. Same key ⇒ identical fleet."""
    if n_slots < 1 or m_columns < 1:
        raise ValueError(f"degenerate fleet ({n_slots} slots, "
                         f"{m_columns} columns)")
    # 5-way split kept (one branch retired with the static thermal draw)
    # so same-seed fleets sample the same mismatch/drift lottery as before.
    k_cap, k_off, _, k_dv, k_dc = jax.random.split(key, 5)
    cap = 1.0 + cfg.cap_sigma * jax.random.normal(k_cap,
                                                  (n_slots, m_columns))
    offset_v = cfg.comparator_sigma_v * jax.random.normal(k_off, (n_slots,))
    if cfg.calibrate_comparator and cfg.comparator_sigma_v > 0.0:
        correction_v = offset_v - calibrated_offset(offset_v, cfg)
    else:
        correction_v = jnp.zeros((n_slots,))
    drift_dir_v = jax.random.normal(k_dv, (n_slots,))
    drift_dir_cap = jax.random.normal(k_dc, (n_slots, m_columns))
    return FleetSilicon(cap=cap.astype(jnp.float32),
                        offset_v=offset_v.astype(jnp.float32),
                        correction_v=correction_v.astype(jnp.float32),
                        drift_dir_v=drift_dir_v.astype(jnp.float32),
                        drift_dir_cap=drift_dir_cap.astype(jnp.float32),
                        age_streams=jnp.float32(0.0))


def fleet_silicon(fleet, cfg, key: Optional[jax.Array] = None
                  ) -> FleetSilicon:
    """Sample a :class:`~repro.compiler.tiling.Fleet`'s silicon (seeded
    from the config's seed unless an explicit key is given). ``cfg`` is
    a :class:`SiliconConfig` OR any macro model / registered macro name
    (``repro.macros``) — the flavour's ``sample`` hook decides the
    sharing structure (per-slot, per-group, ...)."""
    model = _as_macro(cfg)
    if key is None:
        key = jax.random.PRNGKey(model.seed)
    return model.sample(key, fleet.tile_slots, fleet.cfg.m_columns)


def merge(a: FleetSilicon, b: FleetSilicon) -> FleetSilicon:
    """Concatenate two sampled slot ranges into one fleet (observer-style
    mergeability: hosts sampling disjoint slot blocks combine exactly)."""
    if a.m_columns != b.m_columns:
        raise ValueError(f"µArray widths differ: {a.m_columns} vs "
                         f"{b.m_columns}")
    return FleetSilicon(
        cap=jnp.concatenate([a.cap, b.cap]),
        offset_v=jnp.concatenate([a.offset_v, b.offset_v]),
        correction_v=jnp.concatenate([a.correction_v, b.correction_v]),
        drift_dir_v=jnp.concatenate([a.drift_dir_v, b.drift_dir_v]),
        drift_dir_cap=jnp.concatenate([a.drift_dir_cap, b.drift_dir_cap]),
        age_streams=jnp.maximum(a.age_streams, b.age_streams))


def age(sil: FleetSilicon, streams) -> FleetSilicon:
    """Advance the fleet's service age by ``streams`` input streams."""
    return sil._replace(age_streams=sil.age_streams
                        + jnp.float32(streams))


def _drifted_offset_v(sil: FleetSilicon, cfg: SiliconConfig) -> jax.Array:
    """(S,) raw comparator offsets at the fleet's current age (V)."""
    drift = (cfg.drift_sigma_v_per_kstream * (sil.age_streams / 1000.0)
             * sil.drift_dir_v)
    return sil.offset_v + drift


def effective_offsets(sil: FleetSilicon, cfg: SiliconConfig) -> jax.Array:
    """(S,) comparator offsets the ADC sees NOW, as full-scale fractions:
    drifted raw offset minus the standing correction. The (uncorrectable)
    thermal noise floor is NOT folded in here — it is per-conversion
    dither, drawn at every ADC evaluation by
    :meth:`~repro.core.cim.ProjectionSilicon.dither`."""
    off_v = _drifted_offset_v(sil, cfg) - sil.correction_v
    return off_v / cfg.v_full_scale


def effective_caps(sil: FleetSilicon, cfg: SiliconConfig) -> jax.Array:
    """(S, m) cap-DAC weights at the fleet's current age (1.0 nominal)."""
    drift = (cfg.drift_cap_sigma_per_kstream * (sil.age_streams / 1000.0)
             * sil.drift_dir_cap)
    return jnp.maximum(sil.cap + drift, 1e-3)


def recalibrate_comparators(sil: FleetSilicon, cfg) -> FleetSilicon:
    """Re-run the tail-current offset calibration against the DRIFTED
    offsets: the new standing correction cancels the drifted offset to
    within half a cal-DAC LSB wherever it falls inside the ±3σ DAC range
    (beyond-range drift saturates the DAC — residue grows, faithfully).
    No-op when the comparator calibration is disabled. ``cfg`` may be a
    macro model / registered name, whose ``recalibrate`` hook runs."""
    if not isinstance(cfg, SiliconConfig):
        return _as_macro(cfg).recalibrate(sil)
    if not cfg.calibrate_comparator or cfg.comparator_sigma_v == 0.0:
        return sil
    raw_t = _drifted_offset_v(sil, cfg)
    correction = raw_t - calibrated_offset(raw_t, cfg)
    return sil._replace(correction_v=correction.astype(jnp.float32))


def retrim_comparators(sil: FleetSilicon, cfg: SiliconConfig, *,
                       coarse_mult: float = 3.0
                       ) -> tuple[FleetSilicon, jax.Array]:
    """Tiered re-trim against the drifted offsets: the fine ±3σ DAC
    where it still captures, a ``coarse_mult``× re-biased coarse tier
    for slots whose drift saturated the fine range, and an int32 tier
    verdict per slot (0 fine / 1 coarse / 2 saturated-even-coarse —
    the screening candidates for retirement). Bit-identical to
    :func:`recalibrate_comparators` wherever the fine range suffices.
    """
    if not cfg.calibrate_comparator or cfg.comparator_sigma_v == 0.0:
        return sil, jnp.zeros((sil.n_slots,), jnp.int32)
    raw_t = _drifted_offset_v(sil, cfg)
    residue, tier = retrim_offset(raw_t, cfg, coarse_mult)
    correction = raw_t - residue
    return sil._replace(correction_v=correction.astype(jnp.float32)), tier


def retired_slots_mask(sil: FleetSilicon, cfg: SiliconConfig, *,
                       coarse_mult: float = 3.0) -> jax.Array:
    """(S,) bool — slots whose drifted offset exceeds even the coarse
    re-trim range (tier 2 of :func:`retrim_comparators`)."""
    if not cfg.calibrate_comparator or cfg.comparator_sigma_v == 0.0:
        return jnp.zeros((sil.n_slots,), bool)
    _, tier = retrim_offset(_drifted_offset_v(sil, cfg), cfg, coarse_mult)
    return tier == 2


# ---------------------------------------------------------------------------
# Projection-shaped gathers (what the step-time datapath consumes).
# ---------------------------------------------------------------------------

def _gather(eff_cap: jax.Array, eff_off: jax.Array, k: int, n: int,
            base: int, thermal_fs: Optional[jax.Array] = None,
            noise_key: Optional[jax.Array] = None) -> ProjectionSilicon:
    m = eff_cap.shape[-1]
    s = eff_cap.shape[0]
    chunks = -(-k // m)
    idx = (base + jnp.arange(n * chunks, dtype=jnp.int32)).reshape(
        n, chunks) % s
    cap = eff_cap[idx]                       # (N, C, m)
    off = eff_off[idx]                       # (N, C)
    # The |x| dummy-row conversion of chunk c is shared across output
    # channels; it digitises through channel 0's slot for that chunk.
    return ProjectionSilicon(cap, off, cap[0], off[0], thermal_fs,
                             noise_key)


def _thermal_pair(cfg: SiliconConfig,
                  noise_key: Optional[jax.Array] = None):
    """(thermal_fs, noise_key) leaves of the per-conversion dither stream
    — (None, None) when the noise floor is off, keeping the σ_th=0 path
    structurally identical to pre-thermal trees."""
    if cfg.thermal_sigma_v == 0.0:
        return None, None
    fs = jnp.float32(cfg.thermal_sigma_v / cfg.v_full_scale)
    if noise_key is None:
        noise_key = jax.random.PRNGKey(cfg.seed)
    return fs, noise_key


def projection_silicon(sil: FleetSilicon, cfg, k: int,
                       n: int, *, base: int = 0,
                       noise_key: Optional[jax.Array] = None
                       ) -> ProjectionSilicon:
    """The per-tile silicon view of one (k, n) projection whose tiles
    occupy slots ``(base + t) % n_slots`` in column-major tile order.
    ``noise_key`` seeds the per-conversion dither stream when the macro
    adds conversion noise (thermal floor, cross-macro coupling) —
    default: keyed from the config's seed. ``cfg`` is a
    :class:`SiliconConfig` or any macro model / registered name."""
    model = _as_macro(cfg)
    fs, nkey = model.conversion_pair(noise_key)
    return _gather(model.effective_caps(sil), model.effective_offsets(sil),
                   k, n, base, fs, nkey)


def _tiles(k: int, n: int, m: int) -> int:
    return (-(-k // m)) * n


def attach_silicon(params: Any, sil: FleetSilicon, cfg,
                   cim: CimConfig, *, pinned: bool = True) -> Any:
    """Embed per-tile silicon views in every MF projection of a tree.

    ``cfg`` is a :class:`SiliconConfig` or any macro model / registered
    macro name (``repro.macros``): the flavour's effective-caps /
    effective-offsets / conversion-noise hooks shape the views. A bare
    ``SiliconConfig`` dispatches through the SA-ADC flavour, whose hooks
    ARE the raw functions of this module — the identical computation.

    Returns a copy of ``params`` where each projection dict gains a
    ``"sil"`` entry (expert banks: ``sil_up/gate/down``) consumed by
    ``apply_projection`` / ``conv_apply`` / ``_expert_ffn`` in CIM_SIM
    mode. Stacked leading axes (scan periods, experts) get stacked views
    that slice exactly like the programmed state they perturb.

    Projections already programmed into the Pallas kernel layout
    additionally gain a ``"silk"`` entry (``silk_up/gate/down`` for
    experts): the program-time cap fold
    (:func:`~repro.core.cim.cim_program_silicon`) of their silicon view,
    so the fused step-time kernel consumes pre-folded operands instead of
    re-folding caps every decode step. Re-attachment after drift /
    recalibration rebuilds the fold against the refreshed instances.

    ``pinned=True`` advances the slot base per projection in walk order —
    the same order the serve engine compiles (``iter_projections``), so
    every tile of a pinned model reads a distinct slot until the fleet
    wraps. ``pinned=False`` matches round-interleaved serving, whose swap
    rounds always refill slots from 0.
    """
    if sil.m_columns != cim.m_columns:
        raise ValueError(
            f"fleet silicon is sampled for m_columns={sil.m_columns}, "
            f"the model runs m_columns={cim.m_columns}")
    model = _as_macro(cfg)
    eff_cap = model.effective_caps(sil)
    eff_off = model.effective_offsets(sil)
    thermal_fs, noise_root = model.conversion_pair()
    m = cim.m_columns
    next_base = 0
    next_inst = 0

    def take_base(n_tiles: int) -> int:
        nonlocal next_base
        b = next_base if pinned else 0
        if pinned:
            next_base += n_tiles
        return b

    def take_key() -> Optional[jax.Array]:
        """Each projection INSTANCE (walk order, incl. every stacked scan
        period / expert) gets its own dither stream — the walk order is
        deterministic, so re-attachment (drift refresh, recalibration)
        reproduces the same streams."""
        nonlocal next_inst
        if noise_root is None:
            return None
        k = jax.random.fold_in(noise_root, next_inst)
        next_inst += 1
        return k

    def view_nd(w_shape) -> Any:
        """Stacked gather over leading axes of a (..., K, N) weight."""
        *lead, k, n = w_shape
        if not lead:
            return _gather(eff_cap, eff_off, k, n,
                           take_base(_tiles(k, n, m)), thermal_fs,
                           take_key())
        views = [view_nd(tuple(lead[1:]) + (k, n)) for _ in range(lead[0])]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *views)

    def maybe_silk(prog, silv):
        """Program-time cap fold for kernel-layout programmed macros."""
        if not isinstance(prog, ProgrammedMacro) or prog.kernel is None:
            return None
        return cim_program_silicon(prog.kernel, silv, cim)

    def attach(name, node, kind):
        out = dict(node)
        if kind == "experts":
            for key in _EXPERT_KEYS:
                out[f"sil_{key}"] = view_nd(tuple(node[key].shape))
                silk = maybe_silk(node.get(f"prog_{key}"),
                                  out[f"sil_{key}"])
                if silk is not None:
                    out[f"silk_{key}"] = silk
        elif kind == "conv":
            k2, n2 = conv_weight_matrix(node["w"]).shape
            out["sil"] = _gather(eff_cap, eff_off, k2, n2,
                                 take_base(_tiles(k2, n2, m)), thermal_fs,
                                 take_key())
            silk = maybe_silk(node.get("prog"), out["sil"])
            if silk is not None:
                out["silk"] = silk
        else:
            out["sil"] = view_nd(tuple(node["w"].shape))
            silk = maybe_silk(node.get("prog"), out["sil"])
            if silk is not None:
                out["silk"] = silk
        return out

    return map_projections(params, attach)


def strip_silicon(params: Any) -> Any:
    """Inverse of :func:`attach_silicon` (drop every silicon entry,
    including the kernel-layout ``silk`` cap folds)."""
    return strip_keys(params, lambda k: isinstance(k, str)
                      and (k in ("sil", "silk") or k.startswith("sil_")
                           or k.startswith("silk_")))
