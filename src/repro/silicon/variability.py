"""Process-variability models + on-chip calibration (paper Sec. V-D, Fig. 8).

Part of the silicon lab (``repro.silicon``): these are the *distributional*
models — per-column capacitor mismatch, comparator offset, the 2-bit
tail-current offset calibration, and the Fig. 8 screening/crossover
Monte-Carlos. Per-slot *instances* of a whole fleet (every µArray half
gets its own sampled ADC) live in :mod:`repro.silicon.instance`.
``repro.core.variability`` re-exports this module for backwards
compatibility.

Three effects are modelled, all as deterministic keyed-RNG Monte-Carlo:

  1. PL-capacitor mismatch: per-column C_PL = C_nom * (1 + eps),
     eps ~ N(0, sigma^2). Mismatched capacitors skew the charge averaging
     (MAV = sum c_j b_j / sum c_j) so adjacent MAV levels can cross over
     (Fig. 8a/8d). Global C_PL variation is common-mode (the reference DAC
     lives in the other half of the same array) and cancels — only mismatch
     matters, which is why we model eps per column only.

  2. Column screening (Fig. 8b/8c): the strength of each PL capacitor is
     estimated on-chip by counting charge cycles to a threshold; the most
     extreme columns are 'discarded' by writing all-ones (they always
     discharge, contributing only to the averaging denominator, and their
     fixed numerator contribution is subtracted digitally).

  3. Comparator offset (Fig. 8e): offset ~ N(0, sigma_cmp); a 2-bit
     tail-current calibration quantises away the bulk, leaving the residue
     (paper: +-45 mV -> +-12 mV).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp

if TYPE_CHECKING:  # annotation-only: keeps this module import-cycle free
    from repro.core.cim import CimConfig


@dataclasses.dataclass(frozen=True)
class VariabilityConfig:
    cap_sigma: float = 0.04        # per-column C_PL mismatch (fraction)
    comparator_sigma_v: float = 0.045 / 3.0  # so +-3 sigma ~ +-45 mV
    v_full_scale: float = 0.4      # MAV full-scale voltage (= V_PCH)
    calibrate_comparator: bool = True
    comparator_cal_bits: int = 2   # tail-current DAC bits (Fig. 8e)
    screen_fraction: float = 0.03  # discard worst ~3% of columns (Fig. 8d)
    screen_cycles: int = 64        # charge-count cycles for estimation


def sample_cap_weights(key: jax.Array, n_columns: int,
                       cfg: VariabilityConfig) -> jax.Array:
    """Per-column capacitor weights, 1.0 nominal."""
    eps = cfg.cap_sigma * jax.random.normal(key, (n_columns,))
    return 1.0 + eps


def sample_comparator_offset(key: jax.Array, cfg: VariabilityConfig
                             ) -> jax.Array:
    """Comparator offset as a fraction of ADC full scale, post-calibration."""
    off_v = cfg.comparator_sigma_v * jax.random.normal(key, ())
    if cfg.calibrate_comparator:
        off_v = calibrated_offset(off_v, cfg)
    return off_v / cfg.v_full_scale


def calibrated_offset(offset_v: jax.Array, cfg: VariabilityConfig
                      ) -> jax.Array:
    """2-bit tail-current calibration: subtract the nearest DAC step.

    The counter-based scheme estimates the offset sign from the metastable
    0/1 statistics and adds tail transistors until the bias flips; the
    residue is half an LSB of the calibration DAC. +-45 mV at 2 bits ->
    steps of 30 mV over [-45, 45] -> residue <= 15 mV ~ the paper's 12 mV.
    """
    full = 3.0 * cfg.comparator_sigma_v            # +-45 mV range
    steps = 2 ** cfg.comparator_cal_bits
    lsb = 2.0 * full / steps
    return offset_v - jnp.clip(jnp.round(offset_v / lsb), -(steps // 2),
                               steps // 2) * lsb


def retrim_offset(offset_v: jax.Array, cfg: VariabilityConfig,
                  coarse_mult: float = 3.0
                  ) -> tuple[jax.Array, jax.Array]:
    """Tiered tail-current re-trim: ``(residue_v, tier)``.

    Aging extension of :func:`calibrated_offset`. The fine tier is the
    standard ±3σ cal DAC; once a slot's (drifted) offset leaves the fine
    range — |offset| beyond the outermost fine step's capture window —
    the DAC is re-biased to a ``coarse_mult``× wider range at the same
    step count (coarser LSB, same hardware: the tail-current mirror is
    ratioed up). Offsets beyond even the coarse range saturate the DAC;
    their residue grows without bound and the slot is the screening
    candidate for retirement.

    Returns the post-trim residue (V) and an int32 tier per slot:
    0 = fine (bit-identical to :func:`calibrated_offset`), 1 = coarse
    tier engaged, 2 = saturated beyond the coarse range (retire).
    """
    full = 3.0 * cfg.comparator_sigma_v
    steps = 2 ** cfg.comparator_cal_bits
    half = steps // 2
    lsb = 2.0 * full / steps
    fine = offset_v - jnp.clip(jnp.round(offset_v / lsb), -half,
                               half) * lsb
    coarse_full = coarse_mult * full
    coarse_lsb = 2.0 * coarse_full / steps
    coarse = offset_v - jnp.clip(jnp.round(offset_v / coarse_lsb), -half,
                                 half) * coarse_lsb
    # Inside this window the fine clip never binds, so the fine branch
    # IS calibrated_offset — existing drift benches whose offsets stay
    # in range re-trim bit-identically to the single-tier path.
    in_fine = jnp.abs(offset_v) <= full + 0.5 * lsb
    in_coarse = jnp.abs(offset_v) <= coarse_full + 0.5 * coarse_lsb
    residue = jnp.where(in_fine, fine, coarse)
    tier = jnp.where(in_fine, 0,
                     jnp.where(in_coarse, 1, 2)).astype(jnp.int32)
    return residue, tier


def estimate_cap_strength(cap_weights: jax.Array, cfg: VariabilityConfig,
                          key: Optional[jax.Array] = None) -> jax.Array:
    """On-chip charge-cycle counting estimator of per-column C_PL (Fig. 8c).

    Each cycle deposits charge ~ c_j onto the sum line; cycles to cross a
    fixed threshold ~ T/c_j (+ comparator sampling noise). Returns the
    estimated relative strength (bigger = stronger capacitor).
    """
    thresh = cfg.screen_cycles  # nominal column crosses in screen_cycles
    cycles = jnp.ceil(thresh / cap_weights)
    if key is not None:
        cycles = cycles + jax.random.randint(key, cycles.shape, 0, 2)
    return thresh / cycles


def screen_columns(cap_weights: jax.Array, cfg: VariabilityConfig,
                   key: Optional[jax.Array] = None) -> jax.Array:
    """Boolean mask of columns to KEEP after screening the extremes.

    Discards the ``screen_fraction`` columns whose estimated strength
    deviates most from the median.
    """
    n = cap_weights.shape[0]
    est = estimate_cap_strength(cap_weights, cfg, key)
    dev = jnp.abs(est - jnp.median(est))
    k_discard = int(round(cfg.screen_fraction * n))
    if k_discard == 0:
        return jnp.ones((n,), bool)
    cutoff = jnp.sort(dev)[n - k_discard]   # smallest discarded deviation
    return dev < cutoff


# ---------------------------------------------------------------------------
# Fig. 8d: MAV crossover probability Monte-Carlo.
# ---------------------------------------------------------------------------

def mav_crossover_probability(key: jax.Array, cim: CimConfig,
                              var: VariabilityConfig, n_trials: int = 2000,
                              screened: bool = False) -> jax.Array:
    """P(two adjacent MAV levels cross) for an M-column µArray half.

    Fig. 8a/8d: each MAV level k is realised by *some* subset of k
    discharging columns, so mismatched capacitors spread each level into a
    distribution. We Monte-Carlo the per-comparison crossover: draw a
    mismatch sample, draw independent random column subsets realising
    counts k and k+1, and report the probability that the level-(k+1)
    realisation does not exceed the level-k realisation (averaged over k
    and trials). ``screened=True`` first discards the extreme columns via
    the on-chip estimator (Fig. 8b/8c): they are written all-ones, always
    discharge, and their constant contribution is removed digitally.
    """
    m = cim.m_columns
    n_keep = m - (int(round(var.screen_fraction * m)) if screened else 0)

    def one_trial(k):
        kc, ks, k1, k2 = jax.random.split(k, 4)
        caps = sample_cap_weights(kc, m, var)
        if screened:
            keep = screen_columns(caps, var, ks)
        else:
            keep = jnp.ones((m,), bool)
        denom = jnp.sum(caps)

        def level_caps(kperm):
            # random order with kept columns first: signal subsets draw
            # from kept columns only; discarded columns always discharge
            # and their constant term cancels in adjacent comparisons.
            perm = jax.random.permutation(kperm, m)
            order = perm[jnp.argsort(~keep[perm], stable=True)]
            return jnp.cumsum(caps[order]) / denom

        ca, cb = level_caps(k1), level_caps(k2)
        cross = (cb[1:] <= ca[:-1]) & (jnp.arange(m - 1) < n_keep - 1)
        return jnp.sum(cross.astype(jnp.float32)) / (n_keep - 1)

    keys = jax.random.split(key, n_trials)
    return jnp.mean(jax.vmap(one_trial)(keys))
