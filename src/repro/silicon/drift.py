"""Drift monitor: detect silicon aging in a serving engine and steer
auto-recalibration.

Long-lived CIM serving cannot assume the silicon it calibrated at day
zero: comparator offsets and cap-DAC weights drift with age, and the
programmed per-projection activation scales go stale with them. The
monitor closes ROADMAP's "re-calibration drift detection" loop:

  * a fixed PROBE corpus is replayed through two forwards — the float MF
    reference (the distribution calibration targeted) and the live CIM
    datapath (programmed state + current silicon) — and the end-to-end
    logits rel-L2 is compared against the baseline recorded when the
    engine was built;
  * the same probe runs under the calibration lab's activation tap
    (``repro.calib``), giving live per-projection amax which is compared
    against the DAC full scale the programmed
    :class:`~repro.calib.artifact.CalibrationArtifact` scales imply — a
    clipping ratio > 1 means activations have outgrown the programmed
    input DAC range;
  * either signal past its threshold raises a drift ALARM; the serve
    engine then re-runs the comparator offset calibration
    (:func:`repro.silicon.instance.recalibrate_comparators`) and
    re-programs measured activation scales, charging the reload against
    the Eq. 4 roll-up in its :class:`~repro.serve.engine.ServeReport`.

The monitor itself is engine-agnostic: it measures, the engine acts.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence

import jax
import numpy as np

from repro.calib import tap
from repro.calib.corpus import ObserverRegistry, StatsCollector
from repro.calib.observers import ObserverConfig
from repro.core import quant


@dataclasses.dataclass(frozen=True)
class DriftPolicy:
    """When to probe, when to alarm, whether to self-heal.

    ``probe_batches`` are ordinary forward batches (LMs: ``{"tokens":
    (B, T)}``) — kept small; they run at every check and double as the
    recalibration corpus. Intervals are in input STREAMS (decode steps +
    batched-prefill calls), the clock the drift process runs on.
    """

    probe_batches: Sequence[Any]
    check_interval: int = 32            # streams between drift probes
    silicon_update_interval: int = 8    # streams between drift re-gathers
    rel_l2_alarm_ratio: float = 1.5     # alarm: rel_l2 > ratio * baseline
    rel_l2_alarm_floor: float = 0.02    # ... and above this absolute floor
    clip_alarm_ratio: float = 1.25      # alarm: live amax > ratio * DAC range
    auto_recalibrate: bool = True


@dataclasses.dataclass(frozen=True)
class DriftStatus:
    """One drift probe's verdict (``ServeEngine.drift_log`` entries)."""

    stream: int
    rel_l2: float
    baseline_rel_l2: float
    max_clip_ratio: float
    alarm: bool
    reasons: tuple[str, ...]
    recalibrated: bool = False
    post_rel_l2: float = math.nan
    # Tiered re-trim accounting (repro.silicon.instance.retrim_comparators,
    # filled by the engine when a recalibration ran): slots whose drift
    # saturated the fine ±3σ DAC and re-trimmed on the coarse tier, and
    # slots beyond even the coarse range — screened for retirement.
    retrim_coarse_slots: int = 0
    retired_slots: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self) | {"reasons": list(self.reasons)}


class DriftMonitor:
    """Probe harness bound to one LM config + probe corpus.

    The float-reference logits are computed once (they never drift). The
    live probe forward is traced exactly once — inside an observing
    context so the activation-tap ``io_callback``s are staged into the
    compiled program, bound to one long-lived collector whose
    accumulators are zeroed per probe — and re-run against whatever exec
    params the engine currently serves (recalibration changes leaf
    VALUES only, so no retrace). One replay therefore yields BOTH drift
    signals: the probe logits for rel-L2 and the per-projection live
    amax for the clip check.
    """

    def __init__(self, cfg, ref_params: Any, policy: DriftPolicy,
                 registry: ObserverRegistry, scales: dict,
                 x_bits: int, obs_cfg: ObserverConfig = ObserverConfig()):
        from repro.calib.report import lm_ref_config
        from repro.models import transformer as T
        self.policy = policy
        self.registry = registry
        self.obs_cfg = obs_cfg
        self._cfg = cfg
        self._scales = dict(scales)
        self._qmax = quant.qmax(x_bits)
        ref_cfg = lm_ref_config(cfg)
        ref_fwd = jax.jit(lambda p, b: T.lm_forward(p, b, ref_cfg)[0])
        self._ref_logits = [np.asarray(ref_fwd(ref_params, b), np.float32)
                            for b in policy.probe_batches]
        self._collector = StatsCollector(registry.n_ids, obs_cfg)
        self._cim_fwd = jax.jit(lambda p, b: T.lm_forward(p, b, cfg)[0])
        self.baseline_rel_l2: Optional[float] = None
        # The day-zero probe error, never re-baselined: recovery gates
        # (is the healed datapath comparable to fresh silicon?) are
        # judged against this even after maintenance re-baselines.
        self.initial_baseline_rel_l2: Optional[float] = None

    # -- probes -------------------------------------------------------------

    def observe(self, exec_params: Any) -> tuple[float, StatsCollector]:
        """One probe replay of the CIM datapath: returns the logits
        rel-L2 vs the frozen float reference AND the filled activation
        collector (count/amax/histogram per projection instance)."""
        col = self._collector
        col.count[:] = 0.0
        col.amax[:] = 0.0
        col.hist[:] = 0.0
        num = den = 0.0
        # The observing context is re-entered every probe so that any
        # retrace (first call, new batch shape) stages the callbacks
        # into THIS collector; already-compiled replays carry them.
        with tap.observing(col):
            for batch, ref in zip(self.policy.probe_batches,
                                  self._ref_logits):
                cim = np.asarray(self._cim_fwd(exec_params, batch),
                                 np.float32)
                num += float(np.sum((cim - ref) ** 2))
                den += float(np.sum(ref ** 2))
        jax.effects_barrier()
        return float(np.sqrt(num / max(den, 1e-30))), col

    def rel_l2(self, exec_params: Any) -> float:
        """End-to-end probe logits error of the live datapath vs the
        frozen float MF reference."""
        return self.observe(exec_params)[0]

    def live_amax(self, exec_params: Any) -> dict[str, np.ndarray]:
        """Per-projection live activation amax through the calib tap
        (one observe replay of the probe corpus on the CIM datapath)."""
        return self._amax_map(self.observe(exec_params)[1])

    def _amax_map(self, col: StatsCollector) -> dict[str, np.ndarray]:
        out: dict[str, np.ndarray] = {}
        for name, (off, shape) in self.registry.entries.items():
            n = int(np.prod(shape, dtype=np.int64)) if shape else 1
            out[name] = col.amax[off:off + n].reshape(shape or ())
        return out

    def _max_clip_ratio(self, live: dict[str, np.ndarray]) -> float:
        """max over projections of live amax / programmed DAC full scale
        (scale * qmax): > 1 means the programmed artifact now clips."""
        worst = 0.0
        for name, sx in self._scales.items():
            if name not in live:
                continue
            full = np.asarray(sx, np.float64) * self._qmax
            ratio = np.asarray(live[name], np.float64) / np.maximum(full,
                                                                    1e-30)
            worst = max(worst, float(np.max(ratio)))
        return worst

    def max_clip_ratio(self, exec_params: Any) -> float:
        return self._max_clip_ratio(self.live_amax(exec_params))

    def set_scales(self, scales: dict) -> None:
        """Point the clip check at freshly re-programmed scales."""
        self._scales = dict(scales)

    def check(self, exec_params: Any, stream: int) -> DriftStatus:
        """One full drift probe against the recorded baseline (a single
        replay of the probe corpus feeds both alarm signals)."""
        if self.baseline_rel_l2 is None:
            raise RuntimeError("drift monitor has no baseline — call "
                               "record_baseline() before check()")
        rel, col = self.observe(exec_params)
        clip = self._max_clip_ratio(self._amax_map(col))
        pol = self.policy
        reasons = []
        if (rel > pol.rel_l2_alarm_ratio * self.baseline_rel_l2
                and rel > pol.rel_l2_alarm_floor):
            reasons.append(
                f"probe rel_l2 {rel:.4f} > {pol.rel_l2_alarm_ratio:.2f}x "
                f"baseline {self.baseline_rel_l2:.4f}")
        if clip > pol.clip_alarm_ratio:
            reasons.append(
                f"live amax is {clip:.2f}x the programmed DAC full scale "
                f"(> {pol.clip_alarm_ratio:.2f}x)")
        return DriftStatus(stream=stream, rel_l2=rel,
                           baseline_rel_l2=self.baseline_rel_l2,
                           max_clip_ratio=clip, alarm=bool(reasons),
                           reasons=tuple(reasons))

    def record_baseline(self, exec_params: Any) -> float:
        """Measure and pin the pre-drift probe error (the recovery gate)."""
        self.baseline_rel_l2 = self.rel_l2(exec_params)
        if self.initial_baseline_rel_l2 is None:
            self.initial_baseline_rel_l2 = self.baseline_rel_l2
        return self.baseline_rel_l2

    def rebaseline(self, rel_l2: float) -> None:
        """Reset the alarm baseline after maintenance (recalibration):
        re-programmed scales trade some quantisation resolution for DAC
        headroom, so the healed probe error — not the day-zero one — is
        the reference future drift is measured against (otherwise a
        successfully recovered engine re-alarms every check)."""
        self.baseline_rel_l2 = float(rel_l2)
