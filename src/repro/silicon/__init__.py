"""Silicon lab: fleet-scale analog non-ideality modeling for the CIM runtime.

Three layers:

  * :mod:`repro.silicon.variability` — the distributional models (cap
    mismatch, comparator offset, tail-current calibration, Fig. 8
    screening/crossover Monte-Carlos); re-exported by the legacy
    ``repro.core.variability`` path.
  * :mod:`repro.silicon.instance` — per-slot sampled ADC instances of a
    whole fleet (:class:`FleetSilicon`), drift/aging, and the
    projection-shaped gathers the step-time datapath consumes.
  * :mod:`repro.silicon.montecarlo` / :mod:`repro.silicon.drift` — vmapped
    multi-seed yield sweeps and the drift monitor the serve engine uses
    for auto-recalibration (imported lazily by their consumers: they pull
    in the calibration lab).
"""

from repro.silicon.variability import (VariabilityConfig, calibrated_offset,
                                       mav_crossover_probability,
                                       retrim_offset, sample_cap_weights,
                                       sample_comparator_offset,
                                       screen_columns)
from repro.silicon.instance import (FleetSilicon, SiliconConfig,
                                    age, attach_silicon, effective_caps,
                                    effective_offsets, fleet_silicon, merge,
                                    projection_silicon,
                                    recalibrate_comparators,
                                    retired_slots_mask, retrim_comparators,
                                    sample_fleet, strip_silicon)

__all__ = [
    "VariabilityConfig", "calibrated_offset", "mav_crossover_probability",
    "retrim_offset", "sample_cap_weights", "sample_comparator_offset",
    "screen_columns",
    "FleetSilicon", "SiliconConfig", "age", "attach_silicon",
    "effective_caps", "effective_offsets", "fleet_silicon", "merge",
    "projection_silicon", "recalibrate_comparators", "retired_slots_mask",
    "retrim_comparators", "sample_fleet", "strip_silicon",
]
