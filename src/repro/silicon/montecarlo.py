"""Monte-Carlo yield sweeps over the silicon lottery.

Answers the fleet-procurement questions the paper's Sec. IV
mismatch/offset characterisation raises: across many sampled silicon
realisations, how much SQNR does a design point lose at a given mismatch
σ, what fraction of fleets clears an accuracy floor (*yield*), and how
much of the comparator-offset loss does the 2-bit tail-current
calibration win back?

The projection-level sweeps vmap the ENTIRE pipeline — per-slot instance
sampling, the per-tile silicon einsum route, Eq. 2 recombination — over
sampling keys, so a 64-seed sweep is one XLA program. SQNR is measured
against the *nominal* CIM output of the same design point, isolating
silicon-induced error from quantisation/ADC error (which
``benchmarks/calib_report.py`` already tracks). Model-level yield (logits
rel-L2 over seeds, via the calibration lab's evaluators) is composed in
``benchmarks/silicon_report.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.cim import CimConfig, ProjectionSilicon, cim_mf_matmul
from repro.silicon.instance import (FleetSilicon, SiliconConfig, _as_macro,
                                    projection_silicon, sample_fleet)


def sample_projection_silicon(key: jax.Array, k: int, n: int,
                              m_columns: int, cfg) -> ProjectionSilicon:
    """Sample a dedicated slot per µArray tile of one (k, n) projection —
    the fresh-fleet regime. ``cfg`` is a :class:`SiliconConfig` or any
    macro model / registered name (``repro.macros``): the flavour's
    ``sample`` hook decides instance sharing (per-slot, per-group)."""
    model = _as_macro(cfg)
    chunks = -(-k // m_columns)
    fleet = model.sample(key, chunks * n, m_columns)
    # The dither stream rides the sampling key so vmapped MC instances
    # draw independent per-conversion noise.
    return projection_silicon(fleet, model, k, n,
                              noise_key=jax.random.fold_in(key, 7))


def _sqnr_db(ref: jax.Array, y: jax.Array, cap_db: float = 120.0
             ) -> jax.Array:
    num = jnp.sum(ref.astype(jnp.float32) ** 2)
    err = jnp.sum((y.astype(jnp.float32) - ref.astype(jnp.float32)) ** 2)
    floor = num * 10.0 ** (-cap_db / 10.0)
    return 10.0 * jnp.log10(num / jnp.maximum(err, floor))


def projection_sqnr_samples(key: jax.Array, x: jax.Array, w: jax.Array,
                            cim: CimConfig, cfg,
                            n_seeds: int) -> jax.Array:
    """(n_seeds,) SQNR in dB of the silicon route vs the nominal CIM
    output, one sampled fleet per seed (vmapped end to end). ``cfg`` is
    a :class:`SiliconConfig` or any macro model / registered name."""
    y0 = cim_mf_matmul(x, w, cim)
    k, n = w.shape

    def one(seed_key: jax.Array) -> jax.Array:
        sil = sample_projection_silicon(seed_key, k, n, cim.m_columns, cfg)
        return _sqnr_db(y0, cim_mf_matmul(x, w, cim, silicon=sil))

    return jax.vmap(one)(jax.random.split(key, n_seeds))


@dataclasses.dataclass(frozen=True)
class YieldPoint:
    """One (design point, mismatch σ) cell of a yield sweep."""

    cap_sigma: float
    mean_sqnr_db: float
    p05_sqnr_db: float        # 5th-percentile seed — the near-worst fleet
    min_sqnr_db: float
    yield_frac: float         # fraction of seeds >= the SQNR floor
    n_seeds: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def projection_yield_curve(key: jax.Array, x: jax.Array, w: jax.Array,
                           cim: CimConfig, base,
                           sigmas: Sequence[float], n_seeds: int,
                           sqnr_floor_db: float = 20.0
                           ) -> list[YieldPoint]:
    """Sweep cap-DAC mismatch σ; every other knob comes from ``base`` —
    a :class:`SiliconConfig` or any macro model / registered name, so
    yield curves parameterise over the whole macro zoo."""
    model = _as_macro(base)
    points = []
    for i, sigma in enumerate(sigmas):
        cfg = model.with_mismatch(float(sigma))
        s = projection_sqnr_samples(jax.random.fold_in(key, i), x, w, cim,
                                    cfg, n_seeds)
        points.append(YieldPoint(
            cap_sigma=float(sigma),
            mean_sqnr_db=float(jnp.mean(s)),
            p05_sqnr_db=float(jnp.percentile(s, 5.0)),
            min_sqnr_db=float(jnp.min(s)),
            yield_frac=float(jnp.mean((s >= sqnr_floor_db)
                                      .astype(jnp.float32))),
            n_seeds=n_seeds))
    return points


def offset_correction_delta_db(key: jax.Array, x: jax.Array, w: jax.Array,
                               cim: CimConfig, cfg: SiliconConfig,
                               n_seeds: int) -> tuple[float, float, float]:
    """Mean-SQNR gain of the 2-bit tail-current offset calibration.

    Runs the SAME sampling keys with the comparator calibration on and
    off (cap mismatch zeroed to isolate the offset channel) and returns
    ``(delta_db, corrected_db, uncorrected_db)``. The delta is the dB the
    on-chip calibration wins back — the recovery gate of
    ``BENCH_silicon.json``.
    """
    iso = dataclasses.replace(cfg, cap_sigma=0.0)
    on = projection_sqnr_samples(
        key, x, w, cim, dataclasses.replace(iso, calibrate_comparator=True),
        n_seeds)
    off = projection_sqnr_samples(
        key, x, w, cim, dataclasses.replace(iso,
                                            calibrate_comparator=False),
        n_seeds)
    return (float(jnp.mean(on) - jnp.mean(off)), float(jnp.mean(on)),
            float(jnp.mean(off)))


def fleet_samples(key: jax.Array, n_slots: int, m_columns: int,
                  cfg: SiliconConfig, n_seeds: int) -> FleetSilicon:
    """(n_seeds,)-stacked :class:`FleetSilicon` draws (vmapped sampling) —
    the raw material for custom fleet-level Monte-Carlos."""
    return jax.vmap(lambda k: sample_fleet(k, n_slots, m_columns, cfg))(
        jax.random.split(key, n_seeds))
