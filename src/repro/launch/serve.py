"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Runs the slot-based continuous-batching engine on synthetic prompts and
reports decode throughput. Smoke-scale by default (full configs need a
pod; their decode graphs are exercised by the dry-run).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    from repro.configs.registry import get_config
    from repro.models import transformer as T
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config(args.arch, smoke=True)
    if cfg.family == "encdec":
        raise SystemExit("use the whisper example for enc-dec serving")
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, slots=args.slots,
                         max_len=args.max_len,
                         temperature=args.temperature)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(0, cfg.vocab_size,
                                             args.prompt_len)),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)}/{args.requests} requests, "
          f"{total_new} tokens in {dt:.2f}s "
          f"({total_new / max(dt, 1e-9):.1f} tok/s, "
          f"slots={args.slots})")
    print("[serve] sample output:", done[0].out[:16])


if __name__ == "__main__":
    main()
