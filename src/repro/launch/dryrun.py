"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the fake device count before ANY other import — jax locks the
device count on first init.
"""

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time
import traceback
from functools import partial

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (LM_SHAPES, ModelConfig, ParallelConfig,
                                ShapeConfig, TrainConfig)
from repro.configs.registry import (ARCH_IDS, get_config, input_specs,
                                    shape_applicability)
from repro.launch.mesh import make_production_mesh
from repro.models import encdec as encdec_mod
from repro.models import transformer as T
from repro.parallel import sharding as shd
from repro.roofline import analysis as roofline
from repro.train import train_loop as TL


def dryrun_parallel_config(arch: str, shape: ShapeConfig, multi_pod: bool,
                           overrides: dict | None = None) -> ParallelConfig:
    dp = ("pod", "data") if multi_pod else ("data",)
    kw = dict(dp_axes=dp, tp_axis="model", fsdp=True, use_ep=True,
              ep_axes=("model",), remat="block", microbatches=1)
    if arch == "deepseek-v3-671b":
        kw["ep_axes"] = ("data", "model")      # wide EP: 1 expert/chip
    if shape.kind == "decode":
        kw["remat"] = "none"
        # small models serve with replicated params (no per-layer gather)
        if arch in ("qwen3-0.6b", "recurrentgemma-2b", "xlstm-350m",
                    "whisper-base"):
            kw["fsdp"] = False
    if overrides:
        kw.update(overrides)
    return ParallelConfig(**kw)


def dryrun_train_config(arch: str) -> TrainConfig:
    # Adafactor for the 671B (Adam moments would not fit 256x16GB even
    # fully sharded); AdamW elsewhere.
    opt = "adafactor" if arch == "deepseek-v3-671b" else "adamw"
    return TrainConfig(optimizer=opt)


def _shardings(tree_pspecs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_pspecs,
        is_leaf=lambda x: isinstance(x, P))


def _apply_overrides(cfg: ModelConfig, mf_overrides: dict | None
                     ) -> ModelConfig:
    if not mf_overrides:
        return cfg
    return dataclasses.replace(
        cfg, mf=dataclasses.replace(cfg.mf, **mf_overrides))


def build_cell(arch: str, shape: ShapeConfig, multi_pod: bool,
               pcfg_overrides: dict | None = None,
               tcfg: TrainConfig | None = None,
               cfg: ModelConfig | None = None,
               mf_overrides: dict | None = None):
    """Returns (fn, example_args_structs, in_shardings, donate) for a cell."""
    cfg = _apply_overrides(cfg or get_config(arch), mf_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    pcfg = dryrun_parallel_config(arch, shape, multi_pod, pcfg_overrides)
    tcfg = tcfg or dryrun_train_config(arch)
    pctx = T.ParallelContext(mesh=mesh, cfg=pcfg)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        train_step = TL.make_train_step(cfg, pcfg, tcfg, pctx)
        state_struct = jax.eval_shape(
            lambda: TL.init_state(jax.random.PRNGKey(0), cfg, tcfg))
        pspecs = shd.params_pspecs(state_struct.params, pcfg, mesh)
        opt_pspecs = shd.opt_state_pspecs(state_struct.params, pspecs, tcfg)
        state_shardings = TL.TrainState(
            params=_shardings(pspecs, mesh),
            opt_state=_shardings(opt_pspecs, mesh),
            step=NamedSharding(mesh, P()), ef_error=None)
        batch_p = shd.batch_pspecs(specs, pcfg, mesh, seq_shard=True,
                                   cfg=cfg)
        batch_shardings = _shardings(batch_p, mesh)
        return (train_step, (state_struct, specs),
                (state_shardings, batch_shardings), (0,), mesh, cfg, pcfg)

    params_struct = jax.eval_shape(
        lambda: (encdec_mod.encdec_init(jax.random.PRNGKey(0), cfg)
                 if cfg.family == "encdec"
                 else T.lm_init(jax.random.PRNGKey(0), cfg)))
    pspecs = shd.params_pspecs(params_struct, pcfg, mesh)
    param_shardings = _shardings(pspecs, mesh)

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            fn = partial(encdec_mod.encode, cfg=cfg, pctx=pctx)
            args = (params_struct, specs["frames"])
            in_sh = (param_shardings,
                     NamedSharding(mesh, P(shd.dp_spec(pcfg), "model",
                                           None)))
            return fn, args, in_sh, (), mesh, cfg, pcfg
        fn = partial(T.serve_prefill, cfg=cfg, pctx=pctx)
        batch_p = shd.batch_pspecs(specs, pcfg, mesh, seq_shard=True,
                                   cfg=cfg)
        return (fn, (params_struct, specs),
                (param_shardings, _shardings(batch_p, mesh)), (), mesh,
                cfg, pcfg)

    # decode
    if cfg.family == "encdec":
        fn = partial(encdec_mod.encdec_decode_step, cfg=cfg, pctx=pctx)
    else:
        fn = partial(T.lm_decode_step, cfg=cfg, pctx=pctx)
    cache_struct = specs["cache"]
    batch_p = shd.batch_pspecs(specs, pcfg, mesh, cfg=cfg)
    cache_sh = _shardings(batch_p["cache"], mesh)
    tok_sh = NamedSharding(mesh, batch_p["tokens"])
    return (fn, (params_struct, cache_struct, specs["tokens"]),
            (param_shardings, cache_sh, tok_sh), (1,), mesh, cfg, pcfg)


def _measure_variant(arch: str, shape: ShapeConfig, multi_pod: bool,
                     n_units: int, pcfg_overrides: dict | None,
                     mf_overrides: dict | None = None) -> dict:
    """Compile a shallow FULL-WIDTH variant with the layer scan unrolled.

    XLA's cost_analysis counts a while-loop body once regardless of trip
    count, so per-cell FLOPs/bytes/collectives are extrapolated from two
    unrolled variants: total = f(1) + (units - 1) * (f(2) - f(1)).
    """
    cfg = get_config(arch)
    plen = len(cfg.pattern)
    enc_ratio = (cfg.encoder_layers / cfg.n_layers
                 if cfg.family == "encdec" else 0)
    mini = dataclasses.replace(
        cfg, n_layers=plen * n_units,
        encoder_layers=int(round(enc_ratio * plen * n_units)))
    ov = dict(pcfg_overrides or {})
    ov["scan_unroll"] = True
    fn, args, in_sh, donate, mesh, _, _ = build_cell(
        arch, shape, multi_pod, ov, cfg=mini, mf_overrides=mf_overrides)
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh,
                           donate_argnums=donate).lower(*args).compile()
        terms = roofline.terms_from_compiled(compiled, mesh.devices.size)
    return {"flops": terms.flops, "hbm_bytes": terms.hbm_bytes,
            "coll_bytes": terms.coll_bytes,
            "coll_breakdown": terms.coll_breakdown}


def extrapolated_terms(arch: str, shape: ShapeConfig, multi_pod: bool,
                       chips: int, pcfg_overrides: dict | None = None,
                       mf_overrides: dict | None = None
                       ) -> tuple[roofline.RooflineTerms, dict]:
    cfg = get_config(arch)
    plen = len(cfg.pattern)
    units = cfg.n_layers / plen          # fractional tails interpolate
    u1, u2 = 1, max(2, min(4, int(units)))
    f1 = _measure_variant(arch, shape, multi_pod, u1, pcfg_overrides,
                          mf_overrides)
    f2 = _measure_variant(arch, shape, multi_pod, u2, pcfg_overrides,
                          mf_overrides)

    def ex(a, b):
        # per-unit delta clamped >= 0: XLA's global fusion choices differ
        # slightly between compiles; a layer can't have negative cost.
        per = max((b - a) / (u2 - u1), 0.0)
        return a + (units - u1) * per

    coll = {kind: ex(f1["coll_breakdown"][kind],
                     f2["coll_breakdown"][kind])
            for kind in f1["coll_breakdown"]}
    terms = roofline.RooflineTerms(
        flops=ex(f1["flops"], f2["flops"]),
        hbm_bytes=ex(f1["hbm_bytes"], f2["hbm_bytes"]),
        coll_bytes=float(sum(coll.values())), chips=chips,
        coll_breakdown=coll)
    return terms, {"unit1": f1, "unit2": f2, "units": units,
                   "u1": u1, "u2": u2}


def run_cell(arch: str, shape: ShapeConfig, multi_pod: bool, out_dir: str,
             pcfg_overrides: dict | None = None, tag: str = "",
             mf_overrides: dict | None = None) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape.name}__{mesh_name}" + (f"__{tag}" if tag
                                                      else "")
    record: dict = {"arch": arch, "shape": shape.name, "mesh": mesh_name,
                    "kind": shape.kind, "tag": tag}
    t0 = time.time()
    try:
        fn, args, in_sh, donate, mesh, cfg, pcfg = build_cell(
            arch, shape, multi_pod, pcfg_overrides,
            mf_overrides=mf_overrides)
        chips = mesh.devices.size
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            record["lower_s"] = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            record["compile_s"] = time.time() - t1
            try:
                ma = compiled.memory_analysis()
                record["memory_analysis"] = {
                    k: int(getattr(ma, k)) for k in dir(ma)
                    if k.endswith("_bytes") or k.endswith("in_bytes")
                } if ma is not None else None
            except Exception as e:  # noqa: BLE001
                record["memory_analysis"] = f"unavailable: {e!r}"
            raw_terms = roofline.terms_from_compiled(compiled, chips)
            record["roofline_raw"] = raw_terms.as_dict()
        # Scan-corrected costs: extrapolate from two unrolled shallow
        # variants (XLA cost_analysis counts a loop body once).
        terms, measure = extrapolated_terms(arch, shape, multi_pod, chips,
                                            pcfg_overrides, mf_overrides)
        record["roofline"] = terms.as_dict()
        record["measurement"] = measure

        # useful-work reference
        params_tree = args[0].params if shape.kind == "train" else args[0]
        frac = 1.0
        if cfg.moe is not None:
            frac = (cfg.moe.top_k + cfg.moe.n_shared) / (
                cfg.moe.n_experts + cfg.moe.n_shared)
        counts = roofline.count_params(params_tree, frac)
        tokens = (shape.global_batch * shape.seq_len
                  if shape.kind in ("train", "prefill")
                  else shape.global_batch)
        mf = roofline.model_flops(counts["active"], tokens, shape.kind)
        record["model_params"] = counts
        record["model_flops_total"] = mf
        record["model_flops_per_chip"] = mf / chips
        hlo_flops = record["roofline"]["flops"]
        record["useful_flops_ratio"] = (mf / chips / hlo_flops
                                        if hlo_flops else None)
        record["status"] = "ok"
    except Exception as e:  # noqa: BLE001
        record["status"] = "error"
        record["error"] = repr(e)
        record["traceback"] = traceback.format_exc()
    record["total_s"] = time.time() - t0
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
        json.dump(record, f, indent=1, default=str)
    status = record["status"]
    extra = ("" if status == "ok" else
             " :: " + record.get("error", "")[:160])
    print(f"[dryrun] {cell_id}: {status} ({record['total_s']:.1f}s){extra}",
          flush=True)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else (args.arch,)
    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]
    n_ok = n_err = n_skip = 0
    for arch in archs:
        cfg = get_config(arch)
        for shape in LM_SHAPES:
            if args.shape != "all" and shape.name != args.shape:
                continue
            ok, reason = shape_applicability(cfg, shape)
            if not ok:
                print(f"[dryrun] {arch}__{shape.name}: SKIP ({reason})",
                      flush=True)
                n_skip += 1
                continue
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                path = os.path.join(
                    args.out, f"{arch}__{shape.name}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") == "ok":
                            n_ok += 1
                            continue
                rec = run_cell(arch, shape, mp, args.out)
                if rec["status"] == "ok":
                    n_ok += 1
                else:
                    n_err += 1
    print(f"[dryrun] done: {n_ok} ok, {n_err} errors, {n_skip} skipped",
          flush=True)


if __name__ == "__main__":
    main()
