"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — `pod` spans the
DCN link between pods; `data`/`model` span ICI within a pod.

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """Version-portable ``shard_map``.

    jax >= 0.5 exposes ``jax.shard_map`` (with the replication check
    spelled ``check_vma``); on 0.4.x the public symbol raises
    AttributeError through the deprecation shim and the implementation
    lives at ``jax.experimental.shard_map.shard_map`` with the check
    spelled ``check_rep``. Model code calls this wrapper so both runtimes
    lower the same programs.
    """
    sm = getattr(jax, "shard_map", None)
    kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    if sm is not None:
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        return sm(f, **kwargs)
    from jax.experimental.shard_map import shard_map as sm_old
    if check_vma is not None:
        kwargs["check_rep"] = check_vma
    return sm_old(f, **kwargs)


def axis_size(name) -> int:
    """Static size of a bound mesh axis, inside ``shard_map``.

    ``jax.lax.axis_size`` only exists from jax 0.5; on 0.4.x the axis
    environment exposes the same static int via ``jax.core.axis_frame``.
    """
    sz = getattr(jax.lax, "axis_size", None)
    if sz is not None:
        return sz(name)
    return jax.core.axis_frame(name)


def auto_axis_types(n_axes: int) -> dict:
    """``axis_types`` kwargs for ``jax.make_mesh``, if this jax has them.

    ``jax.sharding.AxisType`` only exists from jax 0.5; on older runtimes
    every mesh axis is implicitly Auto, so omitting the kwarg is equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **auto_axis_types(len(axes)))


def make_local_mesh(model_axis: int = 1, data_axis: int = 1):
    """Small mesh over whatever devices exist (tests/smokes)."""
    n = len(jax.devices())
    data_axis = max(1, min(data_axis, n // model_axis))
    return jax.make_mesh((data_axis, model_axis), ("data", "model"),
                         **auto_axis_types(2))


def make_serve_mesh(data: int = 0, fleet: int = 1, devices=None):
    """Serving mesh, axes ``("data", "fleet")``.

    ``data`` spans the engine's decode-batch (cache slot) dimension —
    classic data parallelism over concurrent streams. ``fleet`` places a
    projection's output-channel tiles across devices (macro placement:
    each device holds a contiguous slice of every programmed µArray
    bank). ``data=0`` takes every device not consumed by ``fleet``.
    ``devices`` restricts the mesh to an explicit device list (e.g. the
    single-device parity mesh) — built through ``jax.sharding.Mesh``
    directly because ``jax.make_mesh`` on this jax picks from the global
    device set only.
    """
    if devices is None:
        n = len(jax.devices())
        if data <= 0:
            data = max(1, n // fleet)
        return jax.make_mesh((data, fleet), ("data", "fleet"),
                             **auto_axis_types(2))
    import numpy as np
    devs = np.asarray(devices, dtype=object)
    if data <= 0:
        data = max(1, devs.size // fleet)
    if devs.size != data * fleet:
        raise ValueError(
            f"{devs.size} devices do not fill a ({data}, {fleet}) mesh")
    return jax.sharding.Mesh(devs.reshape(data, fleet), ("data", "fleet"))
