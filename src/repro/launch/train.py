"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Single-host it runs the smoke-scale config on the local devices; on a real
pod the same driver runs per-host (jax.distributed handles the rest). The
loop is restart-safe: checkpoints + stateless data make `--resume` exact.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the reduced config (full configs need a pod)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--task", default="copy")
    ap.add_argument("--mf", default="on", choices=["on", "off", "cim"],
                    help="paper technique: on (MF operator), off (typical),"
                         " cim (bitplane+ADC hardware sim)")
    args = ap.parse_args()

    import dataclasses

    from repro.configs.base import (MFTechniqueConfig, ParallelConfig,
                                    TrainConfig)
    from repro.configs.registry import get_config
    from repro.data.synthetic import DataConfig, lm_batch
    from repro.train import checkpoint as ckpt_mod
    from repro.train import train_loop as TL
    from repro.train.ft import PreemptionHandler, StepWatchdog

    cfg = get_config(args.arch, smoke=args.smoke)
    mf_map = {"on": MFTechniqueConfig(enabled=True, mode="mf"),
              "off": MFTechniqueConfig(enabled=False),
              "cim": MFTechniqueConfig(enabled=True, mode="cim_sim")}
    cfg = dataclasses.replace(cfg, mf=mf_map[args.mf])
    tcfg = TrainConfig(optimizer=args.optimizer, lr=args.lr,
                       warmup_steps=max(args.steps // 20, 1),
                       total_steps=args.steps)
    pcfg = ParallelConfig(microbatches=args.microbatches, remat="none")
    print(f"[train] arch={cfg.name} mf={args.mf} steps={args.steps}")

    state = TL.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = ckpt_mod.CheckpointManager(args.ckpt_dir)
        if args.resume and ckpt_mod.latest_step(args.ckpt_dir) is not None:
            start_step = ckpt_mod.latest_step(args.ckpt_dir)
            state = ckpt_mod.restore(args.ckpt_dir, state, step=start_step)
            print(f"[train] resumed from step {start_step}")

    # repro-lint: disable=R003 reason=built once per process, reused across steps
    step_fn = jax.jit(TL.make_train_step(cfg, pcfg, tcfg),
                      donate_argnums=(0,))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.global_batch, task=args.task)
    preempt = PreemptionHandler().install()
    watchdog = StepWatchdog(log=print)

    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = jax.tree.map(jnp.asarray, lm_batch(dcfg, step))
        if cfg.vision_tokens:
            batch["vision_embeds"] = jax.random.normal(
                jax.random.PRNGKey(step),
                (batch["tokens"].shape[0], cfg.vision_tokens,
                 cfg.vision_embed_dim), cfg.dtype)
        if cfg.family == "encdec":
            batch = {"frames": jax.random.normal(
                jax.random.PRNGKey(step),
                (batch["tokens"].shape[0], args.seq_len, cfg.d_model),
                cfg.dtype),
                "tokens": batch["tokens"], "targets": batch["targets"]}
        state, metrics = step_fn(state, batch)
        watchdog.tick(step)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"[train] step {step} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({watchdog.median_step_s:.3f}s/step)")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save_async(step + 1, state)
        if preempt.preempted():
            print("[train] preempted: writing emergency checkpoint")
            if mgr:
                mgr.save_blocking(step + 1, state)
            return
    if mgr:
        mgr.save_blocking(args.steps, state)
    print(f"[train] done in {time.time() - t0:.1f}s; "
          f"straggler events: {len(watchdog.straggler_events)}")


if __name__ == "__main__":
    main()
