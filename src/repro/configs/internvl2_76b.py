"""internvl2-76b [vlm] — InternViT (stub) + Llama3-70B-class LM backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256 [arXiv:2404.16821].
The ViT frontend is a STUB: `input_specs` provides precomputed patch
embeddings (B, 256, 3200) that the model projects to d_model and prepends.
"""

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    vision_tokens=256,
    vision_embed_dim=3200,
    mlp_type="silu_glu",
    rope_theta=5e5,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=2, d_ff=128, vocab_size=128,
                            vision_tokens=4, vision_embed_dim=24,
                            dtype=jnp.float32)
