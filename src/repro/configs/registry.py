"""Architecture registry: ``--arch <id>`` -> ModelConfig (+ smoke variant),
shape applicability, and ShapeDtypeStruct input specs for the dry-run.
"""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import LM_SHAPES, ModelConfig, ShapeConfig

_ARCH_MODULES = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "starcoder2-7b": "starcoder2_7b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen2-72b": "qwen2_72b",
    "qwen3-0.6b": "qwen3_0_6b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "whisper-base": "whisper_base",
    "internvl2-76b": "internvl2_76b",
    "xlstm-350m": "xlstm_350m",
}

ARCH_IDS = tuple(_ARCH_MODULES)

# The paper's own evaluation networks (convnets; run by benchmarks/examples,
# not the LM dry-run).
PAPER_MODELS = ("paper-mnist-lenet5", "paper-cifar10-cnn",
                "paper-cifar100-mobilenetv2")


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG


def shape_applicability(cfg: ModelConfig, shape: ShapeConfig
                        ) -> tuple[bool, str]:
    """(applicable, reason-if-not) for one (arch x shape) cell."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("full-attention arch: 500k decode needs sub-quadratic "
                       "attention (DESIGN.md skip note)")
    return True, ""


def applicable_shapes(cfg: ModelConfig) -> list[ShapeConfig]:
    return [s for s in LM_SHAPES if shape_applicability(cfg, s)[0]]


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Weak-type-correct, shardable, no device allocation. For decode cells
    the cache specs come from `eval_shape` over the cache initialiser.
    """
    b, t = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    if cfg.family == "encdec":
        if shape.kind == "train":
            t_dec = min(cfg.max_decoder_len, t)
            return {
                "frames": jax.ShapeDtypeStruct((b, t, cfg.d_model),
                                               cfg.dtype),
                "tokens": jax.ShapeDtypeStruct((b, t_dec), i32),
                "targets": jax.ShapeDtypeStruct((b, t_dec), i32),
            }
        if shape.kind == "prefill":
            return {"frames": jax.ShapeDtypeStruct((b, t, cfg.d_model),
                                                   cfg.dtype)}
        # decode: one token against a seq_len-deep self-attn cache plus
        # the encoder cross-cache
        from repro.models import encdec as E
        cache = jax.eval_shape(
            lambda: E.encdec_init_cache(cfg, b, t, enc_len=t))
        return {"tokens": jax.ShapeDtypeStruct((b,), i32), "cache": cache}

    if shape.kind in ("train", "prefill"):
        specs = {"tokens": jax.ShapeDtypeStruct((b, t), i32)}
        if cfg.vision_tokens:
            specs["tokens"] = jax.ShapeDtypeStruct(
                (b, t - cfg.vision_tokens), i32)
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_tokens, cfg.vision_embed_dim), cfg.dtype)
        if shape.kind == "train":
            specs["targets"] = jax.ShapeDtypeStruct(
                specs["tokens"].shape, i32)
        return specs

    # decode
    from repro.models import transformer as T
    cache = jax.eval_shape(lambda: T.lm_init_cache(cfg, b, t))
    return {"tokens": jax.ShapeDtypeStruct((b,), i32), "cache": cache}


def all_cells(smoke: bool = False):
    """Every (arch, shape) cell with applicability annotations."""
    cells = []
    for arch in ARCH_IDS:
        full = get_config(arch, smoke=False)
        for shape in LM_SHAPES:
            ok, reason = shape_applicability(full, shape)
            cells.append((arch, shape, ok, reason))
    return cells
