"""whisper-base [audio] — enc-dec backbone; conv/mel frontend is a STUB.

6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865 [arXiv:2212.04356].
`input_specs` provides precomputed frame embeddings (B, T, d_model).
"""

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    encoder_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    norm_type="layernorm",
    mlp_type="gelu",
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, encoder_layers=2, d_model=64,
                            n_heads=4, n_kv_heads=4, d_ff=128,
                            vocab_size=128, dtype=jnp.float32)
