"""nemotron-4-15b [dense] — GQA, squared-ReLU MLP.

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000 [arXiv:2402.16819].
"""

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="lm",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    norm_type="layernorm",
    mlp_type="sq_relu",
    rope_theta=1e4,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=2, d_ff=128, vocab_size=128,
                            dtype=jnp.float32)
