"""qwen3-0.6b [dense] — qk_norm, GQA, head_dim 128, tied embeddings.

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936 [hf:Qwen/Qwen3].
"""

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="lm",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    tie_embeddings=True,
    mlp_type="silu_glu",
    rope_theta=1e6,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=2, d_ff=128, vocab_size=128,
                            head_dim=16, dtype=jnp.float32)
