"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 pattern.

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, window 2048
[arXiv:2402.19427]. Sub-quadratic: runs the long_500k shape.
"""

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    window=2048,
    block_pattern=("rglru", "rglru", "local_attn"),
    lru_width=2560,
    conv_width=4,
    mlp_type="geglu",
    rope_theta=1e4,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(CONFIG, n_layers=3, d_model=64, n_heads=4,
                            n_kv_heads=1, d_ff=128, vocab_size=128,
                            window=16, lru_width=64, dtype=jnp.float32)
