"""qwen2-72b [dense] — GQA with QKV bias.

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 [arXiv:2407.10671].
"""

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="lm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    mlp_type="silu_glu",
    rope_theta=1e6,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=2, d_ff=128, vocab_size=128,
                            dtype=jnp.float32)
