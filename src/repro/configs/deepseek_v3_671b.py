"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8 experts.

61L d_model=7168 128H (MLA) d_ff=2048/expert vocab=129280 [arXiv:2412.19437].
Per the assignment all 61 layers are MoE (the real model's first-3-dense
simplification is noted in DESIGN.md); MTP omitted (single-token head).
"""

import dataclasses

import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    vocab_size=129280,
    attn_type="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, n_shared=1, d_ff_expert=2048),
    rope_theta=1e4,
    mlp_type="silu_glu",
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
    vocab_size=128,
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_ff_expert=32),
    dtype=jnp.float32,
)
