"""xlstm-350m [ssm] — mLSTM + sLSTM blocks, 7:1 ratio.

24L d_model=1024 4H d_ff=0 (projections live inside the blocks)
vocab=50304 [arXiv:2405.04517]. Sub-quadratic: runs long_500k.
"""

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    conv_width=4,
    norm_type="layernorm",
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(CONFIG, n_layers=4, d_model=32, n_heads=2,
                            n_kv_heads=2, vocab_size=128,
                            block_pattern=("mlstm", "mlstm", "mlstm",
                                           "slstm"), dtype=jnp.float32)
