"""llama4-scout-17b-a16e [moe] — 16 routed experts top-1 + 1 shared.

48L d_model=5120 40H (GQA kv=8) d_ff=8192/expert vocab=202048
[hf:meta-llama/Llama-4-Scout-17B-16E]. Early-fusion multimodal frontend is
out of scope for the LM shapes (text backbone only).
"""

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    moe=MoEConfig(n_experts=16, top_k=1, n_shared=1, d_ff_expert=8192),
    rope_theta=5e5,
    mlp_type="silu_glu",
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64,
    vocab_size=128, moe=MoEConfig(n_experts=4, top_k=1, n_shared=1,
                                  d_ff_expert=64),
    dtype=jnp.float32,
)
