"""starcoder2-7b [dense] — GQA, RoPE, GELU MLP, layernorm, biases.

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152 [arXiv:2402.19173].
"""

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="lm",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    qkv_bias=True,
    norm_type="layernorm",
    mlp_type="gelu",
    rope_theta=1e5,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=2, d_ff=128, vocab_size=128,
                            dtype=jnp.float32)
