"""Config schema: architecture, MF-technique, parallelism, and shapes.

Every assigned architecture is a `ModelConfig` in its own module under
`repro/configs/`; `repro.configs.registry` maps ``--arch <id>`` to it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp

from repro.core.cim import CimConfig


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0              # expert hidden dim
    capacity_factor: float = 1.25
    expert_capacity_factor: float = 2.0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MFTechniqueConfig:
    """How the paper's technique is applied to this architecture."""

    enabled: bool = True
    mode: str = "mf"                  # 'mf' | 'mf_kernel' | 'cim_sim'
    threshold: float = 2.0            # ops/param mixed-mapping threshold
    cim: CimConfig = dataclasses.field(default_factory=CimConfig)
    # Which projection groups run MF when enabled (mixed mapping; embeds,
    # logits and routers are always digital, matching the paper).
    attn_qkv: bool = True
    attn_out: bool = True
    mlp: bool = True
    experts: bool = True
    delta_sigma: float = 0.5
    delta_coeff: float = 1.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # lm | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention flavour
    attn_type: str = "gqa"            # 'gqa' | 'mla'
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    window: Optional[int] = None      # sliding window for local attention
    # mlp flavour
    mlp_type: str = "silu_glu"        # silu_glu | geglu | gelu | sq_relu
    norm_type: str = "rmsnorm"        # rmsnorm | layernorm
    tie_embeddings: bool = False
    # block pattern for hybrid archs; None -> all-attention
    block_pattern: Optional[tuple[str, ...]] = None
    # subconfigs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    # encoder-decoder (whisper): n_layers counts DECODER layers
    encoder_layers: int = 0
    max_decoder_len: int = 448
    # vlm stub frontend
    vision_tokens: int = 0
    vision_embed_dim: int = 0
    # rg-lru / xlstm
    lru_width: Optional[int] = None
    conv_width: int = 4
    # MF technique
    mf: MFTechniqueConfig = dataclasses.field(default_factory=MFTechniqueConfig)
    # numerics
    dtype: Any = jnp.bfloat16
    attn_block: int = 1024            # online-softmax KV block
    # statically skip fully-masked (q,kv) block pairs (§Perf; exact)
    attn_block_skip: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern(self) -> tuple[str, ...]:
        return self.block_pattern or ("attn",)

    def layer_kinds(self) -> list[str]:
        pat = self.pattern
        return [pat[i % len(pat)] for i in range(self.n_layers)]

    @property
    def is_subquadratic(self) -> bool:
        """True if decode state is O(window) or O(1) — long_500k eligible."""
        return set(self.layer_kinds()) <= {"rglru", "local_attn", "mlstm",
                                           "slstm"}


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell: an input-shape point for an architecture."""

    name: str                         # train_4k | prefill_32k | ...
    seq_len: int
    global_batch: int
    kind: str                         # 'train' | 'prefill' | 'decode'


LM_SHAPES: tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """Mesh axis usage. Axis names must exist in the active mesh."""

    dp_axes: tuple[str, ...] = ("data",)   # ('pod','data') multi-pod
    tp_axis: str = "model"
    fsdp: bool = True                      # shard params over dp (ZeRO-3)
    use_ep: bool = True                    # expert parallelism for MoE
    # EP mesh axes: ('model',) = 16-way; ('data','model') = wide 256-way EP
    # (DeepSeek-style — one expert per chip, all_to_all stays intra-pod).
    ep_axes: tuple[str, ...] = ("model",)
    seq_shard_cache: bool = True           # flash-decode KV sharding
    remat: str = "block"                   # 'none' | 'block'
    microbatches: int = 1                  # grad-accum pipeline
    # Fully unroll the layer scan. Used by the dry-run's cost-measurement
    # variants: XLA cost_analysis counts a while-loop body ONCE, so
    # roofline FLOPs/bytes are extrapolated from unrolled shallow models.
    scan_unroll: bool = False
    # Wide-EP fast path when one expert lives per shard (§Perf iteration).
    moe_fuse_single_expert: bool = True
    # Serving layout (§Perf HC3): weight-stationary mega-axis TP — shard
    # projection OUT dims over (data x model) where divisible (fallback:
    # model only) and never the contraction dim, so decode moves ~MB of
    # activations per layer instead of all-gathering GBs of weights.
    serve_tp_megaaxis: bool = False


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"
    lr: float = 3e-4
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_compression: Optional[str] = None  # None | 'int8_ef'
    opt_state_dtype: str = "float32"        # 'float32' | 'int8' (quantised)
