"""Public jit'd wrappers for the Pallas kernels.

Handles shape padding/unpadding, batch-dim flattening, block-size
selection, and the CPU fallback (interpret mode) so models can call these
unconditionally. On CPU hosts (tests, this container) the kernels run in
interpret mode; on TPU they compile to Mosaic.
"""
# repro-lint: module=exactness-critical

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels.cim_mav import (CHUNK_PAD, CHUNKS_PER_TILE,
                                   cim_mav_pallas, cim_mav_sil_pallas)
from repro.kernels.mf_matmul import mf_matmul_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _pick_block(dim: int, preferred: int, align: int) -> int:
    """Largest aligned block <= preferred that keeps padding overhead sane."""
    if dim >= preferred:
        return preferred
    return max(align, _round_up(dim, align))


def mf_matmul(x: jax.Array, w: jax.Array, *, bm: int = 128, bn: int = 128,
              bk: int = 128) -> jax.Array:
    """Fused MF correlation x:(...,K) (+) w:(K,N) -> (...,N).

    Pads every dim to its block multiple (sign/abs of zero-padding
    contribute nothing: sign(0)*|w| + |0|*sign(w) = 0).
    """
    batch_shape = x.shape[:-1]
    k, n = w.shape
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    bm = _pick_block(m, bm, 8)
    bn = _pick_block(n, bn, 128)
    bk = _pick_block(k, bk, 128)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xpad = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    wpad = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    y = mf_matmul_pallas(xpad, wpad, bm=bm, bn=bn, bk=bk,
                         interpret=_on_cpu())
    return y[:m, :n].reshape(batch_shape + (n,))


def pack_chunks(v: jax.Array, m_columns: int) -> jax.Array:
    """Lay out the last (K) axis as chunks of CHUNK_PAD lanes.

    Splits K into µArray chunks of ``m_columns`` real lanes, zero-pads each
    chunk to CHUNK_PAD, and pads the chunk count to a multiple of
    CHUNKS_PER_TILE so the kernel's 128-lane tiles line up.

    The packed layout is position-stable: it depends only on (K,
    m_columns), so weight-side operands can be packed once at program time
    (see ``core/programmed.py``) and reused for every streamed input.
    """
    if m_columns > CHUNK_PAD:
        raise ValueError(
            f"m_columns={m_columns} exceeds the kernel chunk width "
            f"CHUNK_PAD={CHUNK_PAD}: a µArray half must fit one padded "
            f"lane group (use m_columns <= {CHUNK_PAD} or widen CHUNK_PAD "
            f"in kernels/cim_mav.py)")
    if m_columns < 1:
        raise ValueError(f"m_columns must be >= 1, got {m_columns}")
    k = v.shape[-1]
    c = -(-k // m_columns)
    kp = c * m_columns
    v = jnp.pad(v, [(0, 0)] * (v.ndim - 1) + [(0, kp - k)])
    return pack_chunked(v.reshape(v.shape[:-1] + (c, m_columns)), m_columns)


def pack_chunked(v: jax.Array, m_columns: int) -> jax.Array:
    """Lane/tile-pad an ALREADY-chunked (..., C, m) layout -> (..., Kp).

    The tail of :func:`pack_chunks` factored out so operands that are
    natively chunk-shaped — per-tile cap-DAC weights (N, C, m), the
    program-time (C, m, N) weight state — pack into the kernel's K layout
    with bit-identical padding."""
    if not 1 <= m_columns <= CHUNK_PAD or v.shape[-1] != m_columns:
        raise ValueError(
            f"chunked operand {v.shape} does not match m_columns="
            f"{m_columns} (lane axis must hold exactly the µArray half, "
            f"1 <= m <= CHUNK_PAD={CHUNK_PAD})")
    v = jnp.pad(v, [(0, 0)] * (v.ndim - 1) + [(0, CHUNK_PAD - m_columns)],
                )  # pad lanes within chunk
    cpad = _round_up(v.shape[-2], CHUNKS_PER_TILE) - v.shape[-2]
    v = jnp.pad(v, [(0, 0)] * (v.ndim - 2) + [(0, cpad), (0, 0)])
    return v.reshape(v.shape[:-2] + (v.shape[-2] * CHUNK_PAD,))


def pack_planes(planes: jax.Array, m_columns: int) -> jax.Array:
    """Chunk-pack a (P, K, N) bitplane stack along K -> (P, Kp, N)."""
    p = pack_chunks(jnp.moveaxis(planes, -1, 1), m_columns)    # (P, N, Kp)
    return jnp.moveaxis(p, 1, -1)                               # (P, Kp, N)


def cim_mav_packed(gates: jax.Array, planes: jax.Array, *, m_columns: int,
                   adc_bits: int, bb: int = 8, bn: int = 128) -> jax.Array:
    """Digitised step-side partial sum over PRE-PACKED operands.

    gates: (B, Kp) from :func:`pack_chunks`; planes: (P, Kp, N) from
    :func:`pack_planes`. Only B/N padding happens per call — the chunk
    layout is assumed final, which is what lets programmed (weight-
    stationary) state skip the per-step re-pack entirely.
    """
    b = gates.shape[0]
    n = planes.shape[-1]
    bb = _pick_block(b, bb, 8)
    bn = _pick_block(n, bn, 128)
    bp, npad = _round_up(b, bb), _round_up(n, bn)
    g = jnp.pad(gates, ((0, bp - b), (0, 0)))
    p = jnp.pad(planes, ((0, 0), (0, 0), (0, npad - n)))
    y = cim_mav_pallas(g, p, m_columns=m_columns, adc_bits=adc_bits,
                       bb=bb, bn=bn, interpret=_on_cpu())
    return y[:b, :n]


def cim_mav(gates: jax.Array, planes: jax.Array, *, m_columns: int,
            adc_bits: int, bb: int = 8, bn: int = 128) -> jax.Array:
    """Digitised step-side partial sum (see kernels/cim_mav.py).

    gates: (B, K) {0,1}; planes: (Pw, K, N) {0,1} — un-packed layout;
    this wrapper packs chunks then delegates to :func:`cim_mav_packed`.
    """
    g = pack_chunks(gates, m_columns)
    p = pack_planes(planes, m_columns)
    return cim_mav_packed(g, p, m_columns=m_columns, adc_bits=adc_bits,
                          bb=bb, bn=bn)


def cim_mav_silicon(gates: jax.Array, planes: jax.Array, den: jax.Array,
                    off: jax.Array, dither: jax.Array = None, *,
                    adc_bits: int, bb: int = 8, bn: int = 128) -> jax.Array:
    """Fused silicon code sum over PRE-FOLDED operands -> (B, N).

    gates: (Pg, B, Kp) streamed {0,1} packs; planes: (Pp, Kp, N) cap-
    folded stationary operand with den/off: (Kp/CHUNK_PAD, N) per-(chunk,
    channel) SA-ADC instances and optional dither (P, Kp/CHUNK_PAD, B, N)
    — all built at program time by ``core.cim.cim_program_silicon``. Only
    B/N padding happens per call (padded channels get den=1/off=0 so they
    stay inert; padded batch rows are sliced away).
    """
    b = gates.shape[1]
    n = planes.shape[-1]
    bb = _pick_block(b, bb, 8)
    bn = _pick_block(n, bn, 128)
    bp, npad = _round_up(b, bb), _round_up(n, bn)
    g = jnp.pad(gates, ((0, 0), (0, bp - b), (0, 0)))
    p = jnp.pad(planes, ((0, 0), (0, 0), (0, npad - n)))
    d = jnp.pad(den, ((0, 0), (0, npad - n)), constant_values=1.0)
    o = jnp.pad(off, ((0, 0), (0, npad - n)))
    dt = None if dither is None else jnp.pad(
        dither, ((0, 0), (0, 0), (0, bp - b), (0, npad - n)))
    y = cim_mav_sil_pallas(g, p, d, o, dt, adc_bits=adc_bits, bb=bb, bn=bn,
                           interpret=_on_cpu())
    return y[:b, :n]
