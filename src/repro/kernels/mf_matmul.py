"""Fused MF dual-matmul Pallas TPU kernel.

The MF correlation lowers to two MXU matmuls over transformed operands:

    Y = sign(X) @ |W| + |X| @ sign(W)

A naive implementation materialises four derived operands in HBM (2x the
input traffic) and runs two matmuls (2x output traffic for the partial
sums). This kernel reads each X/W tile from HBM exactly once, derives
sign/abs in VMEM registers (VPU elementwise ops, free next to the MXU
matmuls), and accumulates BOTH partial products into a single f32 VMEM
accumulator — the paper's "one memory pass per operand" property, re-derived
for the TPU memory hierarchy (HBM -> VMEM -> VREG/MXU) instead of SRAM
bitlines.

Tiling: (bm x bk) X tiles against (bk x bn) W tiles on a (M/bm, N/bn, K/bk)
grid, K innermost so the accumulator lives in VMEM across the K sweep.
Block sizes default to 128/256 multiples to match the 128x128 MXU and the
(8,128)/(16,128) f32/bf16 VREG tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mf_matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = w_ref[...]
    # Derived operands live in VREGs only; never round-trip to HBM.
    acc_ref[...] += jnp.dot(jnp.sign(x), jnp.abs(w),
                            preferred_element_type=jnp.float32)
    acc_ref[...] += jnp.dot(jnp.abs(x), jnp.sign(w),
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def mf_matmul_pallas(x: jax.Array, w: jax.Array, *, bm: int = 128,
                     bn: int = 128, bk: int = 128,
                     interpret: bool = False) -> jax.Array:
    """Y[m,n] = sum_k sign(x) |w| + |x| sign(w); x:(M,K) w:(K,N), all tiled.

    Shapes must be multiples of the block sizes — `ops.mf_matmul` pads.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (x.shape, w.shape,
                                                         (bm, bn, bk))
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_mf_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
