"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function computes exactly what the corresponding kernel computes,
with no tiling, padding, or fusion — tests sweep shapes/dtypes and
assert_allclose kernel-vs-oracle in interpret mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mf_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Oracle for kernels.mf_matmul: sign(x)@|w| + |x|@sign(w)."""
    acc = (jnp.sign(x).astype(jnp.float32) @ jnp.abs(w).astype(jnp.float32)
           + jnp.abs(x).astype(jnp.float32) @ jnp.sign(w).astype(jnp.float32))
    return acc.astype(x.dtype)


def cim_mav_ref(gates: jax.Array, planes: jax.Array, *, m_columns: int,
                adc_bits: int, chunk_pad: int = 32) -> jax.Array:
    """Oracle for kernels.cim_mav (plane-weighted integer ADC code sums).

    gates: (B, K_pad) {0,1}; planes: (Pw, K_pad, N) {0,1} with the K axis
    laid out as C chunks of ``chunk_pad`` lanes (first ``m_columns`` real).
    """
    b, k_pad = gates.shape
    n_planes, _, n = planes.shape
    c = k_pad // chunk_pad
    g = gates.reshape(b, c, chunk_pad)
    p = planes.reshape(n_planes, c, chunk_pad, n)
    counts = jnp.einsum("bcm,pcmn->bpcn", g, p)
    levels = 2 ** adc_bits - 1
    code = jnp.clip(jnp.round(counts / m_columns * levels), 0, levels)
    scales = 2.0 ** jnp.arange(n_planes)
    return jnp.einsum("bpcn,p->bn", code, scales).astype(jnp.float32)


def cim_mav_sil_ref(gates: jax.Array, planes: jax.Array, den: jax.Array,
                    off: jax.Array, dither: jax.Array = None, *,
                    adc_bits: int, chunk_pad: int = 32) -> jax.Array:
    """Oracle for kernels.cim_mav_sil_pallas.

    gates: (Pg, B, Kp); planes: (Pp, Kp, N) cap-folded; den/off:
    (Kp/chunk_pad, N); dither: optional (P, Kp/chunk_pad, B, N). Computes
    the per-(chunk, plane) silicon SA-ADC codes with the same op order as
    the kernel (MAV = num/den, v = MAV + (off + dither)).
    """
    gp, b, k_pad = gates.shape
    pp, _, n = planes.shape
    c = k_pad // chunk_pad
    g = gates.reshape(gp, b, c, chunk_pad)
    p = planes.reshape(pp, c, chunk_pad, n)
    num = jnp.einsum("gbcm,pcmn->gpbcn", g, p)       # (Pg, Pp, B, C, N)
    num = num.reshape((gp * pp, b, c, n))            # one of Pg/Pp is 1
    mav = num / den[None, None]                      # (P, B, C, N)
    offc = off[None, None]
    if dither is not None:
        offc = offc + jnp.transpose(dither, (0, 2, 1, 3))
    levels = 2 ** adc_bits - 1
    code = jnp.clip(jnp.round((mav + offc) * levels), 0, levels)
    scales = 2.0 ** jnp.arange(code.shape[0])
    return jnp.einsum("pbcn,p->bn", code, scales).astype(jnp.float32)
