"""Fused bitplane-MAV + SA-ADC Pallas TPU kernel.

Emulates the µArray inner loop of the CIM macro for one side of the MF
operator: given 1-bit column gates G (B x K, e.g. step(x)) and weight
magnitude bitplanes P (Pw x K x N, bit p of |w|), compute the plane-
weighted SA-ADC *code sum*

    S[b, n] = sum_p 2^p * sum_chunks ADC_code( (1/M) * sum_{j in chunk}
                                               G[b, j] * P[p, j, n] )

i.e. the integer-valued ``CimPartials`` field of Eq. 2 (the m/levels
rescale is applied ONCE by ``core.cim.cim_mf_recombine``, never inside the
kernel — the same contract as the einsum paths, which is what makes the
fused output bitwise identical to the reference route at every design
point), fused so the (B, N, Pw, C) MAV tensor is never materialised in
HBM.

Hardware mapping: a µArray chunk holds M (e.g. 31) columns. M is not
lane-aligned, so the K axis is laid out as C chunks padded to CHUNK_PAD=32
lanes (pad columns store 0 bits: they never discharge, and the ADC divides
by the true M). A 128-lane K tile therefore carries 4 chunks; the kernel
does 4 (bb x 32) @ (32 x bn) MXU calls per tile and ADC-quantises each
chunk's MAV before accumulating, scaled by 2^p.

Grid: (B/bb, N/bn, Pw, C/4), plane+chunk innermost so the accumulator
stays resident in VMEM.

``cim_mav_sil_pallas`` is the silicon twin: the stationary operand arrives
cap-weighted (plane bit x its tile's fixed-point cap-DAC weight, see
``core.cim.cim_program_silicon``), and the per-(chunk, channel) cap-DAC
denominator, comparator offset, and optional per-conversion thermal dither
ride as extra operands — the full SA-ADC instance evaluates *inside* the
kernel, so sigma>0 fleets never fall back to the reference einsums.
"""
# repro-lint: module=exactness-critical

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK_PAD = 32          # lanes per µArray chunk after padding
CHUNKS_PER_TILE = 4     # 128-lane K tile carries 4 chunks


def _cim_mav_kernel(g_ref, p_ref, o_ref, acc_ref, *, m_columns: int,
                    adc_levels: int, n_planes: int, c_steps: int):
    plane = pl.program_id(2)
    chunk = pl.program_id(3)

    @pl.when(jnp.logical_and(plane == 0, chunk == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...]            # (bb, 128) gates for 4 chunks
    p = p_ref[0]              # (128, bn) bitplane for 4 chunks
    scale = jnp.exp2(plane.astype(jnp.float32))
    inv_m = 1.0 / m_columns
    for s in range(CHUNKS_PER_TILE):
        gs = g[:, s * CHUNK_PAD:(s + 1) * CHUNK_PAD]
        ps = p[s * CHUNK_PAD:(s + 1) * CHUNK_PAD, :]
        # exact-ok: {0,1} gate x plane-bit/grid-cap operands — exact in f32
        counts = jnp.dot(gs, ps, preferred_element_type=jnp.float32)
        mav = counts * inv_m
        code = jnp.clip(jnp.round(mav * adc_levels), 0.0, adc_levels)
        acc_ref[...] += scale * code

    @pl.when(jnp.logical_and(plane == n_planes - 1, chunk == c_steps - 1))
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("m_columns", "adc_bits", "bb", "bn",
                                    "interpret"))
def cim_mav_pallas(gates: jax.Array, planes: jax.Array, *, m_columns: int,
                   adc_bits: int, bb: int = 8, bn: int = 128,
                   interpret: bool = False) -> jax.Array:
    """gates: (B, K_pad) in {0,1}; planes: (Pw, K_pad, N) in {0,1}.

    K_pad must be a multiple of 128 with chunk layout described above
    (`ops.cim_mav` builds it). Returns (B, N) f32 plane-weighted integer
    ADC code sums (a ``CimPartials`` field — recombine with
    ``core.cim.cim_mf_recombine``).
    """
    b, k_pad = gates.shape
    n_planes, k2, n = planes.shape
    assert k_pad == k2 and k_pad % (CHUNK_PAD * CHUNKS_PER_TILE) == 0
    assert b % bb == 0 and n % bn == 0, (gates.shape, planes.shape, (bb, bn))
    c_steps = k_pad // (CHUNK_PAD * CHUNKS_PER_TILE)
    grid = (b // bb, n // bn, n_planes, c_steps)
    kernel = functools.partial(
        _cim_mav_kernel, m_columns=m_columns,
        adc_levels=2 ** adc_bits - 1, n_planes=n_planes, c_steps=c_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, CHUNK_PAD * CHUNKS_PER_TILE),
                         lambda i, j, p, c: (i, c)),
            pl.BlockSpec((1, CHUNK_PAD * CHUNKS_PER_TILE, bn),
                         lambda i, j, p, c: (p, c, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, p, c: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        interpret=interpret,
    )(gates, planes)


def _cim_mav_sil_kernel(*refs, adc_levels: int, n_planes: int, c_steps: int,
                        has_dither: bool):
    """Silicon MAV + in-kernel SA-ADC instance evaluation.

    Per chunk s of the 128-lane tile: numerator = gates @ cap-folded
    planes, MAV = numerator / den[s], v = MAV + (offset[s] [+ dither]),
    code = clip(round(v * levels)) — the exact op sequence (and float
    associativity) of ``core.cim._silicon_partials``, which is what keeps
    the fused route's integer codes identical to the reference einsums.
    """
    if has_dither:
        g_ref, p_ref, den_ref, off_ref, d_ref, o_ref, acc_ref = refs
    else:
        g_ref, p_ref, den_ref, off_ref, o_ref, acc_ref = refs
        d_ref = None
    plane = pl.program_id(2)
    chunk = pl.program_id(3)

    @pl.when(jnp.logical_and(plane == 0, chunk == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[0]              # (bb, 128) gates for 4 chunks
    p = p_ref[0]              # (128, bn) cap-folded planes for 4 chunks
    scale = jnp.exp2(plane.astype(jnp.float32))
    for s in range(CHUNKS_PER_TILE):
        gs = g[:, s * CHUNK_PAD:(s + 1) * CHUNK_PAD]
        ps = p[s * CHUNK_PAD:(s + 1) * CHUNK_PAD, :]
        # exact-ok: {0,1} gate x plane-bit/grid-cap operands — exact in f32
        num = jnp.dot(gs, ps, preferred_element_type=jnp.float32)
        mav = num / den_ref[s:s + 1, :]
        off = off_ref[s:s + 1, :]
        if d_ref is not None:
            off = off + d_ref[0, s]
        v = mav + off
        code = jnp.clip(jnp.round(v * adc_levels), 0.0, adc_levels)
        acc_ref[...] += scale * code

    @pl.when(jnp.logical_and(plane == n_planes - 1, chunk == c_steps - 1))
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("adc_bits", "bb", "bn", "interpret"))
def cim_mav_sil_pallas(gates: jax.Array, planes: jax.Array, den: jax.Array,
                       off: jax.Array, dither: jax.Array | None = None, *,
                       adc_bits: int, bb: int = 8, bn: int = 128,
                       interpret: bool = False) -> jax.Array:
    """Fused silicon MAV: gates (Pg, B, Kp) x planes (Pp, Kp, N) -> (B, N).

    Exactly one of Pg/Pp may exceed 1 (the streaming bit-serial side); the
    other operand is plane-static and broadcasts. ``den``/``off`` give the
    per-(chunk, channel) cap-DAC denominator and comparator offset as
    (Kp/CHUNK_PAD, N) tiles (padded chunks carry den=1, off=0 so they
    digitise to code 0); ``dither`` optionally adds per-conversion thermal
    noise shaped (P, Kp/CHUNK_PAD, B, N). Returns plane-weighted integer
    ADC code sums, the same ``CimPartials`` contract as ``cim_mav_pallas``.
    """
    gp, b, k_pad = gates.shape
    pp, k2, n = planes.shape
    assert k_pad == k2 and k_pad % (CHUNK_PAD * CHUNKS_PER_TILE) == 0
    assert gp == 1 or pp == 1, (gates.shape, planes.shape)
    n_planes = max(gp, pp)
    c_tiles = k_pad // CHUNK_PAD
    assert den.shape == (c_tiles, n) and off.shape == (c_tiles, n)
    assert b % bb == 0 and n % bn == 0, (gates.shape, planes.shape, (bb, bn))
    c_steps = k_pad // (CHUNK_PAD * CHUNKS_PER_TILE)
    grid = (b // bb, n // bn, n_planes, c_steps)
    kernel = functools.partial(
        _cim_mav_sil_kernel, adc_levels=2 ** adc_bits - 1,
        n_planes=n_planes, c_steps=c_steps, has_dither=dither is not None)
    gsel = (lambda p: p) if gp > 1 else (lambda p: 0)
    psel = (lambda p: p) if pp > 1 else (lambda p: 0)
    in_specs = [
        pl.BlockSpec((1, bb, CHUNK_PAD * CHUNKS_PER_TILE),
                     lambda i, j, p, c: (gsel(p), i, c)),
        pl.BlockSpec((1, CHUNK_PAD * CHUNKS_PER_TILE, bn),
                     lambda i, j, p, c: (psel(p), c, j)),
        pl.BlockSpec((CHUNKS_PER_TILE, bn), lambda i, j, p, c: (c, j)),
        pl.BlockSpec((CHUNKS_PER_TILE, bn), lambda i, j, p, c: (c, j)),
    ]
    operands = [gates, planes, den, off]
    if dither is not None:
        assert dither.shape == (n_planes, c_tiles, b, n), dither.shape
        in_specs.append(pl.BlockSpec((1, CHUNKS_PER_TILE, bb, bn),
                                     lambda i, j, p, c: (p, c, i, j)))
        operands.append(dither)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, p, c: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        interpret=interpret,
    )(*operands)
