"""repro-lint: JAX-aware exactness linter for the CIM datapath.

``python -m repro.analysis src benchmarks tests`` — see ``engine`` for
the rule/suppression/baseline vocabulary and ``rules/`` for the bug
classes (R001-R006). The runtime half (``REPRO_SANITIZE=1``) lives in
``repro.analysis.sanitize``.
"""

from repro.analysis.engine import (  # noqa: F401
    Finding,
    FileReport,
    ModuleContext,
    Rule,
    all_rules,
    analyze_file,
    analyze_source,
    diff_baseline,
    iter_python_files,
    load_baseline,
    save_baseline,
)
