"""Runtime sanitizer for the CIM datapath (``REPRO_SANITIZE=1``).

The static rules (R001-R006) catch the bug *shapes*; this module checks
the bitwise contracts themselves while an engine serves:

* **Shadow execution** — every decode tick re-runs from the SAME inputs
  (exec tree aside) through the reference einsum datapath
  (``use_kernel=False``, plane-level programmed state) and asserts the
  sampled tokens AND the logits are bitwise identical to the primary
  path. Identical integer ADC codes imply identical recombines, so any
  drift here means a broken exactness proof — exactly the class the
  PR 7 sigma>0 parity gate guards, but live, against the engine's real
  silicon state and cache.
* **NaN / saturation tripwires** — :func:`repro.core.cim.adc_codes`
  stages a debug callback per conversion while armed; a conversion
  tensor containing NaN, or sitting entirely at full scale (the ADC
  pegged: scales are wrong), raises at the step that produced it.
* **cap_fixed integer-quanta invariant** — on every silicon refresh,
  each attached cap/operand tensor must sit on the 2^-14 fixed-point
  grid with per-conversion denominators far below 2^24 quanta; this is
  the premise of every ``# exact-ok`` pragma on the einsum path.

The sanitizer costs roughly a second full forward per tick plus host
transfers — a debug mode, enabled by environment so production call
sites carry no flag plumbing.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional

import jax
import numpy as np

_ENV = "REPRO_SANITIZE"
_TRUTHY = ("1", "true", "yes", "on")

# Conversion tripwire records staged by adc_codes while armed:
# (nan_fraction, saturated_fraction) per digitised tensor, drained by the
# sanitizer (or a test) after the step that produced them completes.
_TRIPWIRE_LOG: list[tuple[float, float]] = []
_FORCE_ARMED = False


def sanitize_enabled() -> bool:
    return os.environ.get(_ENV, "").strip().lower() in _TRUTHY


def tripwires_armed() -> bool:
    """Read live at trace time: each engine owns a fresh jit cache, so
    arming before the first step stages the callbacks for that engine."""
    return _FORCE_ARMED or sanitize_enabled()


def arm_tripwires(on: bool = True) -> None:
    """Explicit arm/disarm for tests that bypass the environment."""
    global _FORCE_ARMED
    _FORCE_ARMED = on


def stage_conversion_tripwire(codes: jax.Array, levels: float) -> None:
    """Called from ``adc_codes`` under trace while armed."""
    import jax.numpy as jnp

    nan_frac = jnp.mean(jnp.isnan(codes).astype(jnp.float32))
    sat_frac = jnp.mean((codes >= levels).astype(jnp.float32))

    def record(nf, sf):
        _TRIPWIRE_LOG.append((float(nf), float(sf)))

    jax.debug.callback(record, nan_frac, sat_frac)


def drain_tripwires() -> list[tuple[float, float]]:
    out = list(_TRIPWIRE_LOG)
    _TRIPWIRE_LOG.clear()
    return out


class SanitizeError(AssertionError):
    """A bitwise datapath contract was violated at runtime."""


def _finding(msg: str, *, check: str, stream: Optional[int] = None,
             **data: Any) -> SanitizeError:
    """Record a sanitizer finding on the telemetry bus (kind
    ``sanitize``), then hand back the error to raise — a crash-stopped
    serve leaves its last finding in the exported trace next to the
    events that led up to it."""
    from repro.obs import trace as obs_trace
    obs_trace.emit("sanitize", stream=stream, check=check, error=msg,
                   **data)
    return SanitizeError(msg)


def _tree_nodes(tree: Any, cls: type) -> list[Any]:
    """All ``cls`` NamedTuple nodes in a params tree (dict/list/tuple
    recursion; NamedTuples are leaves unless they ARE the target)."""
    found: list[Any] = []

    def walk(node: Any) -> None:
        if isinstance(node, cls):
            found.append(node)
            return
        if isinstance(node, dict):
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)) \
                and not hasattr(node, "_fields"):
            for v in node:
                walk(v)

    walk(tree)
    return found


def check_cap_quanta(exec_params: Any) -> None:
    """Assert the cap_fixed integer-quanta invariant over an exec tree.

    Every silicon operand the datapath will contract against must be an
    integer multiple of 2^-CAP_FIXED_BITS, and every per-conversion
    denominator (the largest possible pre-ADC numerator) must stay below
    2^24 quanta — the premise under which float32 contraction order
    cannot matter.
    """
    from repro.core.cim import (CAP_FIXED_BITS, CimKernelSilicon,
                                ProjectionSilicon, cap_fixed)
    scale = 2.0 ** CAP_FIXED_BITS
    budget = 2.0 ** 24

    def must_be_quanta(arr: jax.Array, what: str) -> np.ndarray:
        q = np.asarray(arr, dtype=np.float64) * scale
        if not np.all(np.isfinite(q)):
            raise SanitizeError(f"{what}: non-finite silicon operand")
        if np.max(np.abs(q - np.round(q)), initial=0.0) != 0.0:
            raise SanitizeError(
                f"{what}: values are off the 2^-{CAP_FIXED_BITS} "
                f"fixed-point grid — float32 contraction order is no "
                f"longer provably irrelevant")
        return q

    for sil in _tree_nodes(exec_params, ProjectionSilicon):
        for name in ("cap", "rx_cap"):
            snapped = cap_fixed(getattr(sil, name))
            q = must_be_quanta(snapped, f"ProjectionSilicon.{name}")
            per_conv = np.sum(q, axis=-1)  # quanta per chunk conversion
            if np.max(per_conv, initial=0.0) >= budget:
                raise SanitizeError(
                    f"ProjectionSilicon.{name}: a conversion denominator "
                    f"reaches {np.max(per_conv):.3g} quanta >= 2^24 — "
                    f"float32 partial sums can round")
    for silk in _tree_nodes(exec_params, CimKernelSilicon):
        for name in ("wpc", "gwc", "rxp"):
            must_be_quanta(getattr(silk, name), f"CimKernelSilicon.{name}")
        for name in ("den", "rx_den"):
            q = must_be_quanta(getattr(silk, name),
                               f"CimKernelSilicon.{name}")
            if np.max(q, initial=0.0) >= budget:
                raise SanitizeError(
                    f"CimKernelSilicon.{name}: a conversion denominator "
                    f"reaches {np.max(q):.3g} quanta >= 2^24 — float32 "
                    f"partial sums can round")


class ServeSanitizer:
    """Shadow-execution harness attached to a :class:`ServeEngine`.

    Owns a reference-datapath twin of the engine's config (fused kernel
    off, lossless collapse off → the plane-level einsum pipeline), a
    shadow programmed/exec tree kept in sync through the engine's
    refresh path, and a jitted shadow step. ``check_step`` replays the
    tick and compares bitwise.
    """

    def __init__(self, engine, temperature: float = 0.0):
        from repro.serve.engine import make_serve_step
        cim = dataclasses.replace(engine.cfg.mf.cim, use_kernel=False)
        mf = dataclasses.replace(engine.cfg.mf, cim=cim)
        self.cfg = dataclasses.replace(engine.cfg, mf=mf)
        self._cim = cim
        self.step_fn = jax.jit(make_serve_step(self.cfg,
                                               temperature=temperature))
        self._programmed_src: Optional[int] = None
        self._shadow_programmed = None
        self.shadow_exec = None
        self.checked_steps = 0
        self.refresh(engine)

    def refresh(self, engine) -> None:
        """Rebuild the shadow exec tree against the engine's CURRENT
        programmed/silicon state; runs the quanta invariant on both."""
        from repro.core.programmed import program_weights
        if self._programmed_src != id(engine._programmed_params):
            # Re-program only when the engine re-programmed (scales /
            # swap changed); silicon-only refreshes reuse the state.
            self._shadow_programmed = program_weights(
                engine._base_params, self._cim,
                scales=engine._last_scales, swap=engine._swap_map,
                prefer_lossless=False)
            self._programmed_src = id(engine._programmed_params)
        if engine.silicon is None:
            self.shadow_exec = self._shadow_programmed
        else:
            from repro.silicon.instance import attach_silicon
            pinned = engine.schedule.pinned \
                if engine.schedule is not None else True
            self.shadow_exec = attach_silicon(
                self._shadow_programmed, engine.silicon,
                engine.silicon_cfg, self._cim, pinned=pinned)
        check_cap_quanta(engine._exec_params)
        check_cap_quanta(self.shadow_exec)

    def check_step(self, engine, cache_before, tokens, rng, step,
                   nxt, logits) -> None:
        """Replay one decode tick through the reference datapath and
        assert bitwise agreement; then inspect the tripwire log."""
        s_nxt, s_logits, _ = self.step_fn(self.shadow_exec, cache_before,
                                          tokens, rng, step)
        h_logits = np.asarray(logits)
        hs_logits = np.asarray(s_logits)
        if np.any(np.isnan(h_logits)):
            raise _finding(
                f"NaN logits at stream step {int(step)} on the primary "
                f"datapath", check="nan_logits", stream=int(step))
        if not np.array_equal(h_logits, hs_logits):
            bad = int(np.sum(h_logits != hs_logits))
            i = np.unravel_index(
                int(np.argmax(h_logits != hs_logits)), h_logits.shape)
            raise _finding(
                f"fused/einsum divergence at stream step {int(step)}: "
                f"{bad} logit(s) differ, first at {tuple(i)} "
                f"(primary {h_logits[i]!r} vs reference {hs_logits[i]!r})"
                f" — the exactness contract between the Pallas kernel "
                f"path and the reference einsums is broken",
                check="logit_divergence", stream=int(step), n_diff=bad)
        if not np.array_equal(np.asarray(nxt), np.asarray(s_nxt)):
            raise _finding(
                f"sampled-token divergence at stream step {int(step)} "
                f"despite equal logits — RNG threading differs between "
                f"primary and shadow steps",
                check="token_divergence", stream=int(step))
        for nan_frac, sat_frac in drain_tripwires():
            if nan_frac > 0.0:
                raise _finding(
                    f"conversion tripwire: {nan_frac:.1%} NaN ADC codes "
                    f"at stream step {int(step)}",
                    check="nan_codes", stream=int(step),
                    nan_frac=nan_frac)
            if sat_frac >= 1.0:
                raise _finding(
                    f"conversion tripwire: a conversion tensor is fully "
                    f"saturated at stream step {int(step)} — activation "
                    f"scales are pegging the ADC",
                    check="saturation", stream=int(step),
                    sat_frac=sat_frac)
        self.checked_steps += 1
