"""R001: PRNG key reuse.

The same key fed to two ``jax.random.*`` draws without an intervening
``split``/``fold_in`` collapses two "independent" noise sources into one —
the exact shape of the PR 6 bug where per-slot dither was drawn once and
replayed every decode step. Two patterns fire:

* a key *name* used by a second draw after an earlier draw consumed it,
  with no reassignment in between (linear def-use per function, branches
  merged by union);
* a draw inside a ``for``/``while`` body whose bare-name key is never
  reassigned inside the loop — every iteration replays the same stream.

Only draws consume: ``split``/``fold_in`` derive fresh streams and keys
built inline (``fold_in(key, i)``, ``keys[i]``) are not bare names, so the
standard idioms pass untouched.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import (
    Finding,
    ModuleContext,
    Rule,
    call_name,
    register,
)

_NON_DRAWS = {
    "split", "fold_in", "PRNGKey", "key", "wrap_key_data", "key_data",
    "key_impl", "clone",
}


def _draw_key_name(node: ast.Call) -> str | None:
    """Bare-name key argument of a ``jax.random.<dist>`` draw, else None."""
    name = call_name(node)
    if name is None:
        return None
    parts = name.split(".")
    # jax.random.x or a conventional alias; numpy/stdlib random is R004's.
    if len(parts) == 3 and parts[:2] == ["jax", "random"]:
        dist = parts[2]
    elif len(parts) == 2 and parts[0] in ("jrandom", "jr"):
        dist = parts[1]
    else:
        return None
    if dist in _NON_DRAWS:
        return None
    if not node.args:
        return None
    key = node.args[0]
    return key.id if isinstance(key, ast.Name) else None


def _assigned_names(stmt: ast.stmt) -> set[str]:
    out: set[str] = set()
    targets: list[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign, ast.For)):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        targets = [i.optional_vars for i in stmt.items
                   if i.optional_vars is not None]
    for t in targets:
        for n in ast.walk(t):
            if isinstance(n, ast.Name):
                out.add(n.id)
    return out


def _all_assigned(stmts: list[ast.stmt]) -> set[str]:
    out: set[str] = set()
    for s in stmts:
        for n in ast.walk(s):
            if isinstance(n, ast.stmt):
                out |= _assigned_names(n)
    return out


@register
class PrngKeyReuse(Rule):
    rule_id = "R001"
    title = "PRNG key reuse without split/fold_in"

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings: list[Finding] = []
        flagged: set[int] = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scan_block(ctx, node.body, set(), findings, flagged,
                                 in_loop=False)
        return findings

    def _stmt_draws(self, stmt: ast.AST) -> list[tuple[ast.Call, str]]:
        """Draws in this statement's own expressions — nested function/
        class scopes are pruned (they may be called with fresh keys)."""
        out: list[tuple[ast.Call, str]] = []

        def visit(n: ast.AST) -> None:
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda, ast.ClassDef)):
                return
            if isinstance(n, ast.Call):
                key = _draw_key_name(n)
                if key is not None:
                    out.append((n, key))
            for child in ast.iter_child_nodes(n):
                visit(child)

        for child in ast.iter_child_nodes(stmt):
            visit(child)
        if isinstance(stmt, ast.Call):
            key = _draw_key_name(stmt)
            if key is not None:
                out.append((stmt, key))
        return out

    def _scan_block(self, ctx: ModuleContext, stmts: list[ast.stmt],
                    consumed: set[str], findings: list[Finding],
                    flagged: set[int], in_loop: bool) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue  # nested scopes are scanned independently
            if isinstance(stmt, (ast.For, ast.While)):
                loop_assigned = _all_assigned(stmt.body) | _assigned_names(
                    stmt)
                for call, key in [d for s in stmt.body
                                  for d in self._stmt_draws(s)]:
                    if key not in loop_assigned and id(call) not in flagged:
                        flagged.add(id(call))
                        findings.append(self.finding(
                            ctx, call,
                            f"key '{key}' is drawn from inside a loop but "
                            f"never reassigned in the loop body — every "
                            f"iteration replays the same stream; fold_in "
                            f"the loop index or split per iteration"))
                self._scan_block(ctx, stmt.body, consumed, findings,
                                 flagged, in_loop=True)
                self._scan_block(ctx, stmt.orelse, consumed, findings,
                                 flagged, in_loop=in_loop)
                continue
            if isinstance(stmt, ast.If):
                c_body = set(consumed)
                c_else = set(consumed)
                self._scan_block(ctx, stmt.body, c_body, findings,
                                 flagged, in_loop)
                self._scan_block(ctx, stmt.orelse, c_else, findings,
                                 flagged, in_loop)
                consumed |= c_body | c_else
                continue
            if isinstance(stmt, ast.Try):
                for block in (stmt.body, stmt.orelse, stmt.finalbody,
                              *[h.body for h in stmt.handlers]):
                    self._scan_block(ctx, block, consumed, findings,
                                     flagged, in_loop)
                continue
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                consumed -= _assigned_names(stmt)
                self._scan_block(ctx, stmt.body, consumed, findings,
                                 flagged, in_loop)
                continue
            for call, key in self._stmt_draws(stmt):
                if id(call) in flagged:
                    continue
                if key in consumed:
                    flagged.add(id(call))
                    findings.append(self.finding(
                        ctx, call,
                        f"key '{key}' was already consumed by an earlier "
                        f"jax.random draw — split or fold_in before "
                        f"drawing again"))
                else:
                    consumed.add(key)
            consumed -= _assigned_names(stmt)
