"""Rule modules register themselves with the engine on import."""

from repro.analysis.rules import (  # noqa: F401
    floatacc,
    noise,
    nondeterminism,
    prng,
    pytree,
    tracing,
)
