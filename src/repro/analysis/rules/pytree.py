"""R002: NamedTuple-pytree rebuild through plain ``tuple(...)``.

PR 5's ``strip_silicon`` walked a parameter tree with
``isinstance(node, tuple)`` + ``tuple(walk(c) for c in node)``: registered
NamedTuple nodes (``ProgrammedMacro``, caches) came back as anonymous
tuples, silently changing the pytree treedef and detaching every
downstream consumer. The safe idiom preserves the node type —
``type(node)(*children)``, ``node._make(children)``, or an explicit
``hasattr(node, "_fields")`` early-return.

The rule fires on any function that (a) type-tests ``tuple`` (or
``(list, tuple)``) AND (b) rebuilds via ``tuple(<comprehension/map>)``,
unless the function also shows one of the preserving guards.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import (
    Finding,
    ModuleContext,
    Rule,
    call_name,
    register,
)


def _tests_tuple(fn: ast.AST) -> bool:
    for n in ast.walk(fn):
        if (isinstance(n, ast.Call) and call_name(n) == "isinstance"
                and len(n.args) == 2):
            cls = n.args[1]
            names = [cls] if not isinstance(cls, ast.Tuple) else cls.elts
            for c in names:
                if isinstance(c, ast.Name) and c.id == "tuple":
                    return True
    return False


def _tuple_rebuilds(fn: ast.AST) -> list[ast.Call]:
    out = []
    for n in ast.walk(fn):
        if (isinstance(n, ast.Call) and call_name(n) == "tuple"
                and len(n.args) == 1):
            arg = n.args[0]
            if isinstance(arg, (ast.GeneratorExp, ast.ListComp)):
                out.append(n)
            elif isinstance(arg, ast.Call) and call_name(arg) == "map":
                out.append(n)
    return out


def _has_preserving_guard(fn: ast.AST) -> bool:
    for n in ast.walk(fn):
        if isinstance(n, ast.Attribute) and n.attr in ("_fields", "_make"):
            return True
        # hasattr(node, "_fields") early-return
        if (isinstance(n, ast.Call) and call_name(n) == "hasattr"
                and len(n.args) == 2
                and isinstance(n.args[1], ast.Constant)
                and n.args[1].value in ("_fields", "_make")):
            return True
        # type(node)(...) reconstruction
        if (isinstance(n, ast.Call) and isinstance(n.func, ast.Call)
                and call_name(n.func) == "type"):
            return True
    return False


@register
class NamedTuplePytreeRebuild(Rule):
    rule_id = "R002"
    title = "pytree walk rebuilds tuples without preserving NamedTuple type"

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings: list[Finding] = []
        seen: set[int] = set()  # a call is visible from every enclosing fn
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not _tests_tuple(fn):
                continue
            if _has_preserving_guard(fn):
                continue
            for call in _tuple_rebuilds(fn):
                if id(call) in seen:
                    continue
                seen.add(id(call))
                findings.append(self.finding(
                    ctx, call,
                    "tuple(...) rebuild in a pytree walk that type-tests "
                    "tuple: registered NamedTuple nodes would come back "
                    "as anonymous tuples and change the treedef — guard "
                    "with hasattr(node, '_fields') or rebuild via "
                    "type(node)(*children)"))
        return findings
