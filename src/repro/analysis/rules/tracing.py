"""R003: trace-cache discipline for ``jax.jit``.

PR 5's ``DriftMonitor`` built ``jax.jit(self._observe)`` inside its probe
method: a fresh bound method each call means a fresh jit wrapper and a
full retrace per probe. The cache only pays off when the jitted callable
is created once and reused. Three shapes fire:

* ``jax.jit(...)`` evaluated inside a ``for``/``while`` body — a new
  wrapper (and trace) per iteration;
* an immediately-invoked ``jax.jit(f)(args)`` inside a function — the
  wrapper dies after one call, so every call of the enclosing function
  retraces;
* ``g = jax.jit(f)`` bound to a local AND called in the same function
  body — same lifetime bug one line later. Factories that *return* the
  wrapper, ``__init__`` methods stashing it on ``self``, and module-level
  bindings all pass.

A fourth shape guards the mutable-closure variant: a jitted inner
function reading a name the enclosing scope bound to a ``list``/``dict``/
``set`` literal — mutations after trace time are invisible to the
compiled code.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import (
    Finding,
    ModuleContext,
    Rule,
    call_name,
    dotted_name,
    register,
)

_JIT_NAMES = {"jax.jit", "jit", "pjit", "jax.pjit"}


def _is_jit_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and call_name(node) in _JIT_NAMES)


def _walk_scope(root: ast.AST):
    """Yield nodes of one scope, pruning nested function/class bodies."""
    stack = list(ast.iter_child_nodes(root))
    while stack:
        n = stack.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.Lambda, ast.ClassDef)):
            continue
        yield n
        stack.extend(ast.iter_child_nodes(n))


def _loop_bodies(fn: ast.AST):
    for n in ast.walk(fn):
        if isinstance(n, (ast.For, ast.While)):
            yield n


@register
class TraceCacheDiscipline(Rule):
    rule_id = "R003"
    title = "jax.jit wrapper created per call / per iteration"

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings: list[Finding] = []
        flagged: set[int] = set()
        self._check_loops(ctx, findings, flagged)
        for fn in ast.walk(ctx.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._check_function(ctx, fn, findings, flagged)
        self._check_mutable_closures(ctx, findings)
        return findings

    def _check_loops(self, ctx: ModuleContext, findings: list[Finding],
                     flagged: set[int]) -> None:
        for loop in _loop_bodies(ctx.tree):
            for stmt in loop.body:
                if isinstance(stmt, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    continue  # defs in loops get their own scan
                for n in _walk_scope(stmt):
                    if _is_jit_call(n) and id(n) not in flagged:
                        flagged.add(id(n))
                        findings.append(self.finding(
                            ctx, n,
                            "jax.jit evaluated inside a loop body — a "
                            "fresh wrapper (and retrace) per iteration; "
                            "hoist the jitted callable out of the loop"))

    def _check_function(self, ctx: ModuleContext, fn: ast.AST,
                        findings: list[Finding],
                        flagged: set[int]) -> None:
        jit_locals: dict[str, ast.Call] = {}
        returned: set[str] = set()
        for n in _walk_scope(fn):
            # immediately-invoked jax.jit(f)(...)
            if (isinstance(n, ast.Call) and _is_jit_call(n.func)
                    and id(n.func) not in flagged):
                flagged.add(id(n.func))
                findings.append(self.finding(
                    ctx, n,
                    "immediately-invoked jax.jit(f)(...) inside a "
                    "function — the wrapper (and its trace cache) dies "
                    "after one call; bind it once at module/init scope"))
            if isinstance(n, ast.Assign) and _is_jit_call(n.value):
                for t in n.targets:
                    if isinstance(t, ast.Name):
                        jit_locals[t.id] = n.value
            if isinstance(n, ast.Return) and n.value is not None:
                # Only BARE returns make a factory: `return g` (or a
                # tuple/dict of names). `return g(x)` still calls the
                # wrapper before it dies, so it stays flagged.
                vals = [n.value]
                if isinstance(n.value, (ast.Tuple, ast.List)):
                    vals = list(n.value.elts)
                elif isinstance(n.value, ast.Dict):
                    vals = [v for v in n.value.values if v is not None]
                for r in vals:
                    if isinstance(r, ast.Name):
                        returned.add(r.id)
        if not jit_locals:
            return
        if getattr(fn, "name", "") == "__init__":
            return  # stashing on self: wrapper lives as long as the object
        for n in _walk_scope(fn):
            if (isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                    and n.func.id in jit_locals
                    and n.func.id not in returned):
                jc = jit_locals[n.func.id]
                if id(jc) in flagged:
                    continue
                flagged.add(id(jc))
                findings.append(self.finding(
                    ctx, jc,
                    f"jax.jit result '{n.func.id}' is created and called "
                    f"within the same function — every call of the "
                    f"enclosing function retraces; create the wrapper "
                    f"once (module scope, __init__, or a returned "
                    f"factory)"))

    def _check_mutable_closures(self, ctx: ModuleContext,
                                findings: list[Finding]) -> None:
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            mutable: set[str] = set()
            for n in _walk_scope(fn):
                if isinstance(n, ast.Assign) and isinstance(
                        n.value, (ast.List, ast.Dict, ast.Set)):
                    for t in n.targets:
                        if isinstance(t, ast.Name):
                            mutable.add(t.id)
            if not mutable:
                continue
            for inner in ast.walk(fn):
                if not isinstance(inner, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                    continue
                if inner is fn or not self._is_jitted(fn, inner):
                    continue
                local = {a.arg for a in inner.args.args}
                local |= {t.id for n in ast.walk(inner)
                          if isinstance(n, ast.Assign)
                          for t in n.targets if isinstance(t, ast.Name)}
                for n in ast.walk(inner):
                    if (isinstance(n, ast.Name)
                            and isinstance(n.ctx, ast.Load)
                            and n.id in mutable and n.id not in local):
                        findings.append(self.finding(
                            ctx, n,
                            f"jitted inner function reads '{n.id}', a "
                            f"mutable literal from the enclosing scope — "
                            f"mutations after trace time are invisible "
                            f"to the compiled code; pass it as an "
                            f"argument or make it immutable"))
                        break
        return

    @staticmethod
    def _is_jitted(outer: ast.AST, inner: ast.AST) -> bool:
        for d in getattr(inner, "decorator_list", ()):
            name = dotted_name(d if not isinstance(d, ast.Call) else d.func)
            if name in _JIT_NAMES:
                return True
            if isinstance(d, ast.Call) and call_name(d) in ("partial",
                                                            "functools.partial"):
                if d.args and dotted_name(d.args[0]) in _JIT_NAMES:
                    return True
        for n in ast.walk(outer):
            if (isinstance(n, ast.Call) and call_name(n) in _JIT_NAMES
                    and n.args and isinstance(n.args[0], ast.Name)
                    and n.args[0].id == getattr(inner, "name", None)):
                return True
        return False
