"""R004: nondeterminism sources in determinism-tagged modules.

Program-time code (weight programming, silicon instantiation, macro
builds) must be a pure function of config + seed: two runs with the same
seed must program identical macros, or the exactness contract between
runs is void before the first decode step. Inside modules tagged
``deterministic`` or ``exactness-critical`` the rule flags wall-clock
reads, OS entropy, stdlib/global-numpy RNG state, and iteration over
unordered sets.

``np.random.default_rng(seed)`` / ``np.random.Generator`` are explicit,
seeded streams and pass; the *global*-state legacy API does not.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import (
    Finding,
    ModuleContext,
    Rule,
    call_name,
    register,
)

_TAGS = ("deterministic", "exactness-critical")

_BANNED_CALLS = {
    "time.time": "wall-clock read",
    "time.time_ns": "wall-clock read",
    "datetime.now": "wall-clock read",
    "datetime.utcnow": "wall-clock read",
    "datetime.datetime.now": "wall-clock read",
    "datetime.datetime.utcnow": "wall-clock read",
    "os.urandom": "OS entropy",
    "uuid.uuid1": "host/time-derived id",
    "uuid.uuid4": "OS entropy",
    "secrets.token_bytes": "OS entropy",
    "secrets.token_hex": "OS entropy",
}

_NP_SEEDED_OK = {"default_rng", "Generator", "SeedSequence", "PCG64",
                 "Philox"}


def _banned_reason(name: str) -> str | None:
    if name in _BANNED_CALLS:
        return _BANNED_CALLS[name]
    parts = name.split(".")
    # stdlib `random` global-state API
    if len(parts) == 2 and parts[0] == "random":
        return "stdlib random global state"
    # numpy legacy global-state API (np.random.seed / .rand / .normal ...)
    if len(parts) >= 3 and parts[0] in ("np", "numpy") \
            and parts[1] == "random" and parts[2] not in _NP_SEEDED_OK:
        return "numpy legacy global RNG state"
    return None


@register
class NondeterminismSources(Rule):
    rule_id = "R004"
    title = "nondeterminism source in a determinism-tagged module"

    def check(self, ctx: ModuleContext) -> list[Finding]:
        if not any(ctx.has_tag(t) for t in _TAGS):
            return []
        findings: list[Finding] = []
        for n in ast.walk(ctx.tree):
            if isinstance(n, ast.Call):
                name = call_name(n)
                reason = _banned_reason(name) if name else None
                if reason is not None:
                    findings.append(self.finding(
                        ctx, n,
                        f"{name}() is a nondeterminism source ({reason}) "
                        f"in a module tagged for determinism — derive it "
                        f"from config/seed instead"))
            if isinstance(n, (ast.For, ast.comprehension)):
                it = n.iter
                if self._is_unordered_set(it):
                    findings.append(self.finding(
                        ctx, it,
                        "iteration over a set has no guaranteed order — "
                        "wrap in sorted(...) so program-time walks are "
                        "reproducible"))
        return findings

    @staticmethod
    def _is_unordered_set(it: ast.AST) -> bool:
        if isinstance(it, ast.Set):
            return True
        if isinstance(it, ast.Call) and call_name(it) == "set":
            return True
        if isinstance(it, ast.BinOp) and isinstance(
                it.op, (ast.BitOr, ast.BitAnd, ast.Sub)) \
                and (isinstance(it.left, (ast.Set,))
                     or isinstance(it.right, (ast.Set,))):
            return True
        return False
