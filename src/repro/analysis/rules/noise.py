"""R006: step-time noise draws not keyed off the conversion clock.

PR 6's bug class: a dither tensor drawn once at silicon-attach time and
replayed on every decode step — physically wrong (thermal noise is fresh
per conversion) and irreproducible once the draw site moves. The contract
since then: every *step-time* ``jax.random`` draw derives its key from
``conversion_step()`` (the ``conversion_clock`` context threads the
engine's stream counter in), usually via
``fold_in(fold_in(noise_key, conversion_step()), salt)``.

In modules tagged ``step-time`` the rule taints names assigned from
expressions containing ``conversion_step()`` (transitively, per
function) and flags any draw whose key expression is untainted. Program-
time draws living in the same module suppress with a reason stating they
run before the clock exists.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import (
    Finding,
    ModuleContext,
    Rule,
    call_name,
    register,
)

_NON_DRAWS = {"split", "fold_in", "PRNGKey", "key", "wrap_key_data",
              "key_data", "clone"}


def _is_draw(node: ast.Call) -> bool:
    name = call_name(node)
    if name is None:
        return False
    parts = name.split(".")
    if len(parts) == 3 and parts[:2] == ["jax", "random"]:
        return parts[2] not in _NON_DRAWS
    if len(parts) == 2 and parts[0] in ("jrandom", "jr"):
        return parts[1] not in _NON_DRAWS
    return False


def _mentions_clock(node: ast.AST, tainted: set[str]) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            name = call_name(n)
            if name and name.split(".")[-1] == "conversion_step":
                return True
        if isinstance(n, ast.Name) and n.id in tainted:
            return True
    return False


@register
class UnkeyedStepNoise(Rule):
    rule_id = "R006"
    title = "step-time draw not derived from conversion_clock"
    required_tag = "step-time"

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings: list[Finding] = []
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            tainted = self._taint(fn)
            for n in ast.walk(fn):
                if isinstance(n, ast.Call) and _is_draw(n) and n.args:
                    key = n.args[0]
                    if not _mentions_clock(key, tainted):
                        findings.append(self.finding(
                            ctx, n,
                            "step-time jax.random draw whose key is not "
                            "derived from conversion_step() — the noise "
                            "replays identically every decode step and "
                            "is not stream-reproducible; fold the "
                            "conversion clock into the key"))
        return findings

    @staticmethod
    def _taint(fn: ast.AST) -> set[str]:
        """Names (transitively) derived from conversion_step() in fn."""
        tainted: set[str] = set()
        changed = True
        while changed:
            changed = False
            for n in ast.walk(fn):
                if not isinstance(n, ast.Assign):
                    continue
                if _mentions_clock(n.value, tainted):
                    for t in n.targets:
                        for tn in ast.walk(t):
                            if isinstance(tn, ast.Name) \
                                    and tn.id not in tainted:
                                tainted.add(tn.id)
                                changed = True
        return tainted
