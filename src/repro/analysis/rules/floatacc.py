"""R005: float accumulation discipline in exactness-critical modules.

The CIM datapath's bitwise contract rests on every contraction being
*provably* order-independent: integer-valued ADC codes, and cap-DAC
weights snapped to the 2^-14 fixed-point grid so partial sums stay exact
in float32 (PR 7). Any ``sum``/``einsum``/``dot``/``matmul``/``@`` in a
module tagged ``exactness-critical`` is therefore either (a) one of
those proven-exact contractions — in which case it carries an
``# exact-ok: <why>`` pragma stating the proof — or (b) a bug waiting
for a tile-size change to surface it.

float64/x64 usage is flagged in the same modules: the exactness proofs
are float32 proofs, and flipping x64 silently changes every threshold.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import (
    Finding,
    ModuleContext,
    Rule,
    call_name,
    register,
)

_ACC_CALLS = {
    "sum", "jnp.sum", "np.sum", "numpy.sum",
    "jnp.einsum", "np.einsum", "numpy.einsum",
    "jnp.dot", "np.dot", "jnp.vdot",
    "jnp.matmul", "np.matmul",
    "jnp.tensordot", "np.tensordot",
    "jax.lax.dot_general", "lax.dot_general",
    "jnp.cumsum", "np.cumsum",
    "math.fsum",
}
_ACC_METHODS = {"sum", "dot", "matmul", "cumsum"}

_X64_MARKERS = {"float64", "f64", "x64", "jax_enable_x64", "enable_x64",
                "double"}


@register
class FloatAccumulation(Rule):
    rule_id = "R005"
    title = "unproven float accumulation in an exactness-critical module"
    required_tag = "exactness-critical"

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings: list[Finding] = []
        for n in ast.walk(ctx.tree):
            if isinstance(n, ast.Call):
                name = call_name(n)
                hit = (name in _ACC_CALLS
                       or (name is not None and "." in name
                           and name.rsplit(".", 1)[1] in _ACC_METHODS
                           and isinstance(n.func, ast.Attribute)))
                if hit and not ctx.exact_ok(n.lineno):
                    findings.append(self.finding(
                        ctx, n,
                        f"{name or 'accumulation'}() in an "
                        f"exactness-critical module without an "
                        f"# exact-ok pragma — state why the contraction "
                        f"is order-independent (integer codes / 2^-14 "
                        f"grid) or move it off the exact path"))
            if isinstance(n, ast.BinOp) and isinstance(n.op, ast.MatMult) \
                    and not ctx.exact_ok(n.lineno):
                findings.append(self.finding(
                    ctx, n,
                    "'@' matmul in an exactness-critical module without "
                    "an # exact-ok pragma"))
            if isinstance(n, ast.Attribute) and n.attr in _X64_MARKERS \
                    and not ctx.exact_ok(n.lineno):
                findings.append(self.finding(
                    ctx, n,
                    f"'{n.attr}' in an exactness-critical module — the "
                    f"exactness proofs are float32 proofs; x64 silently "
                    f"moves every threshold"))
            if isinstance(n, ast.Constant) and n.value in (
                    "float64", "jax_enable_x64") \
                    and not ctx.exact_ok(n.lineno):
                findings.append(self.finding(
                    ctx, n,
                    f"'{n.value}' literal in an exactness-critical "
                    f"module — x64/float64 breaks the float32 exactness "
                    f"contract"))
        return findings
