"""CLI: ``python -m repro.analysis <paths> [--json] [--write-baseline]``.

Exit codes: 0 clean (every finding suppressed-with-reason or in the
baseline, no stale baseline entries), 1 findings/stale entries, 2 usage.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.engine import (
    all_rules,
    analyze_file,
    diff_baseline,
    iter_python_files,
    load_baseline,
    save_baseline,
)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="repro-lint: JAX-aware exactness linter (R001-R006)")
    ap.add_argument("paths", nargs="+",
                    help="files or directories to scan (relative to cwd)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    ap.add_argument("--baseline", default="analysis_baseline.json",
                    help="accepted-findings ledger (default: "
                         "analysis_baseline.json; empty/missing = zero "
                         "accepted findings)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline to the current finding "
                         "set (for paying debt DOWN, reviewed in diff)")
    ap.add_argument("--stats", action="store_true",
                    help="print per-rule finding/suppression counts")
    args = ap.parse_args(argv)

    root = Path.cwd()
    files = iter_python_files(args.paths, root)
    if not files:
        print("repro-lint: no python files under the given paths",
              file=sys.stderr)
        return 2

    rules = all_rules()
    findings = []
    suppressed = []
    for f in files:
        report = analyze_file(f, root, rules)
        findings.extend(report.findings)
        suppressed.extend(report.suppressed)
    findings.sort(key=lambda x: (x.path, x.line, x.col, x.rule))

    baseline_path = Path(args.baseline)
    if args.write_baseline:
        save_baseline(baseline_path, findings)
        print(f"repro-lint: wrote {len(findings)} accepted finding(s) to "
              f"{baseline_path}")
        return 0

    baseline = load_baseline(baseline_path) if baseline_path.exists() \
        else []
    new, stale = diff_baseline(findings, baseline)

    if args.as_json:
        print(json.dumps({
            "findings": [f.as_json() for f in new],
            "baselined": len(findings) - len(new),
            "suppressed": [{"finding": f.as_json(),
                            "reason": s.reason}
                           for f, s in suppressed],
            "stale_baseline": stale,
            "files_scanned": len(files),
        }, indent=2))
    else:
        for f in new:
            print(f.human())
        for b in stale:
            print(f"{b['path']}:{b['line']}: stale baseline entry "
                  f"{b['rule']} — the finding is gone; shrink the "
                  f"baseline (--write-baseline) so it cannot come back")
        if args.stats or not (new or stale):
            per_rule: dict[str, int] = {}
            for f, _ in suppressed:
                per_rule[f.rule] = per_rule.get(f.rule, 0) + 1
            sup_txt = ", ".join(f"{k}:{v}"
                                for k, v in sorted(per_rule.items()))
            print(f"repro-lint: {len(files)} files, "
                  f"{len(new)} finding(s), {len(findings) - len(new)} "
                  f"baselined, {len(suppressed)} suppressed"
                  + (f" [{sup_txt}]" if sup_txt else ""))
    return 1 if (new or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
