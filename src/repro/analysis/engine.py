"""repro-lint engine: rule registry, module scanning, suppressions, baseline.

The linter encodes this repo's *recurring* exactness/reproducibility bug
classes as machine-checked AST rules (see ``repro.analysis.rules``). The
engine is deliberately self-contained (stdlib only — ``ast`` + ``tokenize``)
so it runs in CI before any jax import.

Vocabulary the rules and CLI share:

* **Module tags** — a file opts into tag-scoped rules with a comment
  ``# repro-lint: module=exactness-critical[,step-time,...]`` anywhere in
  the file (conventionally right under the docstring). Tags in use:
  ``exactness-critical`` (R005 float-accumulation discipline + R004
  nondeterminism sources), ``deterministic`` (R004 only), ``step-time``
  (R006 conversion-clock-keyed noise).
* **Suppressions** — ``# repro-lint: disable=R001[,R004] reason=...`` on
  the finding's line (or a comment-only line directly above it) suppresses
  the listed rules there. A suppression WITHOUT a reason is itself a
  finding (R000): the policy is that every accepted exception documents
  why it is safe.
* **Pragmas** — ``# exact-ok: <why>`` marks a float accumulation in an
  exactness-critical module as proven-exact (integer-valued operands,
  fixed-point grid, ...). R005 requires it on every ``sum``/``einsum``/
  ``dot``/``@`` there.
* **Baseline** — a checked-in JSON list of accepted findings
  (``analysis_baseline.json``). The gate fails on any finding not in the
  baseline AND on stale baseline entries, so the baseline can only ever
  shrink: new debt cannot land, paid-off debt must be removed.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import json
import re
import tokenize
from pathlib import Path
from typing import Callable, Iterable, Optional

TOOL = "repro-lint"

_DIRECTIVE_RE = re.compile(r"#\s*repro-lint:\s*(?P<body>.*)")
_DISABLE_RE = re.compile(
    r"disable=(?P<rules>R\d{3}(?:\s*,\s*R\d{3})*)"
    r"(?:\s+reason=(?P<reason>\S.*))?")
_MODULE_RE = re.compile(r"module=(?P<tags>[\w-]+(?:\s*,\s*[\w-]+)*)")
_EXACT_OK_RE = re.compile(r"#\s*exact-ok\b")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str          # repo-relative posix path
    line: int          # 1-indexed
    col: int           # 0-indexed
    message: str

    def key(self) -> tuple:
        """Baseline identity (line-level: stable enough for a baseline
        whose end state is empty, cheap enough to diff by eye)."""
        return (self.rule, self.path, self.line)

    def human(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def as_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ModuleContext:
    """Everything a rule sees about one source file."""

    path: str                      # repo-relative posix path
    tree: ast.AST
    source: str
    tags: frozenset[str]
    comment_lines: dict[int, str]  # physical line -> comment text
    exact_ok_lines: frozenset[int]

    def has_tag(self, tag: str) -> bool:
        return tag in self.tags

    def exact_ok(self, line: int) -> bool:
        """True when ``line`` (or a comment-only line directly above it)
        carries the ``# exact-ok`` pragma."""
        return (line in self.exact_ok_lines
                or (line - 1 in self.exact_ok_lines
                    and _is_comment_only(self, line - 1)))


def _is_comment_only(ctx: ModuleContext, line: int) -> bool:
    if line not in ctx.comment_lines:
        return False
    src_line = ctx.source.splitlines()[line - 1]
    return src_line.lstrip().startswith("#")


class Rule:
    """Base class: subclass, set the class attrs, implement ``check``.

    ``required_tag`` scopes the rule to modules carrying that tag (None =
    every scanned module).
    """

    rule_id: str = "R000"
    title: str = ""
    required_tag: Optional[str] = None

    def check(self, ctx: ModuleContext) -> list[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, ctx: ModuleContext, node: ast.AST,
                message: str) -> Finding:
        return Finding(self.rule_id, ctx.path, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), message)


_REGISTRY: dict[str, Rule] = {}


def register(cls: type) -> type:
    """Class decorator adding a rule to the global registry."""
    inst = cls()
    if inst.rule_id in _REGISTRY:
        raise ValueError(f"duplicate rule id {inst.rule_id}")
    _REGISTRY[inst.rule_id] = inst
    return cls


def all_rules() -> dict[str, Rule]:
    # Import for side effect: the rule modules register themselves.
    from repro.analysis import rules  # noqa: F401
    return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# Comment / directive scanning (tokenize: robust to '#' inside strings).
# ---------------------------------------------------------------------------

def _scan_comments(source: str) -> dict[int, str]:
    comments: dict[int, str] = {}
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type == tokenize.COMMENT:
                comments[tok.start[0]] = tok.string
    except (tokenize.TokenError, IndentationError, SyntaxError):
        pass
    return comments


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int
    rules: frozenset[str]
    reason: Optional[str]
    comment_only: bool


def _scan_directives(source: str, comments: dict[int, str]
                     ) -> tuple[frozenset[str], list[Suppression],
                                frozenset[int]]:
    """Extract (module tags, suppressions, exact-ok pragma lines)."""
    tags: set[str] = set()
    sups: list[Suppression] = []
    exact_ok: set[int] = set()
    lines = source.splitlines()
    for line_no, text in comments.items():
        comment_only = (line_no <= len(lines)
                        and lines[line_no - 1].lstrip().startswith("#"))
        if _EXACT_OK_RE.search(text):
            exact_ok.add(line_no)
        m = _DIRECTIVE_RE.search(text)
        if not m:
            continue
        body = m.group("body")
        mt = _MODULE_RE.search(body)
        if mt:
            tags.update(t.strip() for t in mt.group("tags").split(","))
        md = _DISABLE_RE.search(body)
        if md:
            rules = frozenset(r.strip()
                              for r in md.group("rules").split(","))
            reason = md.group("reason")
            sups.append(Suppression(line_no, rules,
                                    reason.strip() if reason else None,
                                    comment_only))
    return frozenset(tags), sups, frozenset(exact_ok)


# ---------------------------------------------------------------------------
# Per-file analysis.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FileReport:
    path: str
    findings: list[Finding]
    suppressed: list[tuple[Finding, Suppression]]


def analyze_source(source: str, path: str,
                   rules: Optional[dict[str, Rule]] = None) -> FileReport:
    rules = rules if rules is not None else all_rules()
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return FileReport(path, [Finding("E999", path, e.lineno or 1,
                                         e.offset or 0,
                                         f"syntax error: {e.msg}")], [])
    comments = _scan_comments(source)
    tags, sups, exact_ok = _scan_directives(source, comments)
    ctx = ModuleContext(path=path, tree=tree, source=source, tags=tags,
                        comment_lines=comments, exact_ok_lines=exact_ok)
    raw: list[Finding] = []
    for rule in rules.values():
        if rule.required_tag is not None and not ctx.has_tag(
                rule.required_tag):
            continue
        raw.extend(rule.check(ctx))

    # Suppression resolution: a directive covers its own line, and — when
    # it sits on a comment-only line — the next code line below it.
    by_line: dict[int, list[Suppression]] = {}
    for s in sups:
        by_line.setdefault(s.line, []).append(s)
        if s.comment_only:
            by_line.setdefault(s.line + 1, []).append(s)

    findings: list[Finding] = []
    suppressed: list[tuple[Finding, Suppression]] = []
    used: set[Suppression] = set()
    for f in sorted(raw, key=lambda f: (f.line, f.col, f.rule)):
        sup = next((s for s in by_line.get(f.line, ())
                    if f.rule in s.rules), None)
        if sup is None:
            findings.append(f)
            continue
        used.add(sup)
        if sup.reason is None:
            findings.append(Finding(
                "R000", path, sup.line, 0,
                f"suppression of {f.rule} carries no reason= — every "
                f"accepted exception must document why it is safe"))
            findings.append(f)
        else:
            suppressed.append((f, sup))
    for s in sups:
        if s not in used:
            findings.append(Finding(
                "R000", path, s.line, 0,
                f"unused suppression (rules {','.join(sorted(s.rules))} "
                f"do not fire here) — stale directives hide future "
                f"regressions; delete it"))
    findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return FileReport(path, findings, suppressed)


def analyze_file(path: Path, root: Path,
                 rules: Optional[dict[str, Rule]] = None) -> FileReport:
    rel = path.resolve().relative_to(root.resolve()).as_posix() \
        if path.resolve().is_relative_to(root.resolve()) \
        else path.as_posix()
    return analyze_source(path.read_text(encoding="utf-8"), rel, rules)


def iter_python_files(paths: Iterable[str], root: Path) -> list[Path]:
    out: list[Path] = []
    for p in paths:
        pp = (root / p) if not Path(p).is_absolute() else Path(p)
        if pp.is_dir():
            out.extend(sorted(pp.rglob("*.py")))
        elif pp.suffix == ".py":
            out.append(pp)
    return out


# ---------------------------------------------------------------------------
# Baseline (shrink-only accepted-findings ledger).
# ---------------------------------------------------------------------------

def load_baseline(path: Path) -> list[dict]:
    data = json.loads(path.read_text(encoding="utf-8"))
    if not isinstance(data, list):
        raise ValueError(f"{path}: baseline must be a JSON list")
    return data


def save_baseline(path: Path, findings: list[Finding]) -> None:
    entries = [{"rule": f.rule, "path": f.path, "line": f.line,
                "message": f.message} for f in findings]
    path.write_text(json.dumps(entries, indent=2) + "\n", encoding="utf-8")


def diff_baseline(findings: list[Finding], baseline: list[dict]
                  ) -> tuple[list[Finding], list[dict]]:
    """Returns (new findings not in the baseline, stale baseline entries).

    Matching is by (rule, path, line): precise enough for a ledger whose
    target state is empty, and any drift surfaces as "stale" which forces
    a --write-baseline shrink rather than silently passing.
    """
    base_keys = {(b["rule"], b["path"], b["line"]) for b in baseline}
    found_keys = {f.key() for f in findings}
    new = [f for f in findings if f.key() not in base_keys]
    stale = [b for b in baseline
             if (b["rule"], b["path"], b["line"]) not in found_keys]
    return new, stale


# ---------------------------------------------------------------------------
# Shared AST helpers used by several rules.
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """'jax.random.normal' for Attribute/Name chains, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def walk_functions(tree: ast.AST) -> list[ast.AST]:
    """Every function/lambda scope in the module, outermost first."""
    return [n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda))]


def call_name(node: ast.Call) -> Optional[str]:
    return dotted_name(node.func)


def for_each_call(tree: ast.AST, fn: Callable[[ast.Call, str], None]
                  ) -> None:
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = call_name(node)
            if name is not None:
                fn(node, name)
