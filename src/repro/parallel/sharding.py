"""Parameter/activation sharding rules (DP + FSDP + TP + EP + SP).

Storage shardings are assigned per-leaf by path suffix + rank heuristics,
following the Megatron pattern: column-parallel in-projections (out dim on
the `model` axis), row-parallel out-projections (in dim on `model`), FSDP
(ZeRO-3) over the `data` axis, experts over `model` (EP), embedding over
(vocab=`model`, d=`data`). Every proposed axis is divisibility-guarded —
a dim that doesn't divide the axis size stays unsharded (e.g. whisper's
51865 vocab).

Compute-level correctness is GSPMD's job; these specs set the resident
layout the compiler propagates from.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ParallelConfig


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# suffix-name -> (role) where role determines the last-dims template
_COLUMN = {"q", "k", "v", "up", "gate", "in_x", "in_gate", "uq", "uk", "uv",
           "dq", "dkv", "wz", "wi", "wf", "wo", "vision_proj"}
_ROW = {"o", "down", "out"}


def _guard(dim: int, axis: Optional[str], axis_sizes: dict) -> Optional[str]:
    if axis is None:
        return None
    size = axis_sizes.get(axis, 1) if isinstance(axis, str) else int(
        np.prod([axis_sizes.get(a, 1) for a in axis]))
    return axis if size > 1 and dim % size == 0 else None


def spec_for_param(path, leaf, pcfg: ParallelConfig, axis_sizes: dict) -> P:
    name = _path_str(path)
    parts = name.split("/")
    shape = leaf.shape
    rank = len(shape)
    tp = pcfg.tp_axis
    fsdp = "data" if pcfg.fsdp else None

    def tail(*axes):
        """Spec with ``axes`` on the trailing dims, None on leading dims."""
        axes = [_guard(shape[rank - len(axes) + i], a, axis_sizes)
                for i, a in enumerate(axes)]
        return P(*([None] * (rank - len(axes)) + axes))

    # --- special families, most specific first ---------------------------
    if "router" in parts:
        return P()
    if "experts" in parts:
        ep = pcfg.ep_axes if len(pcfg.ep_axes) > 1 else pcfg.ep_axes[0]
        # FSDP on inner dims only if 'data' isn't already consumed by EP.
        efsdp = fsdp if (fsdp not in pcfg.ep_axes) else None
        leafname = parts[-1]
        if leafname in ("up", "gate"):        # (..., E, d, f)
            return tail(ep, efsdp, None)
        if leafname == "down":                # (..., E, f, d)
            return tail(ep, None, efsdp)
        if leafname.startswith("alpha"):      # (..., E, f)
            return tail(ep, None)
        return tail(ep) if rank >= 1 else P()
    if parts[-1] == "table":                  # embedding (V, d)
        return tail(tp, fsdp)
    if "lm_head" in parts and parts[-1] == "w":   # (d, V)
        return tail(fsdp, tp)
    if parts[-1] in ("conv_w",):              # (W, width)
        return tail(None, tp)
    if parts[-1] in ("w_a", "w_x"):           # (width, width) gate kernels
        return tail(None, tp)
    if parts[-1] in ("lam", "b_a", "b_x"):
        return tail(tp)
    if parts[-1] in ("rz", "ri", "rf", "ro"):  # sLSTM block-diag recurrents
        return P()

    owner = parts[-2] if len(parts) >= 2 else ""
    leafname = parts[-1]
    if pcfg.serve_tp_megaaxis and leafname == "w" and (
            owner in _COLUMN or owner in _ROW):
        mega = ("data", tp)

        def first_fit(dim, *cands):
            for c in cands:
                g = _guard(dim, c, axis_sizes)
                if g is not None:
                    return g
            return None

        if owner in _COLUMN:                  # (..., in, out): shard OUT
            out_axis = first_fit(shape[-1], mega, tp, "data")
            return P(*([None] * (rank - 1) + [out_axis]))
        # row: shard the contraction (IN) dim — partial sums reduce over
        # it with an activation-sized all-reduce, never a weight gather.
        in_axis = first_fit(shape[-2], mega, tp, "data")
        return P(*([None] * (rank - 2) + [in_axis, None]))
    if leafname == "w":
        if owner in _COLUMN:
            return tail(fsdp, tp)
        if owner in _ROW:
            return tail(tp, fsdp)
        if owner in ("igate", "fgate"):       # (d_inner, H) tiny
            return tail(fsdp, None)
        return tail(fsdp, None) if rank >= 2 else P()
    if leafname in ("alpha", "b"):
        if owner in _COLUMN:
            if pcfg.serve_tp_megaaxis:
                mega = ("data", tp)
                g = _guard(shape[-1], mega, axis_sizes) or _guard(
                    shape[-1], tp, axis_sizes)
                return P(*([None] * (rank - 1) + [g]))
            return tail(tp)
        return tail(None)
    # norms, scalars, everything else: replicated (leading dims unsharded)
    return P()


def params_pspecs(params, pcfg: ParallelConfig, mesh: Mesh):
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_param(path, leaf, pcfg, axis_sizes),
        params)


def params_shardings(params, pcfg: ParallelConfig, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        params_pspecs(params, pcfg, mesh))


def opt_state_pspecs(params_struct, pspecs, tcfg):
    """Optimizer-state specs derived from parameter specs.

    adamw/sgdm moments mirror the parameter layout; adafactor's factored
    moments drop the reduced dim from the parameter spec. int8-quantised
    moments (blocked layout) are replicated — use adafactor for the
    memory-bound giants instead.
    """
    if tcfg.optimizer == "adamw":
        if tcfg.opt_state_dtype == "int8":
            rep = jax.tree.map(lambda _: P(), params_struct)
            blk = {"q": P(), "s": P()}
            rep = jax.tree.map(lambda _: dict(blk), params_struct)
            return {"m": rep, "v": rep}
        return {"m": pspecs, "v": pspecs}
    if tcfg.optimizer == "sgdm":
        return {"m": pspecs}
    if tcfg.optimizer == "adafactor":
        def fac(p, spec):
            axes = tuple(spec)
            axes = axes + (None,) * (p.ndim - len(axes))
            if p.ndim >= 2:
                return {"vr": P(*axes[:-1]),
                        "vc": P(*(axes[:-2] + axes[-1:]))}
            return {"v": P(*axes)}
        return {"f": jax.tree.map(fac, params_struct, pspecs)}
    raise ValueError(tcfg.optimizer)


# ---------------------------------------------------------------------------
# Batch/cache shardings
# ---------------------------------------------------------------------------

def dp_spec(pcfg: ParallelConfig) -> Any:
    return pcfg.dp_axes if len(pcfg.dp_axes) > 1 else pcfg.dp_axes[0]


def batch_pspecs(batch_specs: dict, pcfg: ParallelConfig, mesh: Mesh,
                 seq_shard: bool = False, cfg=None) -> dict:
    """Input-batch specs: batch dim over DP; optionally seq over TP (SP).

    Cache specs (decode cells) come from the authoritative per-family
    builders that mirror the cache constructors: attention cache sequence
    dims go on the `model` axis (flash-decode SP — valid for any kv-head
    count), recurrent state widths on `model`, batch on DP.
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = dp_spec(pcfg)
    out = {}
    for k, v in batch_specs.items():
        if k == "cache":
            assert cfg is not None, "cache specs need the model config"
            if cfg.family == "encdec":
                from repro.models import encdec as E
                out[k] = E.encdec_cache_pspecs(cfg, v, pcfg, axis_sizes)
            else:
                from repro.models import transformer as T
                out[k] = T.lm_cache_pspecs(cfg, v, pcfg, axis_sizes)
            continue
        rank = len(v.shape)
        if rank == 1:
            out[k] = P(dp)
        elif rank >= 2:
            seq_axis = pcfg.tp_axis if (
                seq_shard and pcfg.tp_axis in axis_sizes
                and v.shape[1] % axis_sizes[pcfg.tp_axis] == 0) else None
            out[k] = P(*([dp, seq_axis] + [None] * (rank - 2)))
    return sanitize_pspecs(out, batch_specs, axis_sizes)


def tree_shardings(tree_pspecs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def sanitize_pspecs(pspecs, structs, axis_sizes: dict):
    """Null out any spec axis that does not divide its dim (e.g. batch=1
    decode cells can't shard batch over data)."""
    def size_of(ax) -> int:
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= axis_sizes.get(a, 1)
        return n

    def fix(spec, leaf):
        dims = list(spec) + [None] * (len(leaf.shape) - len(spec))
        out = [ax if ax is None or leaf.shape[i] % size_of(ax) == 0
               else None for i, ax in enumerate(dims)]
        return P(*out)

    return jax.tree.map(fix, pspecs, structs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Serving-mesh shardings (repro.traffic.shard)
# ---------------------------------------------------------------------------

def serve_cache_pspecs(cfg, cache, axis_sizes: dict,
                       data_axis: str = "data"):
    """Decode-cache specs for the serving mesh: the slot (batch) dim over
    ``data_axis``, everything else replicated.

    Reuses the authoritative per-family cache builders
    (``T.lm_cache_pspecs``), then strips every axis the serving mesh does
    not have (the builders propose training axes like ``model`` for
    flash-decode SP) and every axis that does not divide its dim — so the
    result is always placeable on a ``("data", "fleet")`` mesh.
    """
    from repro.models import transformer as T
    from repro.configs.base import ParallelConfig as PC
    pcfg = PC(dp_axes=(data_axis,))
    specs = T.lm_cache_pspecs(cfg, cache, pcfg, axis_sizes)

    def size_of(axes) -> int:
        n = 1
        for a in axes:
            n *= axis_sizes.get(a, 1)
        return n

    def fix(spec, leaf):
        dims = list(spec) + [None] * (len(leaf.shape) - len(spec))
        out = []
        for i, ax in enumerate(dims):
            if ax is None:
                out.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            ok = (all(a in axis_sizes for a in axes)
                  and size_of(axes) > 1
                  and leaf.shape[i] % size_of(axes) == 0)
            out.append(ax if ok else None)
        return P(*out)

    return jax.tree.map(fix, specs, cache,
                        is_leaf=lambda x: isinstance(x, P))


def exec_param_pspecs(params, axis_sizes: dict, fleet_axis: str = "fleet"):
    """Sharding specs for a ``ServeEngine`` exec tree.

    Programmed macro state is the serving working set: each
    :class:`~repro.core.programmed.ProgrammedMacro`'s weight-plane /
    lossless state and digital residue shard their output-channel (N)
    dim — the macro-placement axis: device d of the ``fleet`` axis holds
    a contiguous slice of every projection's µArray banks, mirroring how
    a multi-die fleet splits a projection's tiles by output channel.
    Scales, swapped macros (scales only), silicon views and every float
    parameter stay replicated — divisibility-guarded like everything
    else, so a fleet axis that doesn't divide some projection's N simply
    leaves that projection replicated.
    """
    from repro.core.programmed import (CimLosslessState, CimPackedPlanes,
                                       ProgrammedMacro, _is_prog_key)

    def rep(sub):
        return jax.tree.map(lambda _: P(), sub)

    def last_dim(leaf) -> P:
        if getattr(leaf, "ndim", 0) < 1:
            return P()
        ax = _guard(leaf.shape[-1], fleet_axis, axis_sizes)
        return P(*([None] * (leaf.ndim - 1) + [ax]))

    def prog_spec(pm: ProgrammedMacro) -> ProgrammedMacro:
        return ProgrammedMacro(
            sw=rep(pm.sw), sx=rep(pm.sx), r_w=last_dim(pm.r_w),
            state=None if pm.state is None else CimPackedPlanes(
                packed=last_dim(pm.state.packed),
                r_w=last_dim(pm.state.r_w)),
            # Kernel state keeps mixed layouts ((N, Kp) gates vs
            # (Pw, Kp, N) planes) — replicated; the Pallas path is not a
            # traffic-lab target.
            kernel=None if pm.kernel is None else rep(pm.kernel),
            lossless=None if pm.lossless is None else CimLosslessState(
                packed=last_dim(pm.lossless.packed)))

    def walk(node):
        if isinstance(node, dict):
            return {k: prog_spec(v)
                    if _is_prog_key(k) and isinstance(v, ProgrammedMacro)
                    else walk(v) for k, v in node.items()}
        if type(node) in (list, tuple):
            return type(node)(walk(v) for v in node)
        return rep(node)

    return walk(params)
