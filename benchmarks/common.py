"""Shared benchmark harness utilities."""

from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import image_batch


def timed(fn: Callable, *args, repeats: int = 3):
    """(result, us_per_call) — median wall time."""
    fn(*args)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") \
            else out
        times.append(time.perf_counter() - t0)
    return out, float(np.median(times) * 1e6)


def train_image_classifier(params, apply_fn, *, steps: int, batch: int,
                           n_classes: int, hw: int, channels: int,
                           lr: float = 2e-3, seed: int = 0,
                           eval_batches: int = 4, noise: float = 0.35):
    """Small-step Adam training on the synthetic class-blob task.

    Returns (trained params, accuracy, loss_history). The task is linearly
    separable-ish, so relative accuracy between operator modes mirrors the
    paper's Table I ordering at a laptop-scale budget.
    """
    from repro.configs.base import TrainConfig
    from repro.train import optimizer as opt_mod

    tcfg = TrainConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                       total_steps=steps, weight_decay=0.0)
    opt = opt_mod.make_adamw(tcfg)
    opt_state = opt.init(params)

    def loss_fn(p, x, y):
        logits = apply_fn(p, x)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))

    @jax.jit
    def step_fn(p, s, x, y, i):
        loss, g = jax.value_and_grad(loss_fn)(p, x, y)
        g, _ = opt_mod.clip_by_global_norm(g, 1.0)
        upd, s = opt.update(g, s, p, i)
        return opt_mod.apply_updates(p, upd), s, loss

    hist = []
    for i in range(steps):
        x, y = image_batch(batch, n_classes, hw, channels, i, seed=seed,
                           noise=noise)
        params, opt_state, loss = step_fn(params, opt_state,
                                          jnp.asarray(x), jnp.asarray(y),
                                          jnp.asarray(i))
        hist.append(float(loss))

    @jax.jit
    def acc_fn(p, x, y):
        return jnp.mean(jnp.argmax(apply_fn(p, x), -1) == y)

    accs = []
    for j in range(eval_batches):
        x, y = image_batch(batch, n_classes, hw, channels, 10_000 + j,
                           seed=seed, noise=noise)
        accs.append(float(acc_fn(params, jnp.asarray(x), jnp.asarray(y))))
    return params, float(np.mean(accs)), hist
