"""Benchmark driver: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full] [--only name]``
prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = (
    "table1_accuracy",
    "table2_efficiency",
    "fig7_precision_sweep",
    "fig8_variability",
    "fig9_mixed_mapping",
    "compiler_report",
    "kernel_bench",
    "serve_bench",
    "traffic_report",
    "calib_report",
    "silicon_report",
    "macro_report",
    "roofline_report",
    "obs_report",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-size sweeps (quick by default)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    for suite in SUITES:
        if args.only and args.only not in suite:
            continue
        mod = __import__(f"benchmarks.{suite}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run(quick=not args.full)
        except Exception:                      # noqa: BLE001
            traceback.print_exc()
            print(f"{suite},0,SUITE_FAILED")
            failures += 1
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        print(f"{suite}__total,{(time.time() - t0) * 1e6:.0f},ok",
              flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
