"""Serving benchmarks: programmed decode, batched prefill, reprogram cost.

Three sections on the qwen3 config with every MF projection mapped to
``cim_sim``:

  * **decode** — programmed (weight-stationary) vs legacy on-the-fly CIM
    serving: steady-state decode tokens/sec (PR 2's >= 2x gate).
  * **prefill** — batched programmed prefill (one (B, T) forward per
    admission wave, the T > 1 prompt axis folded into the collapsed
    step-time matmuls) vs prefill-as-decode (one decode step per prompt
    token): prompt-ingestion tokens/sec, gated >= 2x.
  * **reprogram** — the same model served from a fleet too small to pin
    it: round-interleaved decode (``rounds > 1``) must produce bit-exact
    tokens vs the pinned path, and the run's ``ServeReport`` charges
    every reprogram event against the Eq. 4 roll-up (reload bits / nJ).

Emits ``BENCH_serve.json`` (the serving perf trajectory anchor) and the
``benchmarks/run.py`` CSV rows.

CLI: ``PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.compiler.tiling import Fleet
from repro.configs.base import MFTechniqueConfig
from repro.configs.qwen3_0_6b import SMOKE
from repro.core.cim import CimConfig
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine

OUT_PATH = os.environ.get("BENCH_SERVE_OUT", "BENCH_serve.json")


def _serve_cfg(quick: bool):
    """qwen3 proportions with cim_sim projections.

    The smoke point keeps qwen3's layer pattern at reduced width; the full
    point widens toward the real shapes (still laptop-runnable: the
    behavioural µArray simulator is ~Pw*K*N work per projection call).
    """
    cim = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31)
    mf = MFTechniqueConfig(mode="cim_sim", cim=cim)
    base = SMOKE if quick else dataclasses.replace(
        SMOKE, d_model=256, d_ff=768, head_dim=64, vocab_size=2048)
    return dataclasses.replace(base, dtype=jnp.float32, mf=mf)


def _decode_tok_per_s(engine: ServeEngine, ticks: int, warmup: int = 3,
                      reps: int = 3) -> float:
    """Median steady-state decode throughput over ``reps`` windows."""
    import numpy as np
    for _ in range(engine.slots):
        engine.submit(Request(prompt=[1], max_new_tokens=1 << 30))
    for _ in range(warmup):
        engine.step()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(ticks):
            engine.step()
        jax.block_until_ready(engine.cache["pos"])
        times.append(time.perf_counter() - t0)
    return engine.slots * ticks / float(np.median(times))


def _prompt_tok_per_s(engine: ServeEngine, prompt_len: int, reps: int = 3
                      ) -> float:
    """Median prompt-ingestion throughput (prompt tokens/sec) over full
    ``run()`` waves of ``slots`` requests with one generated token each."""
    import numpy as np

    def one_wave():
        reqs = [Request(prompt=list(range(1, prompt_len + 1)),
                        max_new_tokens=1) for _ in range(engine.slots)]
        t0 = time.perf_counter()
        done = engine.run(reqs)
        dt = time.perf_counter() - t0
        assert all(r.done for r in done)
        return dt

    one_wave()                                    # warmup (compile)
    times = [one_wave() for _ in range(reps)]
    return engine.slots * (prompt_len - 1) / float(np.median(times))


def _greedy_tokens(engine: ServeEngine, prompt: list[int], n: int,
                   n_reqs: int) -> list[list[int]]:
    done = engine.run([Request(prompt=list(prompt), max_new_tokens=n)
                       for _ in range(n_reqs)])
    return [r.out for r in done]


def run(quick: bool = True):
    cfg = _serve_cfg(quick)
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    ticks = 10 if quick else 30
    warmup, reps = 3, 3
    max_len = reps * ticks + warmup + 4
    slots = 2

    prog_eng = ServeEngine(params, cfg, slots=slots, max_len=max_len,
                           program=True)
    legacy_eng = ServeEngine(params, cfg, slots=slots, max_len=max_len,
                             program=False)
    assert prog_eng.programmed and not legacy_eng.programmed
    from repro.core.programmed import (programmed_bytes,
                                       programmed_bytes_unpacked)
    state_bytes = programmed_bytes(prog_eng._exec_params)
    state_bytes_unpacked = programmed_bytes_unpacked(prog_eng._exec_params,
                                                     cfg.mf.cim)
    # Bit-packing gate: packed plane/magnitude cells must strictly shrink
    # the programmed state versus the one-int8-per-cell layouts.
    assert state_bytes < state_bytes_unpacked, (state_bytes,
                                                state_bytes_unpacked)

    prog_tok_s = _decode_tok_per_s(prog_eng, ticks, warmup, reps)
    legacy_tok_s = _decode_tok_per_s(legacy_eng, ticks, warmup, reps)
    speedup = prog_tok_s / legacy_tok_s if legacy_tok_s else 0.0

    # ---- batched programmed prefill vs prefill-as-decode -----------------
    prompt_len = 33 if quick else 65              # 32 / 64 prefill tokens
    pre_len = prompt_len + 8
    pre_batched = ServeEngine(params, cfg, slots=slots, max_len=pre_len)
    pre_decode = ServeEngine(params, cfg, slots=slots, max_len=pre_len,
                             batched_prefill=False)
    assert pre_batched.batched_prefill and not pre_decode.batched_prefill
    batched_ptok_s = _prompt_tok_per_s(pre_batched, prompt_len, reps)
    decode_ptok_s = _prompt_tok_per_s(pre_decode, prompt_len, reps)
    prefill_speedup = batched_ptok_s / decode_ptok_s if decode_ptok_s \
        else 0.0
    # Acceptance gate: batched prefill must at least double prompt
    # ingestion over paying one decode step per token.
    assert prefill_speedup >= 2.0, (
        f"batched prefill speedup {prefill_speedup:.2f}x < 2x "
        f"({batched_ptok_s:.1f} vs {decode_ptok_s:.1f} prompt tok/s)")

    # ---- round-interleaved serving on a fleet too small to pin -----------
    cim = cfg.mf.cim
    swap_fleet = Fleet(n_macros=64 if quick else 1024, cfg=cim)
    swap_eng = ServeEngine(params, cfg, slots=slots, max_len=16,
                           fleet=swap_fleet, batched_prefill=False)
    sched = swap_eng.schedule
    pinned_fleet = Fleet(n_macros=-(-sched.total_tiles // 2), cfg=cim)
    pin_eng = ServeEngine(params, cfg, slots=slots, max_len=16,
                          fleet=pinned_fleet, batched_prefill=False)
    assert pin_eng.schedule.pinned and not sched.pinned
    assert sched.rounds_max > 1, (
        f"fleet {swap_fleet.n_macros} macros did not force rounds > 1")
    # The executed datapath really is round-interleaved: every projection
    # the swap engine serves carries SwappedMacro state (apply_projection
    # dispatches on it), while the pinned engine holds resident macros.
    from repro.core.programmed import SwappedMacro, iter_projections
    swap_progs = [n["prog"] for _, n, _ in
                  iter_projections(swap_eng._exec_params)]
    assert swap_progs and all(isinstance(p, SwappedMacro)
                              for p in swap_progs)
    assert not any(isinstance(n.get("prog"), SwappedMacro) for _, n, _ in
                   iter_projections(pin_eng._exec_params))
    n_new = 4
    pin_out = _greedy_tokens(pin_eng, [1, 2, 3], n_new, slots)
    t0 = time.perf_counter()
    swap_out = _greedy_tokens(swap_eng, [1, 2, 3], n_new, slots)
    swap_dt = time.perf_counter() - t0
    bit_exact = swap_out == pin_out
    assert bit_exact, "round-interleaved decode diverged from pinned path"
    rep = swap_eng.last_report
    assert rep.streams > 0 and rep.reprogram_events > 0
    pin_rep = pin_eng.last_report
    assert pin_rep.reprogram_events == 0 and pin_rep.reload_bits == 0

    payload = {
        "bench": "serve_decode",
        "config": cfg.name,
        "quick": quick,
        "slots": slots,
        "ticks": ticks,
        "w_bits": cfg.mf.cim.w_bits,
        "x_bits": cfg.mf.cim.x_bits,
        "adc_bits": cfg.mf.cim.adc_bits,
        "m_columns": cfg.mf.cim.m_columns,
        "programmed_state_bytes": state_bytes,
        "programmed_state_bytes_unpacked": state_bytes_unpacked,
        "bit_packing_ratio": state_bytes_unpacked / max(state_bytes, 1),
        "programmed_tok_s": prog_tok_s,
        "legacy_tok_s": legacy_tok_s,
        "speedup": speedup,
        "prefill": {
            "prompt_len": prompt_len,
            "batched_prompt_tok_s": batched_ptok_s,
            "as_decode_prompt_tok_s": decode_ptok_s,
            "speedup": prefill_speedup,
            "gate_2x": prefill_speedup >= 2.0,
        },
        "reprogram": {
            "n_macros": swap_fleet.n_macros,
            "tile_slots": swap_fleet.tile_slots,
            "total_tiles": sched.total_tiles,
            "pinned": sched.pinned,
            "rounds_max": sched.rounds_max,
            "reprogram_events_per_stream": sched.total_reprogram_events,
            "reload_bits_per_stream": sched.total_reload_bits,
            "streams": rep.streams,
            "reprogram_events": rep.reprogram_events,
            "reload_bits": rep.reload_bits,
            "reload_energy_nj": rep.reload_energy_nj,
            "reload_s": rep.reload_s,
            "utilization": rep.utilization,
            "swapped_tok_s": slots * n_new / swap_dt,
            "bit_exact_vs_pinned": bit_exact,
        },
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")

    return [
        ("serve_decode_programmed", 1e6 / prog_tok_s,
         f"tok_s={prog_tok_s:.1f}"),
        ("serve_decode_legacy", 1e6 / legacy_tok_s,
         f"tok_s={legacy_tok_s:.1f}"),
        ("serve_decode_speedup", 0.0,
         f"programmed/legacy={speedup:.2f}x json={OUT_PATH}"),
        ("serve_prefill_batched", 1e6 / batched_ptok_s,
         f"prompt_tok_s={batched_ptok_s:.1f}"),
        ("serve_prefill_as_decode", 1e6 / decode_ptok_s,
         f"prompt_tok_s={decode_ptok_s:.1f}"),
        ("serve_prefill_speedup", 0.0,
         f"batched/as_decode={prefill_speedup:.2f}x gate>=2x"),
        ("serve_reprogram_rounds", 0.0,
         f"rounds_max={sched.rounds_max} "
         f"reprog/stream={sched.total_reprogram_events} "
         f"reload_bits/stream={sched.total_reload_bits} "
         f"bit_exact={bit_exact}"),
        ("serve_reprogram_rollup", 0.0,
         f"streams={rep.streams} events={rep.reprogram_events} "
         f"reload={rep.reload_energy_nj:.2f}nJ util={rep.utilization:.2f}"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small qwen3 smoke shapes (CI)")
    args = ap.parse_args()
    for name, us, derived in run(quick=args.smoke):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
