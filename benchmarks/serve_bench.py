"""Decode throughput: programmed (weight-stationary) vs legacy CIM serving.

Spins up two ``ServeEngine`` instances on the qwen3 config with every MF
projection mapped to ``cim_sim`` — one programmed at construction
(weights frozen into macro state, step does input-side work only) and one
on the legacy on-the-fly path (recalibrate/requantise/bitplane/pack every
step) — fills all slots with decode-bound requests, and measures
steady-state decode tokens/sec.

Emits ``BENCH_serve.json`` (the serving perf trajectory anchor) and the
``benchmarks/run.py`` CSV rows.

CLI: ``PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs.base import MFTechniqueConfig
from repro.configs.qwen3_0_6b import SMOKE
from repro.core.cim import CimConfig
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine

OUT_PATH = os.environ.get("BENCH_SERVE_OUT", "BENCH_serve.json")


def _serve_cfg(quick: bool):
    """qwen3 proportions with cim_sim projections.

    The smoke point keeps qwen3's layer pattern at reduced width; the full
    point widens toward the real shapes (still laptop-runnable: the
    behavioural µArray simulator is ~Pw*K*N work per projection call).
    """
    cim = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31)
    mf = MFTechniqueConfig(mode="cim_sim", cim=cim)
    base = SMOKE if quick else dataclasses.replace(
        SMOKE, d_model=256, d_ff=768, head_dim=64, vocab_size=2048)
    return dataclasses.replace(base, dtype=jnp.float32, mf=mf)


def _decode_tok_per_s(engine: ServeEngine, ticks: int, warmup: int = 3,
                      reps: int = 3) -> float:
    """Median steady-state decode throughput over ``reps`` windows."""
    import numpy as np
    for _ in range(engine.slots):
        engine.submit(Request(prompt=[1], max_new_tokens=1 << 30))
    for _ in range(warmup):
        engine.step()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(ticks):
            engine.step()
        jax.block_until_ready(engine.cache["pos"])
        times.append(time.perf_counter() - t0)
    return engine.slots * ticks / float(np.median(times))


def run(quick: bool = True):
    cfg = _serve_cfg(quick)
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    ticks = 10 if quick else 30
    warmup, reps = 3, 3
    max_len = reps * ticks + warmup + 4
    slots = 2

    prog_eng = ServeEngine(params, cfg, slots=slots, max_len=max_len,
                           program=True)
    legacy_eng = ServeEngine(params, cfg, slots=slots, max_len=max_len,
                             program=False)
    assert prog_eng.programmed and not legacy_eng.programmed
    from repro.core.programmed import (programmed_bytes,
                                       programmed_bytes_unpacked)
    state_bytes = programmed_bytes(prog_eng._exec_params)
    state_bytes_unpacked = programmed_bytes_unpacked(prog_eng._exec_params,
                                                     cfg.mf.cim)
    # Bit-packing gate: packed plane/magnitude cells must strictly shrink
    # the programmed state versus the one-int8-per-cell layouts.
    assert state_bytes < state_bytes_unpacked, (state_bytes,
                                                state_bytes_unpacked)

    prog_tok_s = _decode_tok_per_s(prog_eng, ticks, warmup, reps)
    legacy_tok_s = _decode_tok_per_s(legacy_eng, ticks, warmup, reps)
    speedup = prog_tok_s / legacy_tok_s if legacy_tok_s else 0.0

    payload = {
        "bench": "serve_decode",
        "config": cfg.name,
        "quick": quick,
        "slots": slots,
        "ticks": ticks,
        "w_bits": cfg.mf.cim.w_bits,
        "x_bits": cfg.mf.cim.x_bits,
        "adc_bits": cfg.mf.cim.adc_bits,
        "m_columns": cfg.mf.cim.m_columns,
        "programmed_state_bytes": state_bytes,
        "programmed_state_bytes_unpacked": state_bytes_unpacked,
        "bit_packing_ratio": state_bytes_unpacked / max(state_bytes, 1),
        "programmed_tok_s": prog_tok_s,
        "legacy_tok_s": legacy_tok_s,
        "speedup": speedup,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")

    return [
        ("serve_decode_programmed", 1e6 / prog_tok_s,
         f"tok_s={prog_tok_s:.1f}"),
        ("serve_decode_legacy", 1e6 / legacy_tok_s,
         f"tok_s={legacy_tok_s:.1f}"),
        ("serve_decode_speedup", 0.0,
         f"programmed/legacy={speedup:.2f}x json={OUT_PATH}"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small qwen3 smoke shapes (CI)")
    args = ap.parse_args()
    for name, us, derived in run(quick=args.smoke):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
