"""Table II: macro-level TOPS/W of the compute-in-SRAM µArrays.

Model-derived (Eq. 4 with calibrated constants — see core/energy.py):
paper design points 8x62 -> ~105 TOPS/W (5-bit ADC), 8x30 -> ~84 TOPS/W
(4-bit ADC). Also reports the paper's comparison rows and the Fig. 6
energy split / hold-voltage trade-off.
"""

from __future__ import annotations

from benchmarks.common import timed
from repro.core.cim import CimConfig
from repro.core import energy as E


def run(quick: bool = True):
    rows = []
    cfg62 = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31)
    cfg30 = CimConfig(w_bits=8, x_bits=8, adc_bits=4, m_columns=15)

    (v62, us62) = timed(E.tops_per_watt, cfg62)
    (v30, us30) = timed(E.tops_per_watt, cfg30)
    rows.append(("table2_tops_w_8x62", us62, f"{v62:.2f} (paper ~105)"))
    rows.append(("table2_tops_w_8x30", us30, f"{v30:.2f} (paper ~84)"))
    rows.append(("table2_latency_cycles_8x62", 0.0,
                 f"{E.unit_op_cycles(cfg62)} (=W_P*(1+2A_P))"))
    rows.append(("table2_unit_energy_pJ_8x62", 0.0,
                 f"{E.unit_op_energy_j(cfg62) * 1e12:.3f}"))

    split = E.energy_split(cfg62)
    rows.append(("fig6b_energy_split", 0.0,
                 f"mav={split['mav']:.2f} digit={split['digitization']:.2f} "
                 f"leak={split['leakage']:.4f} (paper 0.44/0.55/<0.01)"))
    for v in (0.3, 0.4, 0.5):
        rows.append((f"fig6a_hold_{v}V", 0.0,
                     f"leak={E.leakage_vs_hold_voltage(v) * 1e9:.2f}nW "
                     f"t_dis={E.discharge_time_vs_hold_voltage(v) * 1e12:.0f}ps"))

    # paper comparison rows (their reported numbers, for the table)
    rows.append(("table2_ref_su_isscc20_28nm", 0.0, "7 TOPS/W"))
    rows.append(("table2_ref_yue_isscc20_65nm", 0.0, "2.96 TOPS/W"))
    rows.append(("table2_ref_dong_isscc20_7nm_4b", 0.0, "321 TOPS/W"))
    rows.append(("table2_ref_c3sram_65nm_1b", 0.0, "671.5 TOPS/W"))
    rows.append(("table2_advantage_vs_28nm", 0.0,
                 f"{v62 / 7:.1f}x (paper 15x)"))
    rows.append(("table2_advantage_vs_65nm", 0.0,
                 f"{v62 / 2.96:.1f}x (paper 35x)"))
    return rows
