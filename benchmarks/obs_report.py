"""Observability report: parity, overhead, and the drift-story gates.

Three sections over ``repro.obs`` (the fleet-telemetry layer):

  * **parity** — tracing DISABLED must cost nothing: on the qwen3 smoke
    config, pinned / swapped / sigma0-silicon engines decode tokens that
    are BITWISE identical whether no bus is installed, a bus is
    installed against an untraced engine (host emitters only), or the
    engine itself was built ``tracing=True`` (in-jit ``io_callback``
    emission) — the callback is a pure side channel, never a value.
  * **overhead** — tracing ENABLED on the qwen3 smoke decode loop costs
    <= 5% steady-state tokens/sec versus the untraced engine.
  * **drift story** — a drifting silicon fleet served under a detail
    bus; the exported JSONL trace alone (re-read from disk, not the live
    buffer) must reconstruct the full drift-alarm -> retrim/retire ->
    recalibration maintenance narrative, render the fleet tier heatmap,
    and the engine's metrics must round-trip through the Prometheus
    text exposition.

Emits ``BENCH_obs.json`` plus the sample trace ``BENCH_obs_trace.jsonl``
(both CI artifacts) and the ``benchmarks/run.py`` CSV rows.

CLI: ``PYTHONPATH=src python -m benchmarks.obs_report [--smoke]``.
"""
# repro-lint: module=observability

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from repro import obs
from repro.compiler.tiling import Fleet
from repro.configs.base import MFTechniqueConfig, ModelConfig
from repro.configs.qwen3_0_6b import SMOKE
from repro.core.cim import CimConfig
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine

OUT_PATH = os.environ.get("BENCH_OBS_OUT", "BENCH_obs.json")
TRACE_PATH = os.environ.get("BENCH_OBS_TRACE_OUT", "BENCH_obs_trace.jsonl")


def _qwen_cfg():
    """qwen3 smoke proportions, every MF projection on cim_sim."""
    cim = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31)
    mf = MFTechniqueConfig(mode="cim_sim", cim=cim)
    return dataclasses.replace(SMOKE, dtype=jnp.float32, mf=mf)


def _greedy_tokens(engine: ServeEngine, prompt: list[int], n: int,
                   n_reqs: int) -> list[list[int]]:
    done = engine.run([Request(prompt=list(prompt), max_new_tokens=n)
                       for _ in range(n_reqs)])
    return [r.out for r in done]


# ---------------------------------------------------------------------------
# Section 1: tracing-disabled bitwise parity (pinned / swapped / silicon).
# ---------------------------------------------------------------------------

def _parity_section(params, cfg) -> dict:
    from repro.silicon.instance import SiliconConfig
    cim = cfg.mf.cim
    sigma0 = SiliconConfig(cap_sigma=0.0, comparator_sigma_v=0.0)
    swap_fleet = Fleet(n_macros=64, cfg=cim)
    # Size the pinned fleet off the swap schedule (same trick as
    # serve_bench): each macro carries several tile slots, so half the
    # tile count in macros still pins the whole model.
    probe = ServeEngine(params, cfg, slots=2, max_len=16,
                        fleet=swap_fleet, batched_prefill=False)
    assert not probe.schedule.pinned and probe.schedule.rounds_max > 1
    pin_fleet = Fleet(n_macros=-(-probe.schedule.total_tiles // 2), cfg=cim)

    def build(kind: str, tracing: bool) -> ServeEngine:
        # Interval 1: EVERY tick goes through the traced twin program,
        # the strongest form of the parity assertion.
        kw = dict(slots=2, max_len=16, batched_prefill=False,
                  tracing=tracing, trace_tick_interval=1)
        if kind == "pinned":
            return ServeEngine(params, cfg, fleet=pin_fleet, **kw)
        if kind == "swapped":
            return ServeEngine(params, cfg, fleet=swap_fleet, **kw)
        return ServeEngine(params, cfg, fleet=pin_fleet, silicon=sigma0,
                           **kw)

    out: dict = {}
    for kind in ("pinned", "swapped", "silicon"):
        if kind == "swapped":
            ref_eng = probe                   # reuse the sizing probe
        else:
            ref_eng = build(kind, tracing=False)
        assert obs.bus() is None
        ref = _greedy_tokens(ref_eng, [1, 2, 3], 4, 2)   # no bus at all
        with obs.tracing() as buf:
            host_only = _greedy_tokens(build(kind, False), [1, 2, 3], 4, 2)
            traced = _greedy_tokens(build(kind, True), [1, 2, 3], 4, 2)
            ticks = len(buf.by_kind("decode_tick"))
        assert host_only == ref, f"{kind}: bus install changed tokens"
        assert traced == ref, f"{kind}: in-jit emission changed tokens"
        assert ticks > 0, f"{kind}: traced engine emitted no decode_tick"
        out[kind] = {"bitwise_identical": True, "decode_ticks": ticks,
                     "host_events": buf.total}
    return out


# ---------------------------------------------------------------------------
# Section 2: tracing-enabled decode overhead (<= 5%).
# ---------------------------------------------------------------------------

def _overhead_section(params, cfg, quick: bool) -> dict:
    """Steady-state decode tok/s, untraced vs tracing at the DEFAULT
    sampling cadence. Each timed window spans several cadence periods so
    the traced-twin dispatches it pays for are inside the measurement,
    not between windows."""
    import inspect
    interval = inspect.signature(ServeEngine.__init__) \
        .parameters["trace_tick_interval"].default
    import numpy as np
    periods = 2 if quick else 4
    warmup, reps = 3, 3
    ticks = periods * interval
    max_len = reps * ticks + warmup + 4

    def window(eng):
        t0 = time.perf_counter()
        for _ in range(ticks):
            eng.step()
        jax.block_until_ready(eng.cache["pos"])
        return time.perf_counter() - t0

    with obs.tracing():
        plain = ServeEngine(params, cfg, slots=2, max_len=max_len)
        traced = ServeEngine(params, cfg, slots=2, max_len=max_len,
                             tracing=True)
        for eng in (plain, traced):
            for _ in range(eng.slots):
                eng.submit(Request(prompt=[1], max_new_tokens=1 << 30))
            for _ in range(warmup):
                eng.step()
        # Interleave the timed windows so host-level drift (cache
        # warmth, frequency scaling) hits both engines alike.
        t_plain, t_traced = [], []
        for _ in range(reps):
            t_plain.append(window(plain))
            t_traced.append(window(traced))
        plain_tok_s = plain.slots * ticks / float(np.min(t_plain))
        traced_tok_s = traced.slots * ticks / float(np.min(t_traced))
        n_ticks = len(obs.bus().by_kind("decode_tick"))
    assert n_ticks >= (warmup + reps * ticks) // interval, (
        f"traced run emitted {n_ticks} decode_ticks over "
        f"{warmup + reps * ticks} ticks at cadence {interval}")
    overhead = 1.0 - traced_tok_s / plain_tok_s
    assert overhead <= 0.05, (
        f"tracing overhead {overhead:.1%} > 5% "
        f"({traced_tok_s:.1f} vs {plain_tok_s:.1f} tok/s)")
    return {"untraced_tok_s": plain_tok_s, "traced_tok_s": traced_tok_s,
            "overhead_frac": overhead, "gate_5pct": overhead <= 0.05,
            "trace_tick_interval": interval, "ticks": ticks,
            "reps": reps, "decode_ticks_emitted": n_ticks}


# ---------------------------------------------------------------------------
# Section 3: drift story + heatmap + export round-trips.
# ---------------------------------------------------------------------------

def _drift_cfg():
    return ModelConfig(
        name="serve-tiny", family="lm", n_layers=2, d_model=32,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
        dtype=jnp.float32,
        mf=MFTechniqueConfig(mode="cim_sim",
                             cim=CimConfig(4, 4, 5, 31)))


def _drift_section() -> dict:
    """Aggressively drifting tiny fleet under a detail bus; every gate is
    evaluated on the RE-READ JSONL export, proving the on-disk artifact
    alone explains the maintenance incident."""
    from repro.calib.report import calibrate_lm
    from repro.data.synthetic import DataConfig, lm_batch
    from repro.silicon.drift import DriftPolicy
    from repro.silicon.instance import SiliconConfig

    cfg = _drift_cfg()
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    fleet = Fleet(n_macros=256, cfg=cfg.mf.cim)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=8,
                    global_batch=2, task="uniform")
    cal = [{"tokens": jnp.asarray(lm_batch(dc, i)["tokens"])}
           for i in range(2)]
    art = calibrate_lm(params, cfg, cal, method="amax")
    scfg = SiliconConfig(cap_sigma=0.02, comparator_sigma_v=0.008,
                         drift_sigma_v_per_kstream=8.0)
    pol = DriftPolicy(probe_batches=cal, check_interval=8,
                      silicon_update_interval=4,
                      rel_l2_alarm_ratio=1.2, rel_l2_alarm_floor=0.01)
    with obs.tracing(detail=True) as buf:
        eng = ServeEngine(params, cfg, slots=2, max_len=48, fleet=fleet,
                          batched_prefill=False, calibration=art,
                          silicon=scfg, drift=pol, tracing=True,
                          trace_tick_interval=1)
        eng.run([Request(prompt=[1, 2, 3], max_new_tokens=12)
                 for _ in range(2)])
        prom_text = obs.to_prometheus(eng.metrics)
        live = buf.events()
        assert buf.dropped == 0, "ring evicted events at smoke scale"

    # JSONL round-trip: the export IS the trace.
    n_written = obs.write_trace_jsonl(live, TRACE_PATH)
    events = obs.read_trace_jsonl(TRACE_PATH)
    assert [e.to_json() for e in events] == [e.to_json() for e in live]

    story = obs.drift_story(events)
    assert story.complete, (
        f"drift story incomplete from exported trace: alarm="
        f"{story.alarm_stream} recal={story.recal_stream} "
        f"retire={story.retire_stream}")
    timeline = obs.from_events(events)
    heat = obs.fleet_heatmap(timeline)
    assert heat["retired_now"] > 0 and heat["coarse_now"] > 0, heat
    assert timeline.residue_fs.size > 0, "detail bus shipped no residues"
    assert sum(timeline.recal_reload_bits) > 0
    assert sum(timeline.recal_energy_nj) > 0.0

    # Prometheus round-trip: parse back and compare against the live
    # registry, repr-exact for scalars, count-exact for histograms.
    parsed = obs.parse_prometheus(prom_text)
    for m in eng.metrics.metrics():
        if isinstance(m, (obs.Counter, obs.Gauge)):
            assert parsed[m.name]["value"] == float(m.value), m.name
        else:
            assert parsed[m.name]["count"] == float(sum(m.counts)), m.name
    drift_counters = eng.counters()
    assert drift_counters["drift_alarms"] >= 1
    assert drift_counters["recals"] >= 1

    return {
        "trace_path": TRACE_PATH,
        "events_exported": n_written,
        "event_kinds": sorted({e.kind for e in events}),
        "jsonl_roundtrip": True,
        "prometheus_roundtrip": True,
        "story": {
            "complete": story.complete,
            "alarm_stream": story.alarm_stream,
            "recal_stream": story.recal_stream,
            "retire_stream": story.retire_stream,
            "steps": story.steps,
        },
        "probes": [dataclasses.asdict(p) for p in timeline.probes],
        "recal_reload_bits": timeline.recal_reload_bits,
        "recal_energy_nj": timeline.recal_energy_nj,
        "heatmap": heat,
    }


def run(quick: bool = True):
    cfg = _qwen_cfg()
    params = T.lm_init(jax.random.PRNGKey(0), cfg)

    parity = _parity_section(params, cfg)
    overhead = _overhead_section(params, cfg, quick)
    drift = _drift_section()

    payload = {
        "bench": "obs_report",
        "config": cfg.name,
        "quick": quick,
        "parity": parity,
        "overhead": overhead,
        "drift": drift,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")

    story = drift["story"]
    rows = [(f"obs_parity_{kind}", 0.0,
             f"bitwise={p['bitwise_identical']} "
             f"decode_ticks={p['decode_ticks']}")
            for kind, p in parity.items()]
    rows += [
        ("obs_overhead", 1e6 / overhead["traced_tok_s"],
         f"traced={overhead['traced_tok_s']:.1f} "
         f"untraced={overhead['untraced_tok_s']:.1f} tok/s "
         f"overhead={overhead['overhead_frac']:.1%} gate<=5%"),
        ("obs_drift_story", 0.0,
         f"complete={story['complete']} alarm@{story['alarm_stream']} "
         f"recal@{story['recal_stream']} retire@{story['retire_stream']} "
         f"retired={drift['heatmap']['retired_now']} "
         f"coarse={drift['heatmap']['coarse_now']}"),
        ("obs_export_roundtrip", 0.0,
         f"events={drift['events_exported']} jsonl+prometheus exact "
         f"json={OUT_PATH} trace={TRACE_PATH}"),
    ]
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small qwen3 smoke shapes (CI)")
    args = ap.parse_args()
    for name, us, derived in run(quick=args.smoke):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
