"""Fig. 7: dynamic precision scaling — accuracy/latency/energy surfaces
over weight precision W_P and ADC precision A_P.

Latency/energy from Eq. 4; accuracy by evaluating an MF-trained LeNet
through the CIM bitplane+SA-ADC simulator at each (W_P, A_P) point —
including the paper's iso-accuracy Case-A (W_P=8, A_P=2) vs Case-B
(W_P=4, A_P=5) comparison.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import train_image_classifier
from repro.core.cim import CimConfig
from repro.core import energy as E
from repro.data.synthetic import image_batch
from repro.models import convnets as C


def _cim_accuracy(params, cim_cfg: CimConfig, batches: int = 2,
                  batch: int = 32) -> float:
    modes = {"conv1": "cim_sim", "conv2": "cim_sim", "fc1": "cim_sim",
             "fc2": "regular"}
    accs = []
    for j in range(batches):
        x, y = image_batch(batch, 10, 28, 1, 20_000 + j)
        logits = C.lenet_apply(params, jnp.asarray(x), modes, cim_cfg)
        accs.append(float(jnp.mean(jnp.argmax(logits, -1)
                                   == jnp.asarray(y))))
    return float(np.mean(accs))


def run(quick: bool = True):
    steps = 120 if quick else 600
    rows = []
    # train once in MF mode, then evaluate through the CIM sim
    modes = {"conv1": "mf", "conv2": "mf", "fc1": "mf", "fc2": "regular"}
    params = C.lenet_init(jax.random.PRNGKey(0))
    params, acc_mf, _ = train_image_classifier(
        params, lambda p, x: C.lenet_apply(p, x, modes), steps=steps,
        batch=32, n_classes=10, hw=28, channels=1)
    rows.append(("fig7_float_mf_acc", 0.0, f"{acc_mf:.4f}"))

    grid = [(8, 5), (8, 2), (4, 5), (4, 3), (2, 5), (2, 2)] if quick else \
        [(w, a) for w in (2, 3, 4, 6, 8) for a in (1, 2, 3, 4, 5)]
    for (wp, ap) in grid:
        cim = CimConfig(w_bits=wp, x_bits=8, adc_bits=ap, m_columns=31)
        t0 = time.perf_counter()
        acc = _cim_accuracy(params, cim, batches=1 if quick else 4)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig7_wp{wp}_ap{ap}", us,
                     f"acc={acc:.4f} T={E.unit_op_cycles(cim)}cyc "
                     f"E={E.unit_op_energy_j(cim) * 1e15:.0f}fJ"))

    # Hardware-in-the-loop QAT: the paper's low-A_P accuracies (e.g.
    # Case-A: 95% at W_P=8, A_P=2) are only reachable when the network is
    # tuned THROUGH the quantiser; `cim_mf_matmul_ste` provides exactly
    # that (CIM forward, MF surrogate backward). Fine-tune briefly at the
    # Case-A point and report the recovery.
    from benchmarks.common import train_image_classifier as _train
    case_a = CimConfig(8, 8, 2, 31)
    cmodes = {"conv1": "cim_sim", "conv2": "cim_sim", "fc1": "cim_sim",
              "fc2": "regular"}
    t0 = time.perf_counter()
    qat_params, _, _ = _train(
        params, lambda p, x: C.lenet_apply(p, x, cmodes, case_a),
        steps=40 if quick else 200, batch=16, n_classes=10, hw=28,
        channels=1, lr=5e-4)
    acc_qat = _cim_accuracy(qat_params, case_a, batches=1 if quick else 4)
    rows.append(("fig7_caseA_after_qat", (time.perf_counter() - t0) * 1e6,
                 f"acc={acc_qat:.4f} (pre-QAT collapses; paper ~0.95)"))

    # Case-A vs Case-B (Sec. V-C)
    ca = CimConfig(8, 8, 2, 31)
    cb = CimConfig(4, 8, 5, 31)
    rows.append(("fig7_caseA_vs_caseB_latency", 0.0,
                 f"{E.unit_op_cycles(ca)} vs {E.unit_op_cycles(cb)} cyc "
                 "(paper: A ~10% lower)"))
    rows.append(("fig7_caseA_vs_caseB_energy", 0.0,
                 f"{E.unit_op_energy_j(ca) * 1e15:.0f} vs "
                 f"{E.unit_op_energy_j(cb) * 1e15:.0f} fJ "
                 "(paper: A ~30% higher; not reproducible under Table II "
                 "calibration — see EXPERIMENTS.md)"))
    return rows
