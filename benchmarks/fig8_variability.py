"""Fig. 8: process variability — MAV crossover probability vs capacitor
mismatch and µArray size, column-screening mitigation, comparator
calibration residue.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.cim import CimConfig
from repro.silicon.variability import (VariabilityConfig, calibrated_offset,
                                       mav_crossover_probability)


def run(quick: bool = True):
    trials = 300 if quick else 3000
    rows = []
    key = jax.random.PRNGKey(0)
    for m in (31, 15):
        for sigma in (0.02, 0.06, 0.12):
            cim = CimConfig(8, 8, 5, m)
            var = VariabilityConfig(cap_sigma=sigma)
            t0 = time.perf_counter()
            pf = float(mav_crossover_probability(key, cim, var,
                                                 n_trials=trials))
            us = (time.perf_counter() - t0) * 1e6
            rows.append((f"fig8d_pf_m{m}_sigma{int(sigma * 100)}pct", us,
                         f"{pf:.4f}"))

    # screening mitigation at the paper's +-12% point, ~3% discarded
    cim = CimConfig(8, 8, 5, 31)
    var = VariabilityConfig(cap_sigma=0.12, screen_fraction=0.03)
    p_raw = float(mav_crossover_probability(key, cim, var, n_trials=trials))
    p_scr = float(mav_crossover_probability(key, cim, var, n_trials=trials,
                                            screened=True))
    rows.append(("fig8d_screening_12pct", 0.0,
                 f"raw={p_raw:.4f} screened={p_scr:.4f} "
                 f"(discard 3% of columns)"))

    # comparator 2-bit tail calibration: +-45 mV -> ~+-12-15 mV residue
    offs = 0.045 * jnp.linspace(-1, 1, 81)
    res = jax.vmap(lambda o: calibrated_offset(o, VariabilityConfig()))(offs)
    rows.append(("fig8e_comparator_cal", 0.0,
                 f"max_residue={float(jnp.max(jnp.abs(res))) * 1e3:.1f}mV "
                 "(paper: 45 -> 12 mV)"))
    return rows
