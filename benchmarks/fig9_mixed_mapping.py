"""Fig. 9: synergistic digital + CIM mapping — layer-wise params/ops
distribution, mapping assignment, and projected system-level TOPS/W.

Paper mixed-config projections: MNIST 103.97, CIFAR10 100.91,
CIFAR100 98 TOPS/W (digital fabric at 2.8 TOPS/W, CIM at 105).
"""

from __future__ import annotations

from repro.core.cim import CimConfig
from repro.core.energy import (mixed_system_tops_per_watt,
                               mixed_system_tops_per_watt_energy)
from repro.core.mapping import MappingPolicy, plan_mapping
from repro.models.convnets import cifar_layer_stats, lenet_layer_stats


def _project(stats, overrides, name, rows, paper_val):
    policy = MappingPolicy(threshold=2.0, overrides=overrides)
    rep = plan_mapping(stats, policy)
    mf_ops, dig_ops = rep.ops_split()
    cim = CimConfig(8, 8, 5, 31)
    eff = mixed_system_tops_per_watt(mf_ops, dig_ops, cim)
    eff_e = mixed_system_tops_per_watt_energy(mf_ops, dig_ops, cim)
    rows.append((f"fig9_{name}_mf_ops_frac", 0.0,
                 f"{rep.mf_ops_fraction:.3f} (paper >0.85)"))
    rows.append((f"fig9_{name}_mf_param_frac", 0.0,
                 f"{rep.mf_param_fraction:.3f}"))
    rows.append((f"fig9_{name}_avg_tops_w", 0.0,
                 f"{eff:.2f} (paper {paper_val}; ops-weighted convention)"))
    rows.append((f"fig9_{name}_energy_correct_tops_w", 0.0,
                 f"{eff_e:.2f} (harmonic mean — see EXPERIMENTS.md note)"))
    for s in rep.stats:
        rows.append((f"fig9_{name}_layer_{s.name}", 0.0,
                     f"params={s.params} ops={s.ops} "
                     f"ops/param={s.ops_per_param:.1f} "
                     f"-> {rep.assignments[s.name].value}"))


def run(quick: bool = True):
    rows = []
    # MNIST (paper Fig. 9a): conv1, conv2, fc1 MF; fc2 classifier digital
    _project(lenet_layer_stats(), {"fc1": "mf"}, "mnist", rows, 103.97)
    # CIFAR10 (Fig. 9b): convs MF; both FCs digital
    _project(cifar_layer_stats(), {"fc1": "regular"}, "cifar10", rows,
             100.91)
    # CIFAR100 / MobileNetV2 (Fig. 9c): paper's table, relative op shares
    mb_ops = {"conv3x3_in": (0.008, 3.9), "bn1": (0.008, 8.2),
              "bn2": (0.008, 21.0), "bn3": (0.01, 16.7), "bn4": (0.032, 10.0),
              "bn5": (0.08, 13.7), "bn6": (0.19, 16.8), "bn7": (0.19, 8.3),
              "conv3x3_out": (0.17, 0.9), "fc1": (0.28, 0.9),
              "fc2_classifier": (0.008, 0.5)}
    total_ops = 1e9
    mf_share = sum(o for name, (p, o) in mb_ops.items()
                   if name.startswith("bn")) / 100.0
    cim = CimConfig(8, 8, 5, 31)
    eff = mixed_system_tops_per_watt(mf_share * total_ops,
                                     (1 - mf_share) * total_ops, cim)
    eff_e = mixed_system_tops_per_watt_energy(
        mf_share * total_ops, (1 - mf_share) * total_ops, cim)
    rows.append(("fig9_cifar100_mf_ops_frac", 0.0,
                 f"{mf_share:.3f} (bottlenecks MF)"))
    rows.append(("fig9_cifar100_avg_tops_w", 0.0,
                 f"{eff:.2f} (paper 98; ops-weighted convention)"))
    rows.append(("fig9_cifar100_energy_correct_tops_w", 0.0,
                 f"{eff_e:.2f}"))
    return rows
