"""Aggregate dry-run artifacts into the §Roofline table.

Reads artifacts/dryrun/*.json and emits one row per (arch x shape x mesh)
with the three roofline terms, the dominant bottleneck, MODEL_FLOPS
ratio, and per-device memory. Also writes artifacts/roofline.md for
EXPERIMENTS.md.
"""

from __future__ import annotations

import glob
import json
import os

ART_DIR = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def load_records(art_dir: str = ART_DIR) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def markdown_table(recs: list[dict]) -> str:
    lines = ["| arch | shape | mesh | compute s | memory s | coll s | "
             "dominant | useful ratio | peak GB/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("status") != "ok" or r.get("tag"):
            continue
        rf = r["roofline"]
        ma = r.get("memory_analysis") or {}
        peak = ma.get("peak_memory_in_bytes", 0) if isinstance(ma, dict) \
            else 0
        ratio = (r["model_flops_per_chip"] / rf["flops"]
                 if rf["flops"] else float("nan"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | {rf['dominant']} "
            f"| {ratio:.3f} | {peak / 1e9:.2f} |")
    return "\n".join(lines)


def run(quick: bool = True):
    recs = load_records()
    rows = []
    ok = [r for r in recs if r.get("status") == "ok" and not r.get("tag")]
    err = [r for r in recs if r.get("status") != "ok"]
    rows.append(("roofline_cells_ok", 0.0, str(len(ok))))
    rows.append(("roofline_cells_error", 0.0, str(len(err))))
    for r in ok:
        rf = r["roofline"]
        rows.append((
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}", 0.0,
            f"dom={rf['dominant']} "
            f"bound={max(rf['compute_s'], rf['memory_s'], rf['collective_s']):.4f}s "
            f"c/m/x={rf['compute_s']:.3f}/{rf['memory_s']:.3f}/"
            f"{rf['collective_s']:.3f}"))
    md = markdown_table(recs)
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/roofline.md", "w") as f:
        f.write(md + "\n")
    return rows
