"""Calibration accuracy report: does data-driven `sx` beat full-scale?

For each evaluation model (the qwen3 smoke LM with every projection on
``cim_sim``, and the paper's LeNet-5 conv net) this suite:

  1. collects per-projection activation statistics over a synthetic
     calibration corpus (one observe pass, float MF reference forward),
  2. programs the model four ways — static full-scale ``act_amax=4.0``
     (the PR 2 default) and the three corpus-driven policies (amax /
     percentile / MSE-optimal) — at both paper ADC design points
     (8x62 -> 5-bit, 8x30 -> 4-bit, exactly lossless) AND two non-lossless
     points where real ADC quantisation error is in play: A_P=6 at M=31
     (moderate rounding noise, gated) and A_P=4 at M=31 (noise-dominated,
     reported as a diagnostic only — see ``UNGATED_DESIGNS``), plus the
     macro zoo's collaborative re-budgeted geometries (``MACRO_DESIGNS``,
     ungated — the ADC-starved regime per-channel calibration targets),
  3. measures each against the fp32 MF reference on held-out batches:
     end-to-end logits error (relative L2), top-1 agreement, and
     per-projection SQNR through the error tap,
  4. checks the acceptance gate — the best calibrated policy must beat
     the static baseline on logits error AND mean SQNR for every
     (model, design) cell — and that programming the static default
     *through the scales hook* reproduces the baseline bit for bit.

Emits ``BENCH_calib.json`` (the calibration-quality trajectory anchor)
and the ``benchmarks/run.py`` CSV rows.

CLI: ``PYTHONPATH=src python -m benchmarks.calib_report [--smoke]``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.calib.corpus import (attach_observer_ids, collect_stats,
                                scales_from_stats)
from repro.calib.observers import ObserverConfig
from repro.calib.report import accuracy_report, lm_ref_config
from repro.configs.base import MFTechniqueConfig
from repro.configs.qwen3_0_6b import SMOKE
from repro.core.cim import CimConfig
from repro.core.programmed import (DEFAULT_ACT_AMAX, adc_exactly_lossless,
                                   default_static_sx, program_weights)
from repro.data.synthetic import DataConfig, image_batch, lm_batch
from repro.models import convnets as C
from repro.models import transformer as T

OUT_PATH = os.environ.get("BENCH_CALIB_OUT", "BENCH_calib.json")

# (m_columns, adc_bits) design points: the two paper pairings are exactly
# lossless (2^A_P - 1 == M: ADC code == discharge count), so their cells
# coincide by the lossless identity and never exercise real ADC
# quantisation. The third point (A_P=6 at M=31) is deliberately NOT
# lossless — 63 ADC levels digitising 31-column charge averages round
# every non-trivial count — so calibration there interacts with genuine
# ADC quantisation error (SQNR drops ~14 dB vs the lossless points) and
# the calibrated-beats-static gate covers it.
DESIGNS = ((31, 5), (15, 4), (31, 6))
# Diagnostic-only design points, reported but NOT gated: A_P=4 at M=31
# (the severely under-provisioned ADC) is so lossy that outputs are
# rounding-noise dominated (rel_l2 > 1, SQNR ~3-5 dB) — no activation
# scale policy reliably beats another inside pure ADC noise, which is
# itself a finding worth keeping on the record.
UNGATED_DESIGNS = ((31, 4),)
# Macro-zoo design points (also ungated): the collaborative-digitization
# re-budget trades shared ADC area for µArray columns at fixed macro
# area (repro.macros.fleet_for_macro), opening WIDER halves than any
# 2^A_P - 1 pairing — 38x5 is the ADC-starved regime (31 levels
# digitising 38-column averages) where the per-channel input-DAC trims
# are expected to earn their keep, 38x6 the moderately-rounded one.
# Computed through the same feasible_columns the compiler uses, so these
# cells track the zoo's geometry by construction.


def _macro_design_points() -> tuple[tuple[int, int], ...]:
    from repro.macros import (CollaborativeDigitization, feasible_columns,
                              reference_budget_units)
    budget = reference_budget_units(CimConfig())
    return tuple(
        (feasible_columns(CollaborativeDigitization(group_size=g), a,
                          budget_units=budget), a)
        for g, a in ((4, 5), (4, 6)))


MACRO_DESIGNS = _macro_design_points()
METHODS = ("static", "amax", "percentile", "mse")
# Per-channel variants: the scalar policy's scale shaped over each
# projection's per-feature amax profile (input-DAC gain trims; see
# repro.calib.corpus.scales_from_stats(per_channel=True)). Reported as
# an SQNR delta against the matching scalar cell, not gated, because the
# sign flips with the design point: at the exactly-lossless pairings
# (31x5, 15x4) the gain-weighted charge averages break the code==count
# identity — every S2/R_x conversion picks up real ADC rounding and the
# delta is tens of dB NEGATIVE — while at rounding-limited ADCs (31x6,
# 31x4) the finer per-channel input grids win a few dB. Per-channel
# calibration is an under-provisioned-ADC tool, not a free win.
PC_METHODS = ("amax", "mse")


@dataclasses.dataclass
class _Setup:
    """One evaluation model: forwards + corpus, design-point agnostic."""

    name: str
    params: dict
    ref_forward: callable            # (params, batch) -> logits, float MF
    cim_forward_builder: callable    # CimConfig -> (params, batch) -> logits
    cal_batches: list
    eval_batches: list


def _lm_setup(quick: bool) -> _Setup:
    base = SMOKE if quick else dataclasses.replace(
        SMOKE, d_model=128, d_ff=384, vocab_size=512)
    cfg = dataclasses.replace(
        base, dtype=jnp.float32,
        mf=MFTechniqueConfig(mode="cim_sim", cim=CimConfig()))
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    b, t = (4, 16) if quick else (8, 32)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=t, global_batch=b,
                    task="uniform")
    n_cal, n_eval = (4, 2) if quick else (6, 3)
    cal = [{"tokens": jnp.asarray(lm_batch(dc, i)["tokens"])}
           for i in range(n_cal)]
    ev = [{"tokens": jnp.asarray(lm_batch(dc, 1000 + i)["tokens"])}
          for i in range(n_eval)]

    def ref_forward(p, batch):
        return T.lm_forward(p, batch, lm_ref_config(cfg))[0]

    def cim_builder(cim: CimConfig):
        ccfg = dataclasses.replace(
            cfg, mf=dataclasses.replace(cfg.mf, cim=cim))

        def fwd(p, batch):
            return T.lm_forward(p, batch, ccfg)[0]

        return fwd

    return _Setup(cfg.name, params, ref_forward, cim_builder, cal, ev)


_LENET_REF = {"conv1": "mf", "conv2": "mf", "fc1": "mf", "fc2": "regular"}
_LENET_CIM = {"conv1": "cim_sim", "conv2": "cim_sim", "fc1": "cim_sim",
              "fc2": "regular"}


def _lenet_setup(quick: bool) -> _Setup:
    params = C.lenet_init(jax.random.PRNGKey(0))
    batch = 16 if quick else 32
    n_cal, n_eval = (4, 2) if quick else (6, 3)
    cal = [jnp.asarray(image_batch(batch, 10, 28, 1, i)[0])
           for i in range(n_cal)]
    ev = [jnp.asarray(image_batch(batch, 10, 28, 1, 1000 + i)[0])
          for i in range(n_eval)]

    def ref_forward(p, x):
        return C.lenet_apply(p, x, _LENET_REF)

    def cim_builder(cim: CimConfig):
        def fwd(p, x):
            return C.lenet_apply(p, x, _LENET_CIM, cim_cfg=cim)

        return fwd

    return _Setup("paper-mnist-lenet5", params, ref_forward, cim_builder,
                  cal, ev)


def _static_scales_map(registry, cim: CimConfig) -> dict:
    """Every projection pinned to the full-scale default — must reproduce
    the no-scales baseline bit for bit (the parity gate)."""
    sx = np.float32(default_static_sx(cim))
    return {name: np.full(shape or (), sx, np.float32)
            for name, (_, shape) in registry.entries.items()}


def run(quick: bool = True):
    rows = []
    payload = {
        "bench": "calib_accuracy",
        "quick": quick,
        "act_amax_static": DEFAULT_ACT_AMAX,
        "methods": list(METHODS),
        "per_channel_methods": [f"{m}_pc" for m in PC_METHODS],
        "designs": [f"{m}x{a}" for m, a in DESIGNS],
        "ungated_designs": [f"{m}x{a}" for m, a in UNGATED_DESIGNS],
        "macro_designs": [f"{m}x{a}" for m, a in MACRO_DESIGNS],
        "configs": {},
    }
    obs_cfg = ObserverConfig()
    all_improved = True
    for setup in (_lm_setup(quick), _lenet_setup(quick)):
        tagged, registry = attach_observer_ids(setup.params)
        t0 = time.time()
        collector = collect_stats(setup.ref_forward, tagged,
                                  setup.cal_batches, registry, obs_cfg)
        collect_us = (time.time() - t0) * 1e6
        rows.append((f"calib_collect_{setup.name}", collect_us,
                     f"projections={registry.n_ids}"))
        per_design = {}
        for m, a in DESIGNS + UNGATED_DESIGNS + MACRO_DESIGNS:
            gated = (m, a) in DESIGNS
            cim = CimConfig(w_bits=8, x_bits=8, adc_bits=a, m_columns=m)
            cim_fwd = setup.cim_forward_builder(cim)
            cells = {}
            for method in (METHODS
                           + tuple(f"{m}_pc" for m in PC_METHODS)):
                if method == "static":
                    scales = None
                else:
                    base = method.removesuffix("_pc")
                    scales = scales_from_stats(
                        collector, registry, cim.x_bits, base,
                        per_channel=method.endswith("_pc"))
                progd = program_weights(tagged, cim, scales=scales)
                t0 = time.time()
                rep = accuracy_report(
                    lambda b: setup.ref_forward(setup.params, b),
                    lambda b: cim_fwd(progd, b),
                    setup.eval_batches, registry)
                cells[method] = rep.to_dict()
                rows.append((
                    f"calib_{setup.name}_{m}x{a}_{method}",
                    (time.time() - t0) * 1e6,
                    f"rel_l2={rep.rel_l2:.5f} "
                    f"sqnr={rep.mean_sqnr_db:.2f}dB "
                    f"top1={rep.top1_agree:.3f}"))
            static = cells["static"]
            best = min((cells[meth] for meth in METHODS[1:]),
                       key=lambda c: c["rel_l2"])
            improved = (best["rel_l2"] < static["rel_l2"]
                        and best["mean_sqnr_db"] > static["mean_sqnr_db"])
            if gated:
                all_improved = all_improved and improved
            # Parity gate: the static default programmed THROUGH the
            # scales hook is the identical computation.
            prog_a = program_weights(tagged, cim)
            prog_b = program_weights(tagged, cim,
                                     scales=_static_scales_map(registry,
                                                               cim))
            batch0 = setup.eval_batches[0]
            parity = bool(np.array_equal(
                np.asarray(setup.cim_forward_builder(cim)(prog_a, batch0)),
                np.asarray(setup.cim_forward_builder(cim)(prog_b, batch0))))
            pc_delta = {
                meth: (cells[f"{meth}_pc"]["mean_sqnr_db"]
                       - cells[meth]["mean_sqnr_db"])
                for meth in PC_METHODS}
            rows.append((
                f"calib_{setup.name}_{m}x{a}_pc_delta", 0.0,
                " ".join(f"{meth}={d:+.2f}dB"
                         for meth, d in pc_delta.items())))
            per_design[f"{m}x{a}"] = {
                "cells": cells,
                "adc_exactly_lossless": adc_exactly_lossless(cim),
                "gated": gated,
                "macro_zoo": (m, a) in MACRO_DESIGNS,
                "calibrated_beats_static": improved,
                "per_channel_sqnr_delta_db": pc_delta,
                "static_scales_parity": parity,
            }
            if not parity:
                raise RuntimeError(
                    f"{setup.name} {m}x{a}: static scales through the "
                    f"scales hook broke bit-exact parity")
        payload["configs"][setup.name] = per_design
    payload["calibrated_beats_static_everywhere"] = all_improved

    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")

    rows.append(("calib_gate", 0.0,
                 f"calibrated_beats_static={all_improved} json={OUT_PATH}"))
    if not all_improved:
        raise RuntimeError(
            "calibrated scales did not beat the static full-scale baseline "
            f"on every (model, design) cell — see {OUT_PATH}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes (CI)")
    args = ap.parse_args()
    for name, us, derived in run(quick=args.smoke):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
