"""Macro-compiler report: lower models onto CIM fleets and roll up cost.

For each (model, fleet) pair: per-layer schedule rows (tiles, rounds, unit
ops, latency, energy, TOPS/W, utilization) plus the end-to-end roll-up,
and a bit-exactness check of the tiled executor against the monolithic
behavioural simulator on a real projection.
"""

from __future__ import annotations

import jax

from benchmarks.common import timed
from repro.compiler import (Fleet, benchmark_rows, compile_model,
                            lm_layer_stats, model_cost, plan_tiling,
                            verify_bit_exact)
from repro.configs.registry import get_config
from repro.core.cim import CimConfig
from repro.models.convnets import cifar_layer_stats, lenet_layer_stats

CFG_8X62 = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31)
CFG_8X30 = CimConfig(w_bits=8, x_bits=8, adc_bits=4, m_columns=15)


def _compile_rows(name: str, stats, fleet: Fleet, rows) -> None:
    (msched, us) = timed(compile_model, stats, fleet)
    costs, total = model_cost(msched)
    rows.append((f"compiler_{name}_compile", us,
                 f"layers={len(msched.layers)} digital={len(msched.digital)} "
                 f"pinned={msched.pinned}"))
    rows.extend(benchmark_rows(f"compiler_{name}", msched, costs, total))


def run(quick: bool = True):
    rows = []

    # the paper's own nets on small fleets (both Table II design points)
    _compile_rows("lenet_8x62x32", lenet_layer_stats(),
                  Fleet(n_macros=32, cfg=CFG_8X62), rows)
    _compile_rows("cifar_8x62x512", cifar_layer_stats(),
                  Fleet(n_macros=512, cfg=CFG_8X62), rows)
    _compile_rows("cifar_8x30x512_swap", cifar_layer_stats(),
                  Fleet(n_macros=512, cfg=CFG_8X30,
                        weight_stationary=False), rows)

    # registry LM configs, weight-swapped fleets (decoder blocks never pin)
    tokens = 64 if quick else 1024
    for arch, n_macros in (("qwen3-0.6b", 4096),
                           ("starcoder2-7b", 16384)):
        cfg = get_config(arch, smoke=quick)
        stats = lm_layer_stats(cfg, tokens=tokens,
                               unique_blocks=not quick)
        _compile_rows(f"{arch}_{n_macros}m", stats,
                      Fleet(n_macros=n_macros, cfg=CFG_8X62,
                            weight_stationary=False), rows)

    # tiled-executor bit-exactness on a real-sized projection
    key = jax.random.PRNGKey(0)
    k, n = (70, 9) if quick else (301, 130)
    x = jax.random.normal(key, (4, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    for cfg in (CFG_8X62, CFG_8X30):
        plan = plan_tiling(k, n, cfg, tile_k_chunks=2, tile_n=8)
        (ok, us) = timed(verify_bit_exact, x, w, plan, cfg)
        rows.append((f"compiler_bitexact_{2 * cfg.m_columns}cols_k{k}", us,
                     f"exact={ok} tiles={plan.n_tiles} "
                     f"waste={plan.waste_fraction:.3f}"))
    return rows
