"""Traffic benchmark: throughput / latency / SLO curves under offered load.

Three sections on the qwen3 smoke config with every MF projection mapped
to ``cim_sim`` and served from a pinned fleet:

  * **offered-load sweep** — the per-tick cost of the jitted decode step
    (and of one batched-prefill wave) is measured on the live engine and
    used to calibrate a :class:`~repro.traffic.batching.VirtualClock`;
    the same keyed workload is then replayed at >= 4 offered-load
    fractions of the estimated capacity. Each point emits a full
    :class:`~repro.traffic.report.TrafficReport` (p50/p99/p999 latency,
    TTFT, tok/s, SLO attainment, queue depth, per-wave Eq. 4 energy).
    Gate: SLO attainment >= 0.99 at every point below the knee.
  * **mesh parity** — a single-device serve mesh
    (:func:`repro.traffic.shard.shard_engine`) must decode bitwise
    identically to the unsharded engine. Gate: hard assert.
  * **multi-device scaling** — a subprocess forces
    ``--xla_force_host_platform_device_count`` host devices and measures
    steady-state aggregate decode tok/s on a data-parallel serve mesh vs
    the single-device engine. Gate: >= 1.5x, asserted ONLY when the host
    actually has >= 2 cores (XLA's forced host devices share one thread
    pool per core; on a 1-core machine the gate is recorded as vacuous
    with ``host_parallel_capable: false``). CI exports
    ``BENCH_TRAFFIC_REQUIRE_MULTIDEV=1``, which turns the vacuous
    fallback into a hard failure — on the 4-vCPU runners the >= 1.5x
    gate must actually be measured and asserted.

Emits ``BENCH_traffic.json`` and the ``benchmarks/run.py`` CSV rows.

CLI: ``PYTHONPATH=src python -m benchmarks.traffic_report [--smoke]``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compiler.tiling import Fleet
from repro.configs.base import MFTechniqueConfig
from repro.configs.qwen3_0_6b import SMOKE
from repro.core.cim import CimConfig
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine
from repro.traffic import (ContinuousBatcher, VirtualClock, WallClock,
                           WorkloadConfig, generate, shard_engine)
from repro.traffic.report import from_run
from repro.launch.mesh import make_serve_mesh

OUT_PATH = os.environ.get("BENCH_TRAFFIC_OUT", "BENCH_traffic.json")

LOAD_FRACTIONS = (0.25, 0.5, 0.75, 1.0, 1.5, 2.0)
KNEE_SLO = 0.99


def _traffic_cfg(quick: bool):
    cim = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31)
    mf = MFTechniqueConfig(mode="cim_sim", cim=cim)
    base = SMOKE if quick else dataclasses.replace(
        SMOKE, d_model=256, d_ff=768, head_dim=64, vocab_size=2048)
    return dataclasses.replace(base, dtype=jnp.float32, mf=mf)


def _measure_tick_s(engine: ServeEngine, ticks: int = 8,
                    reps: int = 3) -> float:
    """Median wall cost of one full-batch jitted decode step."""
    for _ in range(engine.slots):
        engine.submit(Request(prompt=[1], max_new_tokens=1 << 30))
    for _ in range(3):
        engine.step()                               # warmup / compile
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(ticks):
            engine.step()
        jax.block_until_ready(engine.cache["pos"])
        times.append((time.perf_counter() - t0) / ticks)
    for slot in list(engine.occupied_slots):
        engine.evict(slot)
    return float(np.median(times))


def _measure_prefill_s(engine: ServeEngine, prompt_len: int,
                       reps: int = 3) -> float:
    """Median wall cost of one batched-prefill admission wave."""
    times = []
    for _ in range(reps + 1):                        # first rep compiles
        reqs = [Request(prompt=list(range(1, prompt_len + 1)),
                        max_new_tokens=1 << 30)
                for _ in range(engine.slots)]
        t0 = time.perf_counter()
        engine.submit_many(reqs)
        jax.block_until_ready(engine.cache["pos"])
        times.append(time.perf_counter() - t0)
        for slot in list(engine.occupied_slots):
            engine.evict(slot)
    return float(np.median(times[1:]))


def _sweep_point(engine, workload_cfg, tick_s, prefill_s, max_ticks):
    reqs = generate(workload_cfg)
    clock = VirtualClock(tick_s, prefill_s=prefill_s)
    bat = ContinuousBatcher(engine, clock=clock)
    log = bat.run(reqs, max_ticks=max_ticks)
    return from_run(log, engine)


def _run_sweep(engine, quick, tick_s, prefill_s):
    slots = engine.slots
    mean_new = 6.0
    # Each occupied slot emits one token per tick, so the fleet completes
    # ~slots/mean_new requests per tick at full occupancy.
    capacity_rps = slots / (mean_new * tick_s)
    ttft_slo = prefill_s + 50.0 * tick_s
    tpot_slo = 3.0 * tick_s
    n_requests = 24 if quick else 64
    points = []
    for frac in LOAD_FRACTIONS:
        wcfg = WorkloadConfig(
            rate_rps=frac * capacity_rps, n_requests=n_requests,
            process="poisson", prompt_len_min=2, prompt_len_max=6,
            decode_len_min=4, decode_len_max=8,
            vocab_size=engine.cfg.vocab_size,
            ttft_slo_s=ttft_slo, tpot_slo_s=tpot_slo, seed=11)
        rep = _sweep_point(engine, wcfg, tick_s, prefill_s,
                           max_ticks=50_000)
        assert not rep.out_of_ticks
        points.append((frac, rep))
    return capacity_rps, points


def _wallclock_smoke(engine, tick_s, prefill_s, quick) -> dict:
    """One LIVE run next to the virtual-clock sweeps: same workload
    machinery on a :class:`WallClock` (arrivals in real perf_counter
    time, idle gaps actually slept). Wall timing is machine-dependent,
    so the gate is completion-shaped — every offered request reaches a
    terminal state and tokens flowed — while latency/SLO numbers are
    recorded for the trajectory, not asserted."""
    capacity_rps = engine.slots / (6.0 * tick_s)
    n_requests = 8 if quick else 16
    wcfg = WorkloadConfig(
        rate_rps=0.5 * capacity_rps, n_requests=n_requests,
        process="poisson", prompt_len_min=2, prompt_len_max=6,
        decode_len_min=4, decode_len_max=8,
        vocab_size=engine.cfg.vocab_size,
        ttft_slo_s=prefill_s + 50.0 * tick_s, tpot_slo_s=3.0 * tick_s,
        seed=13)
    reqs = generate(wcfg)
    bat = ContinuousBatcher(engine, clock=WallClock())
    log = bat.run(reqs, max_ticks=50_000)
    rep = from_run(log, engine)
    assert not log.out_of_ticks
    assert rep.completed + rep.rejected + rep.evicted == n_requests
    assert rep.completed > 0 and rep.decode_tokens > 0
    return dict(offered_frac=0.5, clock="wall", **rep.to_json())


def _mesh_parity(params, cfg, fleet):
    """Single-device serve mesh vs unsharded engine: bitwise tokens."""
    outs, info = [], None
    for shard in (False, True):
        eng = ServeEngine(params, cfg, slots=2, max_len=32, fleet=fleet)
        if shard:
            info = shard_engine(eng, make_serve_mesh(
                data=1, fleet=1, devices=jax.devices()[:1]))
        done = eng.run([Request(prompt=[1 + i, 2 + i, 3 + i],
                                max_new_tokens=6) for i in range(4)])
        outs.append([r.out for r in done])
    return outs[0] == outs[1], info


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os, json, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    import dataclasses
    from repro.compiler.tiling import Fleet
    from repro.configs.base import MFTechniqueConfig
    from repro.configs.qwen3_0_6b import SMOKE
    from repro.core.cim import CimConfig
    from repro.launch.mesh import make_serve_mesh
    from repro.models import transformer as T
    from repro.serve.engine import Request, ServeEngine
    from repro.traffic import shard_engine

    cim = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31)
    cfg = dataclasses.replace(
        SMOKE, dtype=jnp.float32,
        mf=MFTechniqueConfig(mode="cim_sim", cim=cim))
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    fleet = Fleet(n_macros=4096, cfg=cim)
    slots, ticks = 8, int(sys.argv[1])

    def tok_s(eng):
        for _ in range(eng.slots):
            eng.submit(Request(prompt=[1], max_new_tokens=1 << 30))
        for _ in range(3):
            eng.step()
        times = []
        for _ in range(3):
            t0 = time.perf_counter()
            for _ in range(ticks):
                eng.step()
            jax.block_until_ready(eng.cache["pos"])
            times.append(time.perf_counter() - t0)
        return eng.slots * ticks / float(np.median(times))

    single = ServeEngine(params, cfg, slots=slots, max_len=128,
                         fleet=fleet)
    t_single = tok_s(single)
    meshed = ServeEngine(params, cfg, slots=slots, max_len=128,
                         fleet=fleet)
    info = shard_engine(meshed, make_serve_mesh(data=4, fleet=1))
    t_mesh = tok_s(meshed)
    print("MULTIDEV_RESULT " + json.dumps({
        "devices": jax.device_count(), "slots": slots, "ticks": ticks,
        "single_tok_s": t_single, "mesh_tok_s": t_mesh,
        "speedup": t_mesh / t_single, "shard_info": info}))
""")


def _multidevice_scaling(quick: bool) -> dict:
    cpu_count = len(os.sched_getaffinity(0)) if hasattr(
        os, "sched_getaffinity") else (os.cpu_count() or 1)
    capable = cpu_count >= 2
    # CI runs on multi-vCPU hosts and exports this to FORBID the vacuous
    # fallback: a single-core runner there means the gate silently
    # stopped measuring anything, which should fail loudly instead.
    if os.environ.get("BENCH_TRAFFIC_REQUIRE_MULTIDEV") == "1" \
            and not capable:
        raise RuntimeError(
            f"BENCH_TRAFFIC_REQUIRE_MULTIDEV=1 but this host exposes only "
            f"{cpu_count} core(s) — the >=1.5x multi-device gate would be "
            f"vacuous")
    ticks = 8 if quick else 24
    r = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT, str(ticks)],
        capture_output=True, text=True, timeout=1200,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    line = next((ln for ln in r.stdout.splitlines()
                 if ln.startswith("MULTIDEV_RESULT ")), None)
    assert line is not None, r.stdout + r.stderr
    result = json.loads(line[len("MULTIDEV_RESULT "):])
    result["cpu_count"] = cpu_count
    result["host_parallel_capable"] = capable
    if capable:
        # The acceptance gate: a 4-device data-parallel serve mesh must
        # deliver >= 1.5x aggregate decode tok/s at saturating load.
        assert result["speedup"] >= 1.5, (
            f"multi-device mesh speedup {result['speedup']:.2f}x < 1.5x "
            f"on a {cpu_count}-core host")
        result["gate_1_5x"] = True
    else:
        # One core: XLA's forced host devices time-slice a single thread
        # pool, so parallel speedup is physically unobtainable — record
        # the measurement and mark the gate vacuous for this host.
        result["gate_1_5x"] = "vacuous_single_core_host"
    return result


def run(quick: bool = True):
    cfg = _traffic_cfg(quick)
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    cim = cfg.mf.cim
    fleet = Fleet(n_macros=4096, cfg=cim)
    slots = 4
    engine = ServeEngine(params, cfg, slots=slots, max_len=64,
                         fleet=fleet)
    assert engine.schedule is not None and engine.schedule.pinned

    tick_s = _measure_tick_s(engine)
    prefill_s = _measure_prefill_s(engine, prompt_len=6)
    capacity_rps, points = _run_sweep(engine, quick, tick_s, prefill_s)

    # Knee: the highest offered load still meeting the SLO bar. Gate:
    # every point below it (and at least the lowest point) attains it.
    attain = [(frac, rep.slo_attainment) for frac, rep in points]
    knee_frac = max((f for f, a in attain if a >= KNEE_SLO), default=0.0)
    assert len(points) >= 4, "sweep must cover >= 4 offered-load points"
    assert knee_frac > 0.0, f"no load point attained SLO: {attain}"
    below_knee = [(f, a) for f, a in attain if f <= knee_frac]
    assert all(a >= KNEE_SLO for _, a in below_knee), (
        f"SLO attainment dipped below {KNEE_SLO} below the knee: {attain}")

    wall = _wallclock_smoke(engine, tick_s, prefill_s, quick)

    parity, shard_info = _mesh_parity(params, cfg, fleet)
    assert parity, "single-device mesh decode diverged from unsharded"

    multidev = _multidevice_scaling(quick)

    payload = {
        "bench": "traffic_serving",
        "config": cfg.name,
        "quick": quick,
        "slots": slots,
        "tick_s": tick_s,
        "prefill_s": prefill_s,
        "capacity_rps_est": capacity_rps,
        "knee_offered_frac": knee_frac,
        "knee_rps": knee_frac * capacity_rps,
        "gate_slo_below_knee": KNEE_SLO,
        "sweep": [dict(offered_frac=frac, **rep.to_json())
                  for frac, rep in points],
        "wallclock_smoke": wall,
        "mesh_parity": {"single_device_bitwise": parity,
                        **(shard_info or {})},
        "multidevice": multidev,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")

    rows = []
    for frac, rep in points:
        rows.append((
            f"traffic_load_{frac:g}x", 1e6 / rep.tok_s if rep.tok_s else 0,
            f"offered={rep.offered_rps:.2f}rps tok_s={rep.tok_s:.1f} "
            f"slo={rep.slo_attainment:.3f} p99={rep.latency_p99_s:.3f}s "
            f"q_max={rep.queue_depth_max}"))
    rows.append(("traffic_knee", 0.0,
                 f"knee={knee_frac:g}x_capacity "
                 f"({knee_frac * capacity_rps:.2f}rps) "
                 f"gate_slo>={KNEE_SLO} json={OUT_PATH}"))
    rows.append(("traffic_wallclock_smoke", 0.0,
                 f"tok_s={wall['tok_s']:.1f} "
                 f"completed={wall['completed']}/{wall['n_requests']} "
                 f"slo={wall['slo_attainment']:.3f} "
                 f"wall_s={wall['wall_s']:.2f}"))
    rows.append(("traffic_mesh_parity", 0.0,
                 f"single_device_bitwise={parity} "
                 f"cache_leaves={shard_info['cache_sharded_leaves']}"))
    rows.append(("traffic_multidevice", 0.0,
                 f"speedup={multidev['speedup']:.2f}x "
                 f"gate={multidev['gate_1_5x']} "
                 f"cpus={multidev['cpu_count']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small qwen3 smoke shapes (CI)")
    args = ap.parse_args()
    for name, us, derived in run(quick=args.smoke):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
