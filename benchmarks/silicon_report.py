"""Silicon variation report: yield curves, offset-correction recovery,
σ=0 parity, and the drift → alarm → auto-recalibration serving loop.

Four sections, all on the qwen3 smoke LM with every projection on
``cim_sim`` (plus a representative projection for the vmapped sweeps):

  * **sigma0** — a fleet whose every slot samples EXACTLY nominal silicon
    (σ=0) must decode bitwise identically to the silicon-free programmed
    engine, on both a pinned fleet and a round-interleaved (swapped) one.
    This gates the per-tile silicon route against the nominal fast path.
  * **yield** — vmapped multi-seed Monte-Carlo: projection SQNR vs
    cap-DAC mismatch σ at the exactly-lossless design point (31×5) and
    the real-rounding points (31×6, 31×4), plus model-level logits rel-L2
    over sampled fleets via the calibration lab's evaluators.
  * **offset_correction** — mean-SQNR delta of the 2-bit tail-current
    comparator calibration over the same sampling keys (gated: the
    correction must recover >= ``OFFSET_RECOVERY_GATE_DB``).
  * **drift** — a served engine with an aging fleet: comparator offsets
    drift past the ADC decision boundaries, the probe alarm fires,
    auto-recalibration (comparator re-trim + scale re-programming)
    brings the probe error back under the alarm line, and the rewrite is
    charged in the ``ServeReport`` (all gated).

Emits ``BENCH_silicon.json`` and the ``benchmarks/run.py`` CSV rows.

CLI: ``PYTHONPATH=src python -m benchmarks.silicon_report [--smoke]``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.calib.corpus import attach_observer_ids
from repro.calib.report import accuracy_report, calibrate_lm, lm_ref_config
from repro.compiler.tiling import Fleet
from repro.configs.base import MFTechniqueConfig
from repro.configs.qwen3_0_6b import SMOKE
from repro.core.cim import CimConfig
from repro.core.programmed import program_weights
from repro.data.synthetic import DataConfig, lm_batch
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine
from repro.silicon.drift import DriftPolicy
from repro.silicon.instance import SiliconConfig, attach_silicon, sample_fleet
from repro.silicon.montecarlo import (offset_correction_delta_db,
                                      projection_yield_curve)

OUT_PATH = os.environ.get("BENCH_SILICON_OUT", "BENCH_silicon.json")

# Design points for the yield sweeps: the paper's exactly-lossless 31x5
# pairing plus the two real-rounding ADCs of BENCH_calib.json.
DESIGNS = ((31, 5), (31, 6), (31, 4))
# Mean-SQNR the 2-bit tail-current calibration must win back at the
# bench's comparator sigma (measured ~100 dB at the lossless point — the
# uncorrected offset crosses ADC decision boundaries everywhere, the
# corrected residue almost never does).
OFFSET_RECOVERY_GATE_DB = 6.0
# Comparator sigma for the offset/drift scenarios: the post-calibration
# residue (<= half a cal-DAC LSB = 6 mV) sits just under the 31-level
# half-LSB decision boundary (~6.5 mV at 0.4 V full scale), so fresh
# silicon is healthy and any drift crosses into visible error.
CMP_SIGMA_V = 0.008
# Pre-drift recovery gate: after auto-recalibration the probe rel-L2 must
# come back to within this factor of the pre-drift baseline (the alarm
# fired at ~5.6x baseline; the re-trimmed residue lands ~1.3x — the gap
# to 1.0 is the re-measured activation scales, which now reflect the
# served CIM datapath rather than the float reference).
RECOVERY_GATE_RATIO = 1.5
# Fused-kernel serving gate: a σ>0 fleet decoding through the fused
# Pallas route (in-kernel SA-ADC; silicon folded into the kernel
# operands) may cost at most this factor over the nominal fused fast
# path. The extra work is real but small — the cap-folded stationary
# operand rides the same dot, silicon adds the denominator/offset tiles
# and (for thermal fleets) the per-conversion dither draw.
KERNEL_SLOWDOWN_GATE = 1.5
# The kernel-only payload (tier-1 TIER1_KERNEL_BENCH / --only-kernel).
KERNEL_OUT_PATH = os.environ.get("BENCH_SILICON_KERNEL_OUT",
                                 "BENCH_silicon_kernel.json")


def _lm_cfg(cim: CimConfig):
    return dataclasses.replace(
        SMOKE, dtype=jnp.float32,
        mf=MFTechniqueConfig(mode="cim_sim", cim=cim))


def _batches(cfg, n, seed0=0, b=4, t=16):
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=t, global_batch=b,
                    task="uniform")
    return [{"tokens": jnp.asarray(lm_batch(dc, seed0 + i)["tokens"])}
            for i in range(n)]


def _greedy_tokens(engine: ServeEngine, n_new: int, n_reqs: int):
    done = engine.run([Request(prompt=[1, 2, 3], max_new_tokens=n_new)
                       for _ in range(n_reqs)])
    return [r.out for r in done]


def _sigma0_section(params, cfg, cim, rows):
    """σ=0 silicon decode must be bitwise identical to the nominal
    programmed path — pinned AND round-interleaved."""
    nominal0 = SiliconConfig(cap_sigma=0.0, comparator_sigma_v=0.0)
    assert nominal0.is_nominal
    pin_fleet = Fleet(n_macros=4096, cfg=cim)
    swap_fleet = Fleet(n_macros=64, cfg=cim)
    t0 = time.time()
    eng_ref = ServeEngine(params, cfg, slots=2, max_len=16,
                          fleet=pin_fleet, batched_prefill=False)
    assert eng_ref.schedule.pinned
    ref_toks = _greedy_tokens(eng_ref, 4, 2)
    eng_pin = ServeEngine(params, cfg, slots=2, max_len=16,
                          fleet=pin_fleet, batched_prefill=False,
                          silicon=nominal0)
    pin_toks = _greedy_tokens(eng_pin, 4, 2)
    eng_swap = ServeEngine(params, cfg, slots=2, max_len=16,
                           fleet=swap_fleet, batched_prefill=False,
                           silicon=nominal0)
    assert not eng_swap.schedule.pinned
    swap_toks = _greedy_tokens(eng_swap, 4, 2)
    us = (time.time() - t0) * 1e6
    pin_ok = pin_toks == ref_toks
    swap_ok = swap_toks == ref_toks
    assert pin_ok, "sigma=0 silicon decode diverged from nominal (pinned)"
    assert swap_ok, "sigma=0 silicon decode diverged from nominal (swapped)"
    rows.append(("silicon_sigma0_parity", us,
                 f"pinned={pin_ok} swapped={swap_ok}"))
    return {"pinned_bit_exact": pin_ok, "swapped_bit_exact": swap_ok,
            "swap_rounds_max": eng_swap.schedule.rounds_max}


def _yield_section(cfg, rows, quick):
    """Projection-level vmapped sweeps + model-level seeded fleets."""
    sigmas = (0.01, 0.03, 0.05, 0.08, 0.12)
    n_seeds = 16 if quick else 64
    key = jax.random.PRNGKey(42)
    k, n = cfg.d_model, cfg.d_ff
    x = jax.random.normal(jax.random.PRNGKey(0), (8, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    base = SiliconConfig(comparator_sigma_v=0.0)
    out = {}
    for m, a in DESIGNS:
        cim = CimConfig(w_bits=8, x_bits=8, adc_bits=a, m_columns=m)
        t0 = time.time()
        pts = projection_yield_curve(key, x, w, cim, base, sigmas, n_seeds)
        out[f"{m}x{a}"] = [p.to_dict() for p in pts]
        worst = pts[-1]
        rows.append((f"silicon_yield_{m}x{a}", (time.time() - t0) * 1e6,
                     f"sqnr@sigma{sigmas[0]}={pts[0].mean_sqnr_db:.1f}dB "
                     f"@sigma{worst.cap_sigma}={worst.mean_sqnr_db:.1f}dB "
                     f"yield={worst.yield_frac:.2f} seeds={n_seeds}"))
    return {"sigmas": list(sigmas), "n_seeds": n_seeds,
            "projection": out}


def _model_yield_section(params, cfg, rows, quick):
    """Model-level accuracy over sampled fleets (calib-lab evaluators)."""
    cim = cfg.mf.cim
    tagged, registry = attach_observer_ids(params)
    progd = program_weights(tagged, cim, prefer_lossless=False)
    ev = _batches(cfg, 2, seed0=1000)
    ref_cfg = lm_ref_config(cfg)

    def ref_fwd(b):
        return T.lm_forward(params, b, ref_cfg)[0]

    n_seeds = 3 if quick else 8
    cells = {}
    for cap_sigma in (0.02, 0.05):
        scfg = SiliconConfig(cap_sigma=cap_sigma,
                             comparator_sigma_v=CMP_SIGMA_V)
        rels, sqnrs = [], []
        t0 = time.time()
        for seed in range(n_seeds):
            sil = sample_fleet(jax.random.PRNGKey(100 + seed), 2048,
                               cim.m_columns, scfg)
            exec_params = attach_silicon(progd, sil, scfg, cim)
            rep = accuracy_report(
                ref_fwd,
                lambda b, p=exec_params: T.lm_forward(p, b, cfg)[0],
                ev, registry)
            rels.append(rep.rel_l2)
            sqnrs.append(rep.mean_sqnr_db)
        cells[f"cap{cap_sigma}"] = {
            "cap_sigma": cap_sigma,
            "comparator_sigma_v": CMP_SIGMA_V,
            "rel_l2_mean": float(np.mean(rels)),
            "rel_l2_max": float(np.max(rels)),
            "mean_sqnr_db": float(np.mean(sqnrs)),
            "n_seeds": n_seeds,
        }
        rows.append((f"silicon_model_yield_cap{cap_sigma}",
                     (time.time() - t0) * 1e6,
                     f"rel_l2={np.mean(rels):.4f} "
                     f"sqnr={np.mean(sqnrs):.1f}dB seeds={n_seeds}"))
    return cells


def _offset_section(rows, quick):
    cim = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31)
    k, n = 64, 128
    x = jax.random.normal(jax.random.PRNGKey(0), (8, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    scfg = SiliconConfig(comparator_sigma_v=CMP_SIGMA_V)
    n_seeds = 16 if quick else 64
    t0 = time.time()
    delta, on_db, off_db = offset_correction_delta_db(
        jax.random.PRNGKey(7), x, w, cim, scfg, n_seeds)
    rows.append(("silicon_offset_correction", (time.time() - t0) * 1e6,
                 f"delta={delta:.1f}dB corrected={on_db:.1f}dB "
                 f"uncorrected={off_db:.1f}dB gate>={OFFSET_RECOVERY_GATE_DB}"))
    assert delta >= OFFSET_RECOVERY_GATE_DB, (
        f"2-bit offset correction recovered only {delta:.1f} dB "
        f"(gate {OFFSET_RECOVERY_GATE_DB} dB)")
    return {"comparator_sigma_v": CMP_SIGMA_V, "n_seeds": n_seeds,
            "delta_db": delta, "corrected_db": on_db,
            "uncorrected_db": off_db,
            "gate_db": OFFSET_RECOVERY_GATE_DB, "gate_pass": True}


def _kernel_parity_matrix(cim: CimConfig, scfg, rows) -> dict:
    """σ>0 fused-kernel vs reference-einsum exactness, all three serving
    layouts (pinned / compiler-tiled / round-interleaved). The fixed-point
    cap fold makes both routes produce identical integer ADC codes."""
    from repro.compiler.execute import (compiled_matmul_programmed,
                                        program_layer_tiles)
    from repro.compiler.tiling import plan_tiling
    from repro.core import quant
    from repro.core.programmed import (cim_mf_matmul_programmed,
                                       cim_mf_matmul_swapped,
                                       program_macro, swap_macro)
    from repro.silicon.instance import projection_silicon
    cim_k = dataclasses.replace(cim, use_kernel=True)
    m = cim.m_columns

    def sil(slots, k, n, seed):
        fleet = sample_fleet(jax.random.PRNGKey(seed), slots, m, scfg)
        return projection_silicon(fleet, scfg, k, n)

    t0 = time.time()
    out = {}
    # pinned
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 2 * m + 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (2 * m + 8, 9))
    sx = quant.calibrate_scale(x, cim.x_bits)
    s = sil(24, w.shape[0], w.shape[1], 50)
    y_k = cim_mf_matmul_programmed(x, program_macro(w, cim_k, sx=sx),
                                   cim_k, silicon=s)
    y_p = cim_mf_matmul_programmed(
        x, program_macro(w, cim, sx=sx, prefer_lossless=False), cim,
        silicon=s)
    out["pinned_exact"] = bool(np.array_equal(np.asarray(y_k),
                                              np.asarray(y_p)))
    # compiler-tiled
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 3 * m + 7))
    w = jax.random.normal(jax.random.PRNGKey(3), (3 * m + 7, 21))
    sx = quant.calibrate_scale(x, cim.x_bits)
    plan = plan_tiling(w.shape[0], w.shape[1], cim, tile_k_chunks=2,
                       tile_n=8)
    prog = program_layer_tiles(w, plan, cim, sx=sx)
    s = sil(96, w.shape[0], w.shape[1], 51)
    y_k = compiled_matmul_programmed(x, prog, plan, cim_k, silicon=s)
    y_p = compiled_matmul_programmed(x, prog, plan, cim, silicon=s)
    out["tiled_exact"] = bool(np.array_equal(np.asarray(y_k),
                                             np.asarray(y_p)))
    # round-interleaved (swap-scheduled)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 3 * m))
    w = jax.random.normal(jax.random.PRNGKey(5), (3 * m, 7))
    sx = quant.calibrate_scale(x, cim.x_bits)
    swap = swap_macro(w, cim, tile_slots=5, sx=sx)
    s = sil(5, w.shape[0], w.shape[1], 52)
    y_k = cim_mf_matmul_swapped(x, w, swap, cim_k, silicon=s)
    y_p = cim_mf_matmul_swapped(x, w, swap, cim, silicon=s)
    out["swapped_exact"] = bool(np.array_equal(np.asarray(y_k),
                                               np.asarray(y_p)))
    rows.append(("silicon_kernel_parity", (time.time() - t0) * 1e6,
                 " ".join(f"{k}={v}" for k, v in out.items())))
    return out


def _kernel_section(params, cfg, cim, rows, quick):
    """Fused Pallas step-time kernels: σ>0 fleets decode at nominal speed.

    Gates:
      * silicon fused decode tok/s >= (1/KERNEL_SLOWDOWN_GATE) x the
        nominal fused decode tok/s;
      * σ=0 silicon through the fused kernel decodes bitwise the nominal
        fused engine;
      * σ>0 fused output == the reference einsum route EXACTLY (integer
        ADC codes) on the pinned, tiled and swapped layouts, and the
        served σ>0 token streams of the fused and einsum engines match.
    """
    from benchmarks.serve_bench import _decode_tok_per_s
    from repro.kernels.ops import _on_cpu
    cim_k = dataclasses.replace(cim, use_kernel=True)
    cfg_k = _lm_cfg(cim_k)
    fleet = Fleet(n_macros=4096, cfg=cim_k)
    scfg = SiliconConfig(cap_sigma=0.02, comparator_sigma_v=CMP_SIGMA_V)
    ticks, warmup, reps = (4, 2, 3) if quick else (10, 3, 3)
    max_len = reps * ticks + warmup + 4

    def mk(cfg_, silicon=None, ml=max_len):
        return ServeEngine(params, cfg_, slots=2, max_len=ml, fleet=fleet,
                           batched_prefill=False, silicon=silicon)

    t0 = time.time()
    nom_tok_s = _decode_tok_per_s(mk(cfg_k), ticks, warmup, reps)
    sil_tok_s = _decode_tok_per_s(mk(cfg_k, scfg), ticks, warmup, reps)
    us = (time.time() - t0) * 1e6
    ratio = sil_tok_s / nom_tok_s if nom_tok_s else 0.0
    ratio_ok = ratio >= 1.0 / KERNEL_SLOWDOWN_GATE
    rows.append(("silicon_kernel_toks", us,
                 f"nominal_fused={nom_tok_s:.1f}tok/s "
                 f"silicon_fused={sil_tok_s:.1f}tok/s ratio={ratio:.2f} "
                 f"gate>={1.0 / KERNEL_SLOWDOWN_GATE:.2f} "
                 f"interpret={_on_cpu()}"))

    sigma0 = SiliconConfig(cap_sigma=0.0, comparator_sigma_v=0.0)
    sigma0_ok = (_greedy_tokens(mk(cfg_k, sigma0, ml=16), 4, 2)
                 == _greedy_tokens(mk(cfg_k, None, ml=16), 4, 2))
    # σ>0 served-token parity: both engines sample the SAME fleet
    # (PRNGKey(scfg.seed)), one decodes fused, one through the einsums.
    token_parity = (_greedy_tokens(mk(cfg_k, scfg, ml=16), 4, 2)
                    == _greedy_tokens(mk(cfg, scfg, ml=16), 4, 2))
    parity = _kernel_parity_matrix(cim, scfg, rows)

    assert ratio_ok, (
        f"silicon fused decode {sil_tok_s:.1f} tok/s fell below "
        f"1/{KERNEL_SLOWDOWN_GATE} of the nominal fused "
        f"{nom_tok_s:.1f} tok/s")
    assert sigma0_ok, "sigma=0 fused decode diverged from nominal fused"
    assert token_parity, "sigma>0 fused tokens diverged from einsum route"
    assert all(parity.values()), f"fused/einsum code parity broke: {parity}"
    return {
        "slowdown_gate": KERNEL_SLOWDOWN_GATE,
        "cap_sigma": scfg.cap_sigma,
        "comparator_sigma_v": scfg.comparator_sigma_v,
        "decode_ticks": ticks * reps,
        "pallas_interpret": bool(_on_cpu()),
        "nominal_fused_tok_s": nom_tok_s,
        "silicon_fused_tok_s": sil_tok_s,
        "silicon_over_nominal_ratio": ratio,
        "ratio_gate_pass": ratio_ok,
        "sigma0_fused_bit_exact": sigma0_ok,
        "sigma_pos_token_parity": token_parity,
        "sigma_pos_code_parity": parity,
    }


def _drift_section(params, cfg, cim, rows):
    """Aging fleet under serving: alarm fires, recalibration recovers."""
    cal = _batches(cfg, 3)
    artifact = calibrate_lm(params, cfg, cal, method="amax")
    policy = DriftPolicy(probe_batches=cal[:2], check_interval=16,
                         silicon_update_interval=8,
                         rel_l2_alarm_ratio=1.3, rel_l2_alarm_floor=0.02)
    # Accelerated aging: ~0.3 mV of comparator drift per stream pushes a
    # typical slot across the 31-level half-LSB boundary (~6.5 mV) within
    # one check interval; the cal-DAC range (+-3 sigma = 24 mV) still
    # covers the first alarms, so the re-trim can recover.
    scfg = SiliconConfig(cap_sigma=0.02, comparator_sigma_v=CMP_SIGMA_V,
                         drift_sigma_v_per_kstream=0.3)
    fleet = Fleet(n_macros=4096, cfg=cim)
    t0 = time.time()
    eng = ServeEngine(params, cfg, slots=2, max_len=48, fleet=fleet,
                      batched_prefill=False, calibration=artifact,
                      silicon=scfg, drift=policy)
    baseline = eng._monitor.baseline_rel_l2
    eng.run([Request(prompt=[1, 2, 3], max_new_tokens=32)
             for _ in range(2)])
    us = (time.time() - t0) * 1e6
    rep = eng.last_report
    log = [s.to_dict() for s in eng.drift_log]
    first_recal = next((s for s in eng.drift_log if s.recalibrated), None)
    alarm_fired = rep.drift_alarms >= 1
    recovered = (first_recal is not None
                 and not math.isnan(first_recal.post_rel_l2)
                 and first_recal.post_rel_l2
                 <= RECOVERY_GATE_RATIO * baseline)
    charged = rep.recalibrations >= 1 and rep.recal_reload_bits > 0 \
        and rep.recal_energy_j > 0.0
    assert alarm_fired, "drift scenario never raised the drift alarm"
    assert recovered, (
        f"auto-recalibration did not bring the probe back under the "
        f"pre-drift gate: post={getattr(first_recal, 'post_rel_l2', None)}"
        f" baseline={baseline}")
    assert charged, "recalibration events were not charged in ServeReport"
    rows.append(("silicon_drift_recovery", us,
                 f"baseline={baseline:.4f} "
                 f"alarm_rel={first_recal.rel_l2:.4f} "
                 f"post={first_recal.post_rel_l2:.4f} "
                 f"alarms={rep.drift_alarms} recals={rep.recalibrations} "
                 f"recal_nj={rep.recal_energy_nj:.1f}"))
    return {
        "baseline_rel_l2": baseline,
        "recovery_gate_ratio": RECOVERY_GATE_RATIO,
        "drift_sigma_v_per_kstream": scfg.drift_sigma_v_per_kstream,
        "check_interval": policy.check_interval,
        "drift_checks": rep.drift_checks,
        "drift_alarms": rep.drift_alarms,
        "recalibrations": rep.recalibrations,
        "recal_reload_bits": rep.recal_reload_bits,
        "recal_energy_nj": rep.recal_energy_nj,
        "first_alarm_rel_l2": first_recal.rel_l2,
        "first_recal_post_rel_l2": first_recal.post_rel_l2,
        "alarm_fired": alarm_fired,
        "recovered_within_gate": recovered,
        "charged_in_report": charged,
        "log": log,
    }


def run(quick: bool = True):
    rows = []
    cim = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31)
    cfg = _lm_cfg(cim)
    params = T.lm_init(jax.random.PRNGKey(0), cfg)

    payload = {
        "bench": "silicon_report",
        "quick": quick,
        "config": cfg.name,
        "designs": [f"{m}x{a}" for m, a in DESIGNS],
        "sigma0": _sigma0_section(params, cfg, cim, rows),
        "kernel": _kernel_section(params, cfg, cim, rows, quick),
        "yield": _yield_section(cfg, rows, quick),
        "model_yield": _model_yield_section(params, cfg, rows, quick),
        "offset_correction": _offset_section(rows, quick),
        "drift": _drift_section(params, cfg, cim, rows),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    d = payload["drift"]
    k = payload["kernel"]
    rows.append(("silicon_gate", 0.0,
                 f"sigma0_bit_exact=True offset_recovery_pass=True "
                 f"drift_recovered={d['recovered_within_gate']} "
                 f"kernel_ratio={k['silicon_over_nominal_ratio']:.2f} "
                 f"json={OUT_PATH}"))
    return rows


def run_kernel(quick: bool = True):
    """Just the fused-kernel section (tier-1 TIER1_KERNEL_BENCH flag) —
    the same gates, written to ``BENCH_silicon_kernel.json`` so it never
    clobbers a full report."""
    rows = []
    cim = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31)
    cfg = _lm_cfg(cim)
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    payload = {
        "bench": "silicon_report_kernel",
        "quick": quick,
        "config": cfg.name,
        "kernel": _kernel_section(params, cfg, cim, rows, quick),
    }
    with open(KERNEL_OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    k = payload["kernel"]
    rows.append(("silicon_kernel_gate", 0.0,
                 f"ratio={k['silicon_over_nominal_ratio']:.2f} "
                 f"ratio_pass={k['ratio_gate_pass']} "
                 f"sigma0={k['sigma0_fused_bit_exact']} "
                 f"tokens={k['sigma_pos_token_parity']} "
                 f"json={KERNEL_OUT_PATH}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small seed counts (CI)")
    ap.add_argument("--only-kernel", action="store_true",
                    help="run only the fused-kernel section "
                         "(BENCH_silicon_kernel.json)")
    args = ap.parse_args()
    runner = run_kernel if args.only_kernel else run
    for name, us, derived in runner(quick=args.smoke):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
