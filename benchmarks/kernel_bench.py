"""Kernel micro-benchmarks: MF dual-matmul vs typical matmul cost, and the
CIM MAV kernel vs its einsum reference (CPU wall time; the TPU story is in
the dry-run roofline where MF costs exactly 2x matmul FLOPs).
"""

from __future__ import annotations

import jax

from benchmarks.common import timed
from repro.core.cim import CimConfig, cim_mf_matmul
from repro.core.mf import mf_correlate_ref


def run(quick: bool = True):
    rows = []
    m = 256 if quick else 1024
    x = jax.random.normal(jax.random.PRNGKey(0), (m, 512))
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 512))

    reg = jax.jit(lambda a, b: a @ b)
    mf = jax.jit(mf_correlate_ref)
    _, us_reg = timed(reg, x, w, repeats=5)
    _, us_mf = timed(mf, x, w, repeats=5)
    rows.append(("kernel_regular_matmul", us_reg, f"{m}x512x512"))
    rows.append(("kernel_mf_dual_matmul", us_mf,
                 f"ratio_vs_regular={us_mf / us_reg:.2f} (2.0 = FLOP model)"))

    cim = jax.jit(lambda a, b: cim_mf_matmul(
        a, b, CimConfig(8, 8, 5, 31)))
    xs, ws = x[:32], w[:, :64]
    _, us_cim = timed(cim, xs, ws, repeats=3)
    rows.append(("kernel_cim_bitplane_sim", us_cim, "32x512x64 8b/5b"))
    return rows
