"""Macro zoo report: pluggable CIM macro models behind one registry.

Five sections, gating the subsystem of ``repro.macros``:

  * **parity** — the SA-ADC *plug-in* must be indistinguishable from the
    pre-registry silicon path: bitwise-identical served tokens at σ=0
    for EVERY registered flavour's ``nominal()``, and exact-code
    identity (same sampled fleet, same projection views, same served
    tokens) between ``SAADC(silicon=cfg)`` and the raw ``SiliconConfig``
    at σ>0.
  * **design_points** — the area re-budget table: per flavour, the
    widest µArray half that fits the source paper's fixed 31×5 area
    envelope once the flavour's (amortised) ADC cost is paid. Gated:
    collaborative digitization must open ≥ 2 NEW design points strictly
    wider than M=31, all within the envelope.
  * **compiler** — the same smoke LM lowered onto the reference SA-ADC
    fleet and onto a collaborative re-budgeted fleet of the same macro
    count and area: strictly fewer µArray tiles, with the Eq. 4
    latency/energy deltas of the trade (wider MAV, arbitration tail,
    bridge charge) rolled up honestly.
  * **yield** — Monte-Carlo mismatch sweeps (``projection_yield_curve``)
    parameterised over macro models, at the new collaborative design
    points next to the SA-ADC 31×5 baseline, plus the P-8T matching
    advantage at the mismatch corner.
  * **aging** — error creep of an aging fleet: per service age, the
    offset residue and projection SQNR without maintenance, with the
    fine-only re-trim, and with the tiered coarse re-trim; tier counts
    (fine / coarse / retired) per age. A serving engine under
    accelerated drift then surfaces screened-out slots in
    ``ServeReport.retired_slots``.

Emits ``BENCH_macros.json`` and the ``benchmarks/run.py`` CSV rows.

CLI: ``PYTHONPATH=src python -m benchmarks.macro_report [--smoke]``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.calib.report import calibrate_lm
from repro.compiler.cost import model_cost
from repro.compiler.frontend import projection_layer_stats
from repro.compiler.schedule import compile_model
from repro.compiler.tiling import Fleet
from repro.configs.base import MFTechniqueConfig
from repro.configs.qwen3_0_6b import SMOKE
from repro.core.cim import CimConfig, cim_mf_matmul
from repro.data.synthetic import DataConfig, lm_batch
from repro.macros import (CollaborativeDigitization, P8T, SAADC, available,
                          fleet_for_macro, get_macro, reference_budget_units)
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine
from repro.silicon.drift import DriftPolicy
from repro.silicon.instance import (SiliconConfig, age, fleet_silicon,
                                    projection_silicon,
                                    recalibrate_comparators,
                                    retrim_comparators, sample_fleet)
from repro.silicon.montecarlo import projection_yield_curve

OUT_PATH = os.environ.get("BENCH_MACROS_OUT", "BENCH_macros.json")

# The fixed area envelope every flavour re-budgets against: the source
# paper's 8x62 half (M=31, A_P=5) at 8·31 cells + un-shared SA-ADC.
BASE_CIM = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31)
# Collaborative design points the re-budget must open (group_size,
# adc_bits); ≥ MIN_NEW_DESIGNS of them must land strictly wider than
# M=31 inside the envelope.
COLLAB_POINTS = ((4, 5), (4, 6), (2, 6))
MIN_NEW_DESIGNS = 2
# σ>0 parity / aging silicon (the silicon_report conventions: 8 mV
# comparator sigma puts the calibrated residue just under the 31-level
# half-LSB decision boundary).
CMP_SIGMA_V = 0.008
SIGMA_POS = SiliconConfig(cap_sigma=0.02, comparator_sigma_v=CMP_SIGMA_V)


def _lm_cfg(cim: CimConfig):
    return dataclasses.replace(
        SMOKE, dtype=jnp.float32,
        mf=MFTechniqueConfig(mode="cim_sim", cim=cim))


def _batches(cfg, n, seed0=0, b=4, t=16):
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=t, global_batch=b,
                    task="uniform")
    return [{"tokens": jnp.asarray(lm_batch(dc, seed0 + i)["tokens"])}
            for i in range(n)]


def _greedy_tokens(engine: ServeEngine, n_new: int, n_reqs: int):
    done = engine.run([Request(prompt=[1, 2, 3], max_new_tokens=n_new)
                       for _ in range(n_reqs)])
    return [r.out for r in done]


# ---------------------------------------------------------------------------
# parity
# ---------------------------------------------------------------------------

def _parity_section(params, cfg, cim, rows):
    fleet = Fleet(n_macros=4096, cfg=cim)

    def mk(silicon):
        return ServeEngine(params, cfg, slots=2, max_len=16, fleet=fleet,
                           batched_prefill=False, silicon=silicon)

    t0 = time.time()
    ref_toks = _greedy_tokens(mk(None), 4, 2)
    nominal_exact = {}
    for name in available():
        model = get_macro(name).nominal()
        assert model.is_nominal
        nominal_exact[name] = _greedy_tokens(mk(model), 4, 2) == ref_toks

    # σ>0: the SAADC wrapper IS the raw-config path — same sampled
    # fleet, same projection views, same served tokens.
    raw = fleet_silicon(fleet, SIGMA_POS)
    wrapped = fleet_silicon(fleet, SAADC(silicon=SIGMA_POS))
    fleet_exact = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(raw), jax.tree.leaves(wrapped)))
    k, n = 3 * cim.m_columns + 5, 9
    view_cfg = projection_silicon(raw, SIGMA_POS, k, n)
    view_mac = projection_silicon(raw, SAADC(silicon=SIGMA_POS), k, n)
    view_exact = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(view_cfg),
                        jax.tree.leaves(view_mac)))
    token_exact = (_greedy_tokens(mk(SIGMA_POS), 4, 2)
                   == _greedy_tokens(mk(SAADC(silicon=SIGMA_POS)), 4, 2))
    us = (time.time() - t0) * 1e6

    assert all(nominal_exact.values()), (
        f"nominal macro decode diverged from silicon-free: {nominal_exact}")
    assert fleet_exact, "SAADC plug-in sampled a different fleet at sigma>0"
    assert view_exact, "SAADC plug-in projection views diverged at sigma>0"
    assert token_exact, "SAADC plug-in served tokens diverged at sigma>0"
    rows.append(("macro_parity", us,
                 f"nominal_bit_exact={sorted(nominal_exact)} "
                 f"saadc_sigma_pos_exact={token_exact}"))
    return {
        "flavours": sorted(available()),
        "nominal_bit_exact": nominal_exact,
        "saadc_sigma_pos_fleet_exact": fleet_exact,
        "saadc_sigma_pos_view_exact": view_exact,
        "saadc_sigma_pos_token_exact": token_exact,
    }


# ---------------------------------------------------------------------------
# design points
# ---------------------------------------------------------------------------

def _design_section(rows):
    budget = reference_budget_units(BASE_CIM)
    base_fleet = Fleet(n_macros=64, cfg=BASE_CIM)
    t0 = time.time()
    table = []
    models = [("saadc", SAADC(), BASE_CIM.adc_bits),
              ("p8t", P8T(), BASE_CIM.adc_bits)]
    models += [(f"collaborative_g{g}", CollaborativeDigitization(group_size=g),
                a) for g, a in COLLAB_POINTS]
    new_points = []
    for label, model, adc_bits in models:
        f = fleet_for_macro(model, base_fleet, adc_bits=adc_bits)
        entry = {
            "label": label,
            "design": f"{f.cfg.m_columns}x{f.cfg.adc_bits}",
            "m_columns": f.cfg.m_columns,
            "adc_bits": f.cfg.adc_bits,
            "within_envelope": model.half_area_units(f.cfg) <= budget,
        } | model.describe(f.cfg)
        table.append(entry)
        assert entry["within_envelope"], (
            f"{label} re-budget exceeded the {budget:.0f}-unit envelope")
        if label.startswith("collaborative") \
                and f.cfg.m_columns > BASE_CIM.m_columns:
            new_points.append(entry["design"])
    us = (time.time() - t0) * 1e6
    assert len(set(new_points)) >= MIN_NEW_DESIGNS, (
        f"collaborative re-budget opened only {sorted(set(new_points))}, "
        f"need >= {MIN_NEW_DESIGNS} points wider than "
        f"M={BASE_CIM.m_columns}")
    rows.append(("macro_design_points", us,
                 f"budget={budget:.0f}u new={sorted(set(new_points))} "
                 f"saadc={BASE_CIM.m_columns}x{BASE_CIM.adc_bits}"))
    return {"budget_units": budget,
            "reference_design":
                f"{BASE_CIM.m_columns}x{BASE_CIM.adc_bits}",
            "min_new_designs": MIN_NEW_DESIGNS,
            "new_collaborative_designs": sorted(set(new_points)),
            "table": table}


# ---------------------------------------------------------------------------
# compiler
# ---------------------------------------------------------------------------

def _compiler_section(params, rows):
    """Same LM, same macro count, same per-slot area — the collaborative
    fleet's wider halves must strictly shrink the tile count, and the
    Eq. 4 roll-up must price the flavour's latency/energy overheads."""
    stats, _ = projection_layer_stats(params)
    base = Fleet(n_macros=4096, cfg=BASE_CIM)
    collab = CollaborativeDigitization(group_size=4)
    rebud = fleet_for_macro(collab, base, adc_bits=BASE_CIM.adc_bits)
    t0 = time.time()
    sched_b = compile_model(stats, base)
    sched_c = compile_model(stats, rebud)
    _, cost_b = model_cost(sched_b)
    _, cost_c = model_cost(sched_c)
    us = (time.time() - t0) * 1e6
    tiles_ok = sched_c.total_tiles < sched_b.total_tiles
    cols_ok = rebud.cfg.m_columns > base.cfg.m_columns
    assert cols_ok, "collaborative re-budget did not widen the µArray half"
    assert tiles_ok, (
        f"wider halves did not shrink the schedule: "
        f"{sched_c.total_tiles} vs {sched_b.total_tiles} tiles")
    rows.append(("macro_compiler_rebudget", us,
                 f"m={base.cfg.m_columns}->{rebud.cfg.m_columns} "
                 f"tiles={sched_b.total_tiles}->{sched_c.total_tiles} "
                 f"unit_ops={cost_b.unit_ops}->{cost_c.unit_ops} "
                 f"energy={cost_b.energy_j:.3e}->{cost_c.energy_j:.3e}J"))
    return {
        "design": {"base": f"{base.cfg.m_columns}x{base.cfg.adc_bits}",
                   "collaborative":
                       f"{rebud.cfg.m_columns}x{rebud.cfg.adc_bits}"},
        "total_tiles": {"base": sched_b.total_tiles,
                        "collaborative": sched_c.total_tiles},
        "tiles_strictly_fewer": tiles_ok,
        "unit_ops": {"base": cost_b.unit_ops,
                     "collaborative": cost_c.unit_ops},
        "cycles": {"base": cost_b.cycles, "collaborative": cost_c.cycles},
        "latency_s": {"base": cost_b.latency_s,
                      "collaborative": cost_c.latency_s},
        "compute_energy_j": {"base": cost_b.compute_energy_j,
                             "collaborative": cost_c.compute_energy_j},
        "tops_per_w": {"base": cost_b.tops_per_w,
                       "collaborative": cost_c.tops_per_w},
        "eq4_delta": {
            "unit_ops_ratio": cost_c.unit_ops / cost_b.unit_ops,
            "cycles_ratio": cost_c.cycles / cost_b.cycles,
            "energy_ratio": cost_c.energy_j / cost_b.energy_j,
        },
    }


# ---------------------------------------------------------------------------
# yield
# ---------------------------------------------------------------------------

def _yield_section(rows, quick):
    """MC mismatch sweeps over the zoo, at each flavour's re-budgeted
    design point (same fixed area envelope for all)."""
    sigmas = (0.05, 0.12, 0.2)
    n_seeds = 16 if quick else 64
    base_fleet = Fleet(n_macros=64, cfg=BASE_CIM)
    sweeps = [("saadc_31x5", SAADC(silicon=SiliconConfig(
        comparator_sigma_v=0.0)), BASE_CIM)]
    for g, a in COLLAB_POINTS:
        m = CollaborativeDigitization(
            group_size=g, silicon=SiliconConfig(comparator_sigma_v=0.0))
        f = fleet_for_macro(m, base_fleet, adc_bits=a)
        sweeps.append((f"collaborative_g{g}_{f.cfg.m_columns}x{a}", m,
                       f.cfg))
    p8t = P8T(silicon=SiliconConfig(comparator_sigma_v=0.0))
    f = fleet_for_macro(p8t, base_fleet)
    sweeps.append((f"p8t_{f.cfg.m_columns}x{f.cfg.adc_bits}", p8t, f.cfg))

    out = {}
    for label, model, cim in sweeps:
        k, n = 2 * cim.m_columns, 6
        x = jax.random.normal(jax.random.PRNGKey(0), (8, k))
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
        t0 = time.time()
        pts = projection_yield_curve(jax.random.PRNGKey(42), x, w, cim,
                                     model, sigmas, n_seeds)
        out[label] = [p.to_dict() for p in pts]
        worst = pts[-1]
        rows.append((f"macro_yield_{label}", (time.time() - t0) * 1e6,
                     f"sqnr@sigma{sigmas[0]}={pts[0].mean_sqnr_db:.1f}dB "
                     f"@sigma{worst.cap_sigma}={worst.mean_sqnr_db:.1f}dB "
                     f"yield={worst.yield_frac:.2f} seeds={n_seeds}"))
    collab_curves = [k for k in out if k.startswith("collaborative")]
    assert len(collab_curves) >= MIN_NEW_DESIGNS, (
        f"yield sweeps cover only {collab_curves}")
    # the P-8T matching advantage must show at the mismatch corner
    p8t_label = next(k for k in out if k.startswith("p8t"))
    p8t_ok = (out[p8t_label][-1]["mean_sqnr_db"]
              > out["saadc_31x5"][-1]["mean_sqnr_db"])
    return {"sigmas": list(sigmas), "n_seeds": n_seeds,
            "p8t_matching_wins_at_corner": p8t_ok, "curves": out}


# ---------------------------------------------------------------------------
# aging
# ---------------------------------------------------------------------------

def _aging_fleet_section(rows):
    """Error creep vs service age at the projection level: no
    maintenance, fine-only re-trim, tiered re-trim — with tier counts."""
    cim = BASE_CIM
    scfg = dataclasses.replace(SIGMA_POS, cap_sigma=0.0,
                               drift_sigma_v_per_kstream=0.3)
    k, n = 2 * cim.m_columns, 6
    n_slots = 256
    x = jax.random.normal(jax.random.PRNGKey(0), (8, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    y0 = cim_mf_matmul(x, w, cim)

    def sqnr(sil):
        view = projection_silicon(sil, scfg, k, n)
        y = cim_mf_matmul(x, w, cim, silicon=view)
        num = float(np.sum(np.asarray(y0, np.float64) ** 2))
        err = float(np.sum((np.asarray(y, np.float64)
                            - np.asarray(y0, np.float64)) ** 2))
        return 10.0 * np.log10(num / max(err, num * 1e-12))

    fresh = sample_fleet(jax.random.PRNGKey(11), n_slots, cim.m_columns,
                         scfg)
    t0 = time.time()
    creep = []
    for streams in (0, 50, 100, 150, 300, 1000):
        aged = age(fresh, streams)
        fine = recalibrate_comparators(aged, scfg)
        tiered, tiers = retrim_comparators(aged, scfg)
        tiers = np.asarray(tiers)
        creep.append({
            "age_streams": streams,
            "sqnr_db_no_recal": sqnr(aged),
            "sqnr_db_fine_recal": sqnr(fine),
            "sqnr_db_tiered_retrim": sqnr(tiered),
            "tier_fine": int((tiers == 0).sum()),
            "tier_coarse": int((tiers == 1).sum()),
            "tier_retired": int((tiers == 2).sum()),
        })
    us = (time.time() - t0) * 1e6
    last = creep[-1]
    # once drift saturates the fine DAC, the coarse tier must be the
    # better maintenance action
    saturated = [c for c in creep if c["tier_coarse"] > 0]
    tiered_wins = all(c["sqnr_db_tiered_retrim"]
                      >= c["sqnr_db_fine_recal"] for c in saturated)
    assert saturated, "aging sweep never engaged the coarse tier"
    losses = [(c["age_streams"], c["sqnr_db_fine_recal"],
               c["sqnr_db_tiered_retrim"]) for c in saturated]
    assert tiered_wins, (
        f"tiered re-trim lost to the saturated fine DAC: {losses}")
    assert last["tier_retired"] > 0, (
        "deep-age fleet retired no slots — screening is vacuous")
    rows.append(("macro_aging_creep", us,
                 f"ages={[c['age_streams'] for c in creep]} "
                 f"retired@{last['age_streams']}={last['tier_retired']} "
                 f"tiered>=fine={tiered_wins}"))
    return {"drift_sigma_v_per_kstream": scfg.drift_sigma_v_per_kstream,
            "n_slots": n_slots, "tiered_beats_fine_when_saturated":
                tiered_wins, "creep": creep}


def _aging_engine_section(params, cfg, cim, rows):
    """Accelerated drift under serving: the drift alarm triggers the
    tiered re-trim and the screened-out slots surface in ServeReport."""
    cal = _batches(cfg, 3)
    artifact = calibrate_lm(params, cfg, cal, method="amax")
    policy = DriftPolicy(probe_batches=cal[:2], check_interval=16,
                         silicon_update_interval=8,
                         rel_l2_alarm_ratio=1.3, rel_l2_alarm_floor=0.02)
    # ~12 V/kstream: by the first check (stream 16) the drift scale is
    # ~190 mV — far past the ±90 mV coarse window for most slots, so the
    # re-trim retires a visible fraction of the fleet.
    scfg = dataclasses.replace(SIGMA_POS, drift_sigma_v_per_kstream=12.0)
    fleet = Fleet(n_macros=4096, cfg=cim)
    t0 = time.time()
    eng = ServeEngine(params, cfg, slots=2, max_len=48, fleet=fleet,
                      batched_prefill=False, calibration=artifact,
                      silicon=scfg, drift=policy)
    eng.run([Request(prompt=[1, 2, 3], max_new_tokens=32)
             for _ in range(2)])
    us = (time.time() - t0) * 1e6
    rep = eng.last_report
    # ServeReport.retired_slots is the LEVEL after the latest re-trim, so
    # it must agree with the last recalibrated drift-log entry (earlier
    # entries saw less drift and retired fewer slots).
    recal = next((s for s in reversed(eng.drift_log) if s.recalibrated),
                 None)
    assert rep.recalibrations >= 1, "accelerated drift never re-trimmed"
    assert rep.retired_slots > 0, (
        "saturating drift retired no slots in ServeReport")
    assert recal is not None and recal.retired_slots == rep.retired_slots
    rows.append(("macro_aging_serve", us,
                 f"recals={rep.recalibrations} "
                 f"retired={rep.retired_slots}/{fleet.tile_slots} "
                 f"coarse={recal.retrim_coarse_slots}"))
    return {"drift_sigma_v_per_kstream": scfg.drift_sigma_v_per_kstream,
            "tile_slots": fleet.tile_slots,
            "recalibrations": rep.recalibrations,
            "retired_slots": rep.retired_slots,
            "retrim_coarse_slots": recal.retrim_coarse_slots,
            "drift_log": [s.to_dict() for s in eng.drift_log]}


def run(quick: bool = True):
    rows = []
    cfg = _lm_cfg(BASE_CIM)
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    payload = {
        "bench": "macro_report",
        "quick": quick,
        "config": cfg.name,
        "registry": sorted(available()),
        "parity": _parity_section(params, cfg, BASE_CIM, rows),
        "design_points": _design_section(rows),
        "compiler": _compiler_section(params, rows),
        "yield": _yield_section(rows, quick),
        "aging_fleet": _aging_fleet_section(rows),
        "aging_serve": _aging_engine_section(params, cfg, BASE_CIM, rows),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    d = payload["design_points"]
    c = payload["compiler"]
    rows.append(("macro_gate", 0.0,
                 f"parity=True new_designs={d['new_collaborative_designs']} "
                 f"tiles_fewer={c['tiles_strictly_fewer']} "
                 f"retired={payload['aging_serve']['retired_slots']} "
                 f"json={OUT_PATH}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small seed counts (CI)")
    args = ap.parse_args()
    for name, us, derived in run(quick=args.smoke):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
