"""Table I / Fig. 2: conventional vs multiplication-free vs BNN accuracy.

Paper values (real MNIST/CIFAR): conventional 99.01/90.95, MF 98.6/90.2,
BNN 97/85. On the synthetic class-blob task at laptop budget we reproduce
the ORDERING and the small conventional-vs-MF gap; derived value is the
accuracy per mode plus the ordering check.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import train_image_classifier
from repro.models import convnets as C


def _train_lenet(mode: str, steps: int, seed: int = 0):
    modes = {"conv1": mode, "conv2": mode, "fc1": mode, "fc2": "regular"}
    params = C.lenet_init(jax.random.PRNGKey(seed),
                          mf_layers=C.LENET_LAYERS[:3])
    apply_fn = lambda p, x: C.lenet_apply(p, x, modes)
    # noise tuned so operator capacity matters without burying the signal
    return train_image_classifier(params, apply_fn, steps=steps, batch=32,
                                  n_classes=10, hw=28, channels=1,
                                  noise=0.9, lr=3e-3)


def run(quick: bool = True):
    steps = 120 if quick else 600
    rows = []
    accs = {}
    for mode in ("regular", "mf", "bnn"):
        t0 = time.perf_counter()
        _, acc, hist = _train_lenet(mode, steps)
        us = (time.perf_counter() - t0) * 1e6
        accs[mode] = acc
        rows.append((f"table1_mnist_{mode}_acc", us, f"{acc:.4f}"))
        rows.append((f"fig2_mnist_{mode}_final_loss", us,
                     f"{hist[-1]:.4f}"))
    # The conv >= MF relation (paper: 99.01 vs 98.6) is assertable here;
    # the MF > BNN gap is dataset-dependent — the synthetic blob task is
    # sign-dominated, so the BNN baseline does not degrade on it the way
    # it does on real MNIST/CIFAR (paper 97/85). Reported, not asserted.
    ordering = accs["regular"] >= accs["mf"] - 0.03
    rows.append(("table1_conv_ge_mf", 0.0, str(ordering)))
    rows.append(("table1_bnn_caveat", 0.0,
                 f"bnn={accs['bnn']:.4f} (sign-dominated synthetic task; "
                 "paper's BNN gap appears on real datasets)"))
    rows.append(("table1_paper_ref_mnist", 0.0,
                 "conv=0.9901 mf=0.986 bnn=0.97"))
    return rows
