#!/usr/bin/env bash
# Tier-1 gate: fast test suite + compiler-report benchmark smoke.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q -m "not slow"
python -m benchmarks.run --only compiler
