#!/usr/bin/env bash
# Tier-1 gate: fast test suite + compiler-report benchmark smoke.
# TIER1_SERVE_BENCH=1 additionally runs the serve-decode bench smoke
# (programmed vs legacy CIM decode) and leaves BENCH_serve.json behind.
# TIER1_CALIB_BENCH=1 additionally runs the calibration accuracy smoke
# (calibrated vs static activation scales) and leaves BENCH_calib.json.
# TIER1_SILICON_BENCH=1 additionally runs the silicon variation smoke
# (sigma=0 parity, yield sweeps, offset-correction recovery, drift
# auto-recalibration) and leaves BENCH_silicon.json.
# TIER1_TRAFFIC_BENCH=1 additionally runs the traffic serving smoke
# (offered-load sweep, SLO knee, mesh parity, multi-device scaling) and
# leaves BENCH_traffic.json.
# TIER1_KERNEL_BENCH=1 additionally runs ONLY the fused Pallas kernel
# section of the silicon report (nominal vs silicon fused decode tok/s
# gate, sigma=0 bitwise collapse, sigma>0 exact-code parity) and leaves
# BENCH_silicon_kernel.json — a fast alternative to the full
# TIER1_SILICON_BENCH report, which includes the same section.
# TIER1_MACRO_BENCH=1 additionally runs the macro-zoo smoke (registry
# parity, collaborative area re-budget + compiler tile shrink, MC yield
# over macro models, tiered re-trim aging) and leaves BENCH_macros.json.
# TIER1_OBS_BENCH=1 additionally runs the observability smoke (tracing
# disabled = bitwise decode parity, tracing <= 5% tok/s overhead, drift
# alarm -> retrim/retire -> recal story reconstructed from the exported
# trace) and leaves BENCH_obs.json + BENCH_obs_trace.jsonl.
# TIER1_LINT=1 additionally gates on the static passes: repro-lint
# (python -m repro.analysis, zero unsuppressed findings vs the shrink-only
# analysis_baseline.json) and ruff when it is installed.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${TIER1_LINT:-0}" == "1" ]]; then
  python -m repro.analysis src benchmarks tests --stats
  if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks
  else
    echo "tier1: ruff not installed; skipping (CI runs it)" >&2
  fi
fi
python -m pytest -x -q -m "not slow"
python -m benchmarks.run --only compiler
if [[ "${TIER1_SERVE_BENCH:-0}" == "1" ]]; then
  python -m benchmarks.serve_bench --smoke
fi
if [[ "${TIER1_CALIB_BENCH:-0}" == "1" ]]; then
  python -m benchmarks.calib_report --smoke
fi
if [[ "${TIER1_SILICON_BENCH:-0}" == "1" ]]; then
  python -m benchmarks.silicon_report --smoke
fi
if [[ "${TIER1_TRAFFIC_BENCH:-0}" == "1" ]]; then
  python -m benchmarks.traffic_report --smoke
fi
if [[ "${TIER1_KERNEL_BENCH:-0}" == "1" ]]; then
  python -m benchmarks.silicon_report --smoke --only-kernel
fi
if [[ "${TIER1_MACRO_BENCH:-0}" == "1" ]]; then
  python -m benchmarks.macro_report --smoke
fi
if [[ "${TIER1_OBS_BENCH:-0}" == "1" ]]; then
  python -m benchmarks.obs_report --smoke
fi
