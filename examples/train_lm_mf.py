"""Train a small LM with MF projections on the copy task.

    PYTHONPATH=src python examples/train_lm_mf.py --arch qwen3-0.6b \
        --steps 150 [--mf on|off]

Uses the reduced (smoke) config of any assigned architecture — the same
model code the 256/512-chip dry-run lowers — with the MF operator applied
per the mixed-mapping policy (embeddings/logits typical). Shows loss
decreasing and a checkpoint save/restore round trip.
"""

import argparse
import dataclasses
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs.base import MFTechniqueConfig, ParallelConfig, TrainConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.data.synthetic import DataConfig, lm_batch
from repro.train import checkpoint as ckpt_mod
from repro.train import train_loop as TL


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mf", default="on", choices=["on", "off"])
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    cfg = dataclasses.replace(
        cfg, mf=MFTechniqueConfig(enabled=args.mf == "on", mode="mf"))
    tcfg = TrainConfig(lr=3e-3, warmup_steps=args.steps // 10,
                       total_steps=args.steps)
    pcfg = ParallelConfig(remat="none")
    state = TL.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    n_params = sum(v.size for v in jax.tree.leaves(state.params))
    print(f"[lm-mf] arch={args.arch} (smoke) params={n_params:,} "
          f"mf={args.mf}")

    step_fn = jax.jit(TL.make_train_step(cfg, pcfg, tcfg),
                      donate_argnums=(0,))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.batch, task="copy")
    t0, first = time.time(), None
    for i in range(args.steps):
        batch = jax.tree.map(jnp.asarray, lm_batch(dcfg, i))
        if cfg.vision_tokens:
            batch["vision_embeds"] = jax.random.normal(
                jax.random.PRNGKey(i), (args.batch, cfg.vision_tokens,
                                        cfg.vision_embed_dim), cfg.dtype)
        if cfg.family == "encdec":
            batch = {"frames": jax.random.normal(
                jax.random.PRNGKey(i),
                (args.batch, args.seq_len, cfg.d_model), cfg.dtype),
                "tokens": batch["tokens"], "targets": batch["targets"]}
        state, m = step_fn(state, batch)
        first = first if first is not None else float(m["loss"])
        if i % 25 == 0 or i == args.steps - 1:
            print(f"[lm-mf] step {i:4d} loss={float(m['loss']):.4f}")
    print(f"[lm-mf] loss {first:.3f} -> {float(m['loss']):.3f} "
          f"({time.time() - t0:.1f}s)")

    with tempfile.TemporaryDirectory() as d:
        mgr = ckpt_mod.CheckpointManager(d)
        mgr.save_blocking(args.steps, state.params)
        restored = ckpt_mod.restore(d, state.params)
        same = all(bool(jnp.all(a == b)) for a, b in zip(
            jax.tree.leaves(restored), jax.tree.leaves(state.params)))
        print(f"[lm-mf] checkpoint round trip exact: {same}")


if __name__ == "__main__":
    main()
