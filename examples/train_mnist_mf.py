"""End-to-end driver: train the paper's MF LeNet-5 (Table I / Fig. 2).

    PYTHONPATH=src python examples/train_mnist_mf.py [--steps 400] \
        [--mode mf|regular|bnn] [--eval-cim]

Trains LeNet-5 on the synthetic MNIST-like task with the chosen operator
(the paper's mixed config: conv1/conv2/fc1 use the operator, the fc2
classifier stays typical), then optionally evaluates the trained network
through the CIM bitplane + SA-ADC simulator at the 8x62/5-bit design
point — the full algorithm->hardware loop of the paper.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cim import CimConfig
from repro.data.synthetic import image_batch
from repro.models import convnets as C

import sys
sys.path.insert(0, ".")
from benchmarks.common import train_image_classifier  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--mode", default="mf",
                    choices=["mf", "regular", "bnn"])
    ap.add_argument("--eval-cim", action="store_true")
    args = ap.parse_args()

    modes = {"conv1": args.mode, "conv2": args.mode, "fc1": args.mode,
             "fc2": "regular"}
    params = C.lenet_init(jax.random.PRNGKey(0))
    t0 = time.time()
    params, acc, hist = train_image_classifier(
        params, lambda p, x: C.lenet_apply(p, x, modes), steps=args.steps,
        batch=args.batch, n_classes=10, hw=28, channels=1)
    print(f"[mnist-mf] mode={args.mode} steps={args.steps} "
          f"loss {hist[0]:.3f} -> {hist[-1]:.3f} "
          f"accuracy={acc:.4f} ({time.time() - t0:.1f}s)")
    print("[mnist-mf] paper reference: MF 98.6% / conv 99.01% / BNN 97% "
          "(real MNIST)")

    if args.eval_cim:
        cim = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31)
        cmodes = {k: ("cim_sim" if v == "mf" else v)
                  for k, v in modes.items()}
        accs = []
        for j in range(4):
            x, y = image_batch(args.batch, 10, 28, 1, 50_000 + j)
            logits = C.lenet_apply(params, jnp.asarray(x), cmodes, cim)
            accs.append(float(jnp.mean(jnp.argmax(logits, -1)
                                       == jnp.asarray(y))))
        print(f"[mnist-mf] CIM (8x62 µArray, 5-bit SA-ADC) accuracy: "
              f"{np.mean(accs):.4f} (float-MF was {acc:.4f})")


if __name__ == "__main__":
    main()
