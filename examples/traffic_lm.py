"""Traffic-lab quickstart: serve a stochastic arrival stream with SLOs.

    PYTHONPATH=src python examples/traffic_lm.py --process mmpp --rate 40

Generates a keyed arrival trace (Poisson or bursty MMPP), serves it
through the continuous batcher in front of a fleet-faithful CIM serve
engine, and prints the TrafficReport: tok/s, SLO attainment, latency
percentiles, queue pressure, and the per-wave Eq. 4 energy roll-up.

``--mesh`` additionally shards the engine's decode batch over a
single-device serve mesh (bitwise identical to unsharded serving; on a
multi-device host set ``--mesh-data`` to the device count to shard the
slot axis for real).
"""

import argparse

import jax
import jax.numpy as jnp
import dataclasses

from repro.compiler.tiling import Fleet
from repro.configs.base import MFTechniqueConfig
from repro.configs.registry import get_config
from repro.core.cim import CimConfig
from repro.launch.mesh import make_serve_mesh
from repro.models import transformer as T
from repro.serve.engine import ServeEngine
from repro.traffic import (ContinuousBatcher, VirtualClock, WorkloadConfig,
                           generate, shard_engine)
from repro.traffic.report import from_run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--process", default="poisson",
                    choices=["poisson", "mmpp"])
    ap.add_argument("--rate", type=float, default=40.0,
                    help="offered requests/s (virtual-clock seconds)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--tick-s", type=float, default=0.01,
                    help="virtual cost of one decode step")
    ap.add_argument("--mesh", action="store_true",
                    help="serve through a sharded device mesh")
    ap.add_argument("--mesh-data", type=int, default=1,
                    help="data-axis size of the serve mesh")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cim = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31)
    cfg = dataclasses.replace(
        get_config("qwen3-0.6b", smoke=True), dtype=jnp.float32,
        mf=MFTechniqueConfig(mode="cim_sim", cim=cim))
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, slots=args.slots, max_len=64,
                         fleet=Fleet(n_macros=4096, cfg=cim))
    if args.mesh:
        n = args.mesh_data
        info = shard_engine(engine, make_serve_mesh(
            data=n, fleet=1, devices=jax.devices()[:n]))
        print(f"[traffic] mesh: {info}")

    wcfg = WorkloadConfig(
        rate_rps=args.rate, n_requests=args.requests, process=args.process,
        prompt_len_min=2, prompt_len_max=8, decode_len_min=4,
        decode_len_max=12, vocab_size=cfg.vocab_size,
        ttft_slo_s=60 * args.tick_s, tpot_slo_s=3 * args.tick_s,
        seed=args.seed)
    reqs = generate(wcfg)
    bat = ContinuousBatcher(engine, clock=VirtualClock(args.tick_s))
    rep = from_run(bat.run(reqs), engine)

    print(f"[traffic] {args.process} @ {rep.offered_rps:.1f} rps offered: "
          f"{rep.completed}/{rep.n_requests} completed "
          f"({rep.rejected} rejected, {rep.evicted} evicted)")
    print(f"[traffic] {rep.tok_s:.1f} tok/s, SLO attainment "
          f"{rep.slo_attainment:.3f}")
    print(f"[traffic] ttft p50/p99 = {rep.ttft_p50_s:.3f}/"
          f"{rep.ttft_p99_s:.3f}s  latency p50/p99 = "
          f"{rep.latency_p50_s:.3f}/{rep.latency_p99_s:.3f}s")
    print(f"[traffic] queue mean/max = {rep.queue_depth_mean:.1f}/"
          f"{rep.queue_depth_max}, slot utilization "
          f"{rep.slot_utilization:.2f}")
    if rep.wave is not None:
        print(f"[traffic] Eq.4 roll-up: "
              f"{rep.energy_per_token_j * 1e9:.2f} nJ/token over "
              f"{rep.wave.streams} streams")


if __name__ == "__main__":
    main()
