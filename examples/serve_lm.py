"""Batched serving with continuous batching (slot recycling).

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b

Runs the serve engine (smoke config) over a wave of synthetic requests:
prompts are ingested through the same jitted decode step, finished slots
are recycled for waiting requests. Works for every decode-capable arch,
including the recurrent ones (O(1) decode state).
"""

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_IDS)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if cfg.family == "encdec":
        raise SystemExit("enc-dec serving: see the whisper decode path in "
                         "tests/test_models.py")
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, slots=args.slots, max_len=64,
                         temperature=args.temperature)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(map(int, rng.integers(
        0, cfg.vocab_size, args.prompt_len))),
        max_new_tokens=args.new_tokens) for _ in range(args.requests)]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[serve] arch={args.arch} {len(done)}/{args.requests} requests "
          f"done, {toks} new tokens in {dt:.2f}s -> {toks / dt:.1f} tok/s "
          f"with {args.slots} slots")
    for i, r in enumerate(done[:3]):
        print(f"[serve] req{i} out[:8] = {r.out[:8]}")


if __name__ == "__main__":
    main()
