"""Quickstart: the MF-Net operator stack in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Shows the four execution modes of one projection — typical, MF operator,
fused Pallas kernel, and the bitplane + SA-ADC hardware simulation — plus
the Eq. 4 energy model and the mixed-mapping policy.
"""

import jax
import jax.numpy as jnp

from repro.core import (CimConfig, ExecMode, LayerStat, MappingPolicy,
                        apply_projection, mf_dense_init, plan_mapping,
                        tops_per_watt, unit_op_cycles)

key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (4, 62))                    # one µArray row's worth
params = mf_dense_init(jax.random.PRNGKey(1), 62, 8)

print("== one projection, four backends ==")
for mode in ("regular", "mf", "mf_kernel", "cim_sim"):
    y = apply_projection(params, x, mode, cim_cfg=CimConfig(8, 8, 5, 31))
    print(f"{mode:10s} -> {jnp.round(y[0, :4], 3)}")

print("\n== training through the MF surrogate gradients (Eq. 3) ==")
def loss(p):
    return jnp.sum(apply_projection(p, x, ExecMode.MF) ** 2)
grads = jax.grad(loss)(params)
print("grad norms:", {k: float(jnp.linalg.norm(v)) for k, v in grads.items()})

print("\n== Eq. 4 energy/latency model (Table II design points) ==")
for m, a in ((31, 5), (15, 4)):
    cfg = CimConfig(w_bits=8, x_bits=8, adc_bits=a, m_columns=m)
    print(f"8x{2 * m} µArray, {a}-bit ADC: "
          f"{tops_per_watt(cfg):6.1f} TOPS/W, "
          f"{unit_op_cycles(cfg)} cycles/unit-op")

print("\n== mixed mapping (Sec. VI): ops/param decides CIM vs digital ==")
stats = [LayerStat("conv1", 1_000, 10_000_000),
         LayerStat("fc_classifier", 1_000_000, 2_000_000)]
rep = plan_mapping(stats, MappingPolicy(threshold=2.0))
for s in stats:
    print(f"{s.name:14s} ops/param={s.ops_per_param:8.1f} "
          f"-> {rep.assignments[s.name].value}")

print("\n== macro compiler: LeNet onto a 32-macro 8x62 fleet ==")
from repro.compiler import (Fleet, compile_model, compiled_matmul,
                            layer_table, model_cost, rollup_summary)
from repro.models.convnets import lenet_layer_stats

fleet = Fleet(n_macros=32, cfg=CimConfig(8, 8, 5, 31))
msched = compile_model(lenet_layer_stats(), fleet)
costs, total = model_cost(msched)
print(layer_table(msched, costs))
print(rollup_summary(msched, total))

print("\n== tiled execution is bit-exact vs the monolithic simulator ==")
from repro.core import cim_mf_matmul
w62 = jax.random.normal(jax.random.PRNGKey(2), (62, 8))
x62 = jax.random.normal(jax.random.PRNGKey(3), (4, 62))
cfg62 = CimConfig(8, 8, 5, 31)
plan = fleet.plan(62, 8, name="demo", tile_k_chunks=1, tile_n=4)
y_tiled = compiled_matmul(x62, w62, plan, cfg62)
y_mono = cim_mf_matmul(x62, w62, cfg62)
print(f"{len(plan.k_slices)}x{len(plan.n_slices)} tile grid, "
      f"bit-exact: {bool(jnp.all(y_tiled == y_mono))}")
