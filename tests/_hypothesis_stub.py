"""Deterministic fallback for the slice of the hypothesis API this suite uses.

The tier-1 environment does not ship ``hypothesis``; rather than skipping
whole modules (``pytest.importorskip`` at import time would drop every test
in the file, property-based or not), test files guard the import:

    try:
        import hypothesis
        import hypothesis.strategies as st
    except ImportError:
        from _hypothesis_stub import hypothesis, st

When hypothesis is installed the real library is used unchanged. When it is
not, ``given`` degrades to a deterministic sweep over a small set of
representative samples per strategy (bounds, midpoint, seeded uniform
arrays) — weaker than property-based search, but it keeps every assertion
exercised.
"""

from __future__ import annotations

import functools
import itertools
import types

import numpy as np

_MAX_COMBOS = 9


class _Strategy:
    def __init__(self, samples):
        self._samples = list(samples)

    def samples(self):
        return self._samples


def integers(lo: int, hi: int) -> _Strategy:
    picks = dict.fromkeys((lo, hi, (lo + hi) // 2))
    return _Strategy(list(picks))


def floats(lo: float, hi: float, **_kw) -> _Strategy:
    s = _Strategy([float(lo), float(hi), (float(lo) + float(hi)) / 2.0])
    s.bounds = (float(lo), float(hi))
    return s


def arrays(dtype, shape, elements: _Strategy | None = None, **_kw) -> _Strategy:
    lo, hi = getattr(elements, "bounds", (-1.0, 1.0))
    out = []
    for seed in (0, 1):
        rng = np.random.default_rng(seed)
        out.append(rng.uniform(lo, hi, size=shape).astype(dtype))
    out.append(np.zeros(shape, dtype))
    return _Strategy(out)


def given(*strategies: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            combos = itertools.product(*[s.samples() for s in strategies])
            for combo in itertools.islice(combos, _MAX_COMBOS):
                fn(*args, *combo, **kwargs)
        # pytest follows __wrapped__ to the inner signature and would treat
        # the strategy-supplied parameters as fixtures; hide it.
        del wrapper.__wrapped__
        return wrapper
    return deco


def settings(**_kw):
    def deco(fn):
        return fn
    return deco


st = types.SimpleNamespace(integers=integers, floats=floats)
hypothesis = types.SimpleNamespace(
    given=given, settings=settings, strategies=st,
    extra=types.SimpleNamespace(numpy=types.SimpleNamespace(arrays=arrays)))
