"""Reprogram-aware serving: round-interleaved decode, batched prefill,
and the serving/calibration bug batch (ISSUE 4).

The contracts under test:

  * round partitions cover every µArray tile exactly once and the round
    count equals the compiler schedule's ``ceil(tiles / slots)``;
  * swapped (round-interleaved) execution is bit-identical to the pinned
    programmed path, standalone and through a served model;
  * ``ServeReport.reprogram_events`` equals
    ``ModelSchedule.total_reprogram_events x streams``;
  * batched programmed prefill matches prefill-as-decode greedy tokens
    and skips the per-prompt-token decode steps;
  * ``submit``/``run`` reject empty prompts, and ``run`` returns requests
    in submission order (multi-wave and timeout cases included);
  * ``collect_stats`` traces the observe forward once per batch shape.
"""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.schedule import schedule_layer
from repro.compiler.tiling import Fleet
from repro.configs.base import MFTechniqueConfig, ModelConfig
from repro.core.cim import CimConfig
from repro.core.programmed import (SwappedMacro, build_swap_schedule,
                                   cim_mf_matmul_programmed,
                                   cim_mf_matmul_swapped, default_static_sx,
                                   program_macro, program_weights,
                                   swap_macro)
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine

DESIGNS = [(31, 5), (15, 4)]


def _cfg(w_bits=4, x_bits=4, m=31, a=5, **kw):
    base = dict(
        name="serve-tiny", family="lm", n_layers=2, d_model=32,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
        dtype=jnp.float32,
        mf=MFTechniqueConfig(mode="cim_sim",
                             cim=CimConfig(w_bits, x_bits, a, m)))
    base.update(kw)
    return ModelConfig(**base)


class TestSwapSchedule:
    @pytest.mark.parametrize("k,n,m,slots", [
        (64, 48, 31, 16), (31, 5, 31, 3), (70, 7, 15, 10),
        (8, 3, 31, 100), (97, 33, 15, 64), (31, 1, 31, 1)])
    def test_rounds_cover_tiles_exactly_once(self, k, n, m, slots):
        sch = build_swap_schedule(k, n, m, slots)
        cover = np.zeros((sch.n_chunks, n), int)
        for segments in sch.rounds:
            tiles = 0
            for (n0, n1, k0, k1) in segments:
                assert 0 <= n0 < n1 <= n and 0 <= k0 < k1 <= k
                assert k0 % m == 0          # chunk-aligned slice starts
                c0, c1 = k0 // m, -(-k1 // m)
                cover[c0:c1, n0:n1] += 1
                tiles += (c1 - c0) * (n1 - n0)
            assert tiles <= slots           # round fits the fleet
        np.testing.assert_array_equal(cover, 1)

    @pytest.mark.parametrize("k,n,m,slots", [
        (64, 48, 31, 16), (70, 7, 15, 10), (97, 33, 15, 64)])
    def test_round_count_matches_compiler_schedule(self, k, n, m, slots):
        cfg = CimConfig(m_columns=m)
        fleet = Fleet(n_macros=slots, cfg=cfg, halves_per_macro=1)
        sched = schedule_layer(fleet.plan(k, n), fleet)
        assert build_swap_schedule(k, n, m, slots).n_rounds == sched.rounds

    def test_degenerate_inputs_raise(self):
        with pytest.raises(ValueError, match="degenerate"):
            build_swap_schedule(0, 4, 31, 8)
        with pytest.raises(ValueError, match="tile_slots"):
            build_swap_schedule(4, 4, 31, 0)


class TestSwappedMatmul:
    @pytest.mark.parametrize("m,a", DESIGNS)
    @pytest.mark.parametrize("w_bits", [4, 8])
    @pytest.mark.parametrize("slots", [3, 16, 1000])
    def test_bit_exact_vs_pinned_macro(self, m, a, w_bits, slots):
        cfg = CimConfig(w_bits=w_bits, x_bits=8, adc_bits=a, m_columns=m)
        x = jax.random.normal(jax.random.PRNGKey(0), (5, 70))
        w = jax.random.normal(jax.random.PRNGKey(1), (70, 9))
        sx = default_static_sx(cfg)
        y0 = np.asarray(cim_mf_matmul_programmed(
            x, program_macro(w, cfg, sx=sx), cfg))
        sm = swap_macro(w, cfg, slots, sx=sx)
        y1 = np.asarray(cim_mf_matmul_swapped(x, w, sm, cfg))
        np.testing.assert_array_equal(y0, y1)

    def test_stacked_swap_macro_slices_like_params(self):
        # Stacked (scan-period) weights: per-instance sw, one shared
        # static schedule; scanning over instances must reproduce each
        # instance's standalone swapped result.
        cfg = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31)
        w = jax.random.normal(jax.random.PRNGKey(2), (3, 40, 6))
        x = jax.random.normal(jax.random.PRNGKey(3), (4, 40))
        sx = default_static_sx(cfg)
        sm = swap_macro(w, cfg, 4, sx=sx)
        assert sm.sw.shape == (3,) and sm.sx.shape == (3,)

        def body(carry, inp):
            wi, smi = inp
            return carry, cim_mf_matmul_swapped(x, wi, smi, cfg)

        _, ys = jax.lax.scan(body, 0, (w, sm))
        for i in range(3):
            smi = swap_macro(w[i], cfg, 4, sx=sx)
            # allclose, not equal: scan-compiled and standalone programs
            # fuse the final recombine FMA differently (1-ulp noise, the
            # cross-program effect documented in EXPERIMENTS.md). The
            # bitwise contract — swapped vs pinned under the SAME program
            # — is asserted by TestFleetServing.
            np.testing.assert_allclose(
                np.asarray(ys[i]),
                np.asarray(cim_mf_matmul_swapped(x, w[i], smi, cfg)),
                rtol=1e-6)

    def test_program_weights_swap_hook_embeds_swapped_macros(self):
        cfg = _cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        from repro.compiler.frontend import projection_layer_stats
        _, groups = projection_layer_stats(params)
        progd = program_weights(params, cfg.mf.cim,
                                swap={g.name: 8 for g in groups})
        from repro.core.programmed import iter_projections
        for _, node, _ in iter_projections(progd):
            assert isinstance(node["prog"], SwappedMacro)

    def test_swap_hook_rejects_non_linear(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (3, 3, 2, 4))
        params = {"conv1": {"w": w, "alpha": jnp.ones((4,))}}
        with pytest.raises(NotImplementedError, match="linear"):
            program_weights(params, CimConfig(), swap={"conv1": 8})


class TestFleetServing:
    def _engines(self, fleet_macros, **eng_kw):
        cfg = _cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        fleet = Fleet(n_macros=fleet_macros, cfg=cfg.mf.cim)
        eng = ServeEngine(params, cfg, slots=2, max_len=16, fleet=fleet,
                          batched_prefill=False, **eng_kw)
        ref = ServeEngine(params, cfg, slots=2, max_len=16,
                          batched_prefill=False)
        return eng, ref

    def _serve(self, eng, n=4):
        done = eng.run([Request(prompt=[1, 2, 3], max_new_tokens=n)
                        for _ in range(2)])
        return [r.out for r in done]

    def test_pinned_fleet_matches_no_fleet_engine(self):
        eng, ref = self._engines(fleet_macros=1024)
        assert eng.schedule is not None and eng.schedule.pinned
        assert self._serve(eng) == self._serve(ref)
        rep = eng.last_report
        assert rep.pinned and rep.reprogram_events == 0
        assert rep.reload_bits == 0

    def test_round_interleaved_decode_is_bit_exact(self):
        # Fleet sized to force rounds > 1: every layer swaps, the deepest
        # one through multiple rounds, and tokens match the pinned path
        # bit for bit.
        eng, ref = self._engines(fleet_macros=8)
        sched = eng.schedule
        assert not sched.pinned and sched.rounds_max > 1
        assert self._serve(eng) == self._serve(ref)

    def test_report_reprogram_identity(self):
        eng, _ = self._engines(fleet_macros=8)
        self._serve(eng)
        rep = eng.last_report
        sched = eng.schedule
        assert rep.decode_steps == rep.streams > 0
        assert rep.reprogram_events == \
            sched.total_reprogram_events * rep.decode_steps
        assert rep.reload_bits == sched.total_reload_bits * rep.decode_steps
        assert rep.reload_energy_j == pytest.approx(
            rep.reload_bits * eng.fleet.reload_j_per_bit)
        assert rep.rounds_max == sched.rounds_max > 1
        assert 0.0 < rep.utilization <= 1.0

    def test_fleet_requires_programming(self):
        cfg = _cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        with pytest.raises(ValueError, match="fleet"):
            ServeEngine(params, cfg, slots=1, max_len=8, program=False,
                        fleet=Fleet(n_macros=8, cfg=cfg.mf.cim))

    def test_fleet_geometry_must_match_model(self):
        cfg = _cfg(m=31)
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        bad = Fleet(n_macros=8, cfg=CimConfig(m_columns=15))
        with pytest.raises(ValueError, match="geometry"):
            ServeEngine(params, cfg, slots=1, max_len=8, fleet=bad)

    def test_no_fleet_report_has_no_schedule_fields(self):
        cfg = _cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(params, cfg, slots=1, max_len=8,
                          batched_prefill=False)
        eng.run([Request(prompt=[1], max_new_tokens=2)])
        rep = eng.last_report
        assert rep.pinned is None and rep.reprogram_events == 0
        assert rep.decode_tokens == 2 and rep.tok_s > 0


class TestBatchedPrefill:
    def test_prefill_matches_as_decode_greedy_tokens(self):
        cfg = _cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        prompt = [1, 2, 3, 4, 5]
        eng_b = ServeEngine(params, cfg, slots=2, max_len=16)
        eng_d = ServeEngine(params, cfg, slots=2, max_len=16,
                            batched_prefill=False)
        assert eng_b.batched_prefill and not eng_d.batched_prefill
        out_b = [r.out for r in eng_b.run(
            [Request(prompt=prompt, max_new_tokens=5) for _ in range(2)])]
        out_d = [r.out for r in eng_d.run(
            [Request(prompt=prompt, max_new_tokens=5) for _ in range(2)])]
        assert out_b == out_d
        rb, rd = eng_b.last_report, eng_d.last_report
        # Prompt ingestion stops paying one decode step per token.
        assert rb.prefill_calls == 1
        assert rb.prefill_tokens == 2 * (len(prompt) - 1)
        assert rb.decode_steps == rd.decode_steps - (len(prompt) - 1)

    def test_prefill_wave_leaves_mid_decode_slots_untouched(self):
        # Serve request A alone past its prompt, then admit B (long
        # prompt, batched prefill wave): A's continuation must be
        # unchanged vs serving A with no neighbour.
        cfg = _cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)

        def serve_a(with_b):
            eng = ServeEngine(params, cfg, slots=2, max_len=16)
            a = Request(prompt=[1, 2], max_new_tokens=8)
            assert eng.submit(a)
            for _ in range(3):
                eng.step()
            if with_b:
                assert eng.submit(Request(prompt=[3, 4, 5, 6],
                                          max_new_tokens=2))
            while not a.done:
                eng.step()
            return a.out

        assert serve_a(with_b=False) == serve_a(with_b=True)

    def test_swapped_serving_composes_with_prefill(self):
        cfg = _cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        fleet = Fleet(n_macros=8, cfg=cfg.mf.cim)
        eng = ServeEngine(params, cfg, slots=2, max_len=16, fleet=fleet)
        ref = ServeEngine(params, cfg, slots=2, max_len=16)
        reqs = lambda: [Request(prompt=[1, 2, 3, 4], max_new_tokens=3)
                        for _ in range(2)]
        assert [r.out for r in eng.run(reqs())] == \
            [r.out for r in ref.run(reqs())]
        rep = eng.last_report
        assert rep.prefill_calls == 1
        # Prefill waves are input streams too: they reprogram the fleet.
        assert rep.streams == rep.decode_steps + 1
        assert rep.reprogram_events == \
            eng.schedule.total_reprogram_events * rep.streams

    def test_forcing_prefill_on_unsupported_arch_raises(self):
        cfg = _cfg(window=8)          # sliding-window ring cache
        assert not T.prefill_supported(cfg)
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        with pytest.raises(ValueError, match="prefill"):
            ServeEngine(params, cfg, slots=1, max_len=8,
                        batched_prefill=True)
        # auto mode silently falls back to prefill-as-decode
        eng = ServeEngine(params, cfg, slots=1, max_len=8)
        assert not eng.batched_prefill
        done = eng.run([Request(prompt=[1, 2, 3], max_new_tokens=2)])
        assert len(done[0].out) == 2


class TestSubmitRunBugfixes:
    def _engine(self, slots=2):
        cfg = _cfg(mf=MFTechniqueConfig(enabled=False))
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        return ServeEngine(params, cfg, slots=slots, max_len=32)

    def test_empty_prompt_rejected_on_submit(self):
        eng = self._engine()
        with pytest.raises(ValueError, match="empty prompt"):
            eng.submit(Request(prompt=[], max_new_tokens=2))
        # no partial admission happened
        assert eng.free_slots == [0, 1]

    def test_empty_prompt_rejected_on_run(self):
        eng = self._engine()
        good = Request(prompt=[1], max_new_tokens=2)
        with pytest.raises(ValueError, match="empty prompt"):
            eng.run([good, Request(prompt=[], max_new_tokens=2)])
        # rejected before any request was mutated
        assert good.out == [] and not good.done
        assert eng.free_slots == [0, 1]

    def test_overlong_prompt_rejected(self):
        # Symmetric to the empty-prompt guard: a prompt longer than the
        # KV cache would silently wrap and corrupt it (batched prefill
        # and prefill-as-decode alike).
        eng = self._engine()            # max_len=32
        with pytest.raises(ValueError, match="max_len"):
            eng.submit(Request(prompt=[1] * 33, max_new_tokens=1))
        with pytest.raises(ValueError, match="max_len"):
            eng.run([Request(prompt=[1] * 33, max_new_tokens=1)])
        assert eng.free_slots == [0, 1]

    def test_run_returns_submission_order_multi_wave(self):
        # 5 requests through 2 slots with staggered lengths: completion
        # order differs from submission order, the result must not.
        eng = self._engine()
        reqs = [Request(prompt=[i + 1], max_new_tokens=n)
                for i, n in enumerate([6, 1, 3, 1, 2])]
        done = eng.run(reqs)
        assert [id(r) for r in done] == [id(r) for r in reqs]
        assert all(len(r.out) == r.max_new_tokens and not r.timed_out
                   for r in done)

    def test_run_submission_order_with_timeout(self):
        eng = self._engine()
        reqs = [Request(prompt=[i + 1], max_new_tokens=50)
                for i in range(4)]
        done = eng.run(reqs, max_ticks=3)
        assert [id(r) for r in done] == [id(r) for r in reqs]
        assert all(r.timed_out for r in done)
        assert len(done[0].out) == 3          # partial output preserved
        assert len(done[2].out) == 0          # never scheduled
        assert eng.free_slots == [0, 1]

    def test_presubmitted_extras_append_after(self):
        eng = self._engine()
        direct = Request(prompt=[9], max_new_tokens=1)
        assert eng.submit(direct)
        reqs = [Request(prompt=[1], max_new_tokens=2)]
        done = eng.run(reqs)
        assert done[0] is reqs[0] and done[1] is direct


class TestCollectStatsJitsOnce:
    def test_observe_forward_traces_once_per_shape(self):
        from repro.calib.corpus import attach_observer_ids, collect_stats
        cfg = _cfg(w_bits=8, x_bits=8,
                   mf=MFTechniqueConfig(mode="mf"))
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        tagged, registry = attach_observer_ids(params)
        traces = 0

        def fwd(p, batch):
            nonlocal traces
            traces += 1
            return T.lm_forward(p, batch, cfg)[0]

        batches = [{"tokens": jax.random.randint(
            jax.random.PRNGKey(i), (2, 8), 0, cfg.vocab_size)}
            for i in range(4)]
        collector = collect_stats(fwd, tagged, batches, registry)
        assert traces == 1                   # jitted once, replayed 3x
        assert np.all(collector.count > 0)   # every projection observed

    def test_jitted_stats_match_eager_pass(self):
        from repro.calib import tap
        from repro.calib.corpus import (StatsCollector, attach_observer_ids,
                                        collect_stats)
        cfg = _cfg(w_bits=8, x_bits=8, mf=MFTechniqueConfig(mode="mf"))
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        tagged, registry = attach_observer_ids(params)
        batches = [{"tokens": jax.random.randint(
            jax.random.PRNGKey(i), (2, 8), 0, cfg.vocab_size)}
            for i in range(3)]

        def fwd(p, batch):
            return T.lm_forward(p, batch, cfg)[0]

        jit_col = collect_stats(fwd, tagged, batches, registry)
        eager_col = StatsCollector(registry.n_ids)
        with tap.observing(eager_col):
            for b in batches:
                jax.block_until_ready(fwd(tagged, b))
        jax.effects_barrier()
        np.testing.assert_allclose(jit_col.count, eager_col.count)
        np.testing.assert_allclose(jit_col.amax, eager_col.amax)
        np.testing.assert_allclose(jit_col.hist, eager_col.hist)
