"""Unit + property tests for the MF operator (paper Eq. 1-3)."""

try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
except ImportError:  # deterministic fallback, see tests/_hypothesis_stub.py
    from _hypothesis_stub import hypothesis, st
    hnp = hypothesis.extra.numpy
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (hw_sign, mf_correlate_ref, mf_correlate_step_form,
                        mf_matmul, mf_conv2d)

jax.config.update("jax_enable_x64", False)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape)


class TestMFIdentities:
    def test_self_correlation_is_twice_sum(self):
        # sign(x)|x| = x elementwise, so x (+) x = 2*sum(x); for x >= 0
        # this is the paper's 2*||x||_1.
        x = _rand(0, (1, 33))
        y = mf_correlate_ref(x, x[0][:, None])
        np.testing.assert_allclose(y[0, 0], 2 * jnp.sum(x), rtol=1e-5)

    def test_l1_norm_for_nonnegative(self):
        x = jnp.abs(_rand(1, (1, 17)))
        y = mf_correlate_ref(x, x[0][:, None])
        np.testing.assert_allclose(y[0, 0], 2 * jnp.sum(jnp.abs(x)),
                                   rtol=1e-5)

    def test_eq1_equals_eq2_reformulation(self):
        # Eq. 2 step-form identity holds under the hw sign convention.
        x = _rand(2, (5, 41))
        w = _rand(3, (41, 7))
        np.testing.assert_allclose(mf_correlate_ref(x, w, hw=True),
                                   mf_correlate_step_form(x, w),
                                   rtol=1e-4, atol=1e-4)

    def test_hw_sign_convention(self):
        v = jnp.array([-2.0, -0.0, 0.0, 3.0])
        np.testing.assert_array_equal(hw_sign(v), [-1.0, 1.0, 1.0, 1.0])

    def test_negation_antisymmetry(self):
        # (-x) (+) w = -(x (+) w) requires sign-flips on both terms; holds
        # elementwise when no exact zeros are present.
        x = _rand(4, (3, 21)) + 0.1
        w = _rand(5, (21, 4)) + 0.1
        np.testing.assert_allclose(mf_correlate_ref(-x, -w),
                                   -mf_correlate_ref(x, w), rtol=1e-4,
                                   atol=1e-4)

    @hypothesis.given(hnp.arrays(np.float32, (4, 13),
                                 elements=st.floats(-8, 8, width=32)))
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_scale_equivariance_abs_side(self, xs):
        # Scaling w by c > 0 scales sign(x)|w| by c and leaves sign(w)
        # unchanged: x (+) (c*w) = c*sign(x)@|w| + |x|@sign(w).
        w = np.linspace(-1, 1, 13 * 3, dtype=np.float32).reshape(13, 3) + 0.01
        x = jnp.asarray(xs)
        c = 2.5
        lhs = mf_correlate_ref(x, c * w)
        rhs = (c * (jnp.sign(x) @ jnp.abs(w)) + jnp.abs(x) @ jnp.sign(w))
        np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)


class TestMFGradients:
    def test_custom_vjp_matches_eq3(self):
        # dX = sign(X)*(g @ sign(W)^T) + 2*delta(X)*(g @ |W|^T)
        x = _rand(6, (3, 11))
        w = _rand(7, (11, 5))
        g = _rand(8, (3, 5))
        sigma, coeff = 0.5, 1.0
        _, vjp = jax.vjp(lambda a, b: mf_matmul(a, b, sigma, coeff), x, w)
        dx, dw = vjp(g)
        delta = lambda v: (1 / (sigma * np.sqrt(2 * np.pi))
                           * jnp.exp(-0.5 * (v / sigma) ** 2))
        dx_ref = (jnp.sign(x) * (g @ jnp.sign(w).T)
                  + 2 * delta(x) * (g @ jnp.abs(w).T))
        dw_ref = (jnp.sign(w) * (jnp.sign(x).T @ g)
                  + 2 * delta(w) * (jnp.abs(x).T @ g))
        np.testing.assert_allclose(dx, dx_ref, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(dw, dw_ref, rtol=1e-4, atol=1e-5)

    def test_grads_finite_and_nonzero(self):
        x = _rand(9, (4, 7))
        w = _rand(10, (7, 3))
        loss = lambda a, b: jnp.sum(mf_matmul(a, b) ** 2)
        dx, dw = jax.grad(loss, argnums=(0, 1))(x, w)
        assert bool(jnp.all(jnp.isfinite(dx)))
        assert bool(jnp.all(jnp.isfinite(dw)))
        assert float(jnp.max(jnp.abs(dw))) > 0

    def test_delta_coeff_zero_drops_delta_term(self):
        x = _rand(11, (2, 5))
        w = _rand(12, (5, 2))
        g = jnp.ones((2, 2))
        _, vjp = jax.vjp(lambda a, b: mf_matmul(a, b, 0.5, 0.0), x, w)
        dx, _ = vjp(g)
        np.testing.assert_allclose(dx, jnp.sign(x) * (g @ jnp.sign(w).T),
                                   rtol=1e-5, atol=1e-6)

    def test_batched_leading_dims(self):
        x = _rand(13, (2, 3, 7))
        w = _rand(14, (7, 4))
        y = mf_matmul(x, w)
        assert y.shape == (2, 3, 4)
        yr = mf_correlate_ref(x.reshape(-1, 7), w).reshape(2, 3, 4)
        np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)
        dw = jax.grad(lambda b: jnp.sum(mf_matmul(x, b)))(w)
        assert dw.shape == w.shape


class TestMFConv:
    def test_conv_matches_patch_oracle(self):
        x = _rand(15, (2, 8, 8, 3))
        w = _rand(16, (3, 3, 3, 5))
        y = mf_conv2d(x, w, padding="VALID")
        assert y.shape == (2, 6, 6, 5)
        # brute-force oracle at one spatial position
        patch = x[:, 2:5, 1:4, :]                       # (2,3,3,3)
        flat = patch.transpose(0, 3, 1, 2).reshape(2, -1)  # Cin,kh,kw order
        w2 = w.transpose(2, 0, 1, 3).reshape(-1, 5)
        ref = mf_correlate_ref(flat, w2)
        np.testing.assert_allclose(y[:, 2, 1, :], ref, rtol=1e-4, atol=1e-4)

    def test_conv_same_padding_shape(self):
        x = _rand(17, (1, 9, 9, 2))
        w = _rand(18, (3, 3, 2, 4))
        assert mf_conv2d(x, w, padding="SAME").shape == (1, 9, 9, 4)
        assert mf_conv2d(x, w, stride=(2, 2), padding="SAME").shape == (1, 5, 5, 4)
