"""Eq. 4 energy/latency model + mixed-mapping policy tests."""

import numpy as np

from repro.core import (CimConfig, LayerStat, MappingPolicy, ExecMode,
                        mixed_system_tops_per_watt, plan_mapping,
                        tops_per_watt, unit_op_cycles, unit_op_energy_j)
from repro.core.energy import (DIGITAL_TOPS_PER_W, discharge_time_vs_hold_voltage,
                               energy_split, leakage_vs_hold_voltage)


class TestEq4:
    def test_latency_formula(self):
        # T = W_P * (1 + 2 A_P); 8-bit, 5-bit ADC -> 88 cycles (Sec. V-C).
        assert unit_op_cycles(CimConfig(8, 8, 5, 31)) == 88
        assert unit_op_cycles(CimConfig(4, 8, 5, 31)) == 44
        assert unit_op_cycles(CimConfig(8, 8, 2, 31)) == 40

    def test_table2_design_points(self):
        # Calibrated to the paper's headline numbers (see core/energy.py).
        np.testing.assert_allclose(tops_per_watt(CimConfig(8, 8, 5, 31)),
                                   105.0, rtol=0.01)
        np.testing.assert_allclose(tops_per_watt(CimConfig(8, 8, 4, 15)),
                                   84.0, rtol=0.01)

    def test_energy_monotone_in_precision(self):
        e84 = unit_op_energy_j(CimConfig(8, 8, 4, 31))
        e85 = unit_op_energy_j(CimConfig(8, 8, 5, 31))
        e44 = unit_op_energy_j(CimConfig(4, 8, 4, 31))
        assert e44 < e84 < e85

    def test_case_a_vs_case_b_tradeoff(self):
        # Sec. V-C iso-accuracy cases: Case-A (W_P=8, A_P=2) has ~10% lower
        # latency than Case-B (W_P=4, A_P=5) — reproduced exactly (40 vs 44
        # cycles). The paper additionally claims Case-A needs ~30% MORE
        # energy; under Eq. 4b no constants consistent with the Table II
        # TOPS/W design points reproduce that ordering (see EXPERIMENTS.md
        # reproduction notes) — the calibrated model puts them within 3%.
        a = CimConfig(8, 8, 2, 31)
        b = CimConfig(4, 8, 5, 31)
        assert unit_op_cycles(a) < unit_op_cycles(b)
        ea, eb = unit_op_energy_j(a), unit_op_energy_j(b)
        assert abs(ea - eb) / eb < 0.05

    def test_energy_split_sums_to_one(self):
        s = energy_split(CimConfig(8, 8, 5, 31))
        np.testing.assert_allclose(sum(s.values()), 1.0, rtol=1e-6)
        assert s["leakage"] < 0.01  # paper: <1% of total

    def test_hold_voltage_tradeoff(self):
        # Fig. 6a: lower hold voltage -> less leakage, slower discharge.
        assert leakage_vs_hold_voltage(0.3) < leakage_vs_hold_voltage(0.5)
        assert (discharge_time_vs_hold_voltage(0.3)
                > discharge_time_vs_hold_voltage(0.5))


class TestMixedMapping:
    MNIST = [
        LayerStat("conv1", int(0.001 * 61706), int(0.8428 * 1e7)),
        LayerStat("conv2", int(0.0308 * 61706), int(0.067 * 1e7)),
        LayerStat("fc1", int(0.96 * 61706), int(0.0863 * 1e7)),
        LayerStat("fc2_classifier", 850, int(0.001 * 1e7)),
    ]

    def test_policy_assigns_classifier_digital(self):
        rep = plan_mapping(self.MNIST, MappingPolicy(threshold=2.0))
        assert rep.assignments["fc2_classifier"] == ExecMode.REGULAR
        assert rep.assignments["conv1"] == ExecMode.MF

    def test_override_wins(self):
        rep = plan_mapping(self.MNIST, MappingPolicy(
            overrides={"fc1": "mf"}))
        assert rep.assignments["fc1"] == ExecMode.MF

    def test_mf_ops_fraction_dominates(self):
        # Paper: >85% of ops are MF in the mixed configuration.
        rep = plan_mapping(self.MNIST, MappingPolicy(
            threshold=2.0, overrides={"fc1": "mf"}))
        assert rep.mf_ops_fraction > 0.85

    def test_mixed_tops_w_between_endpoints(self):
        cfg = CimConfig(8, 8, 5, 31)
        eff = mixed_system_tops_per_watt(0.99e9, 0.01e9, cfg)
        assert DIGITAL_TOPS_PER_W < eff < tops_per_watt(cfg)
        # MNIST mixed config: paper reports 103.97 with ~99.9% ops MF.
        eff_mnist = mixed_system_tops_per_watt(0.999e9, 0.001e9, cfg)
        assert 95.0 < eff_mnist < 105.0
