"""Fused Pallas silicon kernel: the step-time fast path for σ>0 fleets.

The contracts under test (ISSUE 7):

  * the in-kernel SA-ADC (``cim_mav_sil_pallas`` via ``ops
    .cim_mav_silicon``) matches its pure-jnp oracle bit for bit — the
    fixed-point cap fold (``core.cim.cap_fixed``) makes every pre-ADC
    numerator exact in float32 under any contraction order;
  * σ>0 parity matrix: the fused kernel route produces EXACTLY the
    integer ADC code sums of the reference einsum route on the pinned,
    tiled (compiler) and swapped (round-interleaved) layouts, at both
    paper design points, with and without thermal dither;
  * σ=0 silicon through the fused kernel is bitwise the nominal kernel
    fast path (which is itself bitwise the plane-state einsum route);
  * per-conversion thermal dither through the fused kernel is keyed by
    the conversion clock: same step ⇒ identical outputs, different
    steps decorrelate.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.cim import (CimConfig, cap_fixed, conversion_clock)
from repro.core.programmed import (cim_mf_matmul_programmed,
                                   cim_mf_matmul_swapped, program_macro,
                                   swap_macro)
from repro.kernels import ops
from repro.kernels.cim_mav import CHUNK_PAD, CHUNKS_PER_TILE
from repro.kernels.ref import cim_mav_sil_ref
from repro.silicon import SiliconConfig, projection_silicon, sample_fleet

SIGMA0 = SiliconConfig(cap_sigma=0.0, comparator_sigma_v=0.0)
NOISY = SiliconConfig(cap_sigma=0.08, comparator_sigma_v=0.012)
THERMAL = dataclasses.replace(NOISY, thermal_sigma_v=0.004)

DESIGNS = ((31, 5), (15, 4))


def _xw(b=3, k=70, n=9, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, k))
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, n))
    return x, w


def _proj_sil(scfg, k, n, m=31, slots=24, seed=5, base=0):
    fleet = sample_fleet(jax.random.PRNGKey(seed), slots, m, scfg)
    return projection_silicon(fleet, scfg, k, n, base=base)


def _cfgs(m, a):
    return (CimConfig(8, 8, a, m, use_kernel=True), CimConfig(8, 8, a, m))


class TestSilMavOracle:
    """cim_mav_silicon vs the pure-jnp oracle on pre-folded operands."""

    def _operands(self, pg, pp, b, c, n, seed=0):
        kp = c * CHUNK_PAD
        keys = jax.random.split(jax.random.PRNGKey(seed), 5)
        gates = jax.random.bernoulli(keys[0], 0.5,
                                     (pg, b, kp)).astype(jnp.float32)
        bits = jax.random.bernoulli(keys[1], 0.5,
                                    (pp, kp, n)).astype(jnp.float32)
        # Cap-folded stationary operand: bits weighted by fixed-point
        # caps, exactly like cim_program_silicon builds it.
        caps = cap_fixed(1.0 + 0.08 * jax.random.normal(keys[2], (kp, n)))
        planes = bits * caps[None]
        den = jnp.sum(
            caps.reshape(c, CHUNK_PAD, n), axis=1)              # (C, N)
        off = 0.01 * jax.random.normal(keys[3], (c, n))
        dither = 0.005 * jax.random.normal(keys[4],
                                           (pg * pp, c, b, n))
        return gates, planes, den, off, dither

    @pytest.mark.parametrize("pg,pp", [(1, 7), (7, 1), (1, 1)])
    @pytest.mark.parametrize("adc", [5, 4])
    def test_static_bitwise(self, pg, pp, adc):
        gates, planes, den, off, _ = self._operands(
            pg, pp, b=3, c=2 * CHUNKS_PER_TILE, n=9, seed=pg * 10 + adc)
        y = ops.cim_mav_silicon(gates, planes, den, off, adc_bits=adc)
        yr = cim_mav_sil_ref(gates, planes, den, off, adc_bits=adc)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))

    def test_dither_bitwise(self):
        gates, planes, den, off, dither = self._operands(
            1, 7, b=3, c=CHUNKS_PER_TILE, n=5, seed=3)
        y = ops.cim_mav_silicon(gates, planes, den, off, dither,
                                adc_bits=5)
        yr = cim_mav_sil_ref(gates, planes, den, off, dither, adc_bits=5)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
        y0 = ops.cim_mav_silicon(gates, planes, den, off, adc_bits=5)
        assert not np.array_equal(np.asarray(y), np.asarray(y0))

    def test_block_size_invariance(self):
        gates, planes, den, off, _ = self._operands(
            1, 7, b=12, c=CHUNKS_PER_TILE, n=17, seed=9)
        y1 = ops.cim_mav_silicon(gates, planes, den, off, adc_bits=5,
                                 bb=8, bn=128)
        y2 = ops.cim_mav_silicon(gates, planes, den, off, adc_bits=5,
                                 bb=16, bn=256)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


class TestFusedParityMatrix:
    """σ>0 fused-vs-einsum exactness on every serving layout."""

    @pytest.mark.parametrize("m,a", DESIGNS)
    @pytest.mark.parametrize("scfg", [NOISY, THERMAL],
                             ids=["static", "thermal"])
    def test_pinned(self, m, a, scfg):
        cfg_k, cfg_p = _cfgs(m, a)
        x, w = _xw()
        sil = _proj_sil(scfg, 70, 9, m=m)
        sx = quant.calibrate_scale(x, 8)
        prog_k = program_macro(w, cfg_k, sx=sx)
        prog_p = program_macro(w, cfg_p, sx=sx, prefer_lossless=False)
        y_k = cim_mf_matmul_programmed(x, prog_k, cfg_k, silicon=sil)
        y_p = cim_mf_matmul_programmed(x, prog_p, cfg_p, silicon=sil)
        np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_p))
        # σ>0 actually perturbs (the fused path runs real silicon).
        y_nom = cim_mf_matmul_programmed(x, prog_k, cfg_k)
        assert not np.array_equal(np.asarray(y_k), np.asarray(y_nom))

    @pytest.mark.parametrize("m,a", DESIGNS)
    @pytest.mark.parametrize("scfg", [NOISY, THERMAL],
                             ids=["static", "thermal"])
    def test_tiled(self, m, a, scfg):
        from repro.compiler.execute import (compiled_matmul_programmed,
                                            program_layer_tiles)
        from repro.compiler.tiling import plan_tiling
        cfg_k, cfg_p = _cfgs(m, a)
        x, w = _xw(k=3 * m + 7, n=21, seed=2)
        plan = plan_tiling(w.shape[0], w.shape[1], cfg_p, tile_k_chunks=2,
                           tile_n=8)
        sx = quant.calibrate_scale(x, 8)
        prog = program_layer_tiles(w, plan, cfg_p, sx=sx)
        sil = _proj_sil(scfg, w.shape[0], w.shape[1], m=m, slots=96)
        y_k = compiled_matmul_programmed(x, prog, plan, cfg_k, silicon=sil)
        y_p = compiled_matmul_programmed(x, prog, plan, cfg_p, silicon=sil)
        np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_p))

    @pytest.mark.parametrize("m,a", DESIGNS)
    @pytest.mark.parametrize("scfg", [NOISY, THERMAL],
                             ids=["static", "thermal"])
    def test_swapped(self, m, a, scfg):
        cfg_k, cfg_p = _cfgs(m, a)
        x, w = _xw(k=3 * m, n=7, seed=4)
        sx = quant.calibrate_scale(x, 8)
        swap = swap_macro(w, cfg_p, tile_slots=5, sx=sx)
        assert swap.sched.n_rounds > 1
        sil = _proj_sil(scfg, w.shape[0], w.shape[1], m=m, slots=5)
        y_k = cim_mf_matmul_swapped(x, w, swap, cfg_k, silicon=sil)
        y_p = cim_mf_matmul_swapped(x, w, swap, cfg_p, silicon=sil)
        np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_p))


class TestSigma0Collapse:
    @pytest.mark.parametrize("m,a", DESIGNS)
    def test_fused_sigma0_is_bitwise_nominal(self, m, a):
        cfg_k, cfg_p = _cfgs(m, a)
        x, w = _xw()
        sil0 = _proj_sil(SIGMA0, 70, 9, m=m)
        sx = quant.calibrate_scale(x, 8)
        prog_k = program_macro(w, cfg_k, sx=sx)
        y_sil = cim_mf_matmul_programmed(x, prog_k, cfg_k, silicon=sil0)
        y_nom = cim_mf_matmul_programmed(x, prog_k, cfg_k)
        np.testing.assert_array_equal(np.asarray(y_sil), np.asarray(y_nom))
        # ... which is itself bitwise the plane-state einsum route.
        prog_p = program_macro(w, cfg_p, sx=sx, prefer_lossless=False)
        y_ref = cim_mf_matmul_programmed(x, prog_p, cfg_p)
        np.testing.assert_array_equal(np.asarray(y_nom), np.asarray(y_ref))


class TestThermalClock:
    def test_dither_keyed_by_conversion_step(self):
        cfg_k, _ = _cfgs(31, 5)
        x, w = _xw()
        sil = _proj_sil(THERMAL, 70, 9)
        sx = quant.calibrate_scale(x, 8)
        prog = program_macro(w, cfg_k, sx=sx)

        def run(step):
            with conversion_clock(step):
                return np.asarray(
                    cim_mf_matmul_programmed(x, prog, cfg_k, silicon=sil))

        np.testing.assert_array_equal(run(3), run(3))   # replayable
        assert not np.array_equal(run(3), run(4))       # decorrelates
