"""Distribution-layer tests: sharding rules + a multi-device subprocess
check of the EP MoE and a miniature production-mesh dry-run."""

import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ParallelConfig
from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.parallel import sharding as shd


def _mesh_stub(axis_sizes):
    class M:
        axis_names = tuple(axis_sizes)
        class devices:
            shape = tuple(axis_sizes.values())
    return M


class TestParamSpecs:
    def _specs(self, arch="qwen3-0.6b", pcfg=None, axes=None):
        cfg = get_config(arch, smoke=False)
        params = jax.eval_shape(lambda: T.lm_init(jax.random.PRNGKey(0),
                                                  cfg))
        pcfg = pcfg or ParallelConfig()
        axis_sizes = axes or {"data": 16, "model": 16}
        return jax.tree_util.tree_map_with_path(
            lambda p, l: shd.spec_for_param(p, l, pcfg, axis_sizes), params)

    def test_megatron_pattern(self):
        specs = self._specs()
        layer = specs["layers"][0]
        assert layer["attn"]["q"]["w"] == P(None, "data", "model")
        assert layer["attn"]["o"]["w"] == P(None, "model", "data")
        assert layer["mlp"]["up"]["w"] == P(None, "data", "model")
        assert layer["mlp"]["down"]["w"] == P(None, "model", "data")
        assert specs["embed"]["table"] == P("model", "data")

    def test_divisibility_guard(self):
        # whisper vocab 51865 is not divisible by 16 -> unsharded vocab dim
        cfg = get_config("whisper-base")
        from repro.models import encdec as E
        params = jax.eval_shape(lambda: E.encdec_init(jax.random.PRNGKey(0),
                                                      cfg))
        specs = jax.tree_util.tree_map_with_path(
            lambda p, l: shd.spec_for_param(p, l, ParallelConfig(),
                                            {"data": 16, "model": 16}),
            params)
        assert specs["embed"]["table"] == P(None, "data")

    def test_expert_specs_follow_ep_axes(self):
        cfg = get_config("deepseek-v3-671b")
        params = jax.eval_shape(lambda: T.lm_init(jax.random.PRNGKey(0),
                                                  cfg))
        pcfg = ParallelConfig(ep_axes=("data", "model"))
        specs = jax.tree_util.tree_map_with_path(
            lambda p, l: shd.spec_for_param(p, l, pcfg,
                                            {"data": 16, "model": 16}),
            params)
        up = specs["layers"][0]["moe"]["experts"]["up"]
        assert up == P(None, ("data", "model"), None, None)

    def test_norms_replicated(self):
        specs = self._specs()
        assert specs["final_norm"]["scale"] == P()

    def test_cache_specs_match_cache_structure(self):
        for arch in ("qwen3-0.6b", "recurrentgemma-2b", "xlstm-350m",
                     "deepseek-v3-671b"):
            cfg = get_config(arch)
            cache = jax.eval_shape(lambda c=cfg: T.lm_init_cache(c, 8, 64))
            pcfg = ParallelConfig()
            specs = T.lm_cache_pspecs(cfg, cache, pcfg,
                                      {"data": 16, "model": 16})
            # structures must match exactly (same treedef)
            jax.tree.map(lambda a, b: None, cache, specs,
                         is_leaf=lambda x: isinstance(x, P))


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from repro.configs.base import (ModelConfig, MoEConfig, ParallelConfig,
                                    MFTechniqueConfig)
    from repro.models import transformer as T

    from repro.launch.mesh import auto_axis_types
    mesh = jax.make_mesh((2, 4), ("data", "model"), **auto_axis_types(2))
    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      moe=MoEConfig(n_experts=8, top_k=2, n_shared=1,
                                    d_ff_expert=48, capacity_factor=4.0,
                                    expert_capacity_factor=4.0),
                      dtype=jnp.float32, mf=MFTechniqueConfig(enabled=False))
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                          0, 64),
             "targets": jax.random.randint(jax.random.PRNGKey(2), (4, 16),
                                           0, 64)}
    ref, _ = T.lm_loss(params, batch, cfg)
    pcfg = ParallelConfig(remat="none")
    pctx = T.ParallelContext(mesh=mesh, cfg=pcfg)
    with mesh:
        ep, _ = jax.jit(lambda p, b: T.lm_loss(p, b, cfg, pctx))(params,
                                                                 batch)
    diff = abs(float(ref) - float(ep))
    assert diff < 0.05, diff
    # mini production-style dry-run on the 2x4 mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel import sharding as shd
    specs = shd.params_pspecs(jax.eval_shape(
        lambda: T.lm_init(jax.random.PRNGKey(0), cfg)), pcfg, mesh)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                      is_leaf=lambda x: isinstance(x, P))
    with mesh:
        lowered = jax.jit(lambda p, b: T.lm_loss(p, b, cfg, pctx)[0],
                          in_shardings=(sh, {"tokens": NamedSharding(
                              mesh, P("data", None)), "targets":
                              NamedSharding(mesh, P("data", None))})
                          ).lower(jax.eval_shape(
                              lambda: T.lm_init(jax.random.PRNGKey(0),
                                                cfg)), batch)
        compiled = lowered.compile()
        assert compiled.cost_analysis() is not None
    print("MULTIDEV_OK", diff)
""")


@pytest.mark.slow
def test_ep_moe_multidevice_subprocess():
    """EP MoE == dense MoE on a real 2x4 device mesh (subprocess so the
    fake device count doesn't leak into this test session)."""
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                       capture_output=True, text=True, timeout=540,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr
