"""Silicon lab: per-slot ADC instances, σ=0 parity, drift recalibration.

The contracts under test (ISSUE 5):

  * sampling is keyed-deterministic and mergeable;
  * a σ=0 silicon fleet is BITWISE identical to the nominal programmed
    datapath — monolithic, tiled, pinned-engine and swapped-engine decode;
  * σ>0 perturbs (the whole point) and injection composes with bit-packed
    plane state AND the fused Pallas kernel layout (in-kernel SA-ADC,
    bit-equal to the reference einsums) while the collapsed lossless
    state and the legacy knobs-on-kernel combination raise precisely;
  * the serving drift loop: alarm fires on an aging fleet, comparator
    re-trim + scale re-programming recovers, ServeReport charges it.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.cim import CimConfig, cim_mf_matmul
from repro.core.programmed import (cim_mf_matmul_programmed,
                                   cim_mf_matmul_swapped, program_macro,
                                   swap_macro)
from repro.silicon import (SiliconConfig, attach_silicon,
                           merge, projection_silicon,
                           recalibrate_comparators, sample_fleet,
                           strip_silicon)
from repro.silicon import instance as inst

SIGMA0 = SiliconConfig(cap_sigma=0.0, comparator_sigma_v=0.0)
NOISY = SiliconConfig(cap_sigma=0.08, comparator_sigma_v=0.012)


def _xw(b=3, k=70, n=9):
    x = jax.random.normal(jax.random.PRNGKey(0), (b, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    return x, w


def _proj_sil(scfg, k, n, m=31, slots=24, seed=5, base=0):
    fleet = sample_fleet(jax.random.PRNGKey(seed), slots, m, scfg)
    return projection_silicon(fleet, scfg, k, n, base=base)


class TestSampling:
    def test_same_key_same_fleet(self):
        a = sample_fleet(jax.random.PRNGKey(3), 16, 31, NOISY)
        b = sample_fleet(jax.random.PRNGKey(3), 16, 31, NOISY)
        for fa, fb in zip(a, b):
            np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))

    def test_different_keys_differ(self):
        a = sample_fleet(jax.random.PRNGKey(3), 16, 31, NOISY)
        b = sample_fleet(jax.random.PRNGKey(4), 16, 31, NOISY)
        assert not np.array_equal(np.asarray(a.cap), np.asarray(b.cap))

    def test_sigma0_is_exactly_nominal(self):
        assert SIGMA0.is_nominal and not NOISY.is_nominal
        s = sample_fleet(jax.random.PRNGKey(0), 8, 31, SIGMA0)
        np.testing.assert_array_equal(np.asarray(s.cap), 1.0)
        np.testing.assert_array_equal(
            np.asarray(inst.effective_offsets(s, SIGMA0)), 0.0)

    def test_merge_concatenates_slots(self):
        a = sample_fleet(jax.random.PRNGKey(1), 8, 31, NOISY)
        b = sample_fleet(jax.random.PRNGKey(2), 5, 31, NOISY)
        m = merge(a, b)
        assert m.n_slots == 13
        np.testing.assert_array_equal(np.asarray(m.cap[:8]),
                                      np.asarray(a.cap))
        np.testing.assert_array_equal(np.asarray(m.offset_v[8:]),
                                      np.asarray(b.offset_v))

    def test_comparator_correction_shrinks_offsets(self):
        scfg = SiliconConfig(cap_sigma=0.0, comparator_sigma_v=0.015)
        cal = sample_fleet(jax.random.PRNGKey(6), 64, 31, scfg)
        raw = sample_fleet(
            jax.random.PRNGKey(6), 64, 31,
            dataclasses.replace(scfg, calibrate_comparator=False))
        eff_cal = np.abs(np.asarray(inst.effective_offsets(cal, scfg)))
        eff_raw = np.abs(np.asarray(inst.effective_offsets(raw, scfg)))
        assert eff_cal.mean() < eff_raw.mean()
        # residue <= half a cal-DAC LSB (1.5 sigma at 2 bits)
        assert eff_cal.max() <= 0.75 * 0.015 / scfg.v_full_scale + 1e-6

    def test_recalibration_cancels_drift(self):
        scfg = dataclasses.replace(
            NOISY, drift_sigma_v_per_kstream=0.5)
        s = sample_fleet(jax.random.PRNGKey(8), 32, 31, scfg)
        s = inst.age(s, 100)      # drift ~ 50 mV * dir
        drifted = np.abs(np.asarray(inst.effective_offsets(s, scfg)))
        healed = np.abs(np.asarray(
            inst.effective_offsets(recalibrate_comparators(s, scfg),
                                   scfg)))
        assert healed.mean() < drifted.mean()


class TestSigma0Parity:
    @pytest.mark.parametrize("m,a", [(31, 5), (15, 4), (31, 6)])
    def test_monolithic_bitwise(self, m, a):
        x, w = _xw()
        cfg = CimConfig(8, 8, a, m)
        sil = _proj_sil(SIGMA0, 70, 9, m=m)
        y0 = np.asarray(cim_mf_matmul(x, w, cfg))
        y1 = np.asarray(cim_mf_matmul(x, w, cfg, silicon=sil))
        np.testing.assert_array_equal(y0, y1)

    def test_programmed_bitwise(self):
        x, w = _xw()
        cfg = CimConfig(8, 8, 5, 31)
        sx = quant.calibrate_scale(x, 8)
        prog = program_macro(w, cfg, sx=sx, prefer_lossless=False)
        sil = _proj_sil(SIGMA0, 70, 9)
        y0 = np.asarray(cim_mf_matmul_programmed(x, prog, cfg))
        y1 = np.asarray(cim_mf_matmul_programmed(x, prog, cfg,
                                                 silicon=sil))
        np.testing.assert_array_equal(y0, y1)

    def test_swapped_bitwise(self):
        x, w = _xw(k=93, n=7)
        cfg = CimConfig(8, 8, 5, 31)
        sx = quant.calibrate_scale(x, 8)
        swap = swap_macro(w, cfg, tile_slots=5, sx=sx)
        assert swap.sched.n_rounds > 1
        sil = _proj_sil(SIGMA0, 93, 7, slots=5)
        y0 = np.asarray(cim_mf_matmul_swapped(x, w, swap, cfg))
        y1 = np.asarray(cim_mf_matmul_swapped(x, w, swap, cfg,
                                              silicon=sil))
        np.testing.assert_array_equal(y0, y1)

    def test_tiled_bitwise(self):
        from repro.compiler.execute import (compiled_matmul_programmed,
                                            program_layer_tiles)
        from repro.compiler.tiling import plan_tiling
        cfg = CimConfig(8, 8, 5, 31)
        x, w = _xw(k=3 * 31 + 7, n=21)
        plan = plan_tiling(w.shape[0], w.shape[1], cfg, tile_k_chunks=2,
                           tile_n=8)
        sx = quant.calibrate_scale(x, 8)
        prog = program_layer_tiles(w, plan, cfg, sx=sx)
        sil = _proj_sil(SIGMA0, w.shape[0], w.shape[1], slots=40)
        y0 = np.asarray(compiled_matmul_programmed(x, prog, plan, cfg))
        y1 = np.asarray(compiled_matmul_programmed(x, prog, plan, cfg,
                                                   silicon=sil))
        np.testing.assert_array_equal(y0, y1)


class TestInjection:
    def test_sigma_perturbs_and_matches_across_paths(self):
        """σ>0 changes the output, and swapped/tiled/monolithic all agree
        bit for bit on the SAME sampled silicon."""
        cfg = CimConfig(8, 8, 5, 31)
        x, w = _xw(k=93, n=7)
        sx = quant.calibrate_scale(x, 8)
        sil = _proj_sil(NOISY, 93, 7, slots=5)
        y0 = np.asarray(cim_mf_matmul(x, w, cfg))
        y1 = np.asarray(cim_mf_matmul(x, w, cfg, silicon=sil))
        assert not np.array_equal(y0, y1)
        swap = swap_macro(w, cfg, tile_slots=5, sx=sx)
        y2 = np.asarray(cim_mf_matmul_swapped(x, w, swap, cfg,
                                              silicon=sil))
        # swapped rounds fill slots 0..S-1 == the base-0 gather
        prog = program_macro(w, cfg, sx=sx, prefer_lossless=False)
        y3 = np.asarray(cim_mf_matmul_programmed(x, prog, cfg,
                                                 silicon=sil))
        np.testing.assert_array_equal(y2, y3)

    def test_packed_planes_accept_silicon(self):
        cfg = CimConfig(8, 8, 4, 31)   # non-lossless -> plane state
        x, w = _xw()
        prog = program_macro(w, cfg, sx=0.05)
        assert prog.state is not None
        cim_mf_matmul_programmed(x, prog, cfg,
                                 silicon=_proj_sil(NOISY, 70, 9))

    def test_lossless_state_raises_precisely(self):
        cfg = CimConfig(8, 8, 5, 31)
        x, w = _xw()
        prog = program_macro(w, cfg, sx=0.05)
        assert prog.lossless is not None
        with pytest.raises(ValueError, match="exactly-lossless"):
            cim_mf_matmul_programmed(x, prog, cfg,
                                     silicon=_proj_sil(NOISY, 70, 9))
        with pytest.raises(ValueError, match="prefer_lossless=False"):
            cim_mf_matmul_programmed(x, prog, cfg,
                                     cap_weights=jnp.ones((70,)))

    def test_kernel_state_runs_silicon_fused(self):
        # Silicon on the kernel layout is the fused fast path now: the
        # SA-ADC instances evaluate inside the Pallas kernel, bit-equal
        # to the plane-state reference einsums.
        cfg_k = CimConfig(8, 8, 5, 31, use_kernel=True)
        cfg_p = CimConfig(8, 8, 5, 31)
        x, w = _xw()
        sil = _proj_sil(NOISY, 70, 9)
        prog_k = program_macro(w, cfg_k, sx=0.05)
        assert prog_k.kernel is not None
        prog_p = program_macro(w, cfg_p, sx=0.05, prefer_lossless=False)
        y_k = cim_mf_matmul_programmed(x, prog_k, cfg_k, silicon=sil)
        y_p = cim_mf_matmul_programmed(x, prog_p, cfg_p, silicon=sil)
        np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_p))

    def test_kernel_state_rejects_legacy_knobs(self):
        cfg = CimConfig(8, 8, 5, 31, use_kernel=True)
        x, w = _xw()
        prog = program_macro(w, cfg, sx=0.05)
        with pytest.raises(ValueError, match="Pallas kernel"):
            cim_mf_matmul_programmed(x, prog, cfg,
                                     cap_weights=jnp.ones((70,)))

    def test_silicon_exclusive_with_legacy_knobs(self):
        cfg = CimConfig(8, 8, 5, 31)
        x, w = _xw()
        with pytest.raises(ValueError, match="not both"):
            cim_mf_matmul(x, w, cfg, cap_weights=jnp.ones((70,)),
                          silicon=_proj_sil(NOISY, 70, 9))

    def test_shape_mismatch_raises(self):
        cfg = CimConfig(8, 8, 5, 31)
        x, w = _xw()
        with pytest.raises(ValueError, match="does not match"):
            cim_mf_matmul(x, w, cfg, silicon=_proj_sil(NOISY, 70, 5))

    def test_misaligned_slice_raises(self):
        sil = _proj_sil(NOISY, 93, 7, slots=5)
        with pytest.raises(ValueError, match="aligned"):
            sil.slice(0, 2, 7, 62, 31)


class TestAttach:
    def test_attach_and_strip_round_trip(self):
        from repro.core.mf import mf_dense_init
        from repro.core.programmed import iter_projections
        params = {"a": mf_dense_init(jax.random.PRNGKey(0), 40, 6),
                  "b": {"c": mf_dense_init(jax.random.PRNGKey(1), 33, 4)}}
        fleet = sample_fleet(jax.random.PRNGKey(2), 16, 31, NOISY)
        cim = CimConfig(8, 8, 5, 31)
        tagged = attach_silicon(params, fleet, NOISY, cim)
        names = [n for n, _, _ in iter_projections(tagged)]
        assert all("sil" in node for _, node, _ in
                   iter_projections(tagged)), names
        stripped = strip_silicon(tagged)
        assert all("sil" not in node for _, node, _ in
                   iter_projections(stripped))

    def test_strip_preserves_programmed_namedtuples(self):
        """strip_silicon on a PROGRAMMED tree must leave the
        ProgrammedMacro pytree nodes intact (NamedTuples are leaves of
        the walk, not plain tuples to rebuild) — and strip_programmed
        must not corrupt ProjectionSilicon entries either."""
        from repro.core.mf import mf_dense_init
        from repro.core.programmed import (ProgrammedMacro, program_weights,
                                           strip_programmed)
        cim = CimConfig(8, 8, 5, 31)
        params = {"a": mf_dense_init(jax.random.PRNGKey(0), 40, 6)}
        fleet = sample_fleet(jax.random.PRNGKey(2), 16, 31, NOISY)
        progd = program_weights(params, cim, prefer_lossless=False)
        full = attach_silicon(progd, fleet, NOISY, cim)
        no_sil = strip_silicon(full)
        assert isinstance(no_sil["a"]["prog"], ProgrammedMacro)
        no_prog = strip_programmed(full)
        assert type(no_prog["a"]["sil"]).__name__ == "ProjectionSilicon"
        assert "prog" not in no_prog["a"]

    def test_pinned_bases_advance_in_walk_order(self):
        from repro.core.mf import mf_dense_init
        params = {"a": mf_dense_init(jax.random.PRNGKey(0), 31, 2),
                  "b": mf_dense_init(jax.random.PRNGKey(1), 31, 2)}
        fleet = sample_fleet(jax.random.PRNGKey(2), 16, 31, NOISY)
        cim = CimConfig(8, 8, 5, 31)
        tagged = attach_silicon(params, fleet, NOISY, cim, pinned=True)
        # layer a: tiles 0..1 -> slots 0..1; layer b -> slots 2..3
        eff = np.asarray(inst.effective_offsets(fleet, NOISY))
        np.testing.assert_array_equal(
            np.asarray(tagged["a"]["sil"].offset).ravel(), eff[0:2])
        np.testing.assert_array_equal(
            np.asarray(tagged["b"]["sil"].offset).ravel(), eff[2:4])
        swapped = attach_silicon(params, fleet, NOISY, cim, pinned=False)
        np.testing.assert_array_equal(
            np.asarray(swapped["b"]["sil"].offset).ravel(), eff[0:2])

    def test_geometry_mismatch_raises(self):
        from repro.core.mf import mf_dense_init
        params = {"a": mf_dense_init(jax.random.PRNGKey(0), 31, 2)}
        fleet = sample_fleet(jax.random.PRNGKey(2), 16, 15, NOISY)
        with pytest.raises(ValueError, match="m_columns"):
            attach_silicon(params, fleet, NOISY, CimConfig(8, 8, 5, 31))


class TestMonteCarlo:
    def test_sqnr_samples_deterministic_and_ordered(self):
        from repro.silicon.montecarlo import projection_sqnr_samples
        cim = CimConfig(8, 8, 5, 31)
        x, w = _xw(k=62, n=16)
        lo = projection_sqnr_samples(
            jax.random.PRNGKey(0), x, w, cim,
            SiliconConfig(cap_sigma=0.03, comparator_sigma_v=0.0), 8)
        hi = projection_sqnr_samples(
            jax.random.PRNGKey(0), x, w, cim,
            SiliconConfig(cap_sigma=0.15, comparator_sigma_v=0.0), 8)
        again = projection_sqnr_samples(
            jax.random.PRNGKey(0), x, w, cim,
            SiliconConfig(cap_sigma=0.03, comparator_sigma_v=0.0), 8)
        np.testing.assert_array_equal(np.asarray(lo), np.asarray(again))
        assert float(jnp.mean(lo)) > float(jnp.mean(hi))

    def test_offset_correction_recovers(self):
        from repro.silicon.montecarlo import offset_correction_delta_db
        cim = CimConfig(8, 8, 5, 31)
        x, w = _xw(k=62, n=16)
        delta, on_db, off_db = offset_correction_delta_db(
            jax.random.PRNGKey(1), x, w, cim,
            SiliconConfig(comparator_sigma_v=0.008), 8)
        assert delta > 0 and on_db > off_db


class TestLegacyShim:
    def test_core_variability_reexports(self):
        from repro.core import variability as legacy
        from repro.silicon import variability as lab
        assert legacy.VariabilityConfig is lab.VariabilityConfig
        assert legacy.sample_cap_weights is lab.sample_cap_weights
        from repro.core import VariabilityConfig  # package-level path
        assert VariabilityConfig is lab.VariabilityConfig


def _engine_cfg():
    from repro.configs.base import MFTechniqueConfig
    from repro.configs.qwen3_0_6b import SMOKE
    cim = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31)
    return dataclasses.replace(
        SMOKE, dtype=jnp.float32,
        mf=MFTechniqueConfig(mode="cim_sim", cim=cim)), cim


class TestEngineGuards:
    def test_silicon_requires_fleet_and_no_kernel(self):
        from repro.models import transformer as T
        from repro.serve.engine import ServeEngine
        cfg, cim = _engine_cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        with pytest.raises(ValueError, match="fleet"):
            ServeEngine(params, cfg, slots=2, max_len=16, silicon=SIGMA0)

    def test_drift_requires_calibration(self):
        from repro.compiler.tiling import Fleet
        from repro.models import transformer as T
        from repro.serve.engine import ServeEngine
        from repro.silicon.drift import DriftPolicy
        cfg, cim = _engine_cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        with pytest.raises(ValueError, match="calibration"):
            ServeEngine(params, cfg, slots=2, max_len=16,
                        fleet=Fleet(n_macros=4096, cfg=cim),
                        silicon=SIGMA0,
                        drift=DriftPolicy(probe_batches=[]))


@pytest.mark.slow
class TestEngineSilicon:
    """Engine-level σ=0 parity and the drift loop (compile-heavy; covered
    by the silicon-report bench gates in CI — run explicitly with
    ``-m slow`` or plain ``pytest``)."""

    def _cfg(self):
        return _engine_cfg()

    def test_engine_sigma0_and_drift_loop(self):
        from repro.calib.report import calibrate_lm
        from repro.compiler.tiling import Fleet
        from repro.data.synthetic import DataConfig, lm_batch
        from repro.models import transformer as T
        from repro.serve.engine import Request, ServeEngine
        from repro.silicon.drift import DriftPolicy
        cfg, cim = self._cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        fleet = Fleet(n_macros=4096, cfg=cim)

        def toks(e, n=3):
            done = e.run([Request(prompt=[1, 2], max_new_tokens=n)
                          for _ in range(2)])
            return [r.out for r in done]

        ref = ServeEngine(params, cfg, slots=2, max_len=48, fleet=fleet,
                          batched_prefill=False)
        t_ref = toks(ref)
        sil0 = ServeEngine(params, cfg, slots=2, max_len=48, fleet=fleet,
                           batched_prefill=False, silicon=SIGMA0)
        assert toks(sil0) == t_ref

        # drift loop: alarm -> recalibrate -> recover -> charged
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                        global_batch=4, task="uniform")
        cal = [{"tokens": jnp.asarray(lm_batch(dc, i)["tokens"])}
               for i in range(2)]
        art = calibrate_lm(params, cfg, cal, method="amax")
        scfg = SiliconConfig(cap_sigma=0.02, comparator_sigma_v=0.008,
                             drift_sigma_v_per_kstream=0.3)
        pol = DriftPolicy(probe_batches=cal, check_interval=12,
                          silicon_update_interval=6,
                          rel_l2_alarm_ratio=1.3,
                          rel_l2_alarm_floor=0.02)
        eng = ServeEngine(params, cfg, slots=2, max_len=48, fleet=fleet,
                          batched_prefill=False, calibration=art,
                          silicon=scfg, drift=pol)
        base = eng._monitor.baseline_rel_l2
        eng.run([Request(prompt=[1, 2, 3], max_new_tokens=14)
                 for _ in range(2)])
        rep = eng.last_report
        assert rep.drift_checks >= 1
        assert rep.drift_alarms >= 1, eng.drift_log
        assert rep.recalibrations >= 1
        assert rep.recal_reload_bits > 0 and rep.recal_energy_j > 0
        first = next(s for s in eng.drift_log if s.recalibrated)
        assert first.rel_l2 > pol.rel_l2_alarm_ratio * base
        assert first.post_rel_l2 < first.rel_l2
        assert first.post_rel_l2 <= 1.8 * base
        # maintenance re-baselines the alarm at the healed noise floor
        assert eng._monitor.baseline_rel_l2 == pytest.approx(
            first.post_rel_l2)
        assert eng._monitor.initial_baseline_rel_l2 == pytest.approx(base)
