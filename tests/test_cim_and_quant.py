"""CIM behavioural simulator + quantiser tests (paper Sec. IV-V)."""

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # deterministic fallback, see tests/_hypothesis_stub.py
    from _hypothesis_stub import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CimConfig, VariabilityConfig, calibrate_scale,
                        cim_mf_matmul, cim_mf_matmul_ste, dequantize,
                        mav_crossover_probability, mf_correlate_ref, quantize,
                        sample_cap_weights, sample_comparator_offset)
from repro.core import quant
from repro.core.cim import adc_quantize
from repro.silicon.variability import calibrated_offset, screen_columns


class TestQuant:
    @hypothesis.given(st.integers(2, 8))
    @hypothesis.settings(max_examples=8, deadline=None)
    def test_roundtrip_error_bound(self, bits):
        v = jax.random.normal(jax.random.PRNGKey(0), (64,))
        s = calibrate_scale(v, bits)
        err = jnp.abs(dequantize(quantize(v, s, bits), s) - v)
        assert float(jnp.max(err)) <= float(s) / 2 + 1e-7

    def test_integers_exact(self):
        v = jnp.arange(-127, 128, dtype=jnp.float32) * 0.02
        s = calibrate_scale(v, 8)
        np.testing.assert_allclose(dequantize(quantize(v, s, 8), s), v,
                                   atol=1e-6)

    def test_bitplane_roundtrip(self):
        mag = jnp.arange(0, 128, dtype=jnp.int32)
        planes = quant.bitplanes(mag, 8)
        assert planes.shape == (7, 128)
        np.testing.assert_array_equal(quant.from_bitplanes(planes), mag)

    def test_fake_quant_ste_gradient_is_identity(self):
        v = jax.random.normal(jax.random.PRNGKey(1), (16,))
        g = jax.grad(lambda t: jnp.sum(quant.fake_quant(t, 8)))(v)
        np.testing.assert_allclose(g, jnp.ones_like(v))


class TestADC:
    def test_lossless_pairings(self):
        # The paper's design points: 2^A >= M+1 makes the ADC exact on
        # MAV counts (8x62 -> 5-bit, 8x30 -> 4-bit).
        for m, a in [(31, 5), (15, 4), (7, 3)]:
            counts = jnp.arange(m + 1, dtype=jnp.float32)
            mav = counts / m
            deq = adc_quantize(mav, a) * m
            np.testing.assert_allclose(deq, counts, atol=1e-5)

    @pytest.mark.parametrize("m,a", [(31, 5), (15, 4)])
    def test_exactly_lossless_when_levels_cover_counts(self, m, a):
        # 2^A >= M + 1 gives every discharge count its own code: the paper's
        # 8x62 -> 5-bit and 8x30 -> 4-bit pairings are EXACTLY lossless,
        # bit-for-bit, not merely within tolerance.
        assert 2 ** a >= m + 1
        counts = jnp.arange(m + 1, dtype=jnp.float32)
        deq = adc_quantize(counts / m, a) * m
        np.testing.assert_array_equal(np.asarray(deq), np.asarray(counts))

    def test_monotone(self):
        mav = jnp.linspace(0, 1, 97)
        q = adc_quantize(mav, 4)
        assert bool(jnp.all(jnp.diff(q) >= 0))

    def test_lossy_when_underprovisioned(self):
        counts = jnp.arange(32, dtype=jnp.float32)
        deq = adc_quantize(counts / 31, 3) * 31
        assert float(jnp.max(jnp.abs(deq - counts))) > 0.5


class TestCimSim:
    def _xy(self, b=4, k=70, n=9):
        x = jax.random.normal(jax.random.PRNGKey(0), (b, k))
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
        return x, w

    def test_exact_vs_quantised_reference(self):
        # With a lossless ADC the CIM pipeline == the MF correlation with
        # sign bits from the ORIGINAL operands (stored sign row) and
        # magnitudes from the quantised codes (stored bitplanes).
        from repro.core import hw_sign
        x, w = self._xy()
        cfg = CimConfig(8, 8, 5, 31)
        sw = calibrate_scale(w, 8)
        sx = calibrate_scale(x, 8)
        xq = jnp.abs(dequantize(quantize(x, sx, 8), sx))
        wq = jnp.abs(dequantize(quantize(w, sw, 8), sw))
        ref = hw_sign(x) @ wq + xq @ hw_sign(w)
        np.testing.assert_allclose(cim_mf_matmul(x, w, cfg), ref,
                                   rtol=1e-4, atol=1e-4)

    def test_low_wbits_keeps_sign_information(self):
        # Small negative weights truncate to zero magnitude but keep their
        # stored sign bit: low-W_P error stays bounded (no systematic
        # sign-flip bias). Regression test for the Fig. 7 accuracy cliff.
        x, w = self._xy(k=124)
        ref = mf_correlate_ref(x, w, hw=True)
        err4 = float(jnp.mean(jnp.abs(
            cim_mf_matmul(x, w, CimConfig(4, 8, 5, 31)) - ref)))
        scale = float(jnp.mean(jnp.abs(ref)))
        assert err4 < 0.25 * scale

    @pytest.mark.parametrize("m,a", [(31, 5), (15, 4), (31, 4), (15, 3)])
    def test_geometries_run(self, m, a):
        x, w = self._xy(k=45)
        y = cim_mf_matmul(x, w, CimConfig(8, 8, a, m))
        assert y.shape == (4, 9) and bool(jnp.all(jnp.isfinite(y)))

    def test_lower_adc_precision_increases_error(self):
        x, w = self._xy(k=124)
        cfg_hi = CimConfig(8, 8, 5, 31)
        cfg_lo = CimConfig(8, 8, 2, 31)
        ref = mf_correlate_ref(x, w, hw=True)
        e_hi = float(jnp.mean(jnp.abs(cim_mf_matmul(x, w, cfg_hi) - ref)))
        e_lo = float(jnp.mean(jnp.abs(cim_mf_matmul(x, w, cfg_lo) - ref)))
        assert e_lo > e_hi

    def test_kernel_path_matches_einsum_path(self):
        x, w = self._xy(k=70, n=17)
        for a in (5, 4, 3):
            y0 = cim_mf_matmul(x, w, CimConfig(8, 8, a, 31, use_kernel=False))
            y1 = cim_mf_matmul(x, w, CimConfig(8, 8, a, 31, use_kernel=True))
            np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-4)

    def test_ste_backward_matches_mf_surrogate(self):
        x, w = self._xy(b=2, k=21, n=3)
        cfg = CimConfig(8, 8, 5, 31)
        g = jnp.ones((2, 3))
        _, vjp = jax.vjp(lambda a, b: cim_mf_matmul_ste(a, b, cfg), x, w)
        dx, dw = vjp(g)
        from repro.core import mf_matmul
        _, vjp2 = jax.vjp(lambda a, b: mf_matmul(a, b, 0.5, 1.0), x, w)
        dx2, dw2 = vjp2(g)
        np.testing.assert_allclose(dx, dx2, rtol=1e-5)
        np.testing.assert_allclose(dw, dw2, rtol=1e-5)

    def test_variability_injection_degrades_gracefully(self):
        x, w = self._xy(k=62)
        cfg = CimConfig(8, 8, 5, 31)
        var = VariabilityConfig(cap_sigma=0.12)
        caps = sample_cap_weights(jax.random.PRNGKey(7), 62, var)
        off = sample_comparator_offset(jax.random.PRNGKey(8), var)
        ref = mf_correlate_ref(x, w, hw=True)
        y_clean = cim_mf_matmul(x, w, cfg)
        y_noisy = cim_mf_matmul(x, w, cfg, cap_weights=caps,
                                comparator_offset=off)
        e_clean = float(jnp.mean(jnp.abs(y_clean - ref)))
        e_noisy = float(jnp.mean(jnp.abs(y_noisy - ref)))
        assert np.isfinite(e_noisy) and e_noisy >= e_clean * 0.5


class TestVariability:
    def test_crossover_increases_with_mismatch(self):
        cim = CimConfig(8, 8, 5, 31)
        key = jax.random.PRNGKey(0)
        p_lo = mav_crossover_probability(key, cim,
                                         VariabilityConfig(cap_sigma=0.01),
                                         n_trials=300)
        p_hi = mav_crossover_probability(key, cim,
                                         VariabilityConfig(cap_sigma=0.12),
                                         n_trials=300)
        assert float(p_hi) >= float(p_lo)

    def test_screening_reduces_crossover(self):
        cim = CimConfig(8, 8, 5, 31)
        var = VariabilityConfig(cap_sigma=0.12, screen_fraction=0.1)
        key = jax.random.PRNGKey(1)
        p_raw = mav_crossover_probability(key, cim, var, n_trials=300,
                                          screened=False)
        p_scr = mav_crossover_probability(key, cim, var, n_trials=300,
                                          screened=True)
        assert float(p_scr) <= float(p_raw)

    def test_comparator_calibration_shrinks_offset(self):
        var = VariabilityConfig()
        offs = 0.045 * jnp.linspace(-1, 1, 41)
        res = jax.vmap(lambda o: calibrated_offset(o, var))(offs)
        assert float(jnp.max(jnp.abs(res))) <= 0.016  # ~ +-15 mV residue
        assert float(jnp.max(jnp.abs(res))) < float(jnp.max(jnp.abs(offs)))

    def test_screen_columns_keeps_majority(self):
        var = VariabilityConfig(cap_sigma=0.12, screen_fraction=0.05)
        caps = sample_cap_weights(jax.random.PRNGKey(2), 62, var)
        keep = screen_columns(caps, var)
        assert int(jnp.sum(keep)) == 62 - 3  # 5% of 62 -> 3 discarded


class TestKernelPathParity:
    """CimConfig(use_kernel=True) must agree with the einsum reference."""

    @pytest.mark.parametrize("m,a", [(31, 5), (15, 4)])
    def test_kernel_matches_einsum(self, m, a):
        K, N = 2 * m + 9, 7       # non-divisible K exercises chunk padding
        x = jax.random.normal(jax.random.PRNGKey(0), (3, K))
        w = jax.random.normal(jax.random.PRNGKey(1), (K, N))
        yk = cim_mf_matmul(x, w, CimConfig(8, 8, a, m, use_kernel=True))
        ye = cim_mf_matmul(x, w, CimConfig(8, 8, a, m))
        # identical integer code sums on both paths; only the final float
        # recombination order differs (fused vs staged), so ulp-tight.
        np.testing.assert_allclose(yk, ye, rtol=0, atol=1e-4)

    @pytest.mark.parametrize("m,a", [(31, 5), (15, 4)])
    def test_kernel_parity_batched_shapes(self, m, a):
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 3, m + 2))
        w = jax.random.normal(jax.random.PRNGKey(3), (m + 2, 5))
        yk = cim_mf_matmul(x, w, CimConfig(8, 8, a, m, use_kernel=True))
        ye = cim_mf_matmul(x, w, CimConfig(8, 8, a, m))
        assert yk.shape == ye.shape == (2, 3, 5)
        np.testing.assert_allclose(yk, ye, rtol=0, atol=1e-4)
