"""repro-lint (ISSUE 9): rule fixtures, suppression/baseline machinery,
and the REPRO_SANITIZE runtime sanitizer.

Each rule class is tested on the *historical bug shape* it encodes (true
positive) AND on the repaired/idiomatic shape (false-positive guard). The
self-scan pins the repo's finding count to the checked-in baseline, and
the engine tests show the sanitizer accepting the real fused-vs-einsum
contract and catching a deliberately injected violation.
"""

import dataclasses
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.engine import (analyze_source, diff_baseline,
                                   load_baseline, save_baseline)

REPO = Path(__file__).resolve().parents[1]


def rules_of(src: str, path: str = "mod.py") -> list[str]:
    report = analyze_source(textwrap.dedent(src), path)
    return [f.rule for f in report.findings]


def report_of(src: str, path: str = "mod.py"):
    return analyze_source(textwrap.dedent(src), path)


class TestR001KeyReuse:
    def test_fires_on_sequential_reuse(self):
        src = """
        import jax
        def f(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.normal(key, (3,))
            return a + b
        """
        assert rules_of(src) == ["R001"]

    def test_fires_on_loop_replay(self):
        # the PR 6 shape: one key drawn from inside the step loop
        src = """
        import jax
        def f(key, n):
            outs = []
            for i in range(n):
                outs.append(jax.random.normal(key, (3,)))
            return outs
        """
        assert rules_of(src) == ["R001"]

    def test_split_and_fold_in_pass(self):
        src = """
        import jax
        def f(key, n):
            key, sub = jax.random.split(key)
            a = jax.random.normal(sub, (3,))
            outs = [a]
            for i in range(n):
                key, sub = jax.random.split(key)
                outs.append(jax.random.normal(sub, (3,)))
            for i in range(n):
                outs.append(jax.random.normal(
                    jax.random.fold_in(key, i), (3,)))
            return outs
        """
        assert rules_of(src) == []

    def test_reassignment_resets(self):
        src = """
        import jax
        def f(key):
            a = jax.random.normal(key, (3,))
            key = jax.random.fold_in(key, 1)
            b = jax.random.normal(key, (3,))
            return a + b
        """
        assert rules_of(src) == []

    def test_branches_do_not_cross_flag(self):
        src = """
        import jax
        def f(key, flip):
            if flip:
                return jax.random.normal(key, (3,))
            else:
                return jax.random.uniform(key, (3,))
        """
        assert rules_of(src) == []


class TestR002PytreeRebuild:
    BAD = """
    def strip(params):
        def walk(node):
            if isinstance(node, dict):
                return {k: walk(v) for k, v in node.items()}
            if isinstance(node, tuple):
                return tuple(walk(v) for v in node)
            return node
        return walk(params)
    """

    def test_fires_on_strip_silicon_shape(self):
        assert rules_of(self.BAD) == ["R002"]

    def test_fields_guard_passes(self):
        src = """
        def strip(params):
            def walk(node):
                if isinstance(node, dict):
                    return {k: walk(v) for k, v in node.items()}
                if isinstance(node, tuple):
                    if hasattr(node, "_fields"):
                        return node
                    return tuple(walk(v) for v in node)
                return node
            return walk(params)
        """
        assert rules_of(src) == []

    def test_type_reconstruction_passes(self):
        src = """
        def strip(params):
            def walk(node):
                if isinstance(node, tuple):
                    return type(node)(*[walk(v) for v in node])
                return node
            return walk(params)
        """
        assert rules_of(src) == []

    def test_plain_tuple_call_without_typetest_passes(self):
        src = """
        def f(xs):
            return tuple(x + 1 for x in xs)
        """
        assert rules_of(src) == []


class TestR003TraceCache:
    def test_fires_on_jit_in_loop(self):
        src = """
        import jax
        def f(fns, x):
            for fn in fns:
                x = jax.jit(fn)(x)
            return x
        """
        assert "R003" in rules_of(src)

    def test_fires_on_immediately_invoked(self):
        # the DriftMonitor shape: a fresh wrapper per probe call
        src = """
        import jax
        class Monitor:
            def probe(self, params, batch):
                return jax.jit(self._observe)(params, batch)
        """
        assert rules_of(src) == ["R003"]

    def test_fires_on_local_bind_and_call(self):
        src = """
        import jax
        def probe(fn, x):
            g = jax.jit(fn)
            return g(x)
        """
        assert rules_of(src) == ["R003"]

    def test_module_level_bind_passes(self):
        src = """
        import jax
        def _step(x):
            return x + 1
        step = jax.jit(_step)
        def serve(x):
            return step(x)
        """
        assert rules_of(src) == []

    def test_init_stash_and_factory_pass(self):
        src = """
        import jax
        class Engine:
            def __init__(self, fn):
                self.step_fn = jax.jit(fn)
        def make(fn):
            g = jax.jit(fn)
            return g
        """
        assert rules_of(src) == []

    def test_fires_on_mutable_closure(self):
        src = """
        import jax
        def build():
            acc = []
            @jax.jit
            def step(x):
                return x + len(acc)
            return step
        """
        assert rules_of(src) == ["R003"]


TAGGED = "# repro-lint: module=deterministic\n"


class TestR004Nondeterminism:
    def test_fires_on_clock_and_global_rng(self):
        src = TAGGED + textwrap.dedent("""
        import time, random
        import numpy as np
        def build(n):
            t = time.time()
            a = np.random.rand(n)
            b = random.random()
            return t, a, b
        """)
        assert sorted(rules_of(src)) == ["R004", "R004", "R004"]

    def test_fires_on_set_iteration(self):
        src = TAGGED + "def f(xs):\n    return [x for x in set(xs)]\n"
        assert rules_of(src) == ["R004"]

    def test_seeded_generator_and_sorted_pass(self):
        src = TAGGED + textwrap.dedent("""
        import numpy as np
        def build(n, seed):
            rng = np.random.default_rng(seed)
            return rng.normal(size=n), [x for x in sorted(set(range(n)))]
        """)
        assert rules_of(src) == []

    def test_untagged_module_is_exempt(self):
        src = "import time\ndef f():\n    return time.time()\n"
        assert rules_of(src) == []


EXACT = "# repro-lint: module=exactness-critical\n"


class TestR005FloatAccumulation:
    def test_fires_without_pragma(self):
        src = EXACT + textwrap.dedent("""
        import jax.numpy as jnp
        def recombine(codes, pw):
            return jnp.einsum("bnpc,p->bn", codes, pw)
        """)
        assert rules_of(src) == ["R005"]

    def test_fires_on_matmul_op_and_x64(self):
        src = EXACT + textwrap.dedent("""
        import jax.numpy as jnp
        def f(a, b):
            y = a @ b
            return y.astype(jnp.float64)
        """)
        assert sorted(rules_of(src)) == ["R005", "R005"]

    def test_exact_ok_pragma_passes(self):
        src = EXACT + textwrap.dedent("""
        import jax.numpy as jnp
        def recombine(codes, pw):
            # exact-ok: integer ADC codes x power-of-two plane weights
            return jnp.einsum("bnpc,p->bn", codes, pw)
        """)
        assert rules_of(src) == []

    def test_untagged_module_is_exempt(self):
        src = "import jax.numpy as jnp\ndef f(x):\n    return jnp.sum(x)\n"
        assert rules_of(src) == []


STEP = "# repro-lint: module=step-time\n"


class TestR006UnkeyedNoise:
    def test_fires_on_static_key(self):
        src = STEP + textwrap.dedent("""
        import jax
        def dither(noise_key, shape):
            return jax.random.normal(noise_key, shape)
        """)
        assert rules_of(src) == ["R006"]

    def test_clock_keyed_draw_passes(self):
        # the core/cim.py ProjectionSilicon.dither idiom, incl. the
        # transitive derivation through an intermediate name
        src = STEP + textwrap.dedent("""
        import jax
        from repro.core.cim import conversion_step
        def dither(noise_key, shape, salt):
            k = jax.random.fold_in(noise_key, conversion_step())
            k = jax.random.fold_in(k, salt)
            return jax.random.normal(k, shape)
        """)
        assert rules_of(src) == []

    def test_untagged_module_is_exempt(self):
        src = """
        import jax
        def dither(noise_key, shape):
            return jax.random.normal(noise_key, shape)
        """
        assert rules_of(src) == []


class TestSuppressions:
    BAD = TestR002PytreeRebuild.BAD

    def test_reasoned_suppression_suppresses(self):
        src = self.BAD.replace(
            "return tuple(walk(v) for v in node)",
            "return tuple(walk(v) for v in node)"
            "  # repro-lint: disable=R002 reason=tree is dict/list only")
        report = report_of(src)
        assert report.findings == []
        assert [f.rule for f, _ in report.suppressed] == ["R002"]

    def test_suppression_without_reason_is_a_finding(self):
        src = self.BAD.replace(
            "return tuple(walk(v) for v in node)",
            "return tuple(walk(v) for v in node)"
            "  # repro-lint: disable=R002")
        rules = rules_of(src)
        assert "R000" in rules and "R002" in rules

    def test_unused_suppression_is_a_finding(self):
        src = ("x = 1  # repro-lint: disable=R001 reason=nothing "
               "fires here\n")
        assert rules_of(src) == ["R000"]

    def test_comment_line_above_covers_next_line(self):
        src = self.BAD.replace(
            "            if isinstance(node, tuple):",
            "            # repro-lint: disable=R002 reason=dict-only "
            "trees\n            if isinstance(node, tuple):")
        # directive sits above the isinstance line, not the tuple() line:
        # it must NOT suppress the finding two lines down
        assert rules_of(src) == ["R002", "R000"] or \
            sorted(rules_of(src)) == ["R000", "R002"]

    def test_wrong_rule_id_does_not_suppress(self):
        src = self.BAD.replace(
            "return tuple(walk(v) for v in node)",
            "return tuple(walk(v) for v in node)"
            "  # repro-lint: disable=R001 reason=wrong id")
        rules = rules_of(src)
        assert "R002" in rules


class TestBaseline:
    def test_diff_flags_new_and_stale(self):
        report = report_of(TestR002PytreeRebuild.BAD, "a.py")
        base = [{"rule": "R002", "path": "a.py", "line": 999,
                 "message": "gone"}]
        new, stale = diff_baseline(report.findings, base)
        assert [f.rule for f in new] == ["R002"]
        assert stale == base

    def test_accepted_finding_passes(self):
        report = report_of(TestR002PytreeRebuild.BAD, "a.py")
        base = [{"rule": f.rule, "path": f.path, "line": f.line,
                 "message": f.message} for f in report.findings]
        new, stale = diff_baseline(report.findings, base)
        assert new == [] and stale == []

    def test_save_load_roundtrip(self, tmp_path):
        report = report_of(TestR002PytreeRebuild.BAD, "a.py")
        p = tmp_path / "baseline.json"
        save_baseline(p, report.findings)
        new, stale = diff_baseline(report.findings, load_baseline(p))
        assert new == [] and stale == []


class TestSelfScan:
    def test_repo_scan_matches_baseline(self):
        """The zero-unsuppressed-findings gate, in-process: scanning the
        repo's own src/benchmarks/tests must reproduce exactly the
        checked-in baseline (empty since ISSUE 9 paid all debt down)."""
        from repro.analysis.engine import (all_rules, analyze_file,
                                           iter_python_files)
        rules = all_rules()
        assert len([r for r in rules if r.startswith("R0") and
                    r != "R000"]) >= 6
        findings = []
        for f in iter_python_files(["src", "benchmarks", "tests"], REPO):
            findings.extend(analyze_file(f, REPO, rules).findings)
        baseline = load_baseline(REPO / "analysis_baseline.json")
        new, stale = diff_baseline(findings, baseline)
        assert new == [], "\n".join(f.human() for f in new)
        assert stale == []
        assert baseline == []   # the ledger finished ISSUE 9 empty

    def test_cli_gate(self, tmp_path):
        bad = tmp_path / "bad.py"
        bad.write_text(textwrap.dedent(TestR002PytreeRebuild.BAD))
        env_root = str(REPO / "src")
        r = subprocess.run(
            [sys.executable, "-m", "repro.analysis", str(bad), "--json"],
            capture_output=True, text=True, cwd=tmp_path,
            env={"PYTHONPATH": env_root, "PATH": "/usr/bin:/bin"})
        assert r.returncode == 1, r.stderr
        payload = json.loads(r.stdout)
        assert [f["rule"] for f in payload["findings"]] == ["R002"]
        ok = tmp_path / "ok.py"
        ok.write_text("x = 1\n")
        r = subprocess.run(
            [sys.executable, "-m", "repro.analysis", str(ok)],
            capture_output=True, text=True, cwd=tmp_path,
            env={"PYTHONPATH": env_root, "PATH": "/usr/bin:/bin"})
        assert r.returncode == 0, r.stdout + r.stderr


class TestSanitizerUnits:
    def _silk(self, **over):
        from repro.core.cim import CimKernelSilicon
        g = 2.0 ** -14
        base = dict(
            wpc=jnp.full((2, 8, 3), 4096 * g, jnp.float32),
            gwc=jnp.full((3, 8), 16384 * g, jnp.float32),
            den=jnp.full((2, 3), 31.0, jnp.float32),
            off=jnp.zeros((2, 3), jnp.float32),
            rxp=jnp.full((8,), 16384 * g, jnp.float32),
            rx_den=jnp.full((2,), 31.0, jnp.float32),
            rx_off=jnp.zeros((2,), jnp.float32),
        )
        base.update(over)
        return CimKernelSilicon(**base)

    def test_quanta_invariant_accepts_grid(self):
        from repro.analysis.sanitize import check_cap_quanta
        check_cap_quanta({"layer": {"silk": self._silk()}})

    def test_quanta_invariant_rejects_off_grid(self):
        from repro.analysis.sanitize import SanitizeError, check_cap_quanta
        bad = self._silk(wpc=jnp.full((2, 8, 3), 1.0 / 3.0, jnp.float32))
        with pytest.raises(SanitizeError, match="fixed-point grid"):
            check_cap_quanta({"layer": {"silk": bad}})

    def test_quanta_invariant_rejects_overflow_budget(self):
        from repro.analysis.sanitize import SanitizeError, check_cap_quanta
        bad = self._silk(den=jnp.full((2, 3), 2048.0, jnp.float32))
        with pytest.raises(SanitizeError, match="2\\^24"):
            check_cap_quanta({"layer": {"silk": bad}})

    def test_tripwire_records_nan_and_saturation(self):
        from repro.analysis import sanitize
        from repro.core.cim import adc_codes
        sanitize.arm_tripwires(True)
        try:
            sanitize.drain_tripwires()
            codes = adc_codes(jnp.array([jnp.nan, 0.5]), 5)
            jax.block_until_ready(codes)
            log = sanitize.drain_tripwires()
            assert len(log) == 1 and log[0][0] > 0.0
            codes = adc_codes(jnp.array([2.0, 3.0]), 5)
            jax.block_until_ready(codes)
            log = sanitize.drain_tripwires()
            assert len(log) == 1 and log[0][1] == 1.0
        finally:
            sanitize.arm_tripwires(False)


def _kernel_engine(monkeypatch):
    from repro.configs.base import MFTechniqueConfig
    from repro.configs.qwen3_0_6b import SMOKE
    from repro.core.cim import CimConfig
    from repro.models import transformer as T
    from repro.serve.engine import ServeEngine
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    cim = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31,
                    use_kernel=True)
    cfg = dataclasses.replace(SMOKE, dtype=jnp.float32,
                              mf=MFTechniqueConfig(mode="cim_sim",
                                                   cim=cim))
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    return ServeEngine(params, cfg, slots=2, max_len=16,
                       batched_prefill=False)


class TestSanitizerEngine:
    def test_clean_kernel_engine_passes_shadow_check(self, monkeypatch):
        from repro.serve.engine import Request
        eng = _kernel_engine(monkeypatch)
        assert eng._sanitizer is not None
        done = eng.run([Request(prompt=[1, 2], max_new_tokens=2)
                        for _ in range(2)])
        assert all(len(r.out) == 2 for r in done)
        assert eng._sanitizer.checked_steps >= 3

    def test_injected_kernel_mismatch_is_caught(self, monkeypatch):
        from repro.analysis.sanitize import SanitizeError
        from repro.core import programmed as P
        from repro.serve.engine import Request
        orig = P.cim_kernel_forward

        def corrupted(x2, ks, cfg, sw, sx, dac_gains=None):
            # one ADC-code quantum of divergence on the fused path only
            return orig(x2, ks, cfg, sw, sx, dac_gains) + 1e-3

        monkeypatch.setattr(P, "cim_kernel_forward", corrupted)
        eng = _kernel_engine(monkeypatch)
        with pytest.raises(SanitizeError, match="fused/einsum divergence"):
            eng.run([Request(prompt=[1, 2], max_new_tokens=2)])

    def test_sanitize_off_attaches_nothing(self, monkeypatch):
        from repro.configs.base import MFTechniqueConfig
        from repro.configs.qwen3_0_6b import SMOKE
        from repro.core.cim import CimConfig
        from repro.models import transformer as T
        from repro.serve.engine import ServeEngine
        monkeypatch.delenv("REPRO_SANITIZE", raising=False)
        cim = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31)
        cfg = dataclasses.replace(SMOKE, dtype=jnp.float32,
                                  mf=MFTechniqueConfig(mode="cim_sim",
                                                       cim=cim))
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(params, cfg, slots=1, max_len=8,
                          batched_prefill=False)
        assert eng._sanitizer is None
