"""Fleet telemetry (``repro.obs``): bus, metrics, exports, engine taps.

What is pinned here, in the order the ISSUE lists it:

  * in-jit ``decode_tick`` emission is trace-once — the traced twin
    program compiles exactly once however many ticks run, and buses can
    be installed/swapped between ticks without retracing (the same
    discipline as the calibration lab's ``collect_stats``);
  * tracing disabled (or merely a bus installed against an untraced
    engine) leaves decoded tokens BITWISE identical on the pinned,
    swapped (rounds > 1) and silicon serving paths;
  * histogram merge is order-invariant; windowed counter deltas sum
    exactly even when a recalibration lands inside a window;
  * Prometheus text exposition and trace JSONL both round-trip;
  * ``src/repro/obs`` is tagged ``observability`` and stays OUT of
    repro-lint's ``exactness-critical`` float-accumulation scope.
"""

from __future__ import annotations

from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.compiler.tiling import Fleet
from repro.configs.base import MFTechniqueConfig, ModelConfig
from repro.core.cim import CimConfig
from repro.models import transformer as T
from repro.obs import trace as obs_trace
from repro.serve.engine import Request, ServeEngine, make_serve_step

CIM = CimConfig(4, 4, 5, 31)


def _cfg(**kw):
    base = dict(
        name="obs-tiny", family="lm", n_layers=2, d_model=32,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
        dtype=jnp.float32,
        mf=MFTechniqueConfig(mode="cim_sim", cim=CIM))
    base.update(kw)
    return ModelConfig(**base)


def _params(cfg):
    return T.lm_init(jax.random.PRNGKey(0), cfg)


def _serve(eng, n=4):
    done = eng.run([Request(prompt=[1, 2, 3], max_new_tokens=n)
                    for _ in range(2)])
    return [r.out for r in done]


class TestTraceBus:
    def test_emit_without_bus_is_noop(self):
        obs_trace.emit("program", stream=0)   # must not raise or record
        assert obs.bus() is None

    def test_ring_keeps_newest_and_counts_drops(self):
        buf = obs.TraceBuffer(capacity=4)
        with obs.tracing(capacity=4) as scoped:
            del buf
            for i in range(7):
                obs_trace.emit("decode_tick", stream=i)
        assert scoped.total == 7 and scoped.dropped == 3
        assert [e.stream for e in scoped.events()] == [3, 4, 5, 6]
        seqs = [e.seq for e in scoped.events()]
        assert seqs == sorted(seqs)

    def test_tracing_scope_restores_previous_bus(self):
        outer = obs.install()
        try:
            with obs.tracing() as inner:
                obs_trace.emit("admit")
                assert obs.bus() is inner
            assert obs.bus() is outer
            assert len(inner.events()) == 1 and outer.total == 0
        finally:
            obs.uninstall()

    def test_span_records_duration(self):
        with obs.tracing() as buf:
            with obs_trace.span("recal", stream=3):
                pass
        (ev,) = buf.events()
        assert ev.kind == "recal" and ev.data["dur_ns"] >= 0


class TestInJitEmission:
    def test_traced_twin_traces_once_across_ticks_and_buses(self):
        cfg = _cfg()
        eng = ServeEngine(_params(cfg), cfg, slots=2, max_len=32,
                          batched_prefill=False, tracing=True,
                          trace_tick_interval=1)
        traces = 0
        inner = make_serve_step(cfg, trace_tag=eng.trace_tag)

        def counting(params, cache, tokens, rng, step=0, active=0):
            nonlocal traces
            traces += 1
            return inner(params, cache, tokens, rng, step, active)

        eng._traced_step_fn = jax.jit(counting)
        with obs.tracing() as first:
            _serve(eng, n=3)
        with obs.tracing() as second:   # fresh bus: must NOT retrace
            _serve(eng, n=3)
        _serve(eng, n=3)                # no bus at all: still no retrace
        assert traces == 1
        assert len(first.by_kind("decode_tick")) > 0
        assert len(second.by_kind("decode_tick")) > 0

    def test_cadence_samples_every_interval_ticks(self):
        cfg = _cfg()
        eng = ServeEngine(_params(cfg), cfg, slots=1, max_len=32,
                          batched_prefill=False, tracing=True,
                          trace_tick_interval=4)
        with obs.tracing() as buf:
            eng.run([Request(prompt=[1], max_new_tokens=12)])
        streams = [e.stream for e in buf.by_kind("decode_tick")]
        assert streams == [0, 4, 8]

    def test_decode_tick_payload(self):
        cfg = _cfg()
        eng = ServeEngine(_params(cfg), cfg, slots=2, max_len=32,
                          batched_prefill=False, tracing=True,
                          trace_tick_interval=1)
        with obs.tracing() as buf:
            _serve(eng, n=2)
        ev = buf.by_kind("decode_tick")[0]
        assert ev.engine == eng.trace_tag
        assert ev.data["active"] == 2 and len(ev.data["tokens"]) == 2

    def test_interval_validation(self):
        cfg = _cfg()
        with pytest.raises(ValueError, match="trace_tick_interval"):
            ServeEngine(_params(cfg), cfg, slots=1, max_len=8,
                        tracing=True, trace_tick_interval=0)


class TestDisabledPathParity:
    """Tokens must be bitwise identical with tracing off (today's
    program), with a bus installed against an untraced engine, and with
    the traced twin dispatched every tick."""

    @pytest.mark.parametrize("kind", ["pinned", "swapped", "silicon"])
    def test_bitwise_parity(self, kind):
        from repro.silicon.instance import SiliconConfig
        cfg = _cfg()
        params = _params(cfg)
        sigma0 = SiliconConfig(cap_sigma=0.0, comparator_sigma_v=0.0)

        def build(tracing):
            kw = dict(slots=2, max_len=16, batched_prefill=False,
                      tracing=tracing, trace_tick_interval=1)
            if kind == "pinned":
                return ServeEngine(params, cfg,
                                   fleet=Fleet(n_macros=1024, cfg=CIM),
                                   **kw)
            if kind == "swapped":
                return ServeEngine(params, cfg,
                                   fleet=Fleet(n_macros=8, cfg=CIM), **kw)
            return ServeEngine(params, cfg,
                               fleet=Fleet(n_macros=1024, cfg=CIM),
                               silicon=sigma0, **kw)

        probe = build(False)
        if kind == "swapped":
            assert not probe.schedule.pinned
            assert probe.schedule.rounds_max > 1
        assert obs.bus() is None
        ref = _serve(probe)
        with obs.tracing() as buf:
            assert _serve(build(False)) == ref    # host emitters only
            assert _serve(build(True)) == ref     # in-jit emission
            assert len(buf.by_kind("decode_tick")) > 0


class TestMetrics:
    def test_counter_monotonic(self):
        c = obs.Counter("c")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError, match="negative"):
            c.inc(-1)

    def test_histogram_merge_is_order_invariant(self):
        rng = np.random.default_rng(0)
        xs = rng.exponential(0.1, size=300)
        shards = []
        for part in np.array_split(xs, 3):
            h = obs.Histogram("h", obs.LATENCY_EDGES_S)
            h.observe_many(part)
            shards.append(h)
        orders = [(0, 1, 2), (2, 0, 1), (1, 2, 0)]
        merged = []
        for order in orders:
            acc = obs.Histogram("h", obs.LATENCY_EDGES_S)
            for i in order:
                acc.merge(shards[i])
            merged.append(acc)
        one = obs.Histogram("h", obs.LATENCY_EDGES_S)
        one.observe_many(xs)
        for acc in merged:
            np.testing.assert_array_equal(acc.counts, merged[0].counts)
            np.testing.assert_array_equal(acc.counts, one.counts)
            assert acc.count == one.count == 300

    def test_histogram_merge_rejects_edge_mismatch(self):
        a = obs.Histogram("h", (1.0, 2.0))
        b = obs.Histogram("h", (1.0, 3.0))
        with pytest.raises(ValueError, match="incompatible"):
            a.merge(b)

    def test_histogram_edges_validated(self):
        with pytest.raises(ValueError, match="ascending"):
            obs.Histogram("h", (1.0, 1.0))

    def test_quantile_interpolates_and_clamps(self):
        h = obs.Histogram("h", (1.0, 2.0, 4.0))
        assert np.isnan(h.quantile(0.5))
        h.observe_many([0.5, 1.5, 3.0, 100.0])
        assert 0.0 <= h.quantile(0.25) <= 1.0
        assert h.quantile(1.0) == 4.0          # overflow rank clamps
        with pytest.raises(ValueError, match="outside"):
            h.quantile(1.5)

    def test_registry_get_or_create_and_conflicts(self):
        m = obs.MetricsRegistry()
        c = m.counter("x_total", "help")
        assert m.counter("x_total") is c
        with pytest.raises(ValueError, match="already"):
            m.gauge("x_total")
        h = m.histogram("lat_s", (1.0, 2.0))
        assert m.histogram("lat_s", (1.0, 2.0)) is h
        with pytest.raises(ValueError, match="edges"):
            m.histogram("lat_s", (1.0, 3.0))
        with pytest.raises(ValueError, match="Prometheus"):
            m.counter("bad name")

    def test_window_deltas_sum_exactly_gauges_stay_levels(self):
        m = obs.MetricsRegistry()
        c = m.counter("events_total")
        g = m.gauge("level_now")
        s0 = m.snapshot()
        c.inc(3)
        g.set(7)
        s1 = m.snapshot()
        c.inc(5)
        g.set(2)
        w1 = {k: s1[k] - s0.get(k, 0.0) for k in ("events_total",)}
        w2 = m.delta(s1)
        assert w1["events_total"] + w2["events_total"] == 8.0
        assert w2["level_now"] == 2.0          # level, not a difference


class TestEngineWindowedCounters:
    def test_recal_inside_window_counted_once(self):
        """A recalibration straddled by a snapshot boundary must appear
        in exactly one window, and the two windows must sum to the run
        totals (the TrafficReport windowing contract)."""
        from repro.calib.report import calibrate_lm
        from repro.data.synthetic import DataConfig, lm_batch
        from repro.silicon.drift import DriftPolicy
        from repro.silicon.instance import SiliconConfig
        cfg = _cfg()
        params = _params(cfg)
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=8,
                        global_batch=2, task="uniform")
        cal = [{"tokens": jnp.asarray(lm_batch(dc, i)["tokens"])}
               for i in range(2)]
        art = calibrate_lm(params, cfg, cal, method="amax")
        scfg = SiliconConfig(cap_sigma=0.02, comparator_sigma_v=0.008,
                             drift_sigma_v_per_kstream=8.0)
        pol = DriftPolicy(probe_batches=cal, check_interval=8,
                          silicon_update_interval=4,
                          rel_l2_alarm_ratio=1.2,
                          rel_l2_alarm_floor=0.01)
        eng = ServeEngine(params, cfg, slots=2, max_len=48,
                          fleet=Fleet(n_macros=256, cfg=CIM),
                          batched_prefill=False, calibration=art,
                          silicon=scfg, drift=pol)
        c0 = eng.counters()
        for r in [Request(prompt=[1, 2, 3], max_new_tokens=12)
                  for _ in range(2)]:
            eng.submit(r)
        with obs.tracing() as buf:
            # Window 1 ends at stream 6; the first drift probe (and on
            # alarm, its recalibration) fires at stream 8 — inside
            # window 2, straddling nothing.
            for _ in range(6):
                eng.step()
            c1 = eng.counters()
            while eng.occupied_slots:
                eng.step()
            c2 = eng.counters()
        for key in ("decode_steps", "decode_tokens", "recals",
                    "recal_bits", "drift_checks", "drift_alarms"):
            w1 = c1[key] - c0[key]
            w2 = c2[key] - c1[key]
            assert w1 >= 0 and w2 >= 0, key
            assert w1 + w2 == c2[key] - c0[key], key
        assert c2["recals"] >= 1
        assert (c1["recals"] - c0["recals"]) == 0   # recal in window 2
        # Trace agreement: recal events on the bus == counter delta.
        assert len(buf.by_kind("recal")) == c2["recals"] - c0["recals"]
        # The retrim-tier numbers are gauges (levels): window 2's level
        # stands alone, it is never summed with window 1's.
        assert c2["retired_slots"] >= 0
        rep = eng.report_since(c1, elapsed_s=1.0)
        assert rep.recalibrations == c2["recals"] - c1["recals"]


class TestHealthTimelines:
    def _trace(self):
        with obs.tracing(capacity=256, detail=True) as buf:
            obs_trace.emit("drift_probe", stream=8, rel_l2=0.05,
                           baseline_rel_l2=0.01, max_clip_ratio=0.0,
                           alarm=True, recalibrated=True,
                           reasons=["rel_l2"],
                           residue_fs=[0.1, 0.9, 0.2, 0.05])
            obs_trace.emit("retrim", stream=8, coarse=1, retired=1,
                           tiers=[1, 2, 0, 0])
            obs_trace.emit("retire", stream=8, retired=1, newly=1)
            obs_trace.emit("program", stream=8, calibrated=True)
            obs_trace.emit("recal", stream=8, reload_bits=1024,
                           energy_nj=3.2, post_rel_l2=0.012)
        return buf.events()

    def test_drift_story_complete_and_ordered(self):
        story = obs.drift_story(self._trace())
        assert story.complete
        assert story.alarm_stream == story.recal_stream \
            == story.retire_stream == 8
        kinds = [s["kind"] for s in story.steps]
        assert kinds == ["drift_alarm", "retrim", "retire", "recal"]

    def test_timeline_and_heatmap(self):
        tl = obs.from_events(self._trace())
        assert len(tl.probes) == 1 and tl.alarms == [8]
        assert tl.probes[0].sqnr_db == pytest.approx(26.0206, abs=1e-3)
        assert tl.recal_reload_bits == [1024]
        assert tl.retired_now == 1 and tl.coarse_now == 1
        heat = obs.fleet_heatmap(tl)
        assert heat["render"] == ["o#.."]
        per_slot = obs.slot_timelines(tl)
        assert per_slot[1][0]["residue_fs"] == pytest.approx(0.9)
        assert per_slot[1][1]["tier"] == 2

    def test_story_incomplete_without_alarm(self):
        with obs.tracing() as buf:
            obs_trace.emit("recal", stream=4, reload_bits=8)
        story = obs.drift_story(buf.events())
        assert not story.complete and story.recal_stream is None


class TestExports:
    def test_prometheus_round_trip(self):
        m = obs.MetricsRegistry()
        m.counter("serve_ticks_total", "ticks").inc(12345)
        m.gauge("queue_depth").set(0.30000000000000004)
        h = m.histogram("lat_s", (0.001, 0.1, 1.0), "latency")
        h.observe_many([0.0005, 0.05, 0.5, 5.0])
        text = obs.to_prometheus(m)
        parsed = obs.parse_prometheus(text)
        assert parsed["serve_ticks_total"] == {
            "type": "counter", "value": 12345.0}
        assert parsed["queue_depth"]["value"] == 0.30000000000000004
        assert parsed["lat_s"]["type"] == "histogram"
        assert parsed["lat_s"]["buckets"] == [
            (0.001, 1.0), (0.1, 2.0), (1.0, 3.0), (float("inf"), 4.0)]
        assert parsed["lat_s"]["count"] == 4.0
        assert parsed["lat_s"]["sum"] == pytest.approx(h.sum)

    def test_trace_jsonl_round_trip(self, tmp_path: Path):
        with obs.tracing() as buf:
            obs_trace.emit("admit", stream=1, slot=0, rid="r-1",
                           prompt_tokens=3)
            obs_trace.emit("evict", stream=9, slot=0, rid="r-1",
                           tokens=7)
        path = tmp_path / "trace.jsonl"
        n = obs.write_trace_jsonl(buf, path)
        assert n == 2
        back = obs.read_trace_jsonl(path)
        assert [e.to_json() for e in back] == \
            [e.to_json() for e in buf.events()]

    def test_sanitize_findings_land_on_the_bus(self):
        from repro.analysis.sanitize import SanitizeError, _finding
        with obs.tracing() as buf:
            err = _finding("boom", check="nan_logits", stream=4)
        assert isinstance(err, SanitizeError)
        (ev,) = buf.by_kind("sanitize")
        assert ev.stream == 4 and ev.data["check"] == "nan_logits"


class TestReproLintScope:
    def test_obs_modules_tagged_out_of_exactness_scope(self):
        from repro.analysis.engine import _scan_comments, _scan_directives
        obs_dir = Path(obs.__file__).parent
        files = sorted(obs_dir.glob("*.py"))
        assert files, obs_dir
        for f in files:
            src = f.read_text()
            tags, _, _ = _scan_directives(src, _scan_comments(src))
            assert "observability" in tags, f.name
            assert "exactness-critical" not in tags, f.name
