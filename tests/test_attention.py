"""Attention unit tests: blocked online-softmax vs naive oracle, the
block-skip schedule, GQA grouping, decode partials."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (blocked_attention, combine_partials,
                                    decode_attention,
                                    decode_attention_partial)


def naive_attention(q, k, v, causal=True, window=None):
    b, tq, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(d)
    qp, kp = jnp.arange(tq), jnp.arange(k.shape[1])
    mask = jnp.ones((tq, k.shape[1]), bool)
    if causal:
        mask &= qp[:, None] >= kp[None, :]
    if window is not None:
        mask &= qp[:, None] - kp[None, :] < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None, None], p, 0.0)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


@pytest.mark.parametrize("block_skip", [False, True])
@pytest.mark.parametrize("t,block,causal,window", [
    (130, 32, True, None), (128, 32, True, 48), (96, 32, False, None),
    (64, 128, True, None),
])
def test_blocked_vs_naive(t, block, causal, window, block_skip):
    q = jax.random.normal(jax.random.PRNGKey(0), (2, t, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, t, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, t, 2, 16))
    out = blocked_attention(q, k, v, causal=causal, window=window,
                            block=block, block_skip=block_skip)
    ref = naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


def test_block_skip_matches_dense_schedule():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 260, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 260, 4, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 260, 4, 16))
    a = blocked_attention(q, k, v, block=64, block_skip=False)
    b = blocked_attention(q, k, v, block=64, block_skip=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_decode_matches_naive_last_position():
    t = 33
    q = jax.random.normal(jax.random.PRNGKey(0), (2, t, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, t, 4, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, t, 4, 16))
    full = naive_attention(q, k, v, causal=True)
    out = decode_attention(q[:, -1:], k, v,
                           jnp.full((2,), t, jnp.int32))
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(full[:, -1]), rtol=1e-4,
                               atol=1e-5)


def test_flash_decode_partial_combine():
    """Sequence-sharded decode: partials from two shards == full answer."""
    s = 64
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, s, 4, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, s, 4, 16))
    valid = jnp.ones((2, s), bool)
    m, l, o = decode_attention_partial(q, k, v, valid)
    full = o / l[..., None]
    parts = [decode_attention_partial(q, k[:, :32], v[:, :32],
                                      valid[:, :32]),
             decode_attention_partial(q, k[:, 32:], v[:, 32:],
                                      valid[:, 32:])]
    combined = combine_partials(parts)
    np.testing.assert_allclose(np.asarray(combined), np.asarray(full),
                               rtol=1e-5, atol=1e-6)
