"""End-to-end system behaviour: train/resume determinism, fault tolerance,
serving engine, data pipeline."""

import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ModelConfig, ParallelConfig,
                                TrainConfig)
from repro.data.synthetic import DataConfig, image_batch, lm_batch
from repro.models import transformer as T
from repro.train import checkpoint as ckpt
from repro.train import train_loop as TL
from repro.train.ft import PreemptionHandler, StepWatchdog, run_with_restarts

CFG = ModelConfig(name="sys-tiny", family="lm", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                  dtype=jnp.float32)
TCFG = TrainConfig(lr=3e-3, warmup_steps=2, total_steps=50)
DCFG = DataConfig(vocab_size=64, seq_len=32, global_batch=8, task="copy")


def _run_steps(state, step_fn, start, n):
    m = None
    for i in range(start, start + n):
        batch = jax.tree.map(jnp.asarray, lm_batch(DCFG, i))
        state, m = step_fn(state, batch)
    return state, m


class TestTrainResume:
    def test_checkpoint_resume_is_bitexact(self):
        """10 straight steps == 5 steps + save/restore + 5 steps."""
        step_fn = jax.jit(TL.make_train_step(CFG, ParallelConfig(
            remat="none"), TCFG))
        s0 = TL.init_state(jax.random.PRNGKey(0), CFG, TCFG)
        sA, _ = _run_steps(s0, step_fn, 0, 10)

        sB, _ = _run_steps(TL.init_state(jax.random.PRNGKey(0), CFG, TCFG),
                           step_fn, 0, 5)
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 5, sB)
            sB2 = ckpt.restore(d, jax.eval_shape(lambda: sB))
        sB3, _ = _run_steps(sB2, step_fn, 5, 5)

        for a, b in zip(jax.tree.leaves(sA.params),
                        jax.tree.leaves(sB3.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)

    def test_data_pipeline_stateless_and_host_sharded(self):
        b_all = lm_batch(DCFG, 7)
        b_again = lm_batch(DCFG, 7)
        np.testing.assert_array_equal(b_all["tokens"], b_again["tokens"])
        h0 = lm_batch(dataclasses.replace(DCFG, host_index=0,
                                          host_count=2), 7)
        h1 = lm_batch(dataclasses.replace(DCFG, host_index=1,
                                          host_count=2), 7)
        np.testing.assert_array_equal(
            np.concatenate([h0["tokens"], h1["tokens"]]), b_all["tokens"])

    def test_copy_task_has_learnable_structure(self):
        b = lm_batch(DCFG, 0)
        t = b["tokens"][0]
        half = (DCFG.seq_len + 1) // 2 + 1
        assert np.array_equal(t[half:], t[:DCFG.seq_len - half])


class TestFaultTolerance:
    def test_preemption_flag(self):
        h = PreemptionHandler()
        assert not h.preempted()
        h.trigger()
        assert h.preempted()

    def test_watchdog_flags_straggler(self):
        import time
        w = StepWatchdog(straggler_factor=5.0, stall_timeout_s=60)
        for i in range(12):
            time.sleep(0.005)
            w.tick(i)
        time.sleep(0.2)
        w.tick(99)
        assert any(s == 99 for s, _, _ in w.straggler_events)
        assert not w.stalled()

    def test_run_with_restarts_recovers(self):
        calls = []

        def loop(start):
            calls.append(start)
            if len(calls) < 3:
                raise RuntimeError("simulated node failure")
            return 123

        assert run_with_restarts(loop, max_restarts=5) == 123
        assert len(calls) == 3

    def test_checkpoint_atomic_commit_marker(self):
        with tempfile.TemporaryDirectory() as d:
            tree = {"a": jnp.arange(4.0)}
            ckpt.save(d, 1, tree)
            assert os.path.exists(os.path.join(
                d, "step_000000000001.COMMITTED"))
            # uncommitted dirs are invisible to latest_step
            os.makedirs(os.path.join(d, "step_000000000999"))
            assert ckpt.latest_step(d) == 1

    def test_checkpoint_retention_gc(self):
        with tempfile.TemporaryDirectory() as d:
            tree = {"a": jnp.arange(4.0)}
            for s in (1, 2, 3, 4):
                ckpt.save(d, s, tree)
            ckpt.gc_old(d, keep=2)
            assert ckpt.latest_step(d) == 4
            with pytest.raises(FileNotFoundError):
                ckpt.restore(d, tree, step=1)

    def test_elastic_restore_to_new_sharding(self):
        # restore with explicit shardings — the reshard path used when the
        # mesh changes between runs (elastic scaling)
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import auto_axis_types
        mesh = jax.make_mesh((1,), ("data",), **auto_axis_types(1))
        tree = {"w": jnp.arange(8.0).reshape(2, 4)}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 3, tree)
            sh = {"w": NamedSharding(mesh, P("data", None))}
            out = ckpt.restore(d, tree, shardings=sh)
            np.testing.assert_array_equal(np.asarray(out["w"]),
                                          np.asarray(tree["w"]))
            assert out["w"].sharding == sh["w"]


class TestServeEngine:
    def test_continuous_batching_completes_all(self):
        from repro.serve.engine import Request, ServeEngine
        params = T.lm_init(jax.random.PRNGKey(0), CFG)
        eng = ServeEngine(params, CFG, slots=2, max_len=32)
        reqs = [Request(prompt=[1, 2, 3], max_new_tokens=4)
                for _ in range(5)]
        done = eng.run(reqs)
        assert len(done) == 5
        assert all(len(r.out) == 4 for r in done)

    def test_greedy_decode_deterministic(self):
        from repro.serve.engine import Request, ServeEngine
        params = T.lm_init(jax.random.PRNGKey(0), CFG)
        outs = []
        for _ in range(2):
            eng = ServeEngine(params, CFG, slots=1, max_len=32)
            done = eng.run([Request(prompt=[5, 6], max_new_tokens=6)])
            outs.append(done[0].out)
        assert outs[0] == outs[1]


class TestImageData:
    def test_class_blobs_deterministic_and_separable(self):
        x, y = image_batch(64, 10, 8, 1, 0)
        x2, y2 = image_batch(64, 10, 8, 1, 0)
        np.testing.assert_array_equal(x, x2)
        np.testing.assert_array_equal(y, y2)
        same = [float(np.corrcoef(x[i].ravel(), x[j].ravel())[0, 1])
                for i in range(16) for j in range(16)
                if i != j and y[i] == y[j]]
        diff = [float(np.corrcoef(x[i].ravel(), x[j].ravel())[0, 1])
                for i in range(16) for j in range(16) if y[i] != y[j]]
        assert np.mean(same or [1.0]) > np.mean(diff or [0.0])
