"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import cim_mav_ref, mf_matmul_ref


def _tol(dtype):
    # f32 tolerance allows tiling-order accumulation differences.
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-4, atol=1e-4)


class TestMFMatmulKernel:
    @pytest.mark.parametrize("m,k,n", [
        (8, 128, 128), (128, 128, 128), (5, 37, 9), (130, 260, 70),
        (1, 512, 256), (256, 96, 384),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shapes_dtypes(self, m, k, n, dtype):
        x = jax.random.normal(jax.random.PRNGKey(0), (m, k)).astype(dtype)
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n)).astype(dtype)
        y = ops.mf_matmul(x, w)
        yr = mf_matmul_ref(x, w)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yr, np.float32), **_tol(dtype))

    def test_batched(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (3, 4, 40))
        w = jax.random.normal(jax.random.PRNGKey(3), (40, 24))
        y = ops.mf_matmul(x, w)
        assert y.shape == (3, 4, 24)
        np.testing.assert_allclose(
            y.reshape(-1, 24), mf_matmul_ref(x.reshape(-1, 40), w),
            rtol=1e-5, atol=1e-5)

    def test_block_size_invariance(self):
        x = jax.random.normal(jax.random.PRNGKey(4), (64, 200))
        w = jax.random.normal(jax.random.PRNGKey(5), (200, 72))
        y1 = ops.mf_matmul(x, w, bm=32, bn=128, bk=128)
        y2 = ops.mf_matmul(x, w, bm=64, bn=256, bk=256)
        np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)


class TestCimMavKernel:
    @pytest.mark.parametrize("b,k,n", [(6, 70, 17), (8, 128, 128),
                                       (1, 31, 5), (16, 300, 64)])
    @pytest.mark.parametrize("m_cols,adc", [(31, 5), (15, 4), (31, 3)])
    def test_sweep(self, b, k, n, m_cols, adc):
        kg = jax.random.PRNGKey(b * 100 + k)
        kp = jax.random.PRNGKey(n)
        gates = jax.random.bernoulli(kg, 0.5, (b, k)).astype(jnp.float32)
        planes = jax.random.bernoulli(kp, 0.5, (7, k, n)).astype(jnp.float32)
        y = ops.cim_mav(gates, planes, m_columns=m_cols, adc_bits=adc)
        g2 = ops.pack_chunks(gates, m_cols)
        p2 = jnp.moveaxis(ops.pack_chunks(jnp.moveaxis(planes, -1, 1),
                                          m_cols), 1, -1)
        yr = cim_mav_ref(g2, p2, m_columns=m_cols, adc_bits=adc)
        np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)

    def test_pad_columns_inert(self):
        # Zero-pad lanes never 'discharge': result independent of K padding.
        gates = jnp.ones((2, 31), jnp.float32)
        planes = jnp.ones((3, 31, 8), jnp.float32)
        y1 = ops.cim_mav(gates, planes, m_columns=31, adc_bits=5)
        gates2 = jnp.pad(gates, ((0, 0), (0, 10)))
        planes2 = jnp.pad(planes, ((0, 0), (0, 10), (0, 0)))
        y2 = ops.cim_mav(gates2, planes2, m_columns=31, adc_bits=5)
        np.testing.assert_allclose(y1, y2)
